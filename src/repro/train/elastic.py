"""Elastic scaling: re-shard state onto whatever mesh a restart sees.

A 1000-node job loses hosts; the restart builds the largest healthy mesh
and resumes.  Because checkpoints are logical pytrees (host numpy) and
partition specs are FUNCTIONS of (tree, mesh) — not baked into the
checkpoint — restoring onto a different device count is just
``device_put`` with the new mesh's NamedShardings.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.train import checkpoint as ckpt_lib


def largest_mesh(axis_names: tuple[str, ...] = ("data", "model"),
                 model_parallelism: int = 1) -> Mesh:
    """Build the biggest mesh the surviving devices allow.

    ``model_parallelism`` is pinned (weights must fit); the data axis
    absorbs whatever device count remains — elastic data parallelism.
    """
    n = len(jax.devices())
    model = min(model_parallelism, n)
    data = n // model
    return jax.make_mesh((data, model), axis_names)


def shardings_for(tree: Any, mesh: Mesh,
                  spec_fn: Callable[[tuple, Any], P]) -> Any:
    """Pytree of NamedSharding from a (path, leaf) -> PartitionSpec rule."""
    def one(path, leaf):
        return NamedSharding(mesh, spec_fn(path, leaf))
    return jax.tree_util.tree_map_with_path(one, tree)


def reshard(tree: Any, shardings: Any) -> Any:
    return jax.tree.map(jax.device_put, tree, shardings)


def recover(ckpt_dir: str, template: Any, mesh: Mesh,
            spec_fn: Callable[[tuple, Any], P]) -> tuple[Any, int]:
    """Restore the latest checkpoint directly onto ``mesh``.

    Returns (state_tree, step).  Works for ANY device count: this is the
    elastic-restart entry point.
    """
    sh = shardings_for(template, mesh, spec_fn)
    return ckpt_lib.restore(ckpt_dir, template, shardings=sh)
