from repro.train import checkpoint, data, elastic, loop, optimizer  # noqa: F401
