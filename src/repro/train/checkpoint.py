"""Atomic, versioned, mesh-elastic checkpointing (no orbax).

Layout on disk:
  <dir>/step_<N>/arrays.npz      flattened pytree leaves by index
  <dir>/step_<N>/manifest.json   treedef repr, shapes/dtypes, metadata
  <dir>/step_<N>/.complete       commit marker (written LAST)

Guarantees:
  * atomic: a checkpoint is only considered valid once ``.complete``
    exists; interrupted writes are garbage-collected on the next save.
  * elastic restore: leaves are restored host-side then ``device_put``
    with whatever sharding the CURRENT mesh prescribes — a job restarted
    on a different device count re-shards transparently (train/elastic).
  * keep_last trimming for bounded disk usage.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

COMPLETE = ".complete"


def _step_dir(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"step_{step:08d}")


def save(ckpt_dir: str, step: int, tree: Any, metadata: dict | None = None,
         keep_last: int = 3) -> str:
    """Write one checkpoint atomically; returns the committed path."""
    leaves, treedef = jax.tree.flatten(tree)
    os.makedirs(ckpt_dir, exist_ok=True)
    final = _step_dir(ckpt_dir, step)
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        arrays = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        manifest = {
            "step": step,
            "treedef": str(treedef),
            "n_leaves": len(leaves),
            "shapes": [list(np.shape(x)) for x in leaves],
            "dtypes": [str(np.asarray(x).dtype) for x in leaves],
            "metadata": metadata or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp, COMPLETE), "w") as f:
            f.write("ok")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(ckpt_dir, keep_last)
    return final


def _gc(ckpt_dir: str, keep_last: int) -> None:
    steps = list_steps(ckpt_dir)
    for s in steps[:-keep_last] if keep_last > 0 else []:
        shutil.rmtree(_step_dir(ckpt_dir, s), ignore_errors=True)
    # remove orphaned tmp dirs from crashed saves
    for name in os.listdir(ckpt_dir):
        if name.startswith(".tmp_"):
            shutil.rmtree(os.path.join(ckpt_dir, name), ignore_errors=True)


def list_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and \
                os.path.exists(os.path.join(ckpt_dir, name, COMPLETE)):
            out.append(int(name[5:]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> int | None:
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, like: Any, step: int | None = None,
            shardings: Any = None) -> tuple[Any, int]:
    """Restore into the structure of ``like`` (a pytree template).

    ``shardings``: optional pytree of NamedSharding matching ``like`` —
    leaves are placed directly onto the current mesh (elastic restore).
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = _step_dir(ckpt_dir, step)
    if not os.path.exists(os.path.join(d, COMPLETE)):
        raise FileNotFoundError(f"checkpoint {d} incomplete")
    with np.load(os.path.join(d, "arrays.npz")) as z:
        leaves = [z[f"leaf_{i}"] for i in range(len(z.files))]
    _, treedef = jax.tree.flatten(like)
    like_leaves = jax.tree.leaves(like)
    assert len(leaves) == len(like_leaves), \
        f"leaf count mismatch: {len(leaves)} vs {len(like_leaves)}"
    cast = [np.asarray(a).astype(np.asarray(b).dtype if hasattr(b, 'dtype')
                                 else a.dtype)
            for a, b in zip(leaves, like_leaves)]
    if shardings is not None:
        sh_leaves = treedef.flatten_up_to(shardings)
        placed = [jax.device_put(a, s) for a, s in zip(cast, sh_leaves)]
    else:
        placed = [jnp.asarray(a) for a in cast]
    return treedef.unflatten(placed), step


def read_metadata(ckpt_dir: str, step: int) -> dict:
    with open(os.path.join(_step_dir(ckpt_dir, step), "manifest.json")) as f:
        return json.load(f)["metadata"]
