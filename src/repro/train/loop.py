"""Training loop: checkpoint/restart, straggler deadlines, retry.

Fault-tolerance contract (exercised by tests/test_train.py):
  * data is deterministic-by-step -> a restart resumes from the latest
    checkpoint and replays the exact same batches;
  * a per-step wall-clock deadline flags stragglers (on a real cluster
    the runner re-dispatches the step; here we record + retry);
  * transient step failures (device OOM-retry, preempted host) retry up
    to ``max_retries`` with the same inputs — safe because steps are
    pure functions of (params, opt_state, batch).
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable

import jax

from repro.train import checkpoint as ckpt_lib

log = logging.getLogger("repro.train")


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_dir: str | None = None
    ckpt_every: int = 50
    keep_last: int = 3
    log_every: int = 10
    step_deadline_s: float = 0.0     # 0 = disabled
    max_retries: int = 2


@dataclasses.dataclass
class LoopResult:
    params: Any
    opt_state: Any
    step: int
    metrics: dict
    stragglers: int = 0
    retries: int = 0


def fit(step_fn: Callable, params, opt_state, make_batch: Callable[[int], Any],
        cfg: LoopConfig, to_device: Callable[[Any], Any] = None) -> LoopResult:
    """Run the loop.  ``step_fn(params, opt_state, batch) ->
    (params, opt_state, metrics)``; ``make_batch(step) -> batch`` must be
    deterministic in ``step``.
    """
    start = 0
    if cfg.ckpt_dir:
        latest = ckpt_lib.latest_step(cfg.ckpt_dir)
        if latest is not None:
            (params, opt_state), _ = ckpt_lib.restore(
                cfg.ckpt_dir, (params, opt_state), step=latest)
            start = latest
            log.info("resumed from step %d", start)

    stragglers = retries = 0
    metrics: dict = {}
    for step in range(start, cfg.total_steps):
        batch = make_batch(step)
        if to_device is not None:
            batch = to_device(batch)
        t0 = time.monotonic()
        for attempt in range(cfg.max_retries + 1):
            try:
                params, opt_state, metrics = step_fn(params, opt_state,
                                                     batch)
                metrics = jax.tree.map(
                    lambda x: x.block_until_ready()
                    if hasattr(x, "block_until_ready") else x, metrics)
                break
            except Exception as e:            # noqa: BLE001 — retry path
                retries += 1
                log.warning("step %d attempt %d failed: %s", step, attempt,
                            e)
                if attempt == cfg.max_retries:
                    raise
        dt = time.monotonic() - t0
        if cfg.step_deadline_s and dt > cfg.step_deadline_s:
            stragglers += 1
            log.warning("straggler: step %d took %.3fs (deadline %.3fs)",
                        step, dt, cfg.step_deadline_s)
        if cfg.log_every and (step + 1) % cfg.log_every == 0:
            loss = metrics.get("loss")
            log.info("step %d loss=%s (%.3fs)", step + 1,
                     float(loss) if loss is not None else None, dt)
        if cfg.ckpt_dir and (step + 1) % cfg.ckpt_every == 0:
            ckpt_lib.save(cfg.ckpt_dir, step + 1, (params, opt_state),
                          metadata={"loss": float(metrics.get("loss", 0.0))},
                          keep_last=cfg.keep_last)
    if cfg.ckpt_dir and cfg.total_steps > start and \
            cfg.total_steps % cfg.ckpt_every != 0:
        ckpt_lib.save(cfg.ckpt_dir, cfg.total_steps, (params, opt_state),
                      metadata={"loss": float(metrics.get("loss", 0.0))},
                      keep_last=cfg.keep_last)
    return LoopResult(params=params, opt_state=opt_state,
                      step=cfg.total_steps, metrics=metrics,
                      stragglers=stragglers, retries=retries)
