"""AdamW + schedules + gradient clipping, from scratch (no optax).

State is a pytree mirroring params (m, v) + a scalar step — it inherits
the params' sharding under GSPMD, i.e. fully sharded optimizer state
(ZeRO-style) falls out of the param partition specs for free.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class AdamWState(NamedTuple):
    step: Array        # i32 scalar
    m: dict            # first moment  (mirrors params)
    v: dict            # second moment (mirrors params)


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    schedule: str = "cosine"      # "cosine" | "linear" | "constant"


def lr_at(cfg: AdamWConfig, step: Array) -> Array:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((s - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    if cfg.schedule == "cosine":
        decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * \
            0.5 * (1 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear":
        decay = 1.0 - (1.0 - cfg.min_lr_ratio) * frac
    else:
        decay = jnp.ones(())
    return cfg.lr * warm * decay


def init(params) -> AdamWState:
    zeros = jax.tree.map(jnp.zeros_like, params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.zeros_like, params))


def global_norm(tree) -> Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def update(cfg: AdamWConfig, grads, state: AdamWState, params):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12)) \
        if cfg.clip_norm > 0 else jnp.ones(())
    step = state.step + 1
    lr = lr_at(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m2 / b1c
        vhat = v2 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step=step, m=new_m, v=new_v), metrics


def make_train_step(loss_fn: Callable, cfg: AdamWConfig,
                    microbatches: int = 1) -> Callable:
    """Build a full train step: (params, opt_state, batch) -> (..., loss).

    ``microbatches`` > 1 accumulates gradients over leading-dim splits of
    the batch (gradient accumulation — shrinks peak activation memory).
    """
    def step(params, opt_state, batch):
        if microbatches <= 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                return x.reshape((microbatches, b // microbatches) +
                                 x.shape[1:])
            mb = jax.tree.map(split, batch)

            def acc_body(carry, mb_i):
                loss_acc, grad_acc = carry
                loss_i, grads_i = jax.value_and_grad(loss_fn)(params, mb_i)
                grad_acc = jax.tree.map(jnp.add, grad_acc, grads_i)
                return (loss_acc + loss_i, grad_acc), None

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                acc_body, (jnp.zeros(()), zero_g), mb)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        new_params, new_state, metrics = update(cfg, grads, opt_state,
                                                params)
        metrics["loss"] = loss
        return new_params, new_state, metrics

    return step
