"""Synthetic, shardable, deterministic-by-step data pipelines.

Every batch is a pure function of (seed, step) — after a crash/restart
the pipeline replays exactly, which is what makes checkpoint/restart
byte-identical (fault tolerance contract).  A small background
prefetcher overlaps host batch synthesis with device compute.

Includes the REAL neighbor sampler required by the GNN ``minibatch_lg``
cell: uniform fanout sampling over a CSR adjacency, emitting fixed-shape
padded subgraphs.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator

import numpy as np

# ---------------------------------------------------------------------------
# prefetcher
# ---------------------------------------------------------------------------


class Prefetcher:
    """Background-thread batch prefetch (depth-bounded)."""

    def __init__(self, make_batch: Callable[[int], dict], start_step: int,
                 depth: int = 2):
        self._make = make_batch
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        s = self._step
        while not self._stop.is_set():
            try:
                self._q.put(self._make(s), timeout=0.1)
                s += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[dict]:
        while True:
            yield self._q.get()

    def close(self):
        self._stop.set()


# ---------------------------------------------------------------------------
# LM
# ---------------------------------------------------------------------------


def lm_batch(seed: int, step: int, batch: int, seq: int, vocab: int) -> dict:
    rng = np.random.default_rng((seed, step))
    toks = rng.integers(0, vocab, size=(batch, seq + 1), dtype=np.int64)
    return {"tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32)}


# ---------------------------------------------------------------------------
# recsys
# ---------------------------------------------------------------------------


def sasrec_batch(seed: int, step: int, batch: int, seq: int, n_items: int,
                 n_neg: int) -> dict:
    rng = np.random.default_rng((seed, step))
    hist = rng.integers(1, n_items, size=(batch, seq)).astype(np.int32)
    pos = rng.integers(1, n_items, size=(batch, seq)).astype(np.int32)
    neg = rng.integers(1, n_items, size=(batch, seq, n_neg)).astype(np.int32)
    return {"hist": hist, "pos": pos, "neg": neg}


def bert4rec_batch(seed: int, step: int, batch: int, seq: int, n_items: int,
                   n_neg: int, mask_frac: float = 0.2) -> dict:
    rng = np.random.default_rng((seed, step))
    hist = rng.integers(1, n_items, size=(batch, seq)).astype(np.int32)
    maskpos = rng.random((batch, seq)) < mask_frac
    targets = np.where(maskpos, hist, 0).astype(np.int32)
    hist = np.where(maskpos, n_items, hist).astype(np.int32)   # [MASK] id
    neg = rng.integers(1, n_items, size=(batch, seq, n_neg)).astype(np.int32)
    return {"hist": hist, "targets": targets, "neg": neg}


def dien_batch(seed: int, step: int, batch: int, seq: int, n_items: int
               ) -> dict:
    rng = np.random.default_rng((seed, step))
    return {
        "hist": rng.integers(1, n_items, size=(batch, seq)).astype(np.int32),
        "target": rng.integers(1, n_items, size=(batch,)).astype(np.int32),
        "label": rng.integers(0, 2, size=(batch,)).astype(np.float32),
        "aux_neg": rng.integers(1, n_items,
                                size=(batch, seq)).astype(np.int32),
    }


def xdeepfm_batch(seed: int, step: int, batch: int, n_fields: int,
                  vocab: int, n_hot: int = 1) -> dict:
    rng = np.random.default_rng((seed, step))
    shape = (batch, n_fields) if n_hot == 1 else (batch, n_fields, n_hot)
    return {
        "sparse": rng.integers(0, vocab, size=shape).astype(np.int32),
        "label": rng.integers(0, 2, size=(batch,)).astype(np.float32),
    }


# ---------------------------------------------------------------------------
# graphs
# ---------------------------------------------------------------------------


class CsrGraph:
    """Host-side CSR adjacency (the paper's layout, applied to graphs)."""

    def __init__(self, indptr: np.ndarray, indices: np.ndarray,
                 feats: np.ndarray, labels: np.ndarray):
        self.indptr = indptr
        self.indices = indices
        self.feats = feats
        self.labels = labels

    @property
    def num_nodes(self) -> int:
        return len(self.indptr) - 1

    @property
    def num_edges(self) -> int:
        return len(self.indices)


def make_synthetic_graph(n_nodes: int, n_edges: int, d_feat: int,
                         n_classes: int, seed: int = 0) -> CsrGraph:
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_nodes, n_edges)
    dst = rng.integers(0, n_nodes, n_edges)
    order = np.argsort(src, kind="stable")
    src, dst = src[order], dst[order]
    counts = np.bincount(src, minlength=n_nodes)
    indptr = np.zeros(n_nodes + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    feats = rng.normal(size=(n_nodes, d_feat)).astype(np.float32)
    labels = rng.integers(0, n_classes, n_nodes).astype(np.int32)
    return CsrGraph(indptr, dst.astype(np.int32), feats, labels)


class NeighborSampler:
    """Uniform fanout sampler over CSR: the minibatch_lg training path.

    Emits FIXED-SHAPE padded subgraphs: seeds + fanout[0] 1-hop +
    fanout[0]*fanout[1] 2-hop neighbors; missing neighbors are padded
    with edge endpoints == n_sub (dropped by segment ops).
    """

    def __init__(self, graph: CsrGraph, batch_nodes: int,
                 fanout: tuple[int, ...], seed: int = 0):
        self.g = graph
        self.batch_nodes = batch_nodes
        self.fanout = fanout

    def sample(self, step: int) -> dict:
        rng = np.random.default_rng((hash("sampler") & 0xFFFF, step))
        g = self.g
        seeds = rng.integers(0, g.num_nodes, self.batch_nodes)
        frontier = seeds
        all_src, all_dst = [], []
        nodes = [seeds]
        for f in self.fanout:
            deg = g.indptr[frontier + 1] - g.indptr[frontier]
            # sample f neighbors per frontier node (with repl.; deg==0 pads)
            offs = rng.integers(0, np.maximum(deg, 1)[:, None],
                                size=(len(frontier), f))
            nbr = g.indices[np.minimum(g.indptr[frontier, None] + offs,
                                       len(g.indices) - 1)]
            valid = (deg > 0)[:, None] & np.ones_like(offs, bool)
            nbr = np.where(valid, nbr, -1)
            src = nbr.reshape(-1)
            dst = np.repeat(frontier, f)
            keep = src >= 0
            all_src.append(np.where(keep, src, 0))
            all_dst.append(np.where(keep, dst, -1))
            nodes.append(np.where(keep, src, 0))
            frontier = nbr.reshape(-1)
            frontier = np.where(frontier >= 0, frontier, 0)

        # relabel global ids -> compact local ids (vectorized searchsorted)
        all_nodes = np.concatenate(nodes)
        uniq = np.unique(all_nodes)
        cap = self.batch_nodes          # static node capacity of a block
        m = self.batch_nodes
        for f in self.fanout:
            m = m * f
            cap += m
        src = np.concatenate(all_src)
        dst = np.concatenate(all_dst)
        loc_src = np.searchsorted(uniq, src).astype(np.int32)
        loc_dst = np.where(dst >= 0,
                           np.searchsorted(uniq, np.maximum(dst, 0)),
                           -1).astype(np.int32)
        n_sub = len(uniq)
        seed_loc = np.searchsorted(uniq, seeds)
        feats = np.zeros((cap, g.feats.shape[1]), np.float32)
        feats[:n_sub] = g.feats[uniq]
        labels = np.zeros((cap,), np.int32)
        labels[:n_sub] = g.labels[uniq]
        mask = np.zeros((cap,), bool)
        mask[seed_loc] = True
        # pad edge arrays to fixed size
        e_cap = sum(self.batch_nodes * int(np.prod(self.fanout[:i + 1]))
                    for i in range(len(self.fanout)))
        es = np.full((e_cap,), cap, np.int32)
        ed = np.full((e_cap,), cap, np.int32)
        keep = loc_dst >= 0
        es[:keep.sum()] = loc_src[keep]
        ed[:keep.sum()] = loc_dst[keep]
        return {"feats": feats, "src": es, "dst": ed, "labels": labels,
                "mask": mask}


def molecule_batch(seed: int, step: int, n_graphs: int, nodes_per: int,
                   edges_per: int, d_feat: int, n_classes: int) -> dict:
    rng = np.random.default_rng((seed, step))
    n = n_graphs * nodes_per
    e = n_graphs * edges_per
    base = np.repeat(np.arange(n_graphs) * nodes_per, edges_per)
    src = (rng.integers(0, nodes_per, e) + base).astype(np.int32)
    dst = (rng.integers(0, nodes_per, e) + base).astype(np.int32)
    return {
        "feats": rng.normal(size=(n, d_feat)).astype(np.float32),
        "src": src, "dst": dst,
        "graph_ids": np.repeat(np.arange(n_graphs), nodes_per
                               ).astype(np.int32),
        "g_labels": rng.integers(0, n_classes, n_graphs).astype(np.int32),
    }


def fullgraph_batch(graph: CsrGraph, train_frac: float = 0.5,
                    seed: int = 0) -> dict:
    """Full-batch node-classification inputs from a CSR graph."""
    g = graph
    src = np.repeat(np.arange(g.num_nodes, dtype=np.int32),
                    np.diff(g.indptr).astype(np.int32))
    rng = np.random.default_rng(seed)
    return {"feats": g.feats, "src": src, "dst": g.indices,
            "labels": g.labels,
            "mask": rng.random(g.num_nodes) < train_frac}
