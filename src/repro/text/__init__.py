from repro.text.corpus import CorpusSpec, PAPER_SPEC, generate, sample_query_terms  # noqa: F401
from repro.text.tokenizer import tokenize, stem, fnv1a, hash_terms, mix32  # noqa: F401
