"""Hash tokenizer + stemmer-lite.

The paper's engine (Mitos) stems Greek text and maps words to integer
ids via a word table.  We provide (a) a real-text path — lowercase,
alnum-split, crude suffix stemming, FNV-1a hashing — and (b) a
synthetic path where term ids are mapped to uint32 hashes through a
*bijective* avalanche mix (no collisions by construction), which all
synthetic-corpus tests and benchmarks use.
"""
from __future__ import annotations

import re
from typing import Iterable

import numpy as np

_WORD_RE = re.compile(r"[a-z0-9]+")
_SUFFIXES = ("ations", "ation", "ingly", "ities", "ing", "ions", "ies",
             "edly", "ed", "es", "ly", "s")


def stem(word: str) -> str:
    """Crude suffix stripper ('information' -> 'informat', as the paper)."""
    for suf in _SUFFIXES:
        if word.endswith(suf) and len(word) - len(suf) >= 3:
            return word[: len(word) - len(suf)]
    return word


def tokenize(text: str) -> list[str]:
    return [stem(w) for w in _WORD_RE.findall(text.lower())]


def fnv1a(word: str) -> np.uint32:
    h = np.uint32(2166136261)
    for b in word.encode("utf-8"):
        h = np.uint32(h ^ np.uint32(b))
        h = np.uint32(h * np.uint32(16777619))
    return np.uint32(max(int(h), 1))  # 0 is the "empty query slot" sentinel


def hash_terms(words: Iterable[str]) -> np.ndarray:
    return np.array([fnv1a(w) for w in words], dtype=np.uint32)


def mix32(x: np.ndarray) -> np.ndarray:
    """Bijective 32-bit finalizer (murmur3-style): term id -> unique hash."""
    x = x.astype(np.uint64)
    x ^= x >> 16
    x = (x * 0x85EBCA6B) & 0xFFFFFFFF
    x ^= x >> 13
    x = (x * 0xC2B2AE35) & 0xFFFFFFFF
    x ^= x >> 16
    x = np.maximum(x, 1)  # avoid the empty-slot sentinel 0
    return x.astype(np.uint32)
