"""Synthetic Zipf corpus calibrated to the paper's collection (§4).

The paper's 1,004,721-document Greek crawl is not redistributable; we
generate corpora whose *statistics* match: W distinct terms, average
~239 distinct words per document, Zipf-distributed term frequencies, and
query terms drawn from a high-df band (the paper picks df ≈ 300,000 for
D ≈ 1M, i.e. df/D ≈ 0.3).  Sizes scale down for CPU-runnable tests; the
paper-scale numbers are reproduced analytically via core/size_model.py.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.build import TokenizedCorpus
from repro.text.tokenizer import mix32


@dataclasses.dataclass(frozen=True)
class CorpusSpec:
    num_docs: int = 2_000
    vocab: int = 5_000
    avg_distinct: int = 60      # paper: 239
    zipf_s: float = 1.07
    seed: int = 0


# The paper's collection, for analytic (size-model) reproduction.
PAPER_SPEC = CorpusSpec(num_docs=1_004_721, vocab=216_449, avg_distinct=239)


def _zipf_cdf(vocab: int, s: float) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks ** (-s)
    p /= p.sum()
    return np.cumsum(p)


def generate(spec: CorpusSpec) -> TokenizedCorpus:
    """Vectorized Zipf corpus: per-doc distinct terms + counts."""
    rng = np.random.default_rng(spec.seed)
    cdf = _zipf_cdf(spec.vocab, spec.zipf_s)

    # Document lengths (token draws before dedup): lognormal around the
    # target, then dedup produces distinct-term lists.
    target = max(spec.avg_distinct, 1)
    raw_len = rng.lognormal(mean=np.log(target * 1.6), sigma=0.5,
                            size=spec.num_docs)
    raw_len = np.clip(raw_len.astype(np.int64), 4, spec.vocab * 4)

    doc_term_ids: list[np.ndarray] = []
    doc_counts: list[np.ndarray] = []
    boundaries = np.zeros(spec.num_docs + 1, dtype=np.int64)
    np.cumsum(raw_len, out=boundaries[1:])
    total = int(boundaries[-1])
    u = rng.random(total)
    tokens = np.searchsorted(cdf, u).astype(np.int64)  # Zipf-ranked ids
    tokens = np.minimum(tokens, spec.vocab - 1)
    for d in range(spec.num_docs):
        toks = tokens[boundaries[d]:boundaries[d + 1]]
        terms, counts = np.unique(toks, return_counts=True)
        doc_term_ids.append(terms)
        doc_counts.append(counts)

    term_hashes = mix32(np.arange(spec.vocab, dtype=np.uint32))
    return TokenizedCorpus(doc_term_ids=doc_term_ids, doc_counts=doc_counts,
                           term_hashes=term_hashes, num_docs=spec.num_docs)


def _batch_from_tokens(tokens: np.ndarray, boundaries: np.ndarray,
                       term_hashes: np.ndarray) -> TokenizedCorpus:
    """Vectorized per-doc dedup: one lexsort over the whole batch instead
    of a ``np.unique`` per document (the per-doc loop dominates build
    time at million-page scale)."""
    n_docs = len(boundaries) - 1
    doc_idx = np.repeat(np.arange(n_docs, dtype=np.int64),
                        np.diff(boundaries))
    order = np.lexsort((tokens, doc_idx))
    d, t = doc_idx[order], tokens[order]
    # run boundaries of (doc, term) pairs
    first = np.ones(len(t), dtype=bool)
    first[1:] = (d[1:] != d[:-1]) | (t[1:] != t[:-1])
    starts = np.flatnonzero(first)
    counts = np.diff(np.append(starts, len(t))).astype(np.int64)
    run_docs = d[starts]
    run_terms = t[starts]
    per_doc = np.bincount(run_docs, minlength=n_docs)
    splits = np.cumsum(per_doc)[:-1]
    doc_term_ids = np.split(run_terms, splits)
    doc_counts = np.split(counts, splits)
    return TokenizedCorpus(doc_term_ids=doc_term_ids,
                           doc_counts=doc_counts,
                           term_hashes=term_hashes, num_docs=n_docs)


def stream_batches(spec: CorpusSpec, batch_docs: int = 50_000):
    """Yield the corpus of ``spec`` as TokenizedCorpus batches of at most
    ``batch_docs`` documents WITHOUT materializing the full collection —
    host RAM is bounded by one batch regardless of ``spec.num_docs``.

    Determinism contract: the stream is a pure function of ``(spec,
    batch_docs)`` — each batch draws from its own ``seed + batch index``
    substream, so rerunning with the same batching reproduces the exact
    corpus (this is what makes the committed BENCH artifacts
    re-runnable).  Changing ``batch_docs`` moves batch boundaries and
    therefore reseeds every draw: the token draws differ, and only the
    DISTRIBUTIONAL statistics (Zipf term frequencies, lognormal doc
    lengths) are batching-independent.  Likewise the stream is NOT the
    same corpus as one-shot ``generate``; streams and one-shot corpora
    are distinct corpora by design.

    Feed each batch to ``SegmentedIndex.add_batch(batch,
    refresh_norms=False)`` and call ``refresh_norms()`` once after the
    final ``seal()`` — norms depend only on final global df, so deferring
    the refresh turns a quadratic rescan into a single pass.
    """
    if batch_docs < 1:
        raise ValueError("batch_docs must be >= 1")
    cdf = _zipf_cdf(spec.vocab, spec.zipf_s)
    term_hashes = mix32(np.arange(spec.vocab, dtype=np.uint32))
    target = max(spec.avg_distinct, 1)
    done = 0
    batch_i = 0
    while done < spec.num_docs:
        n = min(batch_docs, spec.num_docs - done)
        rng = np.random.default_rng(spec.seed + 7919 * (batch_i + 1))
        raw_len = rng.lognormal(mean=np.log(target * 1.6), sigma=0.5,
                                size=n)
        raw_len = np.clip(raw_len.astype(np.int64), 4, spec.vocab * 4)
        boundaries = np.zeros(n + 1, dtype=np.int64)
        np.cumsum(raw_len, out=boundaries[1:])
        u = rng.random(int(boundaries[-1]))
        tokens = np.searchsorted(cdf, u).astype(np.int64)
        tokens = np.minimum(tokens, spec.vocab - 1)
        yield _batch_from_tokens(tokens, boundaries, term_hashes)
        done += n
        batch_i += 1


def sample_query_terms(df: np.ndarray, term_hashes: np.ndarray,
                       num_queries: int, terms_per_query: int,
                       df_band: tuple[float, float] = (0.15, 0.5),
                       num_docs: int | None = None,
                       seed: int = 1) -> np.ndarray:
    """Query workload mirroring §4.3: frequent terms (df in a high band).

    Returns u32[num_queries, terms_per_query] hash matrix (0-padded).
    """
    rng = np.random.default_rng(seed)
    D = num_docs if num_docs is not None else int(df.max()) + 1
    frac = df / max(D, 1)
    pool = np.where((frac >= df_band[0]) & (frac <= df_band[1]))[0]
    if len(pool) < terms_per_query:
        pool = np.argsort(df)[::-1][:max(terms_per_query * 8, 64)]
    out = np.zeros((num_queries, terms_per_query), dtype=np.uint32)
    for q in range(num_queries):
        pick = rng.choice(pool, size=terms_per_query,
                          replace=len(pool) < terms_per_query)
        out[q] = term_hashes[pick]
    return out
