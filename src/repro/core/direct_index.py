"""Direct (forward) index: document-based access, paper §4.4.

The paper measures query expansion without any doc->terms access path:
PR degenerates to a 16-hour sequential scan over 240M tuples and even
ORIF takes ~20 minutes.  Its proposed fix — which we implement as a
first-class structure — is a *direct index* stored in the same ORIF
(CSR) representation: for each doc, the packed list of (term_id, tf).

Supported tasks (paper §3.3): query expansion (top terms of top docs),
relevance feedback (terms of user-marked docs), document deletion.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import segments
from repro.core.layouts import PostingsHost, _register

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DirectIndex:
    """CSR doc -> (term_id, tf): the ORIF-representation forward index."""
    _static_fields = ("max_doc_len",)
    offsets: Array    # i32[D+1]
    term_ids: Array   # i32[Nd]
    tfs: Array        # f32[Nd]
    max_doc_len: int

    @property
    def num_docs(self) -> int:
        return self.offsets.shape[0] - 1

    def doc_terms(self, doc_ids: Array, cap: int):
        """Gather each doc's packed (term, tf) slab."""
        t, valid = segments.gather_segments(self.term_ids, self.offsets,
                                            doc_ids, cap, fill=-1)
        f, _ = segments.gather_segments(self.tfs, self.offsets, doc_ids, cap,
                                        fill=0.0)
        return t, f, valid

    def nbytes(self) -> int:
        return int(self.offsets.nbytes + self.term_ids.nbytes +
                   self.tfs.nbytes)


_register(DirectIndex)


def build_direct(h: PostingsHost) -> DirectIndex:
    """Transpose the canonical term-major postings into doc-major CSR."""
    term_of = np.repeat(np.arange(h.num_terms, dtype=np.int64),
                        np.diff(h.offsets))
    order = np.argsort(h.doc_ids, kind="stable")
    docs_sorted = h.doc_ids[order]
    counts = np.bincount(docs_sorted, minlength=h.num_docs)
    offsets = np.zeros(h.num_docs + 1, dtype=np.int64)
    np.cumsum(counts, out=offsets[1:])
    return DirectIndex(
        offsets=jnp.asarray(offsets.astype(np.int32)),
        term_ids=jnp.asarray(term_of[order].astype(np.int32)),
        tfs=jnp.asarray(h.tfs[order].astype(np.float32)),
        max_doc_len=int(counts.max()) if len(counts) else 0,
    )


class ExpansionResult(NamedTuple):
    term_ids: Array   # i32[n_terms]
    weights: Array    # f32[n_terms]


def expand_query(direct: DirectIndex, top_docs: Array, num_terms: int,
                 cap: int, n_suggest: int = 5,
                 exclude_terms: Array | None = None) -> ExpansionResult:
    """Paper §4.4: sum tf of every term over the top docs, suggest top-n.

    ``top_docs`` i32[n] (pad with -1).  O(n·cap) with the direct index,
    versus a full posting scan without it.
    """
    safe = jnp.maximum(top_docs, 0)
    t, f, valid = direct.doc_terms(safe, cap)
    valid = valid & (top_docs >= 0)[:, None]
    flat_t = jnp.where(valid, t, num_terms).reshape(-1)
    flat_f = jnp.where(valid, f, 0.0).reshape(-1)
    sums = jnp.zeros((num_terms + 1,), jnp.float32)
    sums = sums.at[flat_t].add(flat_f, mode="drop")[:num_terms]
    if exclude_terms is not None:
        excl = jnp.maximum(exclude_terms, 0)
        sums = sums.at[excl].set(
            jnp.where(exclude_terms >= 0, 0.0, sums[excl]), mode="drop")
    w, ids = jax.lax.top_k(sums, n_suggest)
    return ExpansionResult(term_ids=jnp.where(w > 0, ids, -1), weights=w)


def expand_query_scan(index: Any, top_docs: Array, num_terms: int,
                      n_suggest: int = 5) -> ExpansionResult:
    """The degenerate path the paper measured (no doc-access structure):
    a full sequential scan of the posting relation filtering by doc id.
    Works on any layout exposing flat (doc_ids, tfs) columns; used by the
    §4.4 benchmark to reproduce the PR-without-index blowup.
    """
    # flat columns: CooIndex heap order or CSR packed order — either way a
    # FULL scan of P postings.
    doc_col = index.doc_ids
    tf_col = index.tfs
    if hasattr(index, "word_ids"):
        term_col = index.word_ids
    else:
        term_col = segments.offsets_to_segment_ids(index.offsets,
                                                   doc_col.shape[0])
    # -1 padding in top_docs never matches a real doc id, so isin is safe.
    member = jnp.isin(doc_col, top_docs)
    w = jnp.where(member, tf_col, 0.0)
    sums = jnp.zeros((num_terms + 1,), jnp.float32)
    sums = sums.at[jnp.where(member, term_col, num_terms)].add(w, mode="drop")
    sums = sums[:num_terms]
    ww, ids = jax.lax.top_k(sums, n_suggest)
    return ExpansionResult(term_ids=jnp.where(ww > 0, ids, -1), weights=ww)


def relevance_feedback(direct: DirectIndex, marked_docs: Array,
                       query_term_ids: Array, num_terms: int, cap: int,
                       alpha: float = 1.0, beta: float = 0.75,
                       n_terms: int = 10) -> ExpansionResult:
    """Rocchio-style feedback using the direct index (document access)."""
    exp = expand_query(direct, marked_docs, num_terms, cap,
                       n_suggest=n_terms)
    boost = jnp.zeros((num_terms + 1,), jnp.float32)
    boost = boost.at[jnp.maximum(query_term_ids, 0)].add(
        jnp.where(query_term_ids >= 0, alpha, 0.0), mode="drop")
    sums = jnp.zeros((num_terms + 1,), jnp.float32)
    sums = sums.at[jnp.maximum(exp.term_ids, 0)].add(
        jnp.where(exp.term_ids >= 0, beta * exp.weights, 0.0), mode="drop")
    merged = (boost + sums)[:num_terms]
    w, ids = jax.lax.top_k(merged, n_terms)
    return ExpansionResult(term_ids=jnp.where(w > 0, ids, -1), weights=w)


def delete_docs(docs_norm: Array, doc_ids: Array) -> Array:
    """Document deletion = zeroing the norm (scoring then skips the doc).

    Postings stay in place until the next bulk rebuild — exactly the
    paper's §3.6 maintenance model (drop/bulk/rebuild).
    """
    safe = jnp.maximum(doc_ids, 0)
    return docs_norm.at[safe].set(
        jnp.where(doc_ids >= 0, 0.0, docs_norm[safe]), mode="drop")
