"""Core: the paper's contribution — index storage layouts + query evaluation."""
from repro.core.layouts import (  # noqa: F401
    BLOCK, BlockedIndex, CompactCsrIndex, CooIndex, CsrIndex, DocTable,
    PackedCsrIndex, PostingsHost, REPRESENTATIONS, build_blocked,
    build_compact_csr, build_coo, build_csr, build_packed_csr,
)
from repro.core.build import TokenizedCorpus, add_documents, bulk_build, corpus_stats  # noqa: F401
from repro.core.direct_index import DirectIndex, build_direct, expand_query  # noqa: F401
from repro.core.query import QueryResult, make_scorer, score_queries, score_query  # noqa: F401
from repro.core import size_model  # noqa: F401
