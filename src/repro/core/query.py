"""Query evaluation — the paper's §3.7 elementary queries over any layout.

The paper decomposes vector-space evaluation into three elementary
queries (Table 3):

  q_word : term name -> (term id, df)         [lookup phase]
  q_occ  : term id   -> posting list (doc,tf) [gather phase]
  q_doc  : doc ids   -> (norm, rank)          [doc-metadata phase]

Every layout in ``core/layouts.py`` exposes ``lookup_terms`` /
``term_df`` / ``gather_postings``; for COR/HOR/packed the lookup is fused
into the occurrence structure (the paper's "one fewer query").  This
module implements the shared scoring core (tf-idf cosine + static-rank
blend), top-k, and batched evaluation.  It is also the pure-jnp oracle
that the Pallas scoring kernel is validated against.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class QueryResult(NamedTuple):
    doc_ids: Array    # i32[k]   (-1 where fewer than k hits)
    scores: Array     # f32[k]


def idf(df: Array, num_docs: int) -> Array:
    """idf = ln(1 + D/df); 0 where the term is absent (df == 0)."""
    safe = jnp.maximum(df, 1)
    return jnp.where(df > 0, jnp.log1p(num_docs / safe.astype(jnp.float32)),
                     0.0)


def dedup_query_hashes(query_hashes: Array) -> Array:
    """Zero out repeated term hashes within each query (keep the first).

    A term name appearing in two slots of the padded query vector must
    contribute ONCE: the gather phase reads one posting list per slot,
    so without dedup the term's tf·idf weight is double-counted by every
    engine and the query norm inflates.  Works on [..., T]; 0 (empty
    slot) is never treated as a duplicate.
    """
    t = query_hashes.shape[-1]
    eq = query_hashes[..., :, None] == query_hashes[..., None, :]
    earlier = jnp.tril(jnp.ones((t, t), jnp.bool_), k=-1)
    dup = jnp.any(eq & earlier, axis=-1) & (query_hashes != 0)
    return jnp.where(dup, 0, query_hashes)


def final_scores(scores: Array, norm: Array, rank: Array, qnorm: Array,
                 rank_blend: float) -> Array:
    """Batched q_doc scoring tail: cosine + static-rank blend; deleted
    (norm == 0) and zero-score docs -> -inf.

    scores f32[B, D], qnorm f32[B].  The fused candidate kernels apply
    the SAME op sequence per resident tile
    (``fused_decode_score._final_from_acc``), so candidate values are
    bit-identical to this dense reference.
    """
    live = norm > 0
    cosine = scores / (jnp.maximum(norm, 1e-12)[None, :] * qnorm[:, None])
    final = cosine + rank_blend * rank[None, :]
    return jnp.where(live[None, :] & (scores > 0), final, -jnp.inf)


def accumulate_scores(doc_ids: Array, weights: Array, valid: Array,
                      num_docs: int) -> Array:
    """Scatter-add posting weights into a dense per-document accumulator.

    doc_ids/weights/valid: [T, cap].  Invalid postings are routed to a
    trash row (index num_docs).  Returns f32[num_docs].
    """
    flat_docs = jnp.where(valid, doc_ids, num_docs).reshape(-1)
    flat_w = jnp.where(valid, weights, 0.0).reshape(-1)
    acc = jnp.zeros((num_docs + 1,), jnp.float32)
    acc = acc.at[flat_docs].add(flat_w, mode="drop")
    return acc[:num_docs]


def accumulate_counts(doc_ids: Array, valid: Array, num_docs: int) -> Array:
    """Exact per-document membership counts (int32 accumulator).

    AND-filtering must COUNT postings, and float32 accumulation loses
    integer exactness past 2**24 — membership counts are integers, so
    they are accumulated as integers.  Returns i32[num_docs].
    """
    flat_docs = jnp.where(valid, doc_ids, num_docs).reshape(-1)
    ones = jnp.where(valid, 1, 0).reshape(-1).astype(jnp.int32)
    acc = jnp.zeros((num_docs + 1,), jnp.int32)
    acc = acc.at[flat_docs].add(ones, mode="drop")
    return acc[:num_docs]


def score_query(index: Any, query_hashes: Array, k: int, cap: int,
                rank_blend: float = 0.0) -> QueryResult:
    """Evaluate one query (padded term-hash vector; 0 = empty slot).

    Implements the paper's three-phase evaluation: lookup -> gather ->
    doc metadata; ranks by cosine(q, d) (+ optional static-rank blend).
    """
    query_hashes = dedup_query_hashes(query_hashes)
    present = query_hashes != 0
    term_ids = index.lookup_terms(query_hashes)            # q_word
    term_ids = jnp.where(present, term_ids, -1)
    df = index.term_df(term_ids)
    num_docs = index.docs.num_docs
    idf_t = idf(df, num_docs)

    d, tf, valid = index.gather_postings(term_ids, cap)    # q_occ
    w = tf * idf_t[:, None]

    scores = accumulate_scores(d, w, valid, num_docs)

    # q_doc: norms + static rank for candidate docs (dense fetch here; the
    # distributed engine fetches only per-shard candidates).
    qnorm = jnp.sqrt(jnp.maximum(jnp.sum(idf_t * idf_t), 1e-12))
    final = final_scores(scores[None, :], index.docs.norm, index.docs.rank,
                         qnorm[None], rank_blend)[0]

    top_scores, top_docs = jax.lax.top_k(final, k)
    hit = jnp.isfinite(top_scores)
    return QueryResult(doc_ids=jnp.where(hit, top_docs, -1),
                       scores=jnp.where(hit, top_scores, 0.0))


def score_queries(index: Any, query_hashes: Array, k: int, cap: int,
                  rank_blend: float = 0.0) -> QueryResult:
    """Batched evaluation: query_hashes u32[B, T]."""
    fn = functools.partial(score_query, index, k=k, cap=cap,
                           rank_blend=rank_blend)
    return jax.vmap(lambda q: fn(query_hashes=q))(query_hashes)


def fused_score_queries(index: Any, query_hashes: Array, k: int, cap: int,
                        rank_blend: float = 0.0,
                        max_pairs: int | None = None,
                        backend: str = "pallas",
                        mode: str = "candidates",
                        tune: Any = None):
    """Batched evaluation through the fused decode-and-score Pallas
    engine (one HBM pass over the shared posting blocks for the whole
    batch).  Requires a BlockedIndex or PackedCsrIndex.

    ``mode="candidates"`` (default) extracts per-tile top-k candidates
    INSIDE the kernel — only O(B * n_tiles * k_tile) candidates reach
    HBM, merged here by the pure ``merge_topk_candidates`` tier;
    ``mode="dense"`` is the PR-1 engine (dense [B, num_docs] scores +
    host-side top_k), kept as the byte-accounting reference.

    Returns (QueryResult, stats) where stats carries the routing
    ``pair_overflow`` counter — nonzero means postings were DROPPED
    because ``max_pairs`` was undersized, never silently.

    ``tune`` is an optional ``kernels.autotune.TuneConfig``; ``None``
    resolves the ACTIVE tuning table for this index's (backend,
    size_class, layout) — which is the historical default geometry
    while the table is empty.
    """
    from repro.kernels import autotune, ops   # (late: avoids import cycle)
    from repro.distributed.topk import merge_topk_candidates

    if mode not in ("candidates", "dense"):
        raise ValueError(f"unknown fused-engine mode: {mode!r}")
    if tune is None:
        tune = autotune.lookup(backend, int(index.docs.num_docs),
                               autotune.layout_of(index))
    query_hashes = dedup_query_hashes(query_hashes)
    present = query_hashes != 0                            # [B, T]
    term_ids = jnp.where(present, index.lookup_terms(query_hashes), -1)
    df = index.term_df(term_ids)
    num_docs = index.docs.num_docs
    idf_t = idf(df, num_docs)

    if mode == "candidates":
        cand_v, cand_i, overflow = ops.fused_batched_topk(
            index, term_ids, idf_t, cap, k, rank_blend=rank_blend,
            max_pairs=max_pairs, backend=backend, tile=tune.tile,
            k_tile=tune.resolve_k_tile(k), q_pad=tune.q_pad,
            reducer=tune.reducer, pairs_per_step=tune.pairs_per_step)
        ops.warn_on_overflow(overflow, "fused engine")
        top_scores, top_docs = merge_topk_candidates(cand_v, cand_i, k)
    else:
        scores, overflow = ops.fused_batched_scores(
            index, term_ids, idf_t, cap, max_pairs=max_pairs,
            backend=backend, tile=tune.tile, q_pad=tune.q_pad)
        ops.warn_on_overflow(overflow, "fused engine")
        # identical scoring tail to score_query (the parity oracle)
        qnorm = jnp.sqrt(jnp.maximum(jnp.sum(idf_t * idf_t, axis=1), 1e-12))
        final = final_scores(scores, index.docs.norm, index.docs.rank,
                             qnorm, rank_blend)
        top_scores, top_docs = jax.lax.top_k(final, k)
    hit = jnp.isfinite(top_scores)
    result = QueryResult(doc_ids=jnp.where(hit, top_docs, -1),
                         scores=jnp.where(hit, top_scores, 0.0))
    return result, {"pair_overflow": overflow}


def make_scorer(index: Any, k: int, cap: int, rank_blend: float = 0.0,
                engine: str = "jnp", max_pairs: int | None = None,
                backend: str = "pallas", mode: str = "candidates",
                return_stats: bool = False, tune: Any = None
                ) -> Callable[[Array], QueryResult]:
    """jit-compiled batched scorer with the index captured as constants.

    ``engine="jnp"`` is the dense pure-jnp oracle; ``engine="pallas"``
    dispatches the fused batched decode-and-score kernel (BlockedIndex /
    PackedCsrIndex only) — same ranked results, one HBM pass, and (with
    the default ``mode="candidates"``) in-kernel per-tile top-k so the
    dense score array never reaches HBM.
    ``backend`` tunes the fused engine's lowering ("pallas" auto /
    "pallas-tpu" / "xla" plain-HLO with the same block dedup).  With
    ``return_stats=True`` the scorer returns (QueryResult, stats).

    ``tune``: explicit ``kernels.autotune.TuneConfig`` kernel geometry;
    ``None`` resolves the ACTIVE tuning table at trace time (an empty
    table yields the historical defaults).  The resolved geometry is
    captured in the jitted scorer — swap the active table BEFORE
    building a scorer, not after.
    """
    if engine not in ("jnp", "pallas"):
        raise ValueError(f"unknown engine: {engine!r}")
    if mode not in ("candidates", "dense"):
        raise ValueError(f"unknown fused-engine mode: {mode!r}")
    from repro.core.live_index import SegmentedIndex  # avoid import cycle
    if isinstance(index, SegmentedIndex):
        # multi-segment path: one fused candidate launch per sealed
        # segment + static-shape delta scoring + host candidate merge
        # (the index handles its own per-segment jit caching)
        if max_pairs is not None:
            raise ValueError(
                "max_pairs is not configurable for a SegmentedIndex — "
                "each sealed segment carries its own exact (size-class "
                "quantized) route_pairs_max budget")
        def live_scorer(query_hashes: Array):
            return index.topk(query_hashes, k, cap=cap,
                              rank_blend=rank_blend, engine=engine,
                              mode=mode, backend=backend,
                              return_stats=return_stats, tune=tune)
        return live_scorer
    if engine == "pallas":
        from repro.core.layouts import BlockedIndex, PackedCsrIndex
        if not isinstance(index, (BlockedIndex, PackedCsrIndex)):
            raise TypeError(
                f"engine='pallas' needs a BlockedIndex or PackedCsrIndex, "
                f"got {type(index).__name__}")

    @jax.jit
    def scorer(query_hashes: Array):
        if engine == "pallas":
            result, stats = fused_score_queries(
                index, query_hashes, k=k, cap=cap, rank_blend=rank_blend,
                max_pairs=max_pairs, backend=backend, mode=mode, tune=tune)
        else:
            result = score_queries(index, query_hashes, k=k, cap=cap,
                                   rank_blend=rank_blend)
            stats = {"pair_overflow": jnp.int32(0)}
        return (result, stats) if return_stats else result
    return scorer


# ---------------------------------------------------------------------------
# adaptive routing budgets (fused engine's max_pairs, learned online)
# ---------------------------------------------------------------------------


def _pow2_at_least(n: int, floor: int = 8) -> int:
    """Power-of-two budget quantizer — the ONE geometric size-class
    quantizer (layouts.size_class) at growth 2, so budget quantization
    and segment size classes can never silently diverge."""
    from repro.core.layouts import size_class
    return size_class(n, base=floor, growth=2)


class AdaptiveRoutingBudget:
    """Per-``n_terms`` routing-pair budgets learned from the fused
    engine's overflow counter and a rolling query-stream sample.

    The static ``max_pairs`` budget trades compile-time shape against
    dropped postings: too small and the engine overflows (surfaced, but
    work is lost), too large and every launch pays for routing slots the
    workload never fills.  Instead of the worst-case build-time bound,
    this tracks the OBSERVED demand per query width: when a batch
    overflows, the true demand is exactly ``budget + overflow`` (the
    counter reports dropped pairs), so one growth step reaches a
    sufficient budget; a rolling window of recent demands lets quiet
    buckets shrink back.  Budgets quantize to powers of two so the
    compile set stays logarithmic in demand (each distinct value is one
    jit signature).
    """

    def __init__(self, initial: int = 64, window: int = 64,
                 shrink_ratio: int = 4):
        self.initial = int(initial)
        self.window = int(window)
        self.shrink_ratio = int(shrink_ratio)
        self._budgets: dict[int, int] = {}
        self._demands: dict[int, list] = {}
        self.overflows = 0          # batches that overflowed (telemetry)

    def budget(self, n_terms: int) -> int:
        return self._budgets.setdefault(
            int(n_terms), _pow2_at_least(self.initial))

    def observe(self, n_terms: int, used_budget: int,
                overflow: int) -> None:
        """Record one batch: ``overflow`` pairs were dropped beyond
        ``used_budget``, so the exact demand was their sum."""
        n_terms = int(n_terms)
        demand = int(used_budget) + int(overflow)
        hist = self._demands.setdefault(n_terms, [])
        hist.append(demand)
        del hist[:-self.window]
        cur = self.budget(n_terms)
        if overflow > 0:
            self.overflows += 1
            # grow past the exact demand by one doubling of headroom so
            # batch-to-batch demand jitter doesn't overflow again at the
            # next power-of-two boundary
            self._budgets[n_terms] = _pow2_at_least(demand) * 2
        elif (len(hist) >= self.window and
              _pow2_at_least(max(hist)) * self.shrink_ratio <= cur):
            # sustained quiet: shrink toward the sampled demand (one
            # headroom doubling), at most one recompile per window
            self._budgets[n_terms] = _pow2_at_least(max(hist)) * 2


def make_adaptive_scorer(index: Any, k: int, cap: int,
                         budget: AdaptiveRoutingBudget | None = None,
                         **scorer_kw):
    """Fused-engine scorer whose ``max_pairs`` follows the workload.

    Batches are bucketed by their widest query (unique present terms);
    each bucket's budget starts small and converges via the overflow
    counter — an overflowing workload reaches zero overflow within a
    growth step per bucket (regression-tested).  Returns
    ``fn(query_hashes) -> (QueryResult, stats)`` with the budget object
    on ``fn.budget`` for introspection.
    """
    budget = budget if budget is not None else AdaptiveRoutingBudget()
    scorers: dict[int, Callable] = {}

    def scorer(query_hashes: Array):
        import numpy as np
        qh = np.asarray(query_hashes)
        deduped = np.asarray(dedup_query_hashes(jnp.asarray(qh)))
        n_terms = max(int((deduped != 0).sum(axis=-1).max()), 1)
        mp = budget.budget(n_terms)
        if mp not in scorers:
            scorers[mp] = make_scorer(index, k=k, cap=cap,
                                      engine="pallas", max_pairs=mp,
                                      return_stats=True, **scorer_kw)
        result, stats = scorers[mp](query_hashes)
        budget.observe(n_terms, mp, int(stats["pair_overflow"]))
        return result, stats

    scorer.budget = budget
    return scorer


# ---------------------------------------------------------------------------
# Boolean / membership utilities (exercise document-based access paths)
# ---------------------------------------------------------------------------


def conjunctive_filter(index: Any, query_hashes: Array, k: int,
                       cap: int) -> tuple[QueryResult, dict]:
    """AND semantics: docs must contain every present query term.

    Duplicate hashes are deduplicated first so ``needed`` counts UNIQUE
    present terms (a repeated slot used to inflate both the membership
    counts and the threshold, and to double-count the tf·idf weight).

    Returns (QueryResult, stats).  ``stats["truncated_terms"]`` counts
    present terms whose posting list is LONGER than ``cap``: the gather
    phase drops their tail postings, so membership can be undercounted
    and true AND matches silently lost — like the fused engine's
    ``pair_overflow``, the truncation is surfaced instead of returning
    a silently wrong result (re-run with ``cap >= max df`` for exact
    AND semantics).
    """
    query_hashes = dedup_query_hashes(query_hashes)
    present = query_hashes != 0
    term_ids = jnp.where(present, index.lookup_terms(query_hashes), -1)
    df = index.term_df(term_ids)
    num_docs = index.docs.num_docs
    d, tf, valid = index.gather_postings(term_ids, cap)
    idf_t = idf(df, num_docs)
    w = tf * idf_t[:, None]
    scores = accumulate_scores(d, w, valid, num_docs)
    counts = accumulate_counts(d, valid, num_docs)
    needed = jnp.sum(present.astype(jnp.int32))
    truncated = jnp.sum(((df > cap) & (term_ids >= 0)).astype(jnp.int32))
    ok = counts >= needed
    final = jnp.where(ok & (index.docs.norm > 0),
                      scores / jnp.maximum(index.docs.norm, 1e-12), -jnp.inf)
    top_scores, top_docs = jax.lax.top_k(final, k)
    hit = jnp.isfinite(top_scores)
    result = QueryResult(doc_ids=jnp.where(hit, top_docs, -1),
                         scores=jnp.where(hit, top_scores, 0.0))
    from repro.kernels import ops   # (late: avoids import cycle)
    ops.record_truncated(truncated)
    return result, {"truncated_terms": truncated}
