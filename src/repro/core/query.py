"""Query evaluation — the paper's §3.7 elementary queries over any layout.

The paper decomposes vector-space evaluation into three elementary
queries (Table 3):

  q_word : term name -> (term id, df)         [lookup phase]
  q_occ  : term id   -> posting list (doc,tf) [gather phase]
  q_doc  : doc ids   -> (norm, rank)          [doc-metadata phase]

Every layout in ``core/layouts.py`` exposes ``lookup_terms`` /
``term_df`` / ``gather_postings``; for COR/HOR/packed the lookup is fused
into the occurrence structure (the paper's "one fewer query").  This
module implements the shared scoring core (tf-idf cosine + static-rank
blend), top-k, and batched evaluation.  It is also the pure-jnp oracle
that the Pallas scoring kernel is validated against.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class QueryResult(NamedTuple):
    doc_ids: Array    # i32[k]   (-1 where fewer than k hits)
    scores: Array     # f32[k]


def idf(df: Array, num_docs: int) -> Array:
    """idf = ln(1 + D/df); 0 where the term is absent (df == 0)."""
    safe = jnp.maximum(df, 1)
    return jnp.where(df > 0, jnp.log1p(num_docs / safe.astype(jnp.float32)),
                     0.0)


def accumulate_scores(doc_ids: Array, weights: Array, valid: Array,
                      num_docs: int) -> Array:
    """Scatter-add posting weights into a dense per-document accumulator.

    doc_ids/weights/valid: [T, cap].  Invalid postings are routed to a
    trash row (index num_docs).  Returns f32[num_docs].
    """
    flat_docs = jnp.where(valid, doc_ids, num_docs).reshape(-1)
    flat_w = jnp.where(valid, weights, 0.0).reshape(-1)
    acc = jnp.zeros((num_docs + 1,), jnp.float32)
    acc = acc.at[flat_docs].add(flat_w, mode="drop")
    return acc[:num_docs]


def accumulate_counts(doc_ids: Array, valid: Array, num_docs: int) -> Array:
    """Exact per-document membership counts (int32 accumulator).

    AND-filtering must COUNT postings, and float32 accumulation loses
    integer exactness past 2**24 — membership counts are integers, so
    they are accumulated as integers.  Returns i32[num_docs].
    """
    flat_docs = jnp.where(valid, doc_ids, num_docs).reshape(-1)
    ones = jnp.where(valid, 1, 0).reshape(-1).astype(jnp.int32)
    acc = jnp.zeros((num_docs + 1,), jnp.int32)
    acc = acc.at[flat_docs].add(ones, mode="drop")
    return acc[:num_docs]


def score_query(index: Any, query_hashes: Array, k: int, cap: int,
                rank_blend: float = 0.0) -> QueryResult:
    """Evaluate one query (padded term-hash vector; 0 = empty slot).

    Implements the paper's three-phase evaluation: lookup -> gather ->
    doc metadata; ranks by cosine(q, d) (+ optional static-rank blend).
    """
    present = query_hashes != 0
    term_ids = index.lookup_terms(query_hashes)            # q_word
    term_ids = jnp.where(present, term_ids, -1)
    df = index.term_df(term_ids)
    num_docs = index.docs.num_docs
    idf_t = idf(df, num_docs)

    d, tf, valid = index.gather_postings(term_ids, cap)    # q_occ
    w = tf * idf_t[:, None]

    scores = accumulate_scores(d, w, valid, num_docs)

    # q_doc: norms + static rank for candidate docs (dense fetch here; the
    # distributed engine fetches only per-shard candidates).
    qnorm = jnp.sqrt(jnp.maximum(jnp.sum(idf_t * idf_t), 1e-12))
    norm = index.docs.norm
    live = norm > 0            # deleted docs have norm == 0
    cosine = scores / (jnp.maximum(norm, 1e-12) * qnorm)
    final = cosine + rank_blend * index.docs.rank
    final = jnp.where(live & (scores > 0), final, -jnp.inf)

    top_scores, top_docs = jax.lax.top_k(final, k)
    hit = jnp.isfinite(top_scores)
    return QueryResult(doc_ids=jnp.where(hit, top_docs, -1),
                       scores=jnp.where(hit, top_scores, 0.0))


def score_queries(index: Any, query_hashes: Array, k: int, cap: int,
                  rank_blend: float = 0.0) -> QueryResult:
    """Batched evaluation: query_hashes u32[B, T]."""
    fn = functools.partial(score_query, index, k=k, cap=cap,
                           rank_blend=rank_blend)
    return jax.vmap(lambda q: fn(query_hashes=q))(query_hashes)


def fused_score_queries(index: Any, query_hashes: Array, k: int, cap: int,
                        rank_blend: float = 0.0,
                        max_pairs: int | None = None,
                        backend: str = "pallas"):
    """Batched evaluation through the fused decode-and-score Pallas
    engine (one HBM pass over the shared posting blocks for the whole
    batch).  Requires a BlockedIndex or PackedCsrIndex.

    Returns (QueryResult, stats) where stats carries the routing
    ``pair_overflow`` counter — nonzero means postings were DROPPED
    because ``max_pairs`` was undersized, never silently.
    """
    from repro.kernels import ops   # engine dispatch (avoids import cycle)

    present = query_hashes != 0                            # [B, T]
    term_ids = jnp.where(present, index.lookup_terms(query_hashes), -1)
    df = index.term_df(term_ids)
    num_docs = index.docs.num_docs
    idf_t = idf(df, num_docs)

    scores, overflow = ops.fused_batched_scores(
        index, term_ids, idf_t, cap, max_pairs=max_pairs, backend=backend)
    ops.warn_on_overflow(overflow, "fused engine")

    # identical scoring tail to score_query (the parity oracle)
    qnorm = jnp.sqrt(jnp.maximum(jnp.sum(idf_t * idf_t, axis=1), 1e-12))
    norm = index.docs.norm
    live = norm > 0
    cosine = scores / (jnp.maximum(norm, 1e-12)[None, :] * qnorm[:, None])
    final = cosine + rank_blend * index.docs.rank[None, :]
    final = jnp.where(live[None, :] & (scores > 0), final, -jnp.inf)
    top_scores, top_docs = jax.lax.top_k(final, k)
    hit = jnp.isfinite(top_scores)
    result = QueryResult(doc_ids=jnp.where(hit, top_docs, -1),
                         scores=jnp.where(hit, top_scores, 0.0))
    return result, {"pair_overflow": overflow}


def make_scorer(index: Any, k: int, cap: int, rank_blend: float = 0.0,
                engine: str = "jnp", max_pairs: int | None = None,
                backend: str = "pallas", return_stats: bool = False
                ) -> Callable[[Array], QueryResult]:
    """jit-compiled batched scorer with the index captured as constants.

    ``engine="jnp"`` is the dense pure-jnp oracle; ``engine="pallas"``
    dispatches the fused batched decode-and-score kernel (BlockedIndex /
    PackedCsrIndex only) — same ranked results, one HBM pass.
    ``backend`` tunes the fused engine's lowering ("pallas" auto /
    "pallas-tpu" / "xla" plain-HLO with the same block dedup).  With
    ``return_stats=True`` the scorer returns (QueryResult, stats).
    """
    if engine not in ("jnp", "pallas"):
        raise ValueError(f"unknown engine: {engine!r}")
    if engine == "pallas":
        from repro.core.layouts import BlockedIndex, PackedCsrIndex
        if not isinstance(index, (BlockedIndex, PackedCsrIndex)):
            raise TypeError(
                f"engine='pallas' needs a BlockedIndex or PackedCsrIndex, "
                f"got {type(index).__name__}")

    @jax.jit
    def scorer(query_hashes: Array):
        if engine == "pallas":
            result, stats = fused_score_queries(
                index, query_hashes, k=k, cap=cap, rank_blend=rank_blend,
                max_pairs=max_pairs, backend=backend)
        else:
            result = score_queries(index, query_hashes, k=k, cap=cap,
                                   rank_blend=rank_blend)
            stats = {"pair_overflow": jnp.int32(0)}
        return (result, stats) if return_stats else result
    return scorer


# ---------------------------------------------------------------------------
# Boolean / membership utilities (exercise document-based access paths)
# ---------------------------------------------------------------------------


def conjunctive_filter(index: Any, query_hashes: Array, k: int,
                       cap: int) -> QueryResult:
    """AND semantics: docs must contain every present query term."""
    present = query_hashes != 0
    term_ids = jnp.where(present, index.lookup_terms(query_hashes), -1)
    df = index.term_df(term_ids)
    num_docs = index.docs.num_docs
    d, tf, valid = index.gather_postings(term_ids, cap)
    idf_t = idf(df, num_docs)
    w = tf * idf_t[:, None]
    scores = accumulate_scores(d, w, valid, num_docs)
    counts = accumulate_counts(d, valid, num_docs)
    needed = jnp.sum(present.astype(jnp.int32))
    ok = counts >= needed
    final = jnp.where(ok & (index.docs.norm > 0),
                      scores / jnp.maximum(index.docs.norm, 1e-12), -jnp.inf)
    top_scores, top_docs = jax.lax.top_k(final, k)
    hit = jnp.isfinite(top_scores)
    return QueryResult(doc_ids=jnp.where(hit, top_docs, -1),
                       scores=jnp.where(hit, top_scores, 0.0))
