"""Segmented live index — LSM-style ingest, tombstone deletes, and
multi-segment fused query over the paper's representations.

The paper's §3.6 maintenance story stops at batch re-indexing: drop the
derived structures, merge-sort every posting, rebuild.  That is
O(total postings) of work and a device-shape change (new XLA
compilation) per ingest batch.  This module replaces it with the
structure every production DB-IR engine converges on (ODYS,
arXiv:1208.4270; compressed-index maintenance, arXiv:1209.5448):
immutable sealed runs + a small mutable tail + background
reorganization.

Segment lifecycle (delta -> seal -> compact)
--------------------------------------------

  * DELTA — a fixed-capacity, append-only, doc-major postings buffer
    (uncompressed CSR).  Ingest batches append here in O(batch) time;
    the device mirror has STATIC shapes (capacity-padded), so queries
    over the delta never recompile.  Postings are kept per-doc in
    ascending unified-term order — the same per-document accumulation
    order the bulk builder's term-major sort produces, which is what
    keeps recomputed norms bit-identical to a from-scratch rebuild.

  * SEAL — when the delta fills (or ``seal()`` is called), its contents
    become one immutable sealed segment: a ``BlockedIndex`` built by the
    existing bulk path over the segment's contiguous doc-id range, then
    padded to a static SIZE CLASS (geometric shape quantization:
    ``layouts.size_class`` / ``pad_blocked_to_class``).

  * COMPACT — a size-tiered policy (core/compaction.py) merges the
    newest run of similarly-sized segments into one, physically dropping
    tombstoned postings and re-blocking.  Doc ids are NEVER reused or
    renumbered, so merged ranges stay contiguous and external references
    stay valid.  ``compact()`` is synchronous but background-callable:
    queries between compactions read the old stack unchanged.

Recompile-avoidance contract
----------------------------

Every per-segment scorer (kernels/ops.py ``fused_segment_topk`` et al.)
is a module-level jitted function taking the segment as a pytree
ARGUMENT; its compilation is keyed on the segment's size class, not its
identity.  Sealing quantizes all shape-bearing statics (block count,
vocab width, doc span, routing budgets, posting-length bounds) to a few
geometric classes, so after one warmup per class, sealing and querying
new segments triggers ZERO new XLA compilations — asserted by the churn
test via jit-cache counters (``scorer_cache_sizes``).  The cross-segment
candidate merge runs on the host (numpy), so a changing segment count
never enters a jit signature.

Exact-ranking contract
----------------------

Scoring state that depends on the WHOLE corpus is maintained globally
and exactly: ``df`` over live documents (incremented on add,
decremented on delete using the per-doc forward postings), the live doc
count behind idf, and tf-idf norms recomputed per mutation batch with
the same float64 op sequence as the bulk builder.  Tombstones mask
deleted docs by zeroing their norm — the existing deleted-doc path of
every engine, applied inside the fused kernel's doc-metadata tail.  The
result: at ANY point of an add/delete/compact schedule, top-k from the
fused candidates engine is bit-identical (ties included) to the jnp
oracle over ``bulk_build`` of the equivalent live corpus
(``export_live_corpus`` builds exactly that corpus for the parity
tests; ranking parity needs ``rank_blend == 0`` or an oracle sharing
this index's static-rank table, and the default full-list ``cap``).

Posting-merge work (the ``stats`` counters): each posting is appended
once (an O(1) buffer write), sealed once, and compacted
O(log N / log min_run) times — vs the rebuild path re-sorting EVERY
posting EVERY batch.  Norm refresh is a separate vectorized
O(live postings) bincount per mutation batch (counted apart in
``postings_norm_refreshed``; it is metadata maintenance, not index
merge work, and never re-sorts or rebuilds posting structures).

Epochs and pinned views (the serving-tier hook)
-----------------------------------------------

Every query-visible mutation (add, delete, seal, compact) advances a
monotonic ``epoch`` counter; ``view()`` returns an immutable
``LiveView`` pinned to the current epoch — shallow-pinned segment
indexes (segment replacement never mutates the old pytree), the delta's
device mirror (rebuilt, never mutated, on change), and copies of the
in-place-mutated global state (df, live mask).  A pinned view answers
``topk``/``conjunctive`` bit-identically to the live index AT THAT
EPOCH no matter what lands afterwards, which is what lets the serving
tier (``repro/serve``) micro-batch queries against a consistent index
while ingest and background maintenance run.  ``view()`` itself must be
called serially with writers (the serving tier holds a write lock for
the pin, never for the query).
"""
from __future__ import annotations

import bisect
import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import build as build_mod
from repro.core import compaction, layouts, size_model
from repro.core.build import TokenizedCorpus
from repro.core.layouts import DocTable, PostingsHost
from repro.core.query import QueryResult, final_scores
from repro.distributed.topk import merge_topk_candidates_host
from repro.kernels import autotune, ops
from repro.obs.registry import EventLog
from repro.kernels.fused_decode_score import (TILE, default_k_tile,
                                              extract_tile_candidates)

Array = jax.Array


# ---------------------------------------------------------------------------
# module-level jitted helpers (argument-passed state => stable caches)
# ---------------------------------------------------------------------------


@jax.jit
def _query_weights(df: Array, d_live: Array):
    """Global idf weights + query norms, same op sequence as the oracle.

    df i32[B, T] LIVE global document frequencies per (dedup'd) slot,
    d_live f32 scalar live doc count.  Bit-identical to
    ``query.idf`` + the oracle's qnorm reduction, so every segment
    scores with exactly the weights a from-scratch rebuild would use.
    """
    safe = jnp.maximum(df, 1)
    idf = jnp.where(df > 0, jnp.log1p(d_live / safe.astype(jnp.float32)),
                    0.0)
    qnorm = jnp.sqrt(jnp.maximum(jnp.sum(idf * idf, axis=1), 1e-12))
    return idf, qnorm


@functools.partial(jax.jit, static_argnames=("k_tile", "tile", "rank_blend"))
def _delta_candidates(terms: Array, tfs: Array, doc_of: Array, norm: Array,
                      rank: Array, tids: Array, idf_w: Array, qnorm: Array,
                      doc_base: Array, *, k_tile: int, tile: int = TILE,
                      rank_blend: float = 0.0):
    """Score the mutable delta (capacity-padded doc-major postings) and
    reduce to the same per-tile candidate lists the sealed-segment
    kernels emit.  All shapes are delta capacities — static for the
    index's lifetime."""
    dcap = norm.shape[0]
    # per-posting query weight: each posting's unified term id against
    # the query's (dedup'd) term-id slots
    match = ((terms[None, :, None] == tids[:, None, :]) &
             (tids[:, None, :] >= 0) & (terms[None, :, None] >= 0))
    w_p = jnp.sum(jnp.where(match, idf_w[:, None, :], 0.0), axis=2)
    valid = doc_of >= 0
    safe_d = jnp.where(valid, doc_of, dcap)
    contrib = jnp.where(valid[None, :], tfs[None, :] * w_p, 0.0)

    def row(c):
        acc = jnp.zeros((dcap + 1,), jnp.float32).at[safe_d].add(
            c, mode="drop")
        return acc[:dcap]

    scores = jax.vmap(row)(contrib)
    final = final_scores(scores, norm, rank, qnorm, rank_blend)
    vals, ids = extract_tile_candidates(final, tile, k_tile)
    gids = jnp.where(ids >= 0, ids + doc_base, -1)
    return vals, gids


@functools.partial(jax.jit, static_argnames=("k_tile", "tile"))
def _delta_conjunctive(terms: Array, tfs: Array, doc_of: Array, norm: Array,
                       tids: Array, idf_w: Array, needed: Array,
                       doc_base: Array, *, k_tile: int, tile: int = TILE):
    """AND-semantics counts + scores over the delta for ONE query.  The
    delta is scanned in full (no posting cap), so it never truncates —
    its ``truncated_terms`` contribution is always zero."""
    dcap = norm.shape[0]
    match = ((terms[:, None] == tids[None, :]) & (tids[None, :] >= 0) &
             (terms[:, None] >= 0))
    w_p = jnp.sum(jnp.where(match, idf_w[None, :], 0.0), axis=1)
    hit_p = jnp.any(match, axis=1)
    valid = doc_of >= 0
    safe_d = jnp.where(valid, doc_of, dcap)
    scores = jnp.zeros((dcap + 1,), jnp.float32).at[safe_d].add(
        jnp.where(valid, tfs * w_p, 0.0), mode="drop")[:dcap]
    counts = jnp.zeros((dcap + 1,), jnp.int32).at[safe_d].add(
        jnp.where(valid & hit_p, 1, 0).astype(jnp.int32),
        mode="drop")[:dcap]
    ok = counts >= needed
    final = jnp.where(ok & (norm > 0),
                      scores / jnp.maximum(norm, 1e-12), -jnp.inf)
    vals, ids = extract_tile_candidates(final[None], tile, k_tile)
    gids = jnp.where(ids[0] >= 0, ids[0] + doc_base, -1)
    return vals[0], gids


def scorer_cache_sizes() -> dict:
    """jit-cache entry counts for every compiled piece of the live query
    path.  The churn test snapshots this after warmup and asserts zero
    growth across further seals, compactions, and queries — the
    measurable form of the recompile-avoidance contract."""
    sizes = dict(ops.segment_scorer_cache_sizes())
    sizes.update({
        "query_weights": _query_weights._cache_size(),
        "delta_candidates": _delta_candidates._cache_size(),
        "delta_conjunctive": _delta_conjunctive._cache_size(),
    })
    return sizes


def _dedup_np(qh: np.ndarray) -> np.ndarray:
    """Host twin of ``query.dedup_query_hashes`` (keep first, zero rest)."""
    out = qh.copy()
    t = qh.shape[-1]
    eq = qh[..., :, None] == qh[..., None, :]
    earlier = np.tril(np.ones((t, t), bool), k=-1)
    dup = (eq & earlier).any(axis=-1) & (qh != 0)
    out[dup] = 0
    return out


def _lookup_sorted(hash_sorted: np.ndarray, hash_order: np.ndarray,
                   qh: np.ndarray) -> np.ndarray:
    """u32[...] hashes -> unified term ids (i64, -1 absent/empty) via a
    host binary search over the sorted vocabulary."""
    w = len(hash_sorted)
    if w == 0:
        return np.full(qh.shape, -1, np.int64)
    flat = qh.reshape(-1)
    pos = np.searchsorted(hash_sorted, flat)
    posc = np.minimum(pos, w - 1)
    hit = (hash_sorted[posc] == flat) & (flat != 0)
    return np.where(hit, hash_order[posc], -1).reshape(qh.shape)


# ---------------------------------------------------------------------------
# stats / delta / segment containers
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LiveIndexStats:
    """Work and lifecycle counters (all cumulative).

    ``postings_merged`` is the posting-MERGE work (postings touched by
    sort/merge/rebuild operations): seal builds + compaction merges —
    each posting is sealed once and compacted O(log N / log min_run)
    times.  Delta appends are pure O(1) buffer writes (no sort, no
    structure rebuild) and are counted apart in ``postings_appended``,
    as is the vectorized per-mutation norm refresh.  The rebuild path's
    equivalent is its full re-sort: EVERY posting touched, every batch.
    """
    postings_appended: int = 0      # delta appends (O(1)/posting writes)
    postings_sealed: int = 0        # delta -> segment bulk builds
    postings_compacted: int = 0     # compaction merge inputs
    postings_norm_refreshed: int = 0  # vectorized norm recompute (not merge)
    docs_added: int = 0
    seals: int = 0
    compactions: int = 0
    deletes: int = 0
    layout_rewrites: int = 0        # single-segment layout conversions

    @property
    def postings_merged(self) -> int:
        return self.postings_sealed + self.postings_compacted


class _Delta:
    """Fixed-capacity append-only doc-major postings buffer (host side).

    Capacities are static so the device mirror's shapes never change;
    per-doc postings are stored in ascending unified-term order."""

    def __init__(self, doc_cap: int, post_cap: int, doc_base: int):
        self.doc_cap = int(doc_cap)
        self.post_cap = int(post_cap)
        self.doc_base = int(doc_base)
        self.n_docs = 0
        self.n_postings = 0
        self.terms = np.full(self.post_cap, -1, np.int32)
        self.tfs = np.zeros(self.post_cap, np.float32)
        self.doc_of = np.full(self.post_cap, -1, np.int32)
        self.doc_offsets = np.zeros(self.doc_cap + 1, np.int64)

    def append(self, lens: np.ndarray, terms: np.ndarray,
               tfs: np.ndarray) -> None:
        n, p = len(lens), len(terms)
        assert self.n_docs + n <= self.doc_cap
        assert self.n_postings + p <= self.post_cap
        s = self.n_postings
        self.terms[s:s + p] = terms
        self.tfs[s:s + p] = tfs
        self.doc_of[s:s + p] = np.repeat(
            np.arange(self.n_docs, self.n_docs + n, dtype=np.int32),
            lens)
        off = self.doc_offsets
        off[self.n_docs + 1:self.n_docs + n + 1] = \
            off[self.n_docs] + np.cumsum(lens)
        self.n_docs += n
        self.n_postings += p


@dataclasses.dataclass
class Segment:
    """One immutable sealed run.

    ``index`` is a size-class-padded BlockedIndex over LOCAL doc ids
    (global id = local + doc_base); the host arrays are the (doc, term)-
    sorted forward canonical used for norm refresh, per-doc delete
    lookups, and compaction merges."""
    index: (layouts.BlockedIndex | layouts.PackedCsrIndex
            | layouts.BandedCsrIndex)
    doc_base: int
    doc_span: int              # allocated local id range (may have holes)
    doc_of: np.ndarray         # i32[P] local doc ids, doc-major
    terms: np.ndarray          # i32[P] unified term ids, asc within doc
    tfs: np.ndarray            # f32[P]
    doc_offsets: np.ndarray    # i64[doc_span + 1] forward CSR
    n_postings: int
    size_class: int = 0        # padded doc-span class the build used
    num_terms: int = 0         # distinct terms with postings in this run
    chooser_reason: str = "default"  # how the layout ladder resolved
    band_cut: int = 0          # banded only: packed-band width cut (words)

    @property
    def layout(self) -> str:
        """The sealed layout this segment was built with — ``"hor"``,
        ``"packed"``, or ``"banded"``.  Snapshots record it per segment
        so a mixed-layout stack restores each segment in its ORIGINAL
        layout (bitwise round-trip), and the sharded stack groups on
        it."""
        if isinstance(self.index, layouts.BandedCsrIndex):
            return "banded"
        return ("packed" if isinstance(self.index, layouts.PackedCsrIndex)
                else "hor")

    @property
    def stats(self) -> size_model.SegmentStats:
        """Aggregate shape the layout chooser sees for this run."""
        return size_model.SegmentStats(num_docs=self.doc_span,
                                       num_postings=self.n_postings,
                                       num_terms=self.num_terms)


def _layout_mix(segments) -> dict:
    """Aggregate per-layout composition of a sealed stack — the
    observability payload behind ``SegmentedIndex.layout_mix`` /
    ``LiveView.layout_mix`` and ``ServerMetrics.layout_mix``."""
    mix = {"segments": [], "counts": {}, "docs": {}, "postings": {},
           "reasons": {}}
    for seg in segments:
        lay = seg.layout
        rec = {
            "doc_base": int(seg.doc_base), "doc_span": int(seg.doc_span),
            "size_class": int(seg.size_class), "layout": lay,
            "n_postings": int(seg.n_postings),
            "chooser_reason": seg.chooser_reason}
        if lay == "banded":
            rec["band_cut"] = int(seg.band_cut)
        mix["segments"].append(rec)
        mix["counts"][lay] = mix["counts"].get(lay, 0) + 1
        mix["docs"][lay] = mix["docs"].get(lay, 0) + int(seg.doc_span)
        mix["postings"][lay] = (mix["postings"].get(lay, 0)
                                + int(seg.n_postings))
        mix["reasons"][seg.chooser_reason] = \
            mix["reasons"].get(seg.chooser_reason, 0) + 1
    return mix


# ---------------------------------------------------------------------------
# epoch-pinned immutable view (the serving tier's unit of consistency)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LiveView:
    """An immutable snapshot of the query-visible index state at one
    epoch.

    Pinning is cheap: sealed segment indexes are immutable pytrees
    (compaction and norm refresh REPLACE them, never mutate), the
    delta's device mirror is rebuilt — not mutated — on change, and only
    the in-place-mutated host state (df, live mask, delta tail) is
    copied.  A view answers ``topk``/``conjunctive`` exactly as the
    ``SegmentedIndex`` did at pin time, and ``export_live_corpus``
    produces the matching oracle corpus — so a response served from a
    pinned view can be checked bit-identical against the jnp oracle OF
    ITS EPOCH even while writers churn the live index.
    """
    epoch: int
    segments: tuple            # pinned shallow copies of Segment
    delta_dev: dict            # capacity-padded device arrays
    delta_terms: np.ndarray    # host delta tail, trimmed copies
    delta_tfs: np.ndarray
    delta_doc_of: np.ndarray
    delta_doc_offsets: np.ndarray   # i64[delta_n_docs + 1]
    delta_doc_base: int
    delta_n_docs: int
    hashes: np.ndarray         # unified vocabulary (replaced on growth)
    hash_sorted: np.ndarray
    hash_order: np.ndarray
    df: np.ndarray             # i64[W] live global df (copy)
    live: np.ndarray           # bool[num_docs] (copy)
    live_docs: int
    num_docs: int

    @property
    def num_segments(self) -> int:
        return len(self.segments)

    def layout_mix(self) -> dict:
        """Per-layout composition of the pinned stack (counts, docs,
        postings, chooser reasons, per-segment decisions)."""
        return _layout_mix(self.segments)

    # -- query path (identical op sequence to the live index) --------------

    def _prep(self, qh: np.ndarray):
        qh = _dedup_np(np.asarray(qh, np.uint32))
        tids = _lookup_sorted(self.hash_sorted, self.hash_order, qh)
        if len(self.df):
            df = np.where(tids >= 0, self.df[np.maximum(tids, 0)],
                          0).astype(np.int32)
        else:
            df = np.zeros(qh.shape, np.int32)
        idf_w, qnorm = _query_weights(
            jnp.asarray(df), jnp.asarray(np.float32(self.live_docs)))
        return qh, tids, idf_w, qnorm

    def topk(self, query_hashes, k: int, *, cap: int | None = None,
             rank_blend: float = 0.0, engine: str = "pallas",
             mode: str = "candidates", backend: str = "pallas",
             return_stats: bool = False, tune=None, trace=None):
        """Batched top-k over this view's delta + sealed segments — the
        same contract as ``SegmentedIndex.topk``, evaluated against the
        pinned epoch.

        ``trace`` optionally takes a ``repro.obs.Trace``: each sealed
        segment records a child span of ``"score"`` carrying its size
        class, layout, the TuneConfig geometry the dispatch resolved,
        and the analytic candidate / posting byte costs; the delta and
        the host candidate merge record their own children.  Tracing
        adds host-side timing only — the op sequence, and therefore
        every result bit, is identical with ``trace=None``.

        Kernel geometry resolves PER SEGMENT from the active tuning
        table (``tune`` overrides it for every segment): each sealed
        segment's (backend, size_class, layout) picks its own tile
        width / reducer / unroll, so a view mixing a 4k-doc segment and
        a 512k-doc segment runs each at its tuned shape.  The delta
        always scores at the default tile (its buffers are
        capacity-padded, not size-classed) with ``k_tile`` clamped to
        that tile — exactness only needs ``k_tile >= min(k, tile)`` per
        SOURCE, and the host merge accepts ragged widths."""
        if engine not in ("pallas", "jnp"):
            raise ValueError(f"unknown engine: {engine!r}")
        if mode not in ("candidates", "dense"):
            raise ValueError(f"unknown fused-engine mode: {mode!r}")
        qh = np.asarray(query_hashes, np.uint32)
        if qh.ndim != 2:
            raise ValueError("query_hashes must be [B, T]")
        qh, tids, idf_w, qnorm = self._prep(qh)
        qh_dev = jnp.asarray(qh)
        k_tile = default_k_tile(k)        # delta path: TILE-wide tiles
        vals, ids, overflows = [], [], []
        for seg in self.segments:
            cfg = (tune if tune is not None else autotune.lookup(
                backend, int(seg.index.docs.num_docs), seg.layout))
            seg_kt = cfg.resolve_k_tile(k)
            if seg.layout == "banded":
                mp_p, mp_h = ops.banded_pairs_budgets(
                    seg.index, cfg.tile, cfg.pairs_per_step)
                mp = mp_p + mp_h
            else:
                mp = ops.padded_pairs_budget(seg.index, cfg.tile,
                                             cfg.pairs_per_step)
            c = int(cap) if cap is not None else seg.index.max_posting_len
            b = jnp.asarray(np.int32(seg.doc_base))
            span = None
            if trace is not None:
                span = trace.span(
                    "segment", parent="score", doc_base=int(seg.doc_base),
                    size_class=int(seg.size_class), layout=seg.layout,
                    tile=int(cfg.tile), k_tile=int(seg_kt),
                    reducer=cfg.reducer,
                    pairs_per_step=int(cfg.pairs_per_step),
                    max_pairs=int(mp),
                    candidate_bytes=size_model.candidate_bytes_per_query(
                        int(seg.index.docs.num_docs), int(cfg.tile),
                        int(seg_kt)),
                    posting_bytes=size_model.est_posting_bytes(
                        seg.stats, seg.layout),
                    **({"band_cut": int(seg.band_cut)}
                       if seg.layout == "banded" else {}))
            if engine == "jnp":
                v, g, o = ops.jnp_segment_topk(
                    seg.index, qh_dev, idf_w, b, k_tile=k_tile, cap=c,
                    rank_blend=rank_blend)
            elif seg.layout == "banded":
                # one fused dense launch per band, partials summed in
                # the engine; both "candidates" and "dense" modes route
                # here (a per-band candidate top-k cannot merge — scores
                # are additive over terms, not max-mergeable)
                v, g, o = ops.fused_segment_banded_topk(
                    seg.index, qh_dev, idf_w, b, k_tile=seg_kt,
                    cap_packed=min(c, max(
                        seg.index.packed.max_posting_len, 1)),
                    cap_hor=min(c, max(seg.index.hor.max_posting_len, 1)),
                    max_pairs_packed=mp_p, max_pairs_hor=mp_h,
                    rank_blend=rank_blend, tile=cfg.tile,
                    backend=backend, q_pad=cfg.q_pad)
            elif mode == "dense":
                v, g, o = ops.fused_segment_dense_topk(
                    seg.index, qh_dev, idf_w, b, k_tile=seg_kt, cap=c,
                    max_pairs=mp, rank_blend=rank_blend, tile=cfg.tile,
                    backend=backend, q_pad=cfg.q_pad)
            else:
                v, g, o = ops.fused_segment_topk(
                    seg.index, qh_dev, idf_w, b, k_tile=seg_kt, cap=c,
                    max_pairs=mp, rank_blend=rank_blend, tile=cfg.tile,
                    backend=backend, q_pad=cfg.q_pad, reducer=cfg.reducer,
                    pairs_per_step=cfg.pairs_per_step)
            # keep device arrays until every segment is dispatched —
            # transferring here would serialize the per-segment launches
            vals.append(v)
            ids.append(g)
            overflows.append(o)
            if span is not None:
                # dispatch-only latency: candidates transfer in merge
                span.end()
        dspan = (trace.span("delta", parent="score",
                            postings=int(self.delta_terms.shape[0]),
                            docs=int(self.delta_n_docs), k_tile=int(k_tile))
                 if trace is not None else None)
        dev = self.delta_dev
        dv, dg = _delta_candidates(
            dev["terms"], dev["tfs"], dev["doc_of"], dev["norm"],
            dev["rank"], jnp.asarray(tids.astype(np.int32)), idf_w, qnorm,
            jnp.asarray(np.int32(self.delta_doc_base)), k_tile=k_tile,
            rank_blend=rank_blend)
        vals.append(dv)
        ids.append(dg)
        if dspan is not None:
            dspan.end()
        overflow = sum(int(o) for o in overflows)
        if not return_stats:
            # stats callers inspect the counter themselves; everyone
            # else gets the engines' loud-overflow contract
            ops.warn_on_overflow(jnp.asarray(overflow), "live-view "
                                 "fused engine")
        mv, mi = merge_topk_candidates_host(vals, ids, k, trace=trace)
        hit = np.isfinite(mv)
        result = QueryResult(
            doc_ids=jnp.asarray(np.where(hit, mi, -1).astype(np.int32)),
            scores=jnp.asarray(np.where(hit, mv, 0.0).astype(np.float32)))
        if return_stats:
            return result, {"pair_overflow": overflow}
        return result

    def conjunctive(self, query_hashes, k: int, cap: int):
        """AND semantics over the pinned index for ONE query [T]; see
        ``SegmentedIndex.conjunctive`` for the stats contract."""
        qh = _dedup_np(np.asarray(query_hashes, np.uint32).reshape(1, -1))
        needed = int((qh != 0).sum())
        qh1, tids, idf_w, _qnorm = self._prep(qh)
        qh_dev = jnp.asarray(qh1[0])
        k_tile = default_k_tile(k)
        vals, ids, truncs = [], [], []
        for seg in self.segments:
            v, g, t = ops.jnp_segment_conjunctive(
                seg.index, qh_dev, idf_w[0], jnp.asarray(np.int32(needed)),
                jnp.asarray(np.int32(seg.doc_base)), k_tile=k_tile,
                cap=int(cap))
            vals.append(v)
            ids.append(g)
            truncs.append(t)
        truncated = sum(int(t) for t in truncs)
        ops.record_truncated(truncated)
        dev = self.delta_dev
        dv, dg = _delta_conjunctive(
            dev["terms"], dev["tfs"], dev["doc_of"], dev["norm"],
            jnp.asarray(tids[0].astype(np.int32)), idf_w[0],
            jnp.asarray(np.int32(needed)),
            jnp.asarray(np.int32(self.delta_doc_base)), k_tile=k_tile)
        vals.append(np.asarray(dv))
        ids.append(np.asarray(dg))
        mv, mi = merge_topk_candidates_host(vals, ids, k)
        hit = np.isfinite(mv)
        result = QueryResult(
            doc_ids=jnp.asarray(np.where(hit, mi, -1).astype(np.int32)),
            scores=jnp.asarray(np.where(hit, mv, 0.0).astype(np.float32)))
        return result, {"truncated_terms": truncated}

    # -- oracle support -----------------------------------------------------

    def _owner(self, d: int):
        """Segment position owning global doc id d (None = the delta)."""
        if d >= self.delta_doc_base:
            return None
        bases = [s.doc_base for s in self.segments]
        i = bisect.bisect_right(bases, d) - 1
        seg = self.segments[i]
        assert seg.doc_base <= d < seg.doc_base + seg.doc_span
        return i

    def export_live_corpus(self):
        """The equivalent live corpus AT THIS EPOCH over the pinned
        vocabulary, plus the ascending global ids of its docs — exactly
        what a parity oracle should ``bulk_build`` against this view."""
        live_ids = np.flatnonzero(self.live)
        doc_term_ids, doc_counts = [], []
        for d in live_ids:
            o = self._owner(int(d))
            if o is None:
                local = int(d) - self.delta_doc_base
                if local >= self.delta_n_docs:
                    t = np.zeros(0, np.int64)
                    tf = np.zeros(0, np.float64)
                else:
                    a, b = (self.delta_doc_offsets[local],
                            self.delta_doc_offsets[local + 1])
                    t = self.delta_terms[a:b]
                    tf = self.delta_tfs[a:b]
            else:
                seg = self.segments[o]
                local = int(d) - seg.doc_base
                a, b = seg.doc_offsets[local], seg.doc_offsets[local + 1]
                t = seg.terms[a:b]
                tf = seg.tfs[a:b]
            doc_term_ids.append(np.asarray(t, np.int64))
            doc_counts.append(np.asarray(tf, np.float64).astype(np.int64))
        tc = TokenizedCorpus(doc_term_ids=doc_term_ids,
                             doc_counts=doc_counts,
                             term_hashes=self.hashes.copy(),
                             num_docs=len(live_ids))
        return tc, live_ids


# ---------------------------------------------------------------------------
# the live index
# ---------------------------------------------------------------------------


class SegmentedIndex:
    """LSM-style live index: mutable delta + sealed segment stack +
    tombstones, queried by the fused candidates engine per segment.

    See the module docstring for the lifecycle and the exact-ranking /
    recompile-avoidance contracts.
    """

    def __init__(self, term_hashes: np.ndarray | None = None, *,
                 delta_doc_capacity: int = 512,
                 delta_posting_capacity: int | None = None,
                 policy: compaction.TieredPolicy | None = None,
                 rank_seed: int = 7, seal_layout: str = "hor",
                 layout_policy: size_model.LayoutCostModel | None = None,
                 event_capacity: int = 256):
        if seal_layout not in ("hor", "packed", "banded"):
            raise ValueError(f"unknown seal layout: {seal_layout!r}")
        self._hashes = (np.asarray(term_hashes, np.uint32).copy()
                        if term_hashes is not None
                        else np.zeros(0, np.uint32))
        self._df = np.zeros(len(self._hashes), np.int64)
        self._rebuild_lookup()
        self._live = np.zeros(0, bool)
        self._rank = np.zeros(0, np.float32)
        self._norm = np.zeros(0, np.float32)
        self._live_docs = 0
        self._segments: list[Segment] = []
        post_cap = (int(delta_posting_capacity)
                    if delta_posting_capacity is not None
                    else int(delta_doc_capacity) * 64)
        self._delta = _Delta(delta_doc_capacity, post_cap, 0)
        self._delta_dev: dict | None = None
        self._delta_dirty = True
        self._policy = policy or compaction.TieredPolicy()
        self._rng = np.random.default_rng(rank_seed)
        self._seal_layout = seal_layout
        self._layout_policy = layout_policy
        self._epoch = 0
        self._view: LiveView | None = None
        self.stats = LiveIndexStats()
        # bounded structured ring of maintenance events (seal/compact/
        # rewrite/ingest/delete/...), queryable from the serving tier;
        # the capacity is caller-sized (ServerConfig/MeshConfig plumb it
        # through) — event-heavy maintenance (banded rewrites emit one
        # event per band decision) must not silently evict the seal/
        # compact provenance the serving tier reads
        self.events = EventLog(capacity=int(event_capacity))

    # -- introspection ------------------------------------------------------

    @property
    def num_docs(self) -> int:
        """Allocated doc-id space (ids are never reused)."""
        return len(self._live)

    @property
    def live_doc_count(self) -> int:
        return self._live_docs

    @property
    def num_segments(self) -> int:
        return len(self._segments)

    @property
    def num_terms(self) -> int:
        return len(self._hashes)

    @property
    def term_hashes(self) -> np.ndarray:
        return self._hashes

    def live_mask(self) -> np.ndarray:
        return self._live.copy()

    def segment_postings(self) -> list:
        return [s.n_postings for s in self._segments]

    def segments(self) -> list:
        """The sealed stack (ascending doc_base; treat as read-only)."""
        return list(self._segments)

    def layout_mix(self) -> dict:
        """Per-layout composition of the sealed stack (counts, docs,
        postings, chooser reasons, per-segment decisions) — what a
        campaign run reports as the mix the chooser converged to."""
        return _layout_mix(self._segments)

    @property
    def layout_policy(self) -> size_model.LayoutCostModel | None:
        """The POLICY rung of the seal-layout override ladder
        (``explicit seal(layout=...) arg > layout_policy > seal_layout``
        default).  ``None`` — the default — is bit-identical to the
        pre-chooser constants."""
        return self._layout_policy

    @layout_policy.setter
    def layout_policy(self, policy: size_model.LayoutCostModel | None):
        self._layout_policy = policy

    @property
    def delta_postings(self) -> int:
        return self._delta.n_postings

    @property
    def policy(self) -> compaction.TieredPolicy:
        return self._policy

    @property
    def delta_fill(self) -> float:
        """Fill fraction of the mutable delta (docs or postings,
        whichever is closer to capacity) — the maintenance thread's
        seal trigger."""
        dl = self._delta
        return max(dl.n_docs / dl.doc_cap, dl.n_postings / dl.post_cap)

    @property
    def epoch(self) -> int:
        """Monotonic counter of query-visible state changes.  The
        serving tier keys result caches on it: a cached (query, k,
        epoch) entry is valid iff the epoch still matches."""
        return self._epoch

    def _bump_epoch(self) -> None:
        self._epoch += 1

    def view(self) -> LiveView:
        """The epoch-pinned immutable view of the current state (cached
        per epoch).  Must be called serially with mutators — the serving
        tier holds its write lock for the pin, never for the query."""
        if self._view is not None and self._view.epoch == self._epoch:
            return self._view
        dl = self._delta
        n_p = dl.n_postings
        self._view = LiveView(
            epoch=self._epoch,
            segments=tuple(dataclasses.replace(s) for s in self._segments),
            delta_dev=self._delta_device(),
            delta_terms=dl.terms[:n_p].copy(),
            delta_tfs=dl.tfs[:n_p].copy(),
            delta_doc_of=dl.doc_of[:n_p].copy(),
            delta_doc_offsets=dl.doc_offsets[:dl.n_docs + 1].copy(),
            delta_doc_base=dl.doc_base, delta_n_docs=dl.n_docs,
            hashes=self._hashes, hash_sorted=self._hash_sorted,
            hash_order=self._hash_order, df=self._df.copy(),
            live=self._live.copy(), live_docs=self._live_docs,
            num_docs=self.num_docs)
        return self._view

    # -- vocabulary ---------------------------------------------------------

    def _rebuild_lookup(self) -> None:
        self._hash_order = np.argsort(self._hashes,
                                      kind="stable").astype(np.int64)
        self._hash_sorted = self._hashes[self._hash_order]

    def lookup_np(self, qh: np.ndarray) -> np.ndarray:
        """u32[...] hashes -> unified term ids (i64, -1 absent/empty)."""
        return _lookup_sorted(self._hash_sorted, self._hash_order, qh)

    # -- mutation: add ------------------------------------------------------

    def add_batch(self, corpus: TokenizedCorpus, *,
                  refresh_norms: bool = True) -> None:
        """Ingest a tokenized batch: unify vocabularies (vectorized
        remap), assign fresh ascending doc ids, append to the delta
        (sealing when full), update live df exactly, refresh norms, and
        let the tiered policy compact.

        ``refresh_norms=False`` defers the norm recomputation — an
        O(all live postings) pass per batch that turns a streaming
        build quadratic.  Norms depend only on the FINAL global df, so
        a streaming ingest loop may pass False for every batch and call
        ``self.refresh_norms()`` once at the end: the result is
        bit-identical to per-batch refreshing (the campaign's streaming
        parity test asserts this).  Until that call, every doc norm is
        0 and queries return no hits — deferral is a BUILD-loop tool,
        not a serving mode."""
        t0 = time.perf_counter()
        nd = corpus.num_docs
        merged, remap = build_mod.merge_vocab(
            self._hashes, np.asarray(corpus.term_hashes, np.uint32))
        if len(merged) != len(self._hashes):
            grow = len(merged) - len(self._hashes)
            self._hashes = merged
            self._df = np.concatenate(
                [self._df, np.zeros(grow, np.int64)])
            self._rebuild_lookup()
        if nd == 0:
            return
        lens = np.array([len(x) for x in corpus.doc_term_ids],
                        dtype=np.int64)
        total = int(lens.sum())
        if total:
            flat_terms = remap[
                np.concatenate(corpus.doc_term_ids).astype(np.int64)]
            flat_tfs = np.concatenate(corpus.doc_counts).astype(np.float32)
            doc_idx = np.repeat(np.arange(nd, dtype=np.int64), lens)
            # per-doc ascending UNIFIED term order: the remap can break
            # the corpus-local ordering, and norm bit-parity with the
            # term-major bulk sort depends on it
            order = np.lexsort((flat_terms, doc_idx))
            flat_terms = flat_terms[order]
            flat_tfs = flat_tfs[order]
        else:
            flat_terms = np.zeros(0, np.int64)
            flat_tfs = np.zeros(0, np.float32)

        self._live = np.concatenate([self._live, np.ones(nd, bool)])
        self._rank = np.concatenate(
            [self._rank,
             (self._rng.random(nd) * 1e-3).astype(np.float32)])
        self._norm = np.concatenate(
            [self._norm, np.zeros(nd, np.float32)])
        if total:
            self._df += np.bincount(flat_terms,
                                    minlength=len(self._hashes))
        self._live_docs += nd
        self.stats.postings_appended += total
        self.stats.docs_added += nd

        doc_starts = np.zeros(nd + 1, np.int64)
        np.cumsum(lens, out=doc_starts[1:])
        d = 0
        while d < nd:
            free_docs = self._delta.doc_cap - self._delta.n_docs
            free_posts = self._delta.post_cap - self._delta.n_postings
            cum = doc_starts[d:] - doc_starts[d]
            m = int(np.searchsorted(cum, free_posts, side="right")) - 1
            m = min(m, free_docs, nd - d)
            if m <= 0:
                if self._delta.n_docs > 0:
                    self._seal_delta()
                    continue
                # a single doc larger than the delta's posting capacity:
                # seal it directly as its own segment
                s, e = doc_starts[d], doc_starts[d + 1]
                self._direct_seal(flat_terms[s:e], flat_tfs[s:e])
                d += 1
                continue
            s, e = doc_starts[d], doc_starts[d + m]
            self._delta.append(lens[d:d + m], flat_terms[s:e],
                               flat_tfs[s:e])
            d += m
        self._delta_dirty = True
        if refresh_norms:
            self._refresh_norms()
        self._maybe_compact()
        self._bump_epoch()
        self.events.emit(
            "ingest", epoch=self._epoch, docs=nd, postings=total,
            norms_refreshed=bool(refresh_norms),
            duration_us=(time.perf_counter() - t0) * 1e6)

    def refresh_norms(self) -> None:
        """Recompute every live doc norm from the current global df and
        push the refreshed metadata to each segment's device DocTable.
        Streaming builds that deferred per-batch refreshes
        (``add_batch(..., refresh_norms=False)``) MUST call this before
        serving queries."""
        t0 = time.perf_counter()
        self._refresh_norms()
        self._bump_epoch()
        self.events.emit(
            "norm_refresh", epoch=self._epoch,
            postings=self.stats.postings_norm_refreshed,
            duration_us=(time.perf_counter() - t0) * 1e6)

    def _direct_seal(self, terms: np.ndarray, tfs: np.ndarray) -> None:
        """Seal one oversized doc straight to a segment, bypassing the
        delta (which must be empty; its base advances past the doc)."""
        assert self._delta.n_docs == 0
        t0 = time.perf_counter()
        base = self._delta.doc_base
        doc_of = np.zeros(len(terms), np.int64)
        seg = self._build_segment(base, 1, doc_of, terms.astype(np.int64),
                                  tfs)
        self._segments.append(seg)
        self.stats.postings_sealed += len(terms)
        self.stats.seals += 1
        self._delta = _Delta(self._delta.doc_cap, self._delta.post_cap,
                             base + 1)
        self._delta_dirty = True
        self._bump_epoch()
        self.events.emit(
            "seal", epoch=self._epoch, doc_base=seg.doc_base,
            docs=seg.doc_span, postings=seg.n_postings,
            size_class=seg.size_class, layout=seg.layout,
            band_cut=seg.band_cut,
            chooser_reason=seg.chooser_reason, direct=True,
            duration_us=(time.perf_counter() - t0) * 1e6)

    # -- mutation: delete ---------------------------------------------------

    def delete(self, doc_ids) -> None:
        """Tombstone documents: mark dead, decrement live df using the
        forward postings, refresh norms (dead norm -> 0, which every
        engine's deleted-doc mask honours in-kernel).  Postings stay in
        place until compaction reclaims them.  Already-dead ids are
        ignored; out-of-range ids raise."""
        ids = np.atleast_1d(np.asarray(doc_ids, np.int64))
        if ids.size == 0:
            return
        if ids.min() < 0 or ids.max() >= self.num_docs:
            raise ValueError(f"doc id out of range [0, {self.num_docs})")
        ids = np.unique(ids)
        ids = ids[self._live[ids]]
        if ids.size == 0:
            return
        for d in ids:
            terms = self._doc_terms(int(d))
            if len(terms):
                self._df[terms.astype(np.int64)] -= 1
        self._live[ids] = False
        self._live_docs -= int(ids.size)
        self.stats.deletes += int(ids.size)
        self._refresh_norms()
        self._bump_epoch()
        self.events.emit("delete", epoch=self._epoch, docs=int(ids.size),
                         live_docs=self._live_docs)

    def _owner(self, d: int):
        """Segment index owning global doc id d, or None for the delta."""
        if d >= self._delta.doc_base:
            return None
        bases = [s.doc_base for s in self._segments]
        i = bisect.bisect_right(bases, d) - 1
        seg = self._segments[i]
        assert seg.doc_base <= d < seg.doc_base + seg.doc_span
        return i

    def _doc_terms(self, d: int) -> np.ndarray:
        o = self._owner(d)
        if o is None:
            dl = self._delta
            local = d - dl.doc_base
            if local >= dl.n_docs:
                return np.zeros(0, np.int32)
            s, e = dl.doc_offsets[local], dl.doc_offsets[local + 1]
            return dl.terms[s:e]
        seg = self._segments[o]
        local = d - seg.doc_base
        s, e = seg.doc_offsets[local], seg.doc_offsets[local + 1]
        return seg.terms[s:e]

    # -- seal / compact -----------------------------------------------------

    def seal(self, layout: str | None = None) -> None:
        """Flush the delta into a sealed segment (no-op when empty).

        ``layout`` overrides the index's ``seal_layout`` for this seal:
        ``"hor"`` emits 128-lane HOR blocks, ``"packed"`` emits
        delta+bit-packed blocks (same size-class quantization, same
        fused-engine entry points, parity-tested against HOR)."""
        self._seal_delta(layout=layout)

    def _seal_delta(self, layout: str | None = None) -> None:
        dl = self._delta
        if dl.n_docs == 0:
            return
        t0 = time.perf_counter()
        n_p = dl.n_postings
        doc_of = dl.doc_of[:n_p].astype(np.int64)
        terms = dl.terms[:n_p].astype(np.int64)
        tfs = dl.tfs[:n_p].copy()
        live = self._live[doc_of + dl.doc_base]
        if not live.all():
            doc_of, terms, tfs = doc_of[live], terms[live], tfs[live]
        seg = self._build_segment(dl.doc_base, dl.n_docs, doc_of, terms,
                                  tfs, layout=layout)
        self._segments.append(seg)
        self.stats.postings_sealed += n_p
        self.stats.seals += 1
        self._delta = _Delta(dl.doc_cap, dl.post_cap,
                             dl.doc_base + dl.n_docs)
        self._delta_dirty = True
        self._bump_epoch()
        self.events.emit(
            "seal", epoch=self._epoch, doc_base=seg.doc_base,
            docs=seg.doc_span, postings=seg.n_postings,
            size_class=seg.size_class, layout=seg.layout,
            band_cut=seg.band_cut,
            chooser_reason=seg.chooser_reason,
            duration_us=(time.perf_counter() - t0) * 1e6)

    def _build_segment(self, base: int, span: int, doc_of: np.ndarray,
                       terms: np.ndarray, tfs: np.ndarray,
                       layout: str | None = None,
                       band_cut: int | None = None) -> Segment:
        """Bulk-build one sealed segment over LOCAL doc ids and pad it to
        its size class.  ``doc_of``/``terms``/``tfs`` must be (doc,
        term)-sorted.

        ``layout`` resolution is the override ladder: an explicit arg
        wins, else the installed ``layout_policy`` chooses from this
        run's measured shape, else the constructor's ``seal_layout``
        default — so seal AND compaction both funnel through the
        chooser, which is what makes merged (hot) segments converge to
        the winning layout over the LSM lifecycle."""
        w = len(self._hashes)
        d_pad = layouts.size_class(span, base=layouts.ROUTE_TILE)
        order = np.lexsort((doc_of, terms))          # term-major for bulk
        df_seg = (np.bincount(terms, minlength=w) if len(terms)
                  else np.zeros(w, np.int64))
        n_terms_seg = int(np.count_nonzero(df_seg))
        run_stats = size_model.SegmentStats(
            num_docs=int(span), num_postings=len(terms),
            num_terms=n_terms_seg)
        layout, reason = size_model.resolve_layout(
            layout, self._layout_policy, run_stats, self._seal_layout,
            size_class=d_pad)
        if layout not in ("hor", "packed", "banded"):
            raise ValueError(f"unknown seal layout: {layout!r}")
        # seal/compaction emit segments already tuned for their size
        # class: the routing cache is built at the tile width the active
        # tuning table picked for (pallas, d_pad, layout) — queries at
        # other widths fall back to the scaled budget path
        route_tile = autotune.lookup("pallas", d_pad, layout).tile
        offsets = np.zeros(w + 1, np.int64)
        np.cumsum(df_seg, out=offsets[1:])
        norm_pad = np.zeros(d_pad, np.float32)
        rank_pad = np.zeros(d_pad, np.float32)
        norm_pad[:span] = self._norm[base:base + span]
        rank_pad[:span] = self._rank[base:base + span]
        host = PostingsHost(
            term_hashes=self._hashes, df=df_seg.astype(np.int32),
            offsets=offsets, doc_ids=doc_of[order].astype(np.int32),
            tfs=tfs[order].astype(np.float32), num_docs=d_pad,
            norm=norm_pad, rank=rank_pad)
        cut = 0
        if layout == "banded":
            # band cut: explicit (snapshot restore reproduces the build
            # bitwise) or byte-model-chosen; lane_quantum=8 prices the
            # cut at the packed lane-dim padding applied just below
            bix = layouts.build_banded(host, max_band_words=band_cut,
                                       route_tile=route_tile,
                                       lane_quantum=8)
            # record the REALIZED pre-pad packed stride as the cut: no
            # term has a width in (realized max, chooser threshold], so
            # rebuilding with it reproduces the same band split — the
            # post-pad stride (multiple of 8) would NOT (it could admit
            # wider terms on restore)
            cut = int(bix.packed.words_per_block)
            p = bix.packed
            p = layouts.pad_packed_to_class(
                p,
                nb_pad=layouts.size_class(int(p.packed.shape[0])),
                w_pad=layouts.size_class(w, base=256),
                max_posting_len=layouts.size_class(p.max_posting_len),
                words_per_block=-(-p.words_per_block // 8) * 8,
                route_pairs_max=layouts.size_class(p.route_pairs_max),
                route_span_max=layouts.size_class(p.route_span_max,
                                                  base=8))
            hx = bix.hor
            mpl_q = layouts.size_class(hx.max_posting_len)
            hx = layouts.pad_blocked_to_class(
                hx,
                nb_pad=layouts.size_class(int(hx.block_docs.shape[0])),
                w_pad=layouts.size_class(w, base=256),
                max_posting_len=mpl_q,
                max_blocks_per_term=mpl_q // layouts.BLOCK,
                route_pairs_max=layouts.size_class(hx.route_pairs_max),
                route_span_max=layouts.size_class(hx.route_span_max,
                                                  base=8))
            # padding rebuilt per-band arrays; re-share the DocTable and
            # the (identical-content) vocabulary buffer across bands
            hx = dataclasses.replace(hx, docs=p.docs,
                                     sorted_hash=p.sorted_hash)
            ix = layouts.BandedCsrIndex(packed=p, hor=hx)
        elif layout == "packed":
            ix = layouts.build_packed_csr(host, route_tile=route_tile)
            ix = layouts.pad_packed_to_class(
                ix,
                nb_pad=layouts.size_class(int(ix.packed.shape[0])),
                w_pad=layouts.size_class(w, base=256),
                max_posting_len=layouts.size_class(ix.max_posting_len),
                # the packed id plane is THE roofline term packed wins
                # on, so its lane dim pads arithmetically (next multiple
                # of 8 words) instead of geometrically: doubling 52 ->
                # 64 words would stream back ~6% of the per-block win
                # as padding on every routed block
                words_per_block=-(-ix.words_per_block // 8) * 8,
                route_pairs_max=layouts.size_class(ix.route_pairs_max),
                route_span_max=layouts.size_class(ix.route_span_max,
                                                  base=8))
        else:
            ix = layouts.build_blocked(host, route_tile=route_tile)
            nb = int(ix.block_docs.shape[0])
            mpl_q = layouts.size_class(ix.max_posting_len)
            ix = layouts.pad_blocked_to_class(
                ix,
                nb_pad=layouts.size_class(nb),
                w_pad=layouts.size_class(w, base=256),
                max_posting_len=mpl_q,
                max_blocks_per_term=mpl_q // layouts.BLOCK,
                route_pairs_max=layouts.size_class(ix.route_pairs_max),
                route_span_max=layouts.size_class(ix.route_span_max,
                                                  base=8))
        doc_offsets = np.zeros(span + 1, np.int64)
        np.cumsum(np.bincount(doc_of.astype(np.int64), minlength=span),
                  out=doc_offsets[1:])
        return Segment(index=ix, doc_base=int(base), doc_span=int(span),
                       doc_of=doc_of.astype(np.int32),
                       terms=terms.astype(np.int32),
                       tfs=tfs.astype(np.float32),
                       doc_offsets=doc_offsets, n_postings=len(terms),
                       size_class=int(d_pad), num_terms=n_terms_seg,
                       chooser_reason=reason, band_cut=cut)

    def compact(self, all_segments: bool = False) -> bool:
        """Merge a policy-picked run of adjacent segments into one,
        physically dropping tombstoned postings (their ids stay dead —
        never reused).  ``all_segments=True`` rewrites the whole stack
        into a single segment (the compat wrapper's full merge).
        Returns True if a merge happened."""
        n = len(self._segments)
        if all_segments:
            pick = (0, n) if n >= 1 else None
        else:
            pick = self._policy.pick(
                [s.n_postings for s in self._segments])
        if pick is None:
            return False
        t0 = time.perf_counter()
        lo, hi = pick
        segs = self._segments[lo:hi]
        base = segs[0].doc_base
        span = segs[-1].doc_base + segs[-1].doc_span - base
        parts_d, parts_t, parts_f = [], [], []
        touched = 0
        for s in segs:
            touched += s.n_postings
            if s.n_postings == 0:
                continue
            live = self._live[s.doc_of.astype(np.int64) + s.doc_base]
            parts_d.append(s.doc_of[live].astype(np.int64) +
                           (s.doc_base - base))
            parts_t.append(s.terms[live].astype(np.int64))
            parts_f.append(s.tfs[live])
        if parts_d:
            doc_of = np.concatenate(parts_d)
            terms = np.concatenate(parts_t)
            tfs = np.concatenate(parts_f)
            order = np.lexsort((terms, doc_of))      # doc-major canonical
            doc_of, terms, tfs = doc_of[order], terms[order], tfs[order]
        else:
            doc_of = np.zeros(0, np.int64)
            terms = np.zeros(0, np.int64)
            tfs = np.zeros(0, np.float32)
        seg = self._build_segment(base, span, doc_of, terms, tfs)
        self._segments[lo:hi] = [seg]
        self.stats.postings_compacted += touched
        self.stats.compactions += 1
        self._bump_epoch()
        self.events.emit(
            "compact", epoch=self._epoch, merged=hi - lo,
            doc_base=seg.doc_base, docs=seg.doc_span,
            postings_in=touched, postings_out=seg.n_postings,
            size_class=seg.size_class, layout=seg.layout,
            band_cut=seg.band_cut,
            chooser_reason=seg.chooser_reason,
            duration_us=(time.perf_counter() - t0) * 1e6)
        return True

    def _maybe_compact(self) -> None:
        while self.compact():
            pass

    def pick_layout_rewrite(self) -> int | None:
        """Position of the oldest sealed segment whose layout disagrees
        with the installed ``layout_policy`` (None when no policy, or
        the stack already converged).  O(num_segments) on stored run
        stats — no posting data touched.  The decision re-evaluates the
        SAME stats ``rewrite_segment`` will rebuild with, so a rewrite
        can never oscillate."""
        if self._layout_policy is None:
            return None
        current = [s.layout for s in self._segments]
        wanted = [self._layout_policy.choose(
            s.stats, size_class=s.size_class).layout
            for s in self._segments]
        return compaction.pick_layout_rewrite(current, wanted)

    def rewrite_segment(self, i: int) -> None:
        """Re-seal segment ``i`` in place through the layout ladder
        (policy decides — there is no explicit arg here), physically
        dropping its tombstoned postings.  Doc ids, norms, and scores
        are unchanged: the rebuilt segment answers bit-identically in
        either layout (the layout-parity contract).  Epoch advances so
        serving tiers repin."""
        seg = self._segments[i]
        t0 = time.perf_counter()
        live = self._live[seg.doc_of.astype(np.int64) + seg.doc_base]
        doc_of = seg.doc_of[live].astype(np.int64)
        terms = seg.terms[live].astype(np.int64)
        tfs = seg.tfs[live]
        new = self._build_segment(seg.doc_base, seg.doc_span, doc_of,
                                  terms, tfs)
        self._segments[i] = new
        self.stats.postings_compacted += seg.n_postings
        self.stats.layout_rewrites += 1
        self._bump_epoch()
        self.events.emit(
            "rewrite", epoch=self._epoch, position=i,
            doc_base=new.doc_base, docs=new.doc_span,
            from_layout=seg.layout, layout=new.layout,
            postings_in=seg.n_postings, postings_out=new.n_postings,
            size_class=new.size_class, band_cut=new.band_cut,
            chooser_reason=new.chooser_reason,
            duration_us=(time.perf_counter() - t0) * 1e6)

    # -- norms / doc metadata ----------------------------------------------

    def _refresh_norms(self) -> None:
        """Recompute every live doc's tf-idf norm with the CURRENT live
        df and doc count — the same float64 bincount (per-doc ascending-
        term accumulation order) as the bulk builder, so norms are
        bit-identical to a rebuild.  Dead docs get norm 0 (the tombstone
        mask every engine honours); live empty docs get 1e-12."""
        n_alloc = self.num_docs
        w = len(self._df)
        idf64 = (np.log1p(self._live_docs /
                          np.maximum(self._df, 1).astype(np.float64))
                 if w else np.zeros(0))
        norm_sq = np.zeros(n_alloc, np.float64)
        touched = 0
        for seg in self._segments:
            if seg.n_postings == 0:
                continue
            wv = seg.tfs * idf64[seg.terms.astype(np.int64)]
            norm_sq += np.bincount(
                seg.doc_of.astype(np.int64) + seg.doc_base,
                weights=wv * wv, minlength=n_alloc)
            touched += seg.n_postings
        dl = self._delta
        if dl.n_postings:
            wv = (dl.tfs[:dl.n_postings] *
                  idf64[dl.terms[:dl.n_postings].astype(np.int64)])
            norm_sq += np.bincount(
                dl.doc_of[:dl.n_postings].astype(np.int64) + dl.doc_base,
                weights=wv * wv, minlength=n_alloc)
            touched += dl.n_postings
        norm = np.sqrt(norm_sq).astype(np.float32)
        norm[norm == 0] = 1e-12
        norm[~self._live] = 0.0
        self._norm = norm
        self.stats.postings_norm_refreshed += touched
        for seg in self._segments:
            self._push_doc_meta(seg)
        self._delta_dirty = True

    def _push_doc_meta(self, seg: Segment) -> None:
        d_pad = seg.index.docs.num_docs
        norm_pad = np.zeros(d_pad, np.float32)
        norm_pad[:seg.doc_span] = self._norm[
            seg.doc_base:seg.doc_base + seg.doc_span]
        docs = DocTable(norm=jnp.asarray(norm_pad),
                        rank=seg.index.docs.rank)
        if isinstance(seg.index, layouts.BandedCsrIndex):
            # one DocTable object, shared by both bands (as at build)
            seg.index = layouts.BandedCsrIndex(
                packed=dataclasses.replace(seg.index.packed, docs=docs),
                hor=dataclasses.replace(seg.index.hor, docs=docs))
        else:
            seg.index = dataclasses.replace(seg.index, docs=docs)

    def _delta_device(self) -> dict:
        if self._delta_dev is None or self._delta_dirty:
            dl = self._delta
            norm = np.zeros(dl.doc_cap, np.float32)
            rank = np.zeros(dl.doc_cap, np.float32)
            hi = min(dl.doc_base + dl.doc_cap, self.num_docs)
            n = max(hi - dl.doc_base, 0)
            norm[:n] = self._norm[dl.doc_base:hi]
            rank[:n] = self._rank[dl.doc_base:hi]
            self._delta_dev = {
                "terms": jnp.asarray(dl.terms),
                "tfs": jnp.asarray(dl.tfs),
                "doc_of": jnp.asarray(dl.doc_of),
                "norm": jnp.asarray(norm),
                "rank": jnp.asarray(rank),
            }
            self._delta_dirty = False
        return self._delta_dev

    # -- queries ------------------------------------------------------------

    def topk(self, query_hashes, k: int, *, cap: int | None = None,
             rank_blend: float = 0.0, engine: str = "pallas",
             mode: str = "candidates", backend: str = "pallas",
             return_stats: bool = False, tune=None, trace=None):
        """Batched top-k over delta + every sealed segment.

        query_hashes u32[B, T].  One fused candidate-kernel launch per
        sealed segment (``engine="pallas"``, the default; ``mode=
        "dense"`` keeps the PR-1 dense tail, ``engine="jnp"`` is the
        gather oracle) + one static-shape delta evaluation; per-segment
        candidate lists merge on the host with the oracle's tie order.
        ``cap`` defaults to each segment's (quantized) full posting
        length — the exact-parity setting.  Evaluates against the
        current epoch's pinned view (``view()``), which is also what the
        serving tier queries directly.  ``tune`` overrides the active
        tuning table's per-segment kernel geometry (see
        ``LiveView.topk``)."""
        return self.view().topk(query_hashes, k, cap=cap,
                                rank_blend=rank_blend, engine=engine,
                                mode=mode, backend=backend,
                                return_stats=return_stats, tune=tune,
                                trace=trace)

    def conjunctive(self, query_hashes, k: int, cap: int):
        """AND semantics over the whole live index for ONE query [T].

        Each sealed segment contributes its local membership counts
        (docs live in exactly one segment, so local == global) and its
        own cap-truncation count; ``stats["truncated_terms"]``
        AGGREGATES across segments — truncation in ANY segment is
        surfaced, not just the last one scored."""
        return self.view().conjunctive(query_hashes, k, cap)

    # -- import / export ----------------------------------------------------

    @classmethod
    def from_host(cls, host: PostingsHost, **kwargs) -> "SegmentedIndex":
        """Seed a live index from bulk-built postings: one sealed
        segment over [0, num_docs), the host's vocabulary and static
        ranks, norms recomputed (identically) from live df."""
        si = cls(term_hashes=host.term_hashes, **kwargs)
        if host.num_docs == 0:
            return si
        si._live = np.ones(host.num_docs, bool)
        si._rank = host.rank.astype(np.float32).copy()
        si._norm = np.zeros(host.num_docs, np.float32)
        si._df = host.df.astype(np.int64).copy()
        si._live_docs = host.num_docs
        term_of = np.repeat(np.arange(host.num_terms, dtype=np.int64),
                            np.diff(host.offsets))
        doc = host.doc_ids.astype(np.int64)
        order = np.lexsort((term_of, doc))           # doc-major canonical
        seg = si._build_segment(0, host.num_docs, doc[order],
                                term_of[order],
                                host.tfs[order].astype(np.float32))
        si._segments.append(seg)
        si.stats.postings_sealed += seg.n_postings
        si.stats.seals += 1
        si._delta = _Delta(si._delta.doc_cap, si._delta.post_cap,
                           host.num_docs)
        si._refresh_norms()
        si._bump_epoch()
        si.events.emit(
            "seal", epoch=si._epoch, doc_base=0, docs=seg.doc_span,
            postings=seg.n_postings, size_class=seg.size_class,
            layout=seg.layout, band_cut=seg.band_cut,
            chooser_reason=seg.chooser_reason,
            via="from_host")
        return si

    def _live_triples(self):
        parts_d, parts_t, parts_f = [], [], []
        for seg in self._segments:
            if seg.n_postings == 0:
                continue
            gdoc = seg.doc_of.astype(np.int64) + seg.doc_base
            live = self._live[gdoc]
            parts_d.append(gdoc[live])
            parts_t.append(seg.terms[live].astype(np.int64))
            parts_f.append(seg.tfs[live])
        dl = self._delta
        if dl.n_postings:
            gdoc = dl.doc_of[:dl.n_postings].astype(np.int64) + dl.doc_base
            live = self._live[gdoc]
            parts_d.append(gdoc[live])
            parts_t.append(dl.terms[:dl.n_postings][live].astype(np.int64))
            parts_f.append(dl.tfs[:dl.n_postings][live])
        if not parts_d:
            return (np.zeros(0, np.int64), np.zeros(0, np.int64),
                    np.zeros(0, np.float32))
        return (np.concatenate(parts_d), np.concatenate(parts_t),
                np.concatenate(parts_f))

    def to_host(self) -> PostingsHost:
        """Export merged live postings as §3.6 bulk output (the compat
        wrapper's return).  Doc ids keep their global values; with
        tombstones present the dead ids export as deleted (norm 0) empty
        docs, and the export's norms use the allocated id count as D —
        build the oracle from ``export_live_corpus`` when an exact
        live-corpus reference is needed."""
        gdoc, terms, tfs = self._live_triples()
        host = build_mod._postings_from_triples(
            gdoc, terms, tfs.astype(np.float64), len(self._hashes),
            self.num_docs, self._hashes)
        if not self._live.all():
            norm = host.norm.copy()
            norm[~self._live] = 0.0
            host = dataclasses.replace(host, norm=norm)
        return host

    def export_live_corpus(self):
        """The equivalent live corpus over the unified vocabulary, plus
        the ascending global ids of its docs — exactly what the parity
        oracle should ``bulk_build`` (compact renumbering preserves doc
        order, so tie-breaking maps 1:1)."""
        return self.view().export_live_corpus()
