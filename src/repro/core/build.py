"""Index construction — the paper's §3.6 bulk "copy" pipeline.

Pipeline (host-side, vectorized numpy — this is the data-ingest layer):

  token streams -> (doc, term, count) triples -> lexsort by (term, doc)
  -> df / offsets / CSR postings -> tf-idf document norms -> PostingsHost

Two paths, mirroring §3.6:
  * ``bulk_build``      — the COPY path: one big sort, no incremental
                          maintenance, indices built once at the end.
  * ``add_documents``   — incremental batch add: drop derived structures,
                          merge-sort new postings in, rebuild metadata
                          (drop-indices -> insert -> re-create, as §3.6).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.layouts import PostingsHost
from repro.core.size_model import CorpusStats


@dataclasses.dataclass(frozen=True)
class TokenizedCorpus:
    """Per-document distinct terms + in-doc counts (already aggregated)."""
    doc_term_ids: Sequence[np.ndarray]   # per-doc i64 distinct term ids
    doc_counts: Sequence[np.ndarray]     # per-doc i64 counts (same shapes)
    term_hashes: np.ndarray              # u32[W], id -> hash (bijective mix)
    num_docs: int

    @property
    def num_terms(self) -> int:
        return len(self.term_hashes)


def _flatten(corpus: TokenizedCorpus):
    lens = np.array([len(x) for x in corpus.doc_term_ids], dtype=np.int64)
    doc_of = np.repeat(np.arange(corpus.num_docs, dtype=np.int64), lens)
    terms = (np.concatenate(corpus.doc_term_ids) if len(lens) and lens.sum()
             else np.zeros(0, np.int64))
    counts = (np.concatenate(corpus.doc_counts) if len(lens) and lens.sum()
              else np.zeros(0, np.int64))
    return doc_of, terms, counts


def _postings_from_triples(doc_of, terms, counts, num_terms, num_docs,
                           term_hashes) -> PostingsHost:
    order = np.lexsort((doc_of, terms))      # term-major, doc-sorted within
    terms_s = terms[order]
    docs_s = doc_of[order].astype(np.int32)
    tf_s = counts[order].astype(np.float32)  # raw counts as tf (Mitos-style)
    df = np.bincount(terms_s, minlength=num_terms).astype(np.int32)
    offsets = np.zeros(num_terms + 1, dtype=np.int64)
    np.cumsum(df, out=offsets[1:])
    # tf-idf document norms (paper §3.6: computed after all docs indexed)
    idf = np.log1p(num_docs / np.maximum(df, 1).astype(np.float64))
    w = tf_s * idf[terms_s]
    norm_sq = np.bincount(docs_s, weights=w * w, minlength=num_docs)
    norm = np.sqrt(norm_sq).astype(np.float32)
    norm[norm == 0] = 1e-12  # empty docs stay "live" but unreachable
    rank = _pagerank_proxy(num_docs)
    return PostingsHost(
        term_hashes=term_hashes.astype(np.uint32), df=df,
        offsets=offsets, doc_ids=docs_s, tfs=tf_s,
        num_docs=num_docs, norm=norm, rank=rank,
    )


def _pagerank_proxy(num_docs: int, seed: int = 7) -> np.ndarray:
    """Static-rank column (the paper stores PageRank; we store a fixed
    pseudo-random static score so ranking paths are exercised)."""
    rng = np.random.default_rng(seed)
    return (rng.random(num_docs).astype(np.float32) * 1e-3)


def bulk_build(corpus: TokenizedCorpus) -> PostingsHost:
    """The §3.6 COPY path: one global sort, derived data computed once."""
    doc_of, terms, counts = _flatten(corpus)
    return _postings_from_triples(doc_of, terms, counts, corpus.num_terms,
                                  corpus.num_docs, corpus.term_hashes)


def add_documents(host: PostingsHost, new_corpus: TokenizedCorpus,
                  doc_id_base: int | None = None) -> PostingsHost:
    """Incremental batch add (drop-indices -> merge -> rebuild).

    New docs get ids starting at ``doc_id_base`` (default: append).
    Term id space must match (same term_hashes); new terms are appended.
    """
    base = host.num_docs if doc_id_base is None else doc_id_base
    doc_of, terms, counts = _flatten(new_corpus)
    doc_of = doc_of + base

    # unify vocabularies: append genuinely new hashes
    old_hash = host.term_hashes
    new_hash = new_corpus.term_hashes
    hash_to_old = {int(h): i for i, h in enumerate(old_hash)}
    remap = np.empty(len(new_hash), dtype=np.int64)
    extra = []
    for i, h in enumerate(new_hash):
        j = hash_to_old.get(int(h))
        if j is None:
            j = len(old_hash) + len(extra)
            extra.append(h)
        remap[i] = j
    merged_hashes = (np.concatenate([old_hash,
                                     np.array(extra, dtype=np.uint32)])
                     if extra else old_hash)
    terms = remap[terms]

    # old postings back to triples, then one merged sort
    old_terms = np.repeat(np.arange(host.num_terms, dtype=np.int64),
                          np.diff(host.offsets))
    all_docs = np.concatenate([host.doc_ids.astype(np.int64), doc_of])
    all_terms = np.concatenate([old_terms, terms])
    all_counts = np.concatenate([host.tfs.astype(np.float64),
                                 counts.astype(np.float64)])
    num_docs = max(host.num_docs, int(doc_of.max()) + 1 if len(doc_of) else 0,
                   base + new_corpus.num_docs)
    return _postings_from_triples(all_docs, all_terms, all_counts,
                                  len(merged_hashes), num_docs,
                                  merged_hashes)


def corpus_stats(host: PostingsHost) -> CorpusStats:
    return CorpusStats(D=host.num_docs, W=host.num_terms,
                       N_d=host.num_postings,
                       N=int(host.tfs.sum()))
