"""Index construction — the paper's §3.6 bulk "copy" pipeline.

Pipeline (host-side, vectorized numpy — this is the data-ingest layer):

  token streams -> (doc, term, count) triples -> lexsort by (term, doc)
  -> df / offsets / CSR postings -> tf-idf document norms -> PostingsHost

Two paths, mirroring §3.6:
  * ``bulk_build``      — the COPY path: one big sort, no incremental
                          maintenance, indices built once at the end.
  * ``add_documents``   — incremental batch add: same contract as the
                          paper's drop-indices -> insert -> re-create,
                          now a compat wrapper over the segmented live
                          index (core/live_index.py) + full compaction.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.layouts import PostingsHost
from repro.core.size_model import CorpusStats


@dataclasses.dataclass(frozen=True)
class TokenizedCorpus:
    """Per-document distinct terms + in-doc counts (already aggregated)."""
    doc_term_ids: Sequence[np.ndarray]   # per-doc i64 distinct term ids
    doc_counts: Sequence[np.ndarray]     # per-doc i64 counts (same shapes)
    term_hashes: np.ndarray              # u32[W], id -> hash (bijective mix)
    num_docs: int

    @property
    def num_terms(self) -> int:
        return len(self.term_hashes)


def _flatten(corpus: TokenizedCorpus):
    lens = np.array([len(x) for x in corpus.doc_term_ids], dtype=np.int64)
    doc_of = np.repeat(np.arange(corpus.num_docs, dtype=np.int64), lens)
    terms = (np.concatenate(corpus.doc_term_ids) if len(lens) and lens.sum()
             else np.zeros(0, np.int64))
    counts = (np.concatenate(corpus.doc_counts) if len(lens) and lens.sum()
              else np.zeros(0, np.int64))
    return doc_of, terms, counts


def _postings_from_triples(doc_of, terms, counts, num_terms, num_docs,
                           term_hashes) -> PostingsHost:
    order = np.lexsort((doc_of, terms))      # term-major, doc-sorted within
    terms_s = terms[order]
    docs_s = doc_of[order].astype(np.int32)
    tf_s = counts[order].astype(np.float32)  # raw counts as tf (Mitos-style)
    df = np.bincount(terms_s, minlength=num_terms).astype(np.int32)
    offsets = np.zeros(num_terms + 1, dtype=np.int64)
    np.cumsum(df, out=offsets[1:])
    # tf-idf document norms (paper §3.6: computed after all docs indexed)
    idf = np.log1p(num_docs / np.maximum(df, 1).astype(np.float64))
    w = tf_s * idf[terms_s]
    norm_sq = np.bincount(docs_s, weights=w * w, minlength=num_docs)
    norm = np.sqrt(norm_sq).astype(np.float32)
    norm[norm == 0] = 1e-12  # empty docs stay "live" but unreachable
    rank = _pagerank_proxy(num_docs)
    return PostingsHost(
        term_hashes=term_hashes.astype(np.uint32), df=df,
        offsets=offsets, doc_ids=docs_s, tfs=tf_s,
        num_docs=num_docs, norm=norm, rank=rank,
    )


def _pagerank_proxy(num_docs: int, seed: int = 7) -> np.ndarray:
    """Static-rank column (the paper stores PageRank; we store a fixed
    pseudo-random static score so ranking paths are exercised)."""
    rng = np.random.default_rng(seed)
    return (rng.random(num_docs).astype(np.float32) * 1e-3)


def bulk_build(corpus: TokenizedCorpus) -> PostingsHost:
    """The §3.6 COPY path: one global sort, derived data computed once."""
    doc_of, terms, counts = _flatten(corpus)
    return _postings_from_triples(doc_of, terms, counts, corpus.num_terms,
                                  corpus.num_docs, corpus.term_hashes)


def merge_vocab(old_hashes: np.ndarray, new_hashes: np.ndarray
                ) -> tuple[np.ndarray, np.ndarray]:
    """Vectorized vocabulary union (replaces the per-hash dict loop).

    Returns ``(merged_hashes, remap)``: ``merged_hashes`` is
    ``old_hashes`` with genuinely new hashes appended in first-
    appearance order; ``remap[i]`` is the merged id of
    ``new_hashes[i]``.  One ``np.searchsorted`` over the sorted old
    hashes instead of a Python dict probe per term — the hot half of
    every incremental vocabulary merge (live-index ingest and the
    legacy ``add_documents`` path share it).
    """
    old = np.asarray(old_hashes, np.uint32)
    new = np.asarray(new_hashes, np.uint32)
    remap = np.empty(len(new), dtype=np.int64)
    if len(old):
        order = np.argsort(old, kind="stable")
        srt = old[order]
        pos = np.minimum(np.searchsorted(srt, new), len(old) - 1)
        found = srt[pos] == new
        remap[found] = order[pos[found]]
    else:
        found = np.zeros(len(new), bool)
    remap[~found] = len(old) + np.cumsum(~found)[~found] - 1
    merged = (np.concatenate([old, new[~found]]) if (~found).any()
              else old)
    return merged, remap


def add_documents(host: PostingsHost, new_corpus: TokenizedCorpus,
                  doc_id_base: int | None = None) -> PostingsHost:
    """Incremental batch add — §3.6 semantics, live-index machinery.

    Historically this dropped every derived structure and merge-sorted
    ALL postings (the paper's drop-indices -> insert -> re-create).  It
    is now a thin compat wrapper over the segmented live index
    (core/live_index.py): seed a one-segment index from ``host``, ingest
    the batch through the delta, seal, fully compact, and export — the
    same merged ``PostingsHost`` (identical df/doc_ids/norms), with the
    vocabulary remap vectorized (``merge_vocab``).  A custom
    ``doc_id_base`` overlapping existing ids keeps the legacy one-shot
    merge path.
    """
    base = host.num_docs if doc_id_base is None else doc_id_base
    if base != host.num_docs:
        return _merge_documents(host, new_corpus, base)
    from repro.core.live_index import SegmentedIndex
    si = SegmentedIndex.from_host(host)
    si.add_batch(new_corpus)
    si.seal()
    si.compact(all_segments=True)
    return si.to_host()


def _merge_documents(host: PostingsHost, new_corpus: TokenizedCorpus,
                     base: int) -> PostingsHost:
    """Legacy one-shot merge (kept for overlapping ``doc_id_base``)."""
    doc_of, terms, counts = _flatten(new_corpus)
    doc_of = doc_of + base
    merged_hashes, remap = merge_vocab(host.term_hashes,
                                       new_corpus.term_hashes)
    terms = remap[terms]

    # old postings back to triples, then one merged sort
    old_terms = np.repeat(np.arange(host.num_terms, dtype=np.int64),
                          np.diff(host.offsets))
    all_docs = np.concatenate([host.doc_ids.astype(np.int64), doc_of])
    all_terms = np.concatenate([old_terms, terms])
    all_counts = np.concatenate([host.tfs.astype(np.float64),
                                 counts.astype(np.float64)])
    num_docs = max(host.num_docs, int(doc_of.max()) + 1 if len(doc_of) else 0,
                   base + new_corpus.num_docs)
    return _postings_from_triples(all_docs, all_terms, all_counts,
                                  len(merged_hashes), num_docs,
                                  merged_hashes)


def corpus_stats(host: PostingsHost) -> CorpusStats:
    return CorpusStats(D=host.num_docs, W=host.num_terms,
                       N_d=host.num_postings,
                       N=int(host.tfs.sum()))
