"""Segment / ragged primitives shared across the framework.

This module is the substrate for the paper's central idea: a ragged
collection of variable-length lists (posting lists, adjacency lists,
embedding bags, expert token groups) stored as ONE contiguous packed
values array plus an ``offsets`` array — i.e. CSR.  Everything here is
jit-compatible and static-shape friendly (TPU requires static shapes, so
ragged structures carry a static capacity and explicit validity).

Conventions
-----------
* ``offsets``: int32[num_segments + 1], monotonically non-decreasing,
  ``offsets[0] == 0``, ``offsets[-1] == total valid entries``.
* ``segment_ids``: int32[capacity] expansion of offsets; entries past the
  valid range point at ``num_segments`` (a trash row).
* All reductions use ``jax.ops.segment_*`` with ``indices_are_sorted`` when
  the layout guarantees it (CSR does).
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

# ---------------------------------------------------------------------------
# offsets <-> segment ids
# ---------------------------------------------------------------------------


def lengths_to_offsets(lengths: Array) -> Array:
    """int32[num_segments] -> int32[num_segments+1] exclusive prefix sum."""
    lengths = lengths.astype(jnp.int32)
    return jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(lengths, dtype=jnp.int32)]
    )


def offsets_to_lengths(offsets: Array) -> Array:
    return (offsets[1:] - offsets[:-1]).astype(jnp.int32)


def offsets_to_segment_ids(offsets: Array, capacity: int) -> Array:
    """Expand CSR offsets into a per-entry segment id vector.

    Entries at positions >= offsets[-1] (padding) get id == num_segments,
    which works as a trash row for ``segment_sum(..., num_segments + 1)``.
    """
    num_segments = offsets.shape[0] - 1
    # searchsorted(side='right') - 1 maps position -> owning segment.
    pos = jnp.arange(capacity, dtype=jnp.int32)
    ids = jnp.searchsorted(offsets, pos, side="right").astype(jnp.int32) - 1
    valid = pos < offsets[-1]
    return jnp.where(valid, ids, num_segments)


def segment_ids_to_offsets(segment_ids: Array, num_segments: int) -> Array:
    """Inverse of the above for sorted segment_ids (padding id == num_segments)."""
    counts = jnp.bincount(segment_ids, length=num_segments + 1)[:num_segments]
    return lengths_to_offsets(counts)


# ---------------------------------------------------------------------------
# segment reductions
# ---------------------------------------------------------------------------


def segment_sum(data: Array, segment_ids: Array, num_segments: int,
                sorted_ids: bool = True) -> Array:
    return jax.ops.segment_sum(
        data, segment_ids, num_segments=num_segments,
        indices_are_sorted=sorted_ids)


def segment_max(data: Array, segment_ids: Array, num_segments: int,
                sorted_ids: bool = True) -> Array:
    return jax.ops.segment_max(
        data, segment_ids, num_segments=num_segments,
        indices_are_sorted=sorted_ids)


def segment_min(data: Array, segment_ids: Array, num_segments: int,
                sorted_ids: bool = True) -> Array:
    return jax.ops.segment_min(
        data, segment_ids, num_segments=num_segments,
        indices_are_sorted=sorted_ids)


def segment_mean(data: Array, segment_ids: Array, num_segments: int,
                 sorted_ids: bool = True) -> Array:
    total = segment_sum(data, segment_ids, num_segments, sorted_ids)
    ones = jnp.ones(data.shape[:1], dtype=data.dtype)
    count = segment_sum(ones, segment_ids, num_segments, sorted_ids)
    count = jnp.maximum(count, 1)
    if data.ndim > 1:
        count = count.reshape((-1,) + (1,) * (data.ndim - 1))
    return total / count


def segment_std(data: Array, segment_ids: Array, num_segments: int,
                sorted_ids: bool = True, eps: float = 1e-5) -> Array:
    """Per-segment standard deviation (PNA 'std' aggregator)."""
    mean = segment_mean(data, segment_ids, num_segments, sorted_ids)
    mean_sq = segment_mean(data * data, segment_ids, num_segments, sorted_ids)
    var = jnp.maximum(mean_sq - mean * mean, 0.0)
    return jnp.sqrt(var + eps)


def segment_softmax(logits: Array, segment_ids: Array, num_segments: int,
                    sorted_ids: bool = True) -> Array:
    """Softmax within each segment (GAT-style edge softmax)."""
    seg_max = segment_max(logits, segment_ids, num_segments, sorted_ids)
    seg_max = jnp.where(jnp.isfinite(seg_max), seg_max, 0.0)
    shifted = logits - seg_max[segment_ids]
    exp = jnp.exp(shifted)
    denom = segment_sum(exp, segment_ids, num_segments, sorted_ids)
    denom = jnp.maximum(denom, 1e-30)
    return exp / denom[segment_ids]


# ---------------------------------------------------------------------------
# ragged gather: fetch one segment's slab (dynamic) into a fixed capacity
# ---------------------------------------------------------------------------


def gather_segment(values: Array, offsets: Array, segment: Array | int,
                   capacity: int, fill=0) -> tuple[Array, Array]:
    """Fetch segment ``segment``'s entries into a [capacity] buffer.

    Returns (buffer, valid_mask).  This is the q_occ primitive: one
    contiguous DMA slab in the CSR layout.
    """
    start = offsets[segment]
    length = offsets[segment + 1] - start
    idx = start + jnp.arange(capacity, dtype=jnp.int32)
    valid = jnp.arange(capacity, dtype=jnp.int32) < length
    idx = jnp.where(valid, idx, 0)
    buf = jnp.take(values, idx, axis=0)
    if values.ndim == 1:
        buf = jnp.where(valid, buf, fill)
    else:
        buf = jnp.where(valid[:, None], buf, fill)
    return buf, valid


def gather_segments(values: Array, offsets: Array, segments: Array,
                    capacity: int, fill=0) -> tuple[Array, Array]:
    """vmap'd gather_segment over a batch of segment ids."""
    fn = functools.partial(gather_segment, capacity=capacity, fill=fill)
    return jax.vmap(lambda s: fn(values, offsets, s))(segments)


# ---------------------------------------------------------------------------
# embedding bag: the recsys primitive, same layout math as the paper
# ---------------------------------------------------------------------------


def embedding_bag(table: Array, indices: Array, offsets: Array,
                  mode: str = "sum", weights: Array | None = None) -> Array:
    """EmbeddingBag via take + segment_sum (JAX has no native one).

    ``indices`` int32[total] ragged bag members, ``offsets`` int32[bags+1].
    This is precisely the paper's ORIF representation of a multi-valued
    attribute: bags are packed contiguously; the bag id is never stored.
    """
    num_bags = offsets.shape[0] - 1
    capacity = indices.shape[0]
    seg = offsets_to_segment_ids(offsets, capacity)
    rows = jnp.take(table, indices, axis=0)
    if weights is not None:
        rows = rows * weights[:, None]
    if mode == "sum":
        return segment_sum(rows, seg, num_bags)
    if mode == "mean":
        return segment_mean(rows, seg, num_bags)
    if mode == "max":
        out = segment_max(rows, seg, num_bags)
        return jnp.where(jnp.isfinite(out), out, 0.0)
    raise ValueError(f"unknown mode {mode!r}")


# ---------------------------------------------------------------------------
# host-side builders (numpy; used by index construction & data pipelines)
# ---------------------------------------------------------------------------


def pack_ragged_np(lists: Sequence[np.ndarray], pad_to: int | None = None,
                   dtype=np.int32) -> tuple[np.ndarray, np.ndarray]:
    """Pack a python list of 1-D arrays into (values, offsets)."""
    lengths = np.array([len(x) for x in lists], dtype=np.int64)
    offsets = np.zeros(len(lists) + 1, dtype=np.int64)
    np.cumsum(lengths, out=offsets[1:])
    total = int(offsets[-1])
    cap = total if pad_to is None else int(pad_to)
    if cap < total:
        raise ValueError(f"pad_to={cap} < total={total}")
    values = np.zeros(cap, dtype=dtype)
    if lists:
        values[:total] = np.concatenate(lists) if total else values[:0]
    return values, offsets.astype(np.int32)
