"""Tiered compaction policy for the segmented live index.

The live index (core/live_index.py) accumulates immutable sealed
segments; left alone, a long ingest stream would mean one fused-kernel
launch per tiny segment at query time and an ever-growing tombstone
set.  Background reorganization fixes both — the DB-IR systems the
design follows (ODYS, arXiv:1208.4270; compressed-index maintenance,
arXiv:1209.5448) merge sealed runs in the background while queries keep
reading the old stack.

This module is the POLICY half: pure functions over the stack's posting
counts deciding WHAT to merge.  The MECHANISM (building the merged
segment, dropping tombstoned postings) lives on ``SegmentedIndex`` so
the policy stays trivially unit-testable.

Size-tiered semantics (Cassandra/Lucene-style): the newest runs are the
smallest (each seal emits one delta-sized run); ``pick_compaction``
finds the maximal suffix of similarly-sized runs (max/min within
``size_ratio``) and merges it once it has ``min_run`` members.  Merged
runs are ~``min_run``x bigger, so they leave the suffix band and only
merge again when enough same-sized peers accumulate — write
amplification stays O(log_{min_run} N) per posting while the stack
depth stays O(log N).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class TieredPolicy:
    """Size-ratio trigger for merging the newest run of segments.

    size_ratio: two runs are "similarly sized" when max/min < size_ratio.
    min_run:    merge only once the similar-sized suffix has this many
                members (smaller merges waste write bandwidth).
    """
    size_ratio: float = 4.0
    min_run: int = 4

    def pick(self, sizes: list[int]) -> tuple[int, int] | None:
        """Segments to merge as a half-open stack slice (lo, hi), newest
        last, or None.  ``sizes`` are per-segment posting counts in
        stack order (oldest first)."""
        return pick_compaction(sizes, self.size_ratio, self.min_run)

    def due(self, sizes: list[int]) -> bool:
        """True when the stack has a mergeable run.  The serving tier's
        maintenance thread checks this BEFORE taking the index write
        lock, so an idle stack costs queries no lock contention."""
        return self.pick(sizes) is not None


def pick_compaction(sizes: list[int], size_ratio: float = 4.0,
                    min_run: int = 4) -> tuple[int, int] | None:
    """Maximal suffix of similarly-sized runs, if long enough to merge.

    Walks from the newest run backwards while the suffix stays within
    ``size_ratio`` (strict: ``max < size_ratio * min``, so a run that
    already absorbed ``size_ratio`` peers does not re-merge with fresh
    delta-sized runs).  Empty segments (size 0, all postings tombstoned
    away) count as size 1 so they are always eligible for cleanup.
    A pick always spans >= 2 segments regardless of ``min_run`` — a
    single-segment "merge" makes no progress, and returning one would
    spin the caller's compact-until-quiescent loop forever.
    """
    n = len(sizes)
    min_run = max(min_run, 2)
    if n < min_run:
        return None
    lo = n - 1
    hi_max = hi_min = max(sizes[-1], 1)
    while lo > 0:
        s = max(sizes[lo - 1], 1)
        new_max, new_min = max(hi_max, s), min(hi_min, s)
        if not new_max < size_ratio * new_min:
            break
        hi_max, hi_min = new_max, new_min
        lo -= 1
    if n - lo >= min_run:
        return lo, n
    return None


def pick_layout_rewrite(current: list[str],
                        wanted: list[str]) -> int | None:
    """Stack position of the next segment to re-seal into its
    policy-preferred layout, or None when converged.

    ``current`` / ``wanted`` are per-segment layout tags in stack order
    (oldest first).  Oldest-first: old segments are the biggest and the
    least likely to be rewritten by a future tiered merge anyway, so
    converging them first retires the most mispredicted bytes per
    rewrite.  Same policy/mechanism split as ``pick_compaction`` — the
    rebuild itself lives on ``SegmentedIndex.rewrite_segment``.
    """
    for i, (cur, want) in enumerate(zip(current, wanted)):
        if cur != want:
            return i
    return None
