"""The paper's four index representations as TPU/HBM array layouts.

Paper -> TPU mapping (see DESIGN.md §2):

  PR   -> CooIndex        heap-of-tuples: postings stored in ARRIVAL (doc)
                          order as three parallel columns, plus a B+tree
                          analogue (a (term,doc)-sorted permutation with
                          per-term starts).  A term's postings are scattered
                          across the heap -> gathers are random-access, and
                          the term-id column is stored per posting.  This is
                          exactly why PR loses: redundant bytes + random I/O.

  OR   -> CsrIndex        postings packed contiguously per term (the
                          ARRAY-of-Point idea): offsets[W+1] + doc_ids[P] +
                          tfs[P].  A separate word table (hash->id, df)
                          remains, as in the paper's OR.

  COR  -> CompactCsrIndex word table folded into the posting relation: the
                          sorted term-hash array IS the lookup structure and
                          df lives alongside.  One fewer lookup phase.

  HOR  -> BlockedIndex    postings in fixed 128-lane blocks with per-block
                          doc-id min/max summaries: the TPU analogue of
                          hstore (keyed access within a term) + GIN (block
                          skipping for document-based probes).

  (beyond paper)
       -> PackedCsrIndex  delta + bit-packed doc ids, fp16 tf — the "special
                          number encodings" §3.1 says DBMSs lack.

All device structures are frozen dataclass pytrees of int32/float32 arrays;
builders are host-side numpy.  ``doc_ids`` within a term are always sorted
ascending (as a DBMS clustered index and every IR system guarantees).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import segments

Array = jax.Array

BLOCK = 128  # posting block size: one VPU lane-width / VMEM-friendly tile
ROUTE_TILE = 512  # doc-tile width the scoring kernels route against


def _block_tile_routing(block_min: np.ndarray, block_max: np.ndarray,
                        num_docs: int, tile: int
                        ) -> Tuple[np.ndarray, np.ndarray]:
    """Host-side pair-routing cache: per-block doc-tile span.

    The fused scoring kernel walks (block, tile) pairs; a block overlaps
    the contiguous tile range [min//tile, max//tile].  This was computed
    per query inside ``build_pairs`` — it is a pure function of the
    (immutable) index, so it is built ONCE here and stored on the index.
    Returns (tile_first i32[NB], tile_count i32[NB]); empty blocks
    (max < 0) get count 0.
    """
    n_tiles = max(-(-num_docs // tile), 1)
    has = block_max >= 0
    t0 = np.clip(block_min // tile, 0, n_tiles - 1)
    t1 = np.clip(block_max // tile, 0, n_tiles - 1)
    first = np.where(has, t0, 0).astype(np.int32)
    count = np.where(has, t1 - t0 + 1, 0).astype(np.int32)
    return first, count


def _register(cls):
    names = [f.name for f in dataclasses.fields(cls)]
    static = set(getattr(cls, "_static_fields", ()))
    jax.tree_util.register_dataclass(
        cls,
        data_fields=[n for n in names if n not in static],
        meta_fields=[n for n in names if n in static],
    )
    return cls


# ---------------------------------------------------------------------------
# shared tables
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DocTable:
    """Per-document metadata: the paper's ``document`` relation."""
    _static_fields = ()
    norm: Array   # f32[D]  vector norm under tf-idf (paper §3.6)
    rank: Array   # f32[D]  PageRank-like static score

    @property
    def num_docs(self) -> int:
        return self.norm.shape[0]

    def nbytes(self) -> int:
        return int(self.norm.nbytes + self.rank.nbytes)


_register(DocTable)


@dataclasses.dataclass(frozen=True)
class SortedLookup:
    """B+tree analogue: binary search over sorted term hashes."""
    _static_fields = ()
    sorted_hash: Array  # u32[W] ascending
    perm: Array         # i32[W] sorted position -> term id

    def lookup(self, hashes: Array) -> Array:
        """u32[T] -> term ids i32[T], -1 where absent."""
        pos = jnp.searchsorted(self.sorted_hash, hashes).astype(jnp.int32)
        pos = jnp.clip(pos, 0, self.sorted_hash.shape[0] - 1)
        hit = self.sorted_hash[pos] == hashes
        return jnp.where(hit, self.perm[pos], -1)

    def nbytes(self) -> int:
        return int(self.sorted_hash.nbytes + self.perm.nbytes)


_register(SortedLookup)

HASH_EMPTY = np.uint32(0xFFFFFFFF)
MAX_PROBES = 16


@dataclasses.dataclass(frozen=True)
class HashLookup:
    """Open-addressed hash table analogue of a DBMS Hash index."""
    _static_fields = ()
    keys: Array   # u32[S], HASH_EMPTY where empty; S power of two
    vals: Array   # i32[S]

    def lookup(self, hashes: Array) -> Array:
        size = self.keys.shape[0]
        mask = jnp.uint32(size - 1)
        base = (hashes * jnp.uint32(2654435761)) & mask
        # vectorized probe: MAX_PROBES slots per query
        probe = (base[:, None] + jnp.arange(MAX_PROBES, dtype=jnp.uint32)[None, :]) & mask
        kk = self.keys[probe]                       # [T, MAX_PROBES]
        hit = kk == hashes[:, None]
        any_hit = jnp.any(hit, axis=1)
        first = jnp.argmax(hit, axis=1)
        slot = jnp.take_along_axis(probe, first[:, None], axis=1)[:, 0]
        return jnp.where(any_hit, self.vals[slot], -1).astype(jnp.int32)

    def nbytes(self) -> int:
        return int(self.keys.nbytes + self.vals.nbytes)


_register(HashLookup)


def build_sorted_lookup(term_hashes: np.ndarray) -> SortedLookup:
    order = np.argsort(term_hashes, kind="stable")
    return SortedLookup(
        sorted_hash=jnp.asarray(term_hashes[order].astype(np.uint32)),
        perm=jnp.asarray(order.astype(np.int32)),
    )


def build_hash_lookup(term_hashes: np.ndarray) -> HashLookup:
    w = len(term_hashes)
    size = 1 << int(np.ceil(np.log2(max(4 * w, 16))))
    while True:
        keys = np.full(size, HASH_EMPTY, dtype=np.uint32)
        vals = np.full(size, -1, dtype=np.int32)
        ok = True
        base = (term_hashes.astype(np.uint64) * 2654435761) % size
        for tid, b in enumerate(base.astype(np.int64)):
            placed = False
            for p in range(MAX_PROBES):
                s = (b + p) & (size - 1)
                if keys[s] == HASH_EMPTY:
                    keys[s] = term_hashes[tid]
                    vals[s] = tid
                    placed = True
                    break
            if not placed:
                ok = False
                break
        if ok:
            return HashLookup(keys=jnp.asarray(keys), vals=jnp.asarray(vals))
        size *= 2  # grow until every key fits within MAX_PROBES


# ---------------------------------------------------------------------------
# Postings source-of-truth (host-side) used by all builders
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PostingsHost:
    """Host (numpy) canonical postings: the logical index content."""
    term_hashes: np.ndarray   # u32[W]  hash of each term (id == position)
    df: np.ndarray            # i32[W]
    # CSR over terms (term-major, doc-sorted within term):
    offsets: np.ndarray       # i64[W+1]
    doc_ids: np.ndarray       # i32[P]
    tfs: np.ndarray           # f32[P]
    num_docs: int
    norm: np.ndarray          # f32[D]
    rank: np.ndarray          # f32[D]

    @property
    def num_terms(self) -> int:
        return len(self.term_hashes)

    @property
    def num_postings(self) -> int:
        return len(self.doc_ids)

    @property
    def max_posting_len(self) -> int:
        if self.num_terms == 0:
            return 0
        return int((self.offsets[1:] - self.offsets[:-1]).max())


# ---------------------------------------------------------------------------
# (PR) CooIndex
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CooIndex:
    """Plain-Relational analogue: heap-of-tuples + B+tree permutation."""
    _static_fields = ("max_posting_len",)
    # heap columns, in arrival (doc-major) order — like tuples in a heap file
    word_ids: Array   # i32[P]  <- the redundant column PR pays for
    doc_ids: Array    # i32[P]
    tfs: Array        # f32[P]
    # "B+tree": (term,doc)-sorted permutation + per-term starts
    perm: Array         # i32[P] sorted posting -> heap position
    term_starts: Array  # i32[W+1]
    df: Array           # i32[W]
    lookup: SortedLookup | HashLookup
    docs: DocTable
    max_posting_len: int

    @property
    def num_terms(self) -> int:
        return self.df.shape[0]

    def lookup_terms(self, hashes: Array) -> Array:
        return self.lookup.lookup(hashes)

    def term_df(self, term_ids: Array) -> Array:
        safe = jnp.maximum(term_ids, 0)
        return jnp.where(term_ids >= 0, self.df[safe], 0)

    def gather_postings(self, term_ids: Array, cap: int
                        ) -> Tuple[Array, Array, Array]:
        """q_occ for PR: read index leaves (perm) then RANDOM heap gathers."""
        safe = jnp.maximum(term_ids, 0)

        def one(tid):
            idx, valid = segments.gather_segment(self.perm, self.term_starts,
                                                 tid, cap)
            d = jnp.take(self.doc_ids, idx, axis=0)
            t = jnp.take(self.tfs, idx, axis=0)
            # PR also streams the word_id column through the memory system;
            # touch it so the cost is real, then mask it out.
            w = jnp.take(self.word_ids, idx, axis=0)
            t = t + 0.0 * w.astype(t.dtype)
            d = jnp.where(valid, d, -1)
            t = jnp.where(valid, t, 0.0)
            return d, t, valid

        d, t, v = jax.vmap(one)(safe)
        present = (term_ids >= 0)[:, None]
        return jnp.where(present, d, -1), jnp.where(present, t, 0.0), v & present

    def nbytes(self) -> int:
        n = sum(int(x.nbytes) for x in
                (self.word_ids, self.doc_ids, self.tfs, self.perm,
                 self.term_starts, self.df))
        return n + self.lookup.nbytes() + self.docs.nbytes()

    def posting_bytes(self) -> int:
        return int(self.word_ids.nbytes + self.doc_ids.nbytes +
                   self.tfs.nbytes + self.perm.nbytes)


_register(CooIndex)


def build_coo(h: PostingsHost, lookup: str = "btree") -> CooIndex:
    P = h.num_postings
    # heap order = arrival order = doc-major: sort canonical (term-major)
    # postings by (doc, term) to synthesize the heap.
    term_of = np.repeat(np.arange(h.num_terms, dtype=np.int64),
                        np.diff(h.offsets))
    heap_order = np.lexsort((term_of, h.doc_ids))      # doc-major heap
    heap_word = term_of[heap_order].astype(np.int32)
    heap_doc = h.doc_ids[heap_order].astype(np.int32)
    heap_tf = h.tfs[heap_order].astype(np.float32)
    # B+tree: sort heap positions by (term, doc)
    perm = np.lexsort((heap_doc, heap_word)).astype(np.int32)
    starts = np.searchsorted(heap_word[perm], np.arange(h.num_terms + 1))
    lk = (build_sorted_lookup(h.term_hashes) if lookup == "btree"
          else build_hash_lookup(h.term_hashes))
    return CooIndex(
        word_ids=jnp.asarray(heap_word), doc_ids=jnp.asarray(heap_doc),
        tfs=jnp.asarray(heap_tf), perm=jnp.asarray(perm),
        term_starts=jnp.asarray(starts.astype(np.int32)),
        df=jnp.asarray(h.df.astype(np.int32)), lookup=lk,
        docs=DocTable(norm=jnp.asarray(h.norm), rank=jnp.asarray(h.rank)),
        max_posting_len=h.max_posting_len,
    )


# ---------------------------------------------------------------------------
# (OR) CsrIndex
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CsrIndex:
    """Object-Relational analogue: contiguous per-term posting slabs."""
    _static_fields = ("max_posting_len",)
    offsets: Array   # i32[W+1]
    doc_ids: Array   # i32[P]
    tfs: Array       # f32[P]
    df: Array        # i32[W]   (separate word table, as in OR)
    lookup: SortedLookup | HashLookup
    docs: DocTable
    max_posting_len: int

    @property
    def num_terms(self) -> int:
        return self.df.shape[0]

    def lookup_terms(self, hashes: Array) -> Array:
        return self.lookup.lookup(hashes)

    def term_df(self, term_ids: Array) -> Array:
        safe = jnp.maximum(term_ids, 0)
        return jnp.where(term_ids >= 0, self.df[safe], 0)

    def gather_postings(self, term_ids: Array, cap: int
                        ) -> Tuple[Array, Array, Array]:
        """q_occ for ORIF: one contiguous slab DMA per term."""
        safe = jnp.maximum(term_ids, 0)
        d, v = segments.gather_segments(self.doc_ids, self.offsets, safe, cap,
                                        fill=-1)
        t, _ = segments.gather_segments(self.tfs, self.offsets, safe, cap,
                                        fill=0.0)
        present = (term_ids >= 0)[:, None]
        return (jnp.where(present, d, -1), jnp.where(present, t, 0.0),
                v & present)

    def nbytes(self) -> int:
        n = sum(int(x.nbytes) for x in
                (self.offsets, self.doc_ids, self.tfs, self.df))
        return n + self.lookup.nbytes() + self.docs.nbytes()

    def posting_bytes(self) -> int:
        return int(self.offsets.nbytes + self.doc_ids.nbytes + self.tfs.nbytes)


_register(CsrIndex)


def build_csr(h: PostingsHost, lookup: str = "btree") -> CsrIndex:
    lk = (build_sorted_lookup(h.term_hashes) if lookup == "btree"
          else build_hash_lookup(h.term_hashes))
    return CsrIndex(
        offsets=jnp.asarray(h.offsets.astype(np.int32)),
        doc_ids=jnp.asarray(h.doc_ids.astype(np.int32)),
        tfs=jnp.asarray(h.tfs.astype(np.float32)),
        df=jnp.asarray(h.df.astype(np.int32)), lookup=lk,
        docs=DocTable(norm=jnp.asarray(h.norm), rank=jnp.asarray(h.rank)),
        max_posting_len=h.max_posting_len,
    )


# ---------------------------------------------------------------------------
# (COR) CompactCsrIndex
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CompactCsrIndex:
    """Compact OR: word table folded into the posting relation.

    Terms are stored in HASH-SORTED order; the sorted hash array doubles as
    the lookup structure (no separate word table), and df sits alongside.
    q_word and q_occ fuse into a single phase — the paper's "one fewer
    query".
    """
    _static_fields = ("max_posting_len",)
    sorted_hash: Array  # u32[W]
    df: Array           # i32[W]   (aligned with sorted_hash)
    offsets: Array      # i32[W+1] (aligned with sorted_hash)
    doc_ids: Array      # i32[P]
    tfs: Array          # f32[P]
    docs: DocTable
    max_posting_len: int

    @property
    def num_terms(self) -> int:
        return self.df.shape[0]

    def lookup_terms(self, hashes: Array) -> Array:
        pos = jnp.searchsorted(self.sorted_hash, hashes).astype(jnp.int32)
        pos = jnp.clip(pos, 0, self.sorted_hash.shape[0] - 1)
        hit = self.sorted_hash[pos] == hashes
        return jnp.where(hit, pos, -1)

    def term_df(self, term_ids: Array) -> Array:
        safe = jnp.maximum(term_ids, 0)
        return jnp.where(term_ids >= 0, self.df[safe], 0)

    def gather_postings(self, term_ids: Array, cap: int
                        ) -> Tuple[Array, Array, Array]:
        safe = jnp.maximum(term_ids, 0)
        d, v = segments.gather_segments(self.doc_ids, self.offsets, safe, cap,
                                        fill=-1)
        t, _ = segments.gather_segments(self.tfs, self.offsets, safe, cap,
                                        fill=0.0)
        present = (term_ids >= 0)[:, None]
        return (jnp.where(present, d, -1), jnp.where(present, t, 0.0),
                v & present)

    def nbytes(self) -> int:
        return sum(int(x.nbytes) for x in
                   (self.sorted_hash, self.df, self.offsets, self.doc_ids,
                    self.tfs)) + self.docs.nbytes()

    def posting_bytes(self) -> int:
        return int(self.offsets.nbytes + self.doc_ids.nbytes + self.tfs.nbytes)


_register(CompactCsrIndex)


def build_compact_csr(h: PostingsHost) -> CompactCsrIndex:
    order = np.argsort(h.term_hashes, kind="stable")
    lengths = np.diff(h.offsets)[order]
    new_offsets = np.zeros(h.num_terms + 1, dtype=np.int64)
    np.cumsum(lengths, out=new_offsets[1:])
    P = h.num_postings
    doc_ids = np.empty(P, dtype=np.int32)
    tfs = np.empty(P, dtype=np.float32)
    for newpos, old in enumerate(order):          # permute slabs
        s, e = h.offsets[old], h.offsets[old + 1]
        ns = new_offsets[newpos]
        doc_ids[ns:ns + (e - s)] = h.doc_ids[s:e]
        tfs[ns:ns + (e - s)] = h.tfs[s:e]
    return CompactCsrIndex(
        sorted_hash=jnp.asarray(h.term_hashes[order].astype(np.uint32)),
        df=jnp.asarray(h.df[order].astype(np.int32)),
        offsets=jnp.asarray(new_offsets.astype(np.int32)),
        doc_ids=jnp.asarray(doc_ids), tfs=jnp.asarray(tfs),
        docs=DocTable(norm=jnp.asarray(h.norm), rank=jnp.asarray(h.rank)),
        max_posting_len=h.max_posting_len,
    )


# ---------------------------------------------------------------------------
# (HOR) BlockedIndex
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BlockedIndex:
    """hstore/GIN analogue: fixed-size posting blocks + per-block summaries.

    Each term's postings are rounded up to multiples of BLOCK lanes
    (padding doc_id = -1, tf = 0).  Per block we keep min/max doc id —
    enabling (a) block-skipping doc-membership probes (document-based
    access, paper §4.4 / GIN) and (b) aligned VMEM tiles for the Pallas
    scoring kernel.
    """
    _static_fields = ("max_posting_len", "max_blocks_per_term", "block",
                      "route_tile", "route_pairs_max", "route_span_max")
    sorted_hash: Array    # u32[W]  (COR-style folded word table)
    df: Array             # i32[W]
    block_offsets: Array  # i32[W+1]  term -> block range
    block_docs: Array     # i32[NB, BLOCK]  (-1 padding)
    block_tfs: Array      # f32[NB, BLOCK]
    block_min: Array      # i32[NB]
    block_max: Array      # i32[NB]
    docs: DocTable
    max_posting_len: int
    max_blocks_per_term: int
    block: int = BLOCK
    # pair-routing cache (block -> doc-tile span at route_tile width)
    tile_first: Array | None = None   # i32[NB]
    tile_count: Array | None = None   # i32[NB]
    route_tile: int = ROUTE_TILE
    route_pairs_max: int = 0   # sum(tile_count): dedup upper bound on pairs
    route_span_max: int = 0    # max(tile_count): worst span of one block

    @property
    def num_terms(self) -> int:
        return self.df.shape[0]

    def lookup_terms(self, hashes: Array) -> Array:
        pos = jnp.searchsorted(self.sorted_hash, hashes).astype(jnp.int32)
        pos = jnp.clip(pos, 0, self.sorted_hash.shape[0] - 1)
        hit = self.sorted_hash[pos] == hashes
        return jnp.where(hit, pos, -1)

    def term_df(self, term_ids: Array) -> Array:
        safe = jnp.maximum(term_ids, 0)
        return jnp.where(term_ids >= 0, self.df[safe], 0)

    def gather_postings(self, term_ids: Array, cap: int
                        ) -> Tuple[Array, Array, Array]:
        nblk = -(-cap // self.block)
        safe = jnp.maximum(term_ids, 0)

        def one(tid):
            start = self.block_offsets[tid]
            nb = self.block_offsets[tid + 1] - start
            bidx = start + jnp.arange(nblk, dtype=jnp.int32)
            bvalid = jnp.arange(nblk, dtype=jnp.int32) < nb
            bidx = jnp.where(bvalid, bidx, 0)
            d = jnp.take(self.block_docs, bidx, axis=0)   # [nblk, BLOCK]
            t = jnp.take(self.block_tfs, bidx, axis=0)
            d = jnp.where(bvalid[:, None], d, -1).reshape(-1)
            t = jnp.where(bvalid[:, None], t, 0.0).reshape(-1)
            return d[:cap], t[:cap]

        d, t = jax.vmap(one)(safe)
        present = (term_ids >= 0)[:, None]
        v = (d >= 0) & present
        return jnp.where(present, d, -1), jnp.where(present, t, 0.0), v

    def contains(self, term_ids: Array, doc_id: Array) -> Array:
        """Doc-membership probe with block skipping (the GIN-style path)."""
        safe = jnp.maximum(term_ids, 0)
        nblk = self.max_blocks_per_term

        def one(tid):
            start = self.block_offsets[tid]
            nb = self.block_offsets[tid + 1] - start
            bidx = start + jnp.arange(nblk, dtype=jnp.int32)
            bvalid = jnp.arange(nblk, dtype=jnp.int32) < nb
            bidx = jnp.where(bvalid, bidx, 0)
            hit_range = (self.block_min[bidx] <= doc_id) & \
                        (self.block_max[bidx] >= doc_id) & bvalid
            # only blocks whose [min,max] covers doc_id are inspected
            d = jnp.take(self.block_docs, bidx, axis=0)
            inblock = jnp.any(d == doc_id, axis=1)
            return jnp.any(hit_range & inblock)

        found = jax.vmap(one)(safe)
        return found & (term_ids >= 0)

    def nbytes(self) -> int:
        return sum(int(x.nbytes) for x in
                   (self.sorted_hash, self.df, self.block_offsets,
                    self.block_docs, self.block_tfs, self.block_min,
                    self.block_max)) + self.docs.nbytes()

    def posting_bytes(self) -> int:
        return int(self.block_offsets.nbytes + self.block_docs.nbytes +
                   self.block_tfs.nbytes + self.block_min.nbytes +
                   self.block_max.nbytes)


_register(BlockedIndex)


def build_blocked(h: PostingsHost, block: int = BLOCK,
                  route_tile: int = ROUTE_TILE) -> BlockedIndex:
    """``route_tile`` sets the doc-tile width of the build-time pair-
    routing cache; the seal path passes the autotuned tile for the
    segment's size class so sealed segments are born pre-tuned."""
    order = np.argsort(h.term_hashes, kind="stable")
    lengths = np.diff(h.offsets)[order]
    nblocks = -(-lengths // block)
    nblocks = np.maximum(nblocks, (lengths > 0).astype(nblocks.dtype))
    block_offsets = np.zeros(h.num_terms + 1, dtype=np.int64)
    np.cumsum(nblocks, out=block_offsets[1:])
    NB = int(block_offsets[-1])
    bd = np.full((NB, block), -1, dtype=np.int32)
    bt = np.zeros((NB, block), dtype=np.float32)
    P = h.num_postings
    if P:
        # vectorized block fill (one fancy-index scatter instead of a
        # per-term python loop — that loop dominated live-index seal
        # wall time): every posting's destination (block row, lane) is a
        # pure function of its rank within its (hash-sorted) term
        new_offsets = np.zeros(h.num_terms + 1, dtype=np.int64)
        np.cumsum(lengths, out=new_offsets[1:])
        starts_src = h.offsets[order].astype(np.int64)   # old slab starts
        within = np.arange(P, dtype=np.int64) - np.repeat(new_offsets[:-1],
                                                          lengths)
        src = np.repeat(starts_src, lengths) + within
        brow = np.repeat(block_offsets[:-1], lengths) + within // block
        lane = within % block
        bd[brow, lane] = h.doc_ids[src]
        bt[brow, lane] = h.tfs[src]
    bmin = np.where((bd >= 0).any(axis=1),
                    np.where(bd >= 0, bd, np.iinfo(np.int32).max).min(axis=1),
                    0).astype(np.int32)
    bmax = bd.max(axis=1).astype(np.int32)
    tfirst, tcount = _block_tile_routing(bmin, bmax, h.num_docs, route_tile)
    return BlockedIndex(
        sorted_hash=jnp.asarray(h.term_hashes[order].astype(np.uint32)),
        df=jnp.asarray(h.df[order].astype(np.int32)),
        block_offsets=jnp.asarray(block_offsets.astype(np.int32)),
        block_docs=jnp.asarray(bd), block_tfs=jnp.asarray(bt),
        block_min=jnp.asarray(bmin), block_max=jnp.asarray(bmax),
        docs=DocTable(norm=jnp.asarray(h.norm), rank=jnp.asarray(h.rank)),
        max_posting_len=h.max_posting_len,
        max_blocks_per_term=int(nblocks.max()) if len(nblocks) else 0,
        block=block,
        tile_first=jnp.asarray(tfirst), tile_count=jnp.asarray(tcount),
        route_tile=int(route_tile),
        route_pairs_max=int(tcount.sum()),
        route_span_max=int(tcount.max()) if len(tcount) else 0,
    )


def size_class(n: int, base: int = 128, growth: int = 2) -> int:
    """Smallest ``base * growth**i >= max(n, 1)`` — the static size-class
    quantizer the live index seals segments into.

    Device shapes (and the jit static metadata derived from them) are
    quantized to a few geometric classes so that sealing a new segment
    reuses an already-compiled kernel instead of triggering an XLA
    recompile: two segments in the same class share one compilation.
    """
    n = max(int(n), 1)
    c = base
    while c < n:
        c *= growth
    return c


def pad_blocked_to_class(ix: BlockedIndex, nb_pad: int, w_pad: int,
                         max_posting_len: int, max_blocks_per_term: int,
                         route_pairs_max: int, route_span_max: int
                         ) -> BlockedIndex:
    """Pad a BlockedIndex to a static size class.

    Arrays grow to (nb_pad blocks, w_pad terms) with inert padding
    (empty blocks with tile_count 0, absent-hash vocabulary slots) and
    the static metadata is OVERRIDDEN with quantized upper bounds
    (``>=`` the real values — each is only ever used as a budget or loop
    bound, so over-approximating is semantically safe).  Every padded
    field participates in the jit signature; quantizing all of them is
    what makes "seal a segment, query it, no new compilation" hold.
    The doc-space padding (``docs.num_docs``) is chosen at build time by
    the caller (a tile-aligned class), not here.
    """
    w, nb = ix.num_terms, int(ix.block_docs.shape[0])
    if nb_pad < nb or w_pad < w:
        raise ValueError(f"size class ({nb_pad}, {w_pad}) below actual "
                         f"({nb}, {w})")
    if (max_posting_len < ix.max_posting_len
            or max_blocks_per_term < ix.max_blocks_per_term
            or route_pairs_max < ix.route_pairs_max
            or route_span_max < ix.route_span_max):
        raise ValueError("quantized static bounds must cover the actual "
                         "index statics")
    dn, dw = nb_pad - nb, w_pad - w
    last = ix.block_offsets[-1]
    return dataclasses.replace(
        ix,
        sorted_hash=jnp.pad(ix.sorted_hash, (0, dw),
                            constant_values=HASH_EMPTY),
        df=jnp.pad(ix.df, (0, dw)),
        block_offsets=jnp.pad(ix.block_offsets, (0, dw),
                              constant_values=last),
        block_docs=jnp.pad(ix.block_docs, ((0, dn), (0, 0)),
                           constant_values=-1),
        block_tfs=jnp.pad(ix.block_tfs, ((0, dn), (0, 0))),
        block_min=jnp.pad(ix.block_min, (0, dn)),
        block_max=jnp.pad(ix.block_max, (0, dn), constant_values=-1),
        tile_first=jnp.pad(ix.tile_first, (0, dn)),
        tile_count=jnp.pad(ix.tile_count, (0, dn)),
        max_posting_len=int(max_posting_len),
        max_blocks_per_term=int(max_blocks_per_term),
        route_pairs_max=int(route_pairs_max),
        route_span_max=int(route_span_max),
    )


def pad_packed_to_class(ix: "PackedCsrIndex", nb_pad: int, w_pad: int,
                        max_posting_len: int, words_per_block: int,
                        route_pairs_max: int, route_span_max: int
                        ) -> "PackedCsrIndex":
    """Pad a PackedCsrIndex to a static size class (the packed twin of
    ``pad_blocked_to_class``, for delta+bit-packed sealed segments).

    Padding blocks are inert: bit width 1 (in-distribution for the
    decoder), count 0 (every lane decodes invalid), tile_count 0 (never
    routed).  ``words_per_block`` is shape-bearing (the packed array's
    lane dim), so it quantizes like the other statics.
    """
    w, nb = ix.num_terms, int(ix.packed.shape[0])
    wpb = int(ix.packed.shape[1])
    if nb_pad < nb or w_pad < w or words_per_block < wpb:
        raise ValueError(f"size class ({nb_pad}, {w_pad}, {words_per_block})"
                         f" below actual ({nb}, {w}, {wpb})")
    if (max_posting_len < ix.max_posting_len
            or route_pairs_max < ix.route_pairs_max
            or route_span_max < ix.route_span_max):
        raise ValueError("quantized static bounds must cover the actual "
                         "index statics")
    dn, dw = nb_pad - nb, w_pad - w
    last = ix.block_offsets[-1]
    return dataclasses.replace(
        ix,
        sorted_hash=jnp.pad(ix.sorted_hash, (0, dw),
                            constant_values=HASH_EMPTY),
        df=jnp.pad(ix.df, (0, dw)),
        block_offsets=jnp.pad(ix.block_offsets, (0, dw),
                              constant_values=last),
        block_bits=jnp.pad(ix.block_bits, (0, dn), constant_values=1),
        block_base=jnp.pad(ix.block_base, (0, dn)),
        block_count=jnp.pad(ix.block_count, (0, dn)),
        packed=jnp.pad(ix.packed, ((0, dn), (0, words_per_block - wpb))),
        block_tfs=jnp.pad(ix.block_tfs, ((0, dn), (0, 0))),
        block_min=jnp.pad(ix.block_min, (0, dn)),
        block_max=jnp.pad(ix.block_max, (0, dn), constant_values=-1),
        tile_first=jnp.pad(ix.tile_first, (0, dn)),
        tile_count=jnp.pad(ix.tile_count, (0, dn)),
        max_posting_len=int(max_posting_len),
        words_per_block=int(words_per_block),
        route_pairs_max=int(route_pairs_max),
        route_span_max=int(route_span_max),
    )


# ---------------------------------------------------------------------------
# (beyond paper) PackedCsrIndex — delta + bit-packed postings
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PackedCsrIndex:
    """Delta+bit-packed doc ids per 128-posting block, fp16 tf.

    The paper (§3.1) notes DBMSs cannot apply the number encodings that
    make inverted files small.  On TPU we can: each block of 128 doc-id
    deltas is packed at a per-block bit width into int32 words; a Pallas
    kernel (kernels/packed_postings.py) unpacks blocks in VMEM.  First
    entry of each block stores the absolute doc id's delta from
    ``block_base``.
    """
    _static_fields = ("max_posting_len", "words_per_block", "block",
                      "route_tile", "route_pairs_max", "route_span_max")
    sorted_hash: Array    # u32[W]
    df: Array             # i32[W]
    block_offsets: Array  # i32[W+1]    term -> block range
    block_bits: Array     # i32[NB]     bit width of this block
    block_base: Array     # i32[NB]     absolute doc id before first entry
    block_count: Array    # i32[NB]     valid postings in this block
    packed: Array         # u32[NB, words_per_block]  (worst-case width)
    block_tfs: Array      # f16[NB, BLOCK]
    docs: DocTable
    max_posting_len: int
    words_per_block: int
    block: int = BLOCK
    # per-block doc-id summaries + pair-routing cache (as in BlockedIndex;
    # for packed blocks these are only recoverable by decoding, so they
    # MUST be captured at build time)
    block_min: Array | None = None    # i32[NB]
    block_max: Array | None = None    # i32[NB]
    tile_first: Array | None = None   # i32[NB]
    tile_count: Array | None = None   # i32[NB]
    route_tile: int = ROUTE_TILE
    route_pairs_max: int = 0
    route_span_max: int = 0

    @property
    def num_terms(self) -> int:
        return self.df.shape[0]

    @property
    def max_blocks_per_term(self) -> int:
        """Worst-case posting blocks one term spans — the BlockedIndex
        field's packed twin, derived from the (possibly size-class
        quantized) posting-length bound.  Used as the per-term candidate
        fan-out bound by the sharded fused engines, which accept either
        layout."""
        return max(-(-self.max_posting_len // self.block), 1)

    def lookup_terms(self, hashes: Array) -> Array:
        pos = jnp.searchsorted(self.sorted_hash, hashes).astype(jnp.int32)
        pos = jnp.clip(pos, 0, self.sorted_hash.shape[0] - 1)
        hit = self.sorted_hash[pos] == hashes
        return jnp.where(hit, pos, -1)

    def term_df(self, term_ids: Array) -> Array:
        safe = jnp.maximum(term_ids, 0)
        return jnp.where(term_ids >= 0, self.df[safe], 0)

    def unpack_block(self, b: Array) -> Tuple[Array, Array, Array]:
        """Decode one block -> (doc_ids[BLOCK], tfs[BLOCK], valid[BLOCK])."""
        bits = self.block_bits[b]
        words = self.packed[b]                       # u32[words_per_block]
        lane = jnp.arange(self.block, dtype=jnp.uint32)
        bitpos = lane * bits.astype(jnp.uint32)
        wi = (bitpos >> 5).astype(jnp.int32)
        off = bitpos & jnp.uint32(31)
        lo = words[wi] >> off
        hi_valid = off > 0
        hi = jnp.where(hi_valid,
                       words[jnp.minimum(wi + 1, words.shape[0] - 1)]
                       << (jnp.uint32(32) - off), jnp.uint32(0))
        raw = lo | hi
        mask = jnp.where(bits >= 32, jnp.uint32(0xFFFFFFFF),
                         (jnp.uint32(1) << bits.astype(jnp.uint32)) - 1)
        deltas = (raw & mask).astype(jnp.int32)
        docs = self.block_base[b] + jnp.cumsum(deltas, dtype=jnp.int32)
        valid = jnp.arange(self.block, dtype=jnp.int32) < self.block_count[b]
        docs = jnp.where(valid, docs, -1)
        tfs = jnp.where(valid, self.block_tfs[b].astype(jnp.float32), 0.0)
        return docs, tfs, valid

    def gather_postings(self, term_ids: Array, cap: int
                        ) -> Tuple[Array, Array, Array]:
        nblk = -(-cap // self.block)
        safe = jnp.maximum(term_ids, 0)

        def one(tid):
            start = self.block_offsets[tid]
            nb = self.block_offsets[tid + 1] - start
            bidx = start + jnp.arange(nblk, dtype=jnp.int32)
            bvalid = jnp.arange(nblk, dtype=jnp.int32) < nb
            bidx = jnp.where(bvalid, bidx, 0)
            d, t, v = jax.vmap(self.unpack_block)(bidx)
            d = jnp.where(bvalid[:, None], d, -1).reshape(-1)
            t = jnp.where(bvalid[:, None], t, 0.0).reshape(-1)
            v = (bvalid[:, None] & v).reshape(-1)
            return d[:cap], t[:cap], v[:cap]

        d, t, v = jax.vmap(one)(safe)
        present = (term_ids >= 0)[:, None]
        return (jnp.where(present, d, -1), jnp.where(present, t, 0.0),
                v & present)

    def nbytes(self) -> int:
        return sum(int(x.nbytes) for x in
                   (self.sorted_hash, self.df, self.block_offsets,
                    self.block_bits, self.block_base, self.block_count,
                    self.packed, self.block_tfs)) + self.docs.nbytes()

    def posting_bytes(self) -> int:
        return int(self.block_offsets.nbytes + self.block_bits.nbytes +
                   self.block_base.nbytes + self.block_count.nbytes +
                   self.packed.nbytes + self.block_tfs.nbytes)


_register(PackedCsrIndex)


def _pack_block_np(deltas: np.ndarray, bits: int, block: int = BLOCK
                   ) -> np.ndarray:
    """Pack ``block`` deltas of ``bits`` width into u32 words."""
    out = np.zeros((block * bits + 31) // 32, dtype=np.uint64)
    for i, dv in enumerate(deltas.astype(np.uint64)):
        bitpos = i * bits
        wi, off = divmod(bitpos, 32)
        out[wi] |= (dv << off) & 0xFFFFFFFF
        spill = dv >> (32 - off) if off else 0
        if spill and wi + 1 < len(out):
            out[wi + 1] |= spill
    return out.astype(np.uint32)


def build_packed_csr(h: PostingsHost, max_bits: int = 32,
                     block: int = BLOCK,
                     route_tile: int = ROUTE_TILE) -> PackedCsrIndex:
    order = np.argsort(h.term_hashes, kind="stable")
    lengths = np.diff(h.offsets)[order]
    nblocks = np.maximum(-(-lengths // block), (lengths > 0).astype(np.int64))
    block_offsets = np.zeros(h.num_terms + 1, dtype=np.int64)
    np.cumsum(nblocks, out=block_offsets[1:])
    NB = int(block_offsets[-1])
    bits_arr = np.zeros(NB, dtype=np.int32)
    base_arr = np.zeros(NB, dtype=np.int32)
    count_arr = np.zeros(NB, dtype=np.int32)
    min_arr = np.zeros(NB, dtype=np.int32)
    max_arr = np.full(NB, -1, dtype=np.int32)
    tf_arr = np.zeros((NB, block), dtype=np.float16)
    blocks_packed = []
    for newpos, old in enumerate(order):
        s, e = int(h.offsets[old]), int(h.offsets[old + 1])
        docs = h.doc_ids[s:e].astype(np.int64)
        tfs = h.tfs[s:e]
        b0 = int(block_offsets[newpos])
        for k in range(int(nblocks[newpos])):
            lo, hi = k * block, min((k + 1) * block, len(docs))
            blk = docs[lo:hi]
            base = int(docs[lo - 1]) if lo > 0 else -1 if len(blk) else -1
            prev = base if lo > 0 else -1
            deltas = np.diff(np.concatenate([[prev], blk])).astype(np.int64)
            width = max(1, int(deltas.max()).bit_length()) if len(deltas) else 1
            width = min(width, max_bits)
            padded = np.zeros(block, dtype=np.int64)
            padded[:len(deltas)] = deltas
            blocks_packed.append(_pack_block_np(padded, width, block))
            bidx = b0 + k
            bits_arr[bidx] = width
            base_arr[bidx] = prev
            count_arr[bidx] = len(blk)
            if len(blk):
                min_arr[bidx] = int(blk[0])
                max_arr[bidx] = int(blk[-1])
            tf_arr[bidx, :len(blk)] = tfs[lo:hi]
    words_per_block = max((len(b) for b in blocks_packed), default=1)
    packed = np.zeros((NB, words_per_block), dtype=np.uint32)
    for i, b in enumerate(blocks_packed):
        packed[i, :len(b)] = b
    tfirst, tcount = _block_tile_routing(min_arr, max_arr, h.num_docs,
                                         route_tile)
    return PackedCsrIndex(
        sorted_hash=jnp.asarray(h.term_hashes[order].astype(np.uint32)),
        df=jnp.asarray(h.df[order].astype(np.int32)),
        block_offsets=jnp.asarray(block_offsets.astype(np.int32)),
        block_bits=jnp.asarray(bits_arr), block_base=jnp.asarray(base_arr),
        block_count=jnp.asarray(count_arr), packed=jnp.asarray(packed),
        block_tfs=jnp.asarray(tf_arr),
        docs=DocTable(norm=jnp.asarray(h.norm), rank=jnp.asarray(h.rank)),
        max_posting_len=h.max_posting_len,
        words_per_block=words_per_block,
        block=block,
        block_min=jnp.asarray(min_arr), block_max=jnp.asarray(max_arr),
        tile_first=jnp.asarray(tfirst), tile_count=jnp.asarray(tcount),
        route_tile=int(route_tile),
        route_pairs_max=int(tcount.sum()),
        route_span_max=int(tcount.max()) if len(tcount) else 0,
    )


# ---------------------------------------------------------------------------
# (beyond paper) BandedCsrIndex — per-term-band layout choice
# ---------------------------------------------------------------------------


def term_packed_words(h: PostingsHost, block: int = BLOCK,
                      max_bits: int = 32
                      ) -> Tuple[np.ndarray, np.ndarray]:
    """Per-term packed width: the int32 words the WIDEST block of each
    term would occupy under ``build_packed_csr``'s delta+bit-packing,
    plus the term's block count.  Returned in ``h``'s original term
    order (i64[W], i64[W]); terms with no postings get width 0.

    This is the byte model's view of the uniform-stride problem: a
    monolithic ``PackedCsrIndex`` stores every block at
    ``max(words)`` — one rare term with 16-bit deltas inflates the
    stride of every dense term in the segment.  ``build_banded`` uses
    these widths to cut the vocabulary into a packed band (width <=
    cut) and an HOR tail.

    The widths replicate the builder exactly: per-block max delta
    (first delta of a block is taken against the previous block's last
    doc id, ``-1`` at term start), bit width via the float exponent
    (``np.frexp`` — exact ``int.bit_length`` for integers below 2**53,
    unlike a log2-plus-epsilon nudge which misrounds near 2**31),
    clipped to [1, max_bits], then ``(block*bits + 31) // 32`` words.
    """
    W = h.num_terms
    lengths = np.diff(h.offsets).astype(np.int64)
    has = lengths > 0
    nblocks = np.maximum(-(-lengths // block), has.astype(np.int64))
    words = np.zeros(W, dtype=np.int64)
    P = h.num_postings
    if P == 0 or W == 0:
        return words, nblocks
    docs = h.doc_ids.astype(np.int64)
    prev = np.empty(P, dtype=np.int64)
    prev[1:] = docs[:-1]
    prev[h.offsets[:-1][has]] = -1          # term starts restart the delta
    deltas = docs - prev
    block_offsets = np.zeros(W + 1, dtype=np.int64)
    np.cumsum(nblocks, out=block_offsets[1:])
    NB = int(block_offsets[-1])
    # posting-array position where each block starts: term slab start +
    # within-term block index * block
    bstart = (np.repeat(h.offsets[:-1][has], nblocks[has]).astype(np.int64)
              + (np.arange(NB, dtype=np.int64)
                 - np.repeat(block_offsets[:-1][has], nblocks[has])) * block)
    bmax = np.maximum.reduceat(deltas, bstart)
    # exact bit_length via the frexp exponent (x = m * 2**e, 0.5<=m<1)
    _, exp = np.frexp(np.maximum(bmax, 1).astype(np.float64))
    bits = np.clip(exp.astype(np.int64), 1, max_bits)
    w_blk = (block * bits + 31) // 32
    term_of_block = np.repeat(np.arange(W, dtype=np.int64), nblocks)
    np.maximum.at(words, term_of_block, w_blk)
    return words, nblocks


@dataclasses.dataclass(frozen=True)
class BandedCsrIndex:
    """Per-term-band sealed segment: packed band + HOR tail.

    Terms whose widest packed block fits in ``<= cut`` int32 words go
    into a ``PackedCsrIndex`` with a BAND-LOCAL ``words_per_block``
    (the dense, high-df shape packing wants); the rest — the
    decode-bound df≈1 tail whose 16+-bit deltas would inflate the
    uniform stride — stay in a ``BlockedIndex``.  Both bands are
    FULL-vocabulary sub-indexes over the SAME doc space (a term's
    postings live in exactly one band; the other band holds an empty
    block range for it), share one ``DocTable``, and share the
    ``sorted_hash`` buffer — one term lookup serves both bands, and a
    query's score is the sum of the two band partials.

    The band cut itself is HOST metadata (``Segment.band_cut``), not a
    pytree static: it varies per segment, and a non-quantized static
    here would defeat the per-(size_class, layout) scorer memoization.
    """
    _static_fields = ()
    packed: PackedCsrIndex
    hor: BlockedIndex

    @property
    def docs(self) -> DocTable:
        return self.packed.docs

    @property
    def sorted_hash(self) -> Array:
        return self.packed.sorted_hash

    @property
    def df(self) -> Array:
        return self.packed.df + self.hor.df

    @property
    def num_terms(self) -> int:
        return self.packed.num_terms

    @property
    def block(self) -> int:
        return self.packed.block

    @property
    def route_tile(self) -> int:
        return self.packed.route_tile

    @property
    def max_posting_len(self) -> int:
        return max(self.packed.max_posting_len, self.hor.max_posting_len)

    def lookup_terms(self, hashes: Array) -> Array:
        return self.packed.lookup_terms(hashes)

    def term_df(self, term_ids: Array) -> Array:
        return self.packed.term_df(term_ids) + self.hor.term_df(term_ids)

    def gather_postings(self, term_ids: Array, cap: int
                        ) -> Tuple[Array, Array, Array]:
        # a term's postings live in exactly one band; the other band
        # yields inert fill (-1 / 0.0 / False), so the merge is a
        # lane-wise max / sum / or
        dp, tp, vp = self.packed.gather_postings(term_ids, cap)
        dh, th, vh = self.hor.gather_postings(term_ids, cap)
        return jnp.maximum(dp, dh), tp + th, vp | vh

    def nbytes(self) -> int:
        # the DocTable is shared between the bands — count it once
        return (self.packed.nbytes() + self.hor.nbytes()
                - self.docs.nbytes())

    def posting_bytes(self) -> int:
        return int(self.packed.posting_bytes() + self.hor.posting_bytes())


_register(BandedCsrIndex)


def _band_host(h: PostingsHost, keep: np.ndarray) -> PostingsHost:
    """Full-vocabulary sub-host: terms outside ``keep`` stay in the
    vocabulary with df 0 and an empty posting slab, so both bands'
    hash-sorted term ids stay aligned."""
    lengths = np.diff(h.offsets).astype(np.int64)
    kept = np.where(keep, lengths, 0)
    offsets = np.zeros(h.num_terms + 1, dtype=np.int64)
    np.cumsum(kept, out=offsets[1:])
    mask = np.repeat(keep, lengths)
    return PostingsHost(
        term_hashes=h.term_hashes,
        df=np.where(keep, h.df, 0).astype(h.df.dtype),
        offsets=offsets,
        doc_ids=h.doc_ids[mask],
        tfs=h.tfs[mask],
        num_docs=h.num_docs,
        norm=h.norm,
        rank=h.rank,
    )


def build_banded(h: PostingsHost, max_band_words: int | None = None,
                 block: int = BLOCK, route_tile: int = ROUTE_TILE,
                 lane_quantum: int = 1) -> BandedCsrIndex:
    """Build a banded segment.  ``max_band_words`` (the band cut, in
    int32 words) defaults to the byte-model optimum from
    ``size_model.choose_band_cut``; pass the recorded cut explicitly to
    reproduce a build bitwise (snapshot restore).  ``lane_quantum``
    lets the seal path price the cut at the packed lane-dim quantum it
    will pad to (8), so the chooser sees seal-time bytes."""
    words, nblocks = term_packed_words(h, block=block)
    if max_band_words is None:
        from repro.core import size_model
        cut, _ = size_model.choose_band_cut(words, nblocks, block=block,
                                            lane_quantum=lane_quantum)
    else:
        cut = int(max_band_words)
    in_packed = (words > 0) & (words <= cut)
    packed = build_packed_csr(_band_host(h, in_packed), block=block,
                              route_tile=route_tile)
    hor = build_blocked(_band_host(h, ~in_packed), block=block,
                        route_tile=route_tile)
    # share the DocTable and the (identical-content) sorted_hash buffer
    hor = dataclasses.replace(hor, docs=packed.docs,
                              sorted_hash=packed.sorted_hash)
    return BandedCsrIndex(packed=packed, hor=hor)


REPRESENTATIONS = {
    "pr": build_coo,            # Plain-Relational
    "or": build_csr,            # Object-Relational
    "cor": build_compact_csr,   # Compact Object-Relational
    "hor": build_blocked,       # HStore Object-Relational
    "packed": build_packed_csr,  # beyond-paper
    "banded": build_banded,      # beyond-paper: per-term-band choice
}
