"""Paper Table-4 analytic size model (§4.1).

Notation (paper Table 4):
  N    total word occurrences in the collection (with positions)
  D    number of documents
  N_d  sum over docs of #distinct words  (total postings)
  W    number of distinct words (vocabulary size)
  t    per-tuple DBMS overhead (paper: 40 bytes in PSQL)
  f    field size (paper: 4 bytes for int4/float4)

Formulas (paper §4.1):
  PR   (no pos):  N_d * (3f + t)
  PR   (pos):     N_d * (3f + t) + N * (3f + t)
  ORIF (no pos):  W * (f + t) + 2 f N_d
  ORIF (pos):     W * (f + t) + 2 f N_d + f N

The inequality ORIF < PR reduces to W < N_d, always true (§4.1).
This module reproduces those formulas exactly, plus the TPU-layout byte
accounting used by benchmarks (true array bytes, no tuple overhead).
"""
from __future__ import annotations

import dataclasses

PSQL_FIELD_BYTES = 4     # int4 / float4
PSQL_TUPLE_OVERHEAD = 40  # paper §4.1
PSQL_PAGE_BYTES = 8 * 1024
PSQL_POINT_BYTES = 16     # paper footnote 8 (point = 2 float8)


@dataclasses.dataclass(frozen=True)
class CorpusStats:
    D: int        # documents
    W: int        # distinct words
    N_d: int      # total postings (sum of per-doc distinct words)
    N: int = 0    # total occurrences (only needed for position variants)

    @property
    def w_avg(self) -> float:
        return self.N_d / max(self.D, 1)


# Paper's own collection (§4): 1,004,721 docs, 216,449 terms, avg 239
# distinct words per doc.
PAPER_COLLECTION = CorpusStats(D=1_004_721, W=216_449,
                               N_d=1_004_721 * 239, N=1_004_721 * 239 * 3)


def pr_bytes(s: CorpusStats, positions: bool = False,
             f: int = PSQL_FIELD_BYTES, t: int = PSQL_TUPLE_OVERHEAD) -> int:
    base = s.N_d * (3 * f + t)
    if positions:
        base += s.N * (3 * f + t)
    return base


def orif_bytes(s: CorpusStats, positions: bool = False,
               f: int = PSQL_FIELD_BYTES, t: int = PSQL_TUPLE_OVERHEAD) -> int:
    base = s.W * (f + t) + 2 * f * s.N_d
    if positions:
        base += f * s.N
    return base


def pr_over_orif(s: CorpusStats, positions: bool = False) -> float:
    return pr_bytes(s, positions) / orif_bytes(s, positions)


def pages(nbytes: int, page: int = PSQL_PAGE_BYTES) -> int:
    return -(-nbytes // page)


# --- TPU-layout analytic sizes (true array bytes; see layouts.py) ---------

def coo_layout_bytes(s: CorpusStats, id_bytes: int = 4, tf_bytes: int = 4) -> int:
    """PR analogue: word_id + doc_id + tf columns, plus word & doc tables."""
    postings = s.N_d * (2 * id_bytes + tf_bytes)
    word_table = s.W * (id_bytes + id_bytes)          # hash, df
    doc_table = s.D * (tf_bytes + tf_bytes)           # norm, rank
    return postings + word_table + doc_table


def csr_layout_bytes(s: CorpusStats, id_bytes: int = 4, tf_bytes: int = 4) -> int:
    """OR/COR analogue: offsets + packed doc_id,tf; word_id column gone."""
    postings = s.N_d * (id_bytes + tf_bytes)
    offsets = (s.W + 1) * id_bytes
    word_table = s.W * (id_bytes + id_bytes)          # hash, df
    doc_table = s.D * (tf_bytes + tf_bytes)
    return postings + offsets + word_table + doc_table


def packed_csr_layout_bytes(s: CorpusStats, mean_bits: float = 12.0,
                            tf_bytes: int = 2, id_bytes: int = 4) -> int:
    """Beyond-paper: delta+bit-packed doc ids (mean_bits/posting) + fp16 tf."""
    postings = int(s.N_d * mean_bits / 8) + s.N_d * tf_bytes
    offsets = (s.W + 1) * id_bytes
    word_table = s.W * (id_bytes + id_bytes)
    doc_table = s.D * (2 * tf_bytes)
    return postings + offsets + word_table + doc_table


# ---------------------------------------------------------------------------
# tuning-table hooks (kernels/autotune.py)
# ---------------------------------------------------------------------------


def tuning_size_class(num_docs: int, route_tile: int = 512) -> int:
    """Size-class key for the kernel tuning table.

    Matches the seal path's doc-count quantization exactly
    (``layouts.size_class(span, base=ROUTE_TILE)`` in
    ``SegmentedIndex._build_segment``), so a config tuned on one sealed
    segment applies to every segment of the same padded class — and the
    key is idempotent (``tuning_size_class(d_pad) == d_pad``), letting
    query-time lookups key on the segment's already-padded doc count.
    """
    n = max(int(num_docs), 1)
    c = max(int(route_tile), 1)
    while c < n:
        c *= 2
    return c


def candidate_bytes_per_query(num_docs: int, tile: int, k_tile: int) -> int:
    """HBM bytes of per-tile candidates one query emits: the (value, id)
    pair lists the fused candidate kernels write instead of a dense
    score row.  The autotuner uses this to break timing ties toward the
    geometry with the smaller output footprint."""
    n_tiles = max(-(-int(num_docs) // max(int(tile), 1)), 1)
    return n_tiles * int(k_tile) * 8
