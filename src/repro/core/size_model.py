"""Paper Table-4 analytic size model (§4.1).

Notation (paper Table 4):
  N    total word occurrences in the collection (with positions)
  D    number of documents
  N_d  sum over docs of #distinct words  (total postings)
  W    number of distinct words (vocabulary size)
  t    per-tuple DBMS overhead (paper: 40 bytes in PSQL)
  f    field size (paper: 4 bytes for int4/float4)

Formulas (paper §4.1):
  PR   (no pos):  N_d * (3f + t)
  PR   (pos):     N_d * (3f + t) + N * (3f + t)
  ORIF (no pos):  W * (f + t) + 2 f N_d
  ORIF (pos):     W * (f + t) + 2 f N_d + f N

The inequality ORIF < PR reduces to W < N_d, always true (§4.1).
This module reproduces those formulas exactly, plus the TPU-layout byte
accounting used by benchmarks (true array bytes, no tuple overhead).
"""
from __future__ import annotations

import dataclasses
import math

PSQL_FIELD_BYTES = 4     # int4 / float4
PSQL_TUPLE_OVERHEAD = 40  # paper §4.1
PSQL_PAGE_BYTES = 8 * 1024
PSQL_POINT_BYTES = 16     # paper footnote 8 (point = 2 float8)


@dataclasses.dataclass(frozen=True)
class CorpusStats:
    D: int        # documents
    W: int        # distinct words
    N_d: int      # total postings (sum of per-doc distinct words)
    N: int = 0    # total occurrences (only needed for position variants)

    @property
    def w_avg(self) -> float:
        return self.N_d / max(self.D, 1)


# Paper's own collection (§4): 1,004,721 docs, 216,449 terms, avg 239
# distinct words per doc.
PAPER_COLLECTION = CorpusStats(D=1_004_721, W=216_449,
                               N_d=1_004_721 * 239, N=1_004_721 * 239 * 3)


def pr_bytes(s: CorpusStats, positions: bool = False,
             f: int = PSQL_FIELD_BYTES, t: int = PSQL_TUPLE_OVERHEAD) -> int:
    base = s.N_d * (3 * f + t)
    if positions:
        base += s.N * (3 * f + t)
    return base


def orif_bytes(s: CorpusStats, positions: bool = False,
               f: int = PSQL_FIELD_BYTES, t: int = PSQL_TUPLE_OVERHEAD) -> int:
    base = s.W * (f + t) + 2 * f * s.N_d
    if positions:
        base += f * s.N
    return base


def pr_over_orif(s: CorpusStats, positions: bool = False) -> float:
    return pr_bytes(s, positions) / orif_bytes(s, positions)


def pages(nbytes: int, page: int = PSQL_PAGE_BYTES) -> int:
    return -(-nbytes // page)


# --- TPU-layout analytic sizes (true array bytes; see layouts.py) ---------

def coo_layout_bytes(s: CorpusStats, id_bytes: int = 4, tf_bytes: int = 4) -> int:
    """PR analogue: word_id + doc_id + tf columns, plus word & doc tables."""
    postings = s.N_d * (2 * id_bytes + tf_bytes)
    word_table = s.W * (id_bytes + id_bytes)          # hash, df
    doc_table = s.D * (tf_bytes + tf_bytes)           # norm, rank
    return postings + word_table + doc_table


def csr_layout_bytes(s: CorpusStats, id_bytes: int = 4, tf_bytes: int = 4) -> int:
    """OR/COR analogue: offsets + packed doc_id,tf; word_id column gone."""
    postings = s.N_d * (id_bytes + tf_bytes)
    offsets = (s.W + 1) * id_bytes
    word_table = s.W * (id_bytes + id_bytes)          # hash, df
    doc_table = s.D * (tf_bytes + tf_bytes)
    return postings + offsets + word_table + doc_table


def packed_csr_layout_bytes(s: CorpusStats, mean_bits: float = 12.0,
                            tf_bytes: int = 2, id_bytes: int = 4) -> int:
    """Beyond-paper: delta+bit-packed doc ids (mean_bits/posting) + fp16 tf."""
    postings = int(s.N_d * mean_bits / 8) + s.N_d * tf_bytes
    offsets = (s.W + 1) * id_bytes
    word_table = s.W * (id_bytes + id_bytes)
    doc_table = s.D * (2 * tf_bytes)
    return postings + offsets + word_table + doc_table


# ---------------------------------------------------------------------------
# tuning-table hooks (kernels/autotune.py)
# ---------------------------------------------------------------------------


def tuning_size_class(num_docs: int, route_tile: int = 512) -> int:
    """Size-class key for the kernel tuning table.

    Matches the seal path's doc-count quantization exactly
    (``layouts.size_class(span, base=ROUTE_TILE)`` in
    ``SegmentedIndex._build_segment``), so a config tuned on one sealed
    segment applies to every segment of the same padded class — and the
    key is idempotent (``tuning_size_class(d_pad) == d_pad``), letting
    query-time lookups key on the segment's already-padded doc count.
    """
    n = max(int(num_docs), 1)
    c = max(int(route_tile), 1)
    while c < n:
        c *= 2
    return c


def candidate_bytes_per_query(num_docs: int, tile: int, k_tile: int) -> int:
    """HBM bytes of per-tile candidates one query emits: the (value, id)
    pair lists the fused candidate kernels write instead of a dense
    score row.  The autotuner uses this to break timing ties toward the
    geometry with the smaller output footprint."""
    n_tiles = max(-(-int(num_docs) // max(int(tile), 1)), 1)
    return n_tiles * int(k_tile) * 8


# ---------------------------------------------------------------------------
# per-segment layout cost model (the adaptive hor-vs-packed chooser)
# ---------------------------------------------------------------------------

_BLOCK = 128          # layouts.BLOCK; kept literal to avoid a core cycle
_HOR_SLOT_BYTES = 8   # i32 doc id + f32 tf per posting slot
_PACKED_TF_BYTES = 2  # f16 tf per posting


@dataclasses.dataclass(frozen=True)
class SegmentStats:
    """Aggregate shape of one posting run (a sealed segment, a merged
    compaction input, or a whole host corpus) — everything the layout
    chooser needs, nothing layout-specific."""
    num_docs: int      # local doc span of the run
    num_postings: int
    num_terms: int     # distinct terms with >= 1 posting in the run

    @property
    def avg_df(self) -> float:
        return self.num_postings / max(self.num_terms, 1)


def est_delta_bits(stats: SegmentStats) -> float:
    """Expected per-block bit width of delta-coded doc ids.

    With df postings spread over num_docs local ids the mean gap is
    num_docs/df; block packing pays the WIDEST gap in each 128-posting
    block, so add one bit of headroom over ceil(log2(mean_gap)) — the
    same +1 slack the measured corpora show (Zipfian 20k-doc bench:
    predicted 7 bits, built 6-8)."""
    gap = max(stats.num_docs / max(stats.avg_df, 1.0), 1.0)
    bits = math.ceil(math.log2(gap + 1.0)) + 1
    return float(min(max(bits, 1), 32))


def hor_posting_bytes_from_df(df, block: int = _BLOCK) -> int:
    """EXACT posting-array bytes of an (unpadded) BlockedIndex built
    from per-term document frequencies ``df``: each term rounds up to
    whole 128-lane blocks of (i32 id, f32 tf), plus the per-block
    min/max routing bounds and the per-term block offsets."""
    import numpy as np
    df = np.asarray(df, dtype=np.int64)
    nb = int(np.sum(-(-df[df > 0] // block)))
    offsets = (len(df) + 1) * 4
    return offsets + nb * (block * _HOR_SLOT_BYTES + 8)


def est_hor_posting_bytes(stats: SegmentStats, block: int = _BLOCK) -> int:
    """Analytic BlockedIndex posting bytes from aggregate stats: every
    term wastes half a block of padding in expectation."""
    nb = stats.num_postings / block + 0.5 * stats.num_terms
    offsets = (stats.num_terms + 1) * 4
    return int(offsets + nb * (block * _HOR_SLOT_BYTES + 8))


def est_packed_posting_bytes(stats: SegmentStats, block: int = _BLOCK,
                             bits: float | None = None) -> int:
    """Analytic PackedCsrIndex posting bytes from aggregate stats.
    Both the packed id words and the f16 tf plane are stored in whole
    128-slot blocks (the kernel decodes block-at-a-time), so the cost
    is per padded SLOT, not per posting: bits/8 + 2 bytes per slot,
    plus the per-block (bits, base, count) decode triple and the
    per-term offsets."""
    if bits is None:
        bits = est_delta_bits(stats)
    nb = stats.num_postings / block + 0.5 * stats.num_terms
    offsets = (stats.num_terms + 1) * 4
    per_slot = bits / 8.0 + _PACKED_TF_BYTES
    return int(offsets + nb * (block * per_slot + 12))


def banded_posting_bytes_from_words(words, nblocks, cut: int,
                                    block: int = _BLOCK,
                                    lane_quantum: int = 1) -> int:
    """EXACT posting-array bytes of an (unpadded) BandedCsrIndex built
    with band cut ``cut`` from per-term packed widths ``words`` and
    block counts ``nblocks`` (``layouts.term_packed_words``).  Terms
    with ``0 < words <= cut`` land in the packed band, whose stride is
    the band-local max width rounded up to ``lane_quantum`` (pass 8 to
    price at the seal path's packed lane-dim padding); the rest pay the
    HOR slot cost.  Both bands carry a full-vocabulary offsets array.
    """
    import numpy as np
    words = np.asarray(words, dtype=np.int64)
    nblocks = np.asarray(nblocks, dtype=np.int64)
    offsets = 2 * (len(words) + 1) * 4
    in_packed = (words > 0) & (words <= int(cut))
    nb_p = int(nblocks[in_packed].sum())
    nb_h = int(nblocks[(words > 0) & ~in_packed].sum())
    if nb_p:
        q = max(int(lane_quantum), 1)
        stride = -(-int(words[in_packed].max()) // q) * q
    else:
        stride = 1
    return (offsets
            + nb_p * (4 * stride + _PACKED_TF_BYTES * block + 12)
            + nb_h * (block * _HOR_SLOT_BYTES + 8))


def choose_band_cut(words, nblocks, block: int = _BLOCK,
                    lane_quantum: int = 1) -> tuple[int, int]:
    """Pick the band cut (in int32 words) minimizing the exact banded
    byte model over the realized per-term widths.  Candidates are 0
    (everything HOR) plus each distinct realized width — the byte curve
    only changes at those points, so the scan is exact and bounded by
    the number of distinct widths (<= ~129 at block 128).  Ties break
    toward the SMALLER cut (fewer terms paying the packed stride).
    Returns ``(cut, posting_bytes_at_cut)``."""
    import numpy as np
    words = np.asarray(words, dtype=np.int64)
    nblocks = np.asarray(nblocks, dtype=np.int64)
    cands = [0] + sorted({int(w) for w in words[words > 0]})
    best_cut, best_bytes = 0, None
    for c in cands:
        b = banded_posting_bytes_from_words(words, nblocks, c, block=block,
                                            lane_quantum=lane_quantum)
        if best_bytes is None or b < best_bytes:
            best_cut, best_bytes = c, b
    return best_cut, int(best_bytes)


def est_banded_posting_bytes(stats: SegmentStats, block: int = _BLOCK) -> int:
    """Analytic BandedCsrIndex posting bytes from aggregate stats.

    Zipfian runs put roughly half the vocabulary in a df~1 tail; price
    that tail as one HOR block per term and the remaining body at the
    packed rate (whose delta bits now reflect the DENSE body shape, not
    the tail), plus the second full-vocabulary offsets array the two
    bands carry.  ``table5_size.py`` prints this estimator's relative
    error next to the exact-width model."""
    t_tail = min(stats.num_terms // 2, stats.num_postings)
    body_terms = stats.num_terms - t_tail
    body_postings = stats.num_postings - t_tail
    extra_offsets = (stats.num_terms + 1) * 4
    if body_terms <= 0 or body_postings <= 0:
        return est_hor_posting_bytes(stats, block) + extra_offsets
    body = SegmentStats(num_docs=stats.num_docs,
                        num_postings=body_postings, num_terms=body_terms)
    tail_bytes = t_tail * (block * _HOR_SLOT_BYTES + 8)
    return int(est_packed_posting_bytes(body, block) + tail_bytes
               + extra_offsets)


def est_posting_bytes(stats: SegmentStats, layout: str,
                      block: int = _BLOCK) -> int:
    """Analytic posting-array bytes for any registered layout — the
    prediction side of the benchmarks' measured-vs-analytic table
    (``benchmarks/table5_size.py`` puts the relative error next to the
    measured ``posting_bytes()``).  Granularity matches each layout's
    ``posting_bytes``: the posting columns + per-term offsets, NOT the
    word/doc tables or lookup structures (those are layout-invariant)."""
    offsets = (stats.num_terms + 1) * 4
    if layout in ("pr", "coo"):
        # heap tuple (word i32, doc i32, tf f32) + B+tree perm i32
        return int(stats.num_postings * 16)
    if layout in ("or", "csr", "cor", "compact_csr"):
        return int(offsets + stats.num_postings * 8)   # doc i32 + tf f32
    if layout == "hor":
        return est_hor_posting_bytes(stats, block)
    if layout == "packed":
        return est_packed_posting_bytes(stats, block)
    if layout == "banded":
        return est_banded_posting_bytes(stats, block)
    raise ValueError(f"unknown layout {layout!r}")


@dataclasses.dataclass(frozen=True)
class LayoutDecision:
    """One chooser verdict: the layout plus a human-readable reason
    string that survives into segment introspection and snapshots."""
    layout: str
    reason: str


@dataclasses.dataclass(frozen=True)
class LayoutCostModel:
    """Measured per-segment hor-vs-packed chooser.

    Cost per candidate layout = predicted posting-HBM bytes/query (the
    analytic estimators above, calibrated against the measured roofline:
    packed ~ 0.33x HOR on the bench corpora) + a decode-cost term taken
    from the kernel tuning table when ``autotune_index`` has measured
    this (backend, size_class) for BOTH layouts.  Packed always wins
    the byte count, so the analytic arm gates on segment size: below
    ``min_packed_docs`` local docs a segment is decode-bound, not
    HBM-bandwidth-bound, and HOR's unpack-free blocks win — which is
    what makes compaction *converge*: small seals stay hor, merged runs
    cross the threshold and flip to packed.

    This object is the POLICY rung of the override ladder
    (``explicit arg > policy > historical default``); a ``None`` policy
    everywhere is bit-identical to today's constants, the same
    discipline as the empty tuning table.
    """
    min_packed_docs: int = 4096
    hbm_ratio_max: float = 0.9   # packed must beat hor by >= 10% bytes
    candidates: tuple = ("hor", "packed")

    def predicted_posting_bytes(self, stats: SegmentStats,
                                layout: str) -> int:
        if layout == "packed":
            return est_packed_posting_bytes(stats)
        if layout == "banded":
            return est_banded_posting_bytes(stats)
        return est_hor_posting_bytes(stats)

    def measured_cost_s(self, backend: str, size_class: int,
                        layout: str) -> float | None:
        """Median fused-engine seconds from the active tuning table's
        sweep record for this exact (backend, size_class, layout), or
        None when the sweep hasn't covered it."""
        from repro.kernels import autotune
        return autotune.get_active().cost(backend, size_class, layout)

    def choose(self, stats: SegmentStats, size_class: int | None = None,
               backend: str = "pallas") -> LayoutDecision:
        """Pick a layout for a run shaped like ``stats``.

        Preference order: measured decode costs when the tuning table
        has swept BOTH candidate layouts at this (backend, size_class);
        otherwise the analytic byte model gated on ``min_packed_docs``.
        """
        if size_class is None:
            size_class = tuning_size_class(stats.num_docs)
        costs = {l: self.measured_cost_s(backend, size_class, l)
                 for l in self.candidates}
        if all(c is not None for c in costs.values()):
            best = min(self.candidates, key=lambda l: (costs[l], l))
            return LayoutDecision(best, (
                f"measured:{backend}@{size_class} "
                + " ".join(f"{l}={costs[l]:.2e}s" for l in self.candidates)))
        d = self._analytic_choose(stats, size_class)
        measured = [l for l in self.candidates if costs[l] is not None]
        if measured:
            # a PARTIAL sweep (some but not all candidates timed) must
            # not masquerade as a measurement: the decision below came
            # from the byte model, and campaign reports read the reason
            return LayoutDecision(d.layout, (
                f"analytic:partial-measured({','.join(measured)}) "
                + d.reason[len("analytic:"):]))
        return d

    def _analytic_choose(self, stats: SegmentStats,
                         size_class: int) -> LayoutDecision:
        """Byte-model rung, generalized over ``candidates``: the best
        non-hor layout by predicted bytes must beat hor by the HBM
        ratio or the run stays hor.  With the historical default
        candidates this emits character-identical reasons to the
        original two-layout chooser."""
        if stats.num_docs < self.min_packed_docs:
            return LayoutDecision("hor", (
                f"analytic:small-segment {stats.num_docs}"
                f"<{self.min_packed_docs} docs (decode-bound)"))
        non_hor = [l for l in self.candidates if l != "hor"]
        if not non_hor:
            return LayoutDecision("hor",
                                  f"analytic:hor only candidate @{size_class}")
        hb = self.predicted_posting_bytes(stats, "hor")
        nh_bytes = {l: self.predicted_posting_bytes(stats, l)
                    for l in non_hor}
        best = min(non_hor, key=lambda l: (nh_bytes[l], l))
        ratio = nh_bytes[best] / max(hb, 1)
        if ratio <= self.hbm_ratio_max:
            return LayoutDecision(best, (
                f"analytic:bytes/q {ratio:.2f}x hor @{size_class}"))
        return LayoutDecision("hor", (
            f"analytic:{best} only {ratio:.2f}x hor @{size_class}"
            f" (>{self.hbm_ratio_max})"))

    def to_dict(self) -> dict:
        return {"min_packed_docs": self.min_packed_docs,
                "hbm_ratio_max": self.hbm_ratio_max,
                "candidates": list(self.candidates)}

    @classmethod
    def from_dict(cls, d: dict) -> "LayoutCostModel":
        return cls(min_packed_docs=int(d["min_packed_docs"]),
                   hbm_ratio_max=float(d["hbm_ratio_max"]),
                   candidates=tuple(d.get("candidates", ("hor", "packed"))))


def resolve_layout(explicit: str | None, policy, stats: SegmentStats,
                   default: str, size_class: int | None = None,
                   backend: str = "pallas") -> tuple[str, str]:
    """THE override ladder every layout-taking layer funnels through:
    ``explicit arg > policy > historical default``.  Returns
    ``(layout, reason)``; with ``explicit=None`` and ``policy=None``
    this is exactly the pre-chooser constant-default behavior."""
    if explicit is not None:
        return str(explicit), "explicit"
    if policy is not None:
        d = policy.choose(stats, size_class=size_class, backend=backend)
        return d.layout, d.reason
    return str(default), "default"
