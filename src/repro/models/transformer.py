"""Unified decoder-only transformer covering the five assigned LM archs.

One config-driven implementation provides:
  * GQA attention (+ optional per-head qk RMS-norm)      — qwen3, gemma3
  * interleaved local(sliding-window):global layers       — gemma3 (5:1),
    with per-layer RoPE bases (10k local / 1M global)       mixtral (SWA)
  * MLA latent attention (expanded prefill, absorbed decode) — minicpm3
  * mixture-of-experts SwiGLU FFN (top-2, capacity + drop) — mixtral
  * scan-over-layers with stacked params (compile-time O(1) in depth),
    chunked attention and chunked softmax-CE loss so no S×S score matrix
    or [B,S,V] logits tensor is ever materialized.

Three entry points per model, matching the dry-run cells:
  ``loss_fn``     (train_*):   tokens+labels -> scalar CE loss
  ``prefill``     (prefill_*): tokens -> last-position logits + KV cache
  ``decode_step`` (decode_* / long_*): one token vs a seq-len cache
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec

from repro.models import attention as attn_lib
from repro.models.attention import MlaDims
from repro.models.layers import (apply_rope, cast, dense_init, embed_init,
                                 rms_norm)

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class MoeConfig:
    n_experts: int
    top_k: int = 2
    capacity_factor: float = 1.25
    # GShard-style dispatch groups == data shards: every dispatch op
    # (one-hot, cumsum ranks, scatter, gather) stays LOCAL to its group,
    # so the MoE layer partitions with zero dispatch collectives.  The
    # cell builder sets this to the mesh's dp size.
    groups: int = 1


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    attn: str = "gqa"                 # "gqa" | "mla"
    mla: MlaDims | None = None
    qk_norm: bool = False
    rope_base: float = 10_000.0
    rope_base_local: float | None = None   # local layers (gemma3: 10k)
    window: int = 0                   # sliding window (0 = full attention)
    global_every: int = 0             # every Nth layer is global (gemma3: 6)
    moe: MoeConfig | None = None
    post_norm: bool = False           # sandwich norms (gemma3)
    embed_scale: float | None = None  # sqrt(d) for gemma, 12 for minicpm3
    residual_scale: float = 1.0       # minicpm3 depth-scaled residuals
    tie_embeddings: bool = True
    dtype: Any = jnp.bfloat16
    chunk_q: int = 512
    loss_chunk: int = 2048
    remat: bool = True
    # ring (window-sized) decode cache: valid when EVERY layer is
    # windowed (mixtral SWA).  Slot order is irrelevant — RoPE is baked
    # into K at write time — so `slot = pos % window` needs no remapping
    # and the cache shrinks seq_len/window (8x at decode_32k, 128x at
    # long_500k).  The paper's thesis, applied to attention state.
    ring_cache: bool = False
    # unroll the decode layer loop: avoids XLA's widen-and-hoist of
    # per-layer bf16->f32 operand converts (a CPU-backend pessimization
    # that also bloats the while state); trades compile time.
    decode_unroll: bool = False
    # GSPMD activation-sharding annotations (set by the cell builder when
    # lowering on a production mesh; empty = no constraints, e.g. tests).
    batch_axes: tuple = ()
    tp_axis: str = ""
    # residual-stream dtype: f32 is the conservative default; bf16 halves
    # every TP activation all-reduce/-gather and the saved SP residuals
    # (hillclimb (a): turns qwen3 train_4k from collective- to
    # compute-bound).  Master weights/optimizer stay f32 either way.
    residual_dtype: Any = jnp.float32

    @property
    def qkv_dim(self) -> int:
        return self.n_heads * self.head_dim

    def layer_is_global(self) -> jnp.ndarray:
        """bool[L]: which layers use full (global) attention."""
        if self.window <= 0:
            return jnp.ones((self.n_layers,), jnp.bool_)
        if self.global_every <= 0:
            return jnp.zeros((self.n_layers,), jnp.bool_)   # all windowed
        idx = jnp.arange(self.n_layers)
        return (idx + 1) % self.global_every == 0

    def param_count(self, params=None) -> int:
        if params is None:
            return 0
        return sum(int(x.size) for x in jax.tree.leaves(params))


def _constrain(x, cfg: "TransformerConfig", *spec):
    """with_sharding_constraint if the config names mesh axes.

    ``spec`` entries: "batch" -> cfg.batch_axes, "tp" -> cfg.tp_axis,
    None -> unsharded.
    """
    if not cfg.batch_axes and not cfg.tp_axis:
        return x
    parts = []
    for e in spec:
        if e == "batch":
            parts.append(cfg.batch_axes if cfg.batch_axes else None)
        elif e == "tp":
            parts.append(cfg.tp_axis if cfg.tp_axis else None)
        else:
            parts.append(None)
    return jax.lax.with_sharding_constraint(x, PartitionSpec(*parts))


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _stack(key, n: int, fn):
    return jax.vmap(fn)(jax.random.split(key, n))


def init_params(key, cfg: TransformerConfig) -> dict:
    keys = jax.random.split(key, 8)
    p: dict = {"embed": embed_init(keys[0], cfg.vocab, cfg.d_model)}

    if cfg.attn == "mla":
        assert cfg.mla is not None
        p["attn"] = _stack(keys[1], cfg.n_layers,
                           lambda k: attn_lib.init_mla(k, cfg.d_model, cfg.mla))
    else:
        def one_attn(k):
            k1, k2, k3, k4 = jax.random.split(k, 4)
            d, hd = cfg.d_model, cfg.head_dim
            prm = {
                "wq": dense_init(k1, d, cfg.n_heads * hd),
                "wk": dense_init(k2, d, cfg.n_kv_heads * hd),
                "wv": dense_init(k3, d, cfg.n_kv_heads * hd),
                "wo": dense_init(k4, cfg.n_heads * hd, d),
            }
            if cfg.qk_norm:
                prm["q_gamma"] = jnp.zeros((hd,), jnp.float32)
                prm["k_gamma"] = jnp.zeros((hd,), jnp.float32)
            return prm
        p["attn"] = _stack(keys[1], cfg.n_layers, one_attn)

    if cfg.moe is None:
        def one_mlp(k):
            k1, k2, k3 = jax.random.split(k, 3)
            return {"w_gate": dense_init(k1, cfg.d_model, cfg.d_ff),
                    "w_up": dense_init(k2, cfg.d_model, cfg.d_ff),
                    "w_down": dense_init(k3, cfg.d_ff, cfg.d_model)}
    else:
        E = cfg.moe.n_experts

        def one_mlp(k):
            k0, k1, k2, k3 = jax.random.split(k, 4)
            return {
                "router": dense_init(k0, cfg.d_model, E),
                "w_gate": jax.vmap(lambda kk: dense_init(
                    kk, cfg.d_model, cfg.d_ff))(jax.random.split(k1, E)),
                "w_up": jax.vmap(lambda kk: dense_init(
                    kk, cfg.d_model, cfg.d_ff))(jax.random.split(k2, E)),
                "w_down": jax.vmap(lambda kk: dense_init(
                    kk, cfg.d_ff, cfg.d_model))(jax.random.split(k3, E)),
            }
    p["mlp"] = _stack(keys[2], cfg.n_layers, one_mlp)

    p["pre_attn_norm"] = jnp.zeros((cfg.n_layers, cfg.d_model), jnp.float32)
    p["pre_mlp_norm"] = jnp.zeros((cfg.n_layers, cfg.d_model), jnp.float32)
    if cfg.post_norm:
        p["post_attn_norm"] = jnp.zeros((cfg.n_layers, cfg.d_model),
                                        jnp.float32)
        p["post_mlp_norm"] = jnp.zeros((cfg.n_layers, cfg.d_model),
                                       jnp.float32)
    p["final_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(keys[3], cfg.d_model, cfg.vocab)
    return p


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------


def _gqa_qkv(prm: dict, x: Array, positions: Array, rope_base: Array,
             cfg: TransformerConfig):
    b, s, _ = x.shape
    hd = cfg.head_dim
    xg = cast(x, cfg.dtype)
    q = (xg @ cast(prm["wq"], cfg.dtype)).reshape(b, s, cfg.n_heads, hd)
    k = (xg @ cast(prm["wk"], cfg.dtype)).reshape(b, s, cfg.n_kv_heads, hd)
    v = (xg @ cast(prm["wv"], cfg.dtype)).reshape(b, s, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, prm["q_gamma"])
        k = rms_norm(k, prm["k_gamma"])
    q = q.transpose(0, 2, 1, 3)
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)
    q = apply_rope(q, positions[:, None, :], rope_base)
    k = apply_rope(k, positions[:, None, :], rope_base)
    q = _constrain(q, cfg, "batch", "tp", None, None)
    k = _constrain(k, cfg, "batch", "tp", None, None)
    v = _constrain(v, cfg, "batch", "tp", None, None)
    return q, k, v


def _moe_ffn(prm: dict, x: Array, moe: MoeConfig, dtype,
             cfg: "TransformerConfig | None" = None,
             dropless: bool = False) -> Array:
    """Capacity-based top-k MoE with GROUPED (GShard) dispatch.

    x [N, d] tokens, reshaped [G, N/G, d] with G == data shards so the
    group dim inherits the batch sharding: one-hot gating, cumsum ranks,
    the capacity-slot scatter, and the combine gather are all LOCAL to a
    group — no dispatch collectives.  Capacity is per group (exactly how
    GShard/MaxText define it).  Expert weights stay FSDP-sharded; XLA
    all-gathers them per layer (ZeRO-3 style).
    """
    n, d = x.shape
    e, k = moe.n_experts, moe.top_k
    g = moe.groups if moe.groups > 0 and n % max(moe.groups, 1) == 0 else 1
    ng = n // g
    # dropless (decode): every expert can hold every token — decode
    # batches are tiny and production decoders never drop tokens.
    cap = ng if dropless else max(int(moe.capacity_factor * ng * k / e), 1)
    xg = cast(x, dtype).reshape(g, ng, d)
    if cfg is not None:
        xg = _constrain(xg, cfg, "batch", None, None)

    logits = jnp.einsum("gnd,de->gne", xg,
                        cast(prm["router"], dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_e = jax.lax.top_k(probs, k)                 # [G, ng, k]
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # per-(group, expert) ranks via slot-sequential cumsum (no sort)
    prev = jnp.zeros((g, 1, e), jnp.float32)
    ranks = []
    for j in range(k):
        oh = jax.nn.one_hot(gate_e[..., j], e, dtype=jnp.float32)
        pos = jnp.cumsum(oh, axis=1) - oh + prev             # [G, ng, e]
        ranks.append(jnp.sum(oh * pos, axis=-1))             # [G, ng]
        prev = prev + jnp.sum(oh, axis=1, keepdims=True)
    rank = jnp.stack(ranks, axis=-1).astype(jnp.int32)       # [G, ng, k]

    keep = rank < cap
    slot = jnp.where(keep, gate_e * cap + rank, e * cap)     # [G, ng, k]

    buf = jnp.zeros((g, e * cap + 1, d), dtype)
    updates = xg[:, :, None, :] * keep[..., None].astype(dtype)
    if cfg is not None:
        updates = _constrain(updates, cfg, "batch", None, None, None)
    # vmap over the group dim -> a scatter with operand BATCH dims, which
    # GSPMD keeps local per shard.  (The broadcast-iota [g,1,1] indexing
    # form was NOT pattern-matched: it replicated + all-reduced the full
    # dispatch buffer — ~4 GiB/layer of wire on mixtral-8x22b.)
    buf = jax.vmap(lambda bg, sg, ug: bg.at[sg].add(ug, mode="drop"))(
        buf, slot, updates)
    buf = buf[:, :e * cap].reshape(g, e, cap, d)
    if cfg is not None:
        buf = _constrain(buf, cfg, "batch", None, None, None)

    gg = jnp.einsum("gecd,edf->gecf", buf, cast(prm["w_gate"], dtype))
    uu = jnp.einsum("gecd,edf->gecf", buf, cast(prm["w_up"], dtype))
    hh = jax.nn.silu(gg.astype(jnp.float32)).astype(dtype) * uu
    out = jnp.einsum("gecf,efd->gecd", hh, cast(prm["w_down"], dtype))
    out = out.reshape(g, e * cap, d)
    if cfg is not None:
        out = _constrain(out, cfg, "batch", None, None)

    safe = jnp.minimum(slot, e * cap - 1)
    gathered = jax.vmap(lambda og, sg: og[sg])(out, safe)    # [G, ng, k, d]
    gathered = gathered * keep[..., None]
    combined = (gathered * gate_w[..., None].astype(dtype)).sum(axis=2)
    return combined.reshape(n, d).astype(x.dtype)


def _dense_ffn(prm: dict, x: Array, dtype) -> Array:
    xg = cast(x, dtype)
    g = xg @ cast(prm["w_gate"], dtype)
    u = xg @ cast(prm["w_up"], dtype)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(dtype) * u
    return (h @ cast(prm["w_down"], dtype)).astype(x.dtype)


def _layer_fwd(cfg: TransformerConfig, x: Array, layer_params: dict,
               is_global: Array, positions: Array, want_cache: bool):
    """One transformer block (shared by train/prefill).  x [B,S,d].

    Sequence parallelism: the layer carry arrives SEQ-SHARDED over the
    tensor axis (saved activations / remat residuals are 1/|model| the
    size — Megatron-SP); it is gathered here and re-scattered at the
    end, which GSPMD lowers to the all-gather / reduce-scatter pair.
    """
    x = _constrain(x, cfg, "batch", None, None)     # gather seq
    b, s, d = x.shape
    rope_base = jnp.where(
        is_global, cfg.rope_base,
        cfg.rope_base_local if cfg.rope_base_local else cfg.rope_base)
    window = jnp.where(is_global, 0, cfg.window)

    h = rms_norm(x, layer_params["pre_attn_norm"])
    cache = None
    if cfg.attn == "mla":
        q, kk, vv, c_kv, k_rope = attn_lib.mla_qkv(
            layer_params["attn"], h, positions, cfg.mla, cfg.rope_base,
            cfg.dtype)
        o = attn_lib.chunked_attention(q, kk, vv, causal=True,
                                       window=0, chunk=cfg.chunk_q,
                                       remat=cfg.remat)
        o = o.transpose(0, 2, 1, 3).reshape(b, s, -1)
        o = (cast(o, cfg.dtype) @
             cast(layer_params["attn"]["w_o"], cfg.dtype)).astype(x.dtype)
        if want_cache:
            cache = (c_kv.astype(cfg.dtype), k_rope.astype(cfg.dtype))
    else:
        q, kk, vv = _gqa_qkv(layer_params["attn"], h, positions, rope_base,
                             cfg)
        o = attn_lib.chunked_attention(q, kk, vv, causal=True, window=window,
                                       chunk=cfg.chunk_q, remat=cfg.remat)
        o = o.transpose(0, 2, 1, 3).reshape(b, s, -1)
        o = (cast(o, cfg.dtype) @
             cast(layer_params["attn"]["wo"], cfg.dtype)).astype(x.dtype)
        if want_cache:
            cache = (kk.astype(cfg.dtype), vv.astype(cfg.dtype))
    if cfg.post_norm:
        o = rms_norm(o, layer_params["post_attn_norm"])
    x = x + cfg.residual_scale * o

    h = rms_norm(x, layer_params["pre_mlp_norm"])
    if cfg.moe is not None:
        f = _moe_ffn(layer_params["mlp"], h.reshape(b * s, d), cfg.moe,
                     cfg.dtype, cfg).reshape(b, s, d)
    else:
        f = _dense_ffn(layer_params["mlp"], h, cfg.dtype)
    if cfg.post_norm:
        f = rms_norm(f, layer_params["post_mlp_norm"])
    x = x + cfg.residual_scale * f
    x = _constrain(x, cfg, "batch", "tp", None)     # re-scatter seq (SP)
    return x, cache


def _split_layer_params(params: dict, cfg: TransformerConfig):
    """Stacked per-layer params fed to lax.scan as xs."""
    out = {"attn": params["attn"], "mlp": params["mlp"],
           "pre_attn_norm": params["pre_attn_norm"],
           "pre_mlp_norm": params["pre_mlp_norm"]}
    if cfg.post_norm:
        out["post_attn_norm"] = params["post_attn_norm"]
        out["post_mlp_norm"] = params["post_mlp_norm"]
    return out


def backbone(params: dict, cfg: TransformerConfig, tokens: Array,
             want_cache: bool = False):
    """tokens i32[B,S] -> hidden [B,S,d] (+ stacked cache if requested)."""
    b, s = tokens.shape
    # cast the table BEFORE the row gather: XLA otherwise all-gathers the
    # f32 master table (594 MiB on qwen3) instead of the bf16 copy.
    x = cast(params["embed"], cfg.dtype)[tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.embed_scale, cfg.dtype)
    x = x.astype(cfg.residual_dtype)
    # seq-sharded (SP) between layers: the scan's saved residuals are
    # 1/|model| the size; each layer gathers at entry, scatters at exit.
    x = _constrain(x, cfg, "batch", "tp", None)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    is_global = cfg.layer_is_global()

    layer_xs = (_split_layer_params(params, cfg), is_global)

    def body(carry, xs):
        # anchor the loop-carried (and remat-saved) residual to the
        # seq-sharded SP layout — without this the [L,B,S,d] saved stack
        # materializes seq-unsharded (measured 24 GiB/device on 8x22b).
        carry = _constrain(carry, cfg, "batch", "tp", None)
        lp, ig = xs
        y, cache = _layer_fwd(cfg, carry, lp, ig, positions, want_cache)
        return y, cache

    if cfg.remat:
        body = jax.checkpoint(body)
    x, caches = jax.lax.scan(body, x, layer_xs)
    x = rms_norm(x, params["final_norm"])
    return x, caches


# ---------------------------------------------------------------------------
# losses / entry points
# ---------------------------------------------------------------------------


def _logits_matrix(params: dict, cfg: TransformerConfig) -> Array:
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


def chunked_xent(h: Array, w_out: Array, targets: Array, chunk: int,
                 dtype, cfg: "TransformerConfig | None" = None) -> Array:
    """Mean CE without materializing [B,S,V]: scan over seq chunks."""
    b, s, d = h.shape
    chunk = min(chunk, s)
    if s % chunk:
        import math
        chunk = math.gcd(chunk, s)   # fallback for odd test lengths
    n = s // chunk

    w_cast = cast(w_out, dtype)   # hoisted: one bf16 copy, gathered once

    def one(hc, tc):
        logits = (cast(hc, dtype) @ w_cast).astype(jnp.float32)
        if cfg is not None:
            # pin [B(batch), chunk, V(tp)] — without this GSPMD resolves
            # the tied-embedding grad by replicating the batch (~19 GiB
            # f32 logits buffers per device; measured on qwen3 train_4k).
            logits = _constrain(logits, cfg, "batch", None, "tp")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        return (lse - gold).sum()

    one = jax.checkpoint(one)

    def scan_body(tot, i):
        hc = jax.lax.dynamic_slice_in_dim(h, i * chunk, chunk, axis=1)
        tc = jax.lax.dynamic_slice_in_dim(targets, i * chunk, chunk, axis=1)
        return tot + one(hc, tc), None

    tot, _ = jax.lax.scan(scan_body, jnp.zeros((), jnp.float32),
                          jnp.arange(n, dtype=jnp.int32))
    return tot / (b * s)


def loss_fn(params: dict, cfg: TransformerConfig, batch: dict) -> Array:
    """batch: tokens i32[B,S], labels i32[B,S] -> scalar CE."""
    h, _ = backbone(params, cfg, batch["tokens"], want_cache=False)
    return chunked_xent(h, _logits_matrix(params, cfg), batch["labels"],
                        cfg.loss_chunk, cfg.dtype, cfg)


class PrefillResult(NamedTuple):
    logits: Array       # [B, V] at the last position
    cache: Any          # stacked per-layer cache
    cache_len: Array    # i32[B]


def prefill(params: dict, cfg: TransformerConfig, tokens: Array
            ) -> PrefillResult:
    h, caches = backbone(params, cfg, tokens, want_cache=True)
    last = h[:, -1, :]
    logits = (cast(last, cfg.dtype) @
              cast(_logits_matrix(params, cfg), cfg.dtype)
              ).astype(jnp.float32)
    b, s = tokens.shape
    # next write position is s: pad the cache (pad_cache) before decoding.
    cache_len = jnp.full((b,), s, jnp.int32)
    return PrefillResult(logits=logits, cache=caches, cache_len=cache_len)


def pad_cache(cache, max_len: int, cfg: TransformerConfig):
    """Grow a prefill cache [L,B,...,S,...] to ``max_len`` slots for decode."""
    def grow(x, axis):
        pad = [(0, 0)] * x.ndim
        pad[axis] = (0, max_len - x.shape[axis])
        return jnp.pad(x, pad)
    if cfg.attn == "mla":
        c, kr = cache
        # prefill emits [L,B,S,dim]
        return (grow(c, 2), grow(kr, 2))
    k, v = cache
    # prefill emits [L,B,Hkv,S,hd]
    return (grow(k, 3), grow(v, 3))


def cache_slots(cfg: TransformerConfig, seq: int) -> int:
    if cfg.ring_cache and cfg.window > 0 and cfg.global_every == 0:
        return min(seq, cfg.window)
    return seq


def init_cache(cfg: TransformerConfig, batch: int, seq: int) -> Any:
    """Zeroed decode cache (stacked over layers)."""
    seq = cache_slots(cfg, seq)
    if cfg.attn == "mla":
        c = jnp.zeros((cfg.n_layers, batch, seq, cfg.mla.kv_lora), cfg.dtype)
        kr = jnp.zeros((cfg.n_layers, batch, seq, cfg.mla.rope), cfg.dtype)
        return (c, kr)
    k = jnp.zeros((cfg.n_layers, batch, cfg.n_kv_heads, seq, cfg.head_dim),
                  cfg.dtype)
    v = jnp.zeros_like(k)
    return (k, v)


def decode_step(params: dict, cfg: TransformerConfig, cache: Any,
                tokens: Array, cache_len: Array):
    """One decode step.  tokens i32[B,1]; cache holds ``seq`` slots;
    the new token's K/V is written at position ``cache_len``.

    Returns (logits [B,V], new_cache, new_cache_len).
    """
    b = tokens.shape[0]
    x = cast(params["embed"], cfg.dtype)[tokens[:, 0]][:, None, :]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.embed_scale, cfg.dtype)
    x = x.astype(cfg.residual_dtype)
    is_global = cfg.layer_is_global()
    positions = cache_len[:, None]                        # [B,1]

    layer_xs = (_split_layer_params(params, cfg), is_global, cache)

    def body(carry, xs):
        lp, ig, layer_cache = xs
        y, new_cache = _decode_layer(cfg, carry, lp, ig, layer_cache,
                                     cache_len)
        return y, new_cache

    x, new_cache = jax.lax.scan(body, x, layer_xs,
                                unroll=cfg.n_layers if cfg.decode_unroll
                                else 1)
    x = rms_norm(x, params["final_norm"])
    logits = (cast(x[:, 0], cfg.dtype) @
              cast(_logits_matrix(params, cfg), cfg.dtype)
              ).astype(jnp.float32)
    return logits, new_cache, cache_len + 1


def _decode_layer(cfg: TransformerConfig, x: Array, lp: dict,
                  is_global: Array, layer_cache, cache_len: Array):
    b = x.shape[0]
    window = jnp.where(is_global, 0, cfg.window)
    rope_base = jnp.where(
        is_global, cfg.rope_base,
        cfg.rope_base_local if cfg.rope_base_local else cfg.rope_base)

    h = rms_norm(x, lp["pre_attn_norm"])
    if cfg.attn == "mla":
        c_cache, kr_cache = layer_cache
        xg = cast(h[:, 0:1, :], cfg.dtype)
        c_new = rms_norm(xg @ cast(lp["attn"]["w_dkv"], cfg.dtype),
                         lp["attn"]["kv_norm"])
        kr_new = apply_rope((xg @ cast(lp["attn"]["w_kr"], cfg.dtype)),
                            cache_len[:, None], cfg.rope_base)
        bidx = jnp.arange(b)
        c_cache = c_cache.at[bidx, cache_len].set(
            c_new[:, 0].astype(c_cache.dtype))
        kr_cache = kr_cache.at[bidx, cache_len].set(
            kr_new[:, 0].astype(kr_cache.dtype))
        o = attn_lib.mla_decode(lp["attn"], h, c_cache, kr_cache, cache_len,
                                cfg.mla, cfg.rope_base, cfg.dtype)
        new_cache = (c_cache, kr_cache)
    else:
        k_cache, v_cache = layer_cache                    # [B,Hkv,S,hd]
        n_slots = k_cache.shape[2]
        ring = cfg.ring_cache and cfg.window > 0 and cfg.global_every == 0
        hd = cfg.head_dim
        xg = cast(h, cfg.dtype)
        q = (xg @ cast(lp["attn"]["wq"], cfg.dtype)
             ).reshape(b, 1, cfg.n_heads, hd)
        kk = (xg @ cast(lp["attn"]["wk"], cfg.dtype)
              ).reshape(b, 1, cfg.n_kv_heads, hd)
        vv = (xg @ cast(lp["attn"]["wv"], cfg.dtype)
              ).reshape(b, 1, cfg.n_kv_heads, hd)
        if cfg.qk_norm:
            q = rms_norm(q, lp["attn"]["q_gamma"])
            kk = rms_norm(kk, lp["attn"]["k_gamma"])
        q = apply_rope(q.transpose(0, 2, 1, 3), cache_len[:, None, None],
                       rope_base)
        kk = apply_rope(kk.transpose(0, 2, 1, 3), cache_len[:, None, None],
                        rope_base)
        vv = vv.transpose(0, 2, 1, 3)
        bidx = jnp.arange(b)
        slot = cache_len % n_slots if ring else cache_len
        k_cache = k_cache.at[bidx, :, slot, :].set(
            kk[:, :, 0, :].astype(k_cache.dtype))
        v_cache = v_cache.at[bidx, :, slot, :].set(
            vv[:, :, 0, :].astype(v_cache.dtype))
        # ring cache holds exactly the window -> plain validity masking
        # (slots <= tokens seen); non-ring uses the positional window.
        o = attn_lib.decode_attention(q, k_cache, v_cache, cache_len,
                                      window=0 if ring else window)
        o = o.reshape(b, 1, -1)
        o = (cast(o, cfg.dtype) @ cast(lp["attn"]["wo"], cfg.dtype)
             ).astype(x.dtype)
        new_cache = (k_cache, v_cache)
    if cfg.post_norm:
        o = rms_norm(o, lp["post_attn_norm"])
    x = x + cfg.residual_scale * o

    h = rms_norm(x, lp["pre_mlp_norm"])
    if cfg.moe is not None:
        f = _moe_ffn(lp["mlp"], h.reshape(b, -1), cfg.moe, cfg.dtype,
                     None, dropless=True).reshape(b, 1, -1)
    else:
        f = _dense_ffn(lp["mlp"], h, cfg.dtype)
    if cfg.post_norm:
        f = rms_norm(f, lp["post_mlp_norm"])
    x = x + cfg.residual_scale * f
    return x, new_cache
