"""PNA (Principal Neighbourhood Aggregation, arXiv:2004.05718) in JAX.

Message passing runs on the paper's CSR insight (DESIGN.md §5): the
adjacency IS a posting list — node -> sorted neighbor slab — and
aggregation is the same gather + segment-reduce primitive as query
evaluation.  Three execution regimes, one forward:

  * full-batch (cora / ogb_products): edge-list segment reductions;
    edges shard over the data axis under GSPMD (partial aggregates are
    psum'd by XLA).
  * sampled minibatch (reddit-scale): the host-side neighbor sampler
    (train/data.py) emits a fixed-shape padded subgraph; same forward.
  * batched small graphs (molecule): disjoint union + per-graph readout.

Aggregators: mean/min/max/std (fused Pallas kernel available for the
padded-degree regime); scalers: identity/amplification/attenuation.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import segments
from repro.models.layers import dense_init

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class PnaConfig:
    name: str
    n_layers: int = 4
    d_hidden: int = 75
    d_feat: int = 1433
    n_classes: int = 16
    delta: float = 2.5          # avg log-degree normalizer (PNA eq. 5)
    eps: float = 1e-5
    # aggregators fixed: mean/min/max/std; scalers: id/amp/atten (x12)


N_AGG = 4
N_SCAL = 3


def init_params(key, cfg: PnaConfig) -> dict:
    keys = jax.random.split(key, 4)
    d = cfg.d_hidden

    def one_layer(k):
        k1, k2 = jax.random.split(k)
        return {
            # message MLP on (h_src || h_dst)
            "w_pre": dense_init(k1, 2 * d, d),
            "b_pre": jnp.zeros((d,), jnp.float32),
            # post-aggregation transform on (h || 12 aggregated channels)
            "w_post": dense_init(k2, (N_AGG * N_SCAL + 1) * d, d),
            "b_post": jnp.zeros((d,), jnp.float32),
        }

    return {
        "enc": dense_init(keys[0], cfg.d_feat, d),
        "layers": jax.vmap(one_layer)(jax.random.split(keys[1],
                                                       cfg.n_layers)),
        "out": dense_init(keys[2], d, cfg.n_classes),
    }


def _pna_layer(lp: dict, h: Array, src: Array, dst: Array, deg: Array,
               num_nodes: int, delta: float, eps: float) -> Array:
    """One PNA layer over an edge list (padding edges: src == dst == N)."""
    m_in = jnp.concatenate([h[src], h[dst]], axis=-1)
    m = jax.nn.relu(m_in @ lp["w_pre"] + lp["b_pre"])          # [E, d]

    mean = segments.segment_mean(m, dst, num_nodes, sorted_ids=False)
    mn = segments.segment_min(m, dst, num_nodes, sorted_ids=False)
    mn = jnp.where(jnp.isfinite(mn), mn, 0.0)
    mx = segments.segment_max(m, dst, num_nodes, sorted_ids=False)
    mx = jnp.where(jnp.isfinite(mx), mx, 0.0)
    std = segments.segment_std(m, dst, num_nodes, sorted_ids=False, eps=eps)
    agg = jnp.concatenate([mean, mn, mx, std], axis=-1)        # [N, 4d]

    logd = jnp.log1p(deg)[:, None]
    s_amp = logd / delta
    s_att = delta / jnp.maximum(logd, 1e-3)
    scaled = jnp.concatenate([agg, agg * s_amp, agg * s_att], axis=-1)

    upd = jnp.concatenate([h, scaled], axis=-1) @ lp["w_post"] + lp["b_post"]
    return h + jax.nn.relu(upd)                                # residual


def forward(params: dict, cfg: PnaConfig, feats: Array, src: Array,
            dst: Array, num_nodes: int) -> Array:
    """feats [N, F], edge lists [E] (pad edges point at node N) -> [N, d]."""
    h = feats @ params["enc"]
    # degree (in-), computed once; padding edges (dst == N) are dropped.
    ones = jnp.ones(dst.shape[:1], jnp.float32)
    deg = segments.segment_sum(ones, dst, num_nodes, sorted_ids=False)

    # layers are stacked but few (4) and cheap: fori over stacked params
    # via scan keeps compile size O(1) in depth.
    def body(h, lp):
        return _pna_layer(lp, h, src, dst, deg, num_nodes, cfg.delta,
                          cfg.eps), None

    # remat: edge-message intermediates ([E, d] x several aggregators)
    # dominate memory at ogb_products scale; recompute them in backward.
    h, _ = jax.lax.scan(jax.checkpoint(body), h, params["layers"])
    return h


def node_logits(params: dict, cfg: PnaConfig, feats: Array, src: Array,
                dst: Array, num_nodes: int) -> Array:
    return forward(params, cfg, feats, src, dst, num_nodes) @ params["out"]


def node_loss(params: dict, cfg: PnaConfig, batch: dict) -> Array:
    """Node classification CE over ``mask``-ed nodes.

    batch: feats [N,F], src/dst [E], labels i32[N], mask bool[N].
    """
    n = batch["feats"].shape[0]
    logits = node_logits(params, cfg, batch["feats"], batch["src"],
                         batch["dst"], n)
    logp = jax.nn.log_softmax(logits, axis=-1)
    gold = jnp.take_along_axis(logp, batch["labels"][:, None], axis=-1)[:, 0]
    m = batch["mask"].astype(jnp.float32)
    return -(gold * m).sum() / jnp.maximum(m.sum(), 1.0)


def graph_loss(params: dict, cfg: PnaConfig, batch: dict) -> Array:
    """Batched small graphs: mean-readout per graph + CE.

    batch: feats [N,F], src/dst [E], graph_ids i32[N], g_labels i32[G].
    """
    n = batch["feats"].shape[0]
    g = batch["g_labels"].shape[0]
    h = forward(params, cfg, batch["feats"], batch["src"], batch["dst"], n)
    pooled = segments.segment_mean(h, batch["graph_ids"], g,
                                   sorted_ids=True)
    logits = pooled @ params["out"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    gold = jnp.take_along_axis(logp, batch["g_labels"][:, None],
                               axis=-1)[:, 0]
    return -gold.mean()
