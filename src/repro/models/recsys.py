"""Recsys architectures: SASRec, BERT4Rec, DIEN, xDeepFM.

The embedding layer is where the paper's layout insight lands (DESIGN.md
§5): item-history / multi-hot lookups are ragged bags over huge tables —
EmbeddingBag implemented as take + segment_sum (core/segments.py) with a
fused Pallas kernel (kernels/embedding_bag.py); this is the exact
W(f+t)+2f·N_d vs N_d(3f+t) storage math from the paper applied to
feature tables.

Four shapes per arch (configs/): train_batch (training loss),
serve_p99 / serve_bulk (full-model scoring), retrieval_cand (two-tower
dot scoring of 1M candidates + top-k — the batched-dot regime, never a
loop).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import segments
from repro.models.layers import (cast, dense_init, embed_init, gru_scan,
                                 init_gru, init_mlp, layer_norm, mlp)

Array = jax.Array

ROW_PAD = 512    # embedding tables pad to lane multiples so the row dim
                 # shards evenly over any production mesh axis product


def padded_rows(n: int) -> int:
    return -(-n // ROW_PAD) * ROW_PAD


# ---------------------------------------------------------------------------
# shared: sampled softmax + two-tower retrieval scoring
# ---------------------------------------------------------------------------


def _sampled_softmax_chunk(user_vec, pos_ids, neg_ids, table, valid):
    pos_e = table[pos_ids]                              # [..., d]
    neg_e = table[neg_ids]                              # [..., K, d]
    pos_l = (user_vec * pos_e).sum(-1, keepdims=True)   # [..., 1]
    neg_l = jnp.einsum("...d,...kd->...k", user_vec, neg_e)
    logits = jnp.concatenate([pos_l, neg_l], axis=-1)
    logp = jax.nn.log_softmax(logits, axis=-1)
    loss = -logp[..., 0]
    w = valid.astype(jnp.float32)
    return (loss * w).sum(), w.sum()


def sampled_softmax_loss(user_vec: Array, pos_ids: Array, neg_ids: Array,
                         table: Array, valid: Array | None = None,
                         seq_chunk: int = 8) -> Array:
    """CE against [pos | sampled negs].  user_vec [B,d] (or [B,S,d]),
    pos_ids [B]([B,S]), neg_ids [B,K]([B,S,K]).

    Sequence inputs are scanned in ``seq_chunk`` slices so the [B,S,K,d]
    negative-embedding gather is never materialized (it was 26 GiB per
    device at the bert4rec train_batch shape).
    """
    if valid is None:
        valid = jnp.ones(pos_ids.shape, bool)
    if pos_ids.ndim == 1:
        num, den = _sampled_softmax_chunk(user_vec, pos_ids, neg_ids, table,
                                          valid)
        return num / jnp.maximum(den, 1.0)
    s = pos_ids.shape[1]
    chunk = min(seq_chunk, s)
    if s % chunk:
        import math
        chunk = math.gcd(chunk, s)
    n = s // chunk

    @jax.checkpoint
    def one(args):
        uv, po, ne, va = args
        return _sampled_softmax_chunk(uv, po, ne, table, va)

    def body(carry, i):
        num, den = carry
        sl = lambda x: jax.lax.dynamic_slice_in_dim(x, i * chunk, chunk, 1)
        dn, dd = one((sl(user_vec), sl(pos_ids), sl(neg_ids), sl(valid)))
        return (num + dn, den + dd), None

    (num, den), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())),
                                 jnp.arange(n, dtype=jnp.int32))
    return num / jnp.maximum(den, 1.0)


def _constrain(x, batch_axes, *rest):
    if not batch_axes and not any(rest):
        return x
    from jax.sharding import PartitionSpec
    spec = [batch_axes if batch_axes else None] + \
        [r if r else None for r in rest]
    return jax.lax.with_sharding_constraint(x, PartitionSpec(*spec))


def iterative_topk(scores: Array, k: int):
    """Exact top-k WITHOUT sort: k rounds of (max, argmax, mask).

    XLA's SPMD partitioner all-gathers the batch dimension for Sort/TopK
    (measured: a 7.8 GiB gather at serve_bulk scale), but max/argmax/
    where are batch-parallel — so for k << M this is the partition-safe
    form.  cost: k * O(M) reductions.
    """
    m = scores.shape[-1]
    iota = jnp.arange(m, dtype=jnp.int32)

    def body(sc, _):
        v = sc.max(axis=-1)
        a = sc.argmax(axis=-1).astype(jnp.int32)
        sc = jnp.where(iota == a[..., None], -jnp.inf, sc)
        return sc, (v, a)

    _, (vals, ids) = jax.lax.scan(body, scores, None, length=k)
    return (jnp.moveaxis(vals, 0, -1), jnp.moveaxis(ids, 0, -1))


def retrieval_topk(user_vec: Array, cand_table: Array, k: int = 100,
                   chunk: int = 8192, batch_axes: tuple = (),
                   tp_axis: str = ""):
    """Score [B] queries against C candidate rows: batched dot + top-k.

    Small tables: one dot + exact top-k.  Large tables (sharded serving):
    a SORT-FREE two-phase pipeline --
      1. scan candidate slabs (table viewed [n_chunks, chunk, d]; for a
         row-sharded table this is a relabeling, not a reshuffle) and
         keep k BUCKET MAXIMA per chunk -- reductions only, so every step
         stays batch-sharded (lax.top_k here would all-gather the whole
         [B, chunk] score matrix: 7.8 GiB/step measured on serve_bulk);
      2. one iterative exact top-k over the n_chunks*k bucket maxima.
    Result is bucketed-approximate overall (one winner per bucket --
    the same scheme as TPU approx_max_k); recall@k is tested in
    tests/test_models.py.
    """
    c = cand_table.shape[0]
    if c <= chunk:
        scores = _constrain((user_vec @ cand_table.T).astype(jnp.float32),
                            batch_axes, None)
        if batch_axes:
            return iterative_topk(scores, k)
        return jax.lax.top_k(scores, k)
    n = -(-c // chunk)
    chunk = c // n
    while c % chunk:
        n += 1
        chunk = c // n
    n = c // chunk
    kb = min(k, chunk)
    width = -(-chunk // kb)
    pad = kb * width - chunk
    slabs = cand_table.reshape(n, chunk, cand_table.shape[-1])
    slabs = _constrain(slabs, None, tp_axis, None)

    def body(_, xs):
        ci, tc = xs                                     # tc [chunk, d]
        sc = (user_vec @ tc.T).astype(jnp.float32)      # [..., chunk]
        sc = _constrain(sc, batch_axes, None)
        scp = jnp.pad(sc, [(0, 0)] * (sc.ndim - 1) + [(0, pad)],
                      constant_values=-jnp.inf)
        b = scp.reshape(sc.shape[:-1] + (kb, width))
        v = b.max(axis=-1)                              # [..., kb]
        a = b.argmax(axis=-1).astype(jnp.int32)
        ids = ci * chunk + jnp.arange(kb, dtype=jnp.int32) * width + a
        return None, (v, ids)

    _, (vs, ids) = jax.lax.scan(
        body, None, (jnp.arange(n, dtype=jnp.int32), slabs))
    # [n, ..., kb] -> [..., n*kb]
    flat_v = jnp.moveaxis(vs, 0, -2).reshape(vs.shape[1:-1] + (n * kb,))
    flat_i = jnp.moveaxis(ids, 0, -2).reshape(ids.shape[1:-1] + (n * kb,))
    flat_v = _constrain(flat_v, batch_axes, None)
    topv, sel = iterative_topk(flat_v, k)
    topi = jnp.take_along_axis(flat_i, sel, axis=-1)
    return topv, topi


# ---------------------------------------------------------------------------
# SASRec (arXiv:1808.09781)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SasRecConfig:
    name: str = "sasrec"
    n_items: int = 1_000_000
    embed_dim: int = 50
    n_blocks: int = 2
    n_heads: int = 1
    seq_len: int = 50
    n_negatives: int = 128
    dtype: Any = jnp.float32
    # GSPMD activation annotations (set by the cell builder on a mesh)
    batch_axes: tuple = ()
    tp_axis: str = ""


def init_sasrec(key, cfg: SasRecConfig) -> dict:
    ks = jax.random.split(key, 3)
    d = cfg.embed_dim

    def one_block(k):
        k1, k2, k3, k4, k5, k6 = jax.random.split(k, 6)
        return {
            "wq": dense_init(k1, d, d), "wk": dense_init(k2, d, d),
            "wv": dense_init(k3, d, d), "wo": dense_init(k4, d, d),
            "w1": dense_init(k5, d, d), "w2": dense_init(k6, d, d),
            "ln1_g": jnp.ones((d,), jnp.float32),
            "ln1_b": jnp.zeros((d,), jnp.float32),
            "ln2_g": jnp.ones((d,), jnp.float32),
            "ln2_b": jnp.zeros((d,), jnp.float32),
        }

    return {
        "item_emb": embed_init(ks[0], padded_rows(cfg.n_items), d),
        "pos_emb": embed_init(ks[1], cfg.seq_len, d),
        "blocks": jax.vmap(one_block)(jax.random.split(ks[2], cfg.n_blocks)),
    }


def _causal_attn(q, k, v, n_heads):
    b, s, d = q.shape
    hd = d // n_heads
    qh = q.reshape(b, s, n_heads, hd).transpose(0, 2, 1, 3)
    kh = k.reshape(b, s, n_heads, hd).transpose(0, 2, 1, 3)
    vh = v.reshape(b, s, n_heads, hd).transpose(0, 2, 1, 3)
    sc = jnp.einsum("bhqd,bhkd->bhqk", qh, kh) / (hd ** 0.5)
    m = jnp.tril(jnp.ones((s, s), bool))
    sc = jnp.where(m, sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, vh)
    return o.transpose(0, 2, 1, 3).reshape(b, s, d)


def sasrec_hidden(params: dict, cfg: SasRecConfig, hist: Array) -> Array:
    """hist i32[B,S] (0 = padding item) -> hidden [B,S,d]."""
    b, s = hist.shape
    h = params["item_emb"][hist] + params["pos_emb"][None, :s]
    pad = (hist == 0)[..., None]
    h = jnp.where(pad, 0.0, h)

    def body(h, blk):
        hn = layer_norm(h, blk["ln1_g"], blk["ln1_b"])
        a = _causal_attn(hn @ blk["wq"], hn @ blk["wk"], hn @ blk["wv"],
                         cfg.n_heads) @ blk["wo"]
        h = h + a
        hn = layer_norm(h, blk["ln2_g"], blk["ln2_b"])
        h = h + jax.nn.relu(hn @ blk["w1"]) @ blk["w2"]
        h = jnp.where(pad, 0.0, h)
        return h, None

    h, _ = jax.lax.scan(jax.checkpoint(body), h, params["blocks"])
    return h


def sasrec_loss(params: dict, cfg: SasRecConfig, batch: dict) -> Array:
    """batch: hist [B,S], pos [B,S] (next item), neg [B,S,K]."""
    h = sasrec_hidden(params, cfg, batch["hist"])
    valid = batch["pos"] != 0
    return sampled_softmax_loss(h, batch["pos"], batch["neg"],
                                params["item_emb"], valid)


def sasrec_user_vec(params: dict, cfg: SasRecConfig, hist: Array) -> Array:
    return sasrec_hidden(params, cfg, hist)[:, -1, :]


# ---------------------------------------------------------------------------
# BERT4Rec (arXiv:1904.06690)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Bert4RecConfig:
    name: str = "bert4rec"
    n_items: int = 1_000_000
    embed_dim: int = 64
    n_blocks: int = 2
    n_heads: int = 2
    seq_len: int = 200
    n_negatives: int = 128
    dtype: Any = jnp.float32
    # GSPMD activation annotations (set by the cell builder on a mesh)
    batch_axes: tuple = ()
    tp_axis: str = ""


def init_bert4rec(key, cfg: Bert4RecConfig) -> dict:
    sas = SasRecConfig(n_items=cfg.n_items + 1,  # +1: [MASK] token
                       embed_dim=cfg.embed_dim, n_blocks=cfg.n_blocks,
                       n_heads=cfg.n_heads, seq_len=cfg.seq_len)
    return init_sasrec(key, sas)    # init pads rows (padded_rows)


def bert4rec_hidden(params: dict, cfg: Bert4RecConfig, hist: Array) -> Array:
    """Bidirectional encoder (no causal mask)."""
    b, s = hist.shape
    h = params["item_emb"][hist] + params["pos_emb"][None, :s]
    pad = (hist == 0)[..., None]
    h = jnp.where(pad, 0.0, h)
    d = cfg.embed_dim

    def body(h, blk):
        hn = layer_norm(h, blk["ln1_g"], blk["ln1_b"])
        q, k, v = hn @ blk["wq"], hn @ blk["wk"], hn @ blk["wv"]
        hd = d // cfg.n_heads
        qh = q.reshape(b, s, cfg.n_heads, hd).transpose(0, 2, 1, 3)
        kh = k.reshape(b, s, cfg.n_heads, hd).transpose(0, 2, 1, 3)
        vh = v.reshape(b, s, cfg.n_heads, hd).transpose(0, 2, 1, 3)
        sc = jnp.einsum("bhqd,bhkd->bhqk", qh, kh) / (hd ** 0.5)
        sc = jnp.where(pad[:, None, None, :, 0], -1e30, sc)  # mask pad keys
        p = jax.nn.softmax(sc, axis=-1)
        a = jnp.einsum("bhqk,bhkd->bhqd", p, vh)
        a = a.transpose(0, 2, 1, 3).reshape(b, s, d) @ blk["wo"]
        h = h + a
        hn = layer_norm(h, blk["ln2_g"], blk["ln2_b"])
        h = h + jax.nn.gelu(hn @ blk["w1"]) @ blk["w2"]
        return h, None

    h, _ = jax.lax.scan(jax.checkpoint(body), h, params["blocks"])
    return h


def bert4rec_loss(params: dict, cfg: Bert4RecConfig, batch: dict) -> Array:
    """Cloze objective: batch hist has [MASK]=n_items at masked slots;
    targets [B,S] hold the true item there (0 elsewhere); neg [B,S,K]."""
    h = bert4rec_hidden(params, cfg, batch["hist"])
    valid = batch["targets"] != 0
    return sampled_softmax_loss(h, batch["targets"], batch["neg"],
                                params["item_emb"], valid)


def bert4rec_user_vec(params: dict, cfg: Bert4RecConfig,
                      hist: Array) -> Array:
    """Serve path: [MASK] appended at the last position scores next item."""
    return bert4rec_hidden(params, cfg, hist)[:, -1, :]


# ---------------------------------------------------------------------------
# DIEN (arXiv:1809.03672)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DienConfig:
    name: str = "dien"
    n_items: int = 1_000_000
    embed_dim: int = 18
    seq_len: int = 100
    gru_dim: int = 108
    mlp_dims: tuple = (200, 80)
    n_negatives: int = 8
    use_aux_loss: bool = True
    dtype: Any = jnp.float32
    # GSPMD activation annotations (set by the cell builder on a mesh)
    batch_axes: tuple = ()
    tp_axis: str = ""


def init_dien(key, cfg: DienConfig) -> dict:
    ks = jax.random.split(key, 6)
    d, g = cfg.embed_dim, cfg.gru_dim
    return {
        "item_emb": embed_init(ks[0], padded_rows(cfg.n_items), d),
        "gru1": init_gru(ks[1], d, g),
        "gru2": init_gru(ks[2], g, g),           # AUGRU (att-gated)
        "att_w": dense_init(ks[3], g + d, 1),
        "aux_w": dense_init(ks[4], g, d),
        "mlp": init_mlp(ks[5], (g + 2 * d,) + tuple(cfg.mlp_dims) + (1,)),
    }


def dien_forward(params: dict, cfg: DienConfig, hist: Array,
                 target: Array):
    """hist i32[B,S], target i32[B] -> (logit [B], interest states)."""
    b, s = hist.shape
    e = params["item_emb"][hist]                           # [B,S,d]
    t_e = params["item_emb"][target]                       # [B,d]
    h0 = jnp.zeros((b, cfg.gru_dim), jnp.float32)
    _, states = gru_scan(params["gru1"], e, h0)            # [B,S,g]

    att_in = jnp.concatenate(
        [states, jnp.broadcast_to(t_e[:, None], (b, s, cfg.embed_dim))],
        axis=-1)
    att = jax.nn.softmax(
        (att_in @ params["att_w"])[..., 0] +
        jnp.where(hist == 0, -1e30, 0.0), axis=-1)         # [B,S]
    final, _ = gru_scan(params["gru2"], states, h0, atts=att)

    feats = jnp.concatenate([final, t_e, (e * att[..., None]).sum(1)],
                            axis=-1)
    logit = mlp(params["mlp"], feats)[:, 0]
    return logit, states, e


def dien_loss(params: dict, cfg: DienConfig, batch: dict) -> Array:
    """batch: hist [B,S], target [B], label f32[B], aux_neg [B,S]."""
    logit, states, e = dien_forward(params, cfg, batch["hist"],
                                    batch["target"])
    loss = _bce(logit, batch["label"])
    if cfg.use_aux_loss and "aux_neg" in batch:
        # auxiliary loss (DIEN §4.2): h_t should predict e_{t+1} vs a neg
        h_proj = states[:, :-1] @ params["aux_w"]          # [B,S-1,d]
        pos_e = e[:, 1:]
        neg_e = params["item_emb"][batch["aux_neg"][:, 1:]]
        valid = (batch["hist"][:, 1:] != 0).astype(jnp.float32)
        pos_l = jax.nn.log_sigmoid((h_proj * pos_e).sum(-1))
        neg_l = jax.nn.log_sigmoid(-(h_proj * neg_e).sum(-1))
        aux = -((pos_l + neg_l) * valid).sum() / jnp.maximum(valid.sum(), 1.)
        loss = loss + aux
    return loss


def dien_user_vec(params: dict, cfg: DienConfig, hist: Array) -> Array:
    b, s = hist.shape
    e = params["item_emb"][hist]
    h0 = jnp.zeros((b, cfg.gru_dim), jnp.float32)
    _, states = gru_scan(params["gru1"], e, h0)
    return states[:, -1] @ params["aux_w"]                 # project to d


def _bce(logit: Array, label: Array) -> Array:
    return -(label * jax.nn.log_sigmoid(logit) +
             (1 - label) * jax.nn.log_sigmoid(-logit)).mean()


# ---------------------------------------------------------------------------
# xDeepFM (arXiv:1803.05170)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class XDeepFmConfig:
    name: str = "xdeepfm"
    n_fields: int = 39
    field_vocab: int = 1_000_000
    embed_dim: int = 10
    cin_layers: tuple = (200, 200, 200)
    mlp_dims: tuple = (400, 400)
    n_hot: int = 1              # multi-hot arity (>1 -> EmbeddingBag path)
    dtype: Any = jnp.float32
    # GSPMD activation annotations (set by the cell builder on a mesh)
    batch_axes: tuple = ()
    tp_axis: str = ""


def init_xdeepfm(key, cfg: XDeepFmConfig) -> dict:
    ks = jax.random.split(key, 5)
    f, v, d = cfg.n_fields, cfg.field_vocab, cfg.embed_dim
    cin_ws = []
    h_prev = f
    kcin = jax.random.split(ks[1], len(cfg.cin_layers))
    for hk, k in zip(cfg.cin_layers, kcin):
        cin_ws.append(dense_init(k, h_prev * f, hk))       # [Hk-1*F, Hk]
        h_prev = hk
    rows = padded_rows(f * v)
    return {
        "tables": embed_init(ks[0], rows, d),              # [F*V, d] fused
        "linear": jnp.zeros((rows,), jnp.float32),         # 1st-order term
        "cin": cin_ws,
        "mlp": init_mlp(ks[2], (f * d,) + tuple(cfg.mlp_dims) + (1,)),
        "cin_out": dense_init(ks[3], sum(cfg.cin_layers), 1),
        "bias": jnp.zeros((), jnp.float32),
    }


def _xdeepfm_embed(params: dict, cfg: XDeepFmConfig, sparse: Array) -> tuple:
    """sparse i32[B, F] (or [B, F, H] multi-hot) -> e [B,F,d], linear [B]."""
    f, v = cfg.n_fields, cfg.field_vocab
    field_off = (jnp.arange(f, dtype=jnp.int32) * v)
    if sparse.ndim == 2:
        ids = sparse + field_off[None, :]
        e = params["tables"][ids]                          # [B,F,d]
        lin = params["linear"][ids].sum(-1)                # [B]
    else:                                                  # multi-hot bags
        ids = sparse + field_off[None, :, None]
        b, ff, hh = ids.shape
        flat = ids.reshape(b * ff, hh)
        bag = segments.embedding_bag(
            params["tables"], flat.reshape(-1),
            jnp.arange(0, b * ff * hh + 1, hh, dtype=jnp.int32))
        e = bag.reshape(b, ff, -1)
        lin = params["linear"][ids].sum((-1, -2))
    return e, lin


def xdeepfm_logit(params: dict, cfg: XDeepFmConfig, sparse: Array) -> Array:
    e, lin = _xdeepfm_embed(params, cfg, sparse)           # [B,F,d]
    b, f, d = e.shape

    # CIN: x^{k+1}_h = sum_ij W^k_{ij,h} (x^k_i * x^0_j)
    xk = e
    pooled = []
    for w in params["cin"]:
        z = jnp.einsum("bid,bjd->bijd", xk, e)             # [B,Hk,F,d]
        z = z.reshape(b, -1, d)                            # [B,Hk*F,d]
        xk = jnp.einsum("bpd,ph->bhd", z, w)               # [B,Hk+1,d]
        pooled.append(xk.sum(-1))                          # [B,Hk+1]
    cin_feat = jnp.concatenate(pooled, axis=-1)
    cin_term = (cin_feat @ params["cin_out"])[:, 0]

    dnn_term = mlp(params["mlp"], e.reshape(b, f * d))[:, 0]
    return lin + cin_term + dnn_term + params["bias"]


def xdeepfm_loss(params: dict, cfg: XDeepFmConfig, batch: dict) -> Array:
    logit = xdeepfm_logit(params, cfg, batch["sparse"])
    return _bce(logit, batch["label"])


def xdeepfm_user_vec(params: dict, cfg: XDeepFmConfig,
                     sparse: Array) -> Array:
    """Two-tower retrieval head: mean field embedding as the user vector."""
    e, _ = _xdeepfm_embed(params, cfg, sparse)
    return e.mean(axis=1)
