"""Shared neural building blocks (pure-JAX, pytree params, no flax).

Conventions:
  * params are nested dicts of jnp arrays; ``init_*`` builds them from a
    jax.random key, ``apply``-style functions are pure.
  * master params are fp32; matmuls run in ``compute_dtype`` (bf16 on
    TPU) via ``cast`` at use sites.
"""
from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp

Array = jax.Array


def dense_init(key, d_in: int, d_out: int, scale: float | None = None,
               dtype=jnp.float32) -> Array:
    s = scale if scale is not None else (1.0 / math.sqrt(d_in))
    return jax.random.normal(key, (d_in, d_out), dtype) * s


def embed_init(key, vocab: int, d: int, dtype=jnp.float32) -> Array:
    return jax.random.normal(key, (vocab, d), dtype) * 0.02


def cast(x: Array, dtype) -> Array:
    return x.astype(dtype) if x.dtype != dtype else x


def rms_norm(x: Array, gamma: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + gamma.astype(jnp.float32))
    return out.astype(dt)


def layer_norm(x: Array, gamma: Array, beta: Array,
               eps: float = 1e-6) -> Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * gamma.astype(jnp.float32) + beta.astype(jnp.float32)
    return out.astype(dt)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, base: float) -> Array:
    exps = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (base ** exps)                     # [head_dim/2]


def apply_rope(x: Array, positions: Array, base: float = 10_000.0) -> Array:
    """x [..., S, D] (D even), positions [..., S] -> rotated x."""
    d = x.shape[-1]
    freqs = rope_freqs(d, base)                     # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, D/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_swiglu(key, d_model: int, d_ff: int) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff),
        "w_up": dense_init(k2, d_model, d_ff),
        "w_down": dense_init(k3, d_ff, d_model),
    }


def swiglu(params: dict, x: Array, dtype=jnp.bfloat16) -> Array:
    xg = cast(x, dtype)
    g = xg @ cast(params["w_gate"], dtype)
    u = xg @ cast(params["w_up"], dtype)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(dtype) * u
    return (h @ cast(params["w_down"], dtype)).astype(x.dtype)


def init_mlp(key, sizes: Sequence[int], bias: bool = True) -> dict:
    keys = jax.random.split(key, len(sizes) - 1)
    layers = []
    for i, k in enumerate(keys):
        layer = {"w": dense_init(k, sizes[i], sizes[i + 1])}
        if bias:
            layer["b"] = jnp.zeros((sizes[i + 1],), jnp.float32)
        layers.append(layer)
    return {"layers": layers}


def mlp(params: dict, x: Array, act=jax.nn.relu, final_act: bool = False,
        dtype=jnp.float32) -> Array:
    n = len(params["layers"])
    h = cast(x, dtype)
    for i, layer in enumerate(params["layers"]):
        h = h @ cast(layer["w"], dtype)
        if "b" in layer:
            h = h + cast(layer["b"], dtype)
        if i < n - 1 or final_act:
            h = act(h)
    return h


# ---------------------------------------------------------------------------
# GRU / AUGRU (DIEN)
# ---------------------------------------------------------------------------


def init_gru(key, d_in: int, d_hidden: int) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_x": dense_init(k1, d_in, 3 * d_hidden),
        "w_h": dense_init(k2, d_hidden, 3 * d_hidden),
        "b": jnp.zeros((3 * d_hidden,), jnp.float32),
    }


def gru_cell(params: dict, h: Array, x: Array,
             att: Array | None = None) -> Array:
    """One GRU step; ``att`` (AUGRU) scales the update gate (DIEN §4.3)."""
    d = h.shape[-1]
    gates = x @ params["w_x"][:, :2 * d] + h @ params["w_h"][:, :2 * d] + \
        params["b"][:2 * d]
    r, z = jnp.split(gates, 2, axis=-1)
    r = jax.nn.sigmoid(r)
    z = jax.nn.sigmoid(z)
    # candidate: n = tanh(W_nx x + (r * h) W_nh + b_n)
    n = jnp.tanh(x @ params["w_x"][:, 2 * d:] +
                 (r * h) @ params["w_h"][:, 2 * d:] + params["b"][2 * d:])
    if att is not None:
        z = z * att[..., None]
    return (1.0 - z) * n + z * h


def gru_scan(params: dict, xs: Array, h0: Array,
             atts: Array | None = None) -> tuple[Array, Array]:
    """xs [B, S, d_in] -> (final h [B, d], all h [B, S, d])."""
    def step(h, inp):
        if atts is None:
            x = inp
            h2 = gru_cell(params, h, x)
        else:
            x, a = inp
            h2 = gru_cell(params, h, x, a)
        return h2, h2
    xs_t = jnp.swapaxes(xs, 0, 1)                   # [S, B, d]
    inputs = xs_t if atts is None else (xs_t, jnp.swapaxes(atts, 0, 1))
    hT, hs = jax.lax.scan(step, h0, inputs)
    return hT, jnp.swapaxes(hs, 0, 1)
