from repro.models import attention, gnn, layers, recsys, transformer  # noqa: F401
