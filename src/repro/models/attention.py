"""Attention variants: chunked (train/prefill), decode (KV cache), MLA.

``chunked_attention`` is the memory-bounded XLA path used by every
transformer config: a lax.scan over query chunks so no S×S score matrix
is ever materialized — this is what makes the 32k-prefill dry-run fit
and is fully GSPMD-partitionable (batch/heads sharded; scores reduce
over the full K which XLA turns into local compute + collectives when K
is sequence-sharded).  The Pallas flash kernel (kernels/flash_attention)
is the TPU fast path validated against the same semantics.

``decode_attention`` runs one new token against a [B, Hkv, S, D] cache;
with the cache sequence-sharded over the mesh the softmax reductions
become the split-K (flash-decoding) pattern — XLA inserts the small
all-reduces over (max, sum, weighted-V) automatically.

MLA (DeepSeek-V2 / MiniCPM3): latent-compressed KV.  Prefill expands the
latent; decode uses the ABSORBED form — scores are taken directly
against the latent cache, so cache bytes per token are (kv_lora + rope)
instead of 2·H·D: a 10-20× KV-cache reduction, which is exactly the
paper-style layout-vs-I/O tradeoff applied to attention state.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, cast, dense_init, rms_norm

Array = jax.Array

NEG_INF = -1e30


def _mask(qpos: Array, kpos: Array, causal: bool, window) -> Array:
    """``window`` may be a python int OR a traced scalar (per-layer)."""
    qp = qpos[..., :, None]
    kp = kpos[..., None, :]
    m = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), jnp.bool_)
    if causal:
        m &= kp <= qp
    w = jnp.asarray(window, jnp.int32)
    m &= (w <= 0) | (kp > qp - w)
    return m


def chunked_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                      window=0, chunk: int = 512,
                      remat: bool = True) -> Array:
    """q [B,Hq,S,Dk], k [B,Hkv,S,Dk], v [B,Hkv,S,Dv] -> [B,Hq,S,Dv].

    GQA via head groups; Dk may differ from Dv (MLA).  ``window`` may be
    a traced per-layer scalar (gemma3's local/global interleave).
    """
    b, hq, s, d = q.shape
    dv = v.shape[-1]
    hkv = k.shape[1]
    group = hq // hkv
    scale = d ** -0.5
    chunk = min(chunk, s)
    if s % chunk:
        import math
        chunk = math.gcd(chunk, s)   # fallback for odd test lengths
    nchunks = s // chunk
    kpos = jnp.arange(s, dtype=jnp.int32)

    kg = k.reshape(b, hkv, 1, s, d)
    vg = v.reshape(b, hkv, 1, s, dv)

    def one_chunk(ci, qc):
        # qc [B, Hq, chunk, D]
        qpos = ci * chunk + jnp.arange(chunk, dtype=jnp.int32)
        qcg = qc.reshape(b, hkv, group, chunk, d)
        scores = jnp.einsum("bhgqd,bhgkd->bhgqk", qcg.astype(jnp.float32),
                            jnp.broadcast_to(kg, (b, hkv, group, s, d)
                                             ).astype(jnp.float32)) * scale
        m = _mask(qpos, kpos, causal, window)
        scores = jnp.where(m, scores, NEG_INF)
        p = jax.nn.softmax(scores, axis=-1)
        oc = jnp.einsum("bhgqk,bhgkd->bhgqd", p.astype(v.dtype),
                        jnp.broadcast_to(vg, (b, hkv, group, s, dv)))
        return oc.reshape(b, hq, chunk, dv)

    if remat:
        one_chunk = jax.checkpoint(one_chunk, static_argnums=())

    def scan_body(_, ci):
        qc = jax.lax.dynamic_slice_in_dim(q, ci * chunk, chunk, axis=2)
        return None, one_chunk(ci, qc)

    _, outs = jax.lax.scan(scan_body, None,
                           jnp.arange(nchunks, dtype=jnp.int32))
    # outs [nchunks, B, Hq, chunk, Dv] -> [B, Hq, S, Dv]
    return jnp.moveaxis(outs, 0, 2).reshape(b, hq, s, dv)


def decode_attention(q: Array, k_cache: Array, v_cache: Array,
                     cache_len: Array, *, window=0) -> Array:
    """q [B,Hq,1,D] vs cache [B,Hkv,S,D]; keys at positions <= cache_len.

    ``window`` may be a traced scalar (per-layer local/global interleave).
    With the cache's S axis sharded over the mesh this is distributed
    split-K decode attention (XLA all-reduces the softmax stats).
    """
    b, hq, _, d = q.shape
    hkv, s = k_cache.shape[1], k_cache.shape[2]
    group = hq // hkv
    scale = d ** -0.5
    qg = q.reshape(b, hkv, group, d)
    # f32 ACCUMULATION without f32 operand casts: pre-casting k_cache
    # lets XLA hoist a full-stack bf16->f32 copy of the cache out of the
    # layer loop (measured: 3x 1.8 GiB buffers on mixtral-8x22b decode).
    scores = jnp.einsum("bhgd,bhsd->bhgs", qg, k_cache,
                        preferred_element_type=jnp.float32) * scale
    kpos = jnp.arange(s, dtype=jnp.int32)
    valid = kpos[None, :] <= cache_len[:, None]          # [B, S]
    w = jnp.asarray(window, jnp.int32)
    valid &= (w <= 0) | (kpos[None, :] > cache_len[:, None] - w)
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bhsd->bhgd", p.astype(v_cache.dtype), v_cache)
    return out.reshape(b, hq, 1, d)


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention)
# ---------------------------------------------------------------------------


class MlaDims(NamedTuple):
    n_heads: int
    q_lora: int
    kv_lora: int
    nope: int
    rope: int
    v_dim: int


def init_mla(key, d_model: int, dims: MlaDims) -> dict:
    ks = jax.random.split(key, 6)
    h, nope, rope, vd = dims.n_heads, dims.nope, dims.rope, dims.v_dim
    return {
        "w_dq": dense_init(ks[0], d_model, dims.q_lora),
        "q_norm": jnp.zeros((dims.q_lora,), jnp.float32),
        "w_uq": dense_init(ks[1], dims.q_lora, h * (nope + rope)),
        "w_dkv": dense_init(ks[2], d_model, dims.kv_lora),
        "kv_norm": jnp.zeros((dims.kv_lora,), jnp.float32),
        "w_ukv": dense_init(ks[3], dims.kv_lora, h * (nope + vd)),
        "w_kr": dense_init(ks[4], d_model, rope),
        "w_o": dense_init(ks[5], h * vd, d_model),
    }


def mla_qkv(params: dict, x: Array, positions: Array, dims: MlaDims,
            rope_base: float, dtype=jnp.bfloat16):
    """Expanded (prefill/train) projections.

    Returns q [B,H,S,nope+rope], k [B,H,S,nope+rope], v [B,H,S,vd],
    plus the latent (c_kv, k_rope) pair for cache writing.
    """
    b, s, _ = x.shape
    h, nope, rope, vd = dims.n_heads, dims.nope, dims.rope, dims.v_dim
    xg = cast(x, dtype)
    cq = rms_norm(xg @ cast(params["w_dq"], dtype), params["q_norm"])
    q = (cq @ cast(params["w_uq"], dtype)).reshape(b, s, h, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope.transpose(0, 2, 1, 3), positions[:, None, :],
                        rope_base).transpose(0, 2, 1, 3)
    q = jnp.concatenate([q_nope, q_rope], axis=-1).transpose(0, 2, 1, 3)

    c_kv = rms_norm(xg @ cast(params["w_dkv"], dtype), params["kv_norm"])
    kv = (c_kv @ cast(params["w_ukv"], dtype)).reshape(b, s, h, nope + vd)
    k_nope, v = kv[..., :nope], kv[..., nope:]
    k_rope = apply_rope((xg @ cast(params["w_kr"], dtype))[:, None, :, :],
                        positions[:, None, :], rope_base)  # [B,1,S,rope]
    k = jnp.concatenate(
        [k_nope.transpose(0, 2, 1, 3),
         jnp.broadcast_to(k_rope, (b, h, s, rope))], axis=-1)
    return q, k, v.transpose(0, 2, 1, 3), c_kv, k_rope[:, 0]


def mla_decode(params: dict, x: Array, c_cache: Array, kr_cache: Array,
               cache_len: Array, dims: MlaDims, rope_base: float,
               dtype=jnp.bfloat16) -> Array:
    """Absorbed-form decode: score against the LATENT cache directly.

    x [B,1,d_model]; c_cache [B,S,kv_lora]; kr_cache [B,S,rope].
    Cache already contains this step's latent at position cache_len.
    """
    b, _, d_model = x.shape
    h, nope, rope, vd = dims.n_heads, dims.nope, dims.rope, dims.v_dim
    kv_lora = dims.kv_lora
    s = c_cache.shape[1]
    scale = (nope + rope) ** -0.5

    xg = cast(x, dtype)
    cq = rms_norm(xg @ cast(params["w_dq"], dtype), params["q_norm"])
    q = (cq @ cast(params["w_uq"], dtype)).reshape(b, h, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope[:, :, None, :], cache_len[:, None, None],
                        rope_base)[:, :, 0, :]

    w_ukv = params["w_ukv"].reshape(kv_lora, h, nope + vd)
    w_uk = cast(w_ukv[..., :nope], dtype)               # [kv_lora, H, nope]
    w_uv = cast(w_ukv[..., nope:], dtype)               # [kv_lora, H, vd]

    # absorb: q_eff[b,h,c] = sum_n q_nope[b,h,n] * w_uk[c,h,n]
    q_eff = jnp.einsum("bhn,chn->bhc", q_nope, w_uk)
    # f32 accumulate, bf16 operands (avoids hoisted f32 cache copies)
    scores = jnp.einsum("bhc,bsc->bhs", q_eff, c_cache,
                        preferred_element_type=jnp.float32)
    scores += jnp.einsum("bhr,bsr->bhs", q_rope, kr_cache,
                         preferred_element_type=jnp.float32)
    scores *= scale
    kpos = jnp.arange(s, dtype=jnp.int32)
    valid = kpos[None, :] <= cache_len[:, None]
    scores = jnp.where(valid[:, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    lat = jnp.einsum("bhs,bsc->bhc", p.astype(c_cache.dtype), c_cache)
    out = jnp.einsum("bhc,chv->bhv", lat, w_uv).reshape(b, 1, h * vd)
    return (out @ cast(params["w_o"], dtype)).astype(x.dtype)
