"""Distributed index engine: document- vs term-partitioned sharding.

The paper's index is a single-node PSQL database; at cluster scale an
index shards one of two ways, and the choice decides the collective
pattern (this is the multi-pod story for the paper's own workload):

  * DOCUMENT-partitioned (``DocShardedIndex``): each shard holds the
    full vocabulary over a slice of documents.  A query broadcasts to
    all shards (cheap: a few u32 hashes), every shard evaluates
    q_word/q_occ/q_doc locally over its CSR slice, and the global
    answer is a distributed top-k merge (all-gather of k candidates per
    shard).  Collective bytes ~ S·k·8 per query — independent of corpus
    size.  This is how every production engine shards, and the ``pod``
    axis document-partitions across pods.

  * TERM-partitioned (``TermShardedIndex``): each shard owns a hash
    range of the vocabulary (whole posting lists).  A query touches only
    the shards owning its terms, but per-document partial scores must be
    psum'd across shards: collective bytes ~ D·4 per query batch.  Wins
    only when queries are single-term or the document space is tiny —
    we implement both so the benchmark can show the crossover.

Both are shard_map programs over stacked, padded per-shard CSR arrays
(the paper's OR layout, sliced and re-packed per shard).

The fused engines make the compressed (delta+bit-packed) layout a
first-class citizen of EVERY distributed path: the term-sharded tier
re-compresses each vocab shard's posting lists
(``build_term_sharded_packed``), the doc-sharded serving tier stacks
packed — or mixed hor+packed — sealed segments
(``stack_segment_shards``), and the bulk doc-sharded tier re-compresses
each document slice (``build_doc_sharded_packed``), in every case
decoding blocks IN VMEM inside the fused kernel so only compressed
bytes cross HBM per shard — the paper's §4.3 layout-determines-I/O
argument at cluster scale.  Which bulk layout to build is itself a
measured decision: ``build_doc_sharded_fused`` runs the layout ladder
(explicit arg > ``size_model.LayoutCostModel`` policy > "hor").
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import layouts, segments
from repro.core.layouts import PostingsHost
from repro.core.query import dedup_query_hashes, idf as idf_fn
from repro.distributed.topk import local_topk_merge
from repro.distributed.shmap import shard_map

Array = jax.Array


# ---------------------------------------------------------------------------
# document-partitioned
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DocShardedIndex:
    """Stacked per-shard CSR arrays (leading dim = shard)."""
    sorted_hash: np.ndarray   # u32[S, W]      (vocab replicated per shard)
    df_local: np.ndarray      # i32[S, W]      per-shard document frequency
    df_global: np.ndarray     # i32[S, W]      global df (same every shard)
    offsets: np.ndarray       # i32[S, W+1]
    doc_ids: np.ndarray       # i32[S, Pmax]   LOCAL doc ids
    tfs: np.ndarray           # f32[S, Pmax]
    norm: np.ndarray          # f32[S, Dmax]
    doc_base: np.ndarray      # i32[S]         global id of local doc 0
    n_shards: int
    num_docs: int
    cap: int                  # max local posting length

    def device_arrays(self) -> dict:
        return {k: jnp.asarray(v) for k, v in dataclasses.asdict(self).items()
                if isinstance(v, np.ndarray)}


def build_doc_sharded(host: PostingsHost, n_shards: int) -> DocShardedIndex:
    order = np.argsort(host.term_hashes, kind="stable")
    sorted_hash = host.term_hashes[order]
    W = host.num_terms
    bounds = np.linspace(0, host.num_docs, n_shards + 1).astype(np.int64)
    term_of = np.repeat(np.arange(W, dtype=np.int64),
                        np.diff(host.offsets))

    sh_offsets, sh_docs, sh_tfs, sh_df = [], [], [], []
    dmax = int(np.max(np.diff(bounds)))
    cap = 0
    for s in range(n_shards):
        lo, hi = bounds[s], bounds[s + 1]
        m = (host.doc_ids >= lo) & (host.doc_ids < hi)
        t = term_of[m][np.argsort(term_of[m], kind="stable")]
        sel = np.argsort(term_of[m], kind="stable")
        docs = (host.doc_ids[m][sel] - lo).astype(np.int32)
        tfs = host.tfs[m][sel]
        df = np.bincount(t, minlength=W).astype(np.int32)
        # reorder terms into hash-sorted order (COR-style fused lookup)
        df_sorted = df[order]
        offs = np.zeros(W + 1, dtype=np.int64)
        np.cumsum(df_sorted, out=offs[1:])
        # postings re-packed in hash-sorted term order
        packed_docs = np.zeros(len(docs), np.int32)
        packed_tfs = np.zeros(len(docs), np.float32)
        src_offs = np.zeros(W + 1, dtype=np.int64)
        np.cumsum(df, out=src_offs[1:])
        for newpos, old in enumerate(order):
            a, bnd = src_offs[old], src_offs[old + 1]
            c = offs[newpos]
            packed_docs[c:c + bnd - a] = docs[a:bnd]
            packed_tfs[c:c + bnd - a] = tfs[a:bnd]
        sh_offsets.append(offs)
        sh_docs.append(packed_docs)
        sh_tfs.append(packed_tfs)
        sh_df.append(df_sorted)
        cap = max(cap, int(df_sorted.max()) if W else 0)

    pmax = max(len(x) for x in sh_docs)
    S = n_shards
    docs_a = np.zeros((S, pmax), np.int32)
    tfs_a = np.zeros((S, pmax), np.float32)
    offs_a = np.zeros((S, W + 1), np.int32)
    df_a = np.zeros((S, W), np.int32)
    norm_a = np.zeros((S, dmax), np.float32)
    for s in range(S):
        docs_a[s, :len(sh_docs[s])] = sh_docs[s]
        tfs_a[s, :len(sh_tfs[s])] = sh_tfs[s]
        offs_a[s] = sh_offsets[s]
        df_a[s] = sh_df[s]
        lo, hi = bounds[s], bounds[s + 1]
        norm_a[s, :hi - lo] = host.norm[lo:hi]
    df_glob = np.broadcast_to(host.df[order][None, :], (S, W)).copy()
    return DocShardedIndex(
        sorted_hash=np.broadcast_to(sorted_hash[None, :], (S, W)).copy(),
        df_local=df_a, df_global=df_glob.astype(np.int32),
        offsets=offs_a, doc_ids=docs_a, tfs=tfs_a, norm=norm_a,
        doc_base=bounds[:-1].astype(np.int32), n_shards=S,
        num_docs=host.num_docs, cap=cap)


def make_doc_sharded_scorer(index: DocShardedIndex, mesh: Mesh, axis: str,
                            k: int = 10):
    """jit fn(query_hashes u32[T]) -> (scores[k], global doc ids[k])."""
    arrs = index.device_arrays()
    cap = max(index.cap, 1)
    dmax = arrs["norm"].shape[1]
    num_docs = index.num_docs

    sharded = {n: P(axis) for n in
               ("sorted_hash", "df_local", "df_global", "offsets",
                "doc_ids", "tfs", "norm", "doc_base")}

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(sharded, P()), out_specs=(P(), P()), check_vma=False)
    def score(ix, qh):
        sq = {n: v[0] for n, v in ix.items()}    # drop shard dim
        qh = dedup_query_hashes(qh)
        pos = jnp.searchsorted(sq["sorted_hash"], qh).astype(jnp.int32)
        pos = jnp.clip(pos, 0, sq["sorted_hash"].shape[0] - 1)
        hit = (sq["sorted_hash"][pos] == qh) & (qh != 0)
        tid = jnp.where(hit, pos, -1)
        # idf uses GLOBAL df — scoring must match the single-node engine
        df_g = jnp.where(hit, sq["df_global"][pos], 0)
        w = idf_fn(df_g, num_docs)
        safe = jnp.maximum(tid, 0)
        d, v = segments.gather_segments(sq["doc_ids"], sq["offsets"], safe,
                                        cap, fill=-1)
        t, _ = segments.gather_segments(sq["tfs"], sq["offsets"], safe, cap,
                                        fill=0.0)
        valid = v & (tid >= 0)[:, None]
        weights = t * w[:, None]
        flat_d = jnp.where(valid, d, dmax).reshape(-1)
        acc = jnp.zeros((dmax + 1,), jnp.float32)
        acc = acc.at[flat_d].add(jnp.where(valid, weights, 0.0).reshape(-1),
                                 mode="drop")
        scores = acc[:dmax]
        qnorm = jnp.sqrt(jnp.maximum(jnp.sum(w * w), 1e-12))
        live = sq["norm"] > 0
        final = jnp.where(live & (scores > 0),
                          scores / (jnp.maximum(sq["norm"], 1e-12) * qnorm),
                          -jnp.inf)
        vv, ids = local_topk_merge(final, k, axis, sq["doc_base"])
        return vv, ids

    return jax.jit(lambda qh: score(arrs, qh))


# ---------------------------------------------------------------------------
# term-partitioned
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TermShardedIndex:
    sorted_hash: np.ndarray  # u32[S, Wmax]  (hash-range partition, padded)
    df: np.ndarray           # i32[S, Wmax]
    offsets: np.ndarray      # i32[S, Wmax+1]
    doc_ids: np.ndarray      # i32[S, Pmax]  GLOBAL doc ids
    tfs: np.ndarray          # f32[S, Pmax]
    norm: np.ndarray         # f32[D] (replicated)
    n_shards: int
    num_docs: int
    cap: int

    def device_arrays(self) -> dict:
        return {k: jnp.asarray(v) for k, v in dataclasses.asdict(self).items()
                if isinstance(v, np.ndarray)}


def build_term_sharded(host: PostingsHost, n_shards: int) -> TermShardedIndex:
    order = np.argsort(host.term_hashes, kind="stable")
    W = host.num_terms
    # contiguous hash-range partition of the sorted vocabulary
    bounds = np.linspace(0, W, n_shards + 1).astype(np.int64)
    wmax = int(np.max(np.diff(bounds)))
    sh = []
    pmax = 0
    for s in range(n_shards):
        terms = order[bounds[s]:bounds[s + 1]]
        lens = (host.offsets[terms + 1] - host.offsets[terms]).astype(np.int64)
        offs = np.zeros(wmax + 1, dtype=np.int64)
        np.cumsum(lens, out=offs[1:len(lens) + 1])
        offs[len(lens) + 1:] = offs[len(lens)]
        total = int(offs[len(lens)])
        docs = np.zeros(total, np.int32)
        tfs = np.zeros(total, np.float32)
        for i, t in enumerate(terms):
            a, bnd = host.offsets[t], host.offsets[t + 1]
            docs[offs[i]:offs[i + 1]] = host.doc_ids[a:bnd]
            tfs[offs[i]:offs[i + 1]] = host.tfs[a:bnd]
        hashes = np.full(wmax, 0xFFFFFFFF, np.uint32)
        hashes[:len(terms)] = host.term_hashes[terms]
        dfs = np.zeros(wmax, np.int32)
        dfs[:len(terms)] = host.df[terms]
        sh.append((hashes, dfs, offs, docs, tfs))
        pmax = max(pmax, total)
    S = n_shards
    out = TermShardedIndex(
        sorted_hash=np.stack([x[0] for x in sh]),
        df=np.stack([x[1] for x in sh]),
        offsets=np.stack([x[2] for x in sh]).astype(np.int32),
        doc_ids=np.zeros((S, pmax), np.int32),
        tfs=np.zeros((S, pmax), np.float32),
        norm=host.norm, n_shards=S, num_docs=host.num_docs,
        cap=int(host.max_posting_len))
    for s, (_, _, _, docs, tfs) in enumerate(sh):
        out.doc_ids[s, :len(docs)] = docs
        out.tfs[s, :len(tfs)] = tfs
    return out


def make_term_sharded_scorer(index: TermShardedIndex, mesh: Mesh, axis: str,
                             k: int = 10):
    arrs = index.device_arrays()
    cap = max(index.cap, 1)
    num_docs = index.num_docs

    sharded = {n: P(axis) for n in
               ("sorted_hash", "df", "offsets", "doc_ids", "tfs")}
    sharded["norm"] = P()

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(sharded, P()), out_specs=(P(), P()), check_vma=False)
    def score(ix, qh):
        sq = {n: (v[0] if n != "norm" else v) for n, v in ix.items()}
        qh = dedup_query_hashes(qh)
        pos = jnp.searchsorted(sq["sorted_hash"], qh).astype(jnp.int32)
        pos = jnp.clip(pos, 0, sq["sorted_hash"].shape[0] - 1)
        hit = (sq["sorted_hash"][pos] == qh) & (qh != 0)
        tid = jnp.where(hit, pos, -1)       # terms NOT on this shard miss
        df = jnp.where(hit, sq["df"][pos], 0)
        w = idf_fn(df, num_docs)
        safe = jnp.maximum(tid, 0)
        d, v = segments.gather_segments(sq["doc_ids"], sq["offsets"], safe,
                                        cap, fill=-1)
        t, _ = segments.gather_segments(sq["tfs"], sq["offsets"], safe, cap,
                                        fill=0.0)
        valid = v & (tid >= 0)[:, None]
        flat_d = jnp.where(valid, d, num_docs).reshape(-1)
        acc = jnp.zeros((num_docs + 1,), jnp.float32)
        acc = acc.at[flat_d].add(
            jnp.where(valid, t * w[:, None], 0.0).reshape(-1), mode="drop")
        partial = acc[:num_docs]
        # THE term-partitioned cost: a full [D] psum across shards
        scores = jax.lax.psum(partial, axis)
        qn2 = jax.lax.psum(jnp.sum(w * w), axis)
        qnorm = jnp.sqrt(jnp.maximum(qn2, 1e-12))
        live = sq["norm"] > 0
        final = jnp.where(live & (scores > 0),
                          scores / (jnp.maximum(sq["norm"], 1e-12) * qnorm),
                          -jnp.inf)
        vv, ii = jax.lax.top_k(final, k)
        return vv, ii

    return jax.jit(lambda qh: score(arrs, qh))


# ---------------------------------------------------------------------------
# document-partitioned, fused Pallas engine (HOR blocks per shard)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class BlockedDocShardedIndex:
    """Stacked per-shard HOR/BlockedIndex arrays for the fused engine.

    Each shard re-packs its document slice into 128-lane posting blocks
    with the build-time (block -> doc-tile) routing cache, so the
    shard_map program can call the fused decode-and-score kernel locally
    and merge per-shard top-k — the distributed version of the one-HBM-
    pass read path.
    """
    sorted_hash: np.ndarray    # u32[S, W]
    df_global: np.ndarray      # i32[S, W]
    block_offsets: np.ndarray  # i32[S, W+1]
    block_docs: np.ndarray     # i32[S, NBmax, BLOCK]  LOCAL doc ids
    block_tfs: np.ndarray      # f32[S, NBmax, BLOCK]
    tile_first: np.ndarray     # i32[S, NBmax]
    tile_count: np.ndarray     # i32[S, NBmax]
    norm: np.ndarray           # f32[S, Dmax]
    doc_base: np.ndarray       # i32[S]
    n_shards: int
    num_docs: int              # global
    dmax: int                  # max local docs per shard
    tile: int
    max_blocks_per_term: int
    route_span_max: int
    route_pairs_max: int

    def device_arrays(self) -> dict:
        # NOT dataclasses.asdict: that deep-copies every (stacked, large)
        # numpy array on the host before the device transfer
        return {f.name: jnp.asarray(getattr(self, f.name))
                for f in dataclasses.fields(self)
                if isinstance(getattr(self, f.name), np.ndarray)}


def _doc_shard_subhosts(host: PostingsHost, n_shards: int):
    """Slice the corpus into per-doc-range PostingsHost sub-indexes
    (contiguous id ranges, LOCAL doc ids, term-major posting order) —
    the one slicing both bulk doc-sharded builders share, so the HOR
    and packed structures see identical per-shard block boundaries
    (that is what makes the two fused engines bit-identical)."""
    bounds = np.linspace(0, host.num_docs, n_shards + 1).astype(np.int64)
    dmax = int(np.max(np.diff(bounds)))
    W = host.num_terms
    term_of = np.repeat(np.arange(W, dtype=np.int64), np.diff(host.offsets))
    subs = []
    for s in range(n_shards):
        lo, hi = bounds[s], bounds[s + 1]
        m = (host.doc_ids >= lo) & (host.doc_ids < hi)
        order = np.lexsort((host.doc_ids[m], term_of[m]))
        docs = (host.doc_ids[m][order] - lo).astype(np.int32)
        tfs = host.tfs[m][order].astype(np.float32)
        df_l = np.bincount(term_of[m], minlength=W).astype(np.int32)
        offs = np.zeros(W + 1, dtype=np.int64)
        np.cumsum(df_l, out=offs[1:])
        subs.append(PostingsHost(term_hashes=host.term_hashes, df=df_l,
                                 offsets=offs, doc_ids=docs, tfs=tfs,
                                 num_docs=int(hi - lo),
                                 norm=host.norm[lo:hi],
                                 rank=host.rank[lo:hi]))
    return subs, bounds, dmax


def build_doc_sharded_blocked(host: PostingsHost, n_shards: int,
                              tile: int | None = None
                              ) -> BlockedDocShardedIndex:
    tile = tile or layouts.ROUTE_TILE
    subs, bounds, dmax = _doc_shard_subhosts(host, n_shards)
    W = host.num_terms
    shards = [layouts.build_blocked(sub) for sub in subs]

    block = shards[0].block
    nbmax = max(int(ix.block_docs.shape[0]) for ix in shards)
    S = n_shards
    bd = np.full((S, nbmax, block), -1, dtype=np.int32)
    bt = np.zeros((S, nbmax, block), dtype=np.float32)
    tf_arr = np.zeros((S, nbmax), dtype=np.int32)
    tc_arr = np.zeros((S, nbmax), dtype=np.int32)
    offs_a = np.zeros((S, W + 1), dtype=np.int32)
    norm_a = np.zeros((S, dmax), dtype=np.float32)
    for s, ix in enumerate(shards):
        nb = int(ix.block_docs.shape[0])
        bd[s, :nb] = np.asarray(ix.block_docs)
        bt[s, :nb] = np.asarray(ix.block_tfs)
        # routing spans vs the PADDED local doc space (uniform across
        # shards) so every shard's kernel sees the same tile grid
        tf_s, tc_s = layouts._block_tile_routing(
            np.asarray(ix.block_min), np.asarray(ix.block_max), dmax, tile)
        tf_arr[s, :nb] = tf_s
        tc_arr[s, :nb] = tc_s
        offs_a[s] = np.asarray(ix.block_offsets)
        lo, hi = bounds[s], bounds[s + 1]
        norm_a[s, :hi - lo] = host.norm[lo:hi]
    order = np.argsort(host.term_hashes, kind="stable")
    return BlockedDocShardedIndex(
        sorted_hash=np.broadcast_to(
            host.term_hashes[order][None, :], (S, W)).copy(),
        df_global=np.broadcast_to(
            host.df[order].astype(np.int32)[None, :], (S, W)).copy(),
        block_offsets=offs_a, block_docs=bd, block_tfs=bt,
        tile_first=tf_arr, tile_count=tc_arr, norm=norm_a,
        doc_base=bounds[:-1].astype(np.int32), n_shards=S,
        num_docs=host.num_docs, dmax=dmax, tile=tile,
        max_blocks_per_term=max(ix.max_blocks_per_term for ix in shards),
        route_span_max=max(int(np.max(tc_arr[s])) if nbmax else 0
                           for s in range(S)),
        route_pairs_max=max(int(np.sum(tc_arr[s])) for s in range(S)),
    )


@dataclasses.dataclass
class PackedDocShardedIndex:
    """Stacked per-shard delta+bit-packed arrays for the fused engine —
    the compressed twin of ``BlockedDocShardedIndex`` (the long-standing
    HOR-only gap of the bulk doc-sharded path).

    Each shard re-compresses its document slice: LOCAL doc-id deltas
    bit-packed at per-block minimal widths, f16 tfs, the per-block
    (bits, base, count) decode scalars, and routing recomputed against
    the PADDED local doc space so every shard's kernel sees the same
    tile grid.  Cross-shard padding blocks carry ``bits=1, count=0`` —
    they decode to nothing, the same inert-padding trick the packed
    term-sharded and segment-stack paths use.
    """
    sorted_hash: np.ndarray    # u32[S, W]
    df_global: np.ndarray      # i32[S, W]
    block_offsets: np.ndarray  # i32[S, W+1]
    packed: np.ndarray         # u32[S, NBmax, WPB]  LOCAL-doc deltas
    block_tfs: np.ndarray      # f16[S, NBmax, BLOCK]
    block_bits: np.ndarray     # i32[S, NBmax]  (1 on padding blocks)
    block_base: np.ndarray     # i32[S, NBmax]
    block_count: np.ndarray    # i32[S, NBmax]  (0 on padding blocks)
    tile_first: np.ndarray     # i32[S, NBmax]
    tile_count: np.ndarray     # i32[S, NBmax]
    norm: np.ndarray           # f32[S, Dmax]
    doc_base: np.ndarray       # i32[S]
    n_shards: int
    num_docs: int              # global
    dmax: int                  # max local docs per shard
    tile: int
    block: int
    words_per_block: int
    max_blocks_per_term: int
    route_span_max: int
    route_pairs_max: int

    def device_arrays(self) -> dict:
        return {f.name: jnp.asarray(getattr(self, f.name))
                for f in dataclasses.fields(self)
                if isinstance(getattr(self, f.name), np.ndarray)}


def build_doc_sharded_packed(host: PostingsHost, n_shards: int,
                             tile: int | None = None
                             ) -> PackedDocShardedIndex:
    """Per-doc-shard re-compression over the SAME slicing as
    ``build_doc_sharded_blocked`` — identical shard bounds, per-shard
    posting order, and block boundaries, so the packed fused engine is
    bit-identical to the HOR one under the candidate-merge tier."""
    tile = tile or layouts.ROUTE_TILE
    subs, bounds, dmax = _doc_shard_subhosts(host, n_shards)
    W = host.num_terms
    shards = [layouts.build_packed_csr(sub) for sub in subs]

    block = shards[0].block
    nbmax = max(int(ix.packed.shape[0]) for ix in shards)
    wpb = max(ix.words_per_block for ix in shards)
    S = n_shards
    pk = np.zeros((S, nbmax, wpb), dtype=np.uint32)
    bt = np.zeros((S, nbmax, block), dtype=np.float16)
    bits_a = np.ones((S, nbmax), dtype=np.int32)   # padding decodes inert
    base_a = np.zeros((S, nbmax), dtype=np.int32)
    cnt_a = np.zeros((S, nbmax), dtype=np.int32)
    tf_arr = np.zeros((S, nbmax), dtype=np.int32)
    tc_arr = np.zeros((S, nbmax), dtype=np.int32)
    offs_a = np.zeros((S, W + 1), dtype=np.int32)
    norm_a = np.zeros((S, dmax), dtype=np.float32)
    for s, ix in enumerate(shards):
        nb = int(ix.packed.shape[0])
        pk[s, :nb, :ix.words_per_block] = np.asarray(ix.packed)
        bt[s, :nb] = np.asarray(ix.block_tfs)
        bits_a[s, :nb] = np.asarray(ix.block_bits)
        base_a[s, :nb] = np.asarray(ix.block_base)
        cnt_a[s, :nb] = np.asarray(ix.block_count)
        # routing spans vs the PADDED local doc space (uniform across
        # shards), same as the HOR builder
        tf_s, tc_s = layouts._block_tile_routing(
            np.asarray(ix.block_min), np.asarray(ix.block_max), dmax, tile)
        tf_arr[s, :nb] = tf_s
        tc_arr[s, :nb] = tc_s
        offs_a[s] = np.asarray(ix.block_offsets)
        lo, hi = bounds[s], bounds[s + 1]
        norm_a[s, :hi - lo] = host.norm[lo:hi]
    order = np.argsort(host.term_hashes, kind="stable")
    return PackedDocShardedIndex(
        sorted_hash=np.broadcast_to(
            host.term_hashes[order][None, :], (S, W)).copy(),
        df_global=np.broadcast_to(
            host.df[order].astype(np.int32)[None, :], (S, W)).copy(),
        block_offsets=offs_a, packed=pk, block_tfs=bt, block_bits=bits_a,
        block_base=base_a, block_count=cnt_a,
        tile_first=tf_arr, tile_count=tc_arr, norm=norm_a,
        doc_base=bounds[:-1].astype(np.int32), n_shards=S,
        num_docs=host.num_docs, dmax=dmax, tile=tile, block=block,
        words_per_block=wpb,
        max_blocks_per_term=max(ix.max_blocks_per_term for ix in shards),
        route_span_max=max(int(np.max(tc_arr[s])) if nbmax else 0
                           for s in range(S)),
        route_pairs_max=max(int(np.sum(tc_arr[s])) for s in range(S)),
    )


def build_doc_sharded_fused(host: PostingsHost, n_shards: int, *,
                            tile: int | None = None,
                            layout: str | None = None, policy=None):
    """Layout-ladder front door for the bulk doc-sharded fused engine:
    ``explicit layout > policy (size_model.LayoutCostModel over the
    host's aggregate stats) > historical "hor" default``.  Returns
    ``(index, reason)`` where index is a Blocked- or
    PackedDocShardedIndex — both accepted by
    ``make_doc_sharded_fused_scorer`` — and reason is the chooser's
    provenance string."""
    from repro.core import size_model
    stats = size_model.SegmentStats(
        num_docs=int(host.num_docs),
        num_postings=int(host.num_postings),
        num_terms=int(np.count_nonzero(np.asarray(host.df))))
    layout, reason = size_model.resolve_layout(layout, policy, stats,
                                               "hor")
    if layout == "packed":
        return build_doc_sharded_packed(host, n_shards, tile=tile), reason
    if layout == "hor":
        return build_doc_sharded_blocked(host, n_shards, tile=tile), reason
    if layout == "banded":
        raise ValueError(
            "banded is not a bulk doc-sharded layout: banded segments "
            "doc-shard through the segment-stack serving tier "
            "(stack_segment_shards / make_doc_sharded_segment_scorer), "
            "which carries both bands per group slot")
    raise ValueError(f"unknown layout: {layout!r}")


def make_doc_sharded_fused_scorer(
        index: BlockedDocShardedIndex | PackedDocShardedIndex,
        mesh: Mesh, axis: str, k: int = 10):
    """jit fn(query_hashes u32[T]) -> (scores[k], global doc ids[k]).

    Same contract as ``make_doc_sharded_scorer`` but every shard runs
    the fused decode-and-score Pallas kernel in CANDIDATE mode over its
    local posting blocks: each doc tile is reduced to a per-tile top-k
    in VMEM (the dense local score vector never reaches HBM), the
    shard's tile candidates become global candidates via ``doc_base``,
    and a thin all-gather candidate merge produces the global answer —
    the ODYS-style per-partition extraction + merge tier.

    Accepts either bulk layout: HOR blocks score in place, packed blocks
    decode IN VMEM (``fused_topk_packed_pallas``) — bit-identical
    answers, ~3x fewer posting bytes across HBM per shard."""
    from repro.distributed.topk import local_candidate_merge
    from repro.kernels import autotune
    from repro.kernels.fused_decode_score import (
        build_batched_pairs, default_k_tile, fused_topk_blocked_pallas,
        fused_topk_packed_pallas)
    from repro.kernels.ops import (expand_block_candidates,
                                    round_up_pairs, warn_on_overflow)

    packed_layout = isinstance(index, PackedDocShardedIndex)
    arrs = index.device_arrays()
    dmax, tile = index.dmax, index.tile
    n_tiles = max(-(-dmax // tile), 1)
    num_docs = index.num_docs
    block = (index.block if packed_layout
             else int(index.block_docs.shape[-1]))
    m_blocks = max(index.max_blocks_per_term, 1)
    # tuned geometry for this shard size — the tile itself is pinned by
    # the sharded routing arrays, so only the routing-free axes (k_pad,
    # q_pad, reducer, unroll) follow the tuning table
    cfg = autotune.lookup("pallas", dmax,
                          "packed" if packed_layout else "hor")
    q_pad = cfg.q_pad
    pps = cfg.pairs_per_step
    if cfg.tile == tile:
        k_tile = cfg.resolve_k_tile(k)
    else:
        k_tile = min(default_k_tile(k, tile, cfg.k_pad), tile)

    names = ("sorted_hash", "df_global", "block_offsets", "tile_first",
             "tile_count", "norm", "doc_base", "block_tfs")
    names += (("packed", "block_bits", "block_base", "block_count")
              if packed_layout else ("block_docs",))
    sharded = {n: P(axis) for n in names}

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(sharded, P()), out_specs=(P(), P()), check_vma=False)
    def score(ix, qh):
        sq = {n: v[0] for n, v in ix.items()}    # drop shard dim
        qh = dedup_query_hashes(qh)
        t = qh.shape[0]
        pos = jnp.searchsorted(sq["sorted_hash"], qh).astype(jnp.int32)
        pos = jnp.clip(pos, 0, sq["sorted_hash"].shape[0] - 1)
        hit = (sq["sorted_hash"][pos] == qh) & (qh != 0)
        tid = jnp.where(hit, pos, -1)
        # idf uses GLOBAL df — scoring must match the single-node engine
        w = idf_fn(jnp.where(hit, sq["df_global"][pos], 0), num_docs)

        cand_block, cand_valid, cand_q, cand_w, _ = \
            expand_block_candidates(sq["block_offsets"], tid[None],
                                    w[None], m_blocks, block)
        max_pairs = max(min(index.route_pairs_max,
                            t * m_blocks * max(index.route_span_max, 1)), 8)
        if pps > 1:
            # run-aligned padding inserts up to pps-1 no-op pairs per tile
            max_pairs += n_tiles * (pps - 1)
        max_pairs = round_up_pairs(max_pairs, pps)
        pb, pt, pqw, pcap, ovf = build_batched_pairs(
            cand_block, cand_valid, cand_q, cand_w,
            sq["tile_first"], sq["tile_count"], n_tiles, 1, max_pairs,
            pairs_per_step=pps)
        # budget above is exact, so this won't fire unless the budget
        # formula is ever loosened
        warn_on_overflow(ovf, "doc-sharded fused engine")
        pqw = jnp.pad(pqw, ((0, 0), (0, q_pad - 1)))
        qnorm = jnp.sqrt(jnp.maximum(jnp.sum(w * w), 1e-12))
        qn = jnp.full((q_pad,), 1.0, jnp.float32).at[0].set(qnorm)
        if packed_layout:
            vals, ids = fused_topk_packed_pallas(
                sq["packed"], sq["block_tfs"], pb, pt, pqw, pcap,
                sq["block_bits"][pb], sq["block_base"][pb],
                sq["block_count"][pb], sq["norm"],
                jnp.zeros_like(sq["norm"]), qn, dmax, block, k_tile,
                tile=tile, reducer=cfg.reducer, pairs_per_step=pps)
        else:
            vals, ids = fused_topk_blocked_pallas(
                sq["block_docs"], sq["block_tfs"], pb, pt, pqw, pcap,
                sq["norm"], jnp.zeros_like(sq["norm"]), qn, dmax, k_tile,
                tile=tile, reducer=cfg.reducer, pairs_per_step=pps)
        gids = jnp.where(ids[0] >= 0, ids[0] + sq["doc_base"], -1)
        return local_candidate_merge(vals[0], gids, k, axis)

    return jax.jit(lambda qh: score(arrs, qh))


# ---------------------------------------------------------------------------
# document-partitioned segment stacks (the live index's serving tier)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StackGroupMeta:
    """Static signature of one ``(size_class, layout)`` group of sealed
    segments in a sharded stack.

    Sealing already quantizes every shape- and budget-bearing static to
    a geometric size class (``layouts.pad_blocked_to_class`` /
    ``pad_packed_to_class``); grouping the stack on the full tuple means
    two stacks whose segments fall into the same classes produce
    IDENTICAL jit signatures — the sharded twin of the live index's
    recompile-avoidance contract.  ``n_slots`` (the group's stack depth)
    is itself pow2-quantized so sealing one more same-class segment
    reuses the compiled scorer."""
    layout: str              # "hor" | "packed" | "banded"
    w_pad: int               # vocab slots per segment (size class)
    nb_pad: int              # posting-block rows per segment
    d_pad: int               # padded local doc span
    block: int
    words_per_block: int     # packed word lanes (0 for hor)
    n_slots: int             # G: per-shard stack depth (pow2, inert pads)
    max_blocks_per_term: int
    route_span_max: int
    route_pairs_max: int
    # banded only: the HOR band's statics ride alongside the packed
    # band's (which reuse the fields above); 0 for hor/packed groups so
    # pre-banded group keys are unchanged
    hor_nb_pad: int = 0
    hor_max_blocks_per_term: int = 0
    hor_route_span_max: int = 0
    hor_route_pairs_max: int = 0


def _segment_group_key(ix) -> StackGroupMeta:
    """The (size_class, layout) bucket a sealed segment stacks into.
    ``n_slots`` is filled in later (it is a property of the stack, not
    of one segment)."""
    if isinstance(ix, layouts.BandedCsrIndex):
        p, h = ix.packed, ix.hor
        return StackGroupMeta(
            layout="banded", w_pad=int(p.sorted_hash.shape[0]),
            nb_pad=int(p.packed.shape[0]), d_pad=int(p.docs.num_docs),
            block=p.block, words_per_block=p.words_per_block, n_slots=0,
            max_blocks_per_term=p.max_blocks_per_term,
            route_span_max=p.route_span_max,
            route_pairs_max=p.route_pairs_max,
            hor_nb_pad=int(h.block_docs.shape[0]),
            hor_max_blocks_per_term=h.max_blocks_per_term,
            hor_route_span_max=h.route_span_max,
            hor_route_pairs_max=h.route_pairs_max)
    if isinstance(ix, layouts.PackedCsrIndex):
        return StackGroupMeta(
            layout="packed", w_pad=int(ix.sorted_hash.shape[0]),
            nb_pad=int(ix.packed.shape[0]), d_pad=int(ix.docs.num_docs),
            block=ix.block, words_per_block=ix.words_per_block, n_slots=0,
            max_blocks_per_term=ix.max_blocks_per_term,
            route_span_max=ix.route_span_max,
            route_pairs_max=ix.route_pairs_max)
    if isinstance(ix, layouts.BlockedIndex):
        return StackGroupMeta(
            layout="hor", w_pad=int(ix.sorted_hash.shape[0]),
            nb_pad=int(ix.block_docs.shape[0]), d_pad=int(ix.docs.num_docs),
            block=ix.block, words_per_block=0, n_slots=0,
            max_blocks_per_term=ix.max_blocks_per_term,
            route_span_max=ix.route_span_max,
            route_pairs_max=ix.route_pairs_max)
    raise ValueError(f"unknown sealed-segment layout: {type(ix).__name__}")


def _group_array_names(layout: str) -> tuple:
    common = ("sorted_hash", "block_offsets", "tile_first", "tile_count",
              "norm", "doc_base")
    packed = ("packed", "block_tfs", "block_bits", "block_base",
              "block_count")
    if layout == "banded":
        # the un-prefixed block arrays are the packed band's (the vocab
        # is shared — both bands carry the full hash-sorted vocabulary)
        return common + packed + ("hor_block_offsets", "hor_block_docs",
                                  "hor_block_tfs", "hor_tile_first",
                                  "hor_tile_count")
    if layout == "packed":
        return common + packed
    return common + ("block_docs", "block_tfs")


def _empty_group_arrays(meta: StackGroupMeta, n_shards: int) -> dict:
    """Inert [S, G, ...] arrays for one group: absent-hash vocab slots,
    tile_count 0 (never routed), and — for packed — bit width 1 with
    count 0, so padding slots are in-distribution for the decoder and
    contribute nothing."""
    S, G = n_shards, meta.n_slots
    w, nb, b = meta.w_pad, meta.nb_pad, meta.block
    arrays = {
        "sorted_hash": np.full((S, G, w), 0xFFFFFFFF, np.uint32),
        "block_offsets": np.zeros((S, G, w + 1), np.int32),
        "tile_first": np.zeros((S, G, nb), np.int32),
        "tile_count": np.zeros((S, G, nb), np.int32),
        "norm": np.zeros((S, G, meta.d_pad), np.float32),
        "doc_base": np.zeros((S, G), np.int32),
    }
    if meta.layout in ("packed", "banded"):
        arrays.update({
            "packed": np.zeros((S, G, nb, meta.words_per_block), np.uint32),
            "block_tfs": np.zeros((S, G, nb, b), np.float16),
            "block_bits": np.ones((S, G, nb), np.int32),
            "block_base": np.zeros((S, G, nb), np.int32),
            "block_count": np.zeros((S, G, nb), np.int32),
        })
    else:
        arrays.update({
            "block_docs": np.full((S, G, nb, b), -1, np.int32),
            "block_tfs": np.zeros((S, G, nb, b), np.float32),
        })
    if meta.layout == "banded":
        hnb = meta.hor_nb_pad
        arrays.update({
            "hor_block_offsets": np.zeros((S, G, meta.w_pad + 1), np.int32),
            "hor_block_docs": np.full((S, G, hnb, b), -1, np.int32),
            "hor_block_tfs": np.zeros((S, G, hnb, b), np.float32),
            "hor_tile_first": np.zeros((S, G, hnb), np.int32),
            "hor_tile_count": np.zeros((S, G, hnb), np.int32),
        })
    return arrays


def _fill_group_slot(arrays: dict, s: int, g: int, seg) -> None:
    ix = seg.index
    if isinstance(ix, layouts.BandedCsrIndex):
        h = ix.hor
        arrays["hor_block_offsets"][s, g] = np.asarray(h.block_offsets)
        arrays["hor_block_docs"][s, g] = np.asarray(h.block_docs)
        arrays["hor_block_tfs"][s, g] = np.asarray(h.block_tfs)
        arrays["hor_tile_first"][s, g] = np.asarray(h.tile_first)
        arrays["hor_tile_count"][s, g] = np.asarray(h.tile_count)
        ix = ix.packed        # the un-prefixed arrays are the packed band
    arrays["sorted_hash"][s, g] = np.asarray(ix.sorted_hash)
    arrays["block_offsets"][s, g] = np.asarray(ix.block_offsets)
    arrays["tile_first"][s, g] = np.asarray(ix.tile_first)
    arrays["tile_count"][s, g] = np.asarray(ix.tile_count)
    arrays["norm"][s, g] = np.asarray(ix.docs.norm)
    arrays["doc_base"][s, g] = seg.doc_base
    if isinstance(ix, layouts.PackedCsrIndex):
        arrays["packed"][s, g] = np.asarray(ix.packed)
        arrays["block_tfs"][s, g] = np.asarray(ix.block_tfs)
        arrays["block_bits"][s, g] = np.asarray(ix.block_bits)
        arrays["block_base"][s, g] = np.asarray(ix.block_base)
        arrays["block_count"][s, g] = np.asarray(ix.block_count)
    else:
        arrays["block_docs"][s, g] = np.asarray(ix.block_docs)
        arrays["block_tfs"][s, g] = np.asarray(ix.block_tfs)


@dataclasses.dataclass
class SegmentStackShards:
    """Per-shard stacks of sealed live-index segments, grouped by
    ``(size_class, layout)`` and stacked ``[S, G, ...]`` per group
    (G = the group's deepest per-shard stack, pow2-padded; empty slots
    inert).  Each shard owns WHOLE segments — the ODYS-style partition-
    by-run layout — so a query runs one fused candidate kernel per local
    segment and the global answer is a candidate merge, exactly the
    single-node live path with shards playing the role of stacks.  HOR
    and delta+bit-packed sealed segments mix freely: each group carries
    its own layout and the candidate lists are canonicalized (ascending
    doc id) before the merge, so ties still break on lowest global id."""
    groups: list               # [(StackGroupMeta, {name: np [S, G, ...]})]
    vocab_hash: np.ndarray     # u32[Wp] unified, hash-sorted (replicated)
    vocab_df: np.ndarray       # i32[Wp] LIVE global df (replicated)
    n_shards: int
    live_docs: int             # D behind idf (traced at query time)
    tile: int

    def signature(self) -> tuple:
        """Hashable static structure: the jit-cache key component."""
        return tuple(meta for meta, _ in self.groups)

    def device_arrays(self) -> dict:
        return {
            "groups": [{n: jnp.asarray(v) for n, v in arrays.items()}
                       for _, arrays in self.groups],
            "vocab_hash": jnp.asarray(self.vocab_hash),
            "vocab_df": jnp.asarray(self.vocab_df),
            "live_docs": jnp.float32(self.live_docs),
        }


def stack_segment_shards(live_index, n_shards: int) -> SegmentStackShards:
    """Distribute a SegmentedIndex's sealed stack across ``n_shards``.
    The delta must be sealed first — the serving tier replicates
    immutable runs only.

    Also accepts an epoch-pinned ``LiveView`` (``SegmentedIndex.view()``
    / ``serve.snapshot.pin``): the sharded serving tier then snapshots a
    CONSISTENT epoch — build the stacks from a pin while ingest keeps
    landing, and the sharded scorer answers exactly as the single-node
    pinned view does, no quiesce needed.  Sealed segments may be HOR
    blocks (``seal_layout="hor"``), delta+bit-packed blocks
    (``"packed"``), or any per-seal mixture: segments stack into
    per-``(size_class, layout)`` groups, so a warm
    ``make_doc_sharded_segment_scorer`` jit cache sees zero new entries
    when a rebuilt stack hits the same group signatures."""
    from repro.core.live_index import LiveView
    if isinstance(live_index, LiveView):
        if live_index.delta_n_docs:
            raise ValueError("pin a view with a sealed delta before "
                             "sharding the stack")
        segs = list(live_index.segments)
        vocab_hashes = live_index.hashes
        vocab_df = np.asarray(live_index.df)
        live_docs = live_index.live_docs
    else:
        if live_index.delta_postings or live_index._delta.n_docs:
            raise ValueError("seal() the delta before sharding the stack")
        segs = live_index.segments()
        vocab_hashes = live_index.term_hashes
        vocab_df = np.asarray(live_index._df)
        live_docs = live_index.live_doc_count
    if not segs:
        raise ValueError("no sealed segments to shard")
    tiles = {s.index.route_tile for s in segs}
    if len(tiles) != 1:
        raise ValueError(f"segments disagree on route_tile: {tiles}")
    # contiguous runs per shard (NOT round-robin): the all-gather
    # candidate merge concatenates shard 0's candidates first, so shards
    # must cover ascending doc-id ranges for exact score ties to break
    # on lowest global doc id, like the single-node live index
    splits = np.array_split(np.arange(len(segs)), n_shards)
    shards = [[segs[i] for i in idx] for idx in splits]

    # bucket by (size_class, layout); G = pow2-padded deepest stack
    keys = sorted({_segment_group_key(s.index) for s in segs},
                  key=lambda m: dataclasses.astuple(m))
    groups = []
    for key in keys:
        depth = max(sum(1 for s in stack
                        if _segment_group_key(s.index) == key)
                    for stack in shards)
        meta = dataclasses.replace(
            key, n_slots=layouts.size_class(depth, base=1))
        arrays = _empty_group_arrays(meta, n_shards)
        for s, stack in enumerate(shards):
            g = 0
            for seg in stack:
                if _segment_group_key(seg.index) == key:
                    _fill_group_slot(arrays, s, g, seg)
                    g += 1
        groups.append((meta, arrays))

    order = np.argsort(vocab_hashes, kind="stable")
    w = len(vocab_hashes)
    w_pad = layouts.size_class(max(w, 1), base=256)
    vh = np.full(w_pad, 0xFFFFFFFF, np.uint32)
    vh[:w] = vocab_hashes[order].astype(np.uint32)
    vdf = np.zeros(w_pad, np.int32)
    vdf[:w] = vocab_df[order].astype(np.int32)
    return SegmentStackShards(
        groups=groups, vocab_hash=vh, vocab_df=vdf, n_shards=n_shards,
        live_docs=live_docs, tile=segs[0].index.route_tile)


# compiled stack scorers, keyed on (mesh, axis, k, static stack
# signature): rebuilding the stack at a new epoch with the same
# (size_class, layout) group structure reuses the warm executable
_STACK_SCORER_CACHE: dict = {}


def stack_scorer_cache_sizes() -> dict:
    """jit-cache counters for the sharded segment-stack scorer — the
    sharded twin of ``live_index.scorer_cache_sizes`` (tests assert zero
    growth across same-class stack rebuilds)."""
    return {
        "doc_sharded_segment_scorers": len(_STACK_SCORER_CACHE),
        "doc_sharded_segment_entries":
            sum(f._cache_size() for f in _STACK_SCORER_CACHE.values()),
    }


def _build_stack_scorer(mesh: Mesh, axis: str, k: int, tile: int,
                        metas: tuple, cfgs: tuple = ()):
    from repro.distributed.topk import (canonicalize_candidates,
                                        local_candidate_merge)
    from repro.kernels import autotune
    from repro.kernels.fused_decode_score import (
        build_batched_pairs, default_k_tile, extract_tile_candidates,
        fused_score_blocked_pallas, fused_score_packed_pallas,
        fused_topk_blocked_pallas, fused_topk_packed_pallas)
    from repro.kernels.ops import expand_block_candidates, round_up_pairs

    if not cfgs:
        cfgs = tuple(autotune.lookup("pallas", m.d_pad, m.layout)
                     for m in metas)

    def _group_k_tile(cfg):
        # the stack tile is pinned by the sharded routing arrays; only
        # apply the tuned k_tile when the table agrees on the tile, else
        # fall back to the tuned k_pad quantum at the stack tile
        if cfg.tile == tile:
            return cfg.resolve_k_tile(k)
        return min(default_k_tile(k, tile, cfg.k_pad), tile)
    group_specs = [{n: P(axis) for n in _group_array_names(m.layout)}
                   for m in metas]
    in_specs = ({"groups": group_specs, "vocab_hash": P(),
                 "vocab_df": P(), "live_docs": P()}, P())

    @functools.partial(
        shard_map, mesh=mesh, in_specs=in_specs, out_specs=(P(), P()),
        check_vma=False)
    def score(ix, qh):
        qh = dedup_query_hashes(qh)
        t = qh.shape[0]
        # global idf from the replicated live vocabulary stats; the live
        # doc count is TRACED (same op sequence as the live index's
        # _query_weights), so ingest between stack rebuilds changes no
        # static — only array contents
        vh, vdf = ix["vocab_hash"], ix["vocab_df"]
        vpos = jnp.searchsorted(vh, qh).astype(jnp.int32)
        vpos = jnp.clip(vpos, 0, vh.shape[0] - 1)
        vhit = (vh[vpos] == qh) & (qh != 0)
        w = idf_fn(jnp.where(vhit, vdf[vpos], 0), ix["live_docs"])
        qnorm = jnp.sqrt(jnp.maximum(jnp.sum(w * w), 1e-12))
        all_v, all_i = [], []
        for meta, cfg, g_arrs in zip(metas, cfgs, ix["groups"]):
            sq = {n: v[0] for n, v in g_arrs.items()}   # drop shard dim
            n_tiles = max(-(-meta.d_pad // tile), 1)
            m_blocks = max(meta.max_blocks_per_term, 1)
            k_tile = _group_k_tile(cfg)
            if meta.layout == "banded":
                # per-band dense partials summed BEFORE extraction — a
                # per-band candidate top-k cannot merge (scores are
                # additive over terms), so the banded slot mirrors the
                # single-host banded engine: one lookup, two fused dense
                # launches, shared scoring tail, per-tile candidates
                m_h = max(meta.hor_max_blocks_per_term, 1)
                mp_p = max(min(meta.route_pairs_max,
                               t * m_blocks * max(meta.route_span_max, 1)),
                           8)
                mp_h = max(min(meta.hor_route_pairs_max,
                               t * m_h * max(meta.hor_route_span_max, 1)),
                           8)
                for g in range(meta.n_slots):
                    pos = jnp.searchsorted(sq["sorted_hash"][g],
                                           qh).astype(jnp.int32)
                    pos = jnp.clip(pos, 0, sq["sorted_hash"].shape[1] - 1)
                    hit = (sq["sorted_hash"][g][pos] == qh) & (qh != 0)
                    tid = jnp.where(hit, pos, -1)
                    cb, cv, cq, cw, _ = expand_block_candidates(
                        sq["block_offsets"][g], tid[None], w[None],
                        m_blocks, meta.block)
                    pb, pt, pqw, pcap, _ovf = build_batched_pairs(
                        cb, cv, cq, cw, sq["tile_first"][g],
                        sq["tile_count"][g], n_tiles, 1, mp_p)
                    pqw = jnp.pad(pqw, ((0, 0), (0, cfg.q_pad - 1)))
                    acc = fused_score_packed_pallas(
                        sq["packed"][g], sq["block_tfs"][g], pb, pt, pqw,
                        pcap, sq["block_bits"][g][pb],
                        sq["block_base"][g][pb], sq["block_count"][g][pb],
                        meta.d_pad, meta.block, tile)[0]
                    cb, cv, cq, cw, _ = expand_block_candidates(
                        sq["hor_block_offsets"][g], tid[None], w[None],
                        m_h, meta.block)
                    pb, pt, pqw, pcap, _ovf = build_batched_pairs(
                        cb, cv, cq, cw, sq["hor_tile_first"][g],
                        sq["hor_tile_count"][g], n_tiles, 1, mp_h)
                    pqw = jnp.pad(pqw, ((0, 0), (0, cfg.q_pad - 1)))
                    acc = acc + fused_score_blocked_pallas(
                        sq["hor_block_docs"][g], sq["hor_block_tfs"][g],
                        pb, pt, pqw, pcap, meta.d_pad, tile)[0]
                    nrm = sq["norm"][g]
                    final = jnp.where(
                        (nrm > 0) & (acc > 0),
                        acc / (jnp.maximum(nrm, 1e-12) * qnorm), -jnp.inf)
                    vals, ids = extract_tile_candidates(final[None], tile,
                                                        k_tile)
                    all_v.append(vals[0])
                    all_i.append(jnp.where(ids[0] >= 0,
                                           ids[0] + sq["doc_base"][g], -1))
                continue
            pps = cfg.pairs_per_step
            qn = jnp.full((cfg.q_pad,), 1.0, jnp.float32).at[0].set(qnorm)
            max_pairs = max(min(meta.route_pairs_max,
                                t * m_blocks * max(meta.route_span_max, 1)),
                            8)
            if pps > 1:
                max_pairs += n_tiles * (pps - 1)
            max_pairs = round_up_pairs(max_pairs, pps)
            for g in range(meta.n_slots):             # static stack depth
                pos = jnp.searchsorted(sq["sorted_hash"][g],
                                       qh).astype(jnp.int32)
                pos = jnp.clip(pos, 0, sq["sorted_hash"].shape[1] - 1)
                hit = (sq["sorted_hash"][g][pos] == qh) & (qh != 0)
                tid = jnp.where(hit, pos, -1)
                cand_block, cand_valid, cand_q, cand_w, _ = \
                    expand_block_candidates(sq["block_offsets"][g],
                                            tid[None], w[None], m_blocks,
                                            meta.block)
                pb, pt, pqw, pcap, _ovf = build_batched_pairs(
                    cand_block, cand_valid, cand_q, cand_w,
                    sq["tile_first"][g], sq["tile_count"][g], n_tiles, 1,
                    max_pairs, pairs_per_step=pps)
                pqw = jnp.pad(pqw, ((0, 0), (0, cfg.q_pad - 1)))
                if meta.layout == "packed":
                    vals, ids = fused_topk_packed_pallas(
                        sq["packed"][g], sq["block_tfs"][g], pb, pt, pqw,
                        pcap, sq["block_bits"][g][pb],
                        sq["block_base"][g][pb], sq["block_count"][g][pb],
                        sq["norm"][g], jnp.zeros_like(sq["norm"][g]), qn,
                        meta.d_pad, meta.block, k_tile, tile=tile,
                        reducer=cfg.reducer, pairs_per_step=pps)
                else:
                    vals, ids = fused_topk_blocked_pallas(
                        sq["block_docs"][g], sq["block_tfs"][g], pb, pt,
                        pqw, pcap, sq["norm"][g],
                        jnp.zeros_like(sq["norm"][g]), qn, meta.d_pad,
                        k_tile, tile=tile,
                        reducer=cfg.reducer, pairs_per_step=pps)
                all_v.append(vals[0])
                all_i.append(jnp.where(ids[0] >= 0,
                                       ids[0] + sq["doc_base"][g], -1))
        # group-major concatenation interleaves doc ranges (mixed
        # layouts, multiple classes) — canonicalize so the merge
        # tie-breaks on lowest global doc id regardless of group order
        cv, ci = canonicalize_candidates(jnp.concatenate(all_v),
                                         jnp.concatenate(all_i))
        return local_candidate_merge(cv, ci, k, axis)

    return jax.jit(score)


def make_doc_sharded_segment_scorer(index: SegmentStackShards, mesh: Mesh,
                                    axis: str, k: int = 10):
    """jit fn(query_hashes u32[T]) -> (scores[k], global doc ids[k]).

    Every shard walks its local segment stack — one fused candidate
    kernel per segment, HOR blocks read in place, packed blocks decoded
    IN VMEM (idf from the replicated LIVE global df, so a shard scores
    exactly as the single-node live index does) — shifts tile candidates
    to global ids via the per-segment doc_base, and the usual all-gather
    candidate merge yields the global top-k.  Deleted docs ride in as
    norm == 0 per segment — tombstones work unchanged at cluster scale.

    The compiled program is cached on (mesh, axis, k, stack signature):
    a stack rebuilt at a newer epoch whose segments fall into the same
    ``(size_class, layout)`` groups reuses the warm executable — zero
    new jit entries (``stack_scorer_cache_sizes``)."""
    if mesh.shape[axis] != index.n_shards:
        raise ValueError(
            f"stack was built for {index.n_shards} shards but mesh axis "
            f"{axis!r} has {mesh.shape[axis]} devices — shard_map would "
            f"silently drop whole per-shard stacks")
    from repro.kernels import autotune
    metas = index.signature()
    # the active tuning table is part of the compiled program — key the
    # cache on the resolved per-group configs so swapping tables (or an
    # empty table, which resolves to historical defaults) never serves a
    # stale geometry
    cfgs = tuple(autotune.lookup("pallas", m.d_pad, m.layout)
                 for m in metas)
    key = (mesh, axis, k, index.tile, index.n_shards,
           int(index.vocab_hash.shape[0]), metas, cfgs)
    fn = _STACK_SCORER_CACHE.get(key)
    if fn is None:
        fn = _build_stack_scorer(mesh, axis, k, index.tile, metas, cfgs)
        _STACK_SCORER_CACHE[key] = fn
    arrs = index.device_arrays()

    def scorer(qh, trace=None):
        # trace=None is the hot path: no span objects, no extra sync —
        # the caller blocks on the results whenever it reads them
        if trace is None:
            return fn(arrs, qh)
        span = trace.span(
            "shard_fanout", parent="score", n_shards=index.n_shards,
            k=k, groups=[{"size_class": m.d_pad, "layout": m.layout}
                         for m in metas])
        out = fn(arrs, qh)
        span.end()
        sync = trace.span("shard_sync", parent="score")
        out = jax.block_until_ready(out)
        sync.end()
        return out

    return scorer


# ---------------------------------------------------------------------------
# term-partitioned, fused Pallas engine (HOR blocks per vocab shard)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class BlockedTermShardedIndex:
    """Stacked per-vocab-shard HOR arrays for the fused engine.

    Each shard owns a contiguous hash range of the vocabulary as whole
    posting lists re-packed into 128-lane blocks with GLOBAL doc ids
    (the doc/tile space is the full corpus, identical on every shard),
    plus the build-time (block -> doc-tile) routing cache.
    """
    sorted_hash: np.ndarray    # u32[S, Wmax]  (padded with 0xFFFFFFFF)
    df: np.ndarray             # i32[S, Wmax]  global df (terms are whole)
    block_offsets: np.ndarray  # i32[S, Wmax+1]
    block_docs: np.ndarray     # i32[S, NBmax, BLOCK]  GLOBAL doc ids
    block_tfs: np.ndarray      # f32[S, NBmax, BLOCK]
    tile_first: np.ndarray     # i32[S, NBmax]
    tile_count: np.ndarray     # i32[S, NBmax]
    norm: np.ndarray           # f32[D] (replicated)
    n_shards: int
    num_docs: int
    tile: int
    max_blocks_per_term: int
    route_span_max: int
    route_pairs_max: int

    def device_arrays(self) -> dict:
        return {f.name: jnp.asarray(getattr(self, f.name))
                for f in dataclasses.fields(self)
                if isinstance(getattr(self, f.name), np.ndarray)}


def build_term_sharded_blocked(host: PostingsHost, n_shards: int
                               ) -> BlockedTermShardedIndex:
    subs, wmax = _term_shard_subhosts(host, n_shards)
    shards = [layouts.build_blocked(sub) for sub in subs]
    block = shards[0].block
    nbmax = max(int(ix.block_docs.shape[0]) for ix in shards)
    S = n_shards
    sh_a = np.full((S, wmax), 0xFFFFFFFF, np.uint32)
    df_a = np.zeros((S, wmax), np.int32)
    offs_a = np.zeros((S, wmax + 1), np.int32)
    bd = np.full((S, nbmax, block), -1, np.int32)
    bt = np.zeros((S, nbmax, block), np.float32)
    tf_a = np.zeros((S, nbmax), np.int32)
    tc_a = np.zeros((S, nbmax), np.int32)
    for s, ix in enumerate(shards):
        w = int(ix.sorted_hash.shape[0])
        nb = int(ix.block_docs.shape[0])
        sh_a[s, :w] = np.asarray(ix.sorted_hash)
        df_a[s, :w] = np.asarray(ix.df)
        offs_a[s, :w + 1] = np.asarray(ix.block_offsets)
        offs_a[s, w + 1:] = offs_a[s, w]
        bd[s, :nb] = np.asarray(ix.block_docs)
        bt[s, :nb] = np.asarray(ix.block_tfs)
        tf_a[s, :nb] = np.asarray(ix.tile_first)
        tc_a[s, :nb] = np.asarray(ix.tile_count)
    return BlockedTermShardedIndex(
        sorted_hash=sh_a, df=df_a, block_offsets=offs_a,
        block_docs=bd, block_tfs=bt, tile_first=tf_a, tile_count=tc_a,
        norm=host.norm.astype(np.float32), n_shards=S,
        num_docs=host.num_docs, tile=layouts.ROUTE_TILE,
        max_blocks_per_term=max(ix.max_blocks_per_term for ix in shards),
        route_span_max=max(ix.route_span_max for ix in shards),
        route_pairs_max=max(ix.route_pairs_max for ix in shards),
    )


@dataclasses.dataclass
class PackedTermShardedIndex:
    """Stacked per-vocab-shard delta+bit-packed arrays for the fused
    engine — the compressed twin of ``BlockedTermShardedIndex``.

    Each shard owns a contiguous hash range of the vocabulary as whole
    posting lists, re-compressed per shard: doc-id deltas bit-packed at
    a per-block width (GLOBAL doc ids, so the doc/tile space is the full
    corpus and identical on every shard), f16 tfs, plus the per-block
    decode scalars and the build-time (block -> doc-tile) routing cache.
    The fused kernel decodes blocks IN VMEM, so the compressed words are
    the only posting bytes a query moves across HBM per shard.
    """
    sorted_hash: np.ndarray    # u32[S, Wmax]  (padded with 0xFFFFFFFF)
    df: np.ndarray             # i32[S, Wmax]  global df (terms are whole)
    block_offsets: np.ndarray  # i32[S, Wmax+1]
    packed: np.ndarray         # u32[S, NBmax, WPB]  bit-packed deltas
    block_tfs: np.ndarray      # f16[S, NBmax, BLOCK]
    block_bits: np.ndarray     # i32[S, NBmax]  (1 on padding blocks)
    block_base: np.ndarray     # i32[S, NBmax]
    block_count: np.ndarray    # i32[S, NBmax]  (0 on padding blocks)
    tile_first: np.ndarray     # i32[S, NBmax]
    tile_count: np.ndarray     # i32[S, NBmax]
    norm: np.ndarray           # f32[D] (replicated)
    n_shards: int
    num_docs: int
    tile: int
    block: int
    words_per_block: int
    max_blocks_per_term: int
    route_span_max: int
    route_pairs_max: int

    def device_arrays(self) -> dict:
        return {f.name: jnp.asarray(getattr(self, f.name))
                for f in dataclasses.fields(self)
                if isinstance(getattr(self, f.name), np.ndarray)}


def _term_shard_subhosts(host: PostingsHost, n_shards: int):
    """Slice the global posting lists into per-vocab-shard PostingsHost
    sub-indexes (contiguous hash ranges, whole lists, GLOBAL doc ids) —
    the one slicing both term-sharded builders share, so the HOR and
    packed structures see identical per-shard term order and block
    boundaries (that is what makes the two engines bit-identical)."""
    order = np.argsort(host.term_hashes, kind="stable")
    W = host.num_terms
    bounds = np.linspace(0, W, n_shards + 1).astype(np.int64)
    subs = []
    for s in range(n_shards):
        terms = order[bounds[s]:bounds[s + 1]]
        lens = (host.offsets[terms + 1] - host.offsets[terms]).astype(np.int64)
        offs = np.zeros(len(terms) + 1, dtype=np.int64)
        np.cumsum(lens, out=offs[1:])
        docs = np.zeros(int(offs[-1]), np.int32)
        tfs = np.zeros(int(offs[-1]), np.float32)
        for i, t in enumerate(terms):
            a, bnd = host.offsets[t], host.offsets[t + 1]
            docs[offs[i]:offs[i + 1]] = host.doc_ids[a:bnd]
            tfs[offs[i]:offs[i + 1]] = host.tfs[a:bnd]
        subs.append(PostingsHost(term_hashes=host.term_hashes[terms],
                                 df=host.df[terms].astype(np.int32),
                                 offsets=offs, doc_ids=docs, tfs=tfs,
                                 num_docs=host.num_docs,
                                 norm=host.norm, rank=host.rank))
    wmax = int(np.max(np.diff(bounds)))
    return subs, wmax


def build_term_sharded_packed(host: PostingsHost, n_shards: int
                              ) -> PackedTermShardedIndex:
    """Per-vocab-shard re-compression: slice the global posting lists
    per hash range, then delta+bit-pack each shard's lists (global doc
    ids, per-block minimal widths) — so the term-partitioned read path
    streams compressed bytes only, like the single-node packed engine."""
    subs, wmax = _term_shard_subhosts(host, n_shards)
    shards = [layouts.build_packed_csr(sub) for sub in subs]
    block = shards[0].block
    nbmax = max(int(ix.packed.shape[0]) for ix in shards)
    wpb = max(ix.words_per_block for ix in shards)
    S = n_shards
    sh_a = np.full((S, wmax), 0xFFFFFFFF, np.uint32)
    df_a = np.zeros((S, wmax), np.int32)
    offs_a = np.zeros((S, wmax + 1), np.int32)
    pk = np.zeros((S, nbmax, wpb), np.uint32)
    bt = np.zeros((S, nbmax, block), np.float16)
    bits_a = np.ones((S, nbmax), np.int32)     # padding blocks decode inert
    base_a = np.zeros((S, nbmax), np.int32)
    cnt_a = np.zeros((S, nbmax), np.int32)
    tf_a = np.zeros((S, nbmax), np.int32)
    tc_a = np.zeros((S, nbmax), np.int32)
    for s, ix in enumerate(shards):
        w = int(ix.sorted_hash.shape[0])
        nb = int(ix.packed.shape[0])
        sh_a[s, :w] = np.asarray(ix.sorted_hash)
        df_a[s, :w] = np.asarray(ix.df)
        offs_a[s, :w + 1] = np.asarray(ix.block_offsets)
        offs_a[s, w + 1:] = offs_a[s, w]
        pk[s, :nb, :ix.words_per_block] = np.asarray(ix.packed)
        bt[s, :nb] = np.asarray(ix.block_tfs)
        bits_a[s, :nb] = np.asarray(ix.block_bits)
        base_a[s, :nb] = np.asarray(ix.block_base)
        cnt_a[s, :nb] = np.asarray(ix.block_count)
        tf_a[s, :nb] = np.asarray(ix.tile_first)
        tc_a[s, :nb] = np.asarray(ix.tile_count)
    return PackedTermShardedIndex(
        sorted_hash=sh_a, df=df_a, block_offsets=offs_a, packed=pk,
        block_tfs=bt, block_bits=bits_a, block_base=base_a,
        block_count=cnt_a, tile_first=tf_a, tile_count=tc_a,
        norm=host.norm.astype(np.float32), n_shards=S,
        num_docs=host.num_docs, tile=layouts.ROUTE_TILE, block=block,
        words_per_block=wpb,
        max_blocks_per_term=max(ix.max_blocks_per_term for ix in shards),
        route_span_max=max(ix.route_span_max for ix in shards),
        route_pairs_max=max(ix.route_pairs_max for ix in shards),
    )


@dataclasses.dataclass
class BandedTermShardedIndex:
    """Stacked per-vocab-shard BANDED arrays for the fused engine.

    Each shard re-bands its hash range with the byte model
    (``layouts.build_banded``): high-df terms pack into that shard's
    packed band at a band-local word stride, the decode-bound tail
    stays HOR.  Terms are whole, so every query term's postings live
    entirely in ONE band of one shard — the scorer sums the two dense
    band partials locally BEFORE the cross-shard psum, keeping the
    term-sharding tax at one [D] reduction exactly like the
    single-layout twins.  The un-prefixed block arrays are the packed
    band's; the HOR band rides under ``hor_*``.
    """
    sorted_hash: np.ndarray        # u32[S, Wmax]  (padded with 0xFFFFFFFF)
    df: np.ndarray                 # i32[S, Wmax]  global df (whole terms)
    block_offsets: np.ndarray      # i32[S, Wmax+1]   packed band
    packed: np.ndarray             # u32[S, NBmax, WPB]
    block_tfs: np.ndarray          # f16[S, NBmax, BLOCK]
    block_bits: np.ndarray         # i32[S, NBmax]  (1 on padding blocks)
    block_base: np.ndarray         # i32[S, NBmax]
    block_count: np.ndarray        # i32[S, NBmax]  (0 on padding blocks)
    tile_first: np.ndarray         # i32[S, NBmax]
    tile_count: np.ndarray         # i32[S, NBmax]
    hor_block_offsets: np.ndarray  # i32[S, Wmax+1]   hor band
    hor_block_docs: np.ndarray     # i32[S, HNBmax, BLOCK]
    hor_block_tfs: np.ndarray      # f32[S, HNBmax, BLOCK]
    hor_tile_first: np.ndarray     # i32[S, HNBmax]
    hor_tile_count: np.ndarray     # i32[S, HNBmax]
    norm: np.ndarray               # f32[D] (replicated)
    n_shards: int
    num_docs: int
    tile: int
    block: int
    words_per_block: int
    max_blocks_per_term: int
    route_span_max: int
    route_pairs_max: int
    hor_max_blocks_per_term: int
    hor_route_span_max: int
    hor_route_pairs_max: int

    def device_arrays(self) -> dict:
        return {f.name: jnp.asarray(getattr(self, f.name))
                for f in dataclasses.fields(self)
                if isinstance(getattr(self, f.name), np.ndarray)}


def build_term_sharded_banded(host: PostingsHost, n_shards: int
                              ) -> BandedTermShardedIndex:
    """Per-vocab-shard banding over the SAME slicing as the hor/packed
    term-sharded builders — identical per-shard term order, so a query
    term resolves to the same shard regardless of layout."""
    subs, wmax = _term_shard_subhosts(host, n_shards)
    shards = [layouts.build_banded(sub) for sub in subs]
    block = shards[0].block
    nbmax = max(int(ix.packed.packed.shape[0]) for ix in shards)
    hnbmax = max(int(ix.hor.block_docs.shape[0]) for ix in shards)
    wpb = max(ix.packed.words_per_block for ix in shards)
    S = n_shards
    sh_a = np.full((S, wmax), 0xFFFFFFFF, np.uint32)
    df_a = np.zeros((S, wmax), np.int32)
    offs_a = np.zeros((S, wmax + 1), np.int32)
    pk = np.zeros((S, nbmax, wpb), np.uint32)
    bt = np.zeros((S, nbmax, block), np.float16)
    bits_a = np.ones((S, nbmax), np.int32)     # padding blocks decode inert
    base_a = np.zeros((S, nbmax), np.int32)
    cnt_a = np.zeros((S, nbmax), np.int32)
    tf_a = np.zeros((S, nbmax), np.int32)
    tc_a = np.zeros((S, nbmax), np.int32)
    h_offs_a = np.zeros((S, wmax + 1), np.int32)
    h_bd = np.full((S, hnbmax, block), -1, np.int32)
    h_bt = np.zeros((S, hnbmax, block), np.float32)
    h_tf_a = np.zeros((S, hnbmax), np.int32)
    h_tc_a = np.zeros((S, hnbmax), np.int32)
    for s, ix in enumerate(shards):
        p, h = ix.packed, ix.hor
        w = int(p.sorted_hash.shape[0])
        nb = int(p.packed.shape[0])
        hnb = int(h.block_docs.shape[0])
        sh_a[s, :w] = np.asarray(p.sorted_hash)
        df_a[s, :w] = np.asarray(ix.df)
        offs_a[s, :w + 1] = np.asarray(p.block_offsets)
        offs_a[s, w + 1:] = offs_a[s, w]
        pk[s, :nb, :p.words_per_block] = np.asarray(p.packed)
        bt[s, :nb] = np.asarray(p.block_tfs)
        bits_a[s, :nb] = np.asarray(p.block_bits)
        base_a[s, :nb] = np.asarray(p.block_base)
        cnt_a[s, :nb] = np.asarray(p.block_count)
        tf_a[s, :nb] = np.asarray(p.tile_first)
        tc_a[s, :nb] = np.asarray(p.tile_count)
        h_offs_a[s, :w + 1] = np.asarray(h.block_offsets)
        h_offs_a[s, w + 1:] = h_offs_a[s, w]
        h_bd[s, :hnb] = np.asarray(h.block_docs)
        h_bt[s, :hnb] = np.asarray(h.block_tfs)
        h_tf_a[s, :hnb] = np.asarray(h.tile_first)
        h_tc_a[s, :hnb] = np.asarray(h.tile_count)
    return BandedTermShardedIndex(
        sorted_hash=sh_a, df=df_a, block_offsets=offs_a, packed=pk,
        block_tfs=bt, block_bits=bits_a, block_base=base_a,
        block_count=cnt_a, tile_first=tf_a, tile_count=tc_a,
        hor_block_offsets=h_offs_a, hor_block_docs=h_bd,
        hor_block_tfs=h_bt, hor_tile_first=h_tf_a, hor_tile_count=h_tc_a,
        norm=host.norm.astype(np.float32), n_shards=S,
        num_docs=host.num_docs, tile=layouts.ROUTE_TILE, block=block,
        words_per_block=wpb,
        max_blocks_per_term=max(ix.packed.max_blocks_per_term
                                for ix in shards),
        route_span_max=max(ix.packed.route_span_max for ix in shards),
        route_pairs_max=max(ix.packed.route_pairs_max for ix in shards),
        hor_max_blocks_per_term=max(ix.hor.max_blocks_per_term
                                    for ix in shards),
        hor_route_span_max=max(ix.hor.route_span_max for ix in shards),
        hor_route_pairs_max=max(ix.hor.route_pairs_max for ix in shards),
    )


def build_term_sharded_from_view(view, n_shards: int,
                                 layout: str = "hor"):
    """Term-partition an epoch-pinned ``LiveView``: bulk-build the
    view's live corpus and shard the vocabulary.

    Returns ``(index, live_ids)`` — the fused term-sharded index over
    the COMPACT live-doc space plus the ascending global ids that map
    compact results back (ascending, so exact-score ties still break on
    lowest global doc id after the mapping).  This is the serving
    tier's alternate topology: unlike the segment-stack path it
    re-builds (and re-compiles for new shapes) per epoch, which is the
    right trade only when the corpus is near-static between handoffs.
    """
    from repro.core import build
    tc_live, live_ids = view.export_live_corpus()
    builder = {"packed": build_term_sharded_packed,
               "banded": build_term_sharded_banded}.get(
                   layout, build_term_sharded_blocked)
    host = build.bulk_build(tc_live)
    return builder(host, n_shards), np.asarray(live_ids, np.int64)


def make_term_sharded_fused_scorer(
        index: (BlockedTermShardedIndex | PackedTermShardedIndex
                | BandedTermShardedIndex),
        mesh: Mesh, axis: str, k: int = 10, cap: int | None = None,
        return_stats: bool = False):
    """jit fn(query_hashes u32[T]) -> (scores[k], global doc ids[k]).

    Term-partitioned fused engine: each shard scores only the query
    terms it owns through the fused Pallas kernel (partial scores over
    the GLOBAL doc space; HOR blocks read in place, packed blocks
    decoded IN VMEM so only compressed bytes cross HBM), pays the
    term-sharding tax — a full [D] psum of partials — then the candidate
    tier takes over: every shard reduces its 1/S slice of the doc-tile
    grid to per-tile candidates and an all-gather candidate merge yields
    the global top-k, so the post-psum ranking tail is candidate-sized
    instead of dense.

    ``cap`` bounds postings read per term at posting granularity (the
    oracle's gather cap); with ``return_stats=True`` the scorer returns
    ``((scores, ids), stats)`` where ``stats["truncated_terms"]`` counts
    query terms whose posting list exceeded ``cap`` — AGGREGATED across
    shards with a psum, the same way the multi-segment conjunctive sums
    its per-segment truncation counters, so truncation on ANY shard is
    surfaced."""
    from repro.distributed.topk import local_candidate_merge
    from repro.kernels import autotune
    from repro.kernels.fused_decode_score import (
        build_batched_pairs, default_k_tile,
        extract_tile_candidates, fused_score_blocked_pallas,
        fused_score_packed_pallas)
    from repro.kernels.ops import (expand_block_candidates,
                                    record_truncated, warn_on_overflow)

    packed_layout = isinstance(index, PackedTermShardedIndex)
    banded_layout = isinstance(index, BandedTermShardedIndex)
    lay = ("banded" if banded_layout
           else "packed" if packed_layout else "hor")
    arrs = index.device_arrays()
    num_docs, tile = index.num_docs, index.tile
    n_tiles = max(-(-num_docs // tile), 1)
    S = index.n_shards
    block = (index.block if packed_layout or banded_layout
             else int(index.block_docs.shape[-1]))
    m_blocks = max(index.max_blocks_per_term, 1)
    m_blocks_h = (max(index.hor_max_blocks_per_term, 1) if banded_layout
                  else 0)
    if cap is not None:
        m_blocks = max(min(m_blocks, -(-cap // block)), 1)
        m_blocks_h = max(min(m_blocks_h, -(-cap // block)), 1)
    # dense-score kernels: only the routing-free geometry (query-lane pad
    # and candidate quantum) follows the tuning table here
    cfg = autotune.lookup("pallas", num_docs, lay)
    q_pad = cfg.q_pad
    if cfg.tile == tile:
        k_tile = cfg.resolve_k_tile(k)
    else:
        k_tile = min(default_k_tile(k, tile, cfg.k_pad), tile)
    # per-shard slice of the tile grid for candidate extraction
    tiles_per = -(-n_tiles // S)
    chunk = tiles_per * tile

    names = ("sorted_hash", "df", "block_offsets", "tile_first",
             "tile_count")
    if banded_layout:
        names += ("packed", "block_tfs", "block_bits", "block_base",
                  "block_count", "hor_block_offsets", "hor_block_docs",
                  "hor_block_tfs", "hor_tile_first", "hor_tile_count")
    elif packed_layout:
        names += ("packed", "block_tfs", "block_bits", "block_base",
                  "block_count")
    else:
        names += ("block_docs", "block_tfs")
    sharded = {n: P(axis) for n in names}
    sharded["norm"] = P()

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(sharded, P()), out_specs=(P(), P(), P()),
        check_vma=False)
    def score(ix, qh):
        sq = {n: (v[0] if n != "norm" else v) for n, v in ix.items()}
        qh = dedup_query_hashes(qh)
        t = qh.shape[0]
        pos = jnp.searchsorted(sq["sorted_hash"], qh).astype(jnp.int32)
        pos = jnp.clip(pos, 0, sq["sorted_hash"].shape[0] - 1)
        hit = (sq["sorted_hash"][pos] == qh) & (qh != 0)
        tid = jnp.where(hit, pos, -1)       # terms NOT on this shard miss
        df = jnp.where(hit, sq["df"][pos], 0)
        w = idf_fn(df, num_docs)
        if cap is not None:
            # cap truncation on ANY shard is surfaced, never swallowed:
            # per-shard counts psum like the multi-segment conjunctive
            trunc = jax.lax.psum(
                jnp.sum((hit & (df > cap)).astype(jnp.int32)), axis)
        else:
            trunc = jnp.int32(0)

        cand_block, cand_valid, cand_q, cand_w, cand_cap = \
            expand_block_candidates(sq["block_offsets"], tid[None],
                                    w[None], m_blocks, block, cap=cap)
        max_pairs = max(min(index.route_pairs_max,
                            t * m_blocks * max(index.route_span_max, 1)), 8)
        pb, pt, pqw, pcap, ovf = build_batched_pairs(
            cand_block, cand_valid, cand_q, cand_w,
            sq["tile_first"], sq["tile_count"], n_tiles, 1, max_pairs,
            cand_cap=cand_cap)
        warn_on_overflow(ovf, "term-sharded fused engine")
        pqw = jnp.pad(pqw, ((0, 0), (0, q_pad - 1)))
        if packed_layout or banded_layout:
            partial = fused_score_packed_pallas(
                sq["packed"], sq["block_tfs"], pb, pt, pqw, pcap,
                sq["block_bits"][pb], sq["block_base"][pb],
                sq["block_count"][pb], num_docs, block, tile)[0]
        else:
            partial = fused_score_blocked_pallas(
                sq["block_docs"], sq["block_tfs"], pb, pt, pqw, pcap,
                num_docs, tile)[0]
        if banded_layout:
            # every term is wholly in one band, so the HOR-band pass
            # scores exactly the terms the packed band skipped; the two
            # dense partials sum locally BEFORE the cross-shard psum
            cand_block, cand_valid, cand_q, cand_w, cand_cap = \
                expand_block_candidates(sq["hor_block_offsets"], tid[None],
                                        w[None], m_blocks_h, block, cap=cap)
            mp_h = max(min(index.hor_route_pairs_max,
                           t * m_blocks_h
                           * max(index.hor_route_span_max, 1)), 8)
            pb, pt, pqw, pcap, ovf = build_batched_pairs(
                cand_block, cand_valid, cand_q, cand_w,
                sq["hor_tile_first"], sq["hor_tile_count"], n_tiles, 1,
                mp_h, cand_cap=cand_cap)
            warn_on_overflow(ovf, "term-sharded fused engine")
            pqw = jnp.pad(pqw, ((0, 0), (0, q_pad - 1)))
            partial = partial + fused_score_blocked_pallas(
                sq["hor_block_docs"], sq["hor_block_tfs"], pb, pt, pqw,
                pcap, num_docs, tile)[0]
        # THE term-partitioned cost: a full [D] psum across shards
        scores = jax.lax.psum(partial, axis)
        qn2 = jax.lax.psum(jnp.sum(w * w), axis)
        qnorm = jnp.sqrt(jnp.maximum(qn2, 1e-12))
        live = sq["norm"] > 0
        final = jnp.where(live & (scores > 0),
                          scores / (jnp.maximum(sq["norm"], 1e-12) * qnorm),
                          -jnp.inf)
        s_idx = jax.lax.axis_index(axis)
        fpad = jnp.pad(final, (0, S * chunk - num_docs),
                       constant_values=-jnp.inf)
        local = jax.lax.dynamic_slice(fpad, (s_idx * chunk,), (chunk,))
        v, ids = extract_tile_candidates(local[None], tile, k_tile)
        gids = jnp.where(ids[0] >= 0, ids[0] + s_idx * chunk, -1)
        vv, ii = local_candidate_merge(v[0], gids, k, axis)
        return vv, ii, trunc

    fn = jax.jit(lambda qh: score(arrs, qh))

    def run(qh, trace=None):
        if trace is None:
            return fn(qh)
        span = trace.span("shard_fanout", parent="score", n_shards=S,
                          k=k, sharding="term", layout=lay)
        out = fn(qh)
        span.end()
        sync = trace.span("shard_sync", parent="score")
        out = jax.block_until_ready(out)
        sync.end()
        return out

    if return_stats:
        def with_stats(qh, trace=None):
            vv, ii, trunc = run(qh, trace=trace)
            trunc = int(trunc)
            record_truncated(trunc)
            return (vv, ii), {"truncated_terms": trunc}
        return with_stats

    def scorer(qh, trace=None):
        return run(qh, trace=trace)[:2]
    return scorer
