"""Distributed index engine: document- vs term-partitioned sharding.

The paper's index is a single-node PSQL database; at cluster scale an
index shards one of two ways, and the choice decides the collective
pattern (this is the multi-pod story for the paper's own workload):

  * DOCUMENT-partitioned (``DocShardedIndex``): each shard holds the
    full vocabulary over a slice of documents.  A query broadcasts to
    all shards (cheap: a few u32 hashes), every shard evaluates
    q_word/q_occ/q_doc locally over its CSR slice, and the global
    answer is a distributed top-k merge (all-gather of k candidates per
    shard).  Collective bytes ~ S·k·8 per query — independent of corpus
    size.  This is how every production engine shards, and the ``pod``
    axis document-partitions across pods.

  * TERM-partitioned (``TermShardedIndex``): each shard owns a hash
    range of the vocabulary (whole posting lists).  A query touches only
    the shards owning its terms, but per-document partial scores must be
    psum'd across shards: collective bytes ~ D·4 per query batch.  Wins
    only when queries are single-term or the document space is tiny —
    we implement both so the benchmark can show the crossover.

Both are shard_map programs over stacked, padded per-shard CSR arrays
(the paper's OR layout, sliced and re-packed per shard).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import segments
from repro.core.layouts import PostingsHost
from repro.core.query import idf as idf_fn
from repro.distributed.topk import local_topk_merge

Array = jax.Array


# ---------------------------------------------------------------------------
# document-partitioned
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DocShardedIndex:
    """Stacked per-shard CSR arrays (leading dim = shard)."""
    sorted_hash: np.ndarray   # u32[S, W]      (vocab replicated per shard)
    df_local: np.ndarray      # i32[S, W]      per-shard document frequency
    df_global: np.ndarray     # i32[S, W]      global df (same every shard)
    offsets: np.ndarray       # i32[S, W+1]
    doc_ids: np.ndarray       # i32[S, Pmax]   LOCAL doc ids
    tfs: np.ndarray           # f32[S, Pmax]
    norm: np.ndarray          # f32[S, Dmax]
    doc_base: np.ndarray      # i32[S]         global id of local doc 0
    n_shards: int
    num_docs: int
    cap: int                  # max local posting length

    def device_arrays(self) -> dict:
        return {k: jnp.asarray(v) for k, v in dataclasses.asdict(self).items()
                if isinstance(v, np.ndarray)}


def build_doc_sharded(host: PostingsHost, n_shards: int) -> DocShardedIndex:
    order = np.argsort(host.term_hashes, kind="stable")
    sorted_hash = host.term_hashes[order]
    W = host.num_terms
    bounds = np.linspace(0, host.num_docs, n_shards + 1).astype(np.int64)
    term_of = np.repeat(np.arange(W, dtype=np.int64),
                        np.diff(host.offsets))

    sh_offsets, sh_docs, sh_tfs, sh_df = [], [], [], []
    dmax = int(np.max(np.diff(bounds)))
    cap = 0
    for s in range(n_shards):
        lo, hi = bounds[s], bounds[s + 1]
        m = (host.doc_ids >= lo) & (host.doc_ids < hi)
        t = term_of[m][np.argsort(term_of[m], kind="stable")]
        sel = np.argsort(term_of[m], kind="stable")
        docs = (host.doc_ids[m][sel] - lo).astype(np.int32)
        tfs = host.tfs[m][sel]
        df = np.bincount(t, minlength=W).astype(np.int32)
        # reorder terms into hash-sorted order (COR-style fused lookup)
        df_sorted = df[order]
        offs = np.zeros(W + 1, dtype=np.int64)
        np.cumsum(df_sorted, out=offs[1:])
        # postings re-packed in hash-sorted term order
        packed_docs = np.zeros(len(docs), np.int32)
        packed_tfs = np.zeros(len(docs), np.float32)
        src_offs = np.zeros(W + 1, dtype=np.int64)
        np.cumsum(df, out=src_offs[1:])
        for newpos, old in enumerate(order):
            a, bnd = src_offs[old], src_offs[old + 1]
            c = offs[newpos]
            packed_docs[c:c + bnd - a] = docs[a:bnd]
            packed_tfs[c:c + bnd - a] = tfs[a:bnd]
        sh_offsets.append(offs)
        sh_docs.append(packed_docs)
        sh_tfs.append(packed_tfs)
        sh_df.append(df_sorted)
        cap = max(cap, int(df_sorted.max()) if W else 0)

    pmax = max(len(x) for x in sh_docs)
    S = n_shards
    docs_a = np.zeros((S, pmax), np.int32)
    tfs_a = np.zeros((S, pmax), np.float32)
    offs_a = np.zeros((S, W + 1), np.int32)
    df_a = np.zeros((S, W), np.int32)
    norm_a = np.zeros((S, dmax), np.float32)
    for s in range(S):
        docs_a[s, :len(sh_docs[s])] = sh_docs[s]
        tfs_a[s, :len(sh_tfs[s])] = sh_tfs[s]
        offs_a[s] = sh_offsets[s]
        df_a[s] = sh_df[s]
        lo, hi = bounds[s], bounds[s + 1]
        norm_a[s, :hi - lo] = host.norm[lo:hi]
    df_glob = np.broadcast_to(host.df[order][None, :], (S, W)).copy()
    return DocShardedIndex(
        sorted_hash=np.broadcast_to(sorted_hash[None, :], (S, W)).copy(),
        df_local=df_a, df_global=df_glob.astype(np.int32),
        offsets=offs_a, doc_ids=docs_a, tfs=tfs_a, norm=norm_a,
        doc_base=bounds[:-1].astype(np.int32), n_shards=S,
        num_docs=host.num_docs, cap=cap)


def make_doc_sharded_scorer(index: DocShardedIndex, mesh: Mesh, axis: str,
                            k: int = 10):
    """jit fn(query_hashes u32[T]) -> (scores[k], global doc ids[k])."""
    arrs = index.device_arrays()
    cap = max(index.cap, 1)
    dmax = arrs["norm"].shape[1]
    num_docs = index.num_docs

    sharded = {n: P(axis) for n in
               ("sorted_hash", "df_local", "df_global", "offsets",
                "doc_ids", "tfs", "norm", "doc_base")}

    @functools.partial(
        jax.shard_map, mesh=mesh,
        in_specs=(sharded, P()), out_specs=(P(), P()), check_vma=False)
    def score(ix, qh):
        sq = {n: v[0] for n, v in ix.items()}    # drop shard dim
        pos = jnp.searchsorted(sq["sorted_hash"], qh).astype(jnp.int32)
        pos = jnp.clip(pos, 0, sq["sorted_hash"].shape[0] - 1)
        hit = (sq["sorted_hash"][pos] == qh) & (qh != 0)
        tid = jnp.where(hit, pos, -1)
        # idf uses GLOBAL df — scoring must match the single-node engine
        df_g = jnp.where(hit, sq["df_global"][pos], 0)
        w = idf_fn(df_g, num_docs)
        safe = jnp.maximum(tid, 0)
        d, v = segments.gather_segments(sq["doc_ids"], sq["offsets"], safe,
                                        cap, fill=-1)
        t, _ = segments.gather_segments(sq["tfs"], sq["offsets"], safe, cap,
                                        fill=0.0)
        valid = v & (tid >= 0)[:, None]
        weights = t * w[:, None]
        flat_d = jnp.where(valid, d, dmax).reshape(-1)
        acc = jnp.zeros((dmax + 1,), jnp.float32)
        acc = acc.at[flat_d].add(jnp.where(valid, weights, 0.0).reshape(-1),
                                 mode="drop")
        scores = acc[:dmax]
        qnorm = jnp.sqrt(jnp.maximum(jnp.sum(w * w), 1e-12))
        live = sq["norm"] > 0
        final = jnp.where(live & (scores > 0),
                          scores / (jnp.maximum(sq["norm"], 1e-12) * qnorm),
                          -jnp.inf)
        vv, ids = local_topk_merge(final, k, axis, sq["doc_base"])
        return vv, ids

    return jax.jit(lambda qh: score(arrs, qh))


# ---------------------------------------------------------------------------
# term-partitioned
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TermShardedIndex:
    sorted_hash: np.ndarray  # u32[S, Wmax]  (hash-range partition, padded)
    df: np.ndarray           # i32[S, Wmax]
    offsets: np.ndarray      # i32[S, Wmax+1]
    doc_ids: np.ndarray      # i32[S, Pmax]  GLOBAL doc ids
    tfs: np.ndarray          # f32[S, Pmax]
    norm: np.ndarray         # f32[D] (replicated)
    n_shards: int
    num_docs: int
    cap: int

    def device_arrays(self) -> dict:
        return {k: jnp.asarray(v) for k, v in dataclasses.asdict(self).items()
                if isinstance(v, np.ndarray)}


def build_term_sharded(host: PostingsHost, n_shards: int) -> TermShardedIndex:
    order = np.argsort(host.term_hashes, kind="stable")
    W = host.num_terms
    # contiguous hash-range partition of the sorted vocabulary
    bounds = np.linspace(0, W, n_shards + 1).astype(np.int64)
    wmax = int(np.max(np.diff(bounds)))
    sh = []
    pmax = 0
    for s in range(n_shards):
        terms = order[bounds[s]:bounds[s + 1]]
        lens = (host.offsets[terms + 1] - host.offsets[terms]).astype(np.int64)
        offs = np.zeros(wmax + 1, dtype=np.int64)
        np.cumsum(lens, out=offs[1:len(lens) + 1])
        offs[len(lens) + 1:] = offs[len(lens)]
        total = int(offs[len(lens)])
        docs = np.zeros(total, np.int32)
        tfs = np.zeros(total, np.float32)
        for i, t in enumerate(terms):
            a, bnd = host.offsets[t], host.offsets[t + 1]
            docs[offs[i]:offs[i + 1]] = host.doc_ids[a:bnd]
            tfs[offs[i]:offs[i + 1]] = host.tfs[a:bnd]
        hashes = np.full(wmax, 0xFFFFFFFF, np.uint32)
        hashes[:len(terms)] = host.term_hashes[terms]
        dfs = np.zeros(wmax, np.int32)
        dfs[:len(terms)] = host.df[terms]
        sh.append((hashes, dfs, offs, docs, tfs))
        pmax = max(pmax, total)
    S = n_shards
    out = TermShardedIndex(
        sorted_hash=np.stack([x[0] for x in sh]),
        df=np.stack([x[1] for x in sh]),
        offsets=np.stack([x[2] for x in sh]).astype(np.int32),
        doc_ids=np.zeros((S, pmax), np.int32),
        tfs=np.zeros((S, pmax), np.float32),
        norm=host.norm, n_shards=S, num_docs=host.num_docs,
        cap=int(host.max_posting_len))
    for s, (_, _, _, docs, tfs) in enumerate(sh):
        out.doc_ids[s, :len(docs)] = docs
        out.tfs[s, :len(tfs)] = tfs
    return out


def make_term_sharded_scorer(index: TermShardedIndex, mesh: Mesh, axis: str,
                             k: int = 10):
    arrs = index.device_arrays()
    cap = max(index.cap, 1)
    num_docs = index.num_docs

    sharded = {n: P(axis) for n in
               ("sorted_hash", "df", "offsets", "doc_ids", "tfs")}
    sharded["norm"] = P()

    @functools.partial(
        jax.shard_map, mesh=mesh,
        in_specs=(sharded, P()), out_specs=(P(), P()), check_vma=False)
    def score(ix, qh):
        sq = {n: (v[0] if n != "norm" else v) for n, v in ix.items()}
        pos = jnp.searchsorted(sq["sorted_hash"], qh).astype(jnp.int32)
        pos = jnp.clip(pos, 0, sq["sorted_hash"].shape[0] - 1)
        hit = (sq["sorted_hash"][pos] == qh) & (qh != 0)
        tid = jnp.where(hit, pos, -1)       # terms NOT on this shard miss
        df = jnp.where(hit, sq["df"][pos], 0)
        w = idf_fn(df, num_docs)
        safe = jnp.maximum(tid, 0)
        d, v = segments.gather_segments(sq["doc_ids"], sq["offsets"], safe,
                                        cap, fill=-1)
        t, _ = segments.gather_segments(sq["tfs"], sq["offsets"], safe, cap,
                                        fill=0.0)
        valid = v & (tid >= 0)[:, None]
        flat_d = jnp.where(valid, d, num_docs).reshape(-1)
        acc = jnp.zeros((num_docs + 1,), jnp.float32)
        acc = acc.at[flat_d].add(
            jnp.where(valid, t * w[:, None], 0.0).reshape(-1), mode="drop")
        partial = acc[:num_docs]
        # THE term-partitioned cost: a full [D] psum across shards
        scores = jax.lax.psum(partial, axis)
        qn2 = jax.lax.psum(jnp.sum(w * w), axis)
        qnorm = jnp.sqrt(jnp.maximum(qn2, 1e-12))
        live = sq["norm"] > 0
        final = jnp.where(live & (scores > 0),
                          scores / (jnp.maximum(sq["norm"], 1e-12) * qnorm),
                          -jnp.inf)
        vv, ii = jax.lax.top_k(final, k)
        return vv, ii

    return jax.jit(lambda qh: score(arrs, qh))
