from repro.distributed import compress, decode_attn, retrieval, topk  # noqa: F401
