"""Distributed top-k merge (document-partitioned retrieval).

Each shard scores its local documents and keeps a local top-k; the
global answer is the top-k of the all-gathered per-shard candidates —
k·n_shards values instead of the full score vector, which is the
standard scatter-gather trick every production search tier uses.

Implemented with shard_map + jax.lax collectives, so it composes with
the retrieval engine in distributed/retrieval.py and with the recsys
``retrieval_cand`` cells.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.distributed.shmap import shard_map
from jax.sharding import Mesh, PartitionSpec as P

Array = jax.Array


def local_topk_merge(scores: Array, k: int, axis_name: str,
                     shard_offset: Array) -> tuple[Array, Array]:
    """Inside shard_map: scores f32[local_n] -> global (values, ids)[k].

    ``shard_offset``: scalar global id of this shard's first row.
    """
    v, i = jax.lax.top_k(scores, k)
    gids = i + shard_offset
    all_v = jax.lax.all_gather(v, axis_name)         # [S, k]
    all_g = jax.lax.all_gather(gids, axis_name)
    flat_v = all_v.reshape(-1)
    flat_g = all_g.reshape(-1)
    vv, ii = jax.lax.top_k(flat_v, k)
    return vv, flat_g[ii]


def sharded_topk(mesh: Mesh, axis: str, scores_spec: P = None):
    """Build a jit-able distributed top-k over a 1-D sharded score vector.

    Returns fn(scores f32[N]) -> (values f32[k], global_ids i32[k]).
    """
    spec = scores_spec if scores_spec is not None else P(axis)

    def make(k: int):
        @functools.partial(
            shard_map, mesh=mesh, in_specs=(spec,),
            out_specs=(P(), P()), check_vma=False)
        def fn(scores):
            local = scores.reshape(-1)
            idx = jax.lax.axis_index(axis)
            off = idx * local.shape[0]
            return local_topk_merge(local, k, axis, off)
        return fn

    return make
