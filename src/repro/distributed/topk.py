"""Distributed top-k merge (document-partitioned retrieval).

Each shard scores its local documents and keeps a local top-k; the
global answer is the top-k of the all-gathered per-shard candidates —
k·n_shards values instead of the full score vector, which is the
standard scatter-gather trick every production search tier uses.

``merge_topk_candidates`` is the pure (collective-free) core of that
merge: it is shared by the single-node fused engine's per-tile candidate
path (kernels/fused_decode_score.py reduces each doc tile to a small
candidate set in VMEM; the merge of those candidate lists is exactly a
shard merge with tiles playing the role of shards) and by the shard_map
scorers here.

Implemented with shard_map + jax.lax collectives, so it composes with
the retrieval engine in distributed/retrieval.py and with the recsys
``retrieval_cand`` cells.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.shmap import shard_map
from jax.sharding import Mesh, PartitionSpec as P

Array = jax.Array


def merge_topk_candidates_host(values, ids, k: int, trace=None):
    """numpy twin of ``merge_topk_candidates`` for host-side merges.

    ``values`` / ``ids``: lists of per-source candidate arrays
    ``[..., C_i]`` (ragged last axes allowed), concatenated in source
    order.  The segmented live index merges its per-segment candidate
    lists here so the merge tier never enters jit — the set of sealed
    segments can change every batch without triggering a recompile.

    Tie-breaking matches ``jax.lax.top_k`` (earliest candidate among
    equal values): a stable descending sort keeps the first occurrence
    first, so with sources ordered by ascending doc-id range the merged
    ranking tie-breaks on lowest global doc id, like the dense oracle.

    ``trace`` optionally records a ``"merge"`` child span (of
    ``"score"``) — note the span covers the device->host transfer of
    every source's candidates (the np.concatenate below is the sync
    point), which is exactly what an operator needs to see.
    """
    span = None
    if trace is not None:
        span = trace.span(
            "merge", parent="score", sources=len(values),
            candidates=int(sum(x.shape[-1] for x in ids)))
    v = np.concatenate([np.asarray(x, np.float32) for x in values], axis=-1)
    i = np.concatenate([np.asarray(x, np.int32) for x in ids], axis=-1)
    c = v.shape[-1]
    if c < k:
        pad = [(0, 0)] * (v.ndim - 1) + [(0, k - c)]
        v = np.pad(v, pad, constant_values=-np.inf)
        i = np.pad(i, pad, constant_values=-1)
    order = np.argsort(-v, axis=-1, kind="stable")[..., :k]
    out = (np.take_along_axis(v, order, axis=-1),
           np.take_along_axis(i, order, axis=-1))
    if span is not None:
        span.end()
    return out


def canonicalize_candidates(values: Array, ids: Array
                            ) -> tuple[Array, Array]:
    """Sort candidate lists by ascending doc id on the last axis.

    ``merge_topk_candidates`` tie-breaks on the EARLIEST candidate among
    equal values, so exact-tie parity with the dense oracle needs the
    concatenated lists in ascending doc-id order.  Sources that are
    naturally ascending (per-tile lists, contiguous shard runs) get that
    for free; sources that interleave doc ranges — the mixed hor+packed
    segment-stack groups, whose group-major concatenation is NOT doc
    ordered — must canonicalize first.  Invalid candidates (id -1,
    value -inf) sort to the front, where they only ever tie other
    -inf entries, so they cannot displace a real candidate.
    """
    order = jnp.argsort(ids, axis=-1, stable=True)
    return (jnp.take_along_axis(values, order, axis=-1),
            jnp.take_along_axis(ids, order, axis=-1))


def merge_topk_candidates(values: Array, ids: Array, k: int
                          ) -> tuple[Array, Array]:
    """Pure top-k merge of candidate (value, id) lists on the last axis.

    values f32[..., C], ids i32[..., C] — candidate lists from any
    partitioning (per-tile, per-shard, all-gathered...).  Pads with
    -inf / -1 when C < k, so ``k`` may exceed the candidate count.

    Tie-breaking: ``jax.lax.top_k`` keeps the EARLIEST candidate among
    equal values, so when candidate lists are ordered by ascending doc
    id (per-tile lists concatenated tile-major, each sorted descending
    with ascending-id ties), the merged ranking tie-breaks on lowest
    doc id — bit-identical to a dense ``top_k`` over all documents.
    """
    c = values.shape[-1]
    if c < k:
        pad = [(0, 0)] * (values.ndim - 1) + [(0, k - c)]
        values = jnp.pad(values, pad, constant_values=-jnp.inf)
        ids = jnp.pad(ids, pad, constant_values=-1)
    v, pos = jax.lax.top_k(values, k)
    return v, jnp.take_along_axis(ids, pos, axis=-1)


def local_topk_merge(scores: Array, k: int, axis_name: str,
                     shard_offset: Array) -> tuple[Array, Array]:
    """Inside shard_map: scores f32[local_n] -> global (values, ids)[k].

    ``shard_offset``: scalar global id of this shard's first row.
    ``k`` may exceed the shard's local length (``jax.lax.top_k``
    requires k <= n): the local top-k is clamped to the local size and
    padded with -inf values / -1 ids before the all-gather merge.
    """
    local_n = scores.shape[-1]
    kl = min(k, local_n)
    v, i = jax.lax.top_k(scores, kl)
    gids = i + shard_offset
    if kl < k:
        v = jnp.pad(v, (0, k - kl), constant_values=-jnp.inf)
        gids = jnp.pad(gids, (0, k - kl), constant_values=-1)
    return local_candidate_merge(v, gids, k, axis_name)


def local_candidate_merge(values: Array, ids: Array, k: int,
                          axis_name: str) -> tuple[Array, Array]:
    """Inside shard_map: merge per-shard candidate lists to a global
    top-k — the thin tier over any per-shard candidate extraction
    (dense local top-k or the fused engine's per-tile candidates).
    """
    all_v = jax.lax.all_gather(values, axis_name).reshape(-1)   # [S*C]
    all_g = jax.lax.all_gather(ids, axis_name).reshape(-1)
    return merge_topk_candidates(all_v, all_g, k)


def sharded_topk(mesh: Mesh, axis: str, scores_spec: P = None):
    """Build a jit-able distributed top-k over a 1-D sharded score vector.

    Returns fn(scores f32[N]) -> (values f32[k], global_ids i32[k]).
    """
    spec = scores_spec if scores_spec is not None else P(axis)

    def make(k: int):
        @functools.partial(
            shard_map, mesh=mesh, in_specs=(spec,),
            out_specs=(P(), P()), check_vma=False)
        def fn(scores):
            local = scores.reshape(-1)
            idx = jax.lax.axis_index(axis)
            off = idx * local.shape[0]
            return local_topk_merge(local, k, axis, off)
        return fn

    return make
