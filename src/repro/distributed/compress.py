"""Gradient compression: int8 all-to-all reduce-scatter with error feedback.

Wire math per device for an N-element f32 gradient over S shards:
  plain ring all-reduce   ~ 2·4N bytes
  int8 a2a reduce-scatter ~ N bytes (a2a) + N bytes (gather) = 2N bytes
-> ~4x fewer ICI bytes; quantization error is carried in a local
error-feedback buffer (1-bit-Adam style), so convergence is preserved.

``quantized_psum_mean`` runs INSIDE shard_map (explicit-DP training path;
see examples/train_lm.py --compress-grads).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.shmap import shard_map
from jax.sharding import Mesh, PartitionSpec as P

Array = jax.Array


def quantize_int8(x: Array) -> tuple[Array, Array]:
    """Per-tensor symmetric int8.  Returns (q, scale)."""
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: Array, scale: Array) -> Array:
    return q.astype(jnp.float32) * scale


def quantized_psum_mean(x: Array, axis: str, n_shards: int) -> Array:
    """Mean over ``axis`` with int8 wire format (inside shard_map).

    x f32[N] with N % n_shards == 0 (caller pads).
    """
    n = x.shape[0]
    chunks = x.reshape(n_shards, n // n_shards)
    q, scale = quantize_int8(chunks.reshape(-1))
    q = q.reshape(n_shards, n // n_shards)
    # each shard receives every peer's copy of ITS chunk (int8 wire)
    recv = jax.lax.all_to_all(q[:, None, :], axis, split_axis=0,
                              concat_axis=1, tiled=False)  # [1,S,chunk]
    scales = jax.lax.all_gather(scale, axis)               # [S]
    summed = (recv[0].astype(jnp.float32) *
              scales[:, None]).sum(axis=0) / n_shards      # local chunk mean
    q2, s2 = quantize_int8(summed)
    out = jax.lax.all_gather(q2, axis)                     # [S, chunk] int8
    out_s = jax.lax.all_gather(s2, axis)                   # [S]
    return (out.astype(jnp.float32) * out_s[:, None]).reshape(n)


def make_compressed_grad_fn(loss_fn, mesh: Mesh, axis: str):
    """Explicit-DP gradient step: per-shard grads -> int8 mean -> update.

    Error feedback: the quantization residual of THIS step is added to
    the NEXT step's gradient (carried as an extra state pytree).
    """
    n_shards = int(mesh.shape[axis])

    def grads_with_feedback(params, batch, err):
        loss, g = jax.value_and_grad(loss_fn)(params, batch)

        def one(gl, el):
            flat = gl.reshape(-1) + el.reshape(-1)
            n = flat.shape[0]
            pad = (-n) % n_shards
            flat_p = jnp.pad(flat, (0, pad))
            mean = quantized_psum_mean(flat_p, axis, n_shards)
            new_err = flat_p - mean          # residual kept locally
            return (mean[:n].reshape(gl.shape),
                    new_err[:n].reshape(gl.shape))

        out = jax.tree.map(one, g, err)
        new_g = jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_e = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        return loss, new_g, new_e

    def wrapped(params, batch, err):
        fn = shard_map(
            functools.partial(grads_with_feedback),
            mesh=mesh,
            in_specs=(P(), P(axis), P()),
            out_specs=(P(), P(), P()),
            check_vma=False)
        return fn(params, batch, err)

    return wrapped


def zeros_like_error(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
