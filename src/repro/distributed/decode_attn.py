"""Explicit split-K (flash-decoding style) distributed decode attention.

The GSPMD path (models/attention.decode_attention with a seq-sharded
cache) lets XLA derive the collectives; this shard_map version makes the
schedule EXPLICIT — each shard computes attention over its cache slice
with a local max/sum, and the combine is three small psums (max-shifted
numerator, denominator, running max), i.e. log-sum-exp merging — so the
wire cost is O(B·H·D) per step regardless of sequence length.

Used by the long_500k serve path and by tests as the oracle-checked
reference for the GSPMD lowering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.distributed.shmap import shard_map
from jax.sharding import Mesh, PartitionSpec as P

Array = jax.Array

NEG_INF = -1e30


def _local_partial(q, k_loc, v_loc, kpos, cache_len, window):
    """Per-shard partial attention: returns (m, num, den)."""
    b, hq, _, d = q.shape
    hkv = k_loc.shape[1]
    group = hq // hkv
    scale = d ** -0.5
    qg = q.reshape(b, hkv, group, d)
    s = jnp.einsum("bhgd,bhsd->bhgs", qg.astype(jnp.float32),
                   k_loc.astype(jnp.float32)) * scale
    valid = kpos[None, :] <= cache_len[:, None]
    w = jnp.asarray(window, jnp.int32)
    valid &= (w <= 0) | (kpos[None, :] > cache_len[:, None] - w)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)                                  # [b,hkv,g]
    p = jnp.exp(s - m[..., None])
    p = jnp.where(valid[:, None, None, :], p, 0.0)
    den = p.sum(axis=-1)                                     # [b,hkv,g]
    num = jnp.einsum("bhgs,bhsd->bhgd", p,
                     v_loc.astype(jnp.float32))
    return m, num, den


def splitk_decode_attention(mesh: Mesh, axis: str):
    """Build fn(q [B,Hq,1,D], k_cache/v_cache [B,Hkv,S,D] seq-sharded,
    cache_len i32[B], window) -> [B,Hq,1,D]."""

    def fn(q, k_cache, v_cache, cache_len, window: int = 0):
        seq = k_cache.shape[2]
        n = int(mesh.shape[axis])
        local = seq // n

        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(P(), P(None, None, axis, None),
                      P(None, None, axis, None), P()),
            out_specs=P(), check_vma=False)
        def inner(qq, kk, vv, cl):
            idx = jax.lax.axis_index(axis)
            kpos = idx * local + jnp.arange(local, dtype=jnp.int32)
            m, num, den = _local_partial(qq, kk, vv, kpos, cl, window)
            g_m = jax.lax.pmax(m, axis)
            corr = jnp.exp(m - g_m)
            num = num * corr[..., None]
            den = den * corr
            g_num = jax.lax.psum(num, axis)
            g_den = jax.lax.psum(den, axis)
            out = g_num / jnp.maximum(g_den, 1e-30)[..., None]
            b, hkv, group, d = out.shape
            return out.reshape(b, hkv * group, 1, d)

        return inner(q, k_cache, v_cache, cache_len)

    return fn
