"""repro: TPU-native index-layout framework (ORDBMS text-indexing paper)."""
__version__ = "0.1.0"
