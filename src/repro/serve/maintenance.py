"""Background index maintenance: seal full deltas, tiered compaction.

PR 3 made ``compact()`` safe to call between query batches but left it
synchronous on the caller.  This module is the background half: a
thread that watches the delta's fill fraction and the compaction
policy's trigger, and runs seal/compact UNDER THE WRITE LOCK while the
query path keeps serving pinned epochs (the QueryServer probes that
lock non-blockingly — a batch never waits on maintenance, it just
scores one epoch staler).

Cheap-check-then-lock: both triggers are read without the lock first
(``delta_fill`` is two integer divides, ``TieredPolicy.due`` a pure
function of posting counts), so an idle index costs queries no lock
contention at all; the trigger is re-checked under the lock before
acting because a writer may have raced in between.
"""
from __future__ import annotations

import dataclasses
import threading
import time

from repro.core.live_index import SegmentedIndex


@dataclasses.dataclass
class MaintenanceStats:
    runs: int = 0            # run_once invocations that checked triggers
    seals: int = 0
    compactions: int = 0
    layout_rewrites: int = 0  # policy-driven single-segment re-seals


class IndexMaintenance:
    """Seal-and-compact runner, callable inline or as a thread.

    ``run_once`` is the whole policy (deterministic, what the tests
    drive); ``start``/``stop`` wrap it in a polling thread for real
    serving loops.  ``seal_fill`` is the delta fill fraction that
    triggers a seal — 1.0 means "only when ingest would have sealed
    anyway", lower values trade delta scan width for seal frequency.
    ``max_compactions_per_run`` bounds lock hold time per run; the
    policy re-fires next run if more merges are due.

    ``layout_policy`` installs an adaptive hor-vs-packed chooser
    (``size_model.LayoutCostModel``) on the index: seals and compactions
    resolve their layout through the override ladder (an explicit
    ``seal_layout`` here still wins), and each run additionally
    converts up to ``max_rewrites_per_run`` already-sealed segments
    whose layout disagrees with the policy — so a quiescent stack still
    converges to the policy's layout mix, one bounded lock hold at a
    time.  ``layout_policy=None`` leaves the index's own policy (or
    lack of one) untouched.
    """

    def __init__(self, index: SegmentedIndex, lock: threading.RLock, *,
                 seal_fill: float = 0.75, interval_s: float = 0.002,
                 max_compactions_per_run: int = 1,
                 seal_layout: str | None = None,
                 layout_policy=None, max_rewrites_per_run: int = 1):
        self.index = index
        self.lock = lock
        self.seal_fill = float(seal_fill)
        self.interval_s = float(interval_s)
        self.max_compactions_per_run = int(max_compactions_per_run)
        self.seal_layout = seal_layout
        self.max_rewrites_per_run = int(max_rewrites_per_run)
        if layout_policy is not None:
            index.layout_policy = layout_policy
        self.stats = MaintenanceStats()
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    def _due(self) -> bool:
        ix = self.index
        return (ix.delta_fill >= self.seal_fill
                or ix.policy.due(ix.segment_postings())
                or ix.pick_layout_rewrite() is not None)

    def run_once(self) -> dict:
        """One maintenance step: seal if the delta is full enough,
        then up to ``max_compactions_per_run`` policy-picked merges,
        then up to ``max_rewrites_per_run`` layout-policy re-seals.
        Returns what happened (for tests and telemetry)."""
        self.stats.runs += 1
        did = {"sealed": False, "compacted": 0, "rewritten": 0}
        if not self._due():                 # unlocked cheap check
            return did
        t0 = time.perf_counter()
        with self.lock:
            ix = self.index
            if ix.delta_fill >= self.seal_fill and ix._delta.n_docs > 0:
                ix.seal(layout=self.seal_layout)
                self.stats.seals += 1
                did["sealed"] = True
            for _ in range(self.max_compactions_per_run):
                if not ix.policy.due(ix.segment_postings()):
                    break
                if not ix.compact():
                    break
                self.stats.compactions += 1
                did["compacted"] += 1
            for _ in range(self.max_rewrites_per_run):
                i = ix.pick_layout_rewrite()
                if i is None:
                    break
                ix.rewrite_segment(i)
                self.stats.layout_rewrites += 1
                did["rewritten"] += 1
        if did["sealed"] or did["compacted"] or did["rewritten"]:
            # the seal/compact/rewrite calls above each emitted their
            # own detailed event; this one records the run envelope
            # (lock hold time, work mix) the serving tier alerts on
            self.index.events.emit(
                "maintenance_run", epoch=self.index.epoch,
                sealed=did["sealed"], compacted=did["compacted"],
                rewritten=did["rewritten"],
                duration_us=(time.perf_counter() - t0) * 1e6)
        return did

    # -- thread -----------------------------------------------------------

    def start(self) -> None:
        if self._thread is not None:
            return
        self._stop.clear()

        def loop():
            while not self._stop.is_set():
                self.run_once()
                self._stop.wait(timeout=self.interval_s)

        self._thread = threading.Thread(target=loop,
                                        name="index-maintenance",
                                        daemon=True)
        self._thread.start()

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join(timeout=30.0)
        self._thread = None
