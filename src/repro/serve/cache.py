"""Query-result cache keyed on (query signature, k, epoch).

The epoch in the key IS the invalidation protocol: any query-visible
mutation of the live index advances its epoch, so entries written at
older epochs can never satisfy a lookup at the current one — stale
results are unreachable by construction, not by a scan-and-evict pass.
``purge_below`` exists only to reclaim their memory eagerly; the LRU
bound would get there anyway.
"""
from __future__ import annotations

from collections import OrderedDict

import numpy as np


class ResultCache:
    """Bounded LRU of (doc_ids, scores) responses.

    Keys are ``(tuple(padded query row), k, epoch)``; values are
    defensive copies, so a cached response is immutable no matter what
    the caller does with the arrays it gets back.
    """

    def __init__(self, capacity: int = 4096):
        self.capacity = int(capacity)
        self._store: OrderedDict[tuple, tuple] = OrderedDict()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._store)

    @staticmethod
    def make_key(query_row: np.ndarray, k: int, epoch: int) -> tuple:
        return (tuple(np.asarray(query_row, np.uint32).tolist()),
                int(k), int(epoch))

    def get(self, key: tuple):
        """(doc_ids, scores) copies, or None.  Counts the hit/miss."""
        hit = self._store.get(key)
        if hit is None:
            self.misses += 1
            return None
        self._store.move_to_end(key)
        self.hits += 1
        return hit[0].copy(), hit[1].copy()

    def put(self, key: tuple, doc_ids: np.ndarray,
            scores: np.ndarray) -> None:
        self._store[key] = (np.asarray(doc_ids).copy(),
                            np.asarray(scores).copy())
        self._store.move_to_end(key)
        while len(self._store) > self.capacity:
            self._store.popitem(last=False)

    def purge_below(self, epoch: int) -> int:
        """Drop entries pinned to epochs older than ``epoch`` (they are
        already unreachable — keys carry their epoch); returns the
        number reclaimed."""
        stale = [k for k in self._store if k[2] < epoch]
        for k in stale:
            del self._store[k]
        return len(stale)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def reset_counters(self) -> None:
        self.hits = 0
        self.misses = 0


class TenantCachePartitions:
    """Per-tenant ``ResultCache`` partitions: keys are effectively
    ``(tenant, query row, k, epoch)``.

    Each tenant gets its own LRU with its own capacity, so one tenant's
    burst can never evict another's working set — isolation holds by
    construction, not by quota accounting.  The tenant directory itself
    is LRU-bounded (``max_tenants``): an evicted tenant loses its
    partition wholesale and starts cold on return.

    Aggregate ``hits``/``misses`` are tracked here (they survive tenant
    eviction); per-partition counters remain on each ``ResultCache``.
    The object satisfies the stats surface ``ServerMetrics.attach_cache``
    expects (hits, misses, hit_rate, __len__, reset_counters).
    """

    make_key = staticmethod(ResultCache.make_key)

    def __init__(self, capacity_per_tenant: int = 1024,
                 max_tenants: int = 64):
        self.capacity_per_tenant = int(capacity_per_tenant)
        self.max_tenants = int(max_tenants)
        self._parts: OrderedDict[str, ResultCache] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.tenant_evictions = 0

    def __len__(self) -> int:
        return sum(len(p) for p in self._parts.values())

    @property
    def tenants(self) -> list[str]:
        return list(self._parts)

    def partition(self, tenant: str) -> ResultCache:
        """The tenant's partition, created lazily; touching it marks
        the tenant most-recently-used in the directory."""
        part = self._parts.get(tenant)
        if part is None:
            part = ResultCache(self.capacity_per_tenant)
            self._parts[tenant] = part
            while len(self._parts) > self.max_tenants:
                self._parts.popitem(last=False)
                self.tenant_evictions += 1
        self._parts.move_to_end(tenant)
        return part

    def get(self, tenant: str, key: tuple):
        out = self.partition(tenant).get(key)
        if out is None:
            self.misses += 1
        else:
            self.hits += 1
        return out

    def put(self, tenant: str, key: tuple, doc_ids: np.ndarray,
            scores: np.ndarray) -> None:
        self.partition(tenant).put(key, doc_ids, scores)

    def purge_below(self, epoch: int) -> int:
        return sum(p.purge_below(epoch) for p in self._parts.values())

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def reset_counters(self) -> None:
        self.hits = 0
        self.misses = 0
        for p in self._parts.values():
            p.reset_counters()

    def per_tenant(self) -> dict:
        """{tenant: {entries, hits, misses}} for observability."""
        return {t: {"entries": len(p), "hits": p.hits, "misses": p.misses}
                for t, p in self._parts.items()}
