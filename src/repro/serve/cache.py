"""Query-result cache keyed on (query signature, k, epoch).

The epoch in the key IS the invalidation protocol: any query-visible
mutation of the live index advances its epoch, so entries written at
older epochs can never satisfy a lookup at the current one — stale
results are unreachable by construction, not by a scan-and-evict pass.
``purge_below`` exists only to reclaim their memory eagerly; the LRU
bound would get there anyway.
"""
from __future__ import annotations

from collections import OrderedDict

import numpy as np


class ResultCache:
    """Bounded LRU of (doc_ids, scores) responses.

    Keys are ``(tuple(padded query row), k, epoch)``; values are
    defensive copies, so a cached response is immutable no matter what
    the caller does with the arrays it gets back.
    """

    def __init__(self, capacity: int = 4096):
        self.capacity = int(capacity)
        self._store: OrderedDict[tuple, tuple] = OrderedDict()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._store)

    @staticmethod
    def make_key(query_row: np.ndarray, k: int, epoch: int) -> tuple:
        return (tuple(np.asarray(query_row, np.uint32).tolist()),
                int(k), int(epoch))

    def get(self, key: tuple):
        """(doc_ids, scores) copies, or None.  Counts the hit/miss."""
        hit = self._store.get(key)
        if hit is None:
            self.misses += 1
            return None
        self._store.move_to_end(key)
        self.hits += 1
        return hit[0].copy(), hit[1].copy()

    def put(self, key: tuple, doc_ids: np.ndarray,
            scores: np.ndarray) -> None:
        self._store[key] = (np.asarray(doc_ids).copy(),
                            np.asarray(scores).copy())
        self._store.move_to_end(key)
        while len(self._store) > self.capacity:
            self._store.popitem(last=False)

    def purge_below(self, epoch: int) -> int:
        """Drop entries pinned to epochs older than ``epoch`` (they are
        already unreachable — keys carry their epoch); returns the
        number reclaimed."""
        stale = [k for k in self._store if k[2] < epoch]
        for k in stale:
            del self._store[k]
        return len(stale)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def reset_counters(self) -> None:
        self.hits = 0
        self.misses = 0
