"""QueryServer: admission queue + micro-batched fused evaluation.

Single queries arrive one at a time; the fused engines want batches of
a STATIC shape (every distinct (B, T) is an XLA compilation).  The
server bridges the two: requests admission-queue, and each pump drains
up to ``batch_size`` of them into one ``(batch_size, n_terms_budget)``
pad-and-mask evaluation — the exact shapes the per-segment kernels are
already warm for, so steady-state serving adds ZERO jit cache entries
(asserted the same way as the PR-3 churn test).

Consistency: each micro-batch pins the index's current epoch view
(``LiveView``) and scores every request in the batch against it — a
response is bit-identical to the jnp oracle evaluated over the live
corpus AT THAT EPOCH, regardless of what ingest or background
maintenance does meanwhile.  The pin itself takes the write lock
NON-blockingly: if a writer holds it (mid-seal, mid-compact), the batch
serves from the previous pinned epoch instead of waiting — churn never
blocks the query path, it only delays epoch freshness by one
maintenance step.

Caching: results key on (padded query row, k, epoch).  An epoch advance
makes every older entry unreachable (see serve/cache.py), so hits are
always consistent with the epoch they will be reported against.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque

import numpy as np

from repro.core.live_index import LiveView, SegmentedIndex
from repro.obs.registry import GLOBAL, MetricsRegistry
from repro.obs.trace import StageAggregator, Trace, Tracer
from repro.serve.cache import ResultCache
from repro.serve.metrics import ServerMetrics


@dataclasses.dataclass(frozen=True)
class ServerConfig:
    """Static serving shapes + engine selection.

    ``batch_size`` and ``n_terms_budget`` ARE the compiled shapes: every
    micro-batch is padded to exactly (batch_size, n_terms_budget), and
    ``k`` fixes the candidate width — together with the live index's
    size classes that is the whole jit signature space of the serving
    path.  Queries wider than ``n_terms_budget`` are rejected at
    admission (never silently truncated).

    ``tune`` optionally pins a ``kernels.autotune.TuneConfig`` for every
    segment the server scores; ``None`` (the default) resolves each
    segment's geometry from the ACTIVE tuning table at trace time, per
    pinned epoch — segments sealed after ``autotune.set_active`` serve
    with their tuned kernels while warm size classes keep their compiled
    executables.

    ``layout_policy`` optionally pins a ``size_model.LayoutCostModel``
    alongside ``tune``: the server installs it on the index at
    construction, so maintenance-driven seals/compactions resolve their
    layout through the override ladder while every response still comes
    from an epoch-pinned view (layout changes only become visible at
    the next pin, like any other mutation).  ``None`` leaves the
    index's own policy untouched — bit-identical to pre-chooser
    serving.

    ``event_capacity`` optionally rebounds the index's maintenance
    event ring at server construction (``index.events.resize``) —
    long-lived serving meshes keep a deeper audit tail than the
    library default of 256 without touching ``SegmentedIndex`` call
    sites.  ``None`` leaves the index's ring as built.

    ``trace_sample`` samples end-to-end query traces: every Nth
    submitted ticket carries a ``repro.obs.Trace`` through queue wait,
    batch assembly, per-segment kernel dispatch, candidate merge, and
    response (``1`` traces every request, ``0`` — the default —
    disables tracing entirely: no span objects are constructed on the
    hot path, and results are bit-identical either way).
    """
    batch_size: int = 8
    n_terms_budget: int = 8
    k: int = 10
    cap: int | None = None
    rank_blend: float = 0.0
    engine: str = "pallas"
    mode: str = "candidates"
    backend: str = "pallas"
    cache_capacity: int = 4096
    tune: object | None = None
    layout_policy: object | None = None
    trace_sample: int = 0
    event_capacity: int | None = None


class Response:
    """One served result: top-k ids/scores + serving metadata.
    ``trace`` is the sampled ``repro.obs.Trace`` (None unless this
    ticket was sampled) — its top-level stage spans sum exactly to
    ``latency_us``.  ``status`` is ``"ok"`` for a served result; shed
    and shutdown resolutions carry ``"shed"`` / ``"shutdown"`` with
    empty ids (-1) and zero scores, so ``result()`` never blocks on a
    ticket the server has already given up on."""
    __slots__ = ("doc_ids", "scores", "epoch", "latency_us", "cached",
                 "trace", "status")

    def __init__(self, doc_ids, scores, epoch, latency_us, cached,
                 trace=None, status="ok"):
        self.doc_ids = doc_ids
        self.scores = scores
        self.epoch = epoch
        self.latency_us = latency_us
        self.cached = cached
        self.trace = trace
        self.status = status

    @property
    def ok(self) -> bool:
        return self.status == "ok"


class Ticket:
    """Admission handle: resolves to a Response when its batch lands.
    ``tenant`` scopes the result-cache partition the response may be
    served from (single-tenant servers leave it at ``"default"``)."""

    def __init__(self, row: np.ndarray, tenant: str = "default"):
        self.row = row
        self.tenant = tenant
        self.t_submit = time.perf_counter()
        self.response: Response | None = None
        self.trace: Trace | None = None
        self._done = threading.Event()

    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: float | None = None) -> Response:
        if not self._done.wait(timeout):
            raise TimeoutError("query not served within timeout")
        return self.response


class QueryServer:
    """Micro-batched server over a SegmentedIndex.

    Drive it either synchronously (``submit`` + ``pump`` from one
    thread — deterministic, what the parity tests do) or with the
    worker thread (``start``/``stop``) while a ``serve.maintenance``
    thread churns the index in the background.  Writers (ingest,
    maintenance) must hold ``index_lock``; the server takes it only to
    pin a fresh view, and falls back to the previous pin when a writer
    has it.
    """

    def __init__(self, index: SegmentedIndex,
                 config: ServerConfig | None = None,
                 lock: threading.RLock | None = None):
        self.index = index
        self.config = config or ServerConfig()
        self.index_lock = lock if lock is not None else threading.RLock()
        self.cache = ResultCache(self.config.cache_capacity)
        self.registry = MetricsRegistry()
        self.metrics = ServerMetrics(registry=self.registry,
                                     cache=self.cache)
        self.tracer = Tracer(self.config.trace_sample)
        self.stages = StageAggregator(self.registry)
        self._register_index_gauges()
        self._queue: deque[Ticket] = deque()
        self._qlock = threading.Lock()
        self._work = threading.Event()
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        with self.index_lock:
            if self.config.layout_policy is not None:
                index.layout_policy = self.config.layout_policy
            if self.config.event_capacity is not None:
                index.events.resize(self.config.event_capacity)
            self._pinned: LiveView = index.view()
        self._purged_epoch = self._pinned.epoch
        self.metrics.observe_layout_mix(self._pinned.layout_mix())

    # -- observability ------------------------------------------------------

    def _register_index_gauges(self) -> None:
        """Expose live-index state + maintenance counters as callback
        gauges, read at snapshot time (no polling thread)."""
        ix = self.index
        for name, fn in (
                ("index_epoch", lambda: ix.epoch),
                ("index_segments", lambda: ix.num_segments),
                ("index_docs", lambda: ix.num_docs),
                ("index_live_docs", lambda: ix.live_doc_count),
                ("index_delta_fill", lambda: ix.delta_fill),
                ("index_seals", lambda: ix.stats.seals),
                ("index_compactions", lambda: ix.stats.compactions),
                ("index_layout_rewrites", lambda: ix.stats.layout_rewrites),
                ("index_postings_merged", lambda: ix.stats.postings_merged),
                ("index_deletes", lambda: ix.stats.deletes),
                ("index_events_total", lambda: ix.events.total)):
            if self.registry.get(name) is None:
                self.registry.register_callback(name, fn)

    def metrics_snapshot(self, include_global: bool = True) -> dict:
        """The stable export (see ``repro.obs.registry``): this
        server's registry — counters, cache gauges, index gauges,
        per-stage histograms — merged with the process-global engine
        counters (pair overflow, truncated terms)."""
        snap = self.registry.snapshot()
        if include_global:
            for name, m in GLOBAL.snapshot().items():
                snap.setdefault(name, m)
        return snap

    def stage_summary(self) -> dict:
        """Per-stage latency breakdown ({stage: {count, sum, p50,
        p99}}) aggregated from sampled traces."""
        return self.stages.summary()

    def events(self, n: int | None = None, kind: str | None = None) -> list:
        """The last ``n`` maintenance events from the index's bounded
        event log (seal/compact/rewrite/ingest/delete/...)."""
        return self.index.events.tail(n, kind=kind)

    # -- admission ----------------------------------------------------------

    def _make_ticket(self, query_hashes, tenant: str = "default") -> Ticket:
        """Validate + zero-pad one query into a Ticket (not yet
        enqueued) — the shared admission front half, so subclasses can
        decide a ticket's fate (enqueue vs shed) after it exists."""
        qh = np.atleast_1d(np.asarray(query_hashes, np.uint32))
        if qh.ndim != 1:
            raise ValueError(
                f"submit takes ONE query (a 1-D hash vector), got shape "
                f"{qh.shape} — submit batch rows individually; the server "
                "does the batching")
        t = self.config.n_terms_budget
        if qh.shape[0] > t:
            raise ValueError(
                f"query has {qh.shape[0]} term slots > n_terms_budget={t} "
                "(widen the budget; truncation would drop terms silently)")
        row = np.zeros(t, np.uint32)
        row[:qh.shape[0]] = qh
        ticket = Ticket(row, tenant=tenant)
        if self.tracer.enabled:
            ticket.trace = self.tracer.sample()
        return ticket

    def submit(self, query_hashes) -> Ticket:
        """Enqueue one query (u32 term-hash vector, <= n_terms_budget
        wide; it is zero-padded to the budget).  Returns a Ticket."""
        ticket = self._make_ticket(query_hashes)
        with self._qlock:
            self._queue.append(ticket)
        self._work.set()
        return ticket

    def query(self, query_hashes, timeout: float = 60.0) -> Response:
        """Synchronous convenience: submit, then either wait on the
        worker thread or pump inline until served."""
        ticket = self.submit(query_hashes)
        if self._thread is None:
            while not ticket.done():
                if self.pump() == 0 and not ticket.done():
                    raise RuntimeError("queue drained without serving "
                                       "the submitted ticket")
        return ticket.result(timeout)

    @property
    def pending(self) -> int:
        with self._qlock:
            return len(self._queue)

    # -- view pinning ---------------------------------------------------

    def refresh_view(self) -> LiveView:
        """Pin the freshest view available WITHOUT waiting on writers:
        non-blocking lock probe, fall back to the previous pinned epoch
        when a writer is mid-mutation."""
        if self.index_lock.acquire(blocking=False):
            try:
                self._pinned = self.index.view()
            finally:
                self.index_lock.release()
        return self._pinned

    @property
    def pinned_epoch(self) -> int:
        return self._pinned.epoch

    # -- the micro-batch loop -------------------------------------------

    def pump(self, max_batches: int = 1) -> int:
        """Serve up to ``max_batches`` micro-batches from the queue;
        returns the number of requests answered."""
        served = 0
        for _ in range(max_batches):
            batch = self._take_batch()
            if not batch:
                break
            self._serve_batch(batch)
            served += len(batch)
        return served

    def _take_batch(self) -> list[Ticket]:
        with self._qlock:
            n = min(len(self._queue), self.config.batch_size)
            batch = [self._queue.popleft() for _ in range(n)]
            if not self._queue:
                self._work.clear()
        return batch

    def _serve_batch(self, batch: list[Ticket]) -> None:
        cfg = self.config
        # stage boundaries are SHARED timestamps: queue_wait ends where
        # assemble (or the cache-hit span) starts, so a sampled ticket's
        # top-level spans sum EXACTLY to its measured e2e latency
        traced = [t for t in batch if t.trace is not None]
        t_batch = time.perf_counter() if traced else 0.0
        for t in traced:
            t.trace.span("queue_wait", t0=t.t_submit).end(t_batch)
        view = self.refresh_view()
        epoch = view.epoch
        self.metrics.observe_epoch(epoch)
        if epoch != self._purged_epoch:
            # stale-epoch entries are already unreachable (keys carry
            # their epoch); reclaim them once per advance, not per batch
            self.cache.purge_below(epoch)
            self._purged_epoch = epoch
            # once per epoch advance: report the layout mix this epoch's
            # stack converged to (seal/compact/rewrite all repin)
            self.metrics.observe_layout_mix(view.layout_mix())
        pending: list[tuple[Ticket, tuple]] = []
        for ticket in batch:
            key = self.cache.make_key(ticket.row, cfg.k, epoch)
            hit = self.cache.get(key)
            if hit is not None:
                self._respond(ticket, hit[0], hit[1], epoch, cached=True,
                              stage_t0=t_batch)
            else:
                pending.append((ticket, key))
        if pending:
            # batch-level spans (assembly, scoring + per-segment/merge
            # children) are recorded ONCE and adopted by every sampled
            # ticket in the batch — the work is genuinely shared
            btr = (Trace() if any(t.trace is not None for t, _ in pending)
                   else None)
            asm = (btr.span("assemble", t0=t_batch, epoch=epoch,
                            fill=len(pending),
                            padded_slots=cfg.batch_size - len(pending))
                   if btr is not None else None)
            qb = np.zeros((cfg.batch_size, cfg.n_terms_budget), np.uint32)
            for i, (ticket, _) in enumerate(pending):
                qb[i] = ticket.row
            if asm is not None:
                asm.end()
            score = (btr.span("score", t0=asm.t1, engine=cfg.engine,
                              mode=cfg.mode, backend=cfg.backend,
                              segments=view.num_segments)
                     if btr is not None else None)
            result = view.topk(qb, cfg.k, cap=cfg.cap,
                               rank_blend=cfg.rank_blend, engine=cfg.engine,
                               mode=cfg.mode, backend=cfg.backend,
                               tune=cfg.tune, trace=btr)
            ids = np.asarray(result.doc_ids)
            scores = np.asarray(result.scores)
            if score is not None:
                score.end()
            t_scored = score.t1 if score is not None else None
            for i, (ticket, key) in enumerate(pending):
                self.cache.put(key, ids[i], scores[i])
                if ticket.trace is not None:
                    ticket.trace.adopt(btr.spans)
                self._respond(ticket, ids[i].copy(), scores[i].copy(),
                              epoch, cached=False, stage_t0=t_scored)
            self.metrics.batches += 1
            self.metrics.batched_queries += len(pending)
            self.metrics.padded_slots += cfg.batch_size - len(pending)

    def _respond(self, ticket: Ticket, doc_ids, scores, epoch: int,
                 cached: bool, stage_t0: float | None = None) -> None:
        now = time.perf_counter()
        latency_us = (now - ticket.t_submit) * 1e6
        tr = ticket.trace
        if tr is not None:
            # final stage closes at the SAME clock reading latency_us is
            # computed from — the stage sum is the e2e latency, exactly
            if stage_t0 is not None:
                tr.span("cache_hit" if cached else "respond",
                        t0=stage_t0, epoch=epoch).end(now)
            self.stages.observe_trace(tr)
            self.stages.observe("e2e", latency_us)
        ticket.response = Response(doc_ids, scores, epoch, latency_us,
                                   cached, trace=tr)
        self.metrics.record_response(latency_us)
        ticket._done.set()

    # -- warmup ---------------------------------------------------------

    def warmup(self) -> None:
        """Compile the serving path's static shapes: one full-width
        batch of empty queries through the current view (shapes do not
        depend on query content).  Call again after the index mints a
        NEW size class if strict zero-compile serving matters; warm
        classes stay warm."""
        view = self.refresh_view()
        cfg = self.config
        qb = np.zeros((cfg.batch_size, cfg.n_terms_budget), np.uint32)
        view.topk(qb, cfg.k, cap=cfg.cap, rank_blend=cfg.rank_blend,
                  engine=cfg.engine, mode=cfg.mode, backend=cfg.backend,
                  tune=cfg.tune)

    # -- worker thread ---------------------------------------------------

    def start(self) -> None:
        """Spawn the worker thread (idempotent)."""
        if self._thread is not None:
            return
        self._stop.clear()

        def loop():
            while not self._stop.is_set():
                if self.pump(max_batches=4) == 0:
                    self._work.wait(timeout=0.005)
            self.pump(max_batches=1_000_000)   # drain on shutdown

        self._thread = threading.Thread(target=loop, name="query-server",
                                        daemon=True)
        self._thread.start()

    def stop(self) -> None:
        """Stop the worker (if running) and resolve every still-queued
        ticket with a ``status="shutdown"`` Response — ``result()``
        must never block until timeout on a server that has stopped.
        The worker drains the queue normally first, so only tickets
        that raced the shutdown (or pump-mode leftovers) are failed."""
        if self._thread is not None:
            self._stop.set()
            self._work.set()
            self._thread.join(timeout=30.0)
            self._thread = None
        self._fail_pending()

    def _fail_pending(self) -> int:
        with self._qlock:
            leftover = list(self._queue)
            self._queue.clear()
            self._work.clear()
        for ticket in leftover:
            self._resolve_shutdown(ticket)
        return len(leftover)

    def _resolve_shutdown(self, ticket: Ticket) -> None:
        """Resolve one unserved ticket as shed-by-shutdown (overridden
        by the mesh to count/log it as a shed)."""
        now = time.perf_counter()
        k = self.config.k
        tr = ticket.trace
        if tr is not None:
            tr.span("shed", t0=ticket.t_submit, reason="shutdown").end(now)
            self.stages.observe_trace(tr)
        ticket.response = Response(
            np.full(k, -1, np.int32), np.zeros(k, np.float32),
            self._pinned.epoch, (now - ticket.t_submit) * 1e6,
            False, trace=tr, status="shutdown")
        self.registry.counter("serve_shutdown_unserved").inc()
        ticket._done.set()
