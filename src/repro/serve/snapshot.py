"""Epoch-pinned snapshots + host serialize/restore of a SegmentedIndex.

Two consistency mechanisms, two lifetimes:

  * ``pin`` — an in-process, zero-copy-where-possible ``LiveView``
    (core/live_index.py): queries score a consistent index at one epoch
    while writes land.  This is what the QueryServer batches against.

  * ``serialize_segmented`` / ``restore_segmented`` — a host-side flat
    ``{name: ndarray}`` state (savez-compatible) holding the canonical
    postings, global scoring state, delta tail, policy, and rng state.
    Restore rebuilds every sealed segment through the SAME bulk build +
    size-class padding path as live sealing, so a restored index
    answers queries bit-identically to the one that was saved (the
    PR-3 failover follow-up), and — because the rank rng state rides
    along — keeps answering identically under identical future
    mutation schedules.
"""
from __future__ import annotations

import dataclasses
import json
import time

import numpy as np

from repro.core import compaction, size_model
from repro.core.live_index import (LiveIndexStats, LiveView, SegmentedIndex,
                                   _Delta)

# v2 adds the layout policy + per-segment chooser provenance
# (size_class, num_terms, chooser_reason); v3 adds the per-segment band
# descriptor (band_cut) so banded segments restore with the EXACT band
# membership they sealed with.  v1/v2 snapshots still restore (no
# policy / band_cut re-derived by the builder) — the arrays are
# identical either way.
_FORMAT_VERSION = 3
_READ_VERSIONS = (1, 2, 3)


def pin(index: SegmentedIndex) -> LiveView:
    """The current epoch's immutable view (see ``LiveView``).  Callers
    running writers concurrently must hold their write lock for this
    call — the serving tier does (and only for the pin, never the
    query)."""
    return index.view()


def serialize_segmented(index: SegmentedIndex, lock=None) -> dict:
    """Flat ``{name: np.ndarray}`` snapshot of the full index state.

    Layout: a JSON manifest (uint8 bytes under ``"meta"``) for scalars
    and per-segment shapes, plus one array per global table and per
    segment postings column.  Everything needed to rebuild — vocabulary,
    live df, live mask, ranks, norms, per-segment canonical triples,
    the delta tail, compaction policy, and the rank rng state.

    The state is gathered in several passes, so like ``view()`` this
    must run serially with writers: pass the serving tier's write lock
    as ``lock`` (held for the whole gather), or otherwise guarantee no
    ingest/maintenance runs concurrently — a torn snapshot would
    restore to a corrupt index.
    """
    if lock is not None:
        with lock:
            return serialize_segmented(index, lock=None)
    dl = index._delta
    n_p = dl.n_postings
    meta = {
        "version": _FORMAT_VERSION,
        "live_docs": int(index._live_docs),
        "epoch": int(index._epoch),
        "seal_layout": index._seal_layout,
        "delta": {"doc_cap": dl.doc_cap, "post_cap": dl.post_cap,
                  "doc_base": dl.doc_base, "n_docs": dl.n_docs},
        "policy": {"size_ratio": index._policy.size_ratio,
                   "min_run": index._policy.min_run},
        "rng_state": index._rng.bit_generator.state,
        "stats": dataclasses.asdict(index.stats),
        # the layout POLICY rides along so a restored index keeps
        # choosing layouts the same way (only LayoutCostModel policies
        # serialize; a custom policy object restores as None)
        "layout_policy": (index.layout_policy.to_dict()
                          if isinstance(index.layout_policy,
                                        size_model.LayoutCostModel)
                          else None),
        # per-segment layout: a mixed hor+packed stack (per-seal layout
        # overrides or per-segment chooser decisions) must restore each
        # segment in its ORIGINAL layout, not the index-wide default or
        # a re-run of the chooser, for a bitwise structural roundtrip —
        # the DECISION is state, so the reason string rides along too
        "segments": [{"doc_base": s.doc_base, "doc_span": s.doc_span,
                      "n_postings": s.n_postings, "layout": s.layout,
                      "size_class": s.size_class,
                      "num_terms": s.num_terms,
                      "chooser_reason": s.chooser_reason,
                      "band_cut": int(s.band_cut)}
                     for s in index._segments],
    }
    state = {
        "meta": np.frombuffer(json.dumps(meta).encode(), dtype=np.uint8),
        "hashes": index._hashes.copy(),
        "df": index._df.copy(),
        "live": index._live.copy(),
        "rank": index._rank.copy(),
        "norm": index._norm.copy(),
        "delta_terms": dl.terms[:n_p].copy(),
        "delta_tfs": dl.tfs[:n_p].copy(),
        "delta_lens": np.diff(dl.doc_offsets[:dl.n_docs + 1]),
    }
    for i, s in enumerate(index._segments):
        state[f"seg{i}_doc_of"] = s.doc_of.copy()
        state[f"seg{i}_terms"] = s.terms.copy()
        state[f"seg{i}_tfs"] = s.tfs.copy()
    return state


def restore_segmented(state: dict) -> SegmentedIndex:
    """Rebuild a SegmentedIndex from ``serialize_segmented`` output.

    Global tables restore verbatim; sealed segments rebuild through
    ``_build_segment`` (bulk build + size-class pad) from their stored
    canonical triples — the same path live sealing takes, so device
    structures come out identical up to vocabulary width (terms added
    after a segment sealed appear as posting-less vocab entries, which
    gate nothing and change no result bit).
    """
    meta = json.loads(bytes(np.asarray(state["meta"])).decode())
    if meta["version"] not in _READ_VERSIONS:
        raise ValueError(f"unknown snapshot version {meta['version']}")
    pol = meta.get("layout_policy")
    si = SegmentedIndex(
        term_hashes=np.asarray(state["hashes"], np.uint32),
        delta_doc_capacity=meta["delta"]["doc_cap"],
        delta_posting_capacity=meta["delta"]["post_cap"],
        policy=compaction.TieredPolicy(**meta["policy"]),
        seal_layout=meta["seal_layout"],
        layout_policy=(size_model.LayoutCostModel.from_dict(pol)
                       if pol is not None else None))
    si._df = np.asarray(state["df"], np.int64).copy()
    si._live = np.asarray(state["live"], bool).copy()
    si._rank = np.asarray(state["rank"], np.float32).copy()
    si._norm = np.asarray(state["norm"], np.float32).copy()
    si._live_docs = int(meta["live_docs"])
    si._rng.bit_generator.state = meta["rng_state"]
    # norms are already restored, so segment builds pad the exact values
    for i, sm in enumerate(meta["segments"]):
        # the stored layout restores as an EXPLICIT arg (top of the
        # ladder), so the roundtrip stays bitwise no matter what the
        # restored policy would choose today; the original chooser
        # reason is then re-attached as provenance (v1: "default")
        seg = si._build_segment(
            int(sm["doc_base"]), int(sm["doc_span"]),
            np.asarray(state[f"seg{i}_doc_of"], np.int64),
            np.asarray(state[f"seg{i}_terms"], np.int64),
            np.asarray(state[f"seg{i}_tfs"], np.float32),
            layout=sm.get("layout", meta["seal_layout"]),
            band_cut=sm.get("band_cut") or None)
        seg.chooser_reason = sm.get("chooser_reason", "default")
        si._segments.append(seg)
    dl = _Delta(meta["delta"]["doc_cap"], meta["delta"]["post_cap"],
                meta["delta"]["doc_base"])
    lens = np.asarray(state["delta_lens"], np.int64)
    if lens.size:
        dl.append(lens, np.asarray(state["delta_terms"], np.int32),
                  np.asarray(state["delta_tfs"], np.float32))
    si._delta = dl
    si._delta_dirty = True
    si.stats = LiveIndexStats(**meta["stats"])
    si._epoch = int(meta["epoch"])
    # the per-segment rebuilds above go through _build_segment directly
    # (no per-segment seal events); one restore event marks the cutover
    si.events.emit("restore", epoch=si._epoch,
                   segments=len(si._segments),
                   snapshot_version=int(meta["version"]))
    return si


def save_segmented(index: SegmentedIndex, path, lock=None) -> None:
    """Snapshot to an ``.npz`` file (compressed).  ``lock`` as in
    ``serialize_segmented`` — hold the write lock when writers may be
    live (only the state gather runs under it, not the file write)."""
    t0 = time.perf_counter()
    state = serialize_segmented(index, lock=lock)
    np.savez_compressed(path, **state)
    index.events.emit("snapshot_save", epoch=index.epoch,
                      segments=index.num_segments, path=str(path),
                      duration_us=(time.perf_counter() - t0) * 1e6)


def load_segmented(path) -> SegmentedIndex:
    """Restore from ``save_segmented`` output."""
    with np.load(path) as z:
        return restore_segmented({k: z[k] for k in z.files})
