"""Online query-serving subsystem over the segmented live index.

The paper stops at query evaluation; ODYS (PAPERS.md) shows what sits
between an index and real traffic: a serving tier.  This package is
that tier for ``core/live_index.SegmentedIndex``:

  server.py      QueryServer — admission queue + micro-batching into
                 the static (Q_pad, n_terms_budget) shapes the fused
                 kernels already compile for, per-request latency
                 accounting
  snapshot.py    epoch-pinned immutable views (queries score a
                 consistent index while writes land) + host
                 serialize/restore for failover
  cache.py       query-result cache keyed (query, k, epoch) —
                 invalidated by epoch advance, hit rate in metrics
  maintenance.py background thread sealing full deltas and running
                 tiered compaction between batches against pinned
                 epochs
  metrics.py     latency percentiles (p50/p99), QPS, batch fill —
                 registry-backed (see repro.obs) with a stable
                 JSON/Prometheus snapshot export
  mesh.py        MeshServer — the distributed tier: micro-batches fan
                 out over sharded segment stacks (or the term-sharded
                 fused engine) at a pinned epoch, with replicated
                 indexes under independent maintenance, cross-shard
                 epoch handoff, admission control, deadline shedding,
                 and per-tenant result-cache partitions

Observability primitives (spans, the metrics registry, the maintenance
event log) live in the dependency-neutral ``repro.obs`` package and are
re-exported here for serving-tier callers.
"""
from repro.obs.registry import EventLog, MetricsRegistry
from repro.obs.trace import Span, StageAggregator, Trace, Tracer
from repro.serve.cache import ResultCache, TenantCachePartitions
from repro.serve.maintenance import IndexMaintenance
from repro.serve.mesh import MeshConfig, MeshServer, ShardReplica
from repro.serve.metrics import LatencyWindow, ServerMetrics, percentiles
from repro.serve.server import QueryServer, Response, ServerConfig, Ticket
from repro.serve.snapshot import (load_segmented, pin, restore_segmented,
                                  save_segmented, serialize_segmented)

__all__ = [
    "QueryServer", "ServerConfig", "Response", "Ticket", "ResultCache",
    "TenantCachePartitions", "IndexMaintenance", "MeshServer",
    "MeshConfig", "ShardReplica", "LatencyWindow", "ServerMetrics",
    "percentiles", "pin", "serialize_segmented", "restore_segmented",
    "save_segmented", "load_segmented", "MetricsRegistry", "EventLog",
    "Span", "Trace", "Tracer", "StageAggregator",
]
