r"""MeshServer: the full serving path — admission, micro-batch, shard
fan-out, candidate merge, response — over replicated live indexes.

This is the subsystem the ROADMAP's first open item asks for, the
ODYS-style tight integration of parallel query serving with online
index maintenance: ``QueryServer``-shaped micro-batches route through
the sharded segment-stack engine (``make_doc_sharded_segment_scorer``)
over a PINNED epoch, while per-shard index replicas run their own
``IndexMaintenance`` and a coordinator performs graceful cross-shard
epoch handoff whenever seal/compaction advances the primary.

Topology
--------
::

                 submit(query, tenant)
                        |
               [admission control]  -- queue full -> shed("admission")
                        |
                  admission queue
                        |
                 micro-batch pump   -- past deadline -> shed("deadline")
                        |
              per-tenant ResultCache -------------------- hit -> respond
                        |
          MeshEpochState (pinned epoch E)
             /      |        \
        shard 0  shard 1 ... shard S-1     one fused kernel per local
           \        |        /             segment, per (class, layout)
            all-gather candidate merge     group stack
                        |
                     respond

    replicas[0..R-1]: full SegmentedIndex clones (bit-identical,
    rng state included), each with its own write lock and
    IndexMaintenance; writes fan out to all, replica 0 is the epoch
    source for handoff.

Consistency contract — the whole point: a ``MeshServer`` response is
bit-identical (ties included) to a single-host ``QueryServer`` over
the same pinned ``LiveView``, no matter what churn does meanwhile.
The sharded stack snapshots a consistent epoch; handoff swaps the
served ``MeshEpochState`` atomically BETWEEN micro-batches, so a batch
never mixes epochs and freshness lags by at most one handoff.

Shedding resolves a ticket immediately with ``status="shed"`` (empty
ids, zero scores) — counted per reason on the metrics registry and
logged to the index ``EventLog`` next to the seal/compact events, so
one stream tells the whole serving + maintenance story.
"""
from __future__ import annotations

import dataclasses
import threading
import time

import jax
import numpy as np

from repro.distributed import retrieval
from repro.serve.cache import TenantCachePartitions
from repro.serve.maintenance import IndexMaintenance
from repro.serve.server import (QueryServer, Response, ServerConfig,
                                Ticket)
from repro.serve.snapshot import restore_segmented, serialize_segmented
from repro.obs.trace import Trace

SHED_REASONS = ("admission", "deadline", "shutdown")


@dataclasses.dataclass(frozen=True)
class MeshConfig(ServerConfig):
    """ServerConfig + the mesh-only knobs.

    ``n_shards`` devices along mesh axis ``axis`` serve each query;
    ``topology`` picks the engine: ``"doc_stack"`` (the default) shards
    whole sealed segments — rebuilds at handoff are array re-stacks that
    reuse warm executables for repeated ``(size_class, layout)`` group
    signatures — while ``"term_fused"`` partitions the vocabulary
    (``term_layout`` hor/packed) and re-builds per handoff, the right
    trade only for near-static corpora.

    ``n_replicas`` full index replicas absorb writes in lockstep (the
    clone carries the rng state, so replicas stay bit-identical under
    identical mutation streams); each runs its own maintenance with
    ``seal_fill``/``maintenance_interval_s``.

    Admission control: at most ``max_queue`` tickets wait (``None`` =
    unbounded); a submit beyond that resolves immediately as
    ``shed("admission")``.  Deadline shedding: a ticket older than
    ``deadline_us`` — the latency target — at batch pickup resolves as
    ``shed("deadline")`` instead of burning shard time on an answer
    that already missed its budget.  ``None`` disables.

    ``auto_handoff`` re-pins after the primary's epoch advances (at
    most once per ``handoff_min_interval_s``, between micro-batches);
    tests drive ``handoff()`` explicitly with it off.
    """
    n_shards: int = 1
    axis: str = "shards"
    topology: str = "doc_stack"
    term_layout: str = "hor"
    n_replicas: int = 1
    max_queue: int | None = None
    deadline_us: float | None = None
    cache_capacity_per_tenant: int = 1024
    max_tenants: int = 64
    seal_fill: float = 0.75
    maintenance_interval_s: float = 0.002
    auto_handoff: bool = True
    handoff_min_interval_s: float = 0.05


class ShardReplica:
    """One full-index replica: a bit-identical ``SegmentedIndex`` clone
    with its own write lock and ``IndexMaintenance``.  The mesh applies
    every mutation to every replica; replica maintenance runs
    independently — seal/compaction is deterministic, so replicas that
    saw the same writes answer identically at equal epochs."""

    def __init__(self, index, cfg: MeshConfig):
        self.index = index
        self.lock = threading.RLock()
        self.maintenance = IndexMaintenance(
            index, self.lock, seal_fill=cfg.seal_fill,
            interval_s=cfg.maintenance_interval_s,
            layout_policy=cfg.layout_policy)

    def digest(self) -> tuple:
        """Cheap divergence signature over QUERY-VISIBLE state (docs,
        tombstones, df), compared across replicas at handoff.  Segment
        structure is deliberately excluded: maintenance timing differs
        per replica, and seal/compaction never change answers — only
        out-of-band writes that bypassed the mesh do, which is what
        this catches."""
        ix = self.index
        with self.lock:
            return (ix.num_docs, ix.live_doc_count,
                    int(np.asarray(ix._df).sum()))


@dataclasses.dataclass
class MeshEpochState:
    """Everything the pump needs to serve one pinned epoch: the view
    (the parity oracle's reference), the compiled sharded scorer, and
    the static group structure for tracing."""
    epoch: int
    view: object
    score_row: object          # fn(row u32[T], trace=None) -> (ids, scores)
    topology: str
    n_groups: int


def _null_score_row(k: int):
    def score_row(row, trace=None):
        return np.full(k, -1, np.int32), np.zeros(k, np.float32)
    return score_row


class MeshServer(QueryServer):
    """Sharded, replicated QueryServer (see module docstring).

    Drive it like the single-host server: ``submit``/``pump`` for the
    deterministic path, ``start``/``stop`` for the worker thread (which
    also starts/stops every replica's maintenance thread).  Mutations
    go through ``add_batch``/``delete_docs`` so all replicas stay in
    lockstep; ``handoff()`` (or ``auto_handoff``) publishes the next
    epoch to the shards.
    """

    def __init__(self, index, config: MeshConfig | None = None,
                 mesh=None):
        cfg = config or MeshConfig()
        if cfg.topology not in ("doc_stack", "term_fused"):
            raise ValueError(f"unknown mesh topology {cfg.topology!r}")
        self.mesh = (mesh if mesh is not None
                     else jax.make_mesh((cfg.n_shards,), (cfg.axis,)))
        if self.mesh.shape[cfg.axis] != cfg.n_shards:
            raise ValueError(
                f"mesh axis {cfg.axis!r} has {self.mesh.shape[cfg.axis]} "
                f"devices but config asks for {cfg.n_shards} shards")
        # replicas BEFORE super().__init__: the clone must not see the
        # layout_policy install (it gets its own below)
        primary = ShardReplica(index, cfg)
        self.replicas = [primary]
        for _ in range(cfg.n_replicas - 1):
            clone = restore_segmented(serialize_segmented(index))
            self.replicas.append(ShardReplica(clone, cfg))
        super().__init__(index, cfg, lock=primary.lock)
        if cfg.layout_policy is not None:
            for r in self.replicas[1:]:
                r.index.layout_policy = cfg.layout_policy
        if cfg.event_capacity is not None:
            for r in self.replicas[1:]:
                r.index.events.resize(cfg.event_capacity)
        # per-tenant result-cache partitions replace the flat LRU; the
        # metrics gauges follow the attach (they read _cache at call
        # time), so cache_hits/misses keep exporting unchanged
        self.cache = TenantCachePartitions(cfg.cache_capacity_per_tenant,
                                           cfg.max_tenants)
        self.metrics.attach_cache(self.cache)
        for reason in SHED_REASONS:
            self.registry.counter(f"mesh_shed_{reason}")
        self.registry.counter("mesh_shed_total")
        self.registry.counter("mesh_handoffs")
        self.registry.gauge("mesh_shards").set(cfg.n_shards)
        self.registry.register_callback(
            "mesh_epoch", lambda: self._state.epoch)
        self._state: MeshEpochState | None = None
        self._last_handoff_t = float("-inf")
        self.handoff()

    # -- writes: fan out to every replica --------------------------------

    def add_batch(self, corpus) -> None:
        """Ingest one tokenized batch on EVERY replica (identical
        mutation stream keeps the clones bit-identical)."""
        for r in self.replicas:
            with r.lock:
                r.index.add_batch(corpus)

    def delete_docs(self, doc_ids) -> None:
        for r in self.replicas:
            with r.lock:
                r.index.delete(doc_ids)

    def run_maintenance_once(self) -> list[dict]:
        """One deterministic maintenance step per replica (the
        thread-free drive the tests use)."""
        return [r.maintenance.run_once() for r in self.replicas]

    # -- epoch handoff ----------------------------------------------------

    def handoff(self) -> float:
        """Graceful cross-shard epoch handoff: seal the primary's delta
        (sharding replicates immutable runs only), pin its view, build
        the sharded state, and swap it in.  The swap is a single
        reference assignment read once per micro-batch, so in-flight
        batches finish on the old epoch and the next batch serves the
        new one — no quiesce, no mixed-epoch batch.  Returns the pause
        (seconds spent building before the swap) and logs a
        ``handoff`` event with it."""
        t0 = time.perf_counter()
        # seal EVERY replica's delta (sharding replicates immutable
        # runs only, and a promoted replica must be handoff-ready);
        # the primary's post-seal view is the epoch that ships
        view = None
        for r in self.replicas:
            with r.lock:
                if r.index._delta.n_docs > 0:
                    r.index.seal()
                if r is self.replicas[0]:
                    view = r.index.view()
        self._check_replicas()
        state = self._build_state(view)
        prev = self._state.epoch if self._state is not None else -1
        self._state = state
        self._pinned = view          # keep the QueryServer surface honest
        self._last_handoff_t = time.perf_counter()
        pause_us = (self._last_handoff_t - t0) * 1e6
        self.registry.counter("mesh_handoffs").inc()
        self.registry.histogram("mesh_handoff_pause_us").observe(pause_us)
        self.metrics.observe_layout_mix(view.layout_mix())
        self.index.events.emit(
            "handoff", epoch=state.epoch, prev_epoch=prev,
            n_shards=self.config.n_shards, topology=state.topology,
            groups=state.n_groups, pause_us=pause_us)
        return pause_us / 1e6

    def _check_replicas(self) -> None:
        ref = self.replicas[0].digest()
        for i, r in enumerate(self.replicas[1:], start=1):
            if r.digest() != ref:
                raise RuntimeError(
                    f"replica {i} diverged from primary ({r.digest()} != "
                    f"{ref}) — mutate through the mesh (add_batch/"
                    "delete_docs), not a replica's index directly")

    def _build_state(self, view) -> MeshEpochState:
        cfg = self.config
        k = cfg.k
        # nothing to shard: no sealed segments (doc topology replicates
        # immutable runs) / no live docs (term topology builds from the
        # live corpus).  Parity holds: the single-host view answers all
        # -1 / 0.0 here too.
        empty = (view.num_segments == 0 if cfg.topology == "doc_stack"
                 else view.live_docs == 0)
        if empty:
            return MeshEpochState(view.epoch, view, _null_score_row(k),
                                  cfg.topology, 0)
        if cfg.topology == "term_fused":
            tix, live_ids = retrieval.build_term_sharded_from_view(
                view, cfg.n_shards, layout=cfg.term_layout)
            scorer = retrieval.make_term_sharded_fused_scorer(
                tix, self.mesh, cfg.axis, k=k, cap=cfg.cap)

            def score_row(row, trace=None):
                vv, ii = scorer(np.asarray(row, np.uint32), trace=trace)
                vv, ii = np.asarray(vv), np.asarray(ii)
                hit = np.isfinite(vv) & (ii >= 0)
                gids = np.where(hit, live_ids[np.maximum(ii, 0)], -1)
                return (gids.astype(np.int32),
                        np.where(hit, vv, 0.0).astype(np.float32))

            return MeshEpochState(view.epoch, view, score_row,
                                  cfg.topology, cfg.n_shards)
        stacks = retrieval.stack_segment_shards(view, cfg.n_shards)
        scorer = retrieval.make_doc_sharded_segment_scorer(
            stacks, self.mesh, cfg.axis, k=k)

        def score_row(row, trace=None):
            vv, ii = scorer(np.asarray(row, np.uint32), trace=trace)
            vv, ii = np.asarray(vv), np.asarray(ii)
            hit = np.isfinite(vv)
            return (np.where(hit, ii, -1).astype(np.int32),
                    np.where(hit, vv, 0.0).astype(np.float32))

        return MeshEpochState(view.epoch, view, score_row, cfg.topology,
                              len(stacks.groups))

    def _handoff_due(self) -> bool:
        cfg = self.config
        if not cfg.auto_handoff:
            return False
        if self.replicas[0].index.epoch == self._state.epoch:
            return False
        return (time.perf_counter() - self._last_handoff_t
                >= cfg.handoff_min_interval_s)

    @property
    def serving_epoch(self) -> int:
        return self._state.epoch

    @property
    def serving_view(self):
        """The pinned LiveView currently served — the single-host
        parity reference for this epoch."""
        return self._state.view

    # -- admission + shedding ---------------------------------------------

    def submit(self, query_hashes, tenant: str = "default") -> Ticket:
        """Enqueue one query for ``tenant`` — or, when the admission
        queue is at ``max_queue``, resolve it immediately as shed."""
        ticket = self._make_ticket(query_hashes, tenant=tenant)
        cfg = self.config
        with self._qlock:
            if cfg.max_queue is not None and len(self._queue) >= cfg.max_queue:
                admitted = False
            else:
                self._queue.append(ticket)
                admitted = True
        if admitted:
            self._work.set()
        else:
            self._shed(ticket, "admission")
        return ticket

    def _shed(self, ticket: Ticket, reason: str,
              stage_t0: float | None = None,
              status: str = "shed") -> None:
        """Resolve ``ticket`` without serving it.  The shed span closes
        at the same clock reading the latency is computed from, so a
        sampled shed trace's stages sum exactly to its latency too."""
        now = time.perf_counter()
        latency_us = (now - ticket.t_submit) * 1e6
        tr = ticket.trace
        if tr is not None:
            tr.span("shed",
                    t0=stage_t0 if stage_t0 is not None else ticket.t_submit,
                    reason=reason).end(now)
            self.stages.observe_trace(tr)
        k = self.config.k
        epoch = self._state.epoch if self._state is not None else -1
        ticket.response = Response(
            np.full(k, -1, np.int32), np.zeros(k, np.float32), epoch,
            latency_us, False, trace=tr, status=status)
        self.registry.counter("mesh_shed_total").inc()
        self.registry.counter(f"mesh_shed_{reason}").inc()
        self.index.events.emit("shed", reason=reason, tenant=ticket.tenant,
                               epoch=epoch, latency_us=latency_us)
        ticket._done.set()

    def _resolve_shutdown(self, ticket: Ticket) -> None:
        # stop() leftovers count and log as sheds on the mesh
        self._shed(ticket, "shutdown", status="shutdown")

    def shed_counts(self) -> dict:
        out = {r: self.registry.counter(f"mesh_shed_{r}").value
               for r in SHED_REASONS}
        out["total"] = self.registry.counter("mesh_shed_total").value
        return out

    def shed_rate(self) -> float:
        """Shed over offered (served + shed) requests."""
        shed = self.registry.counter("mesh_shed_total").value
        offered = self.metrics.requests + shed
        return shed / offered if offered else 0.0

    # -- the sharded micro-batch ------------------------------------------

    def _serve_batch(self, batch: list[Ticket]) -> None:
        cfg = self.config
        traced = [t for t in batch if t.trace is not None]
        t_pickup = time.perf_counter() if traced else 0.0
        # handoff rides BETWEEN pickup and assembly so its cost is a
        # visible stage of the batch that paid it, not queue noise
        t_ready = t_pickup
        if self._handoff_due():
            self.handoff()
            if traced:
                t_ready = time.perf_counter()
        for t in traced:
            t.trace.span("queue_wait", t0=t.t_submit).end(t_pickup)
            if t_ready != t_pickup:
                t.trace.span("handoff", t0=t_pickup,
                             epoch=self._state.epoch).end(t_ready)
        state = self._state
        epoch = state.epoch
        self.metrics.observe_epoch(epoch)
        if epoch != self._purged_epoch:
            self.cache.purge_below(epoch)
            self._purged_epoch = epoch
        live: list[Ticket] = []
        for ticket in batch:
            if cfg.deadline_us is not None and (
                    (time.perf_counter() - ticket.t_submit) * 1e6
                    > cfg.deadline_us):
                self._shed(ticket, "deadline",
                           stage_t0=t_ready if ticket.trace is not None
                           else None)
            else:
                live.append(ticket)
        pending: list[tuple[Ticket, tuple]] = []
        for ticket in live:
            key = self.cache.make_key(ticket.row, cfg.k, epoch)
            hit = self.cache.get(ticket.tenant, key)
            if hit is not None:
                self._respond(ticket, hit[0], hit[1], epoch, cached=True,
                              stage_t0=t_ready)
            else:
                pending.append((ticket, key))
        if not pending:
            return
        btr = (Trace() if any(t.trace is not None for t, _ in pending)
               else None)
        asm = (btr.span("assemble", t0=t_ready, epoch=epoch,
                        fill=len(pending),
                        padded_slots=cfg.batch_size - len(pending))
               if btr is not None else None)
        rows = [ticket.row for ticket, _ in pending]
        if asm is not None:
            asm.end()
        score = (btr.span("score", t0=asm.t1, topology=state.topology,
                          n_shards=cfg.n_shards, groups=state.n_groups)
                 if btr is not None else None)
        # shard fan-out per row: each query runs one fused candidate
        # kernel per local segment on every shard + all-gather merge
        results = [state.score_row(row, trace=btr) for row in rows]
        if score is not None:
            score.end()
        t_scored = score.t1 if score is not None else None
        for (ticket, key), (ids, scores) in zip(pending, results):
            self.cache.put(ticket.tenant, key, ids, scores)
            if ticket.trace is not None:
                ticket.trace.adopt(btr.spans)
            self._respond(ticket, ids.copy(), scores.copy(), epoch,
                          cached=False, stage_t0=t_scored)
        self.metrics.batches += 1
        self.metrics.batched_queries += len(pending)
        self.metrics.padded_slots += cfg.batch_size - len(pending)

    # -- warmup / lifecycle -----------------------------------------------

    def warmup(self) -> None:
        """Compile the current epoch's sharded path (one empty row —
        shapes don't depend on query content).  Re-pinning a stack with
        the same group signatures after churn stays warm."""
        self._state.score_row(np.zeros(self.config.n_terms_budget,
                                       np.uint32))

    def start(self) -> None:
        for r in self.replicas:
            r.maintenance.start()
        super().start()

    def stop(self) -> None:
        for r in self.replicas:
            r.maintenance.stop()
        super().stop()       # drains, then sheds leftovers ("shutdown")

    def mesh_summary(self) -> dict:
        """``ServerMetrics.summary()`` + the mesh-side aggregates."""
        out = self.metrics.summary()
        hist = self.registry.histogram("mesh_handoff_pause_us").snapshot()
        out.update(
            epoch=self._state.epoch, topology=self.config.topology,
            n_shards=self.config.n_shards,
            n_replicas=len(self.replicas),
            shed=self.shed_counts(), shed_rate=self.shed_rate(),
            handoffs=self.registry.counter("mesh_handoffs").value,
            handoff_pause_us={k: hist[k]
                              for k in ("count", "p50", "p99")
                              if k in hist},
            tenants=self.cache.per_tenant())
        return out
