"""Serving metrics: latency percentiles, throughput, batch fill.

One percentile implementation for the whole repo — the serving tier's
in-process metrics AND the benchmark reporting (``benchmarks/common``)
both call :func:`percentiles`, so a p99 printed by ``churn.py`` and a
p99 served from ``QueryServer.metrics`` can never disagree on
definition (linear-interpolated, numpy semantics).

``ServerMetrics`` is backed by a ``repro.obs.MetricsRegistry``: the
counters it exposes as attributes (``requests``, ``batches``, ...) are
registry counters, the cache's hit/miss counters are registered as
callback gauges at server init, and the latency window's percentiles
are exported as callback gauges — so ``registry.snapshot()`` is the
single machine-readable export and ``summary()`` is its human-facing
projection.
"""
from __future__ import annotations

import time
import warnings

import numpy as np

from repro.obs.registry import MetricsRegistry


def percentiles(samples, qs=(50, 99)) -> dict:
    """``{"p50": ..., "p99": ...}`` over ``samples`` (any iterable of
    numbers); empty input yields zeros rather than NaNs so callers can
    format unconditionally."""
    a = np.asarray(list(samples), np.float64)
    if a.size == 0:
        return {f"p{int(q)}": 0.0 for q in qs}
    return {f"p{int(q)}": float(np.percentile(a, q)) for q in qs}


class LatencyWindow:
    """Per-request latency samples over one serving window.

    ``record`` is called at response time with the request's measured
    latency; QPS is completions over the wall span from the first to
    the last response in the window.
    """

    def __init__(self):
        self.reset()

    def reset(self) -> None:
        self._us: list[float] = []
        self._first: float | None = None
        self._last: float | None = None

    def record(self, latency_us: float) -> None:
        now = time.perf_counter()
        if self._first is None:
            self._first = now
        self._last = now
        self._us.append(float(latency_us))

    @property
    def count(self) -> int:
        return len(self._us)

    def samples_us(self) -> np.ndarray:
        return np.asarray(self._us, np.float64)

    def qps(self) -> float:
        if self.count < 2 or self._last is None or self._first is None:
            return 0.0
        span = self._last - self._first
        if span <= 0:
            return 0.0
        # completions after the first mark the span's throughput
        return (self.count - 1) / span

    def summary(self) -> dict:
        p = percentiles(self._us, (50, 99))
        mean = float(np.mean(self._us)) if self._us else 0.0
        return {"count": self.count, "p50_us": p["p50"],
                "p99_us": p["p99"], "mean_us": mean, "qps": self.qps()}


def _counter_property(name: str):
    """Registry counter exposed as a plain int attribute: ``+= 1`` and
    direct assignment both work, so callers written against the old
    dataclass fields keep working unchanged."""

    def fget(self) -> int:
        return self.registry.counter(name).value

    def fset(self, value: int) -> None:
        c = self.registry.counter(name)
        c.reset()
        c.inc(int(value))

    return property(fget, fset)


class ServerMetrics:
    """QueryServer counters + the latency window, registry-backed.

    ``padded_slots`` counts batch slots filled with padding (a measure
    of micro-batch efficiency: fill = batched_queries /
    (batched_queries + padded_slots)); cache hits bypass batching
    entirely and appear only in ``requests`` and the cache's own
    counters — which are registered here at server init, so
    ``summary()`` is complete without the caller passing the cache.
    """

    _COUNTERS = ("serve_requests", "serve_batches",
                 "serve_batched_queries", "serve_padded_slots",
                 "serve_epochs_served")

    requests = _counter_property("serve_requests")
    batches = _counter_property("serve_batches")
    batched_queries = _counter_property("serve_batched_queries")
    padded_slots = _counter_property("serve_padded_slots")
    epochs_served = _counter_property("serve_epochs_served")

    def __init__(self, registry: MetricsRegistry | None = None,
                 cache=None):
        self.registry = registry if registry is not None else MetricsRegistry()
        self.latency = LatencyWindow()
        self.layout_mix: dict = {}
        self._last_epoch: int | None = None
        self._cache = None
        for name in self._COUNTERS:
            self.registry.counter(name)
        self._register("serve_latency_p50_us",
                       lambda: percentiles(self.latency._us)["p50"])
        self._register("serve_latency_p99_us",
                       lambda: percentiles(self.latency._us)["p99"])
        self._register("serve_qps", self.latency.qps)
        self._register("serve_batch_fill", self.batch_fill)
        if cache is not None:
            self.attach_cache(cache)

    def _register(self, name: str, fn) -> None:
        if self.registry.get(name) is None:
            self.registry.register_callback(name, fn)

    def attach_cache(self, cache) -> None:
        """Register the ResultCache counters as callback gauges so the
        snapshot and ``summary()`` carry them unconditionally."""
        self._cache = cache
        self._register("cache_hits", lambda: self._cache.hits)
        self._register("cache_misses", lambda: self._cache.misses)
        self._register("cache_hit_rate", lambda: self._cache.hit_rate)
        self._register("cache_entries", lambda: len(self._cache))

    def observe_epoch(self, epoch: int) -> None:
        if epoch != self._last_epoch:
            self.epochs_served += 1
            self._last_epoch = epoch

    def observe_layout_mix(self, mix: dict) -> None:
        """Record the served stack's per-layout composition (from
        ``LiveView.layout_mix``) — aggregates only, the per-segment
        decision list stays on the view.  Called by the server whenever
        the pinned epoch advances, so the summary always reflects the
        layout mix the LAST served epoch had converged to."""
        self.layout_mix = {k: v for k, v in mix.items()
                           if k != "segments"}

    def record_response(self, latency_us: float) -> None:
        self.requests += 1
        self.latency.record(latency_us)

    def batch_fill(self) -> float:
        total = self.batched_queries + self.padded_slots
        return self.batched_queries / total if total else 0.0

    def reset(self) -> None:
        for name in self._COUNTERS:
            self.registry.counter(name).reset()
        self._last_epoch = None
        self.layout_mix = {}
        self.latency.reset()

    def snapshot(self) -> dict:
        """The registry's stable export (see ``repro.obs.registry``)."""
        return self.registry.snapshot()

    def summary(self, cache=None) -> dict:
        """Human-facing aggregate. The ``cache=`` argument is
        deprecated AND inert: the cache attached at init (or via
        ``attach_cache``) is the only one reported — passing one here
        warns and has no effect.  The parameter survives one more
        release for signature compatibility only."""
        if cache is not None:
            warnings.warn(
                "ServerMetrics.summary(cache=...) is deprecated and "
                "ignored — attach the cache with attach_cache() (the "
                "servers do this at init); the attached cache is "
                "reported unconditionally", DeprecationWarning,
                stacklevel=2)
        src = self._cache
        out = {"requests": self.requests, "batches": self.batches,
               "batch_fill": self.batch_fill(),
               "epochs_served": self.epochs_served,
               "layout_mix": self.layout_mix}
        out.update(self.latency.summary())
        if src is not None:
            out["cache_hit_rate"] = src.hit_rate
            out["cache_hits"] = src.hits
            out["cache_misses"] = src.misses
        return out
