"""Serving metrics: latency percentiles, throughput, batch fill.

One percentile implementation for the whole repo — the serving tier's
in-process metrics AND the benchmark reporting (``benchmarks/common``)
both call :func:`percentiles`, so a p99 printed by ``churn.py`` and a
p99 served from ``QueryServer.metrics`` can never disagree on
definition (linear-interpolated, numpy semantics).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np


def percentiles(samples, qs=(50, 99)) -> dict:
    """``{"p50": ..., "p99": ...}`` over ``samples`` (any iterable of
    numbers); empty input yields zeros rather than NaNs so callers can
    format unconditionally."""
    a = np.asarray(list(samples), np.float64)
    if a.size == 0:
        return {f"p{int(q)}": 0.0 for q in qs}
    return {f"p{int(q)}": float(np.percentile(a, q)) for q in qs}


class LatencyWindow:
    """Per-request latency samples over one serving window.

    ``record`` is called at response time with the request's measured
    latency; QPS is completions over the wall span from the first to
    the last response in the window.
    """

    def __init__(self):
        self.reset()

    def reset(self) -> None:
        self._us: list[float] = []
        self._first: float | None = None
        self._last: float | None = None

    def record(self, latency_us: float) -> None:
        now = time.perf_counter()
        if self._first is None:
            self._first = now
        self._last = now
        self._us.append(float(latency_us))

    @property
    def count(self) -> int:
        return len(self._us)

    def samples_us(self) -> np.ndarray:
        return np.asarray(self._us, np.float64)

    def qps(self) -> float:
        if self.count < 2 or self._last is None or self._first is None:
            return 0.0
        span = self._last - self._first
        if span <= 0:
            return 0.0
        # completions after the first mark the span's throughput
        return (self.count - 1) / span

    def summary(self) -> dict:
        p = percentiles(self._us, (50, 99))
        mean = float(np.mean(self._us)) if self._us else 0.0
        return {"count": self.count, "p50_us": p["p50"],
                "p99_us": p["p99"], "mean_us": mean, "qps": self.qps()}


@dataclasses.dataclass
class ServerMetrics:
    """QueryServer counters + the latency window.

    ``padded_slots`` counts batch slots filled with padding (a measure
    of micro-batch efficiency: fill = batched_queries /
    (batched_queries + padded_slots)); cache hits bypass batching
    entirely and appear only in ``requests`` and the cache's own
    counters.
    """
    requests: int = 0
    batches: int = 0
    batched_queries: int = 0      # requests that went through a kernel
    padded_slots: int = 0
    epochs_served: int = 0        # distinct epochs observed at batch time
    latency: LatencyWindow = dataclasses.field(default_factory=LatencyWindow)
    layout_mix: dict = dataclasses.field(default_factory=dict)
    _last_epoch: int | None = dataclasses.field(default=None, repr=False)

    def observe_epoch(self, epoch: int) -> None:
        if epoch != self._last_epoch:
            self.epochs_served += 1
            self._last_epoch = epoch

    def observe_layout_mix(self, mix: dict) -> None:
        """Record the served stack's per-layout composition (from
        ``LiveView.layout_mix``) — aggregates only, the per-segment
        decision list stays on the view.  Called by the server whenever
        the pinned epoch advances, so the summary always reflects the
        layout mix the LAST served epoch had converged to."""
        self.layout_mix = {k: v for k, v in mix.items()
                           if k != "segments"}

    def record_response(self, latency_us: float) -> None:
        self.requests += 1
        self.latency.record(latency_us)

    def batch_fill(self) -> float:
        total = self.batched_queries + self.padded_slots
        return self.batched_queries / total if total else 0.0

    def reset(self) -> None:
        self.requests = 0
        self.batches = 0
        self.batched_queries = 0
        self.padded_slots = 0
        self.epochs_served = 0
        self._last_epoch = None
        self.layout_mix = {}
        self.latency.reset()

    def summary(self, cache=None) -> dict:
        out = {"requests": self.requests, "batches": self.batches,
               "batch_fill": self.batch_fill(),
               "epochs_served": self.epochs_served,
               "layout_mix": self.layout_mix}
        out.update(self.latency.summary())
        if cache is not None:
            out["cache_hit_rate"] = cache.hit_rate
            out["cache_hits"] = cache.hits
            out["cache_misses"] = cache.misses
        return out
