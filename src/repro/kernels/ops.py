"""jit'd public wrappers around the Pallas kernels (+ XLA fallbacks).

``backend`` selects: "pallas" (interpret=True on CPU — kernel-body
semantics validated in Python), "pallas-tpu" (compiled, real hardware),
or "xla" (the ref.py oracle path — also what the multi-pod dry-run
lowers, so GSPMD sees plain HLO).
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core.layouts import BlockedIndex, PackedCsrIndex
from repro.kernels import ref
from repro.kernels.embedding_bag import embedding_bag_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.packed_postings import unpack_blocks_pallas
from repro.kernels.posting_score import TILE, build_pairs, posting_score_pallas
from repro.kernels.segment_multi_agg import pna_multi_agg_pallas

Array = jax.Array
Backend = Literal["pallas", "pallas-tpu", "xla"]


def _interp(backend: Backend) -> bool:
    return backend != "pallas-tpu"


# ---------------------------------------------------------------------------
# posting-list scoring over a BlockedIndex (the paper's q_occ hot path)
# ---------------------------------------------------------------------------


def select_query_blocks(index: BlockedIndex, term_ids: Array, idf_w: Array,
                        max_blocks_per_term: int):
    """Selected (global block id, validity, per-block weight) for a query."""
    safe = jnp.maximum(term_ids, 0)
    start = index.block_offsets[safe]
    nb = index.block_offsets[safe + 1] - start
    k = jnp.arange(max_blocks_per_term, dtype=jnp.int32)
    sel = (start[:, None] + k[None, :])
    valid = (k[None, :] < nb[:, None]) & (term_ids >= 0)[:, None]
    sel = jnp.where(valid, sel, 0)
    w = jnp.broadcast_to(idf_w[:, None], sel.shape)
    return sel.reshape(-1), valid.reshape(-1), w.reshape(-1)


def blocked_query_scores(index: BlockedIndex, term_ids: Array, idf_w: Array,
                         max_blocks_per_term: int, max_pairs: int,
                         tile: int = TILE,
                         backend: Backend = "pallas") -> Array:
    """Dense per-doc scores for ONE query via the posting_score kernel."""
    sel, valid, w = select_query_blocks(index, term_ids, idf_w,
                                        max_blocks_per_term)
    num_docs = index.docs.num_docs
    if backend == "xla":
        bd = jnp.where(valid[:, None], index.block_docs[sel], -1)
        bt = jnp.where(valid[:, None], index.block_tfs[sel], 0.0)
        return ref.ref_posting_score(bd, bt, w * valid, num_docs)
    pb, pt, pw, _overflow = build_pairs(
        sel, valid, w, index.block_min, index.block_max, num_docs,
        max_pairs, tile)
    return posting_score_pallas(index.block_docs, index.block_tfs,
                                pb, pt, pw, num_docs, tile,
                                interpret=_interp(backend))


# ---------------------------------------------------------------------------
# packed-posting decode
# ---------------------------------------------------------------------------


def unpack_postings(index: PackedCsrIndex,
                    backend: Backend = "pallas") -> Array:
    """Decode ALL blocks of a PackedCsrIndex -> doc ids i32[NB, block]."""
    if backend == "xla":
        return ref.ref_unpack_blocks(index.packed, index.block_bits,
                                     index.block_base, index.block_count,
                                     index.block)
    return unpack_blocks_pallas(index.packed, index.block_bits,
                                index.block_base, index.block_count,
                                index.block, interpret=_interp(backend))


# ---------------------------------------------------------------------------
# embedding bag / PNA aggregation / attention
# ---------------------------------------------------------------------------


def embedding_bag(table: Array, indices: Array, tile_b: int = 256,
                  backend: Backend = "xla") -> Array:
    if backend == "xla":
        return ref.ref_embedding_bag(table, indices)
    return embedding_bag_pallas(table, indices, tile_b=tile_b,
                                interpret=_interp(backend))


def pna_multi_agg(feats: Array, nbr: Array, tile_n: int = 128,
                  backend: Backend = "xla") -> Array:
    if backend == "xla":
        return ref.ref_pna_multi_agg(feats, nbr)
    return pna_multi_agg_pallas(feats, nbr, tile_n=tile_n,
                                interpret=_interp(backend))


def attention(q: Array, k: Array, v: Array, causal: bool = True,
              window: int = 0, backend: Backend = "xla",
              block_q: int = 128, block_k: int = 128) -> Array:
    if backend == "xla":
        return ref.ref_attention(q, k, v, causal=causal, window=window)
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  block_q=block_q, block_k=block_k,
                                  interpret=_interp(backend))
