"""jit'd public wrappers around the Pallas kernels (+ XLA fallbacks).

``backend`` selects: "pallas" (auto: compiled on TPU, interpret-mode
elsewhere — keyed on ``jax.default_backend()``), "pallas-tpu" (force
compiled), or "xla" (the ref.py oracle path — also what the multi-pod
dry-run lowers, so GSPMD sees plain HLO).

This module is also the engine layer for query evaluation: the fused
batched decode-and-score path routes a whole query batch through ONE
Pallas kernel launch — packed posting blocks are decoded in VMEM and
scored against a ``[Q, tile]`` accumulator, so the compressed bytes are
the only posting bytes that cross HBM.  ``fused_batched_scores`` is the
dense engine (full [B, num_docs] score array out);
``fused_batched_topk`` is the candidate engine (per-tile partial top-k
reduced IN VMEM — only O(B * n_tiles * k_tile) candidates reach HBM).
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core.layouts import BandedCsrIndex, BlockedIndex, PackedCsrIndex
from repro.core.query import final_scores
from repro.kernels import ref
from repro.kernels.embedding_bag import embedding_bag_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.fused_decode_score import (
    Q_PAD, build_batched_pairs, default_k_tile, extract_tile_candidates,
    fused_score_blocked_pallas, fused_score_packed_pallas,
    fused_topk_blocked_pallas, fused_topk_packed_pallas)
from repro.kernels.packed_postings import unpack_blocks_pallas
from repro.kernels.posting_score import TILE, build_pairs, posting_score_pallas
from repro.kernels.segment_multi_agg import pna_multi_agg_pallas

Array = jax.Array
Backend = Literal["pallas", "pallas-tpu", "xla"]


def _interp(backend: Backend) -> bool | None:
    """None -> auto (compiled iff jax.default_backend() == "tpu")."""
    return None if backend == "pallas" else False


def _count_capacity_pressure(name: str, amount) -> None:
    """Host-side increment of a process-global registry counter —
    invoked from inside jitted code via ``jax.debug.callback``, so the
    engines stay pure jax while the pressure is still countable."""
    from repro.obs.registry import GLOBAL
    GLOBAL.counter(name).inc(int(amount))


def warn_on_overflow(overflow: Array, label: str) -> None:
    """Routing overflow is surfaced, never silent — shared by every
    engine entry point so the contract can't drift between them.  Each
    overflow also increments the process-global ``engine_pair_overflow``
    registry counter (same taken-branch — zero work when clean)."""

    def _warn(o):
        jax.debug.print(
            label + ": routing overflow dropped {o} (block, tile) "
            "pairs — raise max_pairs", o=o)
        jax.debug.callback(
            functools.partial(_count_capacity_pressure,
                              "engine_pair_overflow"), o)

    jax.lax.cond(overflow > 0, _warn, lambda o: None, overflow)


def record_truncated(truncated, counter: str = "engine_truncated_terms"
                     ) -> None:
    """Count conjunctive cap-truncation into the process-global
    registry.  Accepts a host int (counted directly) or a traced array
    (counted via ``jax.debug.callback`` on the taken branch) — callers
    keep returning the stat either way; this only makes the pressure
    visible per process instead of per call site."""
    if isinstance(truncated, (int, float)):
        if truncated > 0:
            _count_capacity_pressure(counter, truncated)
        return
    jax.lax.cond(
        truncated > 0,
        lambda t: jax.debug.callback(
            functools.partial(_count_capacity_pressure, counter), t),
        lambda t: None, truncated)


# ---------------------------------------------------------------------------
# posting-list scoring over a BlockedIndex (the paper's q_occ hot path)
# ---------------------------------------------------------------------------


def routing_spans(index: BlockedIndex | PackedCsrIndex, tile: int):
    """(tile_first, tile_count, n_tiles) for ``tile``-wide doc tiles.

    Uses the index's build-time pair-routing cache when ``tile`` matches
    its ``route_tile``; otherwise derives spans from the per-block
    min/max summaries (cheap, but per-trace instead of per-build).
    """
    num_docs = index.docs.num_docs
    n_tiles = max(-(-num_docs // tile), 1)
    if tile == index.route_tile and index.tile_first is not None:
        return index.tile_first, index.tile_count, n_tiles
    has = index.block_max >= 0
    t0 = jnp.clip(index.block_min // tile, 0, n_tiles - 1)
    t1 = jnp.clip(index.block_max // tile, 0, n_tiles - 1)
    return (jnp.where(has, t0, 0).astype(jnp.int32),
            jnp.where(has, t1 - t0 + 1, 0).astype(jnp.int32), n_tiles)


def select_query_blocks(index: BlockedIndex, term_ids: Array, idf_w: Array,
                        max_blocks_per_term: int):
    """Selected (global block id, validity, per-block weight) for a query."""
    safe = jnp.maximum(term_ids, 0)
    start = index.block_offsets[safe]
    nb = index.block_offsets[safe + 1] - start
    k = jnp.arange(max_blocks_per_term, dtype=jnp.int32)
    sel = (start[:, None] + k[None, :])
    valid = (k[None, :] < nb[:, None]) & (term_ids >= 0)[:, None]
    sel = jnp.where(valid, sel, 0)
    w = jnp.broadcast_to(idf_w[:, None], sel.shape)
    return sel.reshape(-1), valid.reshape(-1), w.reshape(-1)


def blocked_query_scores(index: BlockedIndex, term_ids: Array, idf_w: Array,
                         max_blocks_per_term: int, max_pairs: int,
                         tile: int = TILE,
                         backend: Backend = "pallas") -> Array:
    """Dense per-doc scores for ONE query via the posting_score kernel."""
    sel, valid, w = select_query_blocks(index, term_ids, idf_w,
                                        max_blocks_per_term)
    num_docs = index.docs.num_docs
    if backend == "xla":
        bd = jnp.where(valid[:, None], index.block_docs[sel], -1)
        bt = jnp.where(valid[:, None], index.block_tfs[sel], 0.0)
        return ref.ref_posting_score(bd, bt, w * valid, num_docs)
    tfirst, tcount, n_tiles = routing_spans(index, tile)
    pb, pt, pw, _overflow = build_pairs(sel, valid, w, tfirst, tcount,
                                        n_tiles, max_pairs)
    return posting_score_pallas(index.block_docs, index.block_tfs,
                                pb, pt, pw, num_docs, tile,
                                interpret=_interp(backend))


# ---------------------------------------------------------------------------
# fused batched decode-and-score (the engine hot path)
# ---------------------------------------------------------------------------


def default_max_pairs(index: BlockedIndex | PackedCsrIndex, num_queries: int,
                      num_terms: int, cap: int, tile: int = TILE) -> int:
    """Static routing-pair budget for a batch.

    After cross-query dedup, pairs are unique (block, tile) — bounded
    both by the whole index's span sum (``route_pairs_max``) and by
    candidate-count x worst single-block span.  Both bounds are exact
    for ``tile == route_tile``, so overflow is impossible at the default
    tile; for other widths the span scales by ``route_tile / tile``.
    """
    m = max(-(-min(cap, max(index.max_posting_len, 1)) // index.block), 1)
    cands = num_queries * num_terms * m
    span = index.route_span_max
    pairs_max = index.route_pairs_max
    if tile != index.route_tile:
        scale = max(-(-index.route_tile // tile), 1)
        nb = (index.packed.shape[0] if isinstance(index, PackedCsrIndex)
              else index.block_docs.shape[0])
        span = span * scale + 1
        pairs_max = pairs_max * scale + nb
    return max(min(pairs_max, cands * max(span, 1)), 8)


def scaled_pairs_budget(index: BlockedIndex | PackedCsrIndex,
                        tile: int = TILE) -> int:
    """Whole-index routing-pair bound at an arbitrary tile width.

    ``route_pairs_max`` is exact for ``tile == route_tile``; narrower
    tiles split each block's span into at most ``ceil(route_tile/tile)``
    extra tiles, wider tiles can only merge spans (the +NB term covers
    off-by-one tile straddles in both directions).  This is what the
    segment engines pass as their static ``max_pairs`` when an autotuned
    config retunes ``tile`` away from the seal-time route tile.
    """
    if tile == index.route_tile:
        return int(index.route_pairs_max)
    scale = max(-(-index.route_tile // tile), 1)
    nb = (index.packed.shape[0] if isinstance(index, PackedCsrIndex)
          else index.block_docs.shape[0])
    return max(int(index.route_pairs_max) * scale + int(nb), 8)


def round_up_pairs(max_pairs: int, pairs_per_step: int) -> int:
    """Pair budgets must be a multiple of the kernel's unroll factor."""
    pps = max(int(pairs_per_step), 1)
    return -(-int(max_pairs) // pps) * pps


def widen_pairs_for_step(max_pairs: int, num_docs: int, tile: int,
                         pairs_per_step: int) -> int:
    """Widen a pair budget for run-aligned no-op padding, then round up.

    ``build_batched_pairs(..., pairs_per_step=pps)`` pads every tile's
    pair run to a multiple of ``pps``, inserting up to ``pps - 1`` no-op
    pairs per visited tile — so a budget that is exact at ``pps == 1``
    (e.g. ``route_pairs_max`` at the route tile) overflows under
    ``pps > 1`` and real routing pairs get DROPPED.  Every ``pps``-aware
    budget must flow through here (the sharded scorers inline the same
    arithmetic on their meta shapes).
    """
    pps = max(int(pairs_per_step), 1)
    if pps > 1:
        n_tiles = max(-(-int(num_docs) // max(int(tile), 1)), 1)
        max_pairs = int(max_pairs) + n_tiles * (pps - 1)
    return round_up_pairs(max_pairs, pps)


def padded_pairs_budget(index: BlockedIndex | PackedCsrIndex,
                        tile: int = TILE,
                        pairs_per_step: int = 1) -> int:
    """``scaled_pairs_budget`` made safe for a tuned ``pairs_per_step``:
    the whole-index budget at ``tile``, widened for run-aligned padding
    and rounded to the unroll quantum.  THE budget the per-segment query
    paths (LiveView.topk, the autotuner's timing loop) must use — taking
    ``scaled_pairs_budget`` + ``round_up_pairs`` directly silently drops
    postings whenever ``pairs_per_step > 1``."""
    return widen_pairs_for_step(
        scaled_pairs_budget(index, tile), index.docs.num_docs, tile,
        pairs_per_step)


def expand_block_candidates(block_offsets: Array, term_ids: Array,
                            idf_w: Array, m: int, block: int,
                            cap: int | None = None):
    """Flat candidate (query, term, block) triples for a term batch.

    term_ids i32[B, T] (-1 absent), idf_w f32[B, T].  Shared by the
    single-node fused engine and the doc-sharded shard_map scorer so cap
    handling stays in lockstep.  Returns
    (cand_block, cand_valid, cand_q, cand_w, cand_cap) flattened to
    [B*T*m]; cand_cap is None when ``cap`` is None (read whole blocks).
    """
    b, t = term_ids.shape
    safe = jnp.maximum(term_ids, 0)
    start = block_offsets[safe]
    nb = block_offsets[safe + 1] - start
    k = jnp.arange(m, dtype=jnp.int32)
    cand_block = (start[..., None] + k).reshape(-1)
    cand_valid = ((k < jnp.minimum(nb, m)[..., None]) &
                  (term_ids >= 0)[..., None]).reshape(-1)
    cand_q = jnp.broadcast_to(
        jnp.arange(b, dtype=jnp.int32)[:, None, None], (b, t, m)).reshape(-1)
    cand_w = jnp.broadcast_to(idf_w[..., None], (b, t, m)).reshape(-1)
    cand_cap = None
    if cap is not None:
        # lanes of the k-th block the posting cap still permits — a cap
        # cutting mid-block truncates the last block, like the oracle
        cand_cap = jnp.broadcast_to(
            jnp.clip(cap - k * block, 0, block)[None, None, :],
            (b, t, m)).reshape(-1)
    return cand_block, cand_valid, cand_q, cand_w, cand_cap


def fused_batched_scores(index: BlockedIndex | PackedCsrIndex,
                         term_ids: Array, idf_w: Array, cap: int,
                         max_pairs: int | None = None, tile: int = TILE,
                         backend: Backend = "pallas", q_pad: int = Q_PAD):
    """Dense scores f32[B, num_docs] for a BATCH of queries in one fused
    kernel launch, plus the routing-overflow counter.

    term_ids i32[B, T] (-1 absent), idf_w f32[B, T] per-slot weights.
    ``cap`` bounds postings read per term at POSTING granularity (the
    last selected block is lane-masked), matching the jnp oracle's
    gather cap exactly.
    """
    b, t = term_ids.shape
    block = index.block
    num_docs = index.docs.num_docs
    m = max(-(-min(cap, max(index.max_posting_len, 1)) // block), 1)
    if isinstance(index, BlockedIndex):
        m = min(m, max(index.max_blocks_per_term, 1))
    if max_pairs is None:
        max_pairs = default_max_pairs(index, b, t, cap, tile)

    cand_block, cand_valid, cand_q, cand_w, cand_cap = \
        expand_block_candidates(index.block_offsets, term_ids, idf_w,
                                m, block, cap)

    if backend == "xla":
        # same cross-query block dedup, lowered as plain HLO: each unique
        # block is read once and scatter-adds a [B]-wide row per posting
        # (ONE scatter for the whole batch, not one per query)
        nb_total = (index.packed.shape[0]
                    if isinstance(index, PackedCsrIndex)
                    else index.block_docs.shape[0])
        # block-level dedup only: one pair per unique block, so the
        # candidate count itself is an exact pair bound
        max_pairs = min(max_pairs, cand_block.shape[0])
        pb, _, pqw, pcap, overflow = build_batched_pairs(
            cand_block, cand_valid, cand_q, cand_w.astype(jnp.float32),
            jnp.zeros((nb_total,), jnp.int32),
            jnp.ones((nb_total,), jnp.int32), 1, b, max_pairs=max_pairs,
            cand_cap=cand_cap)
        if isinstance(index, PackedCsrIndex):
            docs = ref.ref_unpack_blocks(
                index.packed[pb], index.block_bits[pb],
                index.block_base[pb], index.block_count[pb], block)
            tfs = index.block_tfs[pb].astype(jnp.float32)
        else:
            docs = index.block_docs[pb]
            tfs = index.block_tfs[pb]
        lane_ok = (docs >= 0) & (jnp.arange(block, dtype=jnp.int32)[None, :]
                                 < pcap[:, None])
        flat_doc = jnp.where(lane_ok, docs, num_docs).reshape(-1)
        rows = (jnp.where(lane_ok, tfs, 0.0)[:, :, None] *
                pqw[:, None, :]).reshape(-1, pqw.shape[1])
        acc = jnp.zeros((num_docs + 1, pqw.shape[1]), jnp.float32)
        acc = acc.at[flat_doc].add(rows, mode="drop")
        return acc[:num_docs].T[:b], overflow

    tfirst, tcount, n_tiles = routing_spans(index, tile)
    pb, pt, pqw, pcap, overflow = build_batched_pairs(
        cand_block, cand_valid, cand_q,
        cand_w.astype(jnp.float32), tfirst, tcount, n_tiles, b, max_pairs,
        cand_cap=cand_cap)

    # pad the query batch to the accumulator quantum
    bp = -(-b // max(q_pad, 1)) * max(q_pad, 1)
    if bp != b:
        pqw = jnp.pad(pqw, ((0, 0), (0, bp - b)))

    if isinstance(index, PackedCsrIndex):
        scores = fused_score_packed_pallas(
            index.packed, index.block_tfs, pb, pt, pqw, pcap,
            index.block_bits[pb], index.block_base[pb],
            index.block_count[pb], num_docs, block, tile,
            interpret=_interp(backend))
    else:
        scores = fused_score_blocked_pallas(
            index.block_docs, index.block_tfs, pb, pt, pqw, pcap,
            num_docs, tile, interpret=_interp(backend))
    return scores[:b], overflow


def fused_batched_topk(index: BlockedIndex | PackedCsrIndex,
                       term_ids: Array, idf_w: Array, cap: int, k: int,
                       rank_blend: float = 0.0,
                       max_pairs: int | None = None, tile: int = TILE,
                       k_tile: int | None = None,
                       backend: Backend = "pallas", q_pad: int = Q_PAD,
                       reducer: str = "successive",
                       pairs_per_step: int = 1):
    """The candidate path: per-tile partial top-k INSIDE the fused
    engine, so the dense [B, num_docs] score array never reaches HBM.

    Same contract as ``fused_batched_scores`` up to the accumulator;
    each doc tile is then reduced (in VMEM, on its last grid step) to
    ``k_tile`` (value, global doc id) candidates of FINAL score — the
    doc-metadata tail (norm, deleted-doc mask, rank blend) is applied
    per-tile, not densely.  ``k_tile`` defaults to the exactness floor
    ``min(k, tile)`` (rounded up to the lane quantum), which guarantees
    a pure ``merge_topk_candidates`` over the returned tile-major lists
    reproduces the dense oracle's top-k bit-identically.

    Returns (cand_values f32[B, n_tiles*k_tile],
    cand_ids i32[B, n_tiles*k_tile], overflow).

    ``reducer`` / ``pairs_per_step`` / ``q_pad`` are autotuner-selected
    kernel geometry (see ``kernels/autotune.py``); the defaults are the
    historical hardcoded values, so untuned callers are bit-identical
    to the pre-autotuner engine.
    """
    b, t = term_ids.shape
    num_docs = index.docs.num_docs
    if k_tile is None:
        k_tile = default_k_tile(k, tile)
    k_tile = min(k_tile, tile)
    # per-query norm of the idf weight vector (duplicate slots carry 0
    # after dedup) — same reduction the oracle's scoring tail performs
    qnorm = jnp.sqrt(jnp.maximum(jnp.sum(idf_w * idf_w, axis=1), 1e-12))

    if backend == "xla":
        # plain-HLO lowering: dense scores (same block dedup), then the
        # jnp mirror of the kernels' per-tile reduction
        scores, overflow = fused_batched_scores(
            index, term_ids, idf_w, cap, max_pairs=max_pairs, tile=tile,
            backend="xla")
        final = final_scores(scores, index.docs.norm, index.docs.rank,
                             qnorm, rank_blend)
        vals, ids = extract_tile_candidates(final, tile, k_tile)
        return vals, ids, overflow

    block = index.block
    m = max(-(-min(cap, max(index.max_posting_len, 1)) // block), 1)
    if isinstance(index, BlockedIndex):
        m = min(m, max(index.max_blocks_per_term, 1))
    if max_pairs is None:
        # callers passing an explicit budget own its pps widening; the
        # derived default must widen here or pps > 1 overflows it
        max_pairs = widen_pairs_for_step(
            default_max_pairs(index, b, t, cap, tile), num_docs, tile,
            pairs_per_step)
    max_pairs = round_up_pairs(max_pairs, pairs_per_step)

    cand_block, cand_valid, cand_q, cand_w, cand_cap = \
        expand_block_candidates(index.block_offsets, term_ids, idf_w,
                                m, block, cap)
    tfirst, tcount, n_tiles = routing_spans(index, tile)
    pb, pt, pqw, pcap, overflow = build_batched_pairs(
        cand_block, cand_valid, cand_q,
        cand_w.astype(jnp.float32), tfirst, tcount, n_tiles, b, max_pairs,
        cand_cap=cand_cap, pairs_per_step=pairs_per_step)

    # pad the query batch to the accumulator quantum (padding queries
    # get qnorm 1.0 — their zero accumulator masks them to -inf anyway)
    bp = -(-b // max(q_pad, 1)) * max(q_pad, 1)
    qnorm_p = qnorm
    if bp != b:
        pqw = jnp.pad(pqw, ((0, 0), (0, bp - b)))
        qnorm_p = jnp.pad(qnorm, (0, bp - b), constant_values=1.0)

    if isinstance(index, PackedCsrIndex):
        vals, ids = fused_topk_packed_pallas(
            index.packed, index.block_tfs, pb, pt, pqw, pcap,
            index.block_bits[pb], index.block_base[pb],
            index.block_count[pb], index.docs.norm, index.docs.rank,
            qnorm_p, num_docs, block, k_tile, rank_blend=rank_blend,
            tile=tile, reducer=reducer, pairs_per_step=pairs_per_step,
            interpret=_interp(backend))
    else:
        vals, ids = fused_topk_blocked_pallas(
            index.block_docs, index.block_tfs, pb, pt, pqw, pcap,
            index.docs.norm, index.docs.rank, qnorm_p, num_docs, k_tile,
            rank_blend=rank_blend, tile=tile, reducer=reducer,
            pairs_per_step=pairs_per_step, interpret=_interp(backend))
    return vals[:b], ids[:b], overflow


# ---------------------------------------------------------------------------
# per-segment engines for the segmented live index (core/live_index.py)
# ---------------------------------------------------------------------------
#
# One sealed segment == one BlockedIndex padded to a static size class
# (layouts.pad_blocked_to_class).  These module-level jitted entry points
# take the segment as a pytree ARGUMENT (not a captured constant), so a
# freshly sealed segment of an already-warm class reuses the compiled
# executable — the live index's recompile-avoidance contract.  Each
# returns per-tile candidate lists of FINAL scores with GLOBAL doc ids
# (segment-local ids shifted by the traced ``doc_base`` scalar), merged
# host-side by ``distributed.topk.merge_topk_candidates_host``.
#
# ``idf_w`` carries GLOBAL idf weights (live df over live docs, computed
# by the live index) — a segment never scores with its local df, so the
# multi-segment ranking matches a from-scratch rebuild exactly.  Slots
# whose term is absent from THIS segment still contribute to the query
# norm (it is a property of the query, not the segment) but gate no
# posting blocks.


@functools.partial(jax.jit, static_argnames=(
    "k_tile", "cap", "max_pairs", "rank_blend", "tile", "backend",
    "q_pad", "reducer", "pairs_per_step"))
def fused_segment_topk(index: BlockedIndex | PackedCsrIndex,
                       query_hashes: Array,
                       idf_w: Array, doc_base: Array, *, k_tile: int,
                       cap: int, max_pairs: int, rank_blend: float = 0.0,
                       tile: int = TILE, backend: Backend = "pallas",
                       q_pad: int = Q_PAD, reducer: str = "successive",
                       pairs_per_step: int = 1):
    """Candidate engine over one segment: fused decode-and-score kernel
    with in-kernel per-tile top-k (tombstones ride in as norm == 0).

    Accepts either sealed-segment layout — HOR blocks (``seal_layout=
    "hor"``) or delta+bit-packed blocks (``"packed"``); the pytree
    STRUCTURE is part of the jit key, so compilations key on
    ``(size_class, layout)``: the two layouts compile separately but
    segments of one layout still share warm size-class entries.  The
    sharded serving tier applies the same keying to whole stacks
    (``distributed.retrieval.stack_segment_shards`` groups segments on
    ``(size_class, layout)`` and memoizes the compiled stack scorer)."""
    present = query_hashes != 0
    tids = jnp.where(present, index.lookup_terms(query_hashes), -1)
    vals, ids, overflow = fused_batched_topk(
        index, tids, idf_w, cap, k=k_tile, rank_blend=rank_blend,
        max_pairs=max_pairs, tile=tile, k_tile=k_tile, backend=backend,
        q_pad=q_pad, reducer=reducer, pairs_per_step=pairs_per_step)
    gids = jnp.where(ids >= 0, ids + doc_base, -1)
    return vals, gids, overflow


@functools.partial(jax.jit, static_argnames=(
    "k_tile", "cap", "max_pairs", "rank_blend", "tile", "backend",
    "q_pad"))
def fused_segment_dense_topk(index: BlockedIndex | PackedCsrIndex,
                             query_hashes: Array,
                             idf_w: Array, doc_base: Array, *, k_tile: int,
                             cap: int, max_pairs: int,
                             rank_blend: float = 0.0, tile: int = TILE,
                             backend: Backend = "pallas",
                             q_pad: int = Q_PAD):
    """Dense engine over one segment (PR-1 tail): full local score rows,
    then the jnp mirror of the per-tile candidate reduction."""
    present = query_hashes != 0
    tids = jnp.where(present, index.lookup_terms(query_hashes), -1)
    scores, overflow = fused_batched_scores(
        index, tids, idf_w, cap, max_pairs=max_pairs, tile=tile,
        backend=backend, q_pad=q_pad)
    qnorm = jnp.sqrt(jnp.maximum(jnp.sum(idf_w * idf_w, axis=1), 1e-12))
    final = final_scores(scores, index.docs.norm, index.docs.rank, qnorm,
                         rank_blend)
    vals, ids = extract_tile_candidates(final, tile, k_tile)
    gids = jnp.where(ids >= 0, ids + doc_base, -1)
    return vals, gids, overflow


def banded_pairs_budgets(index: BandedCsrIndex, tile: int = TILE,
                         pairs_per_step: int = 1) -> tuple[int, int]:
    """Per-band static pair budgets for a banded segment: each band is
    its own fused-kernel launch with its own routing-pair buffer.  A
    band can be EMPTY (every term landed on the other side of the cut);
    an unpadded empty band carries ``route_pairs_max == 0``, which would
    size a zero-length pair buffer — clamp to the same floor the
    whole-index budgets use (padded sealed bands never hit this: the
    size-class pad lifts ``route_pairs_max`` to >= one class)."""
    return (max(padded_pairs_budget(index.packed, tile, pairs_per_step), 8),
            max(padded_pairs_budget(index.hor, tile, pairs_per_step), 8))


@functools.partial(jax.jit, static_argnames=(
    "k_tile", "cap_packed", "cap_hor", "max_pairs_packed", "max_pairs_hor",
    "rank_blend", "tile", "backend", "q_pad"))
def fused_segment_banded_topk(index: BandedCsrIndex, query_hashes: Array,
                              idf_w: Array, doc_base: Array, *, k_tile: int,
                              cap_packed: int, cap_hor: int,
                              max_pairs_packed: int, max_pairs_hor: int,
                              rank_blend: float = 0.0, tile: int = TILE,
                              backend: Backend = "pallas",
                              q_pad: int = Q_PAD):
    """Engine over one BANDED segment: one fused dense-score launch per
    band (packed band with its band-local stride, HOR tail), band
    partials summed, then the shared scoring tail + per-tile candidate
    reduction.

    One term lookup serves both bands (they share the sorted_hash
    buffer; the band a term does NOT live in holds an empty block range
    for it, so it gates no pairs there).  Scores are additive over
    terms, so ``acc_packed + acc_hor`` is the whole-segment accumulator
    — and because every term contributes through exactly one band, a
    doc's partial in the other band is exactly 0.0, keeping the sum
    bit-identical to a single-layout engine whenever each doc's terms
    are band-pure (the engineered parity tests pin this; mixed docs get
    the same float regrouping tolerance as the term-sharded psum).

    The pytree structure keys compilation on the PAIR of band size
    classes, so warm-class rebuilds reuse the executable — the same
    memoization contract as ``fused_segment_topk``."""
    present = query_hashes != 0
    tids = jnp.where(present, index.packed.lookup_terms(query_hashes), -1)
    acc_p, ov_p = fused_batched_scores(
        index.packed, tids, idf_w, cap_packed, max_pairs=max_pairs_packed,
        tile=tile, backend=backend, q_pad=q_pad)
    acc_h, ov_h = fused_batched_scores(
        index.hor, tids, idf_w, cap_hor, max_pairs=max_pairs_hor,
        tile=tile, backend=backend, q_pad=q_pad)
    scores = acc_p + acc_h
    qnorm = jnp.sqrt(jnp.maximum(jnp.sum(idf_w * idf_w, axis=1), 1e-12))
    final = final_scores(scores, index.docs.norm, index.docs.rank, qnorm,
                         rank_blend)
    vals, ids = extract_tile_candidates(final, tile, k_tile)
    gids = jnp.where(ids >= 0, ids + doc_base, -1)
    return vals, gids, ov_p + ov_h


@functools.partial(jax.jit, static_argnames=(
    "k_tile", "cap", "rank_blend", "tile"))
def jnp_segment_topk(index, query_hashes: Array, idf_w: Array,
                     doc_base: Array, *, k_tile: int, cap: int,
                     rank_blend: float = 0.0, tile: int = TILE):
    """Pure-jnp oracle engine over one segment (gather + scatter-add),
    reduced to the same per-tile candidate lists as the fused kernels."""
    from repro.core.query import accumulate_scores
    num_docs = index.docs.num_docs

    def one(qh, w):
        present = qh != 0
        tids = jnp.where(present, index.lookup_terms(qh), -1)
        d, tf, valid = index.gather_postings(tids, cap)
        return accumulate_scores(d, tf * w[:, None], valid, num_docs)

    scores = jax.vmap(one)(query_hashes, idf_w)
    qnorm = jnp.sqrt(jnp.maximum(jnp.sum(idf_w * idf_w, axis=1), 1e-12))
    final = final_scores(scores, index.docs.norm, index.docs.rank, qnorm,
                         rank_blend)
    vals, ids = extract_tile_candidates(final, tile, k_tile)
    gids = jnp.where(ids >= 0, ids + doc_base, -1)
    return vals, gids, jnp.int32(0)


@functools.partial(jax.jit, static_argnames=("k_tile", "cap", "tile"))
def jnp_segment_conjunctive(index, query_hashes: Array, idf_w: Array,
                            needed: Array, doc_base: Array, *, k_tile: int,
                            cap: int, tile: int = TILE):
    """AND-semantics membership counts + scores over one segment for a
    SINGLE query; a doc lives in exactly one segment, so its local count
    is its global count.  Returns (vals, gids, truncated_terms) where
    ``truncated_terms`` counts terms whose LOCAL posting list exceeds
    ``cap`` — the live index SUMS this across segments (the stats-
    plumbing fix: truncation in any segment is surfaced, not just the
    last one scored)."""
    from repro.core.query import accumulate_counts, accumulate_scores
    num_docs = index.docs.num_docs
    present = query_hashes != 0
    tids = jnp.where(present, index.lookup_terms(query_hashes), -1)
    df_local = index.term_df(tids)
    d, tf, valid = index.gather_postings(tids, cap)
    scores = accumulate_scores(d, tf * idf_w[:, None], valid, num_docs)
    counts = accumulate_counts(d, valid, num_docs)
    truncated = jnp.sum(((df_local > cap) & (tids >= 0)).astype(jnp.int32))
    ok = counts >= needed
    final = jnp.where(ok & (index.docs.norm > 0),
                      scores / jnp.maximum(index.docs.norm, 1e-12),
                      -jnp.inf)
    vals, ids = extract_tile_candidates(final[None], tile, k_tile)
    gids = jnp.where(ids[0] >= 0, ids[0] + doc_base, -1)
    return vals[0], gids, truncated


def segment_scorer_cache_sizes() -> dict:
    """jit-cache sizes of the per-segment engines — the live index's
    churn test asserts these stop growing once every size class is warm
    (new compilations would mean the size-class contract broke)."""
    return {
        "fused_segment_topk": fused_segment_topk._cache_size(),
        "fused_segment_dense_topk": fused_segment_dense_topk._cache_size(),
        "fused_segment_banded_topk":
            fused_segment_banded_topk._cache_size(),
        "jnp_segment_topk": jnp_segment_topk._cache_size(),
        "jnp_segment_conjunctive": jnp_segment_conjunctive._cache_size(),
    }


# ---------------------------------------------------------------------------
# packed-posting decode
# ---------------------------------------------------------------------------


def unpack_postings(index: PackedCsrIndex,
                    backend: Backend = "pallas") -> Array:
    """Decode ALL blocks of a PackedCsrIndex -> doc ids i32[NB, block]."""
    if backend == "xla":
        return ref.ref_unpack_blocks(index.packed, index.block_bits,
                                     index.block_base, index.block_count,
                                     index.block)
    return unpack_blocks_pallas(index.packed, index.block_bits,
                                index.block_base, index.block_count,
                                index.block, interpret=_interp(backend))


# ---------------------------------------------------------------------------
# embedding bag / PNA aggregation / attention
# ---------------------------------------------------------------------------


def embedding_bag(table: Array, indices: Array, tile_b: int = 256,
                  backend: Backend = "xla") -> Array:
    if backend == "xla":
        return ref.ref_embedding_bag(table, indices)
    return embedding_bag_pallas(table, indices, tile_b=tile_b,
                                interpret=_interp(backend))


def pna_multi_agg(feats: Array, nbr: Array, tile_n: int = 128,
                  backend: Backend = "xla") -> Array:
    if backend == "xla":
        return ref.ref_pna_multi_agg(feats, nbr)
    return pna_multi_agg_pallas(feats, nbr, tile_n=tile_n,
                                interpret=_interp(backend))


def attention(q: Array, k: Array, v: Array, causal: bool = True,
              window: int = 0, backend: Backend = "xla",
              block_q: int = 128, block_k: int = 128) -> Array:
    if backend == "xla":
        return ref.ref_attention(q, k, v, causal=causal, window=window)
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  block_q=block_q, block_k=block_k,
                                  interpret=_interp(backend))
