"""Pallas TPU kernel: fused PNA multi-aggregator (mean|min|max|std).

PNA aggregates each node's neighbor features four ways.  The XLA path
runs four segment reductions — four HBM passes over the gathered
neighbor features.  This kernel reads each neighbor row ONCE and updates
all four accumulators in VMEM, emitting the concatenated [mean|min|max|
std] block.  Input is the padded-degree (bucketed) form nbr[N, K] that
the sampled-training path produces anyway.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.runtime import resolve_interpret

Array = jax.Array


def _pna_kernel(nbr_ref, feat_ref, out_ref, *, k: int, tile_n: int,
                d: int, eps: float):
    i = pl.program_id(0)

    def node_body(r, _):
        def nb_body(h, carry):
            s, ssq, mn, mx, cnt = carry
            j = nbr_ref[i * tile_n + r, h]
            safe = jnp.maximum(j, 0)
            row = feat_ref[pl.ds(safe, 1), :]
            ok = j >= 0
            okf = jnp.where(ok, 1.0, 0.0)
            s = s + okf * row
            ssq = ssq + okf * row * row
            mn = jnp.where(ok, jnp.minimum(mn, row), mn)
            mx = jnp.where(ok, jnp.maximum(mx, row), mx)
            return (s, ssq, mn, mx, cnt + okf)

        init = (jnp.zeros((1, d), jnp.float32), jnp.zeros((1, d), jnp.float32),
                jnp.full((1, d), jnp.inf, jnp.float32),
                jnp.full((1, d), -jnp.inf, jnp.float32),
                jnp.zeros((), jnp.float32))
        s, ssq, mn, mx, cnt = jax.lax.fori_loop(0, k, nb_body, init)
        n = jnp.maximum(cnt, 1.0)
        mean = s / n
        var = jnp.maximum(ssq / n - mean * mean, 0.0)
        std = jnp.sqrt(var + eps)
        mn = jnp.where(jnp.isfinite(mn), mn, 0.0)
        mx = jnp.where(jnp.isfinite(mx), mx, 0.0)
        out_ref[pl.ds(r, 1), :] = jnp.concatenate([mean, mn, mx, std], axis=1)
        return 0

    jax.lax.fori_loop(0, tile_n, node_body, 0)


def pna_multi_agg_pallas(feats: Array, nbr: Array, tile_n: int = 128,
                         eps: float = 1e-5,
                         interpret: bool | None = None) -> Array:
    """feats f32[Nsrc, D], nbr i32[N, K] (-1 pad) -> f32[N, 4D]."""
    nsrc, d = feats.shape
    n, k = nbr.shape
    tile_n = min(tile_n, n)
    assert n % tile_n == 0, (n, tile_n)
    kernel = functools.partial(_pna_kernel, k=k, tile_n=tile_n, d=d, eps=eps)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n // tile_n,),
            in_specs=[pl.BlockSpec((nsrc, d), lambda i, nbr: (0, 0))],
            out_specs=pl.BlockSpec((tile_n, 4 * d), lambda i, nbr: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((n, 4 * d), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(nbr, feats)
