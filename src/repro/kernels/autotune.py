"""Kernel geometry autotuner for the fused decode-and-score engine.

The fused kernels historically baked in one geometry — ``TILE = 512``
doc-tile width, ``Q_PAD = 8`` query quantum, ``K_PAD = 8`` candidate
quantum, one routing pair per grid step, successive-maxima tile
reduction.  Those constants are good defaults for a TPU MXU but have no
reason to be optimal for every (backend, index size, layout) triple —
interpret-mode CPU runs in particular pay per-grid-step Python
overhead, so fewer/wider steps win there, and the bitonic tile reducer
beats ``k_tile`` successive-maxima passes once ``k_tile`` outgrows the
fixed ``log2(tile)*(log2(tile)+1)/2`` stage count of a full sort.

This module makes the geometry a measured quantity:

  * ``TuneConfig`` — one frozen geometry choice.  ``DEFAULT_CONFIG`` is
    exactly the historical constants, so an EMPTY tuning table is
    bit-identical to the pre-autotuner engine (the layout-parity fuzz
    suite runs untouched).
  * ``TuningTable`` — winning config per ``(backend, size_class,
    layout)``, JSON-serializable (schema-versioned) for on-disk reuse;
    a module-level ACTIVE table is what ``make_scorer``, the segment
    engines and the sharded scorers consult.  Size classes use
    ``core.size_model.tuning_size_class`` — the same quantization the
    seal path applies to segment doc counts, so seal/compaction emit
    segments that land exactly on a tuned class.
  * ``autotune_index`` — sweeps candidate configs over a real index +
    query batch, stores the min-median winner.

Env override ``REPRO_REDUCER=bitonic`` (or ``successive``) forces the
tile reducer regardless of table state — used by CI to run the whole
layout-parity fuzz suite under the bitonic reducer without editing
tests.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
import warnings
from typing import Iterable

TUNE_SCHEMA = "repro-tune/1"

_TILE_DEFAULT = 512
_Q_PAD_DEFAULT = 8
_K_PAD_DEFAULT = 8


@dataclasses.dataclass(frozen=True)
class TuneConfig:
    """One kernel-geometry choice for the fused candidate engine.

    ``k_tile`` is an optional OVERRIDE of the per-query candidate count;
    ``None`` derives it from (k, tile, k_pad) at call time.  Either way
    ``resolve_k_tile`` clamps to the exactness floor ``min(k, tile)`` so
    a tuned config can widen but never break the merge contract.
    """
    tile: int = _TILE_DEFAULT
    q_pad: int = _Q_PAD_DEFAULT
    k_pad: int = _K_PAD_DEFAULT
    k_tile: int | None = None
    reducer: str = "successive"
    pairs_per_step: int = 1

    def resolve_k_tile(self, k: int) -> int:
        from repro.kernels.fused_decode_score import default_k_tile
        floor = default_k_tile(k, self.tile, self.k_pad)
        if self.k_tile is None:
            return floor
        return min(max(int(self.k_tile), floor), self.tile)

    def resolved(self) -> "TuneConfig":
        """Apply env overrides (REPRO_REDUCER) on top of this config."""
        forced = os.environ.get("REPRO_REDUCER", "")
        if forced and forced != self.reducer:
            from repro.kernels.fused_decode_score import REDUCERS
            if forced not in REDUCERS:
                raise ValueError(f"REPRO_REDUCER={forced!r} not in "
                                 f"{REDUCERS}")
            return dataclasses.replace(self, reducer=forced)
        return self

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "TuneConfig":
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in fields})


DEFAULT_CONFIG = TuneConfig()


def size_class_of(num_docs: int) -> int:
    from repro.core.size_model import tuning_size_class
    return tuning_size_class(num_docs)


def layout_of(index) -> str:
    """'hor' for BlockedIndex, 'packed' for PackedCsrIndex, 'banded' for
    BandedCsrIndex — the same layout tags the segmented live index
    uses."""
    from repro.core.layouts import BandedCsrIndex, PackedCsrIndex
    if isinstance(index, BandedCsrIndex):
        return "banded"
    return "packed" if isinstance(index, PackedCsrIndex) else "hor"


def _compiled_lowering(backend: str) -> bool:
    """True when ``backend`` lowers through the compiled (non-interpret)
    Pallas path, where the bitonic tile reducer is not implemented."""
    if backend == "pallas-tpu":
        return True
    if backend == "pallas":
        import jax
        return jax.default_backend() == "tpu"
    return False


_BITONIC_WARNED = False


def downgrade_reducer(cfg: TuneConfig, backend: str) -> TuneConfig:
    """Resolve a ``reducer="bitonic"`` table entry to ``successive`` on
    compiled lowerings, where the kernel would otherwise reject it at
    entry (fused_decode_score raises NotImplementedError).  Warns once
    per process and bumps the ``autotune_bitonic_downgrade`` counter so
    poisoned tables are visible, not fatal."""
    global _BITONIC_WARNED
    if cfg.reducer != "bitonic" or not _compiled_lowering(backend):
        return cfg
    from repro.obs.registry import GLOBAL
    GLOBAL.counter("autotune_bitonic_downgrade").inc()
    if not _BITONIC_WARNED:
        _BITONIC_WARNED = True
        warnings.warn(
            "tuning table requested reducer='bitonic' on a compiled "
            f"lowering (backend={backend!r}); downgrading to "
            "'successive' — re-tune the table on this backend",
            RuntimeWarning, stacklevel=3)
    return dataclasses.replace(cfg, reducer="successive")


class TuningTable:
    """Winning ``TuneConfig`` per ``(backend, size_class, layout)``."""

    def __init__(self) -> None:
        self._entries: dict[tuple[str, int, str], TuneConfig] = {}
        self._costs: dict[tuple[str, int, str], float] = {}

    def __len__(self) -> int:
        return len(self._entries)

    def put(self, backend: str, size_class: int, layout: str,
            cfg: TuneConfig, cost_s: float | None = None) -> None:
        key = (str(backend), int(size_class), str(layout))
        self._entries[key] = cfg
        if cost_s is not None:
            self._costs[key] = float(cost_s)

    def get(self, backend: str, size_class: int,
            layout: str) -> TuneConfig | None:
        return self._entries.get((str(backend), int(size_class),
                                  str(layout)))

    def cost(self, backend: str, size_class: int,
             layout: str) -> float | None:
        """Measured median seconds of the winning config at EXACTLY this
        (backend, size_class, layout), or None if the sweep never timed
        it.  No nearest-class fallback: the layout cost model must only
        compare costs measured at the same class."""
        return self._costs.get((str(backend), int(size_class),
                                str(layout)))

    def lookup(self, backend: str, num_docs: int, layout: str) -> TuneConfig:
        """Config for an index of ``num_docs`` docs; falls back to the
        nearest SMALLER tuned class of the same (backend, layout), then
        to ``DEFAULT_CONFIG`` — a partially swept table still covers
        every query."""
        cls_ = size_class_of(num_docs)
        hit = self.get(backend, cls_, layout)
        if hit is not None:
            return downgrade_reducer(hit, backend)
        below = [(c, cfg) for (b, c, l), cfg in self._entries.items()
                 if b == backend and l == layout and c < cls_]
        if below:
            return downgrade_reducer(max(below, key=lambda e: e[0])[1],
                                     backend)
        return DEFAULT_CONFIG

    def to_dict(self) -> dict:
        entries = []
        for (b, c, l), cfg in sorted(self._entries.items()):
            e = {"backend": b, "size_class": c, "layout": l,
                 "config": cfg.to_dict()}
            cost = self._costs.get((b, c, l))
            if cost is not None:
                e["median_s"] = cost
            entries.append(e)
        return {"schema": TUNE_SCHEMA, "entries": entries}

    @classmethod
    def from_dict(cls, d: dict) -> "TuningTable":
        if d.get("schema") != TUNE_SCHEMA:
            raise ValueError(f"unknown tuning-table schema "
                             f"{d.get('schema')!r} (want {TUNE_SCHEMA})")
        t = cls()
        for e in d.get("entries", []):
            t.put(e["backend"], e["size_class"], e["layout"],
                  TuneConfig.from_dict(e["config"]),
                  cost_s=e.get("median_s"))
        return t

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=2, sort_keys=True)
            f.write("\n")

    @classmethod
    def load(cls, path: str) -> "TuningTable":
        with open(path) as f:
            return cls.from_dict(json.load(f))


# The table every wiring point (make_scorer, LiveView.topk, the sharded
# scorers, seal-time route_tile selection) consults.  Starts EMPTY:
# every lookup resolves to DEFAULT_CONFIG and the engine is bit-
# identical to the pre-autotuner code.
_ACTIVE = TuningTable()


def get_active() -> TuningTable:
    return _ACTIVE


def set_active(table: TuningTable | None) -> TuningTable:
    """Install ``table`` (None -> fresh empty table) as the active
    tuning table; returns the previous one so tests can restore it."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = table if table is not None else TuningTable()
    return prev


def lookup(backend: str, num_docs: int, layout: str) -> TuneConfig:
    """Active-table resolution + env overrides — THE query-time entry
    point; every engine call site funnels through here."""
    return _ACTIVE.lookup(str(backend), num_docs, str(layout)).resolved()


# ---------------------------------------------------------------------------
# the sweep
# ---------------------------------------------------------------------------


def candidate_configs(k: int, tile_default: int = _TILE_DEFAULT,
                      tiles: Iterable[int] = (256, 512, 1024),
                      reducers: Iterable[str] = ("successive", "bitonic"),
                      pairs: Iterable[int] = (1, 2),
                      include_wide_k: bool = True) -> list[TuneConfig]:
    """The pruned sweep grid: geometry axes that can plausibly matter,
    not the full cross product.  Reducer and pairs-per-step only vary at
    the default tile (they are independent of tile width to first
    order); tile varies with everything else at defaults; ``k_tile``
    widening is tried once (2x the floor) at the default tile."""
    from repro.kernels.fused_decode_score import default_k_tile
    out: list[TuneConfig] = [TuneConfig()]
    for t in tiles:
        if t != tile_default:
            out.append(TuneConfig(tile=t))
    for r in reducers:
        if r != "successive":
            out.append(TuneConfig(reducer=r))
    for p in pairs:
        if p != 1:
            out.append(TuneConfig(pairs_per_step=p))
    if include_wide_k:
        floor = default_k_tile(k, tile_default, _K_PAD_DEFAULT)
        wide = min(2 * floor, tile_default)
        if wide > floor:
            out.append(TuneConfig(k_tile=wide))
            out.append(TuneConfig(k_tile=wide, reducer="bitonic"))
    # combine the two grid-step amortizations (wider tile, multi-pair)
    big = max(tiles)
    if big != tile_default:
        out.append(TuneConfig(tile=big, pairs_per_step=max(pairs)))
    return out


def _median(xs: list[float]) -> float:
    s = sorted(xs)
    n = len(s)
    return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])


def time_config(index, query_hashes, idf_w, k: int, cap: int,
                cfg: TuneConfig, backend: str = "pallas", reps: int = 3,
                warmup: int = 1, rank_blend: float = 0.0) -> float:
    """Median wall-clock seconds of one fused candidate-engine call
    under ``cfg`` (jit-compiled; warmup excluded)."""
    import jax

    from repro.kernels import ops

    k_tile = cfg.resolve_k_tile(k)
    # same widened budget as the query paths — a pps > 1 candidate must
    # be timed doing the FULL pair set, not a silently truncated one
    max_pairs = ops.padded_pairs_budget(index, cfg.tile,
                                        cfg.pairs_per_step)

    def run():
        vals, ids, _ = ops.fused_segment_topk(
            index, query_hashes, idf_w, jax.numpy.int32(0), k_tile=k_tile,
            cap=cap, max_pairs=max_pairs, rank_blend=rank_blend,
            tile=cfg.tile, backend=backend, q_pad=cfg.q_pad,
            reducer=cfg.reducer, pairs_per_step=cfg.pairs_per_step)
        jax.block_until_ready((vals, ids))

    for _ in range(max(warmup, 1)):
        run()
    samples = []
    for _ in range(max(reps, 1)):
        t0 = time.perf_counter()
        run()
        samples.append(time.perf_counter() - t0)
    return _median(samples)


def autotune_index(index, query_hashes, idf_w, k: int, cap: int | None = None,
                   backend: str = "pallas",
                   configs: Iterable[TuneConfig] | None = None,
                   reps: int = 3, warmup: int = 1,
                   table: TuningTable | None = None):
    """Sweep candidate configs on a real (index, query batch) workload.

    Returns ``(best_config, records)`` where records is one dict per
    config (config, median seconds, candidate bytes/query) — the raw
    material of the BENCH_autotune artifact.  If ``table`` is given the
    winner is stored under this index's (backend, size_class, layout)
    key.  Ties inside 2% break toward the smaller candidate output
    (size-model hook), then toward the default config.
    """
    from repro.core.size_model import candidate_bytes_per_query

    if cap is None:
        cap = max(int(index.max_posting_len), 1)
    if configs is None:
        configs = candidate_configs(k)
    num_docs = int(index.docs.num_docs)
    records = []
    for cfg in configs:
        sec = time_config(index, query_hashes, idf_w, k, cap, cfg,
                          backend=backend, reps=reps, warmup=warmup)
        records.append({
            "config": cfg.to_dict(),
            "median_s": sec,
            "candidate_bytes_per_query": candidate_bytes_per_query(
                num_docs, cfg.tile, cfg.resolve_k_tile(k)),
            "is_default": cfg == DEFAULT_CONFIG,
        })
    fastest = min(r["median_s"] for r in records)

    def rank(r):
        return (r["median_s"] > fastest * 1.02,
                r["candidate_bytes_per_query"],
                not r["is_default"], r["median_s"])

    best_rec = min(records, key=rank)
    best = TuneConfig.from_dict(best_rec["config"])
    if table is not None:
        # the winner's measured median feeds the layout cost model's
        # decode-cost term (size_model.LayoutCostModel.measured_cost_s)
        table.put(backend, size_class_of(num_docs), layout_of(index), best,
                  cost_s=best_rec["median_s"])
    return best, records
