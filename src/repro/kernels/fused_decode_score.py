"""Pallas TPU kernel: fused batched decode-and-score — one HBM pass from
(possibly bit-packed) posting blocks to dense per-query scores, or (the
candidate path) straight to per-tile top-k candidates.

The paper's §4.3 claim is that query cost is dominated by posting-list
I/O, so the compressed layout must NOT be decompressed through HBM
before scoring.  This kernel closes that gap: its grid walks
scalar-prefetched routing pairs ``(block, tile)`` and, per step,

  1. DMAs ONE posting block into VMEM — either raw int32 doc ids
     (HOR/BlockedIndex) or delta+bit-packed u32 words (PackedCsrIndex);
  2. for packed blocks, unpacks IN VMEM (per-lane variable shifts +
     intra-block prefix sum — the ``packed_postings`` kernel body folded
     into the scorer), so compressed bytes are the only posting bytes
     that ever cross HBM;
  3. one-hot-matmuls the block's tfs against a ``tile``-wide doc tile on
     the MXU and rank-1 updates a ``[Q, tile]`` accumulator with the
     per-query term weights — a hot block is read ONCE and serves every
     query in the batch that touches it.

Routing pairs are deduplicated across the query batch (two queries
sharing a term share the block read) and sorted by tile so each output
tile stays resident in VMEM for one contiguous run of grid steps
(revisit-accumulation, as in ``posting_score``).  The block -> tile span
table is a build-time cache on the index (``tile_first``/``tile_count``),
not a per-query computation.

CANDIDATE EXTRACTION (the ``fused_topk_*`` variants): the dense engine
still wrote a ``[Q, num_docs]`` score array to HBM before ``top_k`` —
at corpus scale that write dwarfs the compressed posting bytes the read
path saved.  The candidate kernels keep the ``[Q, tile]`` accumulator in
VMEM SCRATCH instead of an output block; on a tile's LAST grid step
(tile-sorted pairs make the run contiguous, so "last" is a prefetched
flag) the accumulator is reduced IN VMEM to a per-tile candidate set:

  * the doc-metadata tail (norm division, deleted-doc mask, static-rank
    blend — bit-identical op sequence to the jnp oracle's scoring tail)
    is applied to the resident tile, and
  * ``k_tile`` successive maxima are extracted (lowest-lane tie-break,
    matching ``jax.lax.top_k``) as (value, global doc id) pairs.

Only ``O(Q * n_tiles * k_tile)`` candidates ever reach HBM; a pure
``merge_topk_candidates`` (distributed/topk.py) over the tile-major
candidate lists reproduces the dense oracle's ranked ids bit-exactly
because per-tile lists are value-sorted with ascending-id ties and tiles
are concatenated in ascending doc order.  ``k_tile >= min(k, tile)``
guarantees no global top-k entry is lost.

HBM bytes per batch ~ sum over unique (block, tile) pairs of the block's
payload: ``4*ceil(128*bits/32) + 2*128`` bytes packed vs ``8*128`` bytes
unpacked, plus ``Q * n_tiles * k_tile * 8`` candidate bytes out (vs
``Q * num_docs * 4`` dense) — the roofline benchmark reports both
ratios.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.query import final_scores
from repro.kernels.runtime import resolve_interpret

Array = jax.Array

TILE = 512   # doc-space tile width (4 x 128 lanes), matches posting_score
Q_PAD = 8    # query-batch padding quantum (f32 sublane width)
K_PAD = 8    # candidate-count padding quantum (per-tile k_tile lanes)


def default_k_tile(k: int, tile: int = TILE, k_pad: int = K_PAD) -> int:
    """Per-tile candidate count: >= min(k, tile) (exactness floor),
    rounded up to the ``k_pad`` lane quantum, never wider than the tile.

    The ``min(tile, ...)`` clamp is load-bearing for autotuned tile
    widths: a narrow tile (e.g. 256) cannot emit more than ``tile``
    candidates, and every kernel entry point rejects ``k_tile > tile``
    rather than silently truncating (see ``_check_k_tile``)."""
    k_pad = max(int(k_pad), 1)
    return min(tile, max(k_pad, -(-max(k, 1) // k_pad) * k_pad))


def _check_k_tile(k_tile: int, tile: int) -> None:
    """Reject geometry the per-tile reduction cannot satisfy.  Call
    sites that assumed ``TILE = 512`` must clamp via ``default_k_tile(k,
    tile)`` (which never exceeds the tile) before reaching a kernel."""
    if k_tile > tile:
        raise ValueError(
            f"k_tile={k_tile} > tile={tile}: a {tile}-wide doc tile "
            f"cannot emit {k_tile} candidates — clamp with "
            "default_k_tile(k, tile)")
    if k_tile < 1:
        raise ValueError(f"k_tile must be >= 1, got {k_tile}")


def _tile_contribution(docs, tfs, qw, tile_base, lane_cap, tile: int):
    """Shared scoring step: one-hot matmul + rank-1 batch update.

    ``lane_cap`` truncates the block at posting granularity so the
    engine honours a per-term ``cap`` that cuts mid-block, exactly like
    the jnp oracle's gather.  Returns the [Q, tile] contribution.
    """
    block = docs.shape[0]
    lane0 = jax.lax.broadcasted_iota(jnp.int32, (block,), 0)
    local = docs - tile_base
    inb = (docs >= 0) & (local >= 0) & (local < tile) & (lane0 < lane_cap)
    w = jnp.where(inb, tfs, 0.0)
    lane = jax.lax.broadcasted_iota(jnp.int32, (docs.shape[0], tile), 1)
    onehot = (local[:, None] == lane).astype(jnp.float32)     # [B, tile]
    row = jnp.dot(w[None, :], onehot,
                  preferred_element_type=jnp.float32)         # [1, tile] MXU
    return jnp.dot(qw[:, None], row,
                   preferred_element_type=jnp.float32)        # [Q, tile]


def _unpack_block_vmem(words, bits, base, count, block: int):
    """In-VMEM decode of one delta+bit-packed block (the
    ``packed_postings`` kernel body, shared by both packed kernels)."""
    lane = jax.lax.broadcasted_iota(jnp.uint32, (block,), 0)
    bitpos = lane * bits
    wi = (bitpos >> 5).astype(jnp.int32)
    off = bitpos & jnp.uint32(31)
    lo = words[wi] >> off
    hi = jnp.where(off > 0,
                   words[jnp.minimum(wi + 1, words.shape[0] - 1)]
                   << (jnp.uint32(32) - off), jnp.uint32(0))
    raw = lo | hi
    mask = jnp.where(bits >= 32, jnp.uint32(0xFFFFFFFF),
                     (jnp.uint32(1) << bits) - jnp.uint32(1))
    deltas = (raw & mask).astype(jnp.int32)
    docs = base + jnp.cumsum(deltas)
    valid = jax.lax.broadcasted_iota(jnp.int32, (block,), 0) < count
    return jnp.where(valid, docs, -1)


def _final_from_acc(acc, norm, rank, qnorm, rank_blend: float):
    """The oracle's q_doc scoring tail, applied to one resident tile.

    Delegates to the ONE shared definition (``core.query.final_scores``)
    so candidate values stay bit-identical to the dense reference — any
    change to the tail changes both sides at once.
    """
    return final_scores(acc, norm, rank, qnorm, rank_blend)


def _tile_topk(final, base, k_tile: int, tile: int):
    """Extract k_tile successive maxima from a [Q, tile] tile in VMEM.

    Tie-break: lowest lane (== lowest doc id) first — the same order
    ``jax.lax.top_k`` produces, so the host-side merge of per-tile lists
    matches a dense top_k exactly.  Exhausted rows yield (-inf, -1).
    """
    q = final.shape[0]
    lane = jax.lax.broadcasted_iota(jnp.int32, (q, tile), 1)
    kidx = jax.lax.broadcasted_iota(jnp.int32, (q, k_tile), 1)

    def body(j, carry):
        work, vals, ids = carry
        m = jnp.max(work, axis=1)                              # [Q]
        am = jnp.min(jnp.where(work == m[:, None], lane, tile), axis=1)
        gid = jnp.where(jnp.isfinite(m), base + am, -1)
        sel = kidx == j
        vals = jnp.where(sel, m[:, None], vals)
        ids = jnp.where(sel, gid[:, None], ids)
        work = jnp.where(lane == am[:, None], -jnp.inf, work)
        return work, vals, ids

    _, vals, ids = jax.lax.fori_loop(
        0, k_tile, body,
        (final, jnp.full((q, k_tile), -jnp.inf, jnp.float32),
         jnp.full((q, k_tile), -1, jnp.int32)))
    return vals, ids


def _swap_stride(x, j: int):
    """Exchange each lane with its partner ``lane ^ j`` along the last
    axis (j a power of two dividing the width).  Implemented as a
    reshape + reversal of the pair axis — lane i decomposes as
    ``g*(2j) + h*j + r`` with ``h`` the bit ``i & j``; flipping ``h``
    is exactly the xor.  NOTE: Mosaic restricts reshapes that move the
    minor (lane) dimension; this helper keeps the minor dim intact
    (``r < j`` stays minor) except at j == 1, which only interpret mode
    handles — ``_check_reducer`` refuses the bitonic reducer on compiled
    lowerings until a roll-based j == 1 exchange replaces this stage.
    """
    q, n = x.shape
    y = x.reshape(q, n // (2 * j), 2, j)
    return y[:, :, ::-1, :].reshape(q, n)


def _tile_topk_bitonic(final, base, k_tile: int, tile: int):
    """Bitonic partial-sort tile reducer: full (value desc, lane asc)
    bitonic sort of the [Q, tile] tile, then the first ``k_tile``
    columns ARE the per-tile candidates.

    Bit-identical to ``_tile_topk``'s successive maxima by construction:
    both orders are the same strict total order (value descending,
    lowest lane wins ties — lanes are distinct, so the order is total
    and the sort is trivially stable), and the sort only PERMUTES the
    score values, never recomputes them, so candidate floats match to
    the bit.  Non-finite survivors map to id -1 exactly as in
    ``_tile_topk``.  Cost is the fixed ``log2(tile)*(log2(tile)+1)/2``
    compare-exchange stages (45 for tile=512) against ``k_tile``
    max+argmin passes — the autotuner decides per shape which wins.
    """
    if tile & (tile - 1):
        raise ValueError(f"bitonic reducer needs a power-of-two tile, "
                         f"got {tile}")
    q = final.shape[0]
    lane = jax.lax.broadcasted_iota(jnp.int32, (q, tile), 1)
    v, l = final, lane
    size = 2
    while size <= tile:
        stride = size // 2
        while stride >= 1:
            pv = _swap_stride(v, stride)
            pl_ = _swap_stride(l, stride)
            lo = (lane & stride) == 0         # low element of its pair
            desc = (lane & size) == 0         # block direction this stage
            # self precedes partner in (value desc, lane asc) order
            first = (v > pv) | ((v == pv) & (l < pl_))
            keep = jnp.where(lo == desc, first, ~first)
            v = jnp.where(keep, v, pv)
            l = jnp.where(keep, l, pl_)
            stride //= 2
        size *= 2
    vals = v[:, :k_tile]
    ids = jnp.where(jnp.isfinite(vals), base + l[:, :k_tile], -1)
    return vals, ids


REDUCERS = ("successive", "bitonic")


def _tile_reduce(final, base, k_tile: int, tile: int, reducer: str):
    """Reducer dispatch shared by the candidate kernels.  Both branches
    are pure jnp, so this same function IS the reference mirror — tests
    call it outside any kernel to compare reducers bit-for-bit."""
    if reducer == "bitonic":
        return _tile_topk_bitonic(final, base, k_tile, tile)
    if reducer == "successive":
        return _tile_topk(final, base, k_tile, tile)
    raise ValueError(f"unknown reducer {reducer!r}; expected {REDUCERS}")


# ---------------------------------------------------------------------------
# dense kernels (scores for every document; the PR-1 engine)
# ---------------------------------------------------------------------------


def _fused_blocked_kernel(pair_block, pair_tile, pair_first,
                          pair_cap,                            # SMEM prefetch
                          docs_ref, tfs_ref, qw_ref,           # VMEM inputs
                          out_ref, *, tile: int):
    i = pl.program_id(0)

    @pl.when(pair_first[i] == 1)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[0] += _tile_contribution(docs_ref[0, :], tfs_ref[0, :],
                                     qw_ref[0, :], pair_tile[i] * tile,
                                     pair_cap[i], tile)


def _fused_packed_kernel(pair_block, pair_tile, pair_first, pair_cap,
                         pair_bits, pair_base, pair_count,     # SMEM prefetch
                         words_ref, tfs_ref, qw_ref,           # VMEM inputs
                         out_ref, *, tile: int, block: int):
    i = pl.program_id(0)

    @pl.when(pair_first[i] == 1)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    docs = _unpack_block_vmem(words_ref[0, :],
                              pair_bits[i].astype(jnp.uint32),
                              pair_base[i], pair_count[i], block)
    out_ref[0] += _tile_contribution(docs, tfs_ref[0, :].astype(jnp.float32),
                                     qw_ref[0, :], pair_tile[i] * tile,
                                     pair_cap[i], tile)


def _pair_first(pair_tile: Array) -> Array:
    return jnp.concatenate(
        [jnp.ones(1, jnp.int32),
         (pair_tile[1:] != pair_tile[:-1]).astype(jnp.int32)])


def _pair_last(pair_tile: Array) -> Array:
    return jnp.concatenate(
        [(pair_tile[1:] != pair_tile[:-1]).astype(jnp.int32),
         jnp.ones(1, jnp.int32)])


def _finish(out: Array, pair_tile: Array, n_tiles: int, tile: int,
            num_docs: int) -> Array:
    """Mask never-visited (garbage) tiles, flatten to [Q, num_docs]."""
    visited = jnp.zeros((n_tiles + 1,), jnp.bool_).at[pair_tile].set(True)
    out = jnp.where(visited[:, None, None], out, 0.0)
    q = out.shape[1]
    return out[:n_tiles].transpose(1, 0, 2).reshape(q, n_tiles * tile)[
        :, :num_docs]


def fused_score_blocked_pallas(block_docs: Array, block_tfs: Array,
                               pair_block: Array, pair_tile: Array,
                               pair_qw: Array, pair_cap: Array,
                               num_docs: int, tile: int = TILE,
                               interpret: bool | None = None) -> Array:
    """HOR path: block_docs i32[NB, B], block_tfs f32[NB, B] read in place;
    pair_* [NP] tile-sorted routing, pair_qw f32[NP, Q] per-query weight
    rows (Q padded to a multiple of 8), pair_cap i32[NP] per-pair valid
    lane count (posting-granular cap).  Returns f32[Q, num_docs]."""
    nb, b = block_docs.shape
    np_pairs, q = pair_qw.shape
    n_tiles = -(-num_docs // tile)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(np_pairs,),
        in_specs=[
            pl.BlockSpec((1, b), lambda i, pb, pt, pf, pc: (pb[i], 0)),
            pl.BlockSpec((1, b), lambda i, pb, pt, pf, pc: (pb[i], 0)),
            pl.BlockSpec((1, q), lambda i, pb, pt, pf, pc: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, q, tile),
                               lambda i, pb, pt, pf, pc: (pt[i], 0, 0)),
    )
    out = pl.pallas_call(
        functools.partial(_fused_blocked_kernel, tile=tile),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_tiles + 1, q, tile), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(pair_block, pair_tile, _pair_first(pair_tile), pair_cap,
      block_docs, block_tfs, pair_qw)
    return _finish(out, pair_tile, n_tiles, tile, num_docs)


def fused_score_packed_pallas(packed: Array, block_tfs: Array,
                              pair_block: Array, pair_tile: Array,
                              pair_qw: Array, pair_cap: Array,
                              pair_bits: Array, pair_base: Array,
                              pair_count: Array,
                              num_docs: int, block: int,
                              tile: int = TILE,
                              interpret: bool | None = None) -> Array:
    """Packed path: packed u32[NB, Wpb] words + f16 tfs stay compressed in
    HBM; decode happens inside the scoring step.  Same routing contract
    as the HOR path plus per-pair (bits, base, count) decode scalars.
    The term-sharded packed engine runs this kernel per vocab shard
    (partial scores over the GLOBAL doc space, ahead of the [D] psum)."""
    nb, wpb = packed.shape
    np_pairs, q = pair_qw.shape
    n_tiles = -(-num_docs // tile)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=7,
        grid=(np_pairs,),
        in_specs=[
            pl.BlockSpec(
                (1, wpb),
                lambda i, pb, pt, pf, pc, pbt, pba, pcnt: (pb[i], 0)),
            pl.BlockSpec(
                (1, block),
                lambda i, pb, pt, pf, pc, pbt, pba, pcnt: (pb[i], 0)),
            pl.BlockSpec(
                (1, q),
                lambda i, pb, pt, pf, pc, pbt, pba, pcnt: (i, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, q, tile),
            lambda i, pb, pt, pf, pc, pbt, pba, pcnt: (pt[i], 0, 0)),
    )
    out = pl.pallas_call(
        functools.partial(_fused_packed_kernel, tile=tile, block=block),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_tiles + 1, q, tile), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(pair_block, pair_tile, _pair_first(pair_tile), pair_cap,
      pair_bits, pair_base, pair_count, packed, block_tfs, pair_qw)
    return _finish(out, pair_tile, n_tiles, tile, num_docs)


# ---------------------------------------------------------------------------
# candidate-extraction kernels (per-tile partial top-k; the dense score
# write never reaches HBM)
# ---------------------------------------------------------------------------


def _fused_blocked_topk_kernel(pair_block, pair_tile, pair_first, pair_last,
                               pair_cap,                       # SMEM prefetch
                               *refs,
                               tile: int, k_tile: int, rank_blend: float,
                               reducer: str, pps: int):
    """``pps`` (pairs-per-grid-step) sub-pairs are unrolled inside one
    grid step: ``refs`` carries ``pps`` replicated (docs, tfs, qw) VMEM
    views (one per sub-pair, each with its own ``pb[i*pps+j]`` index
    map) followed by the shared (norm, rank, qnorm) tiles, the two
    candidate outputs, and the accumulator scratch.  Run-aligned pair
    padding (``build_batched_pairs``) guarantees a tile transition only
    ever happens at a step boundary, so init stays at sub-pair 0 and
    the reduce at sub-pair pps-1."""
    i = pl.program_id(0)
    docs_refs = refs[:pps]
    tfs_refs = refs[pps:2 * pps]
    qw_refs = refs[2 * pps:3 * pps]
    (norm_ref, rank_ref, qn_ref, val_ref, idx_ref, acc_ref) = refs[3 * pps:]
    base = i * pps

    @pl.when(pair_first[base] == 1)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    for j in range(pps):
        acc_ref[...] += _tile_contribution(
            docs_refs[j][0, :], tfs_refs[j][0, :], qw_refs[j][0, :],
            pair_tile[base + j] * tile, pair_cap[base + j], tile)

    @pl.when(pair_last[base + pps - 1] == 1)
    def _reduce():
        final = _final_from_acc(acc_ref[...], norm_ref[0, :], rank_ref[0, :],
                                qn_ref[0, :], rank_blend)
        vals, ids = _tile_reduce(final, pair_tile[base] * tile, k_tile, tile,
                                 reducer)
        val_ref[0] = vals
        idx_ref[0] = ids


def _fused_packed_topk_kernel(pair_block, pair_tile, pair_first, pair_last,
                              pair_cap, pair_bits, pair_base,
                              pair_count,                      # SMEM prefetch
                              *refs,
                              tile: int, block: int, k_tile: int,
                              rank_blend: float, reducer: str, pps: int):
    i = pl.program_id(0)
    words_refs = refs[:pps]
    tfs_refs = refs[pps:2 * pps]
    qw_refs = refs[2 * pps:3 * pps]
    (norm_ref, rank_ref, qn_ref, val_ref, idx_ref, acc_ref) = refs[3 * pps:]
    base = i * pps

    @pl.when(pair_first[base] == 1)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    for j in range(pps):
        docs = _unpack_block_vmem(words_refs[j][0, :],
                                  pair_bits[base + j].astype(jnp.uint32),
                                  pair_base[base + j], pair_count[base + j],
                                  block)
        acc_ref[...] += _tile_contribution(
            docs, tfs_refs[j][0, :].astype(jnp.float32), qw_refs[j][0, :],
            pair_tile[base + j] * tile, pair_cap[base + j], tile)

    @pl.when(pair_last[base + pps - 1] == 1)
    def _reduce():
        final = _final_from_acc(acc_ref[...], norm_ref[0, :], rank_ref[0, :],
                                qn_ref[0, :], rank_blend)
        vals, ids = _tile_reduce(final, pair_tile[base] * tile, k_tile, tile,
                                 reducer)
        val_ref[0] = vals
        idx_ref[0] = ids


def _doc_tiles(norm: Array, rank: Array, n_tiles: int, tile: int):
    """Pad per-doc metadata to the tile grid (+ a zero trash tile for
    padding pairs; norm 0 there marks every lane deleted)."""
    pad = n_tiles * tile - norm.shape[0]
    z = jnp.zeros((1, tile), jnp.float32)
    nt = jnp.pad(norm.astype(jnp.float32), (0, pad)).reshape(n_tiles, tile)
    rt = jnp.pad(rank.astype(jnp.float32), (0, pad)).reshape(n_tiles, tile)
    return jnp.concatenate([nt, z]), jnp.concatenate([rt, z])


def _finish_candidates(vals: Array, ids: Array, pair_tile: Array,
                       n_tiles: int, k_tile: int):
    """Mask never-visited (garbage) tiles to (-inf, -1), flatten the
    per-tile candidate lists tile-major to [Q, n_tiles * k_tile]."""
    visited = jnp.zeros((n_tiles + 1,), jnp.bool_).at[pair_tile].set(True)
    vals = jnp.where(visited[:, None, None], vals, -jnp.inf)
    ids = jnp.where(visited[:, None, None], ids, -1)
    q = vals.shape[1]
    return (vals[:n_tiles].transpose(1, 0, 2).reshape(q, n_tiles * k_tile),
            ids[:n_tiles].transpose(1, 0, 2).reshape(q, n_tiles * k_tile))


def _check_reducer(reducer: str, interpret: bool) -> None:
    """The bitonic reducer's j == 1 exchange reshapes the minor (lane)
    dimension (see ``_swap_stride``), which Mosaic rejects — letting it
    reach a compiled TPU lowering fails at compile time at best and
    miscompiles at worst.  Until the roll-based j == 1 stage lands,
    refuse loudly at trace time instead of trusting a loaded tuning
    table or ``REPRO_REDUCER`` to know the restriction."""
    if reducer == "bitonic" and not interpret:
        raise NotImplementedError(
            "reducer='bitonic' is interpret-only: its j == 1 lane "
            "exchange moves the minor dimension, which the Mosaic TPU "
            "compiler rejects; use reducer='successive' for compiled "
            "runs (or force it with REPRO_REDUCER=successive)")


def _check_pairs_per_step(np_pairs: int, pps: int) -> None:
    if pps < 1:
        raise ValueError(f"pairs_per_step must be >= 1, got {pps}")
    if pps > 1 and np_pairs % pps:
        raise ValueError(
            f"np_pairs={np_pairs} not a multiple of pairs_per_step={pps}; "
            "build pairs with build_batched_pairs(..., pairs_per_step=pps)")


def fused_topk_blocked_pallas(block_docs: Array, block_tfs: Array,
                              pair_block: Array, pair_tile: Array,
                              pair_qw: Array, pair_cap: Array,
                              norm: Array, rank: Array, qnorm: Array,
                              num_docs: int, k_tile: int,
                              rank_blend: float = 0.0, tile: int = TILE,
                              reducer: str = "successive",
                              pairs_per_step: int = 1,
                              interpret: bool | None = None):
    """HOR candidate path: same routing contract as the dense kernel,
    plus per-doc metadata (norm f32[num_docs], rank f32[num_docs]) and
    per-query norms (qnorm f32[Q], padding queries should carry 1.0).
    Returns (values f32[Q, n_tiles*k_tile], ids i32[Q, n_tiles*k_tile])
    tile-major candidate lists of FINAL scores — the dense [Q, num_docs]
    array never leaves VMEM.

    ``pairs_per_step > 1`` amortizes grid-step overhead by processing
    that many routing pairs per step; callers must build the pair
    arrays with matching run-aligned padding
    (``build_batched_pairs(..., pairs_per_step=...)``)."""
    nb, b = block_docs.shape
    np_pairs, q = pair_qw.shape
    pps = pairs_per_step
    interp = resolve_interpret(interpret)
    _check_k_tile(k_tile, tile)
    _check_pairs_per_step(np_pairs, pps)
    _check_reducer(reducer, interp)
    n_tiles = max(-(-num_docs // tile), 1)
    norm_t, rank_t = _doc_tiles(norm, rank, n_tiles, tile)

    def _block_spec(j):
        return pl.BlockSpec(
            (1, b), lambda i, pb, pt, pf, pg, pc, j=j: (pb[i * pps + j], 0))

    def _qw_spec(j):
        return pl.BlockSpec(
            (1, q), lambda i, pb, pt, pf, pg, pc, j=j: (i * pps + j, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,
        grid=(np_pairs // pps,),
        in_specs=(
            [_block_spec(j) for j in range(pps)]
            + [_block_spec(j) for j in range(pps)]
            + [_qw_spec(j) for j in range(pps)]
            + [
                pl.BlockSpec((1, tile),
                             lambda i, pb, pt, pf, pg, pc: (pt[i * pps], 0)),
                pl.BlockSpec((1, tile),
                             lambda i, pb, pt, pf, pg, pc: (pt[i * pps], 0)),
                pl.BlockSpec((1, q), lambda i, pb, pt, pf, pg, pc: (0, 0)),
            ]),
        out_specs=[
            pl.BlockSpec((1, q, k_tile),
                         lambda i, pb, pt, pf, pg, pc: (pt[i * pps], 0, 0)),
            pl.BlockSpec((1, q, k_tile),
                         lambda i, pb, pt, pf, pg, pc: (pt[i * pps], 0, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((q, tile), jnp.float32)],
    )
    vals, ids = pl.pallas_call(
        functools.partial(_fused_blocked_topk_kernel, tile=tile,
                          k_tile=k_tile, rank_blend=rank_blend,
                          reducer=reducer, pps=pps),
        grid_spec=grid_spec,
        out_shape=(
            jax.ShapeDtypeStruct((n_tiles + 1, q, k_tile), jnp.float32),
            jax.ShapeDtypeStruct((n_tiles + 1, q, k_tile), jnp.int32)),
        interpret=interp,
    )(pair_block, pair_tile, _pair_first(pair_tile), _pair_last(pair_tile),
      pair_cap,
      *([block_docs] * pps), *([block_tfs] * pps), *([pair_qw] * pps),
      norm_t, rank_t, qnorm.reshape(1, q))
    return _finish_candidates(vals, ids, pair_tile, n_tiles, k_tile)


def fused_topk_packed_pallas(packed: Array, block_tfs: Array,
                             pair_block: Array, pair_tile: Array,
                             pair_qw: Array, pair_cap: Array,
                             pair_bits: Array, pair_base: Array,
                             pair_count: Array,
                             norm: Array, rank: Array, qnorm: Array,
                             num_docs: int, block: int, k_tile: int,
                             rank_blend: float = 0.0, tile: int = TILE,
                             reducer: str = "successive",
                             pairs_per_step: int = 1,
                             interpret: bool | None = None):
    """Packed candidate path: in-VMEM decode + per-tile top-k; only
    compressed posting bytes in, only candidates out."""
    nb, wpb = packed.shape
    np_pairs, q = pair_qw.shape
    pps = pairs_per_step
    interp = resolve_interpret(interpret)
    _check_k_tile(k_tile, tile)
    _check_pairs_per_step(np_pairs, pps)
    _check_reducer(reducer, interp)
    n_tiles = max(-(-num_docs // tile), 1)
    norm_t, rank_t = _doc_tiles(norm, rank, n_tiles, tile)

    def _words_spec(j):
        return pl.BlockSpec(
            (1, wpb),
            lambda i, pb, pt, pf, pg, pc, pbt, pba, pcnt, j=j:
                (pb[i * pps + j], 0))

    def _tfs_spec(j):
        return pl.BlockSpec(
            (1, block),
            lambda i, pb, pt, pf, pg, pc, pbt, pba, pcnt, j=j:
                (pb[i * pps + j], 0))

    def _qw_spec(j):
        return pl.BlockSpec(
            (1, q),
            lambda i, pb, pt, pf, pg, pc, pbt, pba, pcnt, j=j:
                (i * pps + j, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=8,
        grid=(np_pairs // pps,),
        in_specs=(
            [_words_spec(j) for j in range(pps)]
            + [_tfs_spec(j) for j in range(pps)]
            + [_qw_spec(j) for j in range(pps)]
            + [
                pl.BlockSpec(
                    (1, tile),
                    lambda i, pb, pt, pf, pg, pc, pbt, pba, pcnt:
                        (pt[i * pps], 0)),
                pl.BlockSpec(
                    (1, tile),
                    lambda i, pb, pt, pf, pg, pc, pbt, pba, pcnt:
                        (pt[i * pps], 0)),
                pl.BlockSpec(
                    (1, q),
                    lambda i, pb, pt, pf, pg, pc, pbt, pba, pcnt: (0, 0)),
            ]),
        out_specs=[
            pl.BlockSpec(
                (1, q, k_tile),
                lambda i, pb, pt, pf, pg, pc, pbt, pba, pcnt:
                    (pt[i * pps], 0, 0)),
            pl.BlockSpec(
                (1, q, k_tile),
                lambda i, pb, pt, pf, pg, pc, pbt, pba, pcnt:
                    (pt[i * pps], 0, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((q, tile), jnp.float32)],
    )
    vals, ids = pl.pallas_call(
        functools.partial(_fused_packed_topk_kernel, tile=tile, block=block,
                          k_tile=k_tile, rank_blend=rank_blend,
                          reducer=reducer, pps=pps),
        grid_spec=grid_spec,
        out_shape=(
            jax.ShapeDtypeStruct((n_tiles + 1, q, k_tile), jnp.float32),
            jax.ShapeDtypeStruct((n_tiles + 1, q, k_tile), jnp.int32)),
        interpret=interp,
    )(pair_block, pair_tile, _pair_first(pair_tile), _pair_last(pair_tile),
      pair_cap, pair_bits, pair_base, pair_count,
      *([packed] * pps), *([block_tfs] * pps), *([pair_qw] * pps),
      norm_t, rank_t, qnorm.reshape(1, q))
    return _finish_candidates(vals, ids, pair_tile, n_tiles, k_tile)


def extract_tile_candidates(final: Array, tile: int, k_tile: int):
    """Pure-jnp mirror of the kernels' per-tile reduction, over a dense
    FINAL score array f32[B, num_docs] (-inf = not a hit).

    Used by the XLA lowering of the candidate engine and by the term-
    sharded scorer (whose psum forces the partial scores dense anyway).
    Returns the same tile-major (values, ids) lists as the kernels:
    per-tile ``top_k`` (ascending-id ties), ids -1 where not finite.
    """
    b, nd = final.shape
    n_tiles = max(-(-nd // tile), 1)
    f = jnp.pad(final, ((0, 0), (0, n_tiles * tile - nd)),
                constant_values=-jnp.inf)
    v, idx = jax.lax.top_k(f.reshape(b, n_tiles, tile), k_tile)
    gids = idx + (jnp.arange(n_tiles, dtype=jnp.int32) * tile)[None, :, None]
    gids = jnp.where(jnp.isfinite(v), gids, -1)
    return (v.reshape(b, n_tiles * k_tile),
            gids.reshape(b, n_tiles * k_tile))


def build_batched_pairs(cand_block: Array, cand_valid: Array, cand_q: Array,
                        cand_w: Array, tile_first: Array, tile_count: Array,
                        n_tiles: int, num_queries: int, max_pairs: int,
                        cand_cap: Array | None = None,
                        pairs_per_step: int = 1):
    """jnp glue: batch candidates -> deduplicated tile-sorted routing pairs.

    cand_* [S]: one entry per (query, term, block) candidate across the
    whole batch; cand_w is the query's idf weight for that block's term,
    cand_cap (optional) the number of lanes of the block the per-term
    posting ``cap`` permits (a cap cutting mid-block truncates the last
    block, matching the oracle's gather).  Blocks selected by several
    queries collapse to ONE pair per tile with a weight ROW over the
    batch (scatter-added across each query's DISTINCT terms; duplicate
    term hashes must be dedup'd upstream — ``dedup_query_hashes`` —
    or their weight double-counts here).  Returns
    (pair_block [NP], pair_tile [NP], pair_qw f32[NP, Q], pair_cap [NP],
    overflow) with NP == max_pairs; overflow counts pairs dropped
    because ``max_pairs`` was too small (0 in healthy runs — surfaced by
    the engine).

    ``pairs_per_step > 1`` additionally RUN-ALIGNS the tile-sorted
    pairs: each tile's contiguous run is padded with no-op pairs
    (qw = 0, cap = 0) to a multiple of ``pairs_per_step``, so a kernel
    that unrolls that many pairs per grid step only ever sees a tile
    transition at a step boundary.  ``max_pairs`` must then be a
    multiple of ``pairs_per_step``; padding that pushes real pairs past
    ``max_pairs`` counts toward ``overflow`` like any other drop.
    """
    s = cand_block.shape[0]
    sentinel = jnp.int32(2**30)
    key = jnp.where(cand_valid, cand_block, sentinel)
    order = jnp.argsort(key, stable=True)        # valid blocks first, grouped
    k_s = key[order]
    q_s = cand_q[order]
    w_s = cand_w[order]
    valid_s = k_s < sentinel
    uniq = valid_s & jnp.concatenate(
        [jnp.ones(1, jnp.bool_), k_s[1:] != k_s[:-1]])
    uid = jnp.cumsum(uniq.astype(jnp.int32)) - 1  # owning unique slot (>= 0
    #                                               wherever valid_s holds)
    total_u = uid[-1] + 1 if s > 0 else jnp.int32(0)
    scat = jnp.where(valid_s, uid, s)
    ublock = jnp.zeros((s,), jnp.int32).at[
        jnp.where(uniq, uid, s)].set(k_s.astype(jnp.int32), mode="drop")
    qw = jnp.zeros((s, num_queries), jnp.float32).at[
        scat, q_s].add(w_s, mode="drop")
    if cand_cap is None:
        ucap = jnp.full((s,), jnp.iinfo(jnp.int32).max, jnp.int32)
    else:
        # a block is owned by one term, so every candidate referencing it
        # carries the same cap; scatter-max is just a safe way to pick it
        ucap = jnp.zeros((s,), jnp.int32).at[scat].max(
            cand_cap[order], mode="drop")
    uvalid = jnp.arange(s, dtype=jnp.int32) < total_u

    # expand unique blocks to their (build-time cached) tile spans
    t0 = tile_first[ublock]
    cnt = jnp.where(uvalid, tile_count[ublock], 0)
    offs = jnp.concatenate([jnp.zeros(1, jnp.int32),
                            jnp.cumsum(cnt, dtype=jnp.int32)])
    total = offs[-1]
    p = jnp.arange(max_pairs, dtype=jnp.int32)
    owner = jnp.clip(jnp.searchsorted(offs, p, side="right") - 1,
                     0, max(s - 1, 0)).astype(jnp.int32)
    real = p < total
    pair_block = jnp.where(real, ublock[owner], 0)
    pair_tile = jnp.where(real, t0[owner] + (p - offs[owner]),
                          n_tiles).astype(jnp.int32)
    tile_order = jnp.argsort(pair_tile, stable=True)
    pair_qw = qw[owner[tile_order]] * real[tile_order][:, None]
    pair_cap = ucap[owner[tile_order]]
    overflow = jnp.maximum(total - max_pairs, 0)
    pair_block = pair_block[tile_order]
    pair_tile = pair_tile[tile_order]
    if pairs_per_step <= 1:
        return pair_block, pair_tile, pair_qw, pair_cap, overflow

    pps = int(pairs_per_step)
    if max_pairs % pps:
        raise ValueError(
            f"max_pairs={max_pairs} must be a multiple of "
            f"pairs_per_step={pps}")
    # Re-scatter each real pair to its run-aligned slot: runs of equal
    # tile get padded to a multiple of pps, consecutive runs stay
    # contiguous, so every run start lands on a step boundary.
    pos = jnp.arange(max_pairs, dtype=jnp.int32)
    real_s = pair_tile < n_tiles
    start = jnp.searchsorted(pair_tile, pair_tile,
                             side="left").astype(jnp.int32)
    end = jnp.searchsorted(pair_tile, pair_tile,
                           side="right").astype(jnp.int32)
    rank = pos - start
    runlen = end - start
    extra = (-(-runlen // pps)) * pps - runlen      # pad of my run
    is_start = rank == 0
    cum = jnp.cumsum(jnp.where(is_start & real_s, extra, 0))
    pad_before = cum - jnp.where(real_s, extra, 0)  # pads of EARLIER runs
    new_pos = jnp.where(real_s, start + pad_before + rank, max_pairs)
    overflow = overflow + jnp.sum(
        (real_s & (new_pos >= max_pairs)).astype(jnp.int32))
    nb_ = jnp.zeros((max_pairs,), jnp.int32).at[new_pos].set(
        pair_block, mode="drop")
    nqw = jnp.zeros_like(pair_qw).at[new_pos].set(pair_qw, mode="drop")
    ncap = jnp.zeros((max_pairs,), jnp.int32).at[new_pos].set(
        pair_cap, mode="drop")
    nt = jnp.full((max_pairs,), -1, jnp.int32).at[new_pos].set(
        pair_tile, mode="drop")
    # Padding slots inherit their run's tile (forward fill keeps the
    # sequence sorted so pair_first/pair_last stay step-aligned); a
    # fully empty prefix/batch falls through to the trash tile.
    nt = jax.lax.cummax(nt)
    nt = jnp.where(nt < 0, n_tiles, nt)
    return nb_, nt, nqw, ncap, overflow
