"""Pallas TPU kernel: fused batched decode-and-score — one HBM pass from
(possibly bit-packed) posting blocks to dense per-query scores.

The paper's §4.3 claim is that query cost is dominated by posting-list
I/O, so the compressed layout must NOT be decompressed through HBM
before scoring.  This kernel closes that gap: its grid walks
scalar-prefetched routing pairs ``(block, tile)`` and, per step,

  1. DMAs ONE posting block into VMEM — either raw int32 doc ids
     (HOR/BlockedIndex) or delta+bit-packed u32 words (PackedCsrIndex);
  2. for packed blocks, unpacks IN VMEM (per-lane variable shifts +
     intra-block prefix sum — the ``packed_postings`` kernel body folded
     into the scorer), so compressed bytes are the only posting bytes
     that ever cross HBM;
  3. one-hot-matmuls the block's tfs against a ``tile``-wide doc tile on
     the MXU and rank-1 updates a ``[Q, tile]`` accumulator with the
     per-query term weights — a hot block is read ONCE and serves every
     query in the batch that touches it.

Routing pairs are deduplicated across the query batch (two queries
sharing a term share the block read) and sorted by tile so each output
tile stays resident in VMEM for one contiguous run of grid steps
(revisit-accumulation, as in ``posting_score``).  The block -> tile span
table is a build-time cache on the index (``tile_first``/``tile_count``),
not a per-query computation.

HBM bytes per batch ~ sum over unique (block, tile) pairs of the block's
payload: ``4*ceil(128*bits/32) + 2*128`` bytes packed vs ``8*128`` bytes
unpacked — the roofline benchmark reports the measured ratio.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.runtime import resolve_interpret

Array = jax.Array

TILE = 512   # doc-space tile width (4 x 128 lanes), matches posting_score
Q_PAD = 8    # query-batch padding quantum (f32 sublane width)


def _accumulate(docs, tfs, qw, tile_base, lane_cap, out_ref, tile: int):
    """Shared scoring tail: one-hot matmul + rank-1 batch update.

    ``lane_cap`` truncates the block at posting granularity so the
    engine honours a per-term ``cap`` that cuts mid-block, exactly like
    the jnp oracle's gather.
    """
    block = docs.shape[0]
    lane0 = jax.lax.broadcasted_iota(jnp.int32, (block,), 0)
    local = docs - tile_base
    inb = (docs >= 0) & (local >= 0) & (local < tile) & (lane0 < lane_cap)
    w = jnp.where(inb, tfs, 0.0)
    lane = jax.lax.broadcasted_iota(jnp.int32, (docs.shape[0], tile), 1)
    onehot = (local[:, None] == lane).astype(jnp.float32)     # [B, tile]
    row = jnp.dot(w[None, :], onehot,
                  preferred_element_type=jnp.float32)         # [1, tile] MXU
    out_ref[0] += jnp.dot(qw[:, None], row,
                          preferred_element_type=jnp.float32)  # [Q, tile]


def _fused_blocked_kernel(pair_block, pair_tile, pair_first,
                          pair_cap,                            # SMEM prefetch
                          docs_ref, tfs_ref, qw_ref,           # VMEM inputs
                          out_ref, *, tile: int):
    i = pl.program_id(0)

    @pl.when(pair_first[i] == 1)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    _accumulate(docs_ref[0, :], tfs_ref[0, :], qw_ref[0, :],
                pair_tile[i] * tile, pair_cap[i], out_ref, tile)


def _fused_packed_kernel(pair_block, pair_tile, pair_first, pair_cap,
                         pair_bits, pair_base, pair_count,     # SMEM prefetch
                         words_ref, tfs_ref, qw_ref,           # VMEM inputs
                         out_ref, *, tile: int, block: int):
    i = pl.program_id(0)

    @pl.when(pair_first[i] == 1)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    # in-VMEM decode (packed_postings' _unpack_kernel, fused)
    bits = pair_bits[i].astype(jnp.uint32)
    base = pair_base[i]
    count = pair_count[i]
    words = words_ref[0, :]                                   # u32[Wpb]
    lane = jax.lax.broadcasted_iota(jnp.uint32, (block,), 0)
    bitpos = lane * bits
    wi = (bitpos >> 5).astype(jnp.int32)
    off = bitpos & jnp.uint32(31)
    lo = words[wi] >> off
    hi = jnp.where(off > 0,
                   words[jnp.minimum(wi + 1, words.shape[0] - 1)]
                   << (jnp.uint32(32) - off), jnp.uint32(0))
    raw = lo | hi
    mask = jnp.where(bits >= 32, jnp.uint32(0xFFFFFFFF),
                     (jnp.uint32(1) << bits) - jnp.uint32(1))
    deltas = (raw & mask).astype(jnp.int32)
    docs = base + jnp.cumsum(deltas)
    valid = jax.lax.broadcasted_iota(jnp.int32, (block,), 0) < count
    docs = jnp.where(valid, docs, -1)

    _accumulate(docs, tfs_ref[0, :].astype(jnp.float32), qw_ref[0, :],
                pair_tile[i] * tile, pair_cap[i], out_ref, tile)


def _pair_first(pair_tile: Array) -> Array:
    return jnp.concatenate(
        [jnp.ones(1, jnp.int32),
         (pair_tile[1:] != pair_tile[:-1]).astype(jnp.int32)])


def _finish(out: Array, pair_tile: Array, n_tiles: int, tile: int,
            num_docs: int) -> Array:
    """Mask never-visited (garbage) tiles, flatten to [Q, num_docs]."""
    visited = jnp.zeros((n_tiles + 1,), jnp.bool_).at[pair_tile].set(True)
    out = jnp.where(visited[:, None, None], out, 0.0)
    q = out.shape[1]
    return out[:n_tiles].transpose(1, 0, 2).reshape(q, n_tiles * tile)[
        :, :num_docs]


def fused_score_blocked_pallas(block_docs: Array, block_tfs: Array,
                               pair_block: Array, pair_tile: Array,
                               pair_qw: Array, pair_cap: Array,
                               num_docs: int, tile: int = TILE,
                               interpret: bool | None = None) -> Array:
    """HOR path: block_docs i32[NB, B], block_tfs f32[NB, B] read in place;
    pair_* [NP] tile-sorted routing, pair_qw f32[NP, Q] per-query weight
    rows (Q padded to a multiple of 8), pair_cap i32[NP] per-pair valid
    lane count (posting-granular cap).  Returns f32[Q, num_docs]."""
    nb, b = block_docs.shape
    np_pairs, q = pair_qw.shape
    n_tiles = -(-num_docs // tile)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(np_pairs,),
        in_specs=[
            pl.BlockSpec((1, b), lambda i, pb, pt, pf, pc: (pb[i], 0)),
            pl.BlockSpec((1, b), lambda i, pb, pt, pf, pc: (pb[i], 0)),
            pl.BlockSpec((1, q), lambda i, pb, pt, pf, pc: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, q, tile),
                               lambda i, pb, pt, pf, pc: (pt[i], 0, 0)),
    )
    out = pl.pallas_call(
        functools.partial(_fused_blocked_kernel, tile=tile),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_tiles + 1, q, tile), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(pair_block, pair_tile, _pair_first(pair_tile), pair_cap,
      block_docs, block_tfs, pair_qw)
    return _finish(out, pair_tile, n_tiles, tile, num_docs)


def fused_score_packed_pallas(packed: Array, block_tfs: Array,
                              pair_block: Array, pair_tile: Array,
                              pair_qw: Array, pair_cap: Array,
                              pair_bits: Array, pair_base: Array,
                              pair_count: Array,
                              num_docs: int, block: int,
                              tile: int = TILE,
                              interpret: bool | None = None) -> Array:
    """Packed path: packed u32[NB, Wpb] words + f16 tfs stay compressed in
    HBM; decode happens inside the scoring step.  Same routing contract
    as the HOR path plus per-pair (bits, base, count) decode scalars."""
    nb, wpb = packed.shape
    np_pairs, q = pair_qw.shape
    n_tiles = -(-num_docs // tile)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=7,
        grid=(np_pairs,),
        in_specs=[
            pl.BlockSpec(
                (1, wpb),
                lambda i, pb, pt, pf, pc, pbt, pba, pcnt: (pb[i], 0)),
            pl.BlockSpec(
                (1, block),
                lambda i, pb, pt, pf, pc, pbt, pba, pcnt: (pb[i], 0)),
            pl.BlockSpec(
                (1, q),
                lambda i, pb, pt, pf, pc, pbt, pba, pcnt: (i, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, q, tile),
            lambda i, pb, pt, pf, pc, pbt, pba, pcnt: (pt[i], 0, 0)),
    )
    out = pl.pallas_call(
        functools.partial(_fused_packed_kernel, tile=tile, block=block),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_tiles + 1, q, tile), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(pair_block, pair_tile, _pair_first(pair_tile), pair_cap,
      pair_bits, pair_base, pair_count, packed, block_tfs, pair_qw)
    return _finish(out, pair_tile, n_tiles, tile, num_docs)


def build_batched_pairs(cand_block: Array, cand_valid: Array, cand_q: Array,
                        cand_w: Array, tile_first: Array, tile_count: Array,
                        n_tiles: int, num_queries: int, max_pairs: int,
                        cand_cap: Array | None = None):
    """jnp glue: batch candidates -> deduplicated tile-sorted routing pairs.

    cand_* [S]: one entry per (query, term, block) candidate across the
    whole batch; cand_w is the query's idf weight for that block's term,
    cand_cap (optional) the number of lanes of the block the per-term
    posting ``cap`` permits (a cap cutting mid-block truncates the last
    block, matching the oracle's gather).  Blocks selected by several
    queries collapse to ONE pair per tile with a weight ROW over the
    batch (scatter-added, so duplicate query terms accumulate like the
    oracle).  Returns
    (pair_block [NP], pair_tile [NP], pair_qw f32[NP, Q], pair_cap [NP],
    overflow) with NP == max_pairs; overflow counts pairs dropped
    because ``max_pairs`` was too small (0 in healthy runs — surfaced by
    the engine).
    """
    s = cand_block.shape[0]
    sentinel = jnp.int32(2**30)
    key = jnp.where(cand_valid, cand_block, sentinel)
    order = jnp.argsort(key, stable=True)        # valid blocks first, grouped
    k_s = key[order]
    q_s = cand_q[order]
    w_s = cand_w[order]
    valid_s = k_s < sentinel
    uniq = valid_s & jnp.concatenate(
        [jnp.ones(1, jnp.bool_), k_s[1:] != k_s[:-1]])
    uid = jnp.cumsum(uniq.astype(jnp.int32)) - 1  # owning unique slot (>= 0
    #                                               wherever valid_s holds)
    total_u = uid[-1] + 1 if s > 0 else jnp.int32(0)
    scat = jnp.where(valid_s, uid, s)
    ublock = jnp.zeros((s,), jnp.int32).at[
        jnp.where(uniq, uid, s)].set(k_s.astype(jnp.int32), mode="drop")
    qw = jnp.zeros((s, num_queries), jnp.float32).at[
        scat, q_s].add(w_s, mode="drop")
    if cand_cap is None:
        ucap = jnp.full((s,), jnp.iinfo(jnp.int32).max, jnp.int32)
    else:
        # a block is owned by one term, so every candidate referencing it
        # carries the same cap; scatter-max is just a safe way to pick it
        ucap = jnp.zeros((s,), jnp.int32).at[scat].max(
            cand_cap[order], mode="drop")
    uvalid = jnp.arange(s, dtype=jnp.int32) < total_u

    # expand unique blocks to their (build-time cached) tile spans
    t0 = tile_first[ublock]
    cnt = jnp.where(uvalid, tile_count[ublock], 0)
    offs = jnp.concatenate([jnp.zeros(1, jnp.int32),
                            jnp.cumsum(cnt, dtype=jnp.int32)])
    total = offs[-1]
    p = jnp.arange(max_pairs, dtype=jnp.int32)
    owner = jnp.clip(jnp.searchsorted(offs, p, side="right") - 1,
                     0, max(s - 1, 0)).astype(jnp.int32)
    real = p < total
    pair_block = jnp.where(real, ublock[owner], 0)
    pair_tile = jnp.where(real, t0[owner] + (p - offs[owner]),
                          n_tiles).astype(jnp.int32)
    tile_order = jnp.argsort(pair_tile, stable=True)
    pair_qw = qw[owner[tile_order]] * real[tile_order][:, None]
    pair_cap = ucap[owner[tile_order]]
    overflow = jnp.maximum(total - max_pairs, 0)
    return (pair_block[tile_order], pair_tile[tile_order], pair_qw,
            pair_cap, overflow)
