"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``ref_*`` function is the semantic ground truth; kernel tests sweep
shapes/dtypes and ``assert_allclose`` kernel-vs-oracle.  The oracles are
also the XLA fallback path used on hardware without Pallas support.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------------------
# posting_score: blocked posting-list scoring (the q_occ + accumulate phase)
# ---------------------------------------------------------------------------


def ref_posting_score(block_docs: Array, block_tfs: Array, block_w: Array,
                      num_docs: int) -> Array:
    """Scatter-add tf*w of every valid posting into a dense score vector.

    block_docs i32[NB, B] (-1 = padding), block_tfs f32[NB, B],
    block_w f32[NB] per-block term weight (idf * query weight).
    """
    docs = block_docs.reshape(-1)
    w = (block_tfs * block_w[:, None]).reshape(-1)
    valid = docs >= 0
    tgt = jnp.where(valid, docs, num_docs)
    acc = jnp.zeros((num_docs + 1,), jnp.float32)
    acc = acc.at[tgt].add(jnp.where(valid, w, 0.0), mode="drop")
    return acc[:num_docs]


# ---------------------------------------------------------------------------
# packed_postings: delta + bit-packed doc-id block decode
# ---------------------------------------------------------------------------


def ref_unpack_block(packed: Array, bits: Array, base: Array, count: Array,
                     block: int) -> Array:
    """Decode one packed block -> doc ids i32[block] (-1 past count).

    packed u32[words], bits/base/count scalars.
    """
    lane = jnp.arange(block, dtype=jnp.uint32)
    bitpos = lane * bits.astype(jnp.uint32)
    wi = (bitpos >> 5).astype(jnp.int32)
    off = bitpos & jnp.uint32(31)
    lo = packed[wi] >> off
    hi = jnp.where(off > 0,
                   packed[jnp.minimum(wi + 1, packed.shape[0] - 1)]
                   << (jnp.uint32(32) - off), jnp.uint32(0))
    raw = lo | hi
    mask = jnp.where(bits >= 32, jnp.uint32(0xFFFFFFFF),
                     (jnp.uint32(1) << bits.astype(jnp.uint32)) - 1)
    deltas = (raw & mask).astype(jnp.int32)
    docs = base + jnp.cumsum(deltas, dtype=jnp.int32)
    return jnp.where(jnp.arange(block) < count, docs, -1)


def ref_unpack_blocks(packed: Array, bits: Array, base: Array, count: Array,
                      block: int) -> Array:
    return jax.vmap(lambda p, b, ba, c: ref_unpack_block(p, b, ba, c, block)
                    )(packed, bits, base, count)


# ---------------------------------------------------------------------------
# embedding_bag: fixed multi-hot bag sum (recsys hot path)
# ---------------------------------------------------------------------------


def ref_embedding_bag(table: Array, indices: Array,
                      mode: str = "sum") -> Array:
    """table f32[V, D], indices i32[B, H] (-1 = padding) -> f32[B, D]."""
    safe = jnp.maximum(indices, 0)
    rows = table[safe]                               # [B, H, D]
    valid = (indices >= 0)[..., None].astype(table.dtype)
    rows = rows * valid
    if mode == "sum":
        return rows.sum(axis=1)
    if mode == "mean":
        n = jnp.maximum(valid.sum(axis=1), 1.0)
        return rows.sum(axis=1) / n
    raise ValueError(mode)


# ---------------------------------------------------------------------------
# segment_multi_agg: PNA fused mean/min/max/std over padded neighbor lists
# ---------------------------------------------------------------------------


def ref_pna_multi_agg(feats: Array, nbr: Array, eps: float = 1e-5) -> Array:
    """feats f32[Nsrc, D], nbr i32[N, K] (-1 pad) -> f32[N, 4D].

    Output channels: [mean | min | max | std] (PNA's four aggregators,
    fused so the neighbor features are read ONCE).
    """
    safe = jnp.maximum(nbr, 0)
    x = feats[safe]                                  # [N, K, D]
    valid = (nbr >= 0)[..., None]
    n = jnp.maximum(valid.sum(axis=1).astype(feats.dtype), 1.0)
    xs = jnp.where(valid, x, 0.0)
    mean = xs.sum(axis=1) / n
    mn = jnp.where(valid, x, jnp.inf).min(axis=1)
    mn = jnp.where(jnp.isfinite(mn), mn, 0.0)
    mx = jnp.where(valid, x, -jnp.inf).max(axis=1)
    mx = jnp.where(jnp.isfinite(mx), mx, 0.0)
    mean_sq = jnp.where(valid, x * x, 0.0).sum(axis=1) / n
    std = jnp.sqrt(jnp.maximum(mean_sq - mean * mean, 0.0) + eps)
    return jnp.concatenate([mean, mn, mx, std], axis=-1)


# ---------------------------------------------------------------------------
# flash_attention: causal / sliding-window attention with GQA
# ---------------------------------------------------------------------------


def ref_attention(q: Array, k: Array, v: Array, causal: bool = True,
                  window: int = 0, scale: float | None = None) -> Array:
    """q f32[B, Hq, S, Dh], k/v f32[B, Hkv, S, Dh] -> f32[B, Hq, S, Dh].

    GQA: Hq must be a multiple of Hkv.  ``window`` > 0 limits attention to
    the last ``window`` positions (sliding-window / Mistral-style).
    """
    b, hq, s, dh = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    if scale is None:
        scale = dh ** -0.5
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, kk) * scale
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= kpos <= qpos
    if window and window > 0:
        mask &= kpos > qpos - window
    logits = jnp.where(mask, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv)
