"""Pallas TPU kernel: blocked posting-list scoring (q_occ + accumulate).

The paper's query evaluation bottleneck is streaming posting lists and
accumulating per-document scores.  A GPU implementation would use atomic
scatter-adds; TPUs have no hardware scatter, so we ADAPT (DESIGN.md §2):

  * postings live in the HOR/BlockedIndex layout: 128-lane blocks with
    per-block doc-id min/max — each block is one aligned VMEM tile DMA;
  * the scatter-add becomes a ONE-HOT MATMUL on the MXU: a block's 128
    postings are compared against a 512-wide doc tile (VPU compare) and
    contracted `w[1,128] @ onehot[128,512]` into the tile accumulator;
  * block -> doc-tile routing is data-dependent, so it is precomputed as
    a (block, tile) pair list fed through SCALAR PREFETCH; pairs are
    sorted by tile so each output tile is resident in VMEM for one
    contiguous run of grid steps (revisit-accumulation pattern), with the
    score buffer zero-initialized through input/output aliasing.

HBM traffic: each selected posting block is read exactly once per tile it
overlaps (high-df terms overlap ~1 tile per block); the PR/COO layout by
contrast must gather scattered heap tuples.  This kernel is the TPU
restatement of the paper's claim that layout determines I/O.

Fused-engine design (see ``kernels/fused_decode_score.py``, the batched
successor of this kernel):

  * PAIR ROUTING — the (block, tile) expansion used to be derived from
    ``block_min``/``block_max`` per query inside ``build_pairs``; the
    span table is a pure function of the immutable index, so it is now a
    BUILD-TIME cache (``tile_first``/``tile_count`` on BlockedIndex and
    PackedCsrIndex, plus static ``route_pairs_max``/``route_span_max``
    pair budgets).  ``build_pairs`` only does the per-query cumsum /
    searchsorted expansion over those cached spans.
  * BATCH TILING — the fused kernel widens this kernel's ``[1, tile]``
    accumulator to ``[Q, tile]``: routing pairs are deduplicated across a
    batch of queries and carry a per-query weight ROW, so a hot posting
    block is DMA'd once and a rank-1 MXU update serves every query that
    touches it.
  * HBM-BYTES ACCOUNTING — per batch, posting bytes =
    sum over unique (block, tile) pairs of the block payload:
    ``4*ceil(128*bits/32) + 2*128`` B packed vs ``8*128`` B unpacked HOR,
    i.e. the compressed layout streams <= 0.5x the bytes (measured per
    query by ``benchmarks/roofline.py``).  The fused kernel never writes
    decompressed postings back to HBM — decode happens in VMEM inside
    the scoring step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.runtime import resolve_interpret

Array = jax.Array

TILE = 512  # doc-space tile width (4 × 128 lanes)


def _score_kernel(pair_block, pair_tile, pair_w, pair_first,  # prefetch (SMEM)
                  docs_ref, tfs_ref,                  # inputs (VMEM blocks)
                  out_ref,                            # output tile accumulator
                  *, tile: int):
    i = pl.program_id(0)

    # First pair touching this tile zero-initializes its VMEM block; later
    # pairs (sorted by tile -> contiguous run) accumulate in place.
    @pl.when(pair_first[i] == 1)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    tile_base = pair_tile[i] * tile
    docs = docs_ref[0, :]                               # i32[B]
    local = docs - tile_base
    inb = (docs >= 0) & (local >= 0) & (local < tile)
    w = tfs_ref[0, :] * pair_w[i]                       # f32[B]
    w = jnp.where(inb, w, 0.0)
    lane = jax.lax.broadcasted_iota(jnp.int32, (docs.shape[0], tile), 1)
    onehot = (local[:, None] == lane).astype(jnp.float32)   # [B, tile]
    contrib = jnp.dot(w[None, :], onehot,
                      preferred_element_type=jnp.float32)   # [1, tile] (MXU)
    out_ref[...] += contrib


def posting_score_pallas(block_docs: Array, block_tfs: Array,
                         pair_block: Array, pair_tile: Array, pair_w: Array,
                         num_docs: int, tile: int = TILE,
                         interpret: bool | None = None) -> Array:
    """Run the scoring kernel.

    block_docs i32[NB, B], block_tfs f32[NB, B]: the index's posting blocks
    (read in place — no per-query copy).
    pair_* [NP]: (block, tile, weight) routing triples, SORTED by tile;
    padding pairs use tile == n_tiles (trash row) and weight 0.
    """
    nb, b = block_docs.shape
    n_tiles = -(-num_docs // tile)
    np_pairs = pair_block.shape[0]
    pair_first = jnp.concatenate(
        [jnp.ones(1, jnp.int32),
         (pair_tile[1:] != pair_tile[:-1]).astype(jnp.int32)])

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(np_pairs,),
        in_specs=[
            pl.BlockSpec((1, b), lambda i, pb, pt, pw, pf: (pb[i], 0)),
            pl.BlockSpec((1, b), lambda i, pb, pt, pw, pf: (pb[i], 0)),
        ],
        out_specs=pl.BlockSpec((1, tile), lambda i, pb, pt, pw, pf: (pt[i], 0)),
    )
    out = pl.pallas_call(
        functools.partial(_score_kernel, tile=tile),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_tiles + 1, tile), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(pair_block, pair_tile, pair_w, pair_first, block_docs, block_tfs)
    # Tiles never visited by any pair hold garbage -> mask them to zero.
    visited = jnp.zeros((n_tiles + 1,), jnp.bool_).at[pair_tile].set(True)
    out = jnp.where(visited[:, None], out, 0.0)
    return out[:n_tiles].reshape(-1)[:num_docs]


def build_pairs(sel_blocks: Array, sel_valid: Array, sel_w: Array,
                tile_first: Array, tile_count: Array, n_tiles: int,
                max_pairs: int):
    """jnp glue: expand selected blocks into tile-sorted routing pairs.

    sel_blocks i32[S] global block ids for the query's terms,
    sel_valid bool[S], sel_w f32[S] per-block term weight (idf).
    tile_first/tile_count i32[NB] are the index's BUILD-TIME routing
    cache (block -> doc-tile span) — see ``ops.routing_spans``.
    Returns (pair_block, pair_tile, pair_w, overflow) with static size
    ``max_pairs``; ``overflow`` counts dropped pairs (0 in healthy runs).
    """
    safe = jnp.maximum(sel_blocks, 0)
    t0 = tile_first[safe]
    span = jnp.where(sel_valid, tile_count[safe], 0)
    offs = jnp.concatenate([jnp.zeros(1, jnp.int32),
                            jnp.cumsum(span, dtype=jnp.int32)])
    total = offs[-1]
    p = jnp.arange(max_pairs, dtype=jnp.int32)
    owner = jnp.clip(jnp.searchsorted(offs, p, side="right") - 1,
                     0, sel_blocks.shape[0] - 1).astype(jnp.int32)
    real = p < total
    tile_id = t0[owner] + (p - offs[owner])
    pair_block = jnp.where(real, safe[owner], 0)
    pair_tile = jnp.where(real, tile_id, n_tiles).astype(jnp.int32)
    pair_w = jnp.where(real, sel_w[owner], 0.0)
    order = jnp.argsort(pair_tile, stable=True)
    overflow = jnp.maximum(total - max_pairs, 0)
    return (pair_block[order], pair_tile[order], pair_w[order], overflow)
