"""Pallas TPU kernel: decode delta+bit-packed posting blocks in VMEM.

The beyond-paper layout (PackedCsrIndex) stores doc-id deltas bit-packed
into u32 words — the "special number encodings" the paper says DBMSs
lack (§3.1).  This kernel unpacks a batch of blocks: per-lane variable
shifts (VPU) + an intra-block prefix sum.  HBM traffic per block drops
from 512 B (int32 ids) to ``ceil(128·bits/8)`` bytes — e.g. 192 B at 12
bits — directly attacking the memory roofline term of query evaluation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import resolve_interpret

Array = jax.Array


def _unpack_kernel(words_ref, bits_ref, base_ref, count_ref, out_ref,
                   *, block: int):
    bits = bits_ref[0, 0].astype(jnp.uint32)
    base = base_ref[0, 0]
    count = count_ref[0, 0]
    words = words_ref[0, :]                              # u32[Wpb]
    lane = jax.lax.broadcasted_iota(jnp.uint32, (block,), 0)
    bitpos = lane * bits
    wi = (bitpos >> 5).astype(jnp.int32)
    off = bitpos & jnp.uint32(31)
    lo = words[wi] >> off
    hi = jnp.where(off > 0,
                   words[jnp.minimum(wi + 1, words.shape[0] - 1)]
                   << (jnp.uint32(32) - off), jnp.uint32(0))
    raw = lo | hi
    mask = jnp.where(bits >= 32, jnp.uint32(0xFFFFFFFF),
                     (jnp.uint32(1) << bits) - jnp.uint32(1))
    deltas = (raw & mask).astype(jnp.int32)
    docs = base + jnp.cumsum(deltas)
    valid = jax.lax.broadcasted_iota(jnp.int32, (block,), 0) < count
    out_ref[0, :] = jnp.where(valid, docs, -1)


def unpack_blocks_pallas(packed: Array, bits: Array, base: Array,
                         count: Array, block: int,
                         interpret: bool | None = None) -> Array:
    """packed u32[NB, Wpb], bits/base/count i32[NB] -> doc ids i32[NB, block]."""
    nb, wpb = packed.shape
    kernel = functools.partial(_unpack_kernel, block=block)
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, wpb), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block), jnp.int32),
        interpret=resolve_interpret(interpret),
    )(packed, bits.reshape(-1, 1), base.reshape(-1, 1),
      count.reshape(-1, 1))
