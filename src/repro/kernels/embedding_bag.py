"""Pallas TPU kernel: fixed multi-hot EmbeddingBag (recsys hot path).

JAX has no native EmbeddingBag; the XLA path is take + segment_sum
(core/segments.py).  This kernel fuses the gather and the reduce for the
fixed-arity case (indices [B, H], H hot ids per bag — the common recsys
layout after bucketization): the bag's H rows are loaded once and reduced
in VMEM without materializing the [B, H, D] gather.

TPU note: rows are fetched with dynamic-index loads from the table block;
a production deployment would double-buffer the row DMAs (or keep hot
rows VMEM-resident); the paper-relevant property — O(bag) contiguous
reads instead of per-(bag,id) tuples — is preserved either way.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.runtime import resolve_interpret

Array = jax.Array


def _bag_kernel(idx_ref, table_ref, out_ref, *, hot: int, bsz: int):
    i = pl.program_id(0)

    def row_body(r, _):
        def hot_body(h, acc):
            row = idx_ref[i * bsz + r, h]
            safe = jnp.maximum(row, 0)
            rowvec = table_ref[pl.ds(safe, 1), :]
            return acc + jnp.where(row >= 0, rowvec, 0.0)
        acc = jax.lax.fori_loop(
            0, hot, hot_body,
            jnp.zeros((1, table_ref.shape[1]), table_ref.dtype))
        out_ref[pl.ds(r, 1), :] = acc
        return 0

    jax.lax.fori_loop(0, bsz, row_body, 0)


def embedding_bag_pallas(table: Array, indices: Array, tile_b: int = 256,
                         interpret: bool | None = None) -> Array:
    """table f32[V, D], indices i32[B, H] (-1 pads) -> f32[B, D] (sum)."""
    v, d = table.shape
    bsz, hot = indices.shape
    tile_b = min(tile_b, bsz)
    assert bsz % tile_b == 0, (bsz, tile_b)
    kernel = functools.partial(_bag_kernel, hot=hot, bsz=tile_b)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(bsz // tile_b,),
            in_specs=[pl.BlockSpec((v, d), lambda i, idx: (0, 0))],
            out_specs=pl.BlockSpec((tile_b, d), lambda i, idx: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((bsz, d), table.dtype),
        interpret=resolve_interpret(interpret),
    )(indices, table)
