"""Backend selection shared by every Pallas kernel in this package.

Kernels take ``interpret: bool | None = None``; ``None`` resolves to
"interpret unless we are actually on a TPU", so the same call sites run
the Python interpreter on CPU (semantics validated everywhere) and the
compiled Mosaic kernel on real hardware — no hardcoded ``interpret=True``
defaults to flip before a TPU run.
"""
from __future__ import annotations

import jax


def resolve_interpret(interpret: bool | None) -> bool:
    """Explicit flag wins; otherwise compile only on a real TPU backend."""
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"
