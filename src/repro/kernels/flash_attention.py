"""Pallas TPU kernel: flash attention (causal / sliding-window, GQA).

Online-softmax attention with VMEM-resident running (m, l, acc) carried
across KV tiles — no S×S score matrix ever touches HBM.  Supports:
  * causal masking,
  * sliding windows (Mistral/Gemma local layers),
  * GQA via the KV-head index map (no K/V repeat materialization).

Block sizes are BlockSpec parameters; defaults (128, 128) match the MXU
128×128 systolic tile.  Fully-masked KV tiles short-circuit via pl.when
(their DMA is still issued by the pipeline — an acceptable cost at the
window sizes used here; a production grid would prune them).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.runtime import resolve_interpret

Array = jax.Array

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, scale: float, causal: bool, window: int,
                  tq: int, tk: int, nk: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    i = pl.program_id(1)
    qpos = i * tq + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 0)
    kpos = j * tk + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 1)
    mask = jnp.ones((tq, tk), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window

    # Skip tiles with no unmasked entry (beyond the causal/window frontier).
    any_live = jnp.any(mask)

    @pl.when(any_live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                  # [tq, d]
        k = k_ref[0].astype(jnp.float32)                  # [tk, d]
        v = v_ref[0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]                               # [tq, 1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        l_new = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + \
            jnp.dot(p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(j == nk - 1)
    def _final():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_pallas(q: Array, k: Array, v: Array, causal: bool = True,
                           window: int = 0, scale: float | None = None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool | None = None) -> Array:
    """q [B,Hq,S,D], k/v [B,Hkv,S,D] -> [B,Hq,S,D]; Hq % Hkv == 0."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    if scale is None:
        scale = d ** -0.5
    tq = min(block_q, s)
    tk = min(block_k, s)
    assert s % tq == 0 and s % tk == 0, (s, tq, tk)
    nq, nk = s // tq, s // tk

    qf = q.reshape(b * hq, s, d)
    kf = k.reshape(b * hkv, s, d)
    vf = v.reshape(b * hkv, s, d)

    def kv_map(bh, i, j):
        return ((bh // hq) * hkv + (bh % hq) // group, j, 0)

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               window=window, tq=tq, tk=tk, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(b * hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, tq, d), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, tk, d), kv_map),
            pl.BlockSpec((1, tk, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, tq, d), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((tq, 1), jnp.float32),
            pltpu.VMEM((tq, 1), jnp.float32),
            pltpu.VMEM((tq, d), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(qf, kf, vf)
    return out.reshape(b, hq, s, d)
