"""Query tracing: monotonic-clock spans threaded through the read path.

Span taxonomy (serving tier)
----------------------------
Top-level stages partition a request's lifetime with SHARED boundary
timestamps, so per-request stage durations sum EXACTLY to the measured
end-to-end latency:

    queue_wait   submit -> batch pickup
    assemble     batch pickup -> query block filled (attrs: fill,
                 padded slots)
    score        engine dispatch -> candidates on host
    respond      candidates -> response handed to the ticket
    cache_hit    batch pickup -> response, replacing assemble/score/
                 respond on a result-cache hit

Children of ``score`` (``parent="score"``) record where the engine
itself went: one ``segment`` span per sealed segment (size_class,
layout, resolved TuneConfig geometry, analytic candidate/posting
bytes), a ``delta`` span for the mutable tail, a ``merge`` span for
the host candidate merge, and ``shard_fanout``/``shard_sync`` spans on
the distributed scorers.

Tracing is sampled per ticket (``Tracer``); when disabled (the
default) no ``Span``/``Trace`` object is constructed anywhere on the
hot path — the test suite asserts this by making construction raise.
"""
from __future__ import annotations

import threading
import time
from typing import Any


class Span:
    """One timed region. ``t0``/``t1`` are ``time.perf_counter()``
    readings; pass explicit timestamps to share stage boundaries."""

    __slots__ = ("name", "parent", "t0", "t1", "attrs")

    def __init__(self, name: str, t0: float | None = None,
                 parent: str | None = None, attrs: dict | None = None):
        self.name = name
        self.parent = parent
        self.t0 = time.perf_counter() if t0 is None else t0
        self.t1: float | None = None
        self.attrs = attrs if attrs is not None else {}

    def end(self, t1: float | None = None) -> "Span":
        self.t1 = time.perf_counter() if t1 is None else t1
        return self

    @property
    def duration_us(self) -> float:
        t1 = self.t1 if self.t1 is not None else time.perf_counter()
        return (t1 - self.t0) * 1e6

    def to_dict(self) -> dict:
        d = {"name": self.name, "duration_us": self.duration_us}
        if self.parent is not None:
            d["parent"] = self.parent
        if self.attrs:
            d["attrs"] = dict(self.attrs)
        return d

    def __repr__(self) -> str:
        return (f"Span({self.name!r}, {self.duration_us:.1f}us"
                + (f", parent={self.parent!r}" if self.parent else "") + ")")


class Trace:
    """Ordered span collection for one sampled request."""

    __slots__ = ("spans",)

    def __init__(self):
        self.spans: list[Span] = []

    def span(self, name: str, t0: float | None = None,
             parent: str | None = None, **attrs) -> Span:
        s = Span(name, t0=t0, parent=parent, attrs=attrs or None)
        self.spans.append(s)
        return s

    def adopt(self, spans: list) -> None:
        """Share spans recorded once per micro-batch (assemble/score
        and their children) with every sampled ticket in the batch."""
        self.spans.extend(spans)

    def stage_durations(self) -> dict:
        """Top-level (parentless) span name -> total duration_us."""
        out: dict[str, float] = {}
        for s in self.spans:
            if s.parent is None:
                out[s.name] = out.get(s.name, 0.0) + s.duration_us
        return out

    def total_us(self) -> float:
        return sum(self.stage_durations().values())

    def to_dict(self) -> dict:
        return {"spans": [s.to_dict() for s in self.spans]}


class Tracer:
    """Per-ticket sampling: every ``sample_every``-th submission gets a
    ``Trace``; ``sample_every == 0`` disables tracing entirely (returns
    None without constructing anything)."""

    def __init__(self, sample_every: int = 0):
        self.sample_every = int(sample_every)
        self._lock = threading.Lock()
        self._n = 0

    @property
    def enabled(self) -> bool:
        return self.sample_every > 0

    def sample(self) -> Trace | None:
        if self.sample_every <= 0:
            return None
        with self._lock:
            self._n += 1
            if self._n % self.sample_every != 0:
                return None
        return Trace()


class StageAggregator:
    """Folds sampled traces' top-level stage durations into registry
    histograms (``serve_stage_<name>_us``), so the per-stage latency
    percentiles travel in the same snapshot as every other metric."""

    def __init__(self, registry=None, prefix: str = "serve_stage_"):
        if registry is None:
            from repro.obs.registry import MetricsRegistry
            registry = MetricsRegistry()
        self.registry = registry
        self.prefix = prefix
        self._lock = threading.Lock()
        self._stages: dict[str, Any] = {}

    def _hist(self, stage: str):
        h = self._stages.get(stage)
        if h is None:
            with self._lock:
                h = self._stages.get(stage)
                if h is None:
                    h = self.registry.histogram(self.prefix + stage + "_us")
                    self._stages[stage] = h
        return h

    def observe(self, stage: str, duration_us: float) -> None:
        self._hist(stage).observe(duration_us)

    def observe_trace(self, trace: Trace) -> None:
        for stage, us in trace.stage_durations().items():
            self.observe(stage, us)

    def summary(self) -> dict:
        """stage name -> histogram snapshot ({count, sum, p50, p99})."""
        with self._lock:
            stages = sorted(self._stages.items())
        out = {}
        for stage, hist in stages:
            snap = hist.snapshot()
            snap.pop("type", None)
            out[stage] = snap
        return out

    def reset(self) -> None:
        with self._lock:
            stages = list(self._stages.values())
        for hist in stages:
            hist.reset()
