"""Observability primitives shared by every layer of the system.

This package is dependency-neutral (stdlib + numpy only at import
time), so the core index, the kernels, the distributed tier, and the
serving tier can all instrument themselves against ONE registry and
ONE span format without import cycles:

  registry.py  named counters / gauges / histograms in a
               ``MetricsRegistry`` with a stable snapshot export
               (JSON + Prometheus text, both round-trippable), the
               process-global ``GLOBAL`` registry engine-level counters
               land in, and the bounded structured ``EventLog`` the
               index maintenance path emits into
  trace.py     query tracing — monotonic-clock ``Span``/``Trace``
               threaded through the serving read path, a sampling
               ``Tracer`` (zero span construction when disabled), and
               the ``StageAggregator`` that folds per-request stage
               durations into registry histograms
"""
from repro.obs.registry import (GLOBAL, Counter, EventLog, Gauge, Histogram,
                                MetricsRegistry, global_registry,
                                parse_prometheus, snapshot_from_json,
                                snapshot_to_json)
from repro.obs.trace import Span, StageAggregator, Trace, Tracer

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "EventLog",
    "GLOBAL", "global_registry", "parse_prometheus", "snapshot_to_json",
    "snapshot_from_json", "Span", "Trace", "Tracer", "StageAggregator",
]
