"""Unified metrics registry + bounded maintenance event log.

One process may hold several registries (each ``QueryServer`` owns one
for its serving-path metrics) plus the module-level ``GLOBAL`` registry
that engine internals — code that runs inside ``jax.jit`` and cannot be
handed a per-server object — increment via ``jax.debug.callback``
(routing-pair overflow, conjunctive term truncation).

Export contract
---------------
``MetricsRegistry.snapshot()`` returns one stable dict shape::

    {"serve_requests":   {"type": "counter",   "value": 123},
     "cache_hit_rate":   {"type": "gauge",     "value": 0.25},
     "serve_stage_score_us": {"type": "histogram", "count": 10,
                              "sum": 5231.0, "p50": 410.2, "p99": 980.0}}

and both exports round-trip exactly:

* JSON:        ``snapshot_from_json(snapshot_to_json(snap)) == snap``
* Prometheus:  ``parse_prometheus(reg.to_prometheus()) == snap``

Counters are integer-valued, gauges are float-valued, histogram
``count`` is an integer and the rest floats; floats are serialised with
``repr`` so the text format loses no precision.
"""
from __future__ import annotations

import json
import re
import threading
import time
from collections import deque
from typing import Any, Callable, Iterable

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")

# Percentiles exported for histograms. Kept as (q, label) so the
# Prometheus quantile label ("0.5") and the snapshot key ("p50") stay
# in lockstep.
_HIST_QS = ((50.0, "p50"), (99.0, "p99"))


def _check_name(name: str) -> str:
    if not _NAME_RE.match(name):
        raise ValueError(
            f"metric name {name!r} must match {_NAME_RE.pattern} "
            "(underscore-separated, Prometheus-safe)")
    return name


class Counter:
    """Monotonic integer counter."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str):
        self.name = _check_name(name)
        self._value = 0
        self._lock = threading.Lock()

    def inc(self, amount: int = 1) -> None:
        amount = int(amount)
        if amount < 0:
            raise ValueError(f"counter {self.name}: negative inc {amount}")
        with self._lock:
            self._value += amount

    @property
    def value(self) -> int:
        return self._value

    def reset(self) -> None:
        with self._lock:
            self._value = 0

    def snapshot(self) -> dict:
        return {"type": "counter", "value": int(self._value)}


class Gauge:
    """Point-in-time float value, settable or callback-backed."""

    __slots__ = ("name", "_value", "_fn")

    def __init__(self, name: str, fn: Callable[[], float] | None = None):
        self.name = _check_name(name)
        self._value = 0.0
        self._fn = fn

    def set(self, value: float) -> None:
        if self._fn is not None:
            raise ValueError(f"gauge {self.name} is callback-backed")
        self._value = float(value)

    @property
    def value(self) -> float:
        if self._fn is not None:
            return float(self._fn())
        return self._value

    def snapshot(self) -> dict:
        return {"type": "gauge", "value": float(self.value)}


class Histogram:
    """Bounded-window histogram: total count/sum, percentiles over the
    retained window (computed by the serving tier's ``percentiles``
    impl — one percentile definition across the repo)."""

    __slots__ = ("name", "_window", "_count", "_sum", "_lock")

    def __init__(self, name: str, window: int = 4096):
        self.name = _check_name(name)
        self._window: deque = deque(maxlen=int(window))
        self._count = 0
        self._sum = 0.0
        self._lock = threading.Lock()

    def observe(self, value: float) -> None:
        value = float(value)
        with self._lock:
            self._window.append(value)
            self._count += 1
            self._sum += value

    @property
    def count(self) -> int:
        return self._count

    def samples(self) -> list:
        with self._lock:
            return list(self._window)

    def reset(self) -> None:
        with self._lock:
            self._window.clear()
            self._count = 0
            self._sum = 0.0

    def snapshot(self) -> dict:
        # Lazy import: serve.metrics imports numpy only; the lazy edge
        # keeps obs importable before the serve package.
        from repro.serve.metrics import percentiles
        with self._lock:
            samples = list(self._window)
            count, total = self._count, self._sum
        vals = percentiles(samples, qs=tuple(q for q, _ in _HIST_QS))
        out = {"type": "histogram", "count": int(count), "sum": float(total)}
        for _, label in _HIST_QS:
            out[label] = float(vals[label])
        return out


class MetricsRegistry:
    """Named instruments with get-or-create registration.

    ``counter``/``gauge``/``histogram`` return the existing instrument
    when the name is already registered (type mismatch is an error), so
    independent components can share counters by name alone.
    """

    def __init__(self):
        self._instruments: dict[str, Any] = {}
        self._lock = threading.Lock()

    def _get_or_create(self, name: str, cls, factory):
        with self._lock:
            inst = self._instruments.get(name)
            if inst is not None:
                if not isinstance(inst, cls):
                    raise TypeError(
                        f"metric {name!r} already registered as "
                        f"{type(inst).__name__}, not {cls.__name__}")
                return inst
            inst = factory()
            self._instruments[name] = inst
            return inst

    def counter(self, name: str) -> Counter:
        return self._get_or_create(name, Counter, lambda: Counter(name))

    def gauge(self, name: str) -> Gauge:
        return self._get_or_create(name, Gauge, lambda: Gauge(name))

    def histogram(self, name: str, window: int = 4096) -> Histogram:
        return self._get_or_create(
            name, Histogram, lambda: Histogram(name, window=window))

    def register_callback(self, name: str, fn: Callable[[], float]) -> Gauge:
        """Register a gauge whose value is read from ``fn`` at snapshot
        time (e.g. cache hit rate, current index epoch)."""
        with self._lock:
            if name in self._instruments:
                raise ValueError(f"metric {name!r} already registered")
            g = Gauge(name, fn=fn)
            self._instruments[name] = g
            return g

    def names(self) -> list[str]:
        with self._lock:
            return sorted(self._instruments)

    def get(self, name: str):
        return self._instruments.get(name)

    def reset(self) -> None:
        """Reset counters and histograms (callback gauges re-read live
        state and are left alone)."""
        with self._lock:
            insts = list(self._instruments.values())
        for inst in insts:
            if isinstance(inst, (Counter, Histogram)):
                inst.reset()

    def snapshot(self) -> dict:
        with self._lock:
            insts = sorted(self._instruments.items())
        return {name: inst.snapshot() for name, inst in insts}

    def to_prometheus(self) -> str:
        """Prometheus text exposition of ``snapshot()`` (histograms as
        summaries with quantile labels)."""
        lines = []
        for name, snap in self.snapshot().items():
            kind = snap["type"]
            if kind == "counter":
                lines.append(f"# TYPE {name} counter")
                lines.append(f"{name} {snap['value']}")
            elif kind == "gauge":
                lines.append(f"# TYPE {name} gauge")
                lines.append(f"{name} {snap['value']!r}")
            else:
                lines.append(f"# TYPE {name} summary")
                for q, label in _HIST_QS:
                    lines.append(
                        f'{name}{{quantile="{q / 100.0!r}"}} '
                        f"{snap[label]!r}")
                lines.append(f"{name}_sum {snap['sum']!r}")
                lines.append(f"{name}_count {snap['count']}")
        return "\n".join(lines) + "\n"


def snapshot_to_json(snapshot: dict) -> str:
    return json.dumps(snapshot, sort_keys=True)


def snapshot_from_json(text: str) -> dict:
    return json.loads(text)


def parse_prometheus(text: str) -> dict:
    """Parse ``to_prometheus()`` output back into the snapshot dict
    shape — the round-trip the export contract promises."""
    out: dict[str, dict] = {}
    types: dict[str, str] = {}
    label_of = {f"{q / 100.0!r}": label for q, label in _HIST_QS}
    for line in text.splitlines():
        line = line.strip()
        if not line:
            continue
        if line.startswith("#"):
            m = re.match(r"#\s*TYPE\s+(\S+)\s+(\S+)", line)
            if m:
                types[m.group(1)] = m.group(2)
            continue
        key, _, val = line.rpartition(" ")
        key = key.strip()
        m = re.match(r'^(\S+?)\{quantile="([^"]+)"\}$', key)
        if m:
            name, q = m.groups()
            out.setdefault(name, {"type": "histogram"})
            out[name][label_of.get(q, f"q{q}")] = float(val)
        elif key.endswith("_sum") and types.get(key[:-4]) == "summary":
            out.setdefault(key[:-4], {"type": "histogram"})["sum"] = float(val)
        elif key.endswith("_count") and types.get(key[:-6]) == "summary":
            out.setdefault(key[:-6], {"type": "histogram"})["count"] = \
                int(float(val))
        elif types.get(key) == "counter":
            out[key] = {"type": "counter", "value": int(float(val))}
        else:
            out[key] = {"type": "gauge", "value": float(val)}
    return out


class EventLog:
    """Bounded structured ring of maintenance events.

    Each ``emit(kind, **fields)`` stamps a monotonically increasing
    ``seq`` and a wall-clock ``t_wall``; the ring retains the last
    ``capacity`` events while per-kind counts keep the full history
    countable after eviction.
    """

    def __init__(self, capacity: int = 256):
        self._ring: deque = deque(maxlen=int(capacity))
        self._lock = threading.Lock()
        self._seq = 0
        self._counts: dict[str, int] = {}

    @property
    def capacity(self) -> int:
        return self._ring.maxlen or 0

    def resize(self, capacity: int) -> None:
        """Rebound the ring to ``capacity`` events, keeping the newest
        retained events (shrinking drops from the oldest end).  Seq and
        per-kind counts are untouched."""
        capacity = int(capacity)
        if capacity < 1:
            raise ValueError(f"event log capacity must be >= 1, "
                             f"got {capacity}")
        with self._lock:
            if capacity == self._ring.maxlen:
                return
            self._ring = deque(self._ring, maxlen=capacity)

    def emit(self, kind: str, **fields) -> dict:
        with self._lock:
            self._seq += 1
            event = {"seq": self._seq, "kind": str(kind),
                     "t_wall": time.time(), **fields}
            self._ring.append(event)
            self._counts[kind] = self._counts.get(kind, 0) + 1
        return event

    def tail(self, n: int | None = None, kind: str | None = None) -> list:
        with self._lock:
            events: Iterable[dict] = list(self._ring)
        if kind is not None:
            events = [e for e in events if e["kind"] == kind]
        else:
            events = list(events)
        if n is not None:
            events = events[-int(n):]
        return events

    def counts(self) -> dict:
        with self._lock:
            return dict(self._counts)

    @property
    def total(self) -> int:
        return self._seq

    def __len__(self) -> int:
        return len(self._ring)


#: Process-global registry for engine-level counters incremented from
#: inside jitted code via ``jax.debug.callback`` (see ``kernels.ops``).
GLOBAL = MetricsRegistry()


def global_registry() -> MetricsRegistry:
    return GLOBAL
