"""Training launcher: ``python -m repro.launch.train --arch qwen3-0.6b``.

Runs a REAL training loop (synthetic data) for any registered arch on
whatever devices exist — smoke scale by default, full scale with
--scale full on a real cluster.  Exercises the whole stack: config ->
model -> optimizer -> sharded step -> checkpoint/restart -> elastic
re-mesh.
"""
from __future__ import annotations

import argparse
import logging

import jax
import jax.numpy as jnp
import numpy as np


def make_batch_fn(arch, cfg, shp, seed: int):
    from repro.train import data as data_lib
    kind = arch.kind
    aid = arch.arch_id
    if kind == "lm":
        b, s = shp["batch"], shp["seq"]
        return lambda step: data_lib.lm_batch(seed, step, b, s, cfg.vocab)
    if kind == "gnn":
        if shp.get("graph_level"):
            return lambda step: data_lib.molecule_batch(
                seed, step, shp["n_graphs"],
                shp["n_nodes"] // shp["n_graphs"],
                shp["n_edges"] // shp["n_graphs"], cfg.d_feat,
                cfg.n_classes)
        g = data_lib.make_synthetic_graph(shp["n_nodes"], shp["n_edges"],
                                          cfg.d_feat, cfg.n_classes, seed)
        full = data_lib.fullgraph_batch(g, seed=seed)
        return lambda step: full
    if aid == "sasrec":
        return lambda step: data_lib.sasrec_batch(
            seed, step, shp["batch"], cfg.seq_len, cfg.n_items,
            cfg.n_negatives)
    if aid == "bert4rec":
        return lambda step: data_lib.bert4rec_batch(
            seed, step, shp["batch"], cfg.seq_len, cfg.n_items,
            cfg.n_negatives)
    if aid == "dien":
        return lambda step: data_lib.dien_batch(
            seed, step, shp["batch"], cfg.seq_len, cfg.n_items)
    return lambda step: data_lib.xdeepfm_batch(
        seed, step, shp["batch"], cfg.n_fields, cfg.field_vocab, cfg.n_hot)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default=None,
                    help="train shape id (default: first train shape)")
    ap.add_argument("--scale", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    from repro import configs
    from repro.models import gnn as gnn_lib
    from repro.models import recsys as rec_lib
    from repro.models import transformer as tfm
    from repro.train import loop as loop_lib
    from repro.train import optimizer as opt_lib

    arch = configs.get_arch(args.arch)
    shapes = arch.shapes if args.scale == "full" else arch.smoke_shapes
    shape_id = args.shape or next(
        (k for k, v in shapes.items()
         if v.get("step", "train") == "train" or arch.kind == "gnn"),
        list(shapes)[0])
    shp = shapes[shape_id]
    cfg = arch.make_config(args.scale, shape_id)

    key = jax.random.PRNGKey(args.seed)
    if arch.kind == "lm":
        params = tfm.init_params(key, cfg)
        loss_fn = lambda p, b: tfm.loss_fn(p, cfg, b)          # noqa: E731
    elif arch.kind == "gnn":
        params = gnn_lib.init_params(key, cfg)
        loss_fn = ((lambda p, b: gnn_lib.graph_loss(p, cfg, b))
                   if shp.get("graph_level")
                   else (lambda p, b: gnn_lib.node_loss(p, cfg, b)))
    else:
        init = {"sasrec": rec_lib.init_sasrec,
                "bert4rec": rec_lib.init_bert4rec,
                "dien": rec_lib.init_dien,
                "xdeepfm": rec_lib.init_xdeepfm}[args.arch]
        lfn = {"sasrec": rec_lib.sasrec_loss,
               "bert4rec": rec_lib.bert4rec_loss,
               "dien": rec_lib.dien_loss,
               "xdeepfm": rec_lib.xdeepfm_loss}[args.arch]
        params = init(key, cfg)
        loss_fn = lambda p, b: lfn(p, cfg, b)                  # noqa: E731

    n_params = sum(int(x.size) for x in jax.tree.leaves(params))
    print(f"arch={args.arch} shape={shape_id} scale={args.scale} "
          f"params={n_params:,} devices={len(jax.devices())}")

    ocfg = opt_lib.AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10,
                                                            1),
                               total_steps=args.steps)
    opt_state = opt_lib.init(params)
    step_fn = jax.jit(opt_lib.make_train_step(loss_fn, ocfg,
                                              args.microbatches),
                      donate_argnums=(0, 1))
    batch_fn = make_batch_fn(arch, cfg, shp, args.seed)
    to_dev = lambda b: jax.tree.map(jnp.asarray, b)            # noqa: E731

    lcfg = loop_lib.LoopConfig(total_steps=args.steps,
                               ckpt_dir=args.ckpt_dir,
                               ckpt_every=args.ckpt_every,
                               log_every=args.log_every)
    res = loop_lib.fit(step_fn, params, opt_state, batch_fn, lcfg,
                       to_device=to_dev)
    print(f"done: step={res.step} loss={float(res.metrics['loss']):.4f} "
          f"stragglers={res.stragglers} retries={res.retries}")


if __name__ == "__main__":
    main()
