import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first init.
#
# Multi-pod dry-run: lower + compile every (arch x shape) cell on the
# production meshes and record memory / cost / collective statistics.
#
#   PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
#   PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x7b \
#       --shape train_4k --mesh single
#
# Results land in experiments/dryrun/<mesh>/<arch>__<shape>.json and runs
# are RESUMABLE: existing result files are skipped unless --force.  This
# is deliverable (e): a sharding mismatch, compile-time OOM, or
# unsupported collective here is a bug in the framework.

import argparse
import json
import re
import time
import traceback

import jax
import numpy as np

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(tok_dtype: str, dims: str) -> int:
    if tok_dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[tok_dtype]


def collective_bytes(hlo_text: str) -> dict:
    """Sum RESULT-shape bytes per collective opcode (optimized HLO prints
    operands without type annotations, so we use the lhs result shape —
    equal to operand bytes for all-reduce / permute / all-to-all, and to
    the gathered size for all-gather).  NOTE: ops inside while bodies are
    counted ONCE here; benchmarks/roofline.py re-walks the saved HLO with
    while-trip multiplication for the roofline collective term."""
    out = {c: 0 for c in COLLECTIVES}
    counts = {c: 0 for c in COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        for c in COLLECTIVES:
            if f" {c}(" in stripped and "=" in stripped:
                lhs = stripped.split(f" {c}(", 1)[0]
                for m in _SHAPE_RE.finditer(lhs):
                    out[c] += _shape_bytes(m.group(1), m.group(2))
                counts[c] += 1
                break
    out_total = sum(out.values())
    return {"per_op_bytes": out, "counts": counts, "total_bytes": out_total}


def run_cell(arch_id: str, shape_id: str, mesh_kind: str,
             out_dir: str, force: bool = False) -> dict:
    from repro import configs
    from repro.launch.mesh import make_production_mesh

    path = os.path.join(out_dir, mesh_kind, f"{arch_id}__{shape_id}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    os.makedirs(os.path.dirname(path), exist_ok=True)

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    record = {"arch": arch_id, "shape": shape_id, "mesh": mesh_kind,
              "mesh_shape": dict(zip(mesh.axis_names,
                                     [int(mesh.shape[a])
                                      for a in mesh.axis_names]))}
    try:
        cell = configs.get_arch(arch_id).cell(
            shape_id, scale="full", mesh_axes=tuple(mesh.axis_names))
        record["kind"] = cell.kind
        record["meta"] = cell.meta
        shardings = cell.make_shardings(mesh)
        out_sh = (cell.make_out_shardings(mesh)
                  if cell.make_out_shardings else None)
        t0 = time.time()
        jitted = jax.jit(cell.fn, in_shardings=shardings,
                         out_shardings=out_sh,
                         donate_argnums=cell.donate)
        with mesh:
            lowered = jitted.lower(*cell.abstract_args)
            record["lower_s"] = time.time() - t0
            t1 = time.time()
            compiled = lowered.compile()
            record["compile_s"] = time.time() - t1

        mem = compiled.memory_analysis()
        record["memory"] = {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "alias_size_in_bytes",
                      "generated_code_size_in_bytes")
            if hasattr(mem, k)}
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0] if ca else {}
        record["cost"] = {k: float(v) for k, v in dict(ca).items()
                          if isinstance(v, (int, float, np.floating))
                          and k in ("flops", "bytes accessed",
                                    "transcendentals",
                                    "utilization operand 0 {}",
                                    "optimal_seconds")}
        hlo = compiled.as_text()
        record["collectives"] = collective_bytes(hlo)
        with open(path.replace(".json", ".hlo.txt"), "w") as f:
            f.write(hlo)
        record["ok"] = True
    except Exception as e:                       # noqa: BLE001
        record["ok"] = False
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    status = "OK" if record.get("ok") else "FAIL"
    flops = record.get("cost", {}).get("flops", 0)
    print(f"[{mesh_kind}] {arch_id:15s} {shape_id:14s} {status} "
          f"lower={record.get('lower_s', 0):.1f}s "
          f"compile={record.get('compile_s', 0):.1f}s "
          f"flops={flops:.3g} "
          f"coll={record.get('collectives', {}).get('total_bytes', 0):.3g}B",
          flush=True)
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    from repro import configs
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = configs.list_cells()
    else:
        assert args.arch, "--arch required unless --all"
        shapes = ([args.shape] if args.shape else
                  configs.get_arch(args.arch).shape_ids())
        cells = [(args.arch, s) for s in shapes]

    n_fail = 0
    for mesh_kind in meshes:
        for arch_id, shape_id in cells:
            rec = run_cell(arch_id, shape_id, mesh_kind, args.out,
                           force=args.force)
            n_fail += 0 if rec.get("ok") else 1
    print(f"done; failures: {n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
