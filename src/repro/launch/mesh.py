"""Production meshes.

IMPORTANT: functions, not module-level constants — importing this module
must never touch jax device state (the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` BEFORE any jax
initialization; smoke tests and benches must keep seeing 1 device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallelism: int = 1, axes=("data", "model")):
    """Small mesh over whatever devices exist (tests / elastic restart)."""
    n = len(jax.devices())
    model = min(model_parallelism, n)
    return jax.make_mesh((n // model, model), axes)


def batch_axes(mesh) -> tuple[str, ...]:
    """The axes a batch dimension shards over for this mesh."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def all_axes(mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)
