"""Partition-spec policy: (pytree, mesh, cell kind) -> PartitionSpecs.

Rules (DESIGN.md §4):
  * batch dims shard over ("pod","data");
  * tensor-model parallelism over "model": attention heads / d_ff /
    vocab / expert-ffn columns;
  * FSDP: the d_model ("embed") dimension of big weights shards over
    "data", so optimizer state is fully sharded (ZeRO) for free;
  * decode KV caches: batch over data when divisible, sequence over
    "model" (and over everything for batch=1 long-context) -> split-K
    decode attention;
  * small leaves (norms, biases, scalars) replicate.

Specs are FUNCTIONS of (tree, mesh) — never baked into checkpoints —
which is what makes elastic restart (train/elastic.py) work.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MIN_SHARD_SIZE = 1 << 14       # leaves smaller than 16Ki elems replicate


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path)


def _axis(mesh: Mesh, name: str):
    return name if name in mesh.axis_names else None


def _dp(mesh: Mesh):
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return axes if len(axes) > 1 else (axes[0] if axes else None)


def _all(mesh: Mesh):
    return tuple(mesh.axis_names)


# ---------------------------------------------------------------------------
# LM parameter specs
# ---------------------------------------------------------------------------


def lm_param_spec(path, leaf, mesh: Mesh) -> P:
    s = _path_str(path)
    nd = len(leaf.shape)
    model = _axis(mesh, "model")
    data = _axis(mesh, "data")
    if int(np.prod(leaf.shape)) < MIN_SHARD_SIZE:
        return P()
    if "embed" in s:                                   # [V, d]
        # vocab on model ONLY: sharding d on data creates an axis conflict
        # in the tied-embedding dW contraction (batch is data-sharded) and
        # GSPMD resolves it with a [B_global, chunk, V/16] f32 all-gather
        # (~20 GiB/device for qwen3).  Measured: 82 GiB -> fits after fix.
        return P(model, None)
    if "lm_head" in s:                                 # [d, V]
        return P(None, model)
    if "attn" in s:
        if "wq" in s:                                  # [L, d, Hq*hd]
            return P(None, data, model)
        if any(k in s for k in ("wk", "wv")):          # [L, d, Hkv*hd]
            # KV heads (8) don't divide the model axis (16): GSPMD then
            # splits head_dim, which breaks per-head rope/qk-norm
            # shardings and triggers "involuntary full rematerialization"
            # copies every layer.  KV projections are small -> shard over
            # data (FSDP) only, replicate over model (Megatron GQA).
            return P(None, data, None)
        if "wo" in s or "w_o" in s:                    # [L, H*hd, d]
            return P(None, model, data)
        if any(k in s for k in ("w_dq", "w_dkv", "w_kr")):
            return P(None, data, None)                 # [L, d, lora]
        if any(k in s for k in ("w_uq", "w_ukv")):     # [L, lora, H*x]
            return P(None, None, model)
        return P()                                     # norms/gammas
    if "mlp" in s:
        if "router" in s:                              # [L, d, E]
            return P(None, data, None)
        if "w_down" in s:
            if nd == 4:                                # moe [L, E, ff, d]
                return P(None, None, model, data)
            return P(None, model, data)                # [L, ff, d]
        if any(k in s for k in ("w_gate", "w_up")):
            if nd == 4:                                # moe [L, E, d, ff]
                return P(None, None, data, model)
            return P(None, data, model)                # [L, d, ff]
    return P()


# ---------------------------------------------------------------------------
# other param families
# ---------------------------------------------------------------------------


def gnn_param_spec(path, leaf, mesh: Mesh) -> P:
    return P()     # PNA params are tiny; replicate


def recsys_param_spec(path, leaf, mesh: Mesh) -> P:
    """Embedding tables shard rows over "model" ONLY: replicating the
    16-way slice over data costs ~16 MB/device, and batch-sharded
    lookups/dots against a model-sharded table stay local w.r.t. the
    data axis (vs all-reduces over all 256/512 devices when tables are
    sharded over every axis — measured on bert4rec serve_bulk)."""
    s = _path_str(path)
    model = _axis(mesh, "model")
    if int(np.prod(leaf.shape)) < MIN_SHARD_SIZE:
        return P()
    if any(k in s for k in ("item_emb", "tables", "linear")):
        return P(model) if len(leaf.shape) == 1 \
            else P(model, *([None] * (len(leaf.shape) - 1)))
    return P()


def recsys_serve_param_spec(path, leaf, mesh: Mesh) -> P:
    """Serving replicates the tables outright (bert4rec's 1M x 64 table
    is 256 MB — trivial per device) so lookups and candidate dots are
    fully local; the 800 MiB gather-psum of the sharded-table path
    disappears.  Training keeps the sharded spec (grad memory)."""
    return P()


def lm_small_param_spec(path, leaf, mesh: Mesh) -> P:
    """Small-model policy (< ~2B params): NO tensor parallelism.

    TP=16 on a 0.6B model is collective-bound by 2 orders of magnitude
    (per-layer activation all-reduces ~ 178 GiB wire/step measured on
    qwen3 train_4k).  Instead BOTH non-pod axes act as FSDP/data
    parallelism: weights shard their d_model dim over ("data","model"),
    the batch shards over ("data","model"), grads reduce-scatter.  The
    only per-step collectives left are the FSDP weight gathers and grad
    reductions — O(params), not O(activations x layers).
    """
    s = _path_str(path)
    fsdp = tuple(a for a in ("data", "model") if a in mesh.axis_names)
    fsdp = fsdp if len(fsdp) > 1 else (fsdp[0] if fsdp else None)
    n = int(np.prod([mesh.shape[a] for a in
                     (fsdp if isinstance(fsdp, tuple) else (fsdp,))]))         if fsdp else 1
    if int(np.prod(leaf.shape)) < MIN_SHARD_SIZE:
        return P()
    if "embed" in s:
        return P(fsdp, None) if leaf.shape[0] % n == 0 else P()
    if "lm_head" in s:
        return P(fsdp, None) if leaf.shape[0] % n == 0 else P()
    # stacked layer weights [L, a, b]: shard the first divisible inner dim
    spec = [None] * len(leaf.shape)
    for i in range(1, len(leaf.shape)):
        if leaf.shape[i] % n == 0 and leaf.shape[i] >= n:
            spec[i] = fsdp
            return P(*spec)
    return P()


def lm_small_batch_spec(path, leaf, mesh: Mesh) -> P:
    fsdp = tuple(a for a in ("data", "model") if a in mesh.axis_names)
    n = int(np.prod([mesh.shape[a] for a in fsdp]))
    if leaf.shape and leaf.shape[0] % n == 0 and leaf.shape[0] >= n:
        return P(fsdp, *([None] * (len(leaf.shape) - 1)))
    return batch_spec(path, leaf, mesh)


PARAM_SPEC_FNS = {"lm": lm_param_spec, "gnn": gnn_param_spec,
                  "recsys": recsys_param_spec}


# ---------------------------------------------------------------------------
# batch / cache specs
# ---------------------------------------------------------------------------


def batch_spec(path, leaf, mesh: Mesh) -> P:
    """Shard leading (batch) dim over DP axes when divisible."""
    dp = _dp(mesh)
    if dp is None or not leaf.shape:
        return P()
    n_dp = int(np.prod([mesh.shape[a] for a in
                        (dp if isinstance(dp, tuple) else (dp,))]))
    # GSPMD pads uneven shards, so only a dim smaller than the axis stays
    # replicated (e.g. batch=1 long-context decode).
    if leaf.shape[0] >= n_dp:
        return P(dp, *([None] * (len(leaf.shape) - 1)))
    return P()


def gnn_batch_spec(path, leaf, mesh: Mesh) -> P:
    """Nodes/edges shard over ALL axes: a GNN has no tensor-parallel
    dimension, so leaving "model" idle wastes 16x memory/compute."""
    axes = _all(mesh)
    n_ax = int(np.prod([mesh.shape[a] for a in axes]))
    if leaf.shape and leaf.shape[0] % n_ax == 0 and leaf.shape[0] >= n_ax:
        return P(axes, *([None] * (len(leaf.shape) - 1)))
    return batch_spec(path, leaf, mesh)


def kv_cache_spec(leaf_shape: tuple, mesh: Mesh, batch_idx: int = 1,
                  seq_idx: int = 3) -> P:
    """GQA cache [L,B,Hkv,S,hd] or MLA cache [L,B,S,c] (seq_idx=2)."""
    dp = _dp(mesh)
    model = _axis(mesh, "model")
    n_dp = int(np.prod([mesh.shape[a] for a in
                        (dp if isinstance(dp, tuple) else (dp,))])) \
        if dp else 1
    spec = [None] * len(leaf_shape)
    b = leaf_shape[batch_idx]
    if dp and b % n_dp == 0 and b >= n_dp:
        spec[batch_idx] = dp
        spec[seq_idx] = model
    else:
        # batch too small (long-context): shard the SEQUENCE over
        # everything -> distributed split-K decode attention.
        spec[seq_idx] = tuple(mesh.axis_names)
    return P(*spec)


def cache_specs(cache_shapes: Any, mesh: Mesh, mla: bool) -> Any:
    def one(leaf):
        if mla:
            return kv_cache_spec(leaf.shape, mesh, batch_idx=1, seq_idx=2)
        return kv_cache_spec(leaf.shape, mesh, batch_idx=1, seq_idx=3)
    return jax.tree.map(one, cache_shapes)


# ---------------------------------------------------------------------------
# top level: build NamedSharding pytrees
# ---------------------------------------------------------------------------


def named(tree: Any, mesh: Mesh, spec_fn) -> Any:
    def one(path, leaf):
        return NamedSharding(mesh, spec_fn(path, leaf, mesh))
    return jax.tree_util.tree_map_with_path(one, tree)


def named_from_specs(spec_tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda sp: NamedSharding(mesh, sp), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
