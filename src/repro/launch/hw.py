"""Target-hardware constants (TPU v5e-class) for the roofline model."""

PEAK_FLOPS_BF16 = 197e12       # FLOP/s per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link (~per-axis usable)
HBM_PER_CHIP = 16 * 2**30      # bytes
VMEM_PER_CORE = 128 * 2**20    # ~VMEM budget used for BlockSpec sizing

CHIPS_PER_POD = 256            # 16 x 16 single-pod mesh
PODS = 2
