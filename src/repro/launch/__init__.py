from repro.launch import hw, mesh, sharding  # noqa: F401
