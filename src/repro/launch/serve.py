"""Serving launcher: batched retrieval over the paper's index layouts.

``python -m repro.launch.serve --repr hor --docs 5000 --queries 64``

Builds a synthetic corpus, constructs the chosen index representation,
and serves batched queries through the jit scorer (optionally the
document-sharded distributed engine with --shards N on a host mesh).
Reports throughput and a latency histogram — the q_word/q_occ/q_doc
pipeline of paper §3.7 end to end.
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--repr", default="hor",
                    choices=["pr", "or", "cor", "hor", "packed"])
    ap.add_argument("--docs", type=int, default=5000)
    ap.add_argument("--vocab", type=int, default=8000)
    ap.add_argument("--avg-terms", type=int, default=60)
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--terms", type=int, default=3)
    ap.add_argument("--topk", type=int, default=10)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--shards", type=int, default=0,
                    help=">0: document-sharded engine over a host mesh")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro.core import build, layouts, query
    from repro.text import corpus

    t0 = time.time()
    tc = corpus.generate(corpus.CorpusSpec(
        num_docs=args.docs, vocab=args.vocab, avg_distinct=args.avg_terms,
        seed=args.seed))
    host = build.bulk_build(tc)
    print(f"corpus: D={host.num_docs} W={host.num_terms} "
          f"P={host.num_postings} build={time.time() - t0:.2f}s")

    qh = corpus.sample_query_terms(host.df, host.term_hashes, args.queries,
                                   args.terms, num_docs=host.num_docs,
                                   seed=args.seed + 1)

    if args.shards > 0:
        from repro.distributed import retrieval as dist_ret
        mesh = jax.make_mesh((args.shards,), ("data",))
        ds = dist_ret.build_doc_sharded(host, args.shards)
        scorer1 = dist_ret.make_doc_sharded_scorer(ds, mesh, "data",
                                                   k=args.topk)
        scorer = jax.jit(jax.vmap(scorer1))
        print(f"engine: doc-sharded x{args.shards}")
    else:
        builder = layouts.REPRESENTATIONS[args.repr]
        index = builder(host)
        print(f"engine: {args.repr} index={index.nbytes() / 1e6:.1f} MB")
        cap = max(host.max_posting_len, 1)
        scorer = query.make_scorer(index, k=args.topk, cap=cap)

    lat = []
    hits = 0
    for i in range(0, args.queries, args.batch):
        qb = jnp.asarray(qh[i:i + args.batch])
        t0 = time.time()
        res = scorer(qb)
        jax.tree.map(lambda x: x.block_until_ready(), res)
        lat.append((time.time() - t0) / qb.shape[0])
        ids = np.asarray(res[0] if isinstance(res, tuple) else res.doc_ids)
        hits += int((ids >= 0).any(axis=-1).sum())
    lat_us = np.array(lat[1:] or lat) * 1e6
    print(f"served {args.queries} queries; {hits} with hits; "
          f"p50={np.percentile(lat_us, 50):.0f}us "
          f"p99={np.percentile(lat_us, 99):.0f}us per query "
          f"(steady-state, batch={args.batch})")


if __name__ == "__main__":
    main()
