"""xdeepfm [arXiv:1803.05170; paper]: 39 sparse fields, embed_dim=10,
CIN 200-200-200, DNN 400-400; 1M rows per field (EmbeddingBag path)."""
from repro.configs.base import ArchDef
from repro.models import recsys

SHAPES = {
    "train_batch":    {"step": "train", "batch": 65536},
    "serve_p99":      {"step": "serve", "batch": 512},
    "serve_bulk":     {"step": "serve", "batch": 262144},
    "retrieval_cand": {"step": "retrieval", "batch": 1,
                       "n_candidates": 1_000_000},
}
SMOKE_SHAPES = {
    "train_batch":    {"step": "train", "batch": 16},
    "serve_p99":      {"step": "serve", "batch": 8},
    "serve_bulk":     {"step": "serve", "batch": 32},
    "retrieval_cand": {"step": "retrieval", "batch": 1,
                       "n_candidates": 512},
}


def make_config(scale: str, shape_id: str | None = None):
    if scale == "full":
        return recsys.XDeepFmConfig(n_fields=39, field_vocab=1_000_000,
                                    embed_dim=10,
                                    cin_layers=(200, 200, 200),
                                    mlp_dims=(400, 400))
    return recsys.XDeepFmConfig(n_fields=6, field_vocab=100, embed_dim=8,
                                cin_layers=(12, 12), mlp_dims=(16, 8))


ARCH = ArchDef("xdeepfm", "recsys", make_config, SHAPES, SMOKE_SHAPES,
               source="arXiv:1803.05170")
