"""Config/registry machinery: ArchDef + dry-run Cell builders.

A **Cell** = (architecture x input shape) -> one concrete jit-able step:
  train_*     -> full train step (fwd + bwd + AdamW update)
  prefill_*   -> prefill (logits + KV cache)
  decode_*/long_* -> one decode step against a seq_len cache
  serve_*     -> batched scoring
  retrieval_* -> two-tower candidate scoring + top-k

Cells carry abstract (ShapeDtypeStruct) args and a sharding builder, so
the multi-pod dry-run can ``jit(...).lower(...).compile()`` every cell
without allocating anything.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import sharding as shard_lib
from repro.models import gnn as gnn_lib
from repro.models import recsys as rec_lib
from repro.models import transformer as tfm
from repro.train import optimizer as opt_lib


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


@dataclasses.dataclass(frozen=True)
class Cell:
    arch_id: str
    shape_id: str
    kind: str
    fn: Callable
    abstract_args: tuple
    donate: tuple
    make_shardings: Callable            # mesh -> tuple matching args
    meta: dict
    make_out_shardings: Callable | None = None   # mesh -> out tree or None


@dataclasses.dataclass(frozen=True)
class ArchDef:
    arch_id: str
    kind: str                           # "lm" | "gnn" | "recsys"
    make_config: Callable               # (scale, shape_id) -> model config
    shapes: dict
    smoke_shapes: dict
    source: str = ""                    # provenance tag

    def shape_ids(self):
        return list(self.shapes)

    def cell(self, shape_id: str, scale: str = "full",
             mesh_axes: tuple = ()) -> Cell:
        """``mesh_axes``: axis names of the target mesh; enables GSPMD
        activation-sharding annotations in the model (dry-run path)."""
        shp = (self.shapes if scale == "full" else
               self.smoke_shapes)[shape_id]
        cfg = self.make_config(scale, shape_id)
        if self.kind == "lm":
            if mesh_axes:
                batch_axes = tuple(a for a in ("pod", "data")
                                   if a in mesh_axes)
                cfg = dataclasses.replace(
                    cfg, batch_axes=batch_axes,
                    tp_axis="model" if "model" in mesh_axes else "")
                if cfg.moe is not None:
                    # dispatch groups == dp shards (16 or 32); decode
                    # steps route only `batch` tokens
                    dp = 16 * (2 if "pod" in mesh_axes else 1)
                    tokens = shp["batch"] * (
                        shp["seq"] if shp["step"] in ("train", "prefill")
                        else 1)
                    if tokens % dp == 0:
                        cfg = dataclasses.replace(
                            cfg, moe=dataclasses.replace(cfg.moe,
                                                         groups=dp))
            return _lm_cell(self.arch_id, cfg, shape_id, shp)
        if self.kind == "gnn":
            return _gnn_cell(self.arch_id, cfg, shape_id, shp)
        if mesh_axes:
            cfg = dataclasses.replace(
                cfg,
                batch_axes=tuple(a for a in ("pod", "data")
                                 if a in mesh_axes),
                tp_axis="model" if "model" in mesh_axes else "")
        return _recsys_cell(self.arch_id, cfg, shape_id, shp)


OPT_CFG = opt_lib.AdamWConfig(lr=3e-4, warmup_steps=100, total_steps=10_000)


def _abstract(fn, *args):
    return jax.eval_shape(fn, *args)


def _params_abstract(init_fn):
    return jax.eval_shape(lambda: init_fn(jax.random.PRNGKey(0)))


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------


def lm_active_params(p_abs, cfg: tfm.TransformerConfig) -> int:
    """Active (per-token) parameter count — MoE counts top_k/E experts."""
    total = 0
    flat = jax.tree_util.tree_flatten_with_path(p_abs)[0]
    for path, leaf in flat:
        s = shard_lib._path_str(path)
        n = int(np.prod(leaf.shape))
        if cfg.moe is not None and "mlp" in s and "router" not in s:
            n = int(n * cfg.moe.top_k / cfg.moe.n_experts)
        total += n
    return total


def _bf16_abstract(tree):
    """Serving reads bf16 weights (args + HBM traffic halve)."""
    return jax.tree.map(
        lambda x: sds(x.shape, jnp.bfloat16)
        if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)


def _lm_cell(arch_id: str, cfg: tfm.TransformerConfig, shape_id: str,
             shp: dict) -> Cell:
    p_abs = _params_abstract(lambda k: tfm.init_params(k, cfg))
    n_active = lm_active_params(p_abs, cfg)
    n_total = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(p_abs))
    b, s = shp["batch"], shp["seq"]
    if shp["step"] in ("prefill", "decode"):
        p_abs = _bf16_abstract(p_abs)

    if shp["step"] == "train":
        # parallelism policy: models under ~2B params don't use tensor
        # parallelism — both non-pod axes become FSDP/data (see
        # launch/sharding.lm_small_param_spec).
        small = n_total < 2_000_000_000
        if small and cfg.batch_axes:
            cfg = dataclasses.replace(cfg, tp_axis="",
                                      batch_axes=("data", "model"))
        opt_abs = _abstract(opt_lib.init, p_abs)
        batch_abs = {"tokens": sds((b, s), jnp.int32),
                     "labels": sds((b, s), jnp.int32)}
        step = opt_lib.make_train_step(
            lambda p, bb: tfm.loss_fn(p, cfg, bb), OPT_CFG,
            microbatches=shp.get("microbatches", 1))
        pspec = (shard_lib.lm_small_param_spec if small
                 else shard_lib.lm_param_spec)
        bspec = (shard_lib.lm_small_batch_spec if small
                 else shard_lib.batch_spec)

        def mk_sh(mesh):
            psh = shard_lib.named(p_abs, mesh, pspec)
            osh = shard_lib.named(opt_abs, mesh, pspec)
            bsh = shard_lib.named(batch_abs, mesh, bspec)
            return (psh, osh, bsh)

        return Cell(arch_id, shape_id, "train", step,
                    (p_abs, opt_abs, batch_abs), (0, 1), mk_sh,
                    {"model_flops": 6.0 * n_active * b * s,
                     "n_params": n_total, "n_active": n_active,
                     "tokens": b * s})

    if shp["step"] == "prefill":
        tokens_abs = sds((b, s), jnp.int32)
        fn = functools.partial(_lm_prefill, cfg)
        # out_abs via a constraint-free twin: eval_shape runs without a
        # mesh context and with_sharding_constraint would reject specs.
        cfg_plain = dataclasses.replace(cfg, batch_axes=(), tp_axis="")
        out_abs = _abstract(functools.partial(_lm_prefill, cfg_plain),
                            p_abs, tokens_abs)

        def mk_sh(mesh):
            psh = shard_lib.named(p_abs, mesh, shard_lib.lm_param_spec)
            tsh = shard_lib.named(tokens_abs, mesh, shard_lib.batch_spec)
            return (psh, tsh)

        def mk_out(mesh):
            # the prefill KV cache [L,B,H,S,hd] (or MLA [L,B,S,c]) must
            # leave the step sequence-sharded over "model" — without an
            # out_sharding it materializes unsharded (15+ GiB/device).
            def one(path, leaf):
                if len(leaf.shape) >= 4:     # a cache leaf
                    return shard_lib.named_from_specs(
                        shard_lib.kv_cache_spec(
                            leaf.shape, mesh, batch_idx=1,
                            seq_idx=2 if cfg.attn == "mla" else 3), mesh)
                return shard_lib.named_from_specs(
                    shard_lib.batch_spec(path, leaf, mesh), mesh)
            return jax.tree_util.tree_map_with_path(one, out_abs)

        return Cell(arch_id, shape_id, "prefill", fn, (p_abs, tokens_abs),
                    (), mk_sh,
                    {"model_flops": 2.0 * n_active * b * s,
                     "n_params": n_total, "n_active": n_active,
                     "tokens": b * s}, mk_out)

    # decode (decode_32k / long_500k): one token against a seq-len cache
    cache_abs = _abstract(lambda: tfm.init_cache(cfg, b, s))
    tokens_abs = sds((b, 1), jnp.int32)
    clen_abs = sds((b,), jnp.int32)
    fn = functools.partial(_lm_decode, cfg)

    def mk_sh(mesh):
        psh = shard_lib.named(p_abs, mesh, shard_lib.lm_param_spec)
        csh = jax.tree.map(
            lambda l: shard_lib.named_from_specs(
                shard_lib.kv_cache_spec(
                    l.shape, mesh, batch_idx=1,
                    seq_idx=2 if cfg.attn == "mla" else 3), mesh),
            cache_abs)
        tsh = shard_lib.named(tokens_abs, mesh, shard_lib.batch_spec)
        lsh = shard_lib.named(clen_abs, mesh, shard_lib.batch_spec)
        return (psh, csh, tsh, lsh)

    cache_bytes = sum(int(np.prod(x.shape)) * x.dtype.itemsize
                      for x in jax.tree.leaves(cache_abs))
    return Cell(arch_id, shape_id, "decode", fn,
                (p_abs, cache_abs, tokens_abs, clen_abs), (1,), mk_sh,
                {"model_flops": 2.0 * n_active * b,
                 "n_params": n_total, "n_active": n_active, "tokens": b,
                 "cache_bytes": cache_bytes})


def _lm_prefill(cfg, params, tokens):
    return tfm.prefill(params, cfg, tokens)


def _lm_decode(cfg, params, cache, tokens, cache_len):
    return tfm.decode_step(params, cfg, cache, tokens, cache_len)


# ---------------------------------------------------------------------------
# GNN cells (all four shapes are training steps)
# ---------------------------------------------------------------------------


def _gnn_cell(arch_id: str, cfg: gnn_lib.PnaConfig, shape_id: str,
              shp: dict) -> Cell:
    p_abs = _params_abstract(lambda k: gnn_lib.init_params(k, cfg))
    n_total = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(p_abs))
    opt_abs = _abstract(opt_lib.init, p_abs)
    n, e = shp["n_nodes"], shp["n_edges"]
    if shp.get("graph_level"):
        batch_abs = {"feats": sds((n, cfg.d_feat), jnp.float32),
                     "src": sds((e,), jnp.int32),
                     "dst": sds((e,), jnp.int32),
                     "graph_ids": sds((n,), jnp.int32),
                     "g_labels": sds((shp["n_graphs"],), jnp.int32)}
        loss = lambda p, bb: gnn_lib.graph_loss(p, cfg, bb)   # noqa: E731
    else:
        batch_abs = {"feats": sds((n, cfg.d_feat), jnp.float32),
                     "src": sds((e,), jnp.int32),
                     "dst": sds((e,), jnp.int32),
                     "labels": sds((n,), jnp.int32),
                     "mask": sds((n,), jnp.bool_)}
        loss = lambda p, bb: gnn_lib.node_loss(p, cfg, bb)    # noqa: E731
    step = opt_lib.make_train_step(loss, OPT_CFG)

    def mk_sh(mesh):
        psh = shard_lib.named(p_abs, mesh, shard_lib.gnn_param_spec)
        osh = shard_lib.named(opt_abs, mesh, shard_lib.gnn_param_spec)
        bsh = shard_lib.named(batch_abs, mesh, shard_lib.gnn_batch_spec)
        return (psh, osh, bsh)

    # message-passing flops: ~ E * (2d*d pretrans) + N * posttrans
    d = cfg.d_hidden
    mp_flops = cfg.n_layers * (2 * e * 2 * d * d +
                               2 * n * (13 * d) * d) * 3   # fwd+bwd
    return Cell(arch_id, shape_id, "train", step,
                (p_abs, opt_abs, batch_abs), (0, 1), mk_sh,
                {"model_flops": float(mp_flops), "n_params": n_total,
                 "tokens": n})


# ---------------------------------------------------------------------------
# recsys cells
# ---------------------------------------------------------------------------


_REC_INIT = {
    "sasrec": rec_lib.init_sasrec,
    "bert4rec": rec_lib.init_bert4rec,
    "dien": rec_lib.init_dien,
    "xdeepfm": rec_lib.init_xdeepfm,
}
_REC_LOSS = {
    "sasrec": rec_lib.sasrec_loss,
    "bert4rec": rec_lib.bert4rec_loss,
    "dien": rec_lib.dien_loss,
    "xdeepfm": rec_lib.xdeepfm_loss,
}
_REC_USER = {
    "sasrec": rec_lib.sasrec_user_vec,
    "bert4rec": rec_lib.bert4rec_user_vec,
    "dien": rec_lib.dien_user_vec,
    "xdeepfm": rec_lib.xdeepfm_user_vec,
}


def _rec_batch_abs(arch: str, cfg, b: int) -> dict:
    i32 = jnp.int32
    if arch == "sasrec":
        s = cfg.seq_len
        return {"hist": sds((b, s), i32), "pos": sds((b, s), i32),
                "neg": sds((b, s, cfg.n_negatives), i32)}
    if arch == "bert4rec":
        s = cfg.seq_len
        return {"hist": sds((b, s), i32), "targets": sds((b, s), i32),
                "neg": sds((b, s, cfg.n_negatives), i32)}
    if arch == "dien":
        s = cfg.seq_len
        return {"hist": sds((b, s), i32), "target": sds((b,), i32),
                "label": sds((b,), jnp.float32),
                "aux_neg": sds((b, s), i32)}
    s = cfg.n_fields
    shape = (b, s) if cfg.n_hot == 1 else (b, s, cfg.n_hot)
    return {"sparse": sds(shape, i32), "label": sds((b,), jnp.float32)}


def _rec_serve_inputs(arch: str, cfg, b: int) -> dict:
    i32 = jnp.int32
    if arch in ("sasrec", "bert4rec"):
        return {"hist": sds((b, cfg.seq_len), i32)}
    if arch == "dien":
        return {"hist": sds((b, cfg.seq_len), i32),
                "target": sds((b,), i32)}
    shape = (b, cfg.n_fields) if cfg.n_hot == 1 else \
        (b, cfg.n_fields, cfg.n_hot)
    return {"sparse": sds(shape, i32)}


def _rec_embed_dim(arch: str, cfg) -> int:
    return cfg.embed_dim


def _recsys_cell(arch_id: str, cfg, shape_id: str, shp: dict) -> Cell:
    arch = arch_id.split("-")[0] if "-" in arch_id else arch_id
    init_fn = _REC_INIT[arch]
    p_abs = _params_abstract(lambda k: init_fn(k, cfg))
    n_total = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(p_abs))
    b = shp["batch"]

    if shp["step"] == "train":
        opt_abs = _abstract(opt_lib.init, p_abs)
        batch_abs = _rec_batch_abs(arch, cfg, b)
        loss_fn = _REC_LOSS[arch]
        step = opt_lib.make_train_step(
            lambda p, bb: loss_fn(p, cfg, bb), OPT_CFG)

        def mk_sh(mesh):
            return (shard_lib.named(p_abs, mesh,
                                    shard_lib.recsys_param_spec),
                    shard_lib.named(opt_abs, mesh,
                                    shard_lib.recsys_param_spec),
                    shard_lib.named(batch_abs, mesh, shard_lib.batch_spec))

        # dense tower flops dominate; embedding gathers dominate bytes
        return Cell(arch_id, shape_id, "train", step,
                    (p_abs, opt_abs, batch_abs), (0, 1), mk_sh,
                    {"model_flops": 6.0 * _rec_dense_params(arch, cfg) * b,
                     "n_params": n_total, "tokens": b})

    if shp["step"] == "serve":
        # Big offline batches stream through the encoder tower in user
        # chunks (bert4rec's dense 200x200 attention at 16k users/device
        # was ~10 GiB of temps).  The chunk structure is explicit in the
        # INPUT LAYOUT — [n_chunks, uchunk, ...] with uchunk data-sharded
        # and the scanned chunk dim unsharded — because dynamic-slicing a
        # sharded batch dim makes GSPMD all-gather it (200 MiB/step
        # measured).  Serving params are REPLICATED (the 1M x 64 table is
        # 256 MB) so lookups and candidate dots are local.
        uchunk = shp.get("user_chunk", 2048)
        n_chunks = b // uchunk if (b % uchunk == 0 and b > uchunk) else 1
        ueff = b // n_chunks
        flat_abs = _rec_serve_inputs(arch, cfg, b)
        inp_abs = jax.tree.map(
            lambda x: sds((n_chunks, ueff) + x.shape[1:], x.dtype),
            flat_abs)

        def make_fn(c):
            if arch in ("sasrec", "bert4rec"):
                user_fn = _REC_USER[arch]

                def one(params, sl):
                    return rec_lib.retrieval_topk(
                        user_fn(params, c, sl["hist"]),
                        params["item_emb"], k=shp.get("topk", 100),
                        batch_axes=c.batch_axes, tp_axis="")
            elif arch == "dien":
                def one(params, sl):
                    return rec_lib.dien_forward(params, c, sl["hist"],
                                                sl["target"])[0]
            else:
                def one(params, sl):
                    return rec_lib.xdeepfm_logit(params, c, sl["sparse"])

            def fn(params, inp):
                if n_chunks == 1:
                    return one(params, jax.tree.map(lambda x: x[0], inp))
                return jax.lax.map(lambda sl: one(params, sl), inp)
            return fn

        fn = make_fn(cfg)
        # out_abs via a constraint-free twin (eval_shape has no mesh)
        cfg_plain = dataclasses.replace(cfg, batch_axes=(), tp_axis="")
        out_abs = _abstract(make_fn(cfg_plain), p_abs, inp_abs)

        def _chunk_spec(path, leaf, mesh):
            from jax.sharding import PartitionSpec as P
            dp = shard_lib._dp(mesh)
            return P(None, dp, *([None] * (len(leaf.shape) - 2)))

        def mk_sh(mesh):
            return (shard_lib.named(p_abs, mesh,
                                    shard_lib.recsys_serve_param_spec),
                    shard_lib.named(inp_abs, mesh, _chunk_spec))

        def mk_out(mesh):
            return jax.tree.map(
                lambda x: shard_lib.named_from_specs(
                    _chunk_spec(None, x, mesh)
                    if len(x.shape) >= 2 and n_chunks > 1
                    else shard_lib.batch_spec(None, x, mesh), mesh),
                out_abs)

        retrieval_flops = (2.0 * b * rec_lib.padded_rows(cfg.n_items) *
                           cfg.embed_dim
                           if arch in ("sasrec", "bert4rec") else 0.0)
        return Cell(arch_id, shape_id, "serve", fn, (p_abs, inp_abs), (),
                    mk_sh,
                    {"model_flops": 2.0 * _rec_dense_params(arch, cfg) * b
                     + retrieval_flops,
                     "n_params": n_total, "tokens": b}, mk_out)

    # retrieval_cand: one query vs n_candidates (batched dot + top-k)
    n_cand = rec_lib.padded_rows(shp["n_candidates"])
    d = _rec_embed_dim(arch, cfg)
    inp_abs = _rec_serve_inputs(arch, cfg, b)
    cand_abs = sds((n_cand, d), jnp.float32)
    user_fn = _REC_USER[arch]

    def fn(params, inp, cand):
        first = next(iter(inp.values()))
        uv = user_fn(params, cfg, first) if arch in ("sasrec", "bert4rec") \
            else (rec_lib.dien_user_vec(params, cfg, inp["hist"])
                  if arch == "dien"
                  else rec_lib.xdeepfm_user_vec(params, cfg, inp["sparse"]))
        return rec_lib.retrieval_topk(uv, cand, k=shp.get("topk", 100),
                                      batch_axes=cfg.batch_axes,
                                      tp_axis=cfg.tp_axis)

    def mk_sh(mesh):
        from jax.sharding import NamedSharding, PartitionSpec as P
        model = "model" if "model" in mesh.axis_names else None
        return (shard_lib.named(p_abs, mesh,
                                shard_lib.recsys_serve_param_spec),
                shard_lib.named(inp_abs, mesh, shard_lib.batch_spec),
                NamedSharding(mesh, P(model, None)))

    return Cell(arch_id, shape_id, "retrieval", fn,
                (p_abs, inp_abs, cand_abs), (), mk_sh,
                {"model_flops": 2.0 * n_cand * d * b,
                 "n_params": n_total, "tokens": b * n_cand})


def _rec_dense_params(arch: str, cfg) -> int:
    """Parameters touched per example (excludes embedding tables)."""
    if arch == "sasrec":
        return cfg.n_blocks * 6 * cfg.embed_dim ** 2 + \
            cfg.seq_len * cfg.embed_dim
    if arch == "bert4rec":
        return cfg.n_blocks * 6 * cfg.embed_dim ** 2 + \
            cfg.seq_len * cfg.embed_dim
    if arch == "dien":
        g, d = cfg.gru_dim, cfg.embed_dim
        m = (g + 2 * d) * cfg.mlp_dims[0] + \
            cfg.mlp_dims[0] * cfg.mlp_dims[1] + cfg.mlp_dims[1]
        return 2 * 3 * (d * g + g * g) * cfg.seq_len // max(cfg.seq_len, 1) \
            * cfg.seq_len + m
    # xdeepfm: CIN + DNN
    f, d = cfg.n_fields, cfg.embed_dim
    h_prev, cin = f, 0
    for hk in cfg.cin_layers:
        cin += h_prev * f * hk * d
        h_prev = hk
    dnn = f * d * cfg.mlp_dims[0] + cfg.mlp_dims[0] * cfg.mlp_dims[1]
    return cin // max(d, 1) + dnn
