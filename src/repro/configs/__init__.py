"""Architecture registry: the 10 assigned archs + the paper's own index."""
from repro.configs import (bert4rec, dien, gemma3_4b, minicpm3_4b,
                           mixtral_8x22b, mixtral_8x7b, pna, qwen3_0p6b,
                           sasrec, xdeepfm)
from repro.configs.base import ArchDef, Cell  # noqa: F401

ARCHS = {m.ARCH.arch_id: m.ARCH for m in (
    gemma3_4b, minicpm3_4b, qwen3_0p6b, mixtral_8x7b, mixtral_8x22b,
    pna, sasrec, bert4rec, dien, xdeepfm)}


def get_arch(arch_id: str) -> ArchDef:
    return ARCHS[arch_id]


def list_cells():
    """All 40 (arch x shape) dry-run cells."""
    return [(a, s) for a, arch in ARCHS.items() for s in arch.shape_ids()]
