"""qwen3-0.6b [hf:Qwen/Qwen3-0.6B; hf]: 28L d=1024 16H (GQA kv=8)
head_dim=128 d_ff=3072 vocab=151936; qk-norm; rope theta 1M."""
from repro.configs.base import ArchDef
from repro.models import transformer as tfm

SHAPES = {
    "train_4k":    {"step": "train",   "batch": 256, "seq": 4096},
    "prefill_32k": {"step": "prefill", "batch": 32,  "seq": 32768},
    "decode_32k":  {"step": "decode",  "batch": 128, "seq": 32768},
    "long_500k":   {"step": "decode",  "batch": 1,   "seq": 524288},
}
SMOKE_SHAPES = {
    "train_4k":    {"step": "train",   "batch": 2, "seq": 32},
    "prefill_32k": {"step": "prefill", "batch": 2, "seq": 32},
    "decode_32k":  {"step": "decode",  "batch": 2, "seq": 64},
    "long_500k":   {"step": "decode",  "batch": 1, "seq": 64},
}


def make_config(scale: str, shape_id: str | None = None):
    if scale == "full":
        return tfm.TransformerConfig(
            name="qwen3-0.6b", n_layers=28, d_model=1024, n_heads=16,
            n_kv_heads=8, head_dim=128, d_ff=3072, vocab=152064,  # 151936 padded to 512-lane multiple
            qk_norm=True, rope_base=1_000_000.0, tie_embeddings=True)
    return tfm.TransformerConfig(
        name="qwen3-0.6b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab=512, qk_norm=True,
        rope_base=1_000_000.0, tie_embeddings=True, chunk_q=16,
        loss_chunk=16)


ARCH = ArchDef("qwen3-0.6b", "lm", make_config, SHAPES, SMOKE_SHAPES,
               source="hf:Qwen/Qwen3-0.6B")
