"""bert4rec [arXiv:1904.06690; paper]: embed_dim=64, 2 blocks, 2 heads,
seq_len=200, bidirectional encoder + cloze objective; 1M-item table."""
from repro.configs.base import ArchDef
from repro.models import recsys

SHAPES = {
    "train_batch":    {"step": "train", "batch": 65536},
    "serve_p99":      {"step": "serve", "batch": 512},
    "serve_bulk":     {"step": "serve", "batch": 262144},
    "retrieval_cand": {"step": "retrieval", "batch": 1,
                       "n_candidates": 1_000_000},
}
SMOKE_SHAPES = {
    "train_batch":    {"step": "train", "batch": 16},
    "serve_p99":      {"step": "serve", "batch": 8},
    "serve_bulk":     {"step": "serve", "batch": 32},
    "retrieval_cand": {"step": "retrieval", "batch": 1,
                       "n_candidates": 512},
}


def make_config(scale: str, shape_id: str | None = None):
    if scale == "full":
        return recsys.Bert4RecConfig(n_items=1_000_000, embed_dim=64,
                                     n_blocks=2, n_heads=2, seq_len=200,
                                     n_negatives=128)
    return recsys.Bert4RecConfig(n_items=1000, embed_dim=16, n_blocks=2,
                                 n_heads=2, seq_len=12, n_negatives=8)


ARCH = ArchDef("bert4rec", "recsys", make_config, SHAPES, SMOKE_SHAPES,
               source="arXiv:1904.06690")
