"""minicpm3-4b [hf:openbmb/MiniCPM3-4B; hf]: 62L d=2560 40H d_ff=6400
vocab=73448; MLA (q_lora=768, kv_lora=256, nope=64, rope=32, v=64);
mup-style embed scale 12 and depth-scaled residuals 1.4/sqrt(L)."""
from repro.configs.base import ArchDef
from repro.models import transformer as tfm
from repro.models.attention import MlaDims

SHAPES = {
    "train_4k":    {"step": "train",   "batch": 256, "seq": 4096,
                    "microbatches": 2},
    "prefill_32k": {"step": "prefill", "batch": 32,  "seq": 32768},
    "decode_32k":  {"step": "decode",  "batch": 128, "seq": 32768},
    "long_500k":   {"step": "decode",  "batch": 1,   "seq": 524288},
}
SMOKE_SHAPES = {
    "train_4k":    {"step": "train",   "batch": 2, "seq": 32},
    "prefill_32k": {"step": "prefill", "batch": 2, "seq": 32},
    "decode_32k":  {"step": "decode",  "batch": 2, "seq": 64},
    "long_500k":   {"step": "decode",  "batch": 1, "seq": 64},
}


def make_config(scale: str, shape_id: str | None = None):
    if scale == "full":
        return tfm.TransformerConfig(
            name="minicpm3-4b", n_layers=62, d_model=2560, n_heads=40,
            n_kv_heads=40, head_dim=96, d_ff=6400, vocab=73728,  # 73448 padded to 512-lane multiple
            attn="mla",
            mla=MlaDims(n_heads=40, q_lora=768, kv_lora=256, nope=64,
                        rope=32, v_dim=64),
            embed_scale=12.0, residual_scale=1.4 / (62 ** 0.5),
            tie_embeddings=True)
    return tfm.TransformerConfig(
        name="minicpm3-4b-smoke", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=24, d_ff=128, vocab=512, attn="mla",
        mla=MlaDims(n_heads=4, q_lora=32, kv_lora=16, nope=16, rope=8,
                    v_dim=16),
        embed_scale=12.0, residual_scale=1.4 / (3 ** 0.5),
        tie_embeddings=True, chunk_q=16, loss_chunk=16)


ARCH = ArchDef("minicpm3-4b", "lm", make_config, SHAPES, SMOKE_SHAPES,
               source="hf:openbmb/MiniCPM3-4B")
