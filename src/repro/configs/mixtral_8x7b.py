"""mixtral-8x7b [arXiv:2401.04088; hf]: 32L d=4096 32H (GQA kv=8)
d_ff=14336 vocab=32000; 8-expert top-2 MoE; sliding-window attention."""
from repro.configs.base import ArchDef
from repro.models import transformer as tfm

SHAPES = {
    "train_4k":    {"step": "train",   "batch": 256, "seq": 4096,
                    "microbatches": 2},
    "prefill_32k": {"step": "prefill", "batch": 32,  "seq": 32768},
    "decode_32k":  {"step": "decode",  "batch": 128, "seq": 32768},
    "long_500k":   {"step": "decode",  "batch": 1,   "seq": 524288},
}
SMOKE_SHAPES = {
    "train_4k":    {"step": "train",   "batch": 2, "seq": 32},
    "prefill_32k": {"step": "prefill", "batch": 2, "seq": 32},
    "decode_32k":  {"step": "decode",  "batch": 2, "seq": 64},
    "long_500k":   {"step": "decode",  "batch": 1, "seq": 64},
}


def make_config(scale: str, shape_id: str | None = None):
    if scale == "full":
        return tfm.TransformerConfig(
            name="mixtral-8x7b", n_layers=32, d_model=4096, n_heads=32,
            n_kv_heads=8, head_dim=128, d_ff=14336, vocab=32256,  # 32000 padded to 512-lane multiple
            window=4096, global_every=0, rope_base=1_000_000.0,
            moe=tfm.MoeConfig(n_experts=8, top_k=2),
            tie_embeddings=False, ring_cache=True)
    return tfm.TransformerConfig(
        name="mixtral-8x7b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab=512, window=16,
        global_every=0, rope_base=1_000_000.0,
        moe=tfm.MoeConfig(n_experts=4, top_k=2), tie_embeddings=False,
        ring_cache=True, chunk_q=16, loss_chunk=16)


ARCH = ArchDef("mixtral-8x7b", "lm", make_config, SHAPES, SMOKE_SHAPES,
               source="arXiv:2401.04088")
