"""dien [arXiv:1809.03672; unverified]: embed_dim=18, seq_len=100,
GRU(108) interest extractor + AUGRU interest evolution, MLP 200-80."""
from repro.configs.base import ArchDef
from repro.models import recsys

SHAPES = {
    "train_batch":    {"step": "train", "batch": 65536},
    "serve_p99":      {"step": "serve", "batch": 512},
    "serve_bulk":     {"step": "serve", "batch": 262144},
    "retrieval_cand": {"step": "retrieval", "batch": 1,
                       "n_candidates": 1_000_000},
}
SMOKE_SHAPES = {
    "train_batch":    {"step": "train", "batch": 16},
    "serve_p99":      {"step": "serve", "batch": 8},
    "serve_bulk":     {"step": "serve", "batch": 32},
    "retrieval_cand": {"step": "retrieval", "batch": 1,
                       "n_candidates": 512},
}


def make_config(scale: str, shape_id: str | None = None):
    if scale == "full":
        return recsys.DienConfig(n_items=1_000_000, embed_dim=18,
                                 seq_len=100, gru_dim=108,
                                 mlp_dims=(200, 80))
    return recsys.DienConfig(n_items=1000, embed_dim=8, seq_len=10,
                             gru_dim=12, mlp_dims=(16, 8))


ARCH = ArchDef("dien", "recsys", make_config, SHAPES, SMOKE_SHAPES,
               source="arXiv:1809.03672")
