"""pna [arXiv:2004.05718; paper]: 4 layers, d_hidden=75, aggregators
mean/max/min/std, scalers identity/amplification/attenuation.

Input feature dim / class count are SHAPE properties (each cell is a
different public dataset): cora (full_graph_sm), reddit (minibatch_lg,
real fanout-15,10 neighbor sampler), ogbn-products (full-batch-large),
ogbg-mol-style batched small graphs (molecule).
"""
import numpy as np

from repro.configs.base import ArchDef
from repro.models import gnn

# minibatch_lg block capacity: seeds + 15*seeds + 150*seeds (fanout 15,10)
_MB_NODES = 1024 + 1024 * 15 + 1024 * 150
_MB_EDGES = 1024 * 15 + 1024 * 150

def _p512(n):
    """Pad to a 512 multiple: jit input shardings need the leading dim
    divisible by the mesh axis product; the data pipeline pads with
    trash-node edges (dropped by segment ops)."""
    return -(-n // 512) * 512


SHAPES = {
    "full_graph_sm": {"n_nodes": _p512(2708), "n_edges": _p512(10556),
                      "d_feat": 1433, "n_classes": 7, "delta": 1.6},
    "minibatch_lg":  {"n_nodes": _p512(_MB_NODES), "n_edges": _p512(_MB_EDGES),
                      "d_feat": 602, "n_classes": 41, "delta": 5.0,
                      "full_graph": {"n_nodes": 232_965,
                                     "n_edges": 114_615_892,
                                     "batch_nodes": 1024,
                                     "fanout": (15, 10)}},
    "ogb_products":  {"n_nodes": _p512(2_449_029), "n_edges": _p512(61_859_140),
                      "d_feat": 100, "n_classes": 47, "delta": 3.3},
    "molecule":      {"n_nodes": _p512(128 * 30), "n_edges": _p512(128 * 64),
                      "d_feat": 9, "n_classes": 2, "n_graphs": 128,
                      "graph_level": True, "delta": 1.2},
}
SMOKE_SHAPES = {
    "full_graph_sm": {"n_nodes": 64, "n_edges": 256, "d_feat": 16,
                      "n_classes": 4, "delta": 1.6},
    "minibatch_lg":  {"n_nodes": 8 + 8 * 3 + 8 * 6, "n_edges": 8 * 3 + 8 * 6,
                      "d_feat": 16, "n_classes": 4, "delta": 1.6,
                      "full_graph": {"n_nodes": 500, "n_edges": 4000,
                                     "batch_nodes": 8, "fanout": (3, 2)}},
    "ogb_products":  {"n_nodes": 128, "n_edges": 512, "d_feat": 16,
                      "n_classes": 4, "delta": 1.6},
    "molecule":      {"n_nodes": 8 * 6, "n_edges": 8 * 10, "d_feat": 9,
                      "n_classes": 2, "n_graphs": 8, "graph_level": True,
                      "delta": 1.2},
}


def make_config(scale: str, shape_id: str | None = None):
    shapes = SHAPES if scale == "full" else SMOKE_SHAPES
    shp = shapes[shape_id or "full_graph_sm"]
    d_hidden = 75 if scale == "full" else 16
    n_layers = 4 if scale == "full" else 2
    return gnn.PnaConfig(name="pna", n_layers=n_layers, d_hidden=d_hidden,
                         d_feat=shp["d_feat"], n_classes=shp["n_classes"],
                         delta=shp["delta"])


ARCH = ArchDef("pna", "gnn", make_config, SHAPES, SMOKE_SHAPES,
               source="arXiv:2004.05718")
