"""gemma3-4b [hf:google/gemma-3-4b-pt; unverified]: 34L d=2560 8H(kv=4)
d_ff=10240 vocab=262144; 5:1 local(1024-window):global interleave with
RoPE 10k local / 1M global; qk-norm; sandwich norms; 128k context."""
from repro.configs.base import ArchDef
from repro.models import transformer as tfm

SHAPES = {
    "train_4k":    {"step": "train",   "batch": 256, "seq": 4096,
                    "microbatches": 2},
    "prefill_32k": {"step": "prefill", "batch": 32,  "seq": 32768},
    "decode_32k":  {"step": "decode",  "batch": 128, "seq": 32768},
    "long_500k":   {"step": "decode",  "batch": 1,   "seq": 524288},
}
SMOKE_SHAPES = {
    "train_4k":    {"step": "train",   "batch": 2, "seq": 32},
    "prefill_32k": {"step": "prefill", "batch": 2, "seq": 32},
    "decode_32k":  {"step": "decode",  "batch": 2, "seq": 64},
    "long_500k":   {"step": "decode",  "batch": 1, "seq": 64},
}


def make_config(scale: str, shape_id: str | None = None):
    if scale == "full":
        return tfm.TransformerConfig(
            name="gemma3-4b", n_layers=34, d_model=2560, n_heads=8,
            n_kv_heads=4, head_dim=256, d_ff=10240, vocab=262144,
            qk_norm=True, window=1024, global_every=6,
            rope_base=1_000_000.0, rope_base_local=10_000.0,
            post_norm=True, embed_scale=2560 ** 0.5, tie_embeddings=True)
    return tfm.TransformerConfig(
        name="gemma3-4b-smoke", n_layers=6, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab=512,
        qk_norm=True, window=8, global_every=6,
        rope_base=1_000_000.0, rope_base_local=10_000.0,
        post_norm=True, embed_scale=8.0, tie_embeddings=True,
        chunk_q=16, loss_chunk=16)


ARCH = ArchDef("gemma3-4b", "lm", make_config, SHAPES, SMOKE_SHAPES,
               source="hf:google/gemma-3-4b-pt")
