"""The paper's own experiment configuration, as a config object.

Captures §4's collection statistics and the evaluation protocol so
benchmarks and examples share one source of truth.
"""
import dataclasses

from repro.core.size_model import PAPER_COLLECTION, CorpusStats
from repro.text.corpus import CorpusSpec


@dataclasses.dataclass(frozen=True)
class PaperIndexConfig:
    collection: CorpusStats = PAPER_COLLECTION
    representations: tuple = ("pr", "or", "cor", "hor")
    query_terms: tuple = (1, 2, 3, 4)        # Table 7 protocol
    query_df_band: tuple = (0.15, 0.5)       # df ~ 300k at D=1M (§4.3)
    topk: int = 10
    repeats: int = 10
    # CPU-runnable tier with the paper's posting-length regime
    bench_spec: CorpusSpec = CorpusSpec(num_docs=20_000, vocab=2_000,
                                        avg_distinct=60, seed=42)


PAPER = PaperIndexConfig()
