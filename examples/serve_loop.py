"""The online serving loop end to end: a QueryServer micro-batching
single-query traffic over a live SegmentedIndex while an ingest stream
lands documents and a background maintenance thread seals and compacts
— queries always score a consistent epoch-pinned snapshot, repeated
queries hit the (epoch-keyed) result cache, and a host snapshot taken
mid-flight restores to a bit-identical index.

    PYTHONPATH=src python examples/serve_loop.py
"""
import os
import tempfile
import threading
import time

import numpy as np

from repro.core import build, compaction
from repro.core.live_index import SegmentedIndex
from repro.serve import (IndexMaintenance, QueryServer, ServerConfig,
                         load_segmented, save_segmented)
from repro.text import corpus

spec = corpus.CorpusSpec(num_docs=2400, vocab=1200, avg_distinct=30, seed=5)
tc = corpus.generate(spec)
host = build.bulk_build(tc)


def batch(a, b):
    return build.TokenizedCorpus(tc.doc_term_ids[a:b], tc.doc_counts[a:b],
                                 tc.term_hashes, b - a)


# seed the live index with the first half of the corpus
si = SegmentedIndex(term_hashes=tc.term_hashes, delta_doc_capacity=128,
                    delta_posting_capacity=8192,
                    policy=compaction.TieredPolicy(size_ratio=4.0,
                                                   min_run=4))
for a in range(0, 1200, 300):
    si.add_batch(batch(a, a + 300))

# trace_sample=1: every response carries its span tree, so the summary
# below can say WHERE each millisecond went, not just the e2e number
server = QueryServer(si, ServerConfig(batch_size=8, n_terms_budget=8, k=10,
                                      trace_sample=1))
maint = IndexMaintenance(si, server.index_lock, seal_fill=0.5,
                         interval_s=0.002)
server.warmup()
print(f"serving: docs={si.num_docs} segments={si.num_segments} "
      f"epoch={si.epoch}")

# background ingest: the second half of the corpus lands while we serve
stop_ingest = threading.Event()


def ingest_loop():
    for a in range(1200, 2400, 100):
        if stop_ingest.is_set():
            return
        with server.index_lock:
            si.add_batch(batch(a, a + 100))
            if a % 300 == 0:
                si.delete([a - 7, a - 13])       # churn: tombstones too
        time.sleep(0.01)


ingest = threading.Thread(target=ingest_loop, daemon=True)
server.start()
maint.start()
ingest.start()

# traffic: a finite query pool (repeats -> cache hits at stable epochs)
pool = corpus.sample_query_terms(host.df, host.term_hashes, 32, 3,
                                 num_docs=host.num_docs, seed=9)
rng = np.random.default_rng(0)
tickets = [server.submit(pool[rng.integers(len(pool))]) for _ in range(120)]
responses = [t.result(timeout=120.0) for t in tickets]

ingest.join()
maint.stop()
server.stop()

s = server.metrics.summary()          # cache stats included since init
print(f"served {s['requests']} requests in {s['batches']} batches "
      f"(fill={s['batch_fill']:.2f}) across {s['epochs_served']} epochs")
print(f"latency p50={s['p50_us'] / 1e3:.1f}ms p99={s['p99_us'] / 1e3:.1f}ms"
      f" throughput={s['qps']:.1f} qps")
print(f"cache: hit_rate={s['cache_hit_rate']:.2f} "
      f"({s['cache_hits']} hits / {s['cache_misses']} misses)")
print(f"maintenance: seals={maint.stats.seals} "
      f"compactions={maint.stats.compactions} segments={si.num_segments}")

# per-stage breakdown: every sampled response's spans, aggregated
print("stage breakdown (p50/p99 us per sampled request):")
for stage, st in server.stage_summary().items():
    print(f"  {stage:<11} n={st['count']:<4} p50={st['p50']:>9.1f} "
          f"p99={st['p99']:>9.1f}")

# the maintenance event log: what sealed/compacted/rewrote, when
print(f"last maintenance events ({si.events.total} total, "
      f"counts={si.events.counts()}):")
for e in server.events(n=5):
    extra = {k: v for k, v in e.items()
             if k not in ("seq", "kind", "t_wall", "duration_us")}
    print(f"  #{e['seq']} {e['kind']}: {extra}")

# one sampled trace end to end: stage durations sum to the measured
# e2e latency exactly (shared boundary timestamps)
r = next(r for r in responses if r.trace is not None)
stages = r.trace.stage_durations()
chain = " -> ".join(f"{k}={v:.0f}us" for k, v in stages.items())
print(f"sample trace: {chain} "
      f"(sum={sum(stages.values()):.0f}us e2e={r.latency_us:.0f}us)")
epochs = sorted({r.epoch for r in responses})
print(f"responses pinned to epochs {epochs[0]}..{epochs[-1]} "
      f"(index now at {si.epoch})")

# snapshot / restore: the failover path answers bit-identically
with tempfile.TemporaryDirectory() as d:
    path = os.path.join(d, "index.npz")
    save_segmented(si, path, lock=server.index_lock)
    restored = load_segmented(path)
r1 = si.topk(pool[:8], k=10)
r2 = restored.topk(pool[:8], k=10)
np.testing.assert_array_equal(np.asarray(r1.doc_ids),
                              np.asarray(r2.doc_ids))
np.testing.assert_array_equal(np.asarray(r1.scores),
                              np.asarray(r2.scores))
print("snapshot -> restore -> query: bit-identical")
