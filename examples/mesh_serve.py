"""The distributed serving mesh end to end: a 4-shard MeshServer
fanning micro-batches over sharded segment stacks while ingest churn
drives cross-shard epoch handoffs, admission control and deadline
shedding guard a latency target, and two tenants share the tier
through isolated result-cache partitions — every response pinned to
one epoch and bit-identical to a single-host QueryServer over the
same view.

    PYTHONPATH=src python examples/mesh_serve.py
"""
import os

# the XLA host device count must be set before jax initialises — this
# is what gives the mesh 4 "shards" on a CPU-only machine
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import numpy as np           # noqa: E402

from repro.core import build, compaction                    # noqa: E402
from repro.core.live_index import SegmentedIndex            # noqa: E402
from repro.serve import MeshConfig, MeshServer              # noqa: E402
from repro.text import corpus                               # noqa: E402

spec = corpus.CorpusSpec(num_docs=2000, vocab=1000, avg_distinct=30, seed=5)
tc = corpus.generate(spec)
host = build.bulk_build(tc)


def batch(a, b):
    return build.TokenizedCorpus(tc.doc_term_ids[a:b], tc.doc_counts[a:b],
                                 tc.term_hashes, b - a)


# seed the live index: sealed runs are what the doc topology shards
si = SegmentedIndex(term_hashes=tc.term_hashes, delta_doc_capacity=128,
                    delta_posting_capacity=8192,
                    policy=compaction.TieredPolicy(size_ratio=4.0,
                                                   min_run=4))
for a in range(0, 1200, 300):
    si.add_batch(batch(a, a + 300))
    si.seal()

mesh = MeshServer(si, MeshConfig(
    batch_size=8, n_terms_budget=8, k=10, trace_sample=1,
    n_shards=4, n_replicas=2,
    max_queue=64, deadline_us=60e6,              # the latency target
    auto_handoff=True, handoff_min_interval_s=0.01, seal_fill=0.5))
mesh.warmup()
print(f"mesh up: shards={mesh.config.n_shards} "
      f"replicas={len(mesh.replicas)} epoch={mesh.serving_epoch} "
      f"docs={si.num_docs} segments={si.num_segments}")

# traffic from two tenants over a finite pool (repeats -> cache hits,
# partitioned per tenant), with ingest churn between waves so the pump
# pays — and traces — cross-shard epoch handoffs mid-drive
pool = corpus.sample_query_terms(host.df, host.term_hashes, 24, 3,
                                 num_docs=host.num_docs, seed=9)
rng = np.random.default_rng(0)
tickets = []
for wave, a in enumerate(range(1200, 2000, 200)):
    for _ in range(24):
        tickets.append(mesh.submit(pool[rng.integers(len(pool))],
                                   tenant=f"tenant{len(tickets) % 2}"))
    mesh.add_batch(batch(a, a + 200))     # fans out to every replica
    if wave % 2:
        mesh.delete_docs([a - 7, a - 13])
    mesh.pump(max_batches=2)              # deterministic drive, no threads
    mesh.run_maintenance_once()
while mesh.pending:
    mesh.pump()
responses = [t.result(timeout=120.0) for t in tickets]

# shed both ways, deterministically: a burst past the admission bound
# resolves immediately as shed("admission"), and one ticket backdated
# past the 60s deadline sheds at batch pickup instead of being scored
burst = [mesh.submit(pool[0]) for _ in range(mesh.config.max_queue + 4)]
burst[4].t_submit -= 120.0
while mesh.pending:
    mesh.pump()
assert all(t.result(timeout=120.0).status in ("ok", "shed")
           for t in burst)

s = mesh.mesh_summary()
print(f"served {s['requests']} over {s['n_shards']} shards in "
      f"{s['batches']} batches across {s['epochs_served']} epochs "
      f"(now at epoch {s['epoch']})")
print(f"latency p50={s['p50_us'] / 1e3:.1f}ms p99={s['p99_us'] / 1e3:.1f}ms")
print(f"shed: {s['shed']} (rate={s['shed_rate']:.3f})")
print(f"handoffs: {s['handoffs']} "
      f"pause_p50={s['handoff_pause_us'].get('p50', 0.0) / 1e3:.1f}ms")
print("tenant cache partitions:")
for tenant, st in s["tenants"].items():
    print(f"  {tenant:<8} entries={st['entries']:<4} hits={st['hits']:<4} "
          f"misses={st['misses']}")

# shard fan-out stage breakdown: queue_wait / handoff / assemble /
# score (with per-shard dispatch + sync children) / respond
print("stage breakdown (p50/p99 us per sampled request):")
for stage, st in mesh.stage_summary().items():
    print(f"  {stage:<11} n={st['count']:<4} p50={st['p50']:>9.1f} "
          f"p99={st['p99']:>9.1f}")

# one traced response end to end: the shard fan-out is visible as
# shard_fanout/shard_sync children of the score span, and top-level
# stages sum exactly to the measured e2e latency
r = next(r for r in responses if r.trace is not None and r.status == "ok")
stages = r.trace.stage_durations()
chain = " -> ".join(f"{k}={v:.0f}us" for k, v in stages.items())
print(f"sample trace: {chain} "
      f"(sum={sum(stages.values()):.0f}us e2e={r.latency_us:.0f}us)")
fanout = [sp for sp in r.trace.spans if sp.name in ("shard_fanout",
                                                    "shard_sync")]
print("  score children: " + " ".join(
    f"{sp.name}={(sp.t1 - sp.t0) * 1e6:.0f}us" for sp in fanout))

# the consistency contract, demonstrated: over the now-quiescent mesh,
# responses at the pinned epoch == the single-host view.topk answer
# over the same view, bit for bit (ties included)
fresh = [mesh.submit(pool[i]) for i in range(4)]
mesh.pump()
view = mesh.serving_view
qb = np.stack([t.row for t in fresh])
oracle = view.topk(qb, k=mesh.config.k)
got = [t.result() for t in fresh]
assert all(g.epoch == view.epoch for g in got)
np.testing.assert_array_equal(
    np.stack([g.doc_ids for g in got]), np.asarray(oracle.doc_ids))
np.testing.assert_array_equal(
    np.stack([g.scores for g in got]), np.asarray(oracle.scores))
print("mesh == single-host QueryServer over the pinned view: "
      "bit-identical")

# the event log tells the whole serving + maintenance story in one
# stream: seal/compact next to handoff and shed
print(f"event counts: {si.events.counts()}")
for e in mesh.events(n=3):
    extra = {k: v for k, v in e.items()
             if k not in ("seq", "kind", "t_wall", "duration_us")}
    print(f"  #{e['seq']} {e['kind']}: {extra}")
