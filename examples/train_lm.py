"""End-to-end driver: train a ~100M-parameter qwen3-style LM for a few
hundred steps on synthetic data, with checkpointing and restart.

    PYTHONPATH=src python examples/train_lm.py --steps 300

(Defaults are laptop-sized; on a real pod use launch/train.py with
--scale full.)
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.models import transformer as tfm
from repro.train import data as data_lib, loop as loop_lib, \
    optimizer as opt_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--tiny", action="store_true",
                    help="2-layer debug model instead of ~100M")
    args = ap.parse_args()

    if args.tiny:
        cfg = tfm.TransformerConfig(
            name="tiny", n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
            head_dim=32, d_ff=512, vocab=4096, chunk_q=64, loss_chunk=64)
    else:
        # ~103M params: 12L x 640d, GQA 8/4, qk-norm (qwen3-style)
        cfg = tfm.TransformerConfig(
            name="qwen3-100m", n_layers=12, d_model=640, n_heads=8,
            n_kv_heads=4, head_dim=64, d_ff=2560, vocab=50176,
            qk_norm=True, rope_base=1e6, chunk_q=128, loss_chunk=128)

    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {cfg.name} params={n / 1e6:.1f}M "
          f"devices={len(jax.devices())}")

    ocfg = opt_lib.AdamWConfig(lr=3e-4, warmup_steps=args.steps // 10,
                               total_steps=args.steps)
    step = jax.jit(opt_lib.make_train_step(
        lambda p, b: tfm.loss_fn(p, cfg, b), ocfg), donate_argnums=(0, 1))

    mk = lambda s: jax.tree.map(jnp.asarray, data_lib.lm_batch(  # noqa
        0, s, args.batch, args.seq, cfg.vocab))
    t0 = time.time()
    res = loop_lib.fit(step, params, opt_lib.init(params), mk,
                       loop_lib.LoopConfig(total_steps=args.steps,
                                           ckpt_dir=args.ckpt_dir,
                                           ckpt_every=100, log_every=25))
    dt = time.time() - t0
    tok = args.steps * args.batch * args.seq
    print(f"loss={float(res.metrics['loss']):.4f} "
          f"({tok / dt:.0f} tok/s incl. compile)")
    print(f"checkpoints in {args.ckpt_dir} — rerun to resume.")


if __name__ == "__main__":
    main()
