"""Index lifecycle: bulk build -> incremental batch add -> deletion ->
expansion/feedback — the paper's §3.6 maintenance story end to end,
then the live-index version: LSM-style delta/seal/compact with
tombstone deletes and multi-segment fused queries.

    PYTHONPATH=src python examples/index_lifecycle.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import build, compaction, direct_index, layouts, query
from repro.core.live_index import SegmentedIndex
from repro.text import corpus

spec = corpus.CorpusSpec(num_docs=3000, vocab=2500, avg_distinct=40, seed=3)
tc = corpus.generate(spec)

# bulk build the first 2000 docs (the §3.6 COPY path)
first = build.TokenizedCorpus(tc.doc_term_ids[:2000], tc.doc_counts[:2000],
                              tc.term_hashes, 2000)
host = build.bulk_build(first)
print(f"bulk built: D={host.num_docs} P={host.num_postings}")

# incremental add of a new crawl batch (drop-index -> merge -> rebuild)
second = build.TokenizedCorpus(tc.doc_term_ids[2000:], tc.doc_counts[2000:],
                               tc.term_hashes, 1000)
host = build.add_documents(host, second)
print(f"after add: D={host.num_docs} P={host.num_postings}")

ix = layouts.build_compact_csr(host)       # COR
qh = corpus.sample_query_terms(host.df, host.term_hashes, 1, 3,
                               num_docs=host.num_docs, seed=4)[0]
cap = host.max_posting_len
r = query.score_query(ix, jnp.asarray(qh), k=5, cap=cap)
print("top-5:", np.asarray(r.doc_ids).tolist())

# delete the top document; it disappears from results
norm2 = direct_index.delete_docs(ix.docs.norm, r.doc_ids[:1])
ix2 = layouts.CompactCsrIndex(
    sorted_hash=ix.sorted_hash, df=ix.df, offsets=ix.offsets,
    doc_ids=ix.doc_ids, tfs=ix.tfs,
    docs=layouts.DocTable(norm=norm2, rank=ix.docs.rank),
    max_posting_len=ix.max_posting_len)
r2 = query.score_query(ix2, jnp.asarray(qh), k=5, cap=cap)
print("after delete:", np.asarray(r2.doc_ids).tolist())
assert int(r.doc_ids[0]) not in np.asarray(r2.doc_ids).tolist()

# expansion + Rocchio feedback via the direct index (§4.4)
di = direct_index.build_direct(host)
exp = direct_index.expand_query(di, r2.doc_ids, host.num_terms,
                                cap=di.max_doc_len)
fb = direct_index.relevance_feedback(di, r2.doc_ids[:2],
                                     ix.lookup_terms(jnp.asarray(qh)),
                                     host.num_terms, cap=di.max_doc_len)
print("expansion:", np.asarray(exp.term_ids).tolist())
print("feedback :", np.asarray(fb.term_ids).tolist())

# --- the live-index version: no rebuilds, no recompiles -------------------
# delta -> seal -> compact; deletes are tombstones until compaction
si = SegmentedIndex(term_hashes=tc.term_hashes, delta_doc_capacity=256,
                    policy=compaction.TieredPolicy(size_ratio=4.0,
                                                   min_run=4))
for a in range(0, 3000, 500):
    si.add_batch(build.TokenizedCorpus(tc.doc_term_ids[a:a + 500],
                                       tc.doc_counts[a:a + 500],
                                       tc.term_hashes, 500))
print(f"live index: docs={si.num_docs} segments={si.num_segments} "
      f"seals={si.stats.seals} compactions={si.stats.compactions}")
live = si.topk(qh[None], k=5)
print("live top-5:", np.asarray(live.doc_ids)[0].tolist())
si.delete(np.asarray(live.doc_ids)[0][:1])           # tombstone the winner
live2 = si.topk(qh[None], k=5)
print("after delete:", np.asarray(live2.doc_ids)[0].tolist())
assert int(np.asarray(live.doc_ids)[0][0]) not in \
    np.asarray(live2.doc_ids)[0].tolist()
si.seal()
si.compact(all_segments=True)                        # reclaim tombstones
live3 = si.topk(qh[None], k=5)
np.testing.assert_array_equal(np.asarray(live3.doc_ids),
                              np.asarray(live2.doc_ids))
print(f"after compact: segments={si.num_segments} "
      f"merge_work={si.stats.postings_merged} postings")
print("lifecycle OK")
