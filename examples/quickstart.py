"""Quickstart: build all four paper index representations over a small
corpus, run the paper's q_word/q_occ/q_doc query pipeline on each, and
show size + agreement — the whole paper in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import build, layouts, query, direct_index
from repro.text import corpus

# 1. a synthetic Zipf corpus calibrated to the paper's statistics
spec = corpus.CorpusSpec(num_docs=5_000, vocab=4_000, avg_distinct=60,
                         seed=0)
tc = corpus.generate(spec)
host = build.bulk_build(tc)           # the §3.6 bulk "copy" pipeline
print(f"corpus: D={host.num_docs} W={host.num_terms} "
      f"postings={host.num_postings}")

# 2. the four representations (+ the beyond-paper packed layout)
indexes = {name: builder(host)
           for name, builder in layouts.REPRESENTATIONS.items()}
for name, ix in indexes.items():
    print(f"  {name:7s} {ix.nbytes() / 1e6:8.2f} MB "
          f"(postings: {ix.posting_bytes() / 1e6:.2f} MB)")

# 3. a frequent-terms query ("information retrieval" style, §4.3)
qh = corpus.sample_query_terms(host.df, host.term_hashes, num_queries=1,
                               terms_per_query=2, num_docs=host.num_docs)[0]
cap = host.max_posting_len
results = {}
for name, ix in indexes.items():
    r = query.score_query(ix, jnp.asarray(qh), k=5, cap=cap)
    results[name] = r
    top = ", ".join(f"doc{int(d)}:{float(s):.4f}"
                    for d, s in zip(r.doc_ids, r.scores))
    print(f"  {name:7s} -> {top}")

ids = {name: np.asarray(r.doc_ids).tolist() for name, r in results.items()}
assert all(v == ids["or"] for v in ids.values()), "layouts disagree!"
print("all representations return identical rankings ✓")

# 4. document-based access (§4.4): expansion via the direct index
di = direct_index.build_direct(host)
exp = direct_index.expand_query(di, results["or"].doc_ids,
                                host.num_terms, cap=di.max_doc_len)
print("query expansion suggests terms:",
      np.asarray(exp.term_ids).tolist())
