"""Serve a small retrieval index with batched requests: single-node on
the HOR (blocked) layout + the distributed document-sharded engine on a
host mesh (the production multi-pod topology, scaled down).

    PYTHONPATH=src python examples/serve_retrieval.py
    # distributed engine (8 simulated devices):
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/serve_retrieval.py --shards 8
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import build, layouts, query
from repro.text import corpus


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=8000)
    ap.add_argument("--vocab", type=int, default=4000)
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--shards", type=int, default=0)
    args = ap.parse_args()

    tc = corpus.generate(corpus.CorpusSpec(num_docs=args.docs,
                                           vocab=args.vocab,
                                           avg_distinct=60, seed=1))
    host = build.bulk_build(tc)
    qh = corpus.sample_query_terms(host.df, host.term_hashes,
                                   args.requests, 3,
                                   num_docs=host.num_docs, seed=2)

    if args.shards:
        from repro.distributed import retrieval as dist
        mesh = jax.make_mesh((args.shards,), ("data",))
        ds = dist.build_doc_sharded(host, args.shards)
        one = dist.make_doc_sharded_scorer(ds, mesh, "data", k=10)
        scorer = jax.jit(jax.vmap(one))
        label = f"doc-sharded x{args.shards}"
    else:
        ix = layouts.build_blocked(host)       # HOR: the paper's winner
        scorer = query.make_scorer(ix, k=10, cap=host.max_posting_len)
        label = f"hor single-node ({ix.nbytes() / 1e6:.1f} MB)"

    print(f"serving with {label}")
    lat = []
    for i in range(0, args.requests, args.batch):
        qb = jnp.asarray(qh[i:i + args.batch])
        t0 = time.time()
        out = scorer(qb)
        jax.tree.map(lambda x: x.block_until_ready(), out)
        lat.append((time.time() - t0) / qb.shape[0] * 1e6)
    lat = np.array(lat[1:] if len(lat) > 1 else lat)  # drop warmup batch
    print(f"{args.requests} requests: p50={np.percentile(lat, 50):.0f}us "
          f"p95={np.percentile(lat, 95):.0f}us per query")


if __name__ == "__main__":
    main()
