"""Serving subsystem: micro-batched QueryServer, epoch-pinned
snapshots, result cache, background maintenance.

The central contract (the PR's acceptance criterion): under a churn
schedule — adds, deletes, seals, compactions running between/behind
query batches — EVERY response the server returns is bit-identical
(ties included) to the jnp oracle over ``bulk_build`` of the live
corpus AT THE EPOCH the response was pinned to, and steady-state
serving adds ZERO jit cache entries after one warmup per size class.
"""
import os
import tempfile
import threading

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import build, compaction, layouts, query
from repro.core import live_index as li
from repro.core.build import TokenizedCorpus
from repro.core.live_index import SegmentedIndex
from repro.serve import (IndexMaintenance, QueryServer, ResultCache,
                         ServerConfig, load_segmented, pin,
                         restore_segmented, save_segmented,
                         serialize_segmented)
from repro.serve.metrics import LatencyWindow, ServerMetrics, percentiles
from repro.text import corpus


def _slices(tc, bounds):
    return [TokenizedCorpus(tc.doc_term_ids[a:b], tc.doc_counts[a:b],
                            tc.term_hashes, b - a)
            for a, b in zip(bounds[:-1], bounds[1:])]


class RecordingServer(QueryServer):
    """QueryServer that remembers every view it pinned, keyed by epoch —
    so a test can oracle-check a response against the exact snapshot it
    was served from, even when maintenance ran in another thread."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.views = {self._pinned.epoch: self._pinned}

    def refresh_view(self):
        v = super().refresh_view()
        self.views[v.epoch] = v
        return v


def _oracle_for_view(view, k):
    """jnp-oracle scorer over bulk_build of the view's live corpus, with
    compact doc ids mapped back to global ids."""
    tc_live, live_ids = view.export_live_corpus()
    host = build.bulk_build(tc_live)
    ix = layouts.build_blocked(host)
    cap = max(host.max_posting_len, 1)
    scorer = query.make_scorer(ix, k=k, cap=cap)

    def run(rows):
        r = scorer(jnp.asarray(rows))
        oid = np.asarray(r.doc_ids)
        mapped = np.where(oid >= 0, live_ids[np.maximum(oid, 0)], -1)
        return mapped.astype(np.int32), np.asarray(r.scores)

    return run


def _check_responses(server, answered, k):
    """Every (ticket, response) pair must match the oracle of its pinned
    epoch bit-identically (ids incl. tie order; scores to float tol)."""
    by_epoch = {}
    for ticket in answered:
        r = ticket.response
        by_epoch.setdefault(r.epoch, []).append(ticket)
    for epoch, tickets in by_epoch.items():
        oracle = _oracle_for_view(server.views[epoch], k)
        rows = np.stack([t.row for t in tickets])
        want_ids, want_scores = oracle(rows)
        for i, t in enumerate(tickets):
            np.testing.assert_array_equal(t.response.doc_ids, want_ids[i])
            np.testing.assert_allclose(t.response.scores, want_scores[i],
                                       rtol=1e-5, atol=1e-7)


def test_server_parity_and_zero_recompiles_under_churn():
    """The acceptance criterion: a 64-batch query stream interleaved
    with add/delete/seal/compact maintenance — every response matches
    the oracle at its pinned epoch, zero new jit entries after warmup,
    and the cache serves hits at stable epochs."""
    rng = np.random.default_rng(0)
    tc = corpus.generate(corpus.CorpusSpec(num_docs=1600, vocab=400,
                                           avg_distinct=16, seed=4))
    B = 64
    si = SegmentedIndex(term_hashes=tc.term_hashes, delta_doc_capacity=B,
                        delta_posting_capacity=B * 40,
                        policy=compaction.TieredPolicy(size_ratio=4.0,
                                                       min_run=4))
    cfg = ServerConfig(batch_size=8, n_terms_budget=8, k=10)
    server = RecordingServer(si, cfg)
    maint = IndexMaintenance(si, server.index_lock, seal_fill=0.9)
    pool = corpus.sample_query_terms(
        build.bulk_build(_slices(tc, [0, 200])[0]).df, tc.term_hashes,
        24, 3, num_docs=200, seed=5)

    def submit_and_pump(n):
        tickets = [server.submit(pool[rng.integers(len(pool))])
                   for _ in range(n)]
        while server.pending:
            server.pump()
        return tickets

    # -- warmup: mint the schedule's size classes (delta seals + an
    # L1 compaction + deletes), serving all the while
    answered = []
    a = 0
    for _ in range(6):
        with server.index_lock:
            si.add_batch(_slices(tc, [a, a + B])[0])
        a += B
        maint.run_once()
        answered += submit_and_pump(8)
    with server.index_lock:
        si.delete([a - 1, a - 5])
    server.warmup()
    answered += submit_and_pump(8)
    assert si.stats.compactions >= 1
    snap = li.scorer_cache_sizes()

    # -- the measured stream: 64 micro-batches under churn.  Ingest is
    # paced so compactions stay within the size classes warmup minted
    # (the zero-recompile contract is per warm class, as in the PR-3
    # churn test; a deeper LSM cascade would legitimately mint new
    # classes — that log-bounded growth is pinned by the slow sweep)
    for step in range(64):
        if step % 8 == 1:
            with server.index_lock:
                si.add_batch(_slices(tc, [a, a + B])[0])
            a += B
        if step % 8 == 3:
            live = np.flatnonzero(si.live_mask())
            with server.index_lock:
                si.delete(rng.choice(live, size=5, replace=False))
        if step % 2 == 0:
            maint.run_once()
        answered += submit_and_pump(cfg.batch_size)

    assert li.scorer_cache_sizes() == snap, "serving minted new jit entries"
    assert maint.stats.seals >= 1          # maintenance did real sealing
    assert si.stats.seals >= 6
    assert si.stats.compactions >= 2
    _check_responses(server, answered, cfg.k)
    # the finite pool + stable epochs between mutations => real hits
    assert server.cache.hits > 0
    s = server.metrics.summary()
    assert s["requests"] == len(answered)
    assert s["epochs_served"] >= 3
    assert s["p99_us"] >= s["p50_us"] > 0


def test_server_parity_with_background_threads():
    """Randomized interleave with REAL threads: worker + maintenance +
    an ingest thread race; every response still matches the oracle of
    its pinned epoch (consistency comes from the pin, not from
    scheduling luck)."""
    tc = corpus.generate(corpus.CorpusSpec(num_docs=900, vocab=300,
                                           avg_distinct=14, seed=7))
    si = SegmentedIndex(term_hashes=tc.term_hashes, delta_doc_capacity=48,
                        delta_posting_capacity=2048,
                        policy=compaction.TieredPolicy(size_ratio=4.0,
                                                       min_run=3))
    si.add_batch(_slices(tc, [0, 300])[0])
    cfg = ServerConfig(batch_size=4, n_terms_budget=8, k=10)
    server = RecordingServer(si, cfg)
    maint = IndexMaintenance(si, server.index_lock, seal_fill=0.5,
                             interval_s=0.001)
    server.warmup()
    pool = corpus.sample_query_terms(np.asarray(si._df), si.term_hashes,
                                     16, 3, num_docs=si.live_doc_count,
                                     seed=2)
    rng = np.random.default_rng(3)

    def ingest():
        # free-running writer racing the worker + maintenance threads
        for a in range(300, 600, 60):
            with server.index_lock:
                si.add_batch(_slices(tc, [a, a + 60])[0])
                if a % 120 == 0:
                    si.delete([a - 3, a - 11])

    server.start()
    maint.start()
    ingester = threading.Thread(target=ingest, daemon=True)
    ingester.start()
    # waves: each waits for its responses, with an ingest between waves
    # (so >= 2 distinct epochs are served no matter how the free-running
    # threads happen to schedule)
    tickets = []
    wave_starts = list(range(600, 900, 60))
    for wave in range(6):
        batch = [server.submit(pool[rng.integers(len(pool))])
                 for _ in range(16)]
        for t in batch:
            t.result(timeout=300.0)
        tickets += batch
        if wave < len(wave_starts):
            a = wave_starts[wave]
            with server.index_lock:
                si.add_batch(_slices(tc, [a, a + 60])[0])
    ingester.join(timeout=300.0)
    maint.stop()
    server.stop()
    responses = [t.response for t in tickets]
    assert all(r is not None for r in responses)
    assert len({r.epoch for r in responses}) >= 2
    _check_responses(server, tickets, cfg.k)


def test_pinned_view_is_immutable_under_mutation():
    """A pinned view keeps answering for ITS epoch after the live index
    moves on — deletes and compactions land only in newer epochs."""
    tc = corpus.generate(corpus.CorpusSpec(num_docs=300, vocab=250,
                                           avg_distinct=15, seed=3))
    si = SegmentedIndex(term_hashes=tc.term_hashes, delta_doc_capacity=64,
                        delta_posting_capacity=4096,
                        policy=compaction.TieredPolicy(min_run=100))
    si.add_batch(_slices(tc, [0, 200])[0])
    si.seal()
    qh = corpus.sample_query_terms(np.asarray(si._df), si.term_hashes,
                                   4, 3, num_docs=si.live_doc_count, seed=2)
    view = pin(si)
    before = view.topk(qh, k=10)
    winner = int(np.asarray(before.doc_ids)[0, 0])
    # mutate: delete the winner, add docs, compact
    si.delete([winner])
    si.add_batch(_slices(tc, [200, 300])[0])
    si.seal()
    si.compact(all_segments=True)
    assert si.epoch > view.epoch
    again = view.topk(qh, k=10)
    np.testing.assert_array_equal(np.asarray(again.doc_ids),
                                  np.asarray(before.doc_ids))
    np.testing.assert_array_equal(np.asarray(again.scores),
                                  np.asarray(before.scores))
    # and the pinned view still matches the oracle OF ITS EPOCH
    oracle = _oracle_for_view(view, 10)
    want_ids, want_scores = oracle(qh.astype(np.uint32))
    np.testing.assert_array_equal(np.asarray(again.doc_ids), want_ids)
    # while the live index has genuinely moved on
    now_ids = np.asarray(si.topk(qh, k=10).doc_ids)
    assert winner not in now_ids[now_ids >= 0]


@pytest.mark.parametrize("seal_layout", ["hor", "packed"])
def test_snapshot_restore_bit_identical(seal_layout):
    """serialize -> restore (and save -> load through a file) answers
    bit-identically, keeps stats/policy/rng, and stays bit-identical
    under identical FUTURE mutation schedules (rng state rides along)."""
    tc = corpus.generate(corpus.CorpusSpec(num_docs=400, vocab=250,
                                           avg_distinct=14, seed=9))
    si = SegmentedIndex(term_hashes=tc.term_hashes, delta_doc_capacity=48,
                        delta_posting_capacity=2048,
                        policy=compaction.TieredPolicy(size_ratio=4.0,
                                                       min_run=3),
                        seal_layout=seal_layout)
    for a in range(0, 300, 60):
        si.add_batch(_slices(tc, [a, a + 60])[0])
    si.delete([3, 77, 150])
    # vocab growth after some segments sealed (restore must rebuild old
    # segments against the GROWN vocabulary and still answer identically)
    extra = TokenizedCorpus(
        doc_term_ids=[np.asarray([0, 1], np.int64)],
        doc_counts=[np.asarray([2, 1], np.int64)],
        term_hashes=np.array([0xDEADBEEF, 0xFEEDFACE], np.uint32),
        num_docs=1)
    si.add_batch(extra)
    qh = corpus.sample_query_terms(np.asarray(si._df)[:250],
                                   si.term_hashes[:250], 6, 3,
                                   num_docs=si.live_doc_count, seed=2)

    state = serialize_segmented(si, lock=threading.RLock())
    si2 = restore_segmented(state)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "snap.npz")
        save_segmented(si, path)
        si3 = load_segmented(path)

    for other in (si2, si3):
        assert other.epoch == si.epoch
        assert other.num_segments == si.num_segments
        assert other.live_doc_count == si.live_doc_count
        np.testing.assert_array_equal(other._df, si._df)
        np.testing.assert_array_equal(other._norm, si._norm)
        r1 = si.topk(qh, k=10)
        r2 = other.topk(qh, k=10)
        np.testing.assert_array_equal(np.asarray(r1.doc_ids),
                                      np.asarray(r2.doc_ids))
        np.testing.assert_array_equal(np.asarray(r1.scores),
                                      np.asarray(r2.scores))
    # restored index matches the oracle too (not just the original)
    oracle = _oracle_for_view(si2.view(), 10)
    want_ids, _ = oracle(qh.astype(np.uint32))
    np.testing.assert_array_equal(np.asarray(si2.topk(qh, k=10).doc_ids),
                                  want_ids)
    # identical future mutations stay bit-identical (rng state restored)
    for target in (si, si2):
        target.add_batch(_slices(tc, [300, 400])[0])
        target.delete([301])
    r1, r2 = si.topk(qh, k=10), si2.topk(qh, k=10)
    np.testing.assert_array_equal(np.asarray(r1.doc_ids),
                                  np.asarray(r2.doc_ids))
    np.testing.assert_array_equal(np.asarray(r1.scores),
                                  np.asarray(r2.scores))


def test_result_cache_semantics():
    """LRU bound, epoch keying, purge, hit accounting."""
    c = ResultCache(capacity=2)
    row = np.array([1, 2, 0], np.uint32)
    k1 = c.make_key(row, 10, epoch=1)
    assert c.get(k1) is None and c.misses == 1
    c.put(k1, np.array([5, -1]), np.array([0.5, 0.0]))
    ids, scores = c.get(k1)
    np.testing.assert_array_equal(ids, [5, -1])
    assert c.hits == 1
    # mutating what the caller got back must not poison the cache
    ids[0] = 99
    np.testing.assert_array_equal(c.get(k1)[0], [5, -1])
    # same query at a newer epoch is a different key
    k2 = c.make_key(row, 10, epoch=2)
    assert c.get(k2) is None
    c.put(k2, np.array([6]), np.array([0.1]))
    # LRU bound: k1 was most recently touched via get, so adding a third
    # entry evicts the oldest-touched
    k3 = c.make_key(row, 5, epoch=2)
    c.put(k3, np.array([7]), np.array([0.2]))
    assert len(c) == 2
    # purge_below removes stale-epoch entries
    c.put(k2, np.array([6]), np.array([0.1]))
    assert c.purge_below(2) >= 0
    assert all(key[2] >= 2 for key in c._store)
    assert 0.0 < c.hit_rate < 1.0
    c.reset_counters()
    assert c.hits == c.misses == 0


def test_server_cache_hits_are_bit_identical_and_epoch_scoped():
    tc = corpus.generate(corpus.CorpusSpec(num_docs=200, vocab=200,
                                           avg_distinct=12, seed=6))
    si = SegmentedIndex(term_hashes=tc.term_hashes, delta_doc_capacity=64,
                        delta_posting_capacity=4096)
    si.add_batch(_slices(tc, [0, 150])[0])
    server = QueryServer(si, ServerConfig(batch_size=4, n_terms_budget=6,
                                          k=8))
    server.warmup()
    qh = corpus.sample_query_terms(np.asarray(si._df), si.term_hashes,
                                   1, 3, num_docs=si.live_doc_count,
                                   seed=1)[0]
    r1 = server.query(qh)
    r2 = server.query(qh)
    assert not r1.cached and r2.cached
    assert r1.epoch == r2.epoch
    np.testing.assert_array_equal(r1.doc_ids, r2.doc_ids)
    np.testing.assert_array_equal(r1.scores, r2.scores)
    # epoch advance invalidates: the winner is deleted, a fresh (not
    # cached) response excludes it
    winner = int(r1.doc_ids[0])
    with server.index_lock:
        si.delete([winner])
    r3 = server.query(qh)
    assert not r3.cached and r3.epoch > r1.epoch
    assert winner not in r3.doc_ids[r3.doc_ids >= 0]
    # overwide queries are rejected, never truncated
    with pytest.raises(ValueError):
        server.submit(np.arange(1, 8, dtype=np.uint32))
    # and so are batches: submit takes ONE query, never flattens [B, T]
    with pytest.raises(ValueError, match="ONE query"):
        server.submit(np.ones((2, 3), np.uint32))


def test_metrics_percentiles_and_window():
    samples = [10.0, 20.0, 30.0, 40.0, 100.0]
    p = percentiles(samples, (50, 99))
    assert p["p50"] == pytest.approx(np.percentile(samples, 50))
    assert p["p99"] == pytest.approx(np.percentile(samples, 99))
    assert percentiles([], (50, 99)) == {"p50": 0.0, "p99": 0.0}
    w = LatencyWindow()
    for s in samples:
        w.record(s)
    out = w.summary()
    assert out["count"] == 5
    assert out["p50_us"] == pytest.approx(30.0)
    assert out["mean_us"] == pytest.approx(40.0)
    assert out["qps"] >= 0.0
    m = ServerMetrics()
    m.batched_queries, m.padded_slots = 6, 2
    assert m.batch_fill() == pytest.approx(0.75)
    m.observe_epoch(3)
    m.observe_epoch(3)
    m.observe_epoch(4)
    assert m.epochs_served == 2
    m.reset()
    assert m.epochs_served == 0 and m.batch_fill() == 0.0


def test_maintenance_triggers_and_stats():
    """Seal fires on delta fill, compaction on the policy trigger; an
    idle index is a no-op without taking work."""
    tc = corpus.generate(corpus.CorpusSpec(num_docs=300, vocab=200,
                                           avg_distinct=12, seed=8))
    si = SegmentedIndex(term_hashes=tc.term_hashes, delta_doc_capacity=100,
                        delta_posting_capacity=8192,
                        policy=compaction.TieredPolicy(size_ratio=4.0,
                                                       min_run=3))
    lock = threading.RLock()
    maint = IndexMaintenance(si, lock, seal_fill=0.5,
                             max_compactions_per_run=4)
    assert maint.run_once() == {"sealed": False, "compacted": 0,
                                "rewritten": 0}
    si.add_batch(_slices(tc, [0, 60])[0])        # fill 0.6 >= 0.5
    did = maint.run_once()
    assert did["sealed"] and si.num_segments == 1
    assert si.delta_fill == 0.0
    # three more delta-sized runs -> policy merges on the next run
    for a in range(60, 240, 60):
        si.add_batch(_slices(tc, [a, a + 60])[0])
        maint.run_once()
    assert maint.stats.seals >= 3
    assert si.stats.compactions >= 1
    # quiescent: nothing due, nothing done
    before = (maint.stats.seals, maint.stats.compactions)
    assert maint.run_once() == {"sealed": False, "compacted": 0,
                                "rewritten": 0}
    assert (maint.stats.seals, maint.stats.compactions) == before
    # thread start/stop is clean and idempotent
    maint.start()
    maint.start()
    maint.stop()


def test_sharded_stack_from_pinned_view_requires_sealed_delta():
    tc = corpus.generate(corpus.CorpusSpec(num_docs=200, vocab=200,
                                           avg_distinct=12, seed=5))
    si = SegmentedIndex(term_hashes=tc.term_hashes, delta_doc_capacity=64,
                        delta_posting_capacity=4096)
    si.add_batch(_slices(tc, [0, 150])[0])
    from repro.distributed import retrieval
    with pytest.raises(ValueError, match="seal"):
        retrieval.stack_segment_shards(pin(si), 2)
    si.seal()
    # packed stacks are first-class now (the former HOR-only ValueError
    # is gone): the builder buckets them into packed-layout groups
    si2 = SegmentedIndex(term_hashes=tc.term_hashes, delta_doc_capacity=64,
                         delta_posting_capacity=4096, seal_layout="packed")
    si2.add_batch(_slices(tc, [0, 150])[0])
    si2.seal()
    stacks = retrieval.stack_segment_shards(si2, 2)
    assert {m.layout for m, _ in stacks.groups} == {"packed"}


def test_server_over_packed_sharded_stack_under_ingest():
    """Serving-tier regression for the packed distributed tier: a
    sharded stack built from a pinned epoch of a PACKED index answers
    bit-identically to that epoch's oracle (and to the QueryServer
    responses pinned to it) while ingest keeps landing afterwards."""
    import jax
    from repro.distributed import retrieval

    tc = corpus.generate(corpus.CorpusSpec(num_docs=600, vocab=300,
                                           avg_distinct=14, seed=21))
    si = SegmentedIndex(term_hashes=tc.term_hashes, delta_doc_capacity=64,
                        delta_posting_capacity=4096,
                        policy=compaction.TieredPolicy(min_run=100),
                        seal_layout="packed")
    si.add_batch(_slices(tc, [0, 300])[0])
    cfg = ServerConfig(batch_size=4, n_terms_budget=8, k=10)
    server = RecordingServer(si, cfg)
    server.warmup()
    pool = corpus.sample_query_terms(np.asarray(si._df), si.term_hashes,
                                     8, 3, num_docs=si.live_doc_count,
                                     seed=4)

    # pin a consistent epoch with a sealed delta, then build the sharded
    # serving stack FROM THE PIN while the writer keeps mutating
    with server.index_lock:
        si.seal()
        view = pin(si)
    mesh = jax.make_mesh((1,), ("data",))
    stacks = retrieval.stack_segment_shards(view, 1)
    assert {m.layout for m, _ in stacks.groups} == {"packed"}
    scorer = retrieval.make_doc_sharded_segment_scorer(stacks, mesh,
                                                       "data", k=cfg.k)

    # concurrent ingest: later epochs must not leak into the stack
    with server.index_lock:
        si.add_batch(_slices(tc, [300, 450])[0])
        si.delete([5, 17])
    tickets = [server.submit(q) for q in pool]
    while server.pending:
        server.pump()

    oracle = _oracle_for_view(view, cfg.k)
    want_ids, want_scores = oracle(pool.astype(np.uint32))
    for i, q in enumerate(pool):
        vv, ids = scorer(np.asarray(q, np.uint32))
        hit = np.isfinite(np.asarray(vv))
        np.testing.assert_array_equal(
            np.where(hit, np.asarray(ids), -1), want_ids[i])
        np.testing.assert_allclose(np.asarray(vv)[hit],
                                   want_scores[i][hit], rtol=1e-5,
                                   atol=1e-7)
    # and the server's responses are themselves oracle-exact at their
    # (newer) pinned epochs — serving never regressed while the stack
    # stayed consistent at ITS epoch
    _check_responses(server, tickets, cfg.k)


def test_snapshot_restore_mixed_layout_bitwise():
    """A MIXED hor+packed stack (per-seal layout overrides) round-trips
    through serialize/restore with each segment in its original layout,
    answers bit-identically, and stays bit-identical under identical
    future mutations."""
    from repro.core.layouts import BlockedIndex, PackedCsrIndex

    tc = corpus.generate(corpus.CorpusSpec(num_docs=400, vocab=250,
                                           avg_distinct=14, seed=31))
    si = SegmentedIndex(term_hashes=tc.term_hashes, delta_doc_capacity=96,
                        delta_posting_capacity=8192,
                        policy=compaction.TieredPolicy(min_run=100))
    for i, a in enumerate(range(0, 300, 75)):
        si.add_batch(_slices(tc, [a, a + 75])[0])
        si.seal(layout="packed" if i % 2 else "hor")
    si.delete([8, 120, 260])
    want_layouts = [s.layout for s in si.segments()]
    assert set(want_layouts) == {"hor", "packed"}

    state = serialize_segmented(si, lock=threading.RLock())
    si2 = restore_segmented(state)
    # structural roundtrip: every segment restored in its ORIGINAL
    # layout (not the index-wide default)
    assert [s.layout for s in si2.segments()] == want_layouts
    for s1, s2 in zip(si.segments(), si2.segments()):
        assert type(s1.index) is type(s2.index)
        if isinstance(s1.index, PackedCsrIndex):
            np.testing.assert_array_equal(np.asarray(s1.index.packed),
                                          np.asarray(s2.index.packed))
        else:
            assert isinstance(s1.index, BlockedIndex)
            np.testing.assert_array_equal(np.asarray(s1.index.block_docs),
                                          np.asarray(s2.index.block_docs))
    qh = corpus.sample_query_terms(np.asarray(si._df), si.term_hashes,
                                   6, 3, num_docs=si.live_doc_count,
                                   seed=2)
    r1, r2 = si.topk(qh, k=10), si2.topk(qh, k=10)
    np.testing.assert_array_equal(np.asarray(r1.doc_ids),
                                  np.asarray(r2.doc_ids))
    np.testing.assert_array_equal(np.asarray(r1.scores),
                                  np.asarray(r2.scores))
    # identical future mutations (incl. a packed seal) stay bitwise
    for target in (si, si2):
        target.add_batch(_slices(tc, [300, 400])[0])
        target.seal(layout="packed")
        target.delete([301])
    r1, r2 = si.topk(qh, k=10), si2.topk(qh, k=10)
    np.testing.assert_array_equal(np.asarray(r1.doc_ids),
                                  np.asarray(r2.doc_ids))
    np.testing.assert_array_equal(np.asarray(r1.scores),
                                  np.asarray(r2.scores))


@pytest.mark.slow
def test_serving_benchmark_long_sweep():
    """The daily-suite QPS sweep: more rates and requests than the
    PR-gating smoke, through the real threaded server + maintenance."""
    from benchmarks import common, serving
    tc = corpus.generate(corpus.CorpusSpec(num_docs=1500, vocab=600,
                                           avg_distinct=25, seed=42))
    host = build.bulk_build(tc)
    results = serving.run_sweep([25, 100, 400], 192, tc=tc, host=host)
    rates = [s["offered_qps"] for s in results if "offered_qps" in s]
    assert rates == [25, 100, 400]
    for s in results:
        if "offered_qps" not in s:
            continue
        assert s["requests"] == 192
        assert s["p99_us"] >= s["p50_us"] > 0
        assert 0.0 <= s["cache_hit_rate"] <= 1.0
        assert common.latency_summary(s["samples_us"]).startswith("p50=")
    lifecycle = results[-1]["lifecycle"]
    assert lifecycle["epoch"] > 0
