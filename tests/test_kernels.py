"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import build, layouts
from repro.core.query import idf as idf_fn
from repro.kernels import ops, ref
from repro.text import corpus


def _host(seed, docs=512, vocab=400, avg=25):
    tc = corpus.generate(corpus.CorpusSpec(num_docs=docs, vocab=vocab,
                                           avg_distinct=avg, seed=seed))
    return build.bulk_build(tc)


@pytest.mark.parametrize("seed,block,tile", [(0, 16, 128), (1, 32, 256),
                                             (2, 64, 128)])
@pytest.mark.slow
def test_posting_score_sweep(seed, block, tile):
    host = _host(seed)
    hor = layouts.build_blocked(host, block=block)
    qh = corpus.sample_query_terms(host.df, host.term_hashes, 1, 4,
                                   num_docs=host.num_docs, seed=seed)[0]
    tids = hor.lookup_terms(jnp.asarray(qh))
    w = idf_fn(hor.term_df(tids), host.num_docs)
    kw = dict(max_blocks_per_term=hor.max_blocks_per_term, max_pairs=8192)
    s_pl = ops.blocked_query_scores(hor, tids, w, tile=tile,
                                    backend="pallas", **kw)
    s_x = ops.blocked_query_scores(hor, tids, w, backend="xla", **kw)
    np.testing.assert_allclose(np.asarray(s_pl), np.asarray(s_x),
                               rtol=1e-5, atol=1e-6)


def test_posting_score_pair_overflow_counter():
    from repro.kernels.posting_score import build_pairs
    host = _host(3)
    hor = layouts.build_blocked(host, block=16)
    tfirst, tcount, n_tiles = ops.routing_spans(hor, 64)
    sel = jnp.arange(8, dtype=jnp.int32)
    valid = jnp.ones(8, bool)
    w = jnp.ones(8)
    *_, ovf = build_pairs(sel, valid, w, tfirst, tcount, n_tiles,
                          max_pairs=2)
    assert int(ovf) > 0      # too-small pair budget is REPORTED, not silent


@pytest.mark.parametrize("seed,block", [(0, 16), (1, 32), (2, 128)])
@pytest.mark.slow
def test_packed_unpack_sweep(seed, block):
    host = _host(seed)
    packed = layouts.build_packed_csr(host, block=block)
    d_pl = ops.unpack_postings(packed, backend="pallas")
    d_x = ops.unpack_postings(packed, backend="xla")
    assert (np.asarray(d_pl) == np.asarray(d_x)).all()
    # decoded ids reproduce the source postings exactly
    order = np.argsort(host.term_hashes, kind="stable")
    t0 = order[0]
    s, e = host.offsets[t0], host.offsets[t0 + 1]
    b0 = int(packed.block_offsets[0])
    got = np.asarray(d_pl[b0])[:e - s]
    np.testing.assert_array_equal(got[:min(block, e - s)],
                                  host.doc_ids[s:s + min(block, e - s)])


def _np_unpack_block(words, bits, base, count, block):
    """Independent numpy oracle for the bit-packed block decoder,
    including the kernel's exact int32 wrap-around semantics."""
    mask = (1 << bits) - 1 if bits < 32 else 0xFFFFFFFF
    deltas = np.zeros(block, np.int64)
    for lane in range(block):
        bitpos = lane * bits
        wi, off = divmod(bitpos, 32)
        lo = int(words[wi]) >> off
        hi = (int(words[min(wi + 1, len(words) - 1)]) << (32 - off)) \
            if off else 0
        deltas[lane] = (lo | hi) & mask
    docs = int(base) + np.cumsum(deltas)
    docs = ((docs + 2**31) % 2**32 - 2**31).astype(np.int32)  # i32 wrap
    return np.where(np.arange(block) < count, docs, -1)


@pytest.mark.parametrize("bits", list(range(4, 33)))
@pytest.mark.parametrize("block", [16, 128])
@pytest.mark.slow
def test_packed_unpack_bit_width_sweep(bits, block):
    """Cross-block bleed guard: the kernel's hi-word fetch clamps to the
    LAST WORD OF THE BLOCK, so every bit width whose final lane lands on
    a word boundary must still decode exactly — swept bits 4..32 against
    an independent numpy unpacker over adversarial random words."""
    rng = np.random.default_rng(bits * 1000 + block)
    nb = 8
    wpb = (block * bits + 31) // 32
    # random words with all-ones high bytes mixed in: if the clamped
    # hi-word fetch ever bled into a neighbouring lane, these would show
    words = rng.integers(0, 2**32, size=(nb, wpb), dtype=np.uint32)
    words[:, -1] |= np.uint32(0xFF000000)
    bits_a = np.full(nb, bits, np.int32)
    base_a = rng.integers(-5, 1000, size=nb).astype(np.int32)
    count_a = rng.integers(1, block + 1, size=nb).astype(np.int32)
    from repro.kernels.packed_postings import unpack_blocks_pallas
    got = np.asarray(unpack_blocks_pallas(
        jnp.asarray(words), jnp.asarray(bits_a), jnp.asarray(base_a),
        jnp.asarray(count_a), block, interpret=True))
    want = np.stack([_np_unpack_block(words[i], bits, base_a[i],
                                      count_a[i], block)
                     for i in range(nb)])
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("bits", [4, 7, 11, 13, 17, 23, 29, 31, 32])
@pytest.mark.slow
def test_pack_roundtrip_bit_width_sweep(bits):
    """pack -> kernel unpack is the identity for every bit width,
    including widths whose final lane straddles a u32 word boundary."""
    from repro.kernels.packed_postings import unpack_blocks_pallas
    rng = np.random.default_rng(bits)
    block = 128
    hi = min(1 << bits, 2**24)        # keep cumsum inside int32
    deltas = rng.integers(0, hi, size=block).astype(np.int64)
    deltas[-1] = hi - 1               # force the last lane's full width
    words = layouts._pack_block_np(deltas, bits, block)[None, :]
    got = np.asarray(unpack_blocks_pallas(
        jnp.asarray(words.astype(np.uint32)),
        jnp.asarray([bits], np.int32), jnp.asarray([0], np.int32),
        jnp.asarray([block], np.int32), block, interpret=True))[0]
    np.testing.assert_array_equal(got, np.cumsum(deltas).astype(np.int32))


@pytest.mark.parametrize("v,d,b,h,dtype", [
    (100, 8, 32, 4, jnp.float32),
    (500, 16, 64, 7, jnp.float32),
    (50, 32, 16, 2, jnp.bfloat16),
])
@pytest.mark.slow
def test_embedding_bag_sweep(v, d, b, h, dtype):
    rng = np.random.default_rng(v + b)
    tab = jnp.asarray(rng.normal(size=(v, d)).astype(np.float32)).astype(dtype)
    idx = jnp.asarray(rng.integers(-1, v, size=(b, h)).astype(np.int32))
    got = ops.embedding_bag(tab, idx, tile_b=min(16, b), backend="pallas")
    want = ops.embedding_bag(tab, idx, backend="xla")
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-6,
                               atol=1e-2 if dtype == jnp.bfloat16 else 1e-6)


@pytest.mark.parametrize("n,k,d,nsrc", [(32, 5, 8, 100), (64, 9, 16, 64)])
@pytest.mark.slow
def test_pna_multi_agg_sweep(n, k, d, nsrc):
    rng = np.random.default_rng(n + k)
    feats = jnp.asarray(rng.normal(size=(nsrc, d)).astype(np.float32))
    nbr = jnp.asarray(rng.integers(-1, nsrc, size=(n, k)).astype(np.int32))
    got = ops.pna_multi_agg(feats, nbr, tile_n=min(32, n), backend="pallas")
    want = ops.pna_multi_agg(feats, nbr, backend="xla")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                               atol=1e-5)


@pytest.mark.parametrize("causal,window,hq,hkv,s,d,dtype", [
    (True, 0, 4, 2, 64, 16, jnp.float32),
    (True, 24, 4, 4, 64, 16, jnp.float32),
    (False, 0, 2, 1, 32, 32, jnp.float32),
    (True, 16, 8, 2, 64, 16, jnp.bfloat16),
])
@pytest.mark.slow
def test_flash_attention_sweep(causal, window, hq, hkv, s, d, dtype):
    rng = np.random.default_rng(s + hq)
    q = jnp.asarray(rng.normal(size=(2, hq, s, d)).astype(np.float32)).astype(dtype)
    k = jnp.asarray(rng.normal(size=(2, hkv, s, d)).astype(np.float32)).astype(dtype)
    v = jnp.asarray(rng.normal(size=(2, hkv, s, d)).astype(np.float32)).astype(dtype)
    got = ops.attention(q, k, v, causal=causal, window=window,
                        backend="pallas", block_q=32, block_k=32)
    want = ops.attention(q, k, v, causal=causal, window=window,
                         backend="xla")
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=tol,
                               atol=tol)


def test_flash_matches_chunked_model_attention():
    """The Pallas kernel agrees with the model's chunked-XLA attention."""
    from repro.models.attention import chunked_attention
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(2, 4, 64, 16)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(2, 2, 64, 16)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, 2, 64, 16)).astype(np.float32))
    for window in (0, 24):
        a = chunked_attention(q, k, v, causal=True, window=window, chunk=16)
        b = ops.attention(q, k, v, causal=True, window=window,
                          backend="pallas", block_q=16, block_k=16)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                                   atol=1e-5)
