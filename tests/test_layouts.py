"""The four paper representations: equivalence, sizes, access paths."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import layouts, query
from repro.core.layouts import REPRESENTATIONS


def all_indexes(host):
    return {
        "pr": layouts.build_coo(host),
        "pr-hash": layouts.build_coo(host, lookup="hash"),
        "or": layouts.build_csr(host),
        "or-hash": layouts.build_csr(host, lookup="hash"),
        "cor": layouts.build_compact_csr(host),
        "hor": layouts.build_blocked(host, block=32),
        "packed": layouts.build_packed_csr(host, block=32),
    }


def test_scoring_equivalent_across_representations(small_host, query_hashes):
    """Table 3: every representation answers queries identically."""
    cap = small_host.max_posting_len
    idx = all_indexes(small_host)
    ref = query.score_queries(idx["or"], jnp.asarray(query_hashes), k=10,
                              cap=cap)
    for name, ix in idx.items():
        r = query.score_queries(ix, jnp.asarray(query_hashes), k=10, cap=cap)
        np.testing.assert_allclose(np.asarray(r.scores),
                                   np.asarray(ref.scores), rtol=2e-3,
                                   atol=1e-5, err_msg=name)


def test_size_ordering_matches_paper(small_host):
    """ORIF must be smaller than PR (paper §4.1: W < N_d always)."""
    idx = all_indexes(small_host)
    assert idx["or"].posting_bytes() < idx["pr"].posting_bytes()
    assert idx["cor"].nbytes() <= idx["or"].nbytes()


def test_packed_beats_csr_at_realistic_density():
    """Delta+bitpack wins once posting lists amortize the block padding
    (paper-scale df ~ 300k; here df ~ 266 >> block)."""
    from repro.core import build
    from repro.text import corpus
    tc = corpus.generate(corpus.CorpusSpec(num_docs=2000, vocab=300,
                                           avg_distinct=40, seed=2))
    host = build.bulk_build(tc)
    orx = layouts.build_csr(host)
    pk = layouts.build_packed_csr(host, block=128)
    assert pk.posting_bytes() < 0.7 * orx.posting_bytes()


def test_lookup_btree_vs_hash(small_host, query_hashes):
    """Paper Table 2: B+tree and Hash lookups give identical term ids."""
    bt = layouts.build_csr(small_host, lookup="btree")
    hs = layouts.build_csr(small_host, lookup="hash")
    q = jnp.asarray(query_hashes[0])
    assert (bt.lookup_terms(q) == hs.lookup_terms(q)).all()
    # absent terms -> -1
    missing = jnp.asarray([4242424242, 7], dtype=jnp.uint32)
    assert (bt.lookup_terms(missing) == -1).all()
    assert (hs.lookup_terms(missing) == -1).all()


def test_blocked_contains(small_host):
    """HOR's GIN-analogue doc-membership probe with block skipping."""
    hor = layouts.build_blocked(small_host, block=32)
    t = 5
    tid_sorted = int(np.searchsorted(
        np.asarray(hor.sorted_hash),
        np.uint32(small_host.term_hashes[t])))
    s, e = small_host.offsets[t], small_host.offsets[t + 1]
    member = int(small_host.doc_ids[s])         # a doc containing term t
    docs_in = set(small_host.doc_ids[s:e].tolist())
    non_member = next(d for d in range(small_host.num_docs)
                      if d not in docs_in)
    tids = jnp.asarray([tid_sorted])
    assert bool(hor.contains(tids, jnp.int32(member))[0])
    assert not bool(hor.contains(tids, jnp.int32(non_member))[0])


def test_doc_deletion(small_host, query_hashes):
    """Document deletion (norm zeroing) removes docs from results."""
    from repro.core.direct_index import delete_docs
    ix = layouts.build_csr(small_host)
    cap = small_host.max_posting_len
    r = query.score_query(ix, jnp.asarray(query_hashes[0]), k=5, cap=cap)
    victim = r.doc_ids[0]
    new_norm = delete_docs(ix.docs.norm, jnp.asarray([victim]))
    ix2 = layouts.CsrIndex(
        offsets=ix.offsets, doc_ids=ix.doc_ids, tfs=ix.tfs, df=ix.df,
        lookup=ix.lookup,
        docs=layouts.DocTable(norm=new_norm, rank=ix.docs.rank),
        max_posting_len=ix.max_posting_len)
    r2 = query.score_query(ix2, jnp.asarray(query_hashes[0]), k=5, cap=cap)
    assert int(victim) not in np.asarray(r2.doc_ids).tolist()


def test_gather_postings_sorted_and_valid(small_host):
    ix = layouts.build_csr(small_host)
    tid = jnp.asarray([0, 1, -1])
    d, t, v = ix.gather_postings(tid, cap=small_host.max_posting_len)
    d0 = np.asarray(d[0])[np.asarray(v[0])]
    assert (np.diff(d0) > 0).all()          # doc-sorted within a term
    assert not np.asarray(v[2]).any()       # absent term -> all invalid
