"""The four paper representations: equivalence, sizes, access paths."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import layouts, query
from repro.core.layouts import REPRESENTATIONS


def all_indexes(host):
    return {
        "pr": layouts.build_coo(host),
        "pr-hash": layouts.build_coo(host, lookup="hash"),
        "or": layouts.build_csr(host),
        "or-hash": layouts.build_csr(host, lookup="hash"),
        "cor": layouts.build_compact_csr(host),
        "hor": layouts.build_blocked(host, block=32),
        "packed": layouts.build_packed_csr(host, block=32),
    }


def test_scoring_equivalent_across_representations(small_host, query_hashes):
    """Table 3: every representation answers queries identically."""
    cap = small_host.max_posting_len
    idx = all_indexes(small_host)
    ref = query.score_queries(idx["or"], jnp.asarray(query_hashes), k=10,
                              cap=cap)
    for name, ix in idx.items():
        r = query.score_queries(ix, jnp.asarray(query_hashes), k=10, cap=cap)
        np.testing.assert_allclose(np.asarray(r.scores),
                                   np.asarray(ref.scores), rtol=2e-3,
                                   atol=1e-5, err_msg=name)


def test_size_ordering_matches_paper(small_host):
    """ORIF must be smaller than PR (paper §4.1: W < N_d always)."""
    idx = all_indexes(small_host)
    assert idx["or"].posting_bytes() < idx["pr"].posting_bytes()
    assert idx["cor"].nbytes() <= idx["or"].nbytes()


def test_packed_beats_csr_at_realistic_density():
    """Delta+bitpack wins once posting lists amortize the block padding
    (paper-scale df ~ 300k; here df ~ 266 >> block)."""
    from repro.core import build
    from repro.text import corpus
    tc = corpus.generate(corpus.CorpusSpec(num_docs=2000, vocab=300,
                                           avg_distinct=40, seed=2))
    host = build.bulk_build(tc)
    orx = layouts.build_csr(host)
    pk = layouts.build_packed_csr(host, block=128)
    assert pk.posting_bytes() < 0.7 * orx.posting_bytes()


def test_lookup_btree_vs_hash(small_host, query_hashes):
    """Paper Table 2: B+tree and Hash lookups give identical term ids."""
    bt = layouts.build_csr(small_host, lookup="btree")
    hs = layouts.build_csr(small_host, lookup="hash")
    q = jnp.asarray(query_hashes[0])
    assert (bt.lookup_terms(q) == hs.lookup_terms(q)).all()
    # absent terms -> -1
    missing = jnp.asarray([4242424242, 7], dtype=jnp.uint32)
    assert (bt.lookup_terms(missing) == -1).all()
    assert (hs.lookup_terms(missing) == -1).all()


def test_blocked_contains(small_host):
    """HOR's GIN-analogue doc-membership probe with block skipping."""
    hor = layouts.build_blocked(small_host, block=32)
    t = 5
    tid_sorted = int(np.searchsorted(
        np.asarray(hor.sorted_hash),
        np.uint32(small_host.term_hashes[t])))
    s, e = small_host.offsets[t], small_host.offsets[t + 1]
    member = int(small_host.doc_ids[s])         # a doc containing term t
    docs_in = set(small_host.doc_ids[s:e].tolist())
    non_member = next(d for d in range(small_host.num_docs)
                      if d not in docs_in)
    tids = jnp.asarray([tid_sorted])
    assert bool(hor.contains(tids, jnp.int32(member))[0])
    assert not bool(hor.contains(tids, jnp.int32(non_member))[0])


def test_doc_deletion(small_host, query_hashes):
    """Document deletion (norm zeroing) removes docs from results."""
    from repro.core.direct_index import delete_docs
    ix = layouts.build_csr(small_host)
    cap = small_host.max_posting_len
    r = query.score_query(ix, jnp.asarray(query_hashes[0]), k=5, cap=cap)
    victim = r.doc_ids[0]
    new_norm = delete_docs(ix.docs.norm, jnp.asarray([victim]))
    ix2 = layouts.CsrIndex(
        offsets=ix.offsets, doc_ids=ix.doc_ids, tfs=ix.tfs, df=ix.df,
        lookup=ix.lookup,
        docs=layouts.DocTable(norm=new_norm, rank=ix.docs.rank),
        max_posting_len=ix.max_posting_len)
    r2 = query.score_query(ix2, jnp.asarray(query_hashes[0]), k=5, cap=cap)
    assert int(victim) not in np.asarray(r2.doc_ids).tolist()


def test_gather_postings_sorted_and_valid(small_host):
    ix = layouts.build_csr(small_host)
    tid = jnp.asarray([0, 1, -1])
    d, t, v = ix.gather_postings(tid, cap=small_host.max_posting_len)
    d0 = np.asarray(d[0])[np.asarray(v[0])]
    assert (np.diff(d0) > 0).all()          # doc-sorted within a term
    assert not np.asarray(v[2]).any()       # absent term -> all invalid


def _fill_blocks_reference(h, block):
    """The pre-vectorization per-term python packing loop, kept verbatim
    as the byte-level reference for ``build_blocked``'s fill."""
    order = np.argsort(h.term_hashes, kind="stable")
    lengths = np.diff(h.offsets)[order]
    nblocks = -(-lengths // block)
    nblocks = np.maximum(nblocks, (lengths > 0).astype(nblocks.dtype))
    block_offsets = np.zeros(h.num_terms + 1, dtype=np.int64)
    np.cumsum(nblocks, out=block_offsets[1:])
    NB = int(block_offsets[-1])
    bd = np.full((NB, block), -1, dtype=np.int32)
    bt = np.zeros((NB, block), dtype=np.float32)
    for newpos, old in enumerate(order):
        s, e = h.offsets[old], h.offsets[old + 1]
        n = e - s
        b0 = block_offsets[newpos]
        flat_d = bd[b0:block_offsets[newpos + 1]].reshape(-1)
        flat_t = bt[b0:block_offsets[newpos + 1]].reshape(-1)
        flat_d[:n] = h.doc_ids[s:e]
        flat_t[:n] = h.tfs[s:e]
    return block_offsets, bd, bt


@pytest.mark.parametrize("block", [32, 128])
def test_build_blocked_vectorized_fill_matches_loop(small_host, block):
    """The np-bucketing block packer (seal hot path) emits byte-identical
    blocks to the old per-term python loop."""
    ref_offs, ref_bd, ref_bt = _fill_blocks_reference(small_host, block)
    ix = layouts.build_blocked(small_host, block=block)
    np.testing.assert_array_equal(np.asarray(ix.block_offsets),
                                  ref_offs.astype(np.int32))
    assert np.asarray(ix.block_docs).tobytes() == ref_bd.tobytes()
    assert np.asarray(ix.block_tfs).tobytes() == ref_bt.tobytes()


def test_build_blocked_vectorized_fill_edge_cases():
    """Empty terms, empty corpus, single oversized term."""
    hashes = np.array([7, 3, 9], np.uint32)
    # term 1 (hash 3) empty; term 2 spans 3 blocks of 4
    offsets = np.array([0, 2, 2, 12], np.int64)
    doc_ids = np.arange(12, dtype=np.int32)
    h = layouts.PostingsHost(
        term_hashes=hashes, df=np.array([2, 0, 10], np.int32),
        offsets=offsets, doc_ids=doc_ids,
        tfs=np.ones(12, np.float32), num_docs=16,
        norm=np.ones(16, np.float32), rank=np.zeros(16, np.float32))
    ref_offs, ref_bd, ref_bt = _fill_blocks_reference(h, 4)
    ix = layouts.build_blocked(h, block=4)
    assert np.asarray(ix.block_docs).tobytes() == ref_bd.tobytes()
    assert np.asarray(ix.block_tfs).tobytes() == ref_bt.tobytes()
    # empty corpus
    h0 = layouts.PostingsHost(
        term_hashes=np.zeros(0, np.uint32), df=np.zeros(0, np.int32),
        offsets=np.zeros(1, np.int64), doc_ids=np.zeros(0, np.int32),
        tfs=np.zeros(0, np.float32), num_docs=0,
        norm=np.zeros(0, np.float32), rank=np.zeros(0, np.float32))
    ix0 = layouts.build_blocked(h0)
    assert ix0.block_docs.shape[0] == 0


def test_pad_packed_to_class_roundtrip(small_host, query_hashes):
    """A size-class-padded packed index answers queries identically to
    the unpadded build (inert padding blocks, quantized statics)."""
    pk = layouts.build_packed_csr(small_host)
    nb = int(pk.packed.shape[0])
    padded = layouts.pad_packed_to_class(
        pk, nb_pad=layouts.size_class(nb),
        w_pad=layouts.size_class(pk.num_terms, base=256),
        max_posting_len=layouts.size_class(pk.max_posting_len),
        words_per_block=layouts.size_class(pk.words_per_block, base=8),
        route_pairs_max=layouts.size_class(pk.route_pairs_max),
        route_span_max=layouts.size_class(pk.route_span_max, base=8))
    cap = small_host.max_posting_len
    ref = query.score_queries(pk, jnp.asarray(query_hashes), k=10, cap=cap)
    got = query.score_queries(padded, jnp.asarray(query_hashes), k=10,
                              cap=cap)
    np.testing.assert_array_equal(np.asarray(got.doc_ids),
                                  np.asarray(ref.doc_ids))
    np.testing.assert_allclose(np.asarray(got.scores),
                               np.asarray(ref.scores), rtol=1e-6)
    with pytest.raises(ValueError):
        layouts.pad_packed_to_class(pk, nb_pad=1, w_pad=1,
                                    max_posting_len=1, words_per_block=1,
                                    route_pairs_max=1, route_span_max=1)
