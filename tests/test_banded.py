"""Banded packed segments: the uniform-stride floor fix.

A monolithic ``PackedCsrIndex`` stores every block at the segment-wide
``max(words_per_block)``, so one rare term whose deltas need 16 bits
inflates the stride of every dense term — a per-routed-block byte floor
(524/1032 = 0.508x-vs-hor at 16-bit deltas) that no amount of dense
data can cross.  ``layouts.build_banded`` cuts the vocabulary by
per-term packed width: dense terms go into a packed band with a
band-local stride, the decode-bound tail stays HOR.

The contract under test:

  * the byte model IS the builder: ``choose_band_cut`` +
    ``banded_posting_bytes_from_words`` price the built arrays to the
    byte, and on the engineered floor corpus the banded build's
    per-routed-block bytes drop from >= 0.5x-vs-hor to <= 0.49x;
  * banded top-k is bit-identical (ties included) to the HOR twin, the
    monolithic-packed twin, and the jnp oracle — single-host,
    doc-stacked, and term-sharded;
  * the band descriptor is state (snapshot v3 round-trips ``band_cut``
    bitwise; v2 snapshots still restore) and band membership is HOST
    metadata, so warm size classes add zero new jit entries.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import build, compaction, layouts, size_model
from repro.core.build import TokenizedCorpus
from repro.core.live_index import SegmentedIndex
from repro.kernels import ops
from repro.text import corpus
from repro.text.tokenizer import mix32

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _floor_corpus(num_docs=33_000, dense_docs=2048, dense_terms=10):
    """The merged-class floor reproduction: ``dense_terms`` terms dense
    over docs 0..dense_docs-1 (unit deltas — 4 packed words/block), a
    filler term in every doc (keeps all docs live), and ONE rare term in
    docs {0, num_docs-1} whose single gap needs 16 bits — inflating a
    monolithic segment's stride to 64 words/block."""
    rare = dense_terms + 1
    dense = np.arange(1, dense_terms + 1, dtype=np.int64)
    doc_term_ids, doc_counts = [], []
    for d in range(num_docs):
        ts = [np.array([0], np.int64)]
        if d < dense_docs:
            ts.append(dense)
        if d in (0, num_docs - 1):
            ts.append(np.array([rare], np.int64))
        ids = np.concatenate(ts)
        doc_term_ids.append(ids)
        doc_counts.append(np.ones(len(ids), np.int64))
    return TokenizedCorpus(
        doc_term_ids=doc_term_ids, doc_counts=doc_counts,
        term_hashes=mix32(np.arange(rare + 1, dtype=np.uint32)),
        num_docs=num_docs)


def _per_routed_block(words_per_block: int, block: int) -> float:
    """HBM bytes a query streams per routed packed block, over the HOR
    cost of the same block: (id words + f16 tfs + decode triple) /
    (i32 ids + f32 tfs + min/max bounds)."""
    return (words_per_block * 4 + block * 2 + 12) / (block * 8 + 8)


def _seal_three_ways(tc):
    out = {}
    for layout in ("hor", "packed", "banded"):
        si = SegmentedIndex(term_hashes=tc.term_hashes,
                            delta_doc_capacity=tc.num_docs,
                            delta_posting_capacity=80_000,
                            policy=compaction.TieredPolicy(min_run=100))
        si.add_batch(tc)
        si.seal(layout=layout)
        out[layout] = si
    return out


def test_uniform_stride_floor_engineered():
    """The tentpole acceptance: on the engineered merged-class corpus
    the monolithic packed stride sits AT the 0.508x floor, the banded
    packed band prices <= 0.49x — and all three layouts (plus the jnp
    oracle) answer bit-identically, ties included."""
    tc = _floor_corpus()
    tri = _seal_three_ways(tc)

    mono = tri["packed"].segments()[0].index
    assert int(mono.words_per_block) == 64          # inflated by 1 term
    mono_ratio = _per_routed_block(int(mono.words_per_block), mono.block)
    assert mono_ratio >= 0.5                        # the floor

    bseg = tri["banded"].segments()[0]
    assert bseg.layout == "banded" and bseg.band_cut >= 4
    band = bseg.index
    assert int(band.packed.words_per_block) < int(mono.words_per_block)
    band_ratio = _per_routed_block(int(band.packed.words_per_block),
                                   band.block)
    assert band_ratio <= 0.49                       # below the floor
    # the rare wide term lives in the HOR tail, dense terms packed
    assert int(np.asarray(band.hor.df).astype(np.int64).sum()) == 2
    assert int(np.count_nonzero(np.asarray(band.packed.df))) == 11

    # bit parity across the stack: ids AND scores, ties included
    dense_q = np.zeros((3, 8), np.uint32)
    dense_q[0, :3] = tc.term_hashes[[1, 2, 11]]     # dense + rare
    dense_q[1, :2] = tc.term_hashes[[3, 11]]
    dense_q[2, :4] = tc.term_hashes[[4, 5, 6, 7]]   # pure dense ties
    ref = tri["hor"].topk(dense_q, k=10)
    oracle = tri["banded"].topk(dense_q, k=10, engine="jnp")
    for si in (tri["packed"], tri["banded"]):
        got = si.topk(dense_q, k=10)
        np.testing.assert_array_equal(np.asarray(got.doc_ids),
                                      np.asarray(ref.doc_ids))
        np.testing.assert_array_equal(np.asarray(got.scores),
                                      np.asarray(ref.scores))
    np.testing.assert_array_equal(np.asarray(oracle.doc_ids),
                                  np.asarray(ref.doc_ids))
    np.testing.assert_allclose(np.asarray(oracle.scores),
                               np.asarray(ref.scores),
                               rtol=1e-5, atol=1e-7)


def test_byte_model_prices_banded_build_exactly():
    """``choose_band_cut`` + the exact-width estimator must equal the
    built (unpadded) arrays to the byte, and order the three layouts
    banded < monolithic packed < hor on the floor corpus."""
    host = build.bulk_build(_floor_corpus(num_docs=33_000, dense_docs=512,
                                          dense_terms=6))
    words, nblocks = layouts.term_packed_words(host)
    cut, predicted = size_model.choose_band_cut(words, nblocks)
    bix = layouts.build_banded(host)
    assert predicted == bix.posting_bytes()
    assert predicted == size_model.banded_posting_bytes_from_words(
        words, nblocks, cut)
    mono = layouts.build_packed_csr(host).posting_bytes()
    hor = size_model.hor_posting_bytes_from_df(host.df)
    assert bix.posting_bytes() < mono < hor
    # the realized band stride matches the cut's band-local max width
    in_band = (words > 0) & (words <= cut)
    assert int(bix.packed.words_per_block) == int(words[in_band].max())


def test_banded_chooser_slice():
    """Bounded chooser run for the PR lane: with banded as a candidate,
    small seals stay hor (decode-bound), the compacted merge flips
    banded via the byte model — and answers stay bit-identical to the
    jnp oracle through the flip."""
    tc = corpus.generate(corpus.CorpusSpec(num_docs=360, vocab=150,
                                           avg_distinct=12, seed=5))
    pol = size_model.LayoutCostModel(min_packed_docs=256,
                                     candidates=("hor", "banded"))
    si = SegmentedIndex(term_hashes=tc.term_hashes, delta_doc_capacity=90,
                        delta_posting_capacity=32_768,
                        policy=compaction.TieredPolicy(min_run=100),
                        layout_policy=pol)
    for a in range(0, 360, 90):
        si.add_batch(TokenizedCorpus(tc.doc_term_ids[a:a + 90],
                                     tc.doc_counts[a:a + 90],
                                     tc.term_hashes, 90))
        si.seal()
    assert [s.layout for s in si.segments()] == ["hor"] * 4
    qh = corpus.sample_query_terms(np.asarray(si._df), si.term_hashes,
                                   3, 3, num_docs=si.live_doc_count, seed=3)
    before = si.topk(qh, k=10)
    assert si.compact(all_segments=True)
    seg = si.segments()[0]
    assert seg.layout == "banded" and seg.band_cut > 0
    assert "bytes/q" in seg.chooser_reason
    assert si.layout_mix()["counts"] == {"banded": 1}
    after = si.topk(qh, k=10)
    np.testing.assert_array_equal(np.asarray(before.doc_ids),
                                  np.asarray(after.doc_ids))
    oracle = si.topk(qh, k=10, engine="jnp")
    np.testing.assert_array_equal(np.asarray(after.doc_ids),
                                  np.asarray(oracle.doc_ids))
    np.testing.assert_allclose(np.asarray(after.scores),
                               np.asarray(oracle.scores),
                               rtol=1e-5, atol=1e-7)


def test_banded_warm_class_zero_new_jit():
    """Two banded seals in the same size class (different band cuts —
    the cut is host metadata, not a pytree static) must reuse the
    warm engine: zero growth in the segment-scorer jit caches."""
    tc = corpus.generate(corpus.CorpusSpec(num_docs=400, vocab=200,
                                           avg_distinct=18, seed=7))
    si = SegmentedIndex(term_hashes=tc.term_hashes, delta_doc_capacity=200,
                        delta_posting_capacity=32_768,
                        policy=compaction.TieredPolicy(min_run=100),
                        seal_layout="banded")
    qh = None
    sizes = None
    for a in (0, 200):
        si.add_batch(TokenizedCorpus(tc.doc_term_ids[a:a + 200],
                                     tc.doc_counts[a:a + 200],
                                     tc.term_hashes, 200))
        si.seal()
        qh = corpus.sample_query_terms(np.asarray(si._df), si.term_hashes,
                                       2, 3, num_docs=si.live_doc_count,
                                       seed=9)
        si.topk(qh, k=10)
        if sizes is None:
            sizes = ops.segment_scorer_cache_sizes()     # warm after seg 1
    segs = si.segments()
    assert [s.layout for s in segs] == ["banded", "banded"]
    assert all(s.band_cut > 0 for s in segs)
    assert segs[0].size_class == segs[1].size_class
    assert ops.segment_scorer_cache_sizes() == sizes     # zero growth


def test_banded_snapshot_v3_roundtrip_and_v2_back_compat(tmp_path):
    from repro.serve import snapshot

    tc = corpus.generate(corpus.CorpusSpec(num_docs=300, vocab=140,
                                           avg_distinct=11, seed=13))
    si = SegmentedIndex(term_hashes=tc.term_hashes, delta_doc_capacity=150,
                        delta_posting_capacity=32_768,
                        policy=compaction.TieredPolicy(min_run=100))
    for a, layout in ((0, "banded"), (150, "hor")):
        si.add_batch(TokenizedCorpus(tc.doc_term_ids[a:a + 150],
                                     tc.doc_counts[a:a + 150],
                                     tc.term_hashes, 150))
        si.seal(layout=layout)
    qh = corpus.sample_query_terms(np.asarray(si._df), si.term_hashes,
                                   3, 3, num_docs=si.live_doc_count, seed=1)
    want = si.topk(qh, k=10)
    path = tmp_path / "snap.npz"
    snapshot.save_segmented(si, path)
    rt = snapshot.load_segmented(path)
    assert [s.layout for s in rt.segments()] == ["banded", "hor"]
    assert [s.band_cut for s in rt.segments()] == \
        [s.band_cut for s in si.segments()]
    assert rt.segments()[0].band_cut > 0
    # the restored band membership is bitwise: same cut -> same arrays
    a, b = si.segments()[0].index, rt.segments()[0].index
    np.testing.assert_array_equal(np.asarray(a.packed.df),
                                  np.asarray(b.packed.df))
    assert int(a.packed.words_per_block) == int(b.packed.words_per_block)
    got = rt.topk(qh, k=10)
    np.testing.assert_array_equal(np.asarray(want.doc_ids),
                                  np.asarray(got.doc_ids))
    np.testing.assert_array_equal(np.asarray(want.scores),
                                  np.asarray(got.scores))

    # a v2 snapshot (no band_cut in the manifest) must still restore:
    # non-banded segments rebuild identically, the version check passes
    state = snapshot.serialize_segmented(si)
    meta = json.loads(bytes(np.asarray(state["meta"])).decode())
    meta["version"] = 2
    for sm in meta["segments"]:
        del sm["band_cut"]
        sm["layout"] = "hor"          # v2 never sealed banded segments
    state["meta"] = np.frombuffer(json.dumps(meta).encode(), dtype=np.uint8)
    old = snapshot.restore_segmented(state)
    assert [s.layout for s in old.segments()] == ["hor", "hor"]
    assert all(s.band_cut == 0 for s in old.segments())


BANDED_SHARDED_SCRIPT = r"""
import numpy as np, jax
from repro.text import corpus
from repro.core.build import TokenizedCorpus
from repro.core.live_index import SegmentedIndex
from repro.distributed import retrieval

tc = corpus.generate(corpus.CorpusSpec(num_docs=800, vocab=400,
                                       avg_distinct=30, seed=9))
si = SegmentedIndex(term_hashes=tc.term_hashes, delta_doc_capacity=400,
                    seal_layout="banded")
for a in range(0, 800, 200):
    si.add_batch(TokenizedCorpus(tc.doc_term_ids[a:a + 200],
                                 tc.doc_counts[a:a + 200],
                                 tc.term_hashes, 200))
    si.seal()
view = si.view()
assert all(s.layout == "banded" and s.band_cut > 0 for s in view.segments)

qh = corpus.sample_query_terms(np.asarray(si._df), si.term_hashes, 4, 4,
                               num_docs=si.live_doc_count, seed=2)
k = 10
ref = view.topk(qh, k)
ref_ids, ref_scores = np.asarray(ref.doc_ids), np.asarray(ref.scores)
mesh = jax.make_mesh((4,), ("shards",))

# doc-stacked banded groups: BITWISE equal to the single host
stacks = retrieval.stack_segment_shards(view, 4)
assert all(m.layout == "banded" for m, _ in stacks.groups)
scorer = retrieval.make_doc_sharded_segment_scorer(stacks, mesh,
                                                   "shards", k=k)
for i in range(len(qh)):
    vv, ii = scorer(qh[i])
    vv, ii = np.asarray(vv), np.asarray(ii)
    hit = np.isfinite(vv)
    np.testing.assert_array_equal(
        np.where(hit, ii, -1).astype(np.int32), ref_ids[i])
    np.testing.assert_array_equal(np.where(hit, vv, 0.0), ref_scores[i])

# warm-class rebuild: zero stack-scorer cache growth
before = retrieval.stack_scorer_cache_sizes()
s2 = retrieval.make_doc_sharded_segment_scorer(
    retrieval.stack_segment_shards(si.view(), 4), mesh, "shards", k=k)
s2(qh[0])
assert retrieval.stack_scorer_cache_sizes() == before, (
    before, retrieval.stack_scorer_cache_sizes())

# term-sharded banded: ids bit-identical, scores to psum tolerance
tix, live_ids = retrieval.build_term_sharded_from_view(view, 4,
                                                       layout="banded")
assert type(tix).__name__ == "BandedTermShardedIndex"
tscorer = retrieval.make_term_sharded_fused_scorer(tix, mesh, "shards",
                                                   k=k)
for i in range(len(qh)):
    vv, ii = tscorer(qh[i])
    vv, ii = np.asarray(vv), np.asarray(ii)
    hit = np.isfinite(vv) & (ii >= 0)
    gids = np.where(hit, live_ids[np.maximum(ii, 0)], -1).astype(np.int32)
    np.testing.assert_array_equal(gids, ref_ids[i])
    np.testing.assert_allclose(np.where(hit, vv, 0.0), ref_scores[i],
                               rtol=1e-5, atol=1e-6)

# banded is NOT a bulk doc-sharded layout: the stack tier serves it
try:
    retrieval.build_doc_sharded_fused(
        __import__("repro.core.build", fromlist=["bulk_build"])
        .bulk_build(tc), 2, layout="banded")
    raise SystemExit("bulk banded did not raise")
except ValueError as e:
    assert "segment-stack" in str(e)

print("BANDED_SHARDED_OK")
"""


def test_banded_sharded_parity_subprocess():
    """Doc-stacked banded groups are BITWISE equal to the single-host
    answer across 4 shards; term-sharded banded matches to psum
    tolerance with bit-identical ids; warm-class rebuilds add zero jit
    entries; and the bulk doc-sharded path refuses banded loudly
    (subprocess: XLA device count must be set before jax init)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", BANDED_SHARDED_SCRIPT],
                         env=env, capture_output=True, text=True,
                         timeout=1200)
    assert "BANDED_SHARDED_OK" in out.stdout, out.stderr[-4000:]
