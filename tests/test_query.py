"""Query evaluation vs a brute-force numpy oracle."""
import jax.numpy as jnp
import numpy as np

from repro.core import layouts, query


def brute_force_scores(host, hashes):
    """Direct tf-idf cosine from the canonical postings."""
    h2t = {int(h): i for i, h in enumerate(host.term_hashes)}
    scores = np.zeros(host.num_docs)
    idf = {}
    w2 = 0.0
    for h in hashes:
        t = h2t.get(int(h))
        if t is None or h == 0:
            continue
        idf_t = np.log1p(host.num_docs / max(host.df[t], 1))
        idf[t] = idf_t
        w2 += idf_t ** 2
        s, e = host.offsets[t], host.offsets[t + 1]
        scores[host.doc_ids[s:e]] += host.tfs[s:e] * idf_t
    qnorm = np.sqrt(max(w2, 1e-12))
    return scores / (np.maximum(host.norm, 1e-12) * qnorm)


def test_matches_brute_force(small_host, query_hashes):
    ix = layouts.build_csr(small_host)
    cap = small_host.max_posting_len
    for q in query_hashes[:3]:
        r = query.score_query(ix, jnp.asarray(q), k=10, cap=cap)
        ref = brute_force_scores(small_host, q)
        order = np.argsort(ref)[::-1][:10]
        np.testing.assert_allclose(np.asarray(r.scores), ref[order],
                                   rtol=1e-5)


def test_conjunctive_is_subset_of_disjunctive(small_host, query_hashes):
    ix = layouts.build_csr(small_host)
    cap = small_host.max_posting_len
    q = jnp.asarray(query_hashes[0][:2])
    conj, _ = query.conjunctive_filter(ix, q, k=50, cap=cap)
    h2t = {int(h): i for i, h in enumerate(small_host.term_hashes)}
    for d in np.asarray(conj.doc_ids):
        if d < 0:
            continue
        for h in np.asarray(q):
            t = h2t[int(h)]
            s, e = small_host.offsets[t], small_host.offsets[t + 1]
            assert d in small_host.doc_ids[s:e]


def test_conjunctive_counts_are_exact_ints(small_host, query_hashes):
    """Regression: AND-membership counting must use an integer
    accumulator — float32 loses integer exactness past 2**24, which
    silently mis-filters long posting lists."""
    ix = layouts.build_csr(small_host)
    cap = small_host.max_posting_len
    q = jnp.asarray(query_hashes[0][:2])
    counts_dtype = query.accumulate_counts(
        jnp.zeros((1, 4), jnp.int32), jnp.ones((1, 4), bool), 8).dtype
    assert counts_dtype == jnp.int32
    # AND result equals the numpy ground truth doc set
    conj, _ = query.conjunctive_filter(ix, q, k=small_host.num_docs, cap=cap)
    got = set(int(d) for d in np.asarray(conj.doc_ids) if d >= 0)
    h2t = {int(h): i for i, h in enumerate(small_host.term_hashes)}
    want = None
    for h in np.asarray(q):
        t = h2t[int(h)]
        s, e = small_host.offsets[t], small_host.offsets[t + 1]
        docs = set(small_host.doc_ids[s:e].tolist())
        want = docs if want is None else want & docs
    assert got == want


def test_absent_and_empty_terms(small_host):
    ix = layouts.build_csr(small_host)
    cap = small_host.max_posting_len
    q = jnp.asarray([0, 0, 0, 0], dtype=jnp.uint32)      # empty query
    r = query.score_query(ix, q, k=5, cap=cap)
    assert (np.asarray(r.doc_ids) == -1).all()


def test_duplicate_terms_score_once(small_host, query_hashes):
    """Regression: the same term hash in two query slots must contribute
    ONCE — the gather phase reads one posting list per slot, so without
    dedup tf·idf weight is double-counted and the query norm inflates."""
    ix = layouts.build_csr(small_host)
    cap = small_host.max_posting_len
    h = query_hashes[0][0]
    single = jnp.asarray(np.array([h, 0, 0, 0], np.uint32))
    doubled = jnp.asarray(np.array([h, h, 0, h], np.uint32))
    rs = query.score_query(ix, single, k=10, cap=cap)
    rd = query.score_query(ix, doubled, k=10, cap=cap)
    np.testing.assert_array_equal(np.asarray(rs.doc_ids),
                                  np.asarray(rd.doc_ids))
    np.testing.assert_allclose(np.asarray(rs.scores), np.asarray(rd.scores))


def test_dedup_query_hashes_keeps_first_only():
    qh = jnp.asarray(np.array([[7, 7, 0, 7], [1, 2, 1, 2]], np.uint32))
    got = np.asarray(query.dedup_query_hashes(qh))
    np.testing.assert_array_equal(got, [[7, 0, 0, 0], [1, 2, 0, 0]])


def test_conjunctive_duplicate_terms_keep_and_semantics(small_host,
                                                        query_hashes):
    """A duplicated AND term must not change the result set (it used to
    inflate both the membership counts and the needed threshold, and
    double-count the score weights)."""
    ix = layouts.build_csr(small_host)
    cap = small_host.max_posting_len
    q2 = np.asarray(query_hashes[0][:2])
    plain, _ = query.conjunctive_filter(ix, jnp.asarray(q2), k=50, cap=cap)
    dup = np.array([q2[0], q2[1], q2[0], q2[1]], np.uint32)
    doubled, _ = query.conjunctive_filter(ix, jnp.asarray(dup), k=50,
                                          cap=cap)
    np.testing.assert_array_equal(np.asarray(plain.doc_ids),
                                  np.asarray(doubled.doc_ids))
    np.testing.assert_allclose(np.asarray(plain.scores),
                               np.asarray(doubled.scores))


def test_conjunctive_cap_truncation_is_surfaced(small_host, query_hashes):
    """A cap that truncates a posting list can undercount membership and
    silently drop true AND matches — the filter must SURFACE it."""
    ix = layouts.build_csr(small_host)
    cap = small_host.max_posting_len
    q = jnp.asarray(query_hashes[0][:2])
    _, stats = query.conjunctive_filter(ix, q, k=10, cap=cap)
    assert int(stats["truncated_terms"]) == 0      # full cap: exact
    h2t = {int(h): i for i, h in enumerate(small_host.term_hashes)}
    min_df = min(int(small_host.df[h2t[int(h)]]) for h in np.asarray(q))
    _, stats = query.conjunctive_filter(ix, q, k=10, cap=min_df - 1)
    assert int(stats["truncated_terms"]) > 0       # truncated: flagged
