"""Query evaluation vs a brute-force numpy oracle."""
import jax.numpy as jnp
import numpy as np

from repro.core import layouts, query


def brute_force_scores(host, hashes):
    """Direct tf-idf cosine from the canonical postings."""
    h2t = {int(h): i for i, h in enumerate(host.term_hashes)}
    scores = np.zeros(host.num_docs)
    idf = {}
    w2 = 0.0
    for h in hashes:
        t = h2t.get(int(h))
        if t is None or h == 0:
            continue
        idf_t = np.log1p(host.num_docs / max(host.df[t], 1))
        idf[t] = idf_t
        w2 += idf_t ** 2
        s, e = host.offsets[t], host.offsets[t + 1]
        scores[host.doc_ids[s:e]] += host.tfs[s:e] * idf_t
    qnorm = np.sqrt(max(w2, 1e-12))
    return scores / (np.maximum(host.norm, 1e-12) * qnorm)


def test_matches_brute_force(small_host, query_hashes):
    ix = layouts.build_csr(small_host)
    cap = small_host.max_posting_len
    for q in query_hashes[:3]:
        r = query.score_query(ix, jnp.asarray(q), k=10, cap=cap)
        ref = brute_force_scores(small_host, q)
        order = np.argsort(ref)[::-1][:10]
        np.testing.assert_allclose(np.asarray(r.scores), ref[order],
                                   rtol=1e-5)


def test_conjunctive_is_subset_of_disjunctive(small_host, query_hashes):
    ix = layouts.build_csr(small_host)
    cap = small_host.max_posting_len
    q = jnp.asarray(query_hashes[0][:2])
    conj = query.conjunctive_filter(ix, q, k=50, cap=cap)
    h2t = {int(h): i for i, h in enumerate(small_host.term_hashes)}
    for d in np.asarray(conj.doc_ids):
        if d < 0:
            continue
        for h in np.asarray(q):
            t = h2t[int(h)]
            s, e = small_host.offsets[t], small_host.offsets[t + 1]
            assert d in small_host.doc_ids[s:e]


def test_conjunctive_counts_are_exact_ints(small_host, query_hashes):
    """Regression: AND-membership counting must use an integer
    accumulator — float32 loses integer exactness past 2**24, which
    silently mis-filters long posting lists."""
    ix = layouts.build_csr(small_host)
    cap = small_host.max_posting_len
    q = jnp.asarray(query_hashes[0][:2])
    counts_dtype = query.accumulate_counts(
        jnp.zeros((1, 4), jnp.int32), jnp.ones((1, 4), bool), 8).dtype
    assert counts_dtype == jnp.int32
    # AND result equals the numpy ground truth doc set
    conj = query.conjunctive_filter(ix, q, k=small_host.num_docs, cap=cap)
    got = set(int(d) for d in np.asarray(conj.doc_ids) if d >= 0)
    h2t = {int(h): i for i, h in enumerate(small_host.term_hashes)}
    want = None
    for h in np.asarray(q):
        t = h2t[int(h)]
        s, e = small_host.offsets[t], small_host.offsets[t + 1]
        docs = set(small_host.doc_ids[s:e].tolist())
        want = docs if want is None else want & docs
    assert got == want


def test_absent_and_empty_terms(small_host):
    ix = layouts.build_csr(small_host)
    cap = small_host.max_posting_len
    q = jnp.asarray([0, 0, 0, 0], dtype=jnp.uint32)      # empty query
    r = query.score_query(ix, q, k=5, cap=cap)
    assert (np.asarray(r.doc_ids) == -1).all()
