"""Adaptive per-segment layout selection: the override ladder, the
LayoutCostModel chooser, and its threading through seal, compaction,
maintenance rewrites, snapshots, and serving metrics.

The contract under test, layer by layer:

  * ``resolve_layout`` is THE ladder — explicit arg > policy >
    historical default — and a None/None resolution is bit-identical to
    the pre-chooser constants (the same discipline as the empty tuning
    table).
  * The analytic chooser is size-gated: small seals stay hor
    (decode-bound), merged compaction outputs cross ``min_packed_docs``
    and flip packed — which is what makes an LSM stack CONVERGE to the
    winning layout, deterministically.
  * Every re-layout (seal, compact, maintenance rewrite) keeps top-k
    answers bit-identical to the jnp oracle, ties included.
  * The decision is STATE: layout + chooser reason survive snapshot
    save/restore bitwise, alongside the policy itself.
"""
import numpy as np
import pytest

from repro.core import compaction, size_model
from repro.core.build import TokenizedCorpus
from repro.core.live_index import SegmentedIndex
from repro.kernels import autotune
from repro.text import corpus


def _slices(tc, bounds):
    return [TokenizedCorpus(tc.doc_term_ids[a:b], tc.doc_counts[a:b],
                            tc.term_hashes, b - a)
            for a, b in zip(bounds[:-1], bounds[1:])]


def _build(tc, bounds, seed=0, **kwargs):
    si = SegmentedIndex(term_hashes=tc.term_hashes,
                        delta_doc_capacity=max(b - a for a, b in
                                               zip(bounds[:-1], bounds[1:])),
                        delta_posting_capacity=32_768,
                        policy=compaction.TieredPolicy(min_run=100),
                        **kwargs)
    for b in _slices(tc, bounds):
        si.add_batch(b)
        si.seal()
    return si


def _queries(si, n=4, seed=3):
    return corpus.sample_query_terms(np.asarray(si._df), si.term_hashes,
                                     n, 3, num_docs=si.live_doc_count,
                                     seed=seed)


def _assert_same_answers(a, b, qh, k=10):
    ra, rb = a.topk(qh, k=k), b.topk(qh, k=k)
    np.testing.assert_array_equal(np.asarray(ra.doc_ids),
                                  np.asarray(rb.doc_ids))
    np.testing.assert_array_equal(np.asarray(ra.scores),
                                  np.asarray(rb.scores))


def _assert_oracle_parity(si, qh, k=10):
    fused, oracle = si.topk(qh, k=k), si.topk(qh, k=k, engine="jnp")
    np.testing.assert_array_equal(np.asarray(fused.doc_ids),
                                  np.asarray(oracle.doc_ids))
    np.testing.assert_allclose(np.asarray(fused.scores),
                               np.asarray(oracle.scores),
                               rtol=1e-5, atol=1e-7)


STATS_BIG = size_model.SegmentStats(num_docs=20_000, num_postings=400_000,
                                    num_terms=2_000)
STATS_SMALL = size_model.SegmentStats(num_docs=300, num_postings=6_000,
                                      num_terms=400)


# ---------------------------------------------------------------------------
# the ladder
# ---------------------------------------------------------------------------


def test_ladder_precedence():
    pol = size_model.LayoutCostModel(min_packed_docs=1_000)
    # explicit beats a policy that would choose the other layout
    assert size_model.resolve_layout("hor", pol, STATS_BIG, "hor") == \
        ("hor", "explicit")
    assert size_model.resolve_layout("packed", None, STATS_SMALL,
                                     "hor") == ("packed", "explicit")
    # policy beats the default
    lay, reason = size_model.resolve_layout(None, pol, STATS_BIG, "hor")
    assert lay == "packed" and reason.startswith("analytic:bytes/q")
    lay, reason = size_model.resolve_layout(None, pol, STATS_SMALL,
                                            "packed")
    assert lay == "hor" and "small-segment" in reason
    # None/None falls through to the historical default
    assert size_model.resolve_layout(None, None, STATS_BIG, "hor") == \
        ("hor", "default")
    assert size_model.resolve_layout(None, None, STATS_BIG, "packed") == \
        ("packed", "default")


def test_none_policy_bit_identical_to_constants():
    """An index with no policy must behave EXACTLY like the pre-chooser
    code: every seal takes the constructor default, reasons stay
    'default', and answers match an explicitly-sealed twin bitwise."""
    tc = corpus.generate(corpus.CorpusSpec(num_docs=240, vocab=120,
                                           avg_distinct=10, seed=21))
    bounds = [0, 80, 160, 240]
    auto = _build(tc, bounds)                       # layout_policy=None
    explicit = SegmentedIndex(term_hashes=tc.term_hashes,
                              delta_doc_capacity=80,
                              delta_posting_capacity=32_768,
                              policy=compaction.TieredPolicy(min_run=100))
    for b in _slices(tc, bounds):
        explicit.add_batch(b)
        explicit.seal(layout="hor")
    assert [s.layout for s in auto.segments()] == ["hor"] * 3
    assert [s.chooser_reason for s in auto.segments()] == ["default"] * 3
    assert auto.pick_layout_rewrite() is None
    _assert_same_answers(auto, explicit, _queries(auto))


# ---------------------------------------------------------------------------
# chooser + convergence
# ---------------------------------------------------------------------------


def test_size_gated_flip_and_compaction_convergence():
    """Small seals stay hor; compacting them into one run that crosses
    min_packed_docs flips the merged segment packed — and answers stay
    bit-identical to the oracle through the flip."""
    tc = corpus.generate(corpus.CorpusSpec(num_docs=360, vocab=150,
                                           avg_distinct=12, seed=5))
    si = _build(tc, [0, 90, 180, 270, 360],
                layout_policy=size_model.LayoutCostModel(
                    min_packed_docs=256))
    assert [s.layout for s in si.segments()] == ["hor"] * 4
    assert all("small-segment" in s.chooser_reason
               for s in si.segments())
    qh = _queries(si)
    before = si.topk(qh, k=10)
    assert si.compact(all_segments=True)
    segs = si.segments()
    assert [s.layout for s in segs] == ["packed"]
    assert "bytes/q" in segs[0].chooser_reason
    assert si.pick_layout_rewrite() is None          # converged
    after = si.topk(qh, k=10)
    np.testing.assert_array_equal(np.asarray(before.doc_ids),
                                  np.asarray(after.doc_ids))
    np.testing.assert_allclose(np.asarray(before.scores),
                               np.asarray(after.scores), rtol=1e-6)
    _assert_oracle_parity(si, qh)
    mix = si.layout_mix()
    assert mix["counts"] == {"packed": 1}
    assert list(mix["reasons"]) == [segs[0].chooser_reason]


def test_maintenance_rewrites_converge_quiescent_stack():
    """A stack sealed hor by explicit override converges to the policy's
    mix through bounded per-run maintenance rewrites — no ingest, no
    compaction triggers, just ``pick_layout_rewrite`` walking the
    mismatches oldest-first."""
    import threading

    from repro.serve.maintenance import IndexMaintenance

    tc = corpus.generate(corpus.CorpusSpec(num_docs=300, vocab=130,
                                           avg_distinct=10, seed=8))
    si = SegmentedIndex(term_hashes=tc.term_hashes,
                        delta_doc_capacity=100,
                        delta_posting_capacity=32_768,
                        policy=compaction.TieredPolicy(min_run=100))
    for b in _slices(tc, [0, 100, 200, 300]):
        si.add_batch(b)
        si.seal(layout="hor")
    qh = _queries(si)
    want = si.topk(qh, k=10)
    mt = IndexMaintenance(
        si, threading.RLock(),
        layout_policy=size_model.LayoutCostModel(min_packed_docs=64),
        max_rewrites_per_run=1)
    # oldest-first, one segment per run: hor count strictly decreases
    for want_hor in (2, 1, 0):
        did = mt.run_once()
        assert did["rewritten"] == 1
        counts = si.layout_mix()["counts"]
        assert counts.get("hor", 0) == want_hor
    assert mt.run_once()["rewritten"] == 0           # converged
    assert mt.stats.layout_rewrites == 3
    assert si.stats.layout_rewrites == 3
    got = si.topk(qh, k=10)
    np.testing.assert_array_equal(np.asarray(want.doc_ids),
                                  np.asarray(got.doc_ids))
    np.testing.assert_allclose(np.asarray(want.scores),
                               np.asarray(got.scores), rtol=1e-6)
    _assert_oracle_parity(si, qh)


def test_pick_layout_rewrite_policy_function():
    assert compaction.pick_layout_rewrite([], []) is None
    assert compaction.pick_layout_rewrite(["hor"], ["hor"]) is None
    assert compaction.pick_layout_rewrite(["hor", "packed"],
                                          ["packed", "packed"]) == 0
    assert compaction.pick_layout_rewrite(["packed", "hor", "hor"],
                                          ["packed", "packed", "hor"]) == 1


# ---------------------------------------------------------------------------
# measured costs (tuning-table integration)
# ---------------------------------------------------------------------------


def test_measured_costs_override_analytic():
    """When the sweep has timed BOTH layouts at the exact (backend,
    size_class), the chooser trusts the measurement — even against the
    analytic gate — and the costs survive table serialization."""
    table = autotune.TuningTable()
    cfg = autotune.TuneConfig(tile=1024)
    # measured: hor faster despite the byte model preferring packed
    table.put("pallas", 2048, "hor", cfg, cost_s=1e-4)
    table.put("pallas", 2048, "packed", cfg, cost_s=5e-4)
    assert table.cost("pallas", 2048, "hor") == pytest.approx(1e-4)
    assert table.cost("pallas", 4096, "hor") is None   # exact class only
    rt = autotune.TuningTable.from_dict(table.to_dict())
    assert rt.cost("pallas", 2048, "packed") == pytest.approx(5e-4)
    assert rt.get("pallas", 2048, "hor") == cfg

    prev = autotune.set_active(table)
    try:
        pol = size_model.LayoutCostModel(min_packed_docs=64)
        big = size_model.SegmentStats(2_000, 60_000, 500)
        d = pol.choose(big, size_class=2048)
        assert d.layout == "hor"
        assert d.reason.startswith("measured:pallas@2048")
        # one-sided sweeps fall back to the analytic model
        d = pol.choose(big, size_class=4096)
        assert d.layout == "packed" and d.reason.startswith("analytic")
    finally:
        autotune.set_active(prev)


# ---------------------------------------------------------------------------
# snapshots + serving surfaces
# ---------------------------------------------------------------------------


def test_snapshot_roundtrip_preserves_policy_and_decisions(tmp_path):
    from repro.serve import snapshot

    tc = corpus.generate(corpus.CorpusSpec(num_docs=300, vocab=140,
                                           avg_distinct=11, seed=13))
    pol = size_model.LayoutCostModel(min_packed_docs=128,
                                     hbm_ratio_max=0.8)
    si = _build(tc, [0, 60, 160, 300], layout_policy=pol)
    si.delete([5, 61])
    si.compact(all_segments=True)
    qh = _queries(si)
    path = tmp_path / "snap.npz"
    snapshot.save_segmented(si, path)
    rt = snapshot.load_segmented(path)
    assert rt.layout_policy == pol
    assert [s.layout for s in rt.segments()] == \
        [s.layout for s in si.segments()]
    assert [s.chooser_reason for s in rt.segments()] == \
        [s.chooser_reason for s in si.segments()]
    assert rt.layout_mix() == si.layout_mix()
    _assert_same_answers(si, rt, qh)


def test_server_reports_layout_mix():
    from repro.serve import QueryServer, ServerConfig

    tc = corpus.generate(corpus.CorpusSpec(num_docs=200, vocab=100,
                                           avg_distinct=10, seed=2))
    si = _build(tc, [0, 100, 200])
    pol = size_model.LayoutCostModel(min_packed_docs=64)
    server = QueryServer(si, ServerConfig(backend="xla",
                                          layout_policy=pol))
    assert si.layout_policy is pol                  # installed at init
    mix = server.metrics.summary()["layout_mix"]
    assert mix["counts"] == {"hor": 2}              # sealed pre-policy
    assert "segments" not in mix                    # aggregates only
    # converge the stack, serve once: the fresh epoch's mix is reported
    si.compact(all_segments=True)
    server.query(_queries(si, n=1)[0])
    mix = server.metrics.summary()["layout_mix"]
    assert mix["counts"] == {"packed": 1}


# ---------------------------------------------------------------------------
# bounded auto-layout fuzz (the per-PR "not slow" slice — deterministic
# seeds so it runs without the optional hypothesis dep; the full drawn
# schedule space runs daily via tests/test_properties.py)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed,min_docs,compact",
                         [(11, 64, True), (23, 128, False),
                          (37, 128, True), (51, 1024, True),
                          (67, 64, False), (83, 1024, False)])
def test_auto_layout_fuzz_bounded(seed, min_docs, compact):
    """Chooser-driven seal/compact schedules on small corpora: whatever
    mix the policy converges to, the fused engine stays bit-identical
    to the jnp oracle, and every segment carries a chooser reason."""
    rng = np.random.default_rng(seed)
    tc = corpus.generate(corpus.CorpusSpec(
        num_docs=int(rng.integers(120, 260)),
        vocab=int(rng.integers(60, 160)),
        avg_distinct=int(rng.integers(6, 14)), seed=seed))
    n = tc.num_docs
    bounds = [0, n // 3, 2 * (n // 3), n]
    si = _build(tc, bounds,
                layout_policy=size_model.LayoutCostModel(
                    min_packed_docs=min_docs))
    if compact:
        si.compact(all_segments=True)
        while (i := si.pick_layout_rewrite()) is not None:
            si.rewrite_segment(i)
    assert all(s.chooser_reason != "default" for s in si.segments())
    for s in si.segments():
        want, _ = size_model.resolve_layout(None, si.layout_policy,
                                            s.stats, "hor",
                                            size_class=s.size_class)
        assert s.layout == want
    _assert_oracle_parity(si, _queries(si, n=2, seed=seed))


def test_partial_sweep_reason_is_honest():
    """A sweep that timed only ONE candidate layout must not masquerade
    as a measurement: the decision comes from the byte model and the
    reason says so — 'analytic:partial-measured(<swept>)' — while still
    starting with 'analytic' so reason-prefix consumers keep working."""
    table = autotune.TuningTable()
    table.put("pallas", 2048, "hor", autotune.TuneConfig(), cost_s=1e-4)
    prev = autotune.set_active(table)
    try:
        pol = size_model.LayoutCostModel(min_packed_docs=64)
        big = size_model.SegmentStats(2_000, 60_000, 500)
        d = pol.choose(big, size_class=2048)
        assert d.reason.startswith("analytic:partial-measured(hor) ")
        assert d.reason.startswith("analytic")
        # the decision itself matches the pure-analytic twin
        ref = pol.choose(big, size_class=4096)       # nothing swept there
        assert d.layout == ref.layout == "packed"
        assert ref.reason.startswith("analytic:bytes/q")
        assert "partial" not in ref.reason
    finally:
        autotune.set_active(prev)
