"""Distributed engine tests — run in a subprocess with 8 host devices
(XLA_FLAGS must be set before jax initializes; the main test process
must keep seeing 1 device)."""
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.text import corpus
from repro.core import build, layouts, query
from repro.distributed import retrieval, compress, decode_attn, topk
from repro.distributed.shmap import shard_map

mesh = jax.make_mesh((8,), ("data",))

tc = corpus.generate(corpus.CorpusSpec(num_docs=640, vocab=500,
                                       avg_distinct=30, seed=9))
host = build.bulk_build(tc)
ref_ix = layouts.build_csr(host)
qh = corpus.sample_query_terms(host.df, host.term_hashes, 3, 3,
                               num_docs=host.num_docs)

# 1) document-partitioned == single-node (scores AND doc sets)
ds = retrieval.build_doc_sharded(host, 8)
scorer = retrieval.make_doc_sharded_scorer(ds, mesh, "data", k=10)
for q in qh:
    vv, ids = scorer(jnp.asarray(q))
    ref = query.score_query(ref_ix, jnp.asarray(q), k=10,
                            cap=host.max_posting_len)
    np.testing.assert_allclose(np.asarray(vv), np.asarray(ref.scores),
                               rtol=1e-5)
    assert set(np.asarray(ids).tolist()) == \
        set(np.asarray(ref.doc_ids).tolist())

# 1b) document-partitioned FUSED Pallas engine == single-node
bs = retrieval.build_doc_sharded_blocked(host, 8)
fscorer = retrieval.make_doc_sharded_fused_scorer(bs, mesh, "data", k=10)
for q in qh:
    vv, ids = fscorer(jnp.asarray(q))
    ref = query.score_query(ref_ix, jnp.asarray(q), k=10,
                            cap=host.max_posting_len)
    np.testing.assert_allclose(np.asarray(vv), np.asarray(ref.scores),
                               rtol=1e-5)
    assert set(np.asarray(ids).tolist()) == \
        set(np.asarray(ref.doc_ids).tolist())

# 1c) PACKED document-partitioned fused engine: per-shard packed
#     rebuild (identical shard bounds, posting order, and block
#     boundaries as 1b) — must be BIT-identical (values and ids, ties
#     included) to the HOR fused engine under the same candidate-merge
#     tier; the ladder front door returns the same index + a reason
ps = retrieval.build_doc_sharded_packed(host, 8)
pscorer = retrieval.make_doc_sharded_fused_scorer(ps, mesh, "data", k=10)
for q in qh:
    pv, pi = pscorer(jnp.asarray(q))
    hv, hi = fscorer(jnp.asarray(q))
    np.testing.assert_array_equal(np.asarray(pv), np.asarray(hv))
    np.testing.assert_array_equal(np.asarray(pi), np.asarray(hi))
lad, reason = retrieval.build_doc_sharded_fused(host, 8, layout="packed")
assert isinstance(lad, retrieval.PackedDocShardedIndex), lad
assert reason == "explicit", reason
lad2, reason2 = retrieval.build_doc_sharded_fused(host, 8)
assert isinstance(lad2, retrieval.BlockedDocShardedIndex), lad2
assert reason2 == "default", reason2

# 2) term-partitioned == single-node
ts = retrieval.build_term_sharded(host, 8)
tscorer = retrieval.make_term_sharded_scorer(ts, mesh, "data", k=10)
for q in qh:
    tv, ti = tscorer(jnp.asarray(q))
    ref = query.score_query(ref_ix, jnp.asarray(q), k=10,
                            cap=host.max_posting_len)
    np.testing.assert_allclose(np.asarray(tv), np.asarray(ref.scores),
                               rtol=1e-5)

# 2b) term-partitioned FUSED Pallas engine == single-node (per-shard
#     fused partial scores -> [D] psum -> sharded candidate extraction
#     -> candidate merge)
tb = retrieval.build_term_sharded_blocked(host, 8)
tfscorer = retrieval.make_term_sharded_fused_scorer(tb, mesh, "data", k=10)
for q in qh:
    tv, ti = tfscorer(jnp.asarray(q))
    ref = query.score_query(ref_ix, jnp.asarray(q), k=10,
                            cap=host.max_posting_len)
    np.testing.assert_allclose(np.asarray(tv), np.asarray(ref.scores),
                               rtol=1e-5)
    assert set(np.asarray(ti).tolist()) == \
        set(np.asarray(ref.doc_ids).tolist())

# 2d) PACKED term-sharded fused engine: per-vocab-shard re-compression,
#     in-VMEM decode, [D] psum, sharded candidate extraction — must be
#     BIT-identical (values and ids, ties included) to the HOR
#     term-sharded engine, which shares its slicing and block geometry
tp = retrieval.build_term_sharded_packed(host, 8)
tpscorer = retrieval.make_term_sharded_fused_scorer(tp, mesh, "data", k=10)
for q in qh:
    pv, pi = tpscorer(jnp.asarray(q))
    hv, hi = tfscorer(jnp.asarray(q))
    np.testing.assert_array_equal(np.asarray(pv), np.asarray(hv))
    np.testing.assert_array_equal(np.asarray(pi), np.asarray(hi))
    ref = query.score_query(ref_ix, jnp.asarray(q), k=10,
                            cap=host.max_posting_len)
    np.testing.assert_allclose(np.asarray(pv), np.asarray(ref.scores),
                               rtol=1e-5)
    assert set(np.asarray(pi).tolist()) == \
        set(np.asarray(ref.doc_ids).tolist())

# 2e) cap truncation surfaces ACROSS shards: truncated_terms is psum'd
#     (like the multi-segment conjunctive sums per-segment counters),
#     and the capped ranking matches the capped single-node oracle
cap = 8
qt = qh[0]
dfg = np.asarray(host.df)
expect_trunc = sum(
    1 for h in np.unique(qt[qt != 0])
    for pos in [np.flatnonzero(host.term_hashes == h)]
    if len(pos) and dfg[pos[0]] > cap)
capped = retrieval.make_term_sharded_fused_scorer(
    tp, mesh, "data", k=10, cap=cap, return_stats=True)
(cv, ci), st = capped(jnp.asarray(qt))
assert st["truncated_terms"] == expect_trunc, st
ref_c = query.score_query(ref_ix, jnp.asarray(qt), k=10, cap=cap)
np.testing.assert_allclose(np.asarray(cv), np.asarray(ref_c.scores),
                           rtol=1e-5)

# 2c) term-sharded vs doc-sharded fused agreement on a 2x2 mesh: docs
#     partitioned over axis "x", vocabulary over axis "y" — the two
#     fused engines must return identical rankings
mesh22 = jax.sharding.Mesh(np.array(jax.devices()[:4]).reshape(2, 2),
                           ("x", "y"))
bs2 = retrieval.build_doc_sharded_blocked(host, 2)
tb2 = retrieval.build_term_sharded_blocked(host, 2)
dsc = retrieval.make_doc_sharded_fused_scorer(bs2, mesh22, "x", k=10)
tsc = retrieval.make_term_sharded_fused_scorer(tb2, mesh22, "y", k=10)
for q in qh:
    dv, di = dsc(jnp.asarray(q))
    tv, ti = tsc(jnp.asarray(q))
    np.testing.assert_allclose(np.asarray(dv), np.asarray(tv), rtol=1e-5)
    assert set(np.asarray(di).tolist()) == set(np.asarray(ti).tolist())

# 3) distributed top-k over a sharded score vector
fn = topk.sharded_topk(mesh, "data")(5)
scores = jnp.arange(64, dtype=jnp.float32)
v, i = fn(scores)
assert np.asarray(i).tolist() == [63, 62, 61, 60, 59]

# 3b) k exceeding the shard-local length (top_k needs k <= n): local
#     top-k is clamped and padded with -inf / -1 before the merge
fn = topk.sharded_topk(mesh, "data")(20)
v, i = fn(jnp.arange(64, dtype=jnp.float32))   # local length 8 < k=20
assert np.asarray(i)[:5].tolist() == [63, 62, 61, 60, 59]
assert np.asarray(v).tolist() == list(range(63, 43, -1))
fused_k = retrieval.make_doc_sharded_fused_scorer(bs, mesh, "data",
                                                  k=2 * host.num_docs // 8)
vv, ids = fused_k(jnp.asarray(qh[0]))   # k > docs-per-shard
ref = query.score_query(ref_ix, jnp.asarray(qh[0]),
                        k=2 * host.num_docs // 8,
                        cap=host.max_posting_len)
hits = np.asarray(ref.doc_ids) >= 0
np.testing.assert_allclose(np.asarray(vv)[hits],
                           np.asarray(ref.scores)[hits], rtol=1e-5)
assert set(np.asarray(ids)[hits].tolist()) == \
    set(np.asarray(ref.doc_ids)[hits].tolist())

# 4) int8 compressed grad mean ~ identity within quantization error
x = jnp.asarray(np.random.default_rng(0).normal(size=(128,))
                .astype(np.float32))
cm = jax.jit(shard_map(
    lambda v: compress.quantized_psum_mean(v, "data", 8),
    mesh=mesh, in_specs=P(), out_specs=P(), check_vma=False))
np.testing.assert_allclose(np.asarray(cm(x)), np.asarray(x), rtol=0.1,
                           atol=0.05)

# 5) split-K decode attention == single-device oracle
from repro.models.attention import decode_attention
rng = np.random.default_rng(1)
q = jnp.asarray(rng.normal(size=(2, 4, 1, 16)).astype(np.float32))
kc = jnp.asarray(rng.normal(size=(2, 2, 64, 16)).astype(np.float32))
vc = jnp.asarray(rng.normal(size=(2, 2, 64, 16)).astype(np.float32))
cl = jnp.asarray([50, 63], jnp.int32)
sk = decode_attn.splitk_decode_attention(mesh, "data")
for w in (0, 16):
    got = sk(q, kc, vc, cl, window=w)
    want = decode_attention(q, kc, vc, cl, window=w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=1e-5)

# 6) GSPMD decode attention with seq-sharded cache == oracle (the
#    long_500k cell's partitioning, small scale)
from jax.sharding import NamedSharding
kc_sh = jax.device_put(kc, NamedSharding(mesh, P(None, None, "data", None)))
vc_sh = jax.device_put(vc, NamedSharding(mesh, P(None, None, "data", None)))
got = jax.jit(decode_attention)(q, kc_sh, vc_sh, cl)
want = decode_attention(q, kc, vc, cl)
np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4,
                           atol=1e-5)
print("DISTRIBUTED_ALL_OK")
"""


MIXED_STACK_SCRIPT = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.text import corpus
from repro.core import build, compaction
from repro.core.build import TokenizedCorpus
from repro.core.live_index import SegmentedIndex
from repro.distributed import retrieval

mesh = jax.make_mesh((4,), ("data",))
tc = corpus.generate(corpus.CorpusSpec(num_docs=600, vocab=400,
                                       avg_distinct=20, seed=13))
si = SegmentedIndex(term_hashes=tc.term_hashes, delta_doc_capacity=128,
                    delta_posting_capacity=8192,
                    policy=compaction.TieredPolicy(min_run=100))
layouts_cycle = ["hor", "packed", "hor", "packed", "hor", "packed"]
for i, a in enumerate(range(0, 600, 100)):
    si.add_batch(TokenizedCorpus(tc.doc_term_ids[a:a+100],
                                 tc.doc_counts[a:a+100],
                                 tc.term_hashes, 100))
    si.seal(layout=layouts_cycle[i])
si.delete([3, 155, 470, 599])

stacks = retrieval.stack_segment_shards(si, 4)
assert {m.layout for m, _ in stacks.groups} == {"hor", "packed"}
scorer = retrieval.make_doc_sharded_segment_scorer(stacks, mesh, "data",
                                                   k=10)
qh = corpus.sample_query_terms(np.asarray(si._df), si.term_hashes, 4, 3,
                               num_docs=si.live_doc_count, seed=3)
for q in qh:
    vv, ids = scorer(jnp.asarray(q))
    ref = si.topk(q[None], k=10)
    # mixed hor+packed groups interleave doc ranges; the canonicalized
    # candidate merge still reproduces the single-node ranking EXACTLY
    # (ties included)
    np.testing.assert_array_equal(np.asarray(ids),
                                  np.asarray(ref.doc_ids)[0])
    np.testing.assert_allclose(np.asarray(vv),
                               np.asarray(ref.scores)[0], rtol=1e-5)
    assert not np.isin(np.asarray(ids), [3, 155, 470, 599]).any()
print("MIXED_STACK_SHARDED_OK")

# zero new jit entries on a same-class rebuild: seal one more segment
# whose content is IDENTICAL to an earlier batch (so every quantized
# static lands in an existing (size_class, layout) group), rebuild the
# stack at the newer epoch, and the warm compiled scorer is reused
snap = retrieval.stack_scorer_cache_sizes()
si.add_batch(TokenizedCorpus(tc.doc_term_ids[0:100], tc.doc_counts[0:100],
                             tc.term_hashes, 100))
si.seal(layout="packed")
stacks2 = retrieval.stack_segment_shards(si, 4)
assert stacks2.signature() == stacks.signature(), (
    stacks2.signature(), stacks.signature())
scorer2 = retrieval.make_doc_sharded_segment_scorer(stacks2, mesh, "data",
                                                    k=10)
for q in qh[:2]:
    vv, ids = scorer2(jnp.asarray(q))
    ref = si.topk(q[None], k=10)
    np.testing.assert_array_equal(np.asarray(ids),
                                  np.asarray(ref.doc_ids)[0])
assert retrieval.stack_scorer_cache_sizes() == snap, (
    snap, retrieval.stack_scorer_cache_sizes())
print("MIXED_STACK_CACHE_OK")
"""


def test_mixed_stack_sharded_serving():
    """Packed and mixed hor+packed sealed-segment stacks shard across 4
    host devices, answer bit-identically to the single-node live index,
    and a same-class stack rebuild reuses the warm compiled scorer
    (zero new jit entries) — the PR-job guard on the packed distributed
    tier."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", MIXED_STACK_SCRIPT],
                         env=env, capture_output=True, text=True,
                         timeout=500)
    assert "MIXED_STACK_SHARDED_OK" in out.stdout, out.stderr[-3000:]
    assert "MIXED_STACK_CACHE_OK" in out.stdout, out.stderr[-3000:]


EDGE_CASE_SCRIPT = r"""
import dataclasses
import numpy as np, jax, jax.numpy as jnp
from repro.core import build, layouts, query
from repro.core.build import TokenizedCorpus
from repro.distributed import retrieval

mesh = jax.make_mesh((2,), ("data",))

# engineered corpus: 8 terms with ascending hashes (hash-sorted order ==
# term id), 1024 docs == exactly 2 doc tiles == one tile per shard
H = np.array([10, 20, 30, 40, 50, 60, 70, 80], np.uint32)
D = 1024
docs, counts = [], []
for d in range(D):
    t, c = [0], [1]                      # term 0 in EVERY doc: deltas
    if 512 <= d < 640:                   #   of 1 -> 1-bit packed blocks
        t.append(6); c.append(5)         # term 6: tile-1 docs, strong tf
    if d in (0, 700):
        t.append(5); c.append(2)         # term 5: one block, gap of 700
    if 100 <= d < 110:
        t.append(3); c.append(1)         # term 3: last term of shard 0
    if 200 <= d < 210:
        t.append(4); c.append(1)         # term 4: first term of shard 1
    if 300 <= d < 330:
        t.append(2); c.append(1)
    if 900 <= d < 910:
        t.append(7); c.append(1)
    docs.append(np.asarray(t, np.int64))
    counts.append(np.asarray(c, np.int64))
host = build.bulk_build(TokenizedCorpus(docs, counts, H, D))
ref_ix = layouts.build_csr(host)

tb = retrieval.build_term_sharded_blocked(host, 2)
tp = retrieval.build_term_sharded_packed(host, 2)
# term 0's consecutive doc ids really did pack at width 1
assert (np.asarray(tp.block_bits)[np.asarray(tp.block_count) > 0] == 1
        ).any(), np.asarray(tp.block_bits)
sh = retrieval.make_term_sharded_fused_scorer(tb, mesh, "data", k=10)
sp = retrieval.make_term_sharded_fused_scorer(tp, mesh, "data", k=10)

# a query whose terms sit on BOTH sides of the vocab-shard boundary
# (term 3 = last term of shard 0, term 4 = first term of shard 1), plus
# the 1-bit and wide-delta terms
queries = [np.array([40, 50, 10], np.uint32),     # boundary straddle
           np.array([10, 60, 0], np.uint32),      # 1-bit + gap block
           np.array([70, 10, 0], np.uint32)]
for q in queries:
    hv, hi = sh(jnp.asarray(q))
    pv, pi = sp(jnp.asarray(q))
    np.testing.assert_array_equal(np.asarray(pv), np.asarray(hv))
    np.testing.assert_array_equal(np.asarray(pi), np.asarray(hi))
    ref = query.score_query(ref_ix, jnp.asarray(q), k=10,
                            cap=host.max_posting_len)
    np.testing.assert_allclose(np.asarray(pv), np.asarray(ref.scores),
                               rtol=1e-5)
    assert set(np.asarray(pi).tolist()) == \
        set(np.asarray(ref.doc_ids).tolist())
print("EDGE_PARITY_OK")

# 32-bit delta width: re-encode term 5's single block (deltas [1, 700])
# at the full 32-bit width — the format is width-agnostic, so the
# re-encoded index must answer bit-identically
spos = 1                 # term 5 (hash 60) is slot 1 of shard 1's vocab
blk = int(np.asarray(tp.block_offsets)[1, spos])
deltas = np.zeros(128, np.int64)
deltas[0], deltas[1] = 1, 700            # doc 0 (base -1), then doc 700
wide = layouts._pack_block_np(deltas, 32, 128)
wpb32 = len(wide)
pk = np.zeros((tp.packed.shape[0], tp.packed.shape[1], wpb32), np.uint32)
pk[:, :, :tp.packed.shape[2]] = tp.packed
pk[1, blk, :] = 0
pk[1, blk, :wpb32] = wide
bits = tp.block_bits.copy()
bits[1, blk] = 32
tp32 = dataclasses.replace(tp, packed=pk, block_bits=bits,
                           words_per_block=wpb32)
sp32 = retrieval.make_term_sharded_fused_scorer(tp32, mesh, "data", k=10)
for q in queries:
    pv, pi = sp(jnp.asarray(q))
    wv, wi = sp32(jnp.asarray(q))
    np.testing.assert_array_equal(np.asarray(wv), np.asarray(pv))
    np.testing.assert_array_equal(np.asarray(wi), np.asarray(pi))
print("EDGE_32BIT_OK")

# an all-tombstoned tile "winning" a shard-local top-k: kill every doc
# of shard 1's tile (512..1023) — exactly where term 6's strong hits
# live; the dead tile's candidates are all (-inf, -1) and must never
# displace live docs in the merge
norm_dead = host.norm.copy()
norm_dead[512:1024] = 0.0
host_dead = dataclasses.replace(host, norm=norm_dead)
tb_d = retrieval.build_term_sharded_blocked(host_dead, 2)
tp_d = retrieval.build_term_sharded_packed(host_dead, 2)
ref_d = layouts.build_csr(host_dead)
sh_d = retrieval.make_term_sharded_fused_scorer(tb_d, mesh, "data", k=10)
sp_d = retrieval.make_term_sharded_fused_scorer(tp_d, mesh, "data", k=10)
q6 = np.array([70, 10, 0], np.uint32)
for sc in (sh_d, sp_d):
    dv, di = sc(jnp.asarray(q6))
    di = np.asarray(di)
    assert not ((di >= 512) & (di < 1024)).any(), di
    ref = query.score_query(ref_d, jnp.asarray(q6), k=10,
                            cap=host.max_posting_len)
    np.testing.assert_allclose(np.asarray(dv), np.asarray(ref.scores),
                               rtol=1e-5)
    assert set(di.tolist()) == set(np.asarray(ref.doc_ids).tolist())
# a query hitting ONLY the dead tile returns no hits at all
q_only = np.array([70, 0, 0], np.uint32)
dv, di = sp_d(jnp.asarray(q_only))
assert (np.asarray(di) == -1).all(), np.asarray(di)
print("EDGE_TOMBSTONE_OK")

# k greater than the shard-local candidate count (one 512-wide tile per
# shard, k_tile caps at 512): the merge clamps and pads with -inf / -1
k_big = 600
sp_k = retrieval.make_term_sharded_fused_scorer(tp, mesh, "data", k=k_big)
bv, bi = sp_k(jnp.asarray(queries[0]))
ref = query.score_query(ref_ix, jnp.asarray(queries[0]), k=k_big,
                        cap=host.max_posting_len)
hits = np.asarray(ref.doc_ids) >= 0
np.testing.assert_allclose(np.asarray(bv)[hits],
                           np.asarray(ref.scores)[hits], rtol=1e-5)
assert set(np.asarray(bi)[hits].tolist()) == \
    set(np.asarray(ref.doc_ids)[hits].tolist())
print("EDGE_KBIG_OK")
"""


def test_packed_term_sharded_edge_cases():
    """Engineered bit-width and boundary cases through the packed
    term-sharded fused path: 1-bit and 32-bit delta widths, query terms
    straddling the vocab-shard boundary, an all-tombstoned tile that
    would have won a shard-local top-k, and k exceeding the shard-local
    candidate count."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", EDGE_CASE_SCRIPT],
                         env=env, capture_output=True, text=True,
                         timeout=500)
    for marker in ("EDGE_PARITY_OK", "EDGE_32BIT_OK",
                   "EDGE_TOMBSTONE_OK", "EDGE_KBIG_OK"):
        assert marker in out.stdout, (marker, out.stderr[-3000:])


@pytest.mark.parametrize("n_dev", [8])
def test_distributed_suite(n_dev):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=500)
    assert "DISTRIBUTED_ALL_OK" in out.stdout, out.stderr[-3000:]


def test_smoke_cell_dryrun_on_host_mesh():
    """Lower+compile a smoke cell on a tiny 4-device mesh end to end —
    the same machinery the production dry-run uses."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    script = r"""
import jax
from repro import configs
mesh = jax.make_mesh((2, 2), ("data", "model"))
for arch_id, shape_id in [("qwen3-0.6b", "train_4k"),
                          ("mixtral-8x7b", "decode_32k"),
                          ("pna", "full_graph_sm"),
                          ("xdeepfm", "serve_bulk")]:
    cell = configs.get_arch(arch_id).cell(shape_id, scale="smoke",
                                          mesh_axes=("data", "model"))
    sh = cell.make_shardings(mesh)
    with mesh:
        c = jax.jit(cell.fn, in_shardings=sh,
                    donate_argnums=cell.donate).lower(
            *cell.abstract_args).compile()
    assert c.memory_analysis() is not None
print("SMOKE_DRYRUN_OK")
"""
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=500)
    assert "SMOKE_DRYRUN_OK" in out.stdout, out.stderr[-3000:]
