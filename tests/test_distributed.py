"""Distributed engine tests — run in a subprocess with 8 host devices
(XLA_FLAGS must be set before jax initializes; the main test process
must keep seeing 1 device)."""
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.text import corpus
from repro.core import build, layouts, query
from repro.distributed import retrieval, compress, decode_attn, topk
from repro.distributed.shmap import shard_map

mesh = jax.make_mesh((8,), ("data",))

tc = corpus.generate(corpus.CorpusSpec(num_docs=640, vocab=500,
                                       avg_distinct=30, seed=9))
host = build.bulk_build(tc)
ref_ix = layouts.build_csr(host)
qh = corpus.sample_query_terms(host.df, host.term_hashes, 3, 3,
                               num_docs=host.num_docs)

# 1) document-partitioned == single-node (scores AND doc sets)
ds = retrieval.build_doc_sharded(host, 8)
scorer = retrieval.make_doc_sharded_scorer(ds, mesh, "data", k=10)
for q in qh:
    vv, ids = scorer(jnp.asarray(q))
    ref = query.score_query(ref_ix, jnp.asarray(q), k=10,
                            cap=host.max_posting_len)
    np.testing.assert_allclose(np.asarray(vv), np.asarray(ref.scores),
                               rtol=1e-5)
    assert set(np.asarray(ids).tolist()) == \
        set(np.asarray(ref.doc_ids).tolist())

# 1b) document-partitioned FUSED Pallas engine == single-node
bs = retrieval.build_doc_sharded_blocked(host, 8)
fscorer = retrieval.make_doc_sharded_fused_scorer(bs, mesh, "data", k=10)
for q in qh:
    vv, ids = fscorer(jnp.asarray(q))
    ref = query.score_query(ref_ix, jnp.asarray(q), k=10,
                            cap=host.max_posting_len)
    np.testing.assert_allclose(np.asarray(vv), np.asarray(ref.scores),
                               rtol=1e-5)
    assert set(np.asarray(ids).tolist()) == \
        set(np.asarray(ref.doc_ids).tolist())

# 2) term-partitioned == single-node
ts = retrieval.build_term_sharded(host, 8)
tscorer = retrieval.make_term_sharded_scorer(ts, mesh, "data", k=10)
for q in qh:
    tv, ti = tscorer(jnp.asarray(q))
    ref = query.score_query(ref_ix, jnp.asarray(q), k=10,
                            cap=host.max_posting_len)
    np.testing.assert_allclose(np.asarray(tv), np.asarray(ref.scores),
                               rtol=1e-5)

# 2b) term-partitioned FUSED Pallas engine == single-node (per-shard
#     fused partial scores -> [D] psum -> sharded candidate extraction
#     -> candidate merge)
tb = retrieval.build_term_sharded_blocked(host, 8)
tfscorer = retrieval.make_term_sharded_fused_scorer(tb, mesh, "data", k=10)
for q in qh:
    tv, ti = tfscorer(jnp.asarray(q))
    ref = query.score_query(ref_ix, jnp.asarray(q), k=10,
                            cap=host.max_posting_len)
    np.testing.assert_allclose(np.asarray(tv), np.asarray(ref.scores),
                               rtol=1e-5)
    assert set(np.asarray(ti).tolist()) == \
        set(np.asarray(ref.doc_ids).tolist())

# 2c) term-sharded vs doc-sharded fused agreement on a 2x2 mesh: docs
#     partitioned over axis "x", vocabulary over axis "y" — the two
#     fused engines must return identical rankings
mesh22 = jax.sharding.Mesh(np.array(jax.devices()[:4]).reshape(2, 2),
                           ("x", "y"))
bs2 = retrieval.build_doc_sharded_blocked(host, 2)
tb2 = retrieval.build_term_sharded_blocked(host, 2)
dsc = retrieval.make_doc_sharded_fused_scorer(bs2, mesh22, "x", k=10)
tsc = retrieval.make_term_sharded_fused_scorer(tb2, mesh22, "y", k=10)
for q in qh:
    dv, di = dsc(jnp.asarray(q))
    tv, ti = tsc(jnp.asarray(q))
    np.testing.assert_allclose(np.asarray(dv), np.asarray(tv), rtol=1e-5)
    assert set(np.asarray(di).tolist()) == set(np.asarray(ti).tolist())

# 3) distributed top-k over a sharded score vector
fn = topk.sharded_topk(mesh, "data")(5)
scores = jnp.arange(64, dtype=jnp.float32)
v, i = fn(scores)
assert np.asarray(i).tolist() == [63, 62, 61, 60, 59]

# 3b) k exceeding the shard-local length (top_k needs k <= n): local
#     top-k is clamped and padded with -inf / -1 before the merge
fn = topk.sharded_topk(mesh, "data")(20)
v, i = fn(jnp.arange(64, dtype=jnp.float32))   # local length 8 < k=20
assert np.asarray(i)[:5].tolist() == [63, 62, 61, 60, 59]
assert np.asarray(v).tolist() == list(range(63, 43, -1))
fused_k = retrieval.make_doc_sharded_fused_scorer(bs, mesh, "data",
                                                  k=2 * host.num_docs // 8)
vv, ids = fused_k(jnp.asarray(qh[0]))   # k > docs-per-shard
ref = query.score_query(ref_ix, jnp.asarray(qh[0]),
                        k=2 * host.num_docs // 8,
                        cap=host.max_posting_len)
hits = np.asarray(ref.doc_ids) >= 0
np.testing.assert_allclose(np.asarray(vv)[hits],
                           np.asarray(ref.scores)[hits], rtol=1e-5)
assert set(np.asarray(ids)[hits].tolist()) == \
    set(np.asarray(ref.doc_ids)[hits].tolist())

# 4) int8 compressed grad mean ~ identity within quantization error
x = jnp.asarray(np.random.default_rng(0).normal(size=(128,))
                .astype(np.float32))
cm = jax.jit(shard_map(
    lambda v: compress.quantized_psum_mean(v, "data", 8),
    mesh=mesh, in_specs=P(), out_specs=P(), check_vma=False))
np.testing.assert_allclose(np.asarray(cm(x)), np.asarray(x), rtol=0.1,
                           atol=0.05)

# 5) split-K decode attention == single-device oracle
from repro.models.attention import decode_attention
rng = np.random.default_rng(1)
q = jnp.asarray(rng.normal(size=(2, 4, 1, 16)).astype(np.float32))
kc = jnp.asarray(rng.normal(size=(2, 2, 64, 16)).astype(np.float32))
vc = jnp.asarray(rng.normal(size=(2, 2, 64, 16)).astype(np.float32))
cl = jnp.asarray([50, 63], jnp.int32)
sk = decode_attn.splitk_decode_attention(mesh, "data")
for w in (0, 16):
    got = sk(q, kc, vc, cl, window=w)
    want = decode_attention(q, kc, vc, cl, window=w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=1e-5)

# 6) GSPMD decode attention with seq-sharded cache == oracle (the
#    long_500k cell's partitioning, small scale)
from jax.sharding import NamedSharding
kc_sh = jax.device_put(kc, NamedSharding(mesh, P(None, None, "data", None)))
vc_sh = jax.device_put(vc, NamedSharding(mesh, P(None, None, "data", None)))
got = jax.jit(decode_attention)(q, kc_sh, vc_sh, cl)
want = decode_attention(q, kc, vc, cl)
np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4,
                           atol=1e-5)
print("DISTRIBUTED_ALL_OK")
"""


@pytest.mark.parametrize("n_dev", [8])
def test_distributed_suite(n_dev):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=500)
    assert "DISTRIBUTED_ALL_OK" in out.stdout, out.stderr[-3000:]


def test_smoke_cell_dryrun_on_host_mesh():
    """Lower+compile a smoke cell on a tiny 4-device mesh end to end —
    the same machinery the production dry-run uses."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    script = r"""
import jax
from repro import configs
mesh = jax.make_mesh((2, 2), ("data", "model"))
for arch_id, shape_id in [("qwen3-0.6b", "train_4k"),
                          ("mixtral-8x7b", "decode_32k"),
                          ("pna", "full_graph_sm"),
                          ("xdeepfm", "serve_bulk")]:
    cell = configs.get_arch(arch_id).cell(shape_id, scale="smoke",
                                          mesh_axes=("data", "model"))
    sh = cell.make_shardings(mesh)
    with mesh:
        c = jax.jit(cell.fn, in_shardings=sh,
                    donate_argnums=cell.donate).lower(
            *cell.abstract_args).compile()
    assert c.memory_analysis() is not None
print("SMOKE_DRYRUN_OK")
"""
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=500)
    assert "SMOKE_DRYRUN_OK" in out.stdout, out.stderr[-3000:]
