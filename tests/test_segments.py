"""Segment/ragged primitives vs numpy (+ hypothesis roundtrips)."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip, don't break collection
from hypothesis import given, settings, strategies as st

from repro.core import segments


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(0, 7), min_size=1, max_size=20))
def test_offsets_segment_ids_roundtrip(lengths):
    lengths = np.array(lengths, np.int32)
    offs = segments.lengths_to_offsets(jnp.asarray(lengths))
    assert (np.asarray(segments.offsets_to_lengths(offs)) == lengths).all()
    cap = int(lengths.sum()) + 3
    ids = segments.offsets_to_segment_ids(offs, cap)
    back = segments.segment_ids_to_offsets(ids, len(lengths))
    assert (np.asarray(back) == np.asarray(offs)).all()


def test_segment_reductions_vs_numpy():
    rng = np.random.default_rng(0)
    ids = np.sort(rng.integers(0, 10, 100)).astype(np.int32)
    x = rng.normal(size=(100, 4)).astype(np.float32)
    for name, fn, npfn in [
        ("sum", segments.segment_sum, np.sum),
        ("max", segments.segment_max, np.max),
        ("min", segments.segment_min, np.min),
        ("mean", segments.segment_mean, np.mean),
    ]:
        out = np.asarray(fn(jnp.asarray(x), jnp.asarray(ids), 10))
        for s in range(10):
            rows = x[ids == s]
            if len(rows):
                np.testing.assert_allclose(out[s], npfn(rows, axis=0),
                                           rtol=1e-5, err_msg=name)


def test_segment_std_and_softmax():
    rng = np.random.default_rng(1)
    ids = np.sort(rng.integers(0, 5, 50)).astype(np.int32)
    x = rng.normal(size=(50,)).astype(np.float32)
    std = np.asarray(segments.segment_std(jnp.asarray(x), jnp.asarray(ids),
                                          5, eps=0.0))
    for s in range(5):
        rows = x[ids == s]
        if len(rows):
            np.testing.assert_allclose(std[s], rows.std(), rtol=1e-4,
                                       atol=1e-5)
    sm = np.asarray(segments.segment_softmax(jnp.asarray(x),
                                             jnp.asarray(ids), 5))
    for s in range(5):
        if (ids == s).any():
            np.testing.assert_allclose(sm[ids == s].sum(), 1.0, rtol=1e-5)


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_embedding_bag_property(data):
    rng = np.random.default_rng(data.draw(st.integers(0, 1000)))
    v, d, bags = 20, 3, data.draw(st.integers(1, 6))
    lengths = data.draw(st.lists(st.integers(0, 5), min_size=bags,
                                 max_size=bags))
    table = rng.normal(size=(v, d)).astype(np.float32)
    idx = rng.integers(0, v, sum(lengths)).astype(np.int32)
    offs = np.zeros(bags + 1, np.int32)
    np.cumsum(lengths, out=offs[1:])
    out = np.asarray(segments.embedding_bag(
        jnp.asarray(table), jnp.asarray(idx), jnp.asarray(offs)))
    for b in range(bags):
        ref = table[idx[offs[b]:offs[b + 1]]].sum(axis=0) if lengths[b] \
            else np.zeros(d)
        np.testing.assert_allclose(out[b], ref, rtol=1e-5, atol=1e-6)


def test_gather_segment():
    vals = jnp.arange(10, dtype=jnp.int32)
    offs = jnp.asarray([0, 3, 3, 10], jnp.int32)
    buf, valid = segments.gather_segment(vals, offs, 0, capacity=5, fill=-1)
    assert np.asarray(buf).tolist() == [0, 1, 2, -1, -1]
    buf, valid = segments.gather_segment(vals, offs, 1, capacity=5, fill=-1)
    assert not np.asarray(valid).any()
