"""Distributed serving mesh: MeshServer parity, admission control,
deadline shedding, per-tenant caching, and cross-shard epoch handoff.

The central contract: every MeshServer response is bit-identical (tie
order included) to a single-host QueryServer over the SAME pinned
LiveView — under a randomized add/delete/compact churn schedule, on
either topology, with zero new jit entries once a size class is warm.
The deterministic tests drive the mesh thread-free via ``pump()`` (no
real-time sleeps); the ≥4-shard parity test runs in a subprocess
because XLA's host device count must be set before jax initializes.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax

from repro.core.build import TokenizedCorpus
from repro.core import live_index as li
from repro.core.live_index import SegmentedIndex
from repro.distributed import retrieval
from repro.serve import (MeshConfig, MeshServer, QueryServer,
                         ServerConfig, TenantCachePartitions,
                         restore_segmented, serialize_segmented)
from repro.text import corpus

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

# one mesh object for the whole module: the stack-scorer jit cache is
# keyed on the Mesh instance, so zero-growth assertions need both runs
# of a schedule to share it
MESH_1 = jax.make_mesh((1,), ("shards",))


def _slices(tc, bounds):
    return [TokenizedCorpus(tc.doc_term_ids[a:b], tc.doc_counts[a:b],
                            tc.term_hashes, b - a)
            for a, b in zip(bounds[:-1], bounds[1:])]


def _corpus(num_docs=480, vocab=360, seed=1):
    return corpus.generate(corpus.CorpusSpec(
        num_docs=num_docs, vocab=vocab, avg_distinct=20, seed=seed))


def _queries(si, n, seed=5):
    return corpus.sample_query_terms(
        np.asarray(si._df), si.term_hashes, n, 3,
        num_docs=max(si.num_docs, 1), seed=seed)


def _seeded_index(tc, n_docs=240, cap=128):
    si = SegmentedIndex(delta_doc_capacity=cap)
    si.add_batch(_slices(tc, [0, n_docs])[0])
    si.seal()
    return si


def _assert_view_parity(ms, tickets, rtol=1e-5):
    """Every response must match ``view.topk`` of the SERVED epoch —
    the exact computation a single-host QueryServer performs over that
    pin — ids exactly (tie order included), scores to float tol."""
    by_epoch = {}
    for t in tickets:
        by_epoch.setdefault(t.response.epoch, []).append(t)
    views = {ms.serving_epoch: ms.serving_view}
    views.update(getattr(ms, "_view_log", {}))
    for epoch, group in by_epoch.items():
        view = views[epoch]
        rows = np.stack([t.row for t in group])
        ref = view.topk(rows, ms.config.k)
        ids, scores = np.asarray(ref.doc_ids), np.asarray(ref.scores)
        for i, t in enumerate(group):
            assert t.response.status == "ok"
            np.testing.assert_array_equal(
                np.asarray(t.response.doc_ids), ids[i])
            np.testing.assert_allclose(
                np.asarray(t.response.scores), scores[i], rtol=rtol)


class RecordingMesh(MeshServer):
    """MeshServer that remembers every epoch state it served, so the
    test can oracle-check stale responses after further handoffs."""

    def handoff(self):
        out = super().handoff()
        if not hasattr(self, "_view_log"):
            self._view_log = {}
        self._view_log[self._state.epoch] = self._state.view
        return out


# ---------------------------------------------------------------------------
# randomized churn parity + zero new jit entries (single-shard pump mode)
# ---------------------------------------------------------------------------


def _run_churn_schedule(si, tc, mesh, steps=10, seed=3):
    """One deterministic randomized schedule: interleave ingest,
    deletes, maintenance (seal/compact), handoff, and query batches.
    Returns every answered ticket for parity checking."""
    rng = np.random.default_rng(seed)
    cfg = MeshConfig(batch_size=4, n_terms_budget=8, k=10, n_shards=1,
                     auto_handoff=False, trace_sample=3)
    ms = RecordingMesh(si, cfg, mesh=mesh)
    ms.warmup()
    bounds = np.linspace(240, tc.num_docs, steps + 1).astype(int)
    live = set(range(240))
    next_id = 240
    answered = []
    for step in range(steps):
        a, b = bounds[step], bounds[step + 1]
        action = rng.integers(0, 4)
        if action == 0 and b > a:
            ms.add_batch(_slices(tc, [a, b])[0])
            live.update(range(next_id, next_id + (b - a)))
            next_id += b - a
        elif action == 1 and len(live) > 24:
            dead = rng.choice(sorted(live), size=8, replace=False)
            ms.delete_docs(dead)
            live.difference_update(dead.tolist())
        elif action == 2:
            ms.run_maintenance_once()
        if rng.integers(0, 2) == 1:
            ms.handoff()
        qh = _queries(si, 4, seed=100 + step)
        tickets = [ms.submit(q) for q in qh]
        ms.pump(max_batches=4)
        answered.extend(tickets)
    ms.handoff()
    qh = _queries(si, 4, seed=999)
    tickets = [ms.submit(q) for q in qh]
    ms.pump(max_batches=4)
    answered.extend(tickets)
    assert all(t.done() for t in answered)
    _assert_view_parity(ms, answered)
    return ms


def test_mesh_parity_under_randomized_churn_and_zero_new_jit_entries():
    tc = _corpus()
    # run 1 warms every (size_class, layout, depth) signature the
    # schedule mints; run 2 replays it on a fresh index and must add
    # ZERO jit entries anywhere in the serving path
    _run_churn_schedule(_seeded_index(tc), tc, MESH_1)
    warm_stack = retrieval.stack_scorer_cache_sizes()
    warm_live = li.scorer_cache_sizes()
    ms = _run_churn_schedule(_seeded_index(tc), tc, MESH_1)
    assert retrieval.stack_scorer_cache_sizes() == warm_stack
    assert li.scorer_cache_sizes() == warm_live
    # and the replay answered from a warm mesh: handoffs happened
    assert ms.registry.counter("mesh_handoffs").value >= 2


def test_mesh_matches_queryserver_over_same_pin():
    """Direct cross-check: a single-host QueryServer over a clone of
    the mesh's primary at the same epoch answers identically."""
    tc = _corpus()
    si = _seeded_index(tc)
    ms = MeshServer(si, MeshConfig(batch_size=4, k=10, n_shards=1,
                                   auto_handoff=False), mesh=MESH_1)
    ms.add_batch(_slices(tc, [240, 360])[0])
    ms.delete_docs(np.arange(10, 40))
    ms.handoff()
    clone = restore_segmented(serialize_segmented(si))
    qs = QueryServer(clone, ServerConfig(batch_size=4, k=10))
    assert qs.pinned_epoch == ms.serving_epoch
    qh = _queries(si, 8, seed=11)
    mt = [ms.submit(q) for q in qh]
    qt = [qs.submit(q) for q in qh]
    ms.pump(max_batches=4)
    qs.pump(max_batches=4)
    for m, q in zip(mt, qt):
        assert m.response.epoch == q.response.epoch
        np.testing.assert_array_equal(np.asarray(m.response.doc_ids),
                                      np.asarray(q.response.doc_ids))
        np.testing.assert_allclose(np.asarray(m.response.scores),
                                   np.asarray(q.response.scores),
                                   rtol=1e-5)


def test_mesh_term_topology_parity():
    tc = _corpus()
    si = _seeded_index(tc)
    ms = MeshServer(si, MeshConfig(batch_size=4, k=10, n_shards=1,
                                   topology="term_fused",
                                   auto_handoff=False), mesh=MESH_1)
    ms.delete_docs(np.arange(0, 30))
    ms.handoff()
    qh = _queries(si, 6, seed=7)
    tickets = [ms.submit(q) for q in qh]
    ms.pump(max_batches=4)
    _assert_view_parity(ms, tickets)


# ---------------------------------------------------------------------------
# admission control + deadline shedding (thread-free, no sleeps)
# ---------------------------------------------------------------------------


def test_mesh_admission_and_deadline_shedding_deterministic():
    tc = _corpus(num_docs=260)
    si = _seeded_index(tc)
    events_before = si.events.counts().get("shed", 0)
    cfg = MeshConfig(batch_size=4, k=10, n_shards=1, max_queue=3,
                     deadline_us=50_000.0, auto_handoff=False,
                     trace_sample=1)
    ms = MeshServer(si, cfg, mesh=MESH_1)
    qh = _queries(si, 8, seed=13)
    tickets = [ms.submit(q, tenant=f"t{i % 2}") for i, q in enumerate(qh)]

    # admission: the queue holds 3, the other 5 resolve immediately
    admitted = [t for t in tickets if not t.done()]
    shed_now = [t for t in tickets if t.done()]
    assert len(admitted) == 3 and len(shed_now) == 5
    for t in shed_now:
        r = t.result(timeout=0)           # already resolved — no wait
        assert r.status == "shed" and not r.ok
        assert np.all(np.asarray(r.doc_ids) == -1)
        assert np.all(np.asarray(r.scores) == 0.0)
        # the shed trace's stages sum exactly to its latency
        sd = r.trace.stage_durations()
        assert set(sd) == {"shed"}
        assert abs(sum(sd.values()) - r.latency_us) < 1e-3

    # deadline: age two queued tickets past the 50ms target — they
    # shed at pickup, the remaining one serves
    admitted[0].t_submit -= 1.0
    admitted[1].t_submit -= 1.0
    ms.pump(max_batches=2)
    assert admitted[0].response.status == "shed"
    assert admitted[1].response.status == "shed"
    assert admitted[2].response.status == "ok"
    sd = admitted[0].response.trace.stage_durations()
    assert set(sd) == {"queue_wait", "shed"}
    assert abs(sum(sd.values()) - admitted[0].response.latency_us) < 1e-3

    counts = ms.shed_counts()
    assert counts["admission"] == 5 and counts["deadline"] == 2
    assert counts["total"] == 7
    assert ms.shed_rate() == pytest.approx(7 / 8)
    # ... and the events landed in the index EventLog, per kind
    shed_events = ms.events(kind="shed")
    assert len(shed_events) == 7
    reasons = sorted(e["reason"] for e in shed_events)
    assert reasons == ["admission"] * 5 + ["deadline"] * 2
    assert si.events.counts()["shed"] == events_before + 7


def test_mesh_stop_and_queryserver_stop_resolve_queued_tickets():
    tc = _corpus(num_docs=260)
    si = _seeded_index(tc)
    # pump-mode QueryServer: stop() must resolve, not strand, the queue
    qs = QueryServer(restore_segmented(serialize_segmented(si)),
                     ServerConfig(batch_size=4, k=10))
    t1 = qs.submit(_queries(si, 1, seed=2)[0])
    qs.stop()
    r = t1.result(timeout=0.1)            # resolves without blocking
    assert r.status == "shutdown" and not r.ok
    assert np.all(np.asarray(r.doc_ids) == -1)
    assert qs.registry.counter("serve_shutdown_unserved").value == 1

    # mesh: shutdown leftovers count and log as sheds
    ms = MeshServer(si, MeshConfig(batch_size=4, k=10, n_shards=1,
                                   auto_handoff=False), mesh=MESH_1)
    tickets = [ms.submit(q) for q in _queries(si, 3, seed=3)]
    ms.stop()
    for t in tickets:
        assert t.result(timeout=0.1).status == "shutdown"
    assert ms.shed_counts()["shutdown"] == 3
    kinds = {e["reason"] for e in ms.events(kind="shed")}
    assert kinds == {"shutdown"}

    # threaded stop: the worker drains what it can, then nothing blocks
    ms2 = MeshServer(si, MeshConfig(batch_size=4, k=10, n_shards=1,
                                    auto_handoff=False), mesh=MESH_1)
    ms2.warmup()
    ms2.start()
    tickets = [ms2.submit(q) for q in _queries(si, 6, seed=4)]
    ms2.stop()
    for t in tickets:
        assert t.result(timeout=5.0).status in ("ok", "shutdown")


# ---------------------------------------------------------------------------
# per-tenant result-cache partitions
# ---------------------------------------------------------------------------


def test_tenant_cache_partitions_isolation_unit():
    parts = TenantCachePartitions(capacity_per_tenant=2, max_tenants=2)
    key = parts.make_key(np.asarray([1, 2], np.uint32), 10, 0)
    ids, sc = np.asarray([5], np.int32), np.asarray([1.0], np.float32)
    parts.put("a", key, ids, sc)
    assert parts.get("b", key) is None          # no cross-tenant hits
    assert parts.get("a", key) is not None
    # a's burst cannot evict b's working set
    parts.put("b", key, ids, sc)
    for i in range(8):
        parts.put("a", parts.make_key(np.asarray([i], np.uint32), 10, 0),
                  ids, sc)
    assert parts.get("b", key) is not None
    assert len(parts.partition("a")) == 2       # a stayed LRU-bounded
    # tenant directory is itself bounded: a third tenant evicts the LRU
    parts.put("c", key, ids, sc)
    assert parts.tenant_evictions == 1
    assert len(parts.tenants) == 2
    st = parts.per_tenant()
    assert set(st) == set(parts.tenants)
    assert parts.hits == 2 and parts.misses == 1


def test_mesh_tenant_cache_partitions_end_to_end():
    tc = _corpus(num_docs=260)
    si = _seeded_index(tc)
    ms = MeshServer(si, MeshConfig(batch_size=4, k=10, n_shards=1,
                                   auto_handoff=False), mesh=MESH_1)
    q = _queries(si, 1, seed=21)[0]
    a1 = ms.submit(q, tenant="a"); ms.pump()
    a2 = ms.submit(q, tenant="a"); ms.pump()
    b1 = ms.submit(q, tenant="b"); ms.pump()
    assert not a1.response.cached
    assert a2.response.cached                   # same tenant: warm
    assert not b1.response.cached               # other tenant: isolated
    np.testing.assert_array_equal(np.asarray(a2.response.doc_ids),
                                  np.asarray(b1.response.doc_ids))
    per = ms.cache.per_tenant()
    assert per["a"]["hits"] == 1 and per["b"]["hits"] == 0
    # epoch advance invalidates every partition
    ms.add_batch(_slices(tc, [240, 260])[0])
    ms.handoff()
    a3 = ms.submit(q, tenant="a"); ms.pump()
    assert not a3.response.cached
    assert a3.response.epoch > a2.response.epoch


# ---------------------------------------------------------------------------
# handoff semantics + replicas
# ---------------------------------------------------------------------------


def test_mesh_handoff_events_auto_handoff_and_trace_span():
    tc = _corpus()
    si = _seeded_index(tc)
    cfg = MeshConfig(batch_size=4, k=10, n_shards=1, auto_handoff=True,
                     handoff_min_interval_s=0.0, trace_sample=1)
    ms = RecordingMesh(si, cfg, mesh=MESH_1)
    ms.warmup()
    e0 = ms.serving_epoch
    handoffs0 = ms.registry.counter("mesh_handoffs").value
    # a quiescent mesh never re-pins
    t = ms.submit(_queries(si, 1, seed=31)[0]); ms.pump()
    assert ms.serving_epoch == e0
    assert ms.registry.counter("mesh_handoffs").value == handoffs0
    # ingest advances the primary epoch -> the NEXT batch pays one
    # handoff, visible as a top-level trace stage, then serves fresh
    ms.add_batch(_slices(tc, [240, 300])[0])
    t2 = ms.submit(_queries(si, 1, seed=32)[0]); ms.pump()
    assert ms.serving_epoch > e0
    assert t2.response.epoch == ms.serving_epoch
    sd = t2.response.trace.stage_durations()
    assert "handoff" in sd
    assert abs(sum(sd.values()) - t2.response.latency_us) < 1e-3
    _assert_view_parity(ms, [t, t2])
    ev = ms.events(kind="handoff")
    assert ev and ev[-1]["pause_us"] > 0
    assert ev[-1]["epoch"] == ms.serving_epoch
    assert ev[-1]["n_shards"] == 1
    hist = ms.registry.histogram("mesh_handoff_pause_us").snapshot()
    assert hist["count"] == ms.registry.counter("mesh_handoffs").value


def test_mesh_replicas_stay_in_lockstep_and_divergence_is_caught():
    tc = _corpus()
    si = _seeded_index(tc)
    ms = MeshServer(si, MeshConfig(batch_size=4, k=10, n_shards=1,
                                   n_replicas=3, auto_handoff=False),
                    mesh=MESH_1)
    ms.add_batch(_slices(tc, [240, 330])[0])
    ms.delete_docs(np.arange(50, 70))
    ms.run_maintenance_once()
    ms.handoff()                                 # digests agree
    assert len({r.digest() for r in ms.replicas}) == 1
    tickets = [ms.submit(q) for q in _queries(si, 4, seed=41)]
    ms.pump(max_batches=2)
    _assert_view_parity(ms, tickets)
    # an out-of-band write to one replica is caught at the next handoff
    ms.replicas[1].index.delete(np.asarray([80]))
    with pytest.raises(RuntimeError, match="diverged"):
        ms.handoff()


# ---------------------------------------------------------------------------
# >= 4-shard subprocess parity (PR lane: not slow)
# ---------------------------------------------------------------------------

MESH_4SHARD_SCRIPT = r"""
import numpy as np
import jax
from repro.core.build import TokenizedCorpus
from repro.core import live_index as li
from repro.core.live_index import SegmentedIndex
from repro.distributed import retrieval
from repro.serve import MeshConfig, MeshServer
from repro.text import corpus

mesh = jax.make_mesh((4,), ("shards",))
tc = corpus.generate(corpus.CorpusSpec(num_docs=520, vocab=380,
                                       avg_distinct=20, seed=1))

def sl(a, b):
    return TokenizedCorpus(tc.doc_term_ids[a:b], tc.doc_counts[a:b],
                           tc.term_hashes, b - a)

def run_schedule(seed):
    rng = np.random.default_rng(seed)
    si = SegmentedIndex(delta_doc_capacity=96)
    si.add_batch(sl(0, 96)); si.seal()
    si.add_batch(sl(96, 192)); si.seal()
    si.add_batch(sl(192, 288)); si.seal()
    si.add_batch(sl(288, 384)); si.seal()
    cfg = MeshConfig(batch_size=4, k=10, n_shards=4,
                     auto_handoff=False, trace_sample=4)
    ms = MeshServer(si, cfg, mesh=mesh)
    ms.warmup()
    views, answered, nxt, live = {}, [], 384, set(range(384))
    bounds = np.linspace(384, 520, 7).astype(int)
    for step in range(6):
        act = rng.integers(0, 3)
        a, b = bounds[step], bounds[step + 1]
        if act == 0 and b > a:
            ms.add_batch(sl(a, b)); nxt += b - a
            live.update(range(nxt - (b - a), nxt))
        elif act == 1:
            dead = rng.choice(sorted(live), size=6, replace=False)
            ms.delete_docs(dead); live.difference_update(dead.tolist())
        else:
            ms.run_maintenance_once()          # seal/compact
        if rng.integers(0, 2) == 1:
            ms.handoff()
        views[ms.serving_epoch] = ms.serving_view
        qh = corpus.sample_query_terms(np.asarray(si._df), si.term_hashes,
                                       4, 3, num_docs=si.num_docs,
                                       seed=700 + step)
        ts = [ms.submit(q) for q in qh]
        ms.pump(max_batches=4)
        answered.extend(ts)
    by_epoch = {}
    for t in answered:
        assert t.response.status == "ok"
        by_epoch.setdefault(t.response.epoch, []).append(t)
    for epoch, group in by_epoch.items():
        rows = np.stack([t.row for t in group])
        ref = views[epoch].topk(rows, 10)
        ids, sc = np.asarray(ref.doc_ids), np.asarray(ref.scores)
        for i, t in enumerate(group):
            np.testing.assert_array_equal(
                np.asarray(t.response.doc_ids), ids[i])
            np.testing.assert_allclose(
                np.asarray(t.response.scores), sc[i], rtol=1e-5)
    return ms

run_schedule(7)
print("MESH4_PARITY_OK")
warm_stack = retrieval.stack_scorer_cache_sizes()
warm_live = li.scorer_cache_sizes()
ms = run_schedule(7)
assert retrieval.stack_scorer_cache_sizes() == warm_stack, "stack jit grew"
assert li.scorer_cache_sizes() == warm_live, "live jit grew"
print("MESH4_ZERO_JIT_OK")
assert ms.mesh_summary()["n_shards"] == 4
assert ms.registry.counter("mesh_handoffs").value >= 1
print("MESH4_SUMMARY_OK")
"""


def test_mesh_subprocess_parity_4shards():
    """The acceptance criterion end to end: a 4-shard mesh under a
    randomized add/delete/compact churn schedule answers bit-identically
    to the single-host path at every pinned epoch, and a schedule
    replay adds zero jit entries."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", MESH_4SHARD_SCRIPT],
                         env=env, capture_output=True, text=True,
                         timeout=520)
    for marker in ("MESH4_PARITY_OK", "MESH4_ZERO_JIT_OK",
                   "MESH4_SUMMARY_OK"):
        assert marker in out.stdout, (marker, out.stderr[-3000:])
