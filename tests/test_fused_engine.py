"""Parity tests: fused batched decode-and-score engine vs the jnp oracle.

``make_scorer(engine="pallas")`` must return bit-identical top-k doc ids
to ``score_queries`` (the pure-jnp oracle) across the HOR and Packed
layouts — including deleted docs (norm == 0), absent terms, empty
queries, and k > hits.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import build, layouts, query
from repro.core.layouts import DocTable
from repro.text import corpus


def _host(seed=7, docs=600, vocab=500, avg=25):
    tc = corpus.generate(corpus.CorpusSpec(num_docs=docs, vocab=vocab,
                                           avg_distinct=avg, seed=seed))
    return build.bulk_build(tc)


def _absent_hash(host):
    """A nonzero u32 hash guaranteed not to be in the vocabulary."""
    taken = set(int(h) for h in host.term_hashes)
    h = 12345
    while h in taken or h == 0:
        h += 1
    return np.uint32(h)


BUILDERS = {"hor": layouts.build_blocked, "packed": layouts.build_packed_csr}


def _assert_parity(ix, qh, k, cap, **scorer_kw):
    oracle = query.make_scorer(ix, k=k, cap=cap)(qh)
    fused = query.make_scorer(ix, k=k, cap=cap, engine="pallas",
                              **scorer_kw)(qh)
    np.testing.assert_array_equal(np.asarray(fused.doc_ids),
                                  np.asarray(oracle.doc_ids))
    np.testing.assert_allclose(np.asarray(fused.scores),
                               np.asarray(oracle.scores),
                               rtol=1e-5, atol=1e-7)


@pytest.mark.parametrize("layout", ["hor", "packed"])
def test_fused_matches_oracle_batched(layout):
    host = _host()
    ix = BUILDERS[layout](host)
    cap = max(host.max_posting_len, 1)
    qh = corpus.sample_query_terms(host.df, host.term_hashes, 8, 4,
                                   num_docs=host.num_docs, seed=3)
    _assert_parity(ix, jnp.asarray(qh), k=10, cap=cap)


@pytest.mark.parametrize("layout", ["hor", "packed"])
def test_fused_shared_terms_across_batch(layout):
    """Queries sharing terms exercise the cross-query pair dedup."""
    host = _host()
    ix = BUILDERS[layout](host)
    cap = max(host.max_posting_len, 1)
    q = corpus.sample_query_terms(host.df, host.term_hashes, 2, 4,
                                  num_docs=host.num_docs, seed=5)
    qh = np.stack([q[0], q[0], q[1], q[0]])       # heavy term sharing
    qh[1, 2:] = q[1][2:]                          # partial overlap too
    _assert_parity(ix, jnp.asarray(qh), k=10, cap=cap)


@pytest.mark.parametrize("layout", ["hor", "packed"])
def test_fused_absent_and_empty_terms(layout):
    host = _host()
    ix = BUILDERS[layout](host)
    cap = max(host.max_posting_len, 1)
    q = corpus.sample_query_terms(host.df, host.term_hashes, 1, 4,
                                  num_docs=host.num_docs, seed=1)[0]
    absent = _absent_hash(host)
    qh = np.zeros((3, 4), np.uint32)
    qh[0] = q
    qh[0, 1] = absent                 # absent term mixed into a real query
    qh[1, 0] = absent                 # only-absent-term query
    # qh[2] stays all zeros           # fully empty query
    _assert_parity(ix, jnp.asarray(qh), k=5, cap=cap)
    fused = query.make_scorer(ix, k=5, cap=cap, engine="pallas")(
        jnp.asarray(qh))
    assert (np.asarray(fused.doc_ids)[1:] == -1).all()


@pytest.mark.parametrize("layout", ["hor", "packed"])
def test_fused_deleted_docs(layout):
    """Docs with norm == 0 are deleted: never returned by either engine."""
    host = _host()
    ix = BUILDERS[layout](host)
    cap = max(host.max_posting_len, 1)
    norm = np.asarray(ix.docs.norm).copy()
    deleted = np.arange(0, host.num_docs, 3)
    norm[deleted] = 0.0
    ix = dataclasses.replace(
        ix, docs=DocTable(norm=jnp.asarray(norm), rank=ix.docs.rank))
    qh = corpus.sample_query_terms(host.df, host.term_hashes, 4, 3,
                                   num_docs=host.num_docs, seed=2)
    _assert_parity(ix, jnp.asarray(qh), k=10, cap=cap)
    fused = query.make_scorer(ix, k=10, cap=cap, engine="pallas")(
        jnp.asarray(qh))
    ids = np.asarray(fused.doc_ids)
    assert not np.isin(ids[ids >= 0], deleted).any()


@pytest.mark.parametrize("layout", ["hor", "packed"])
def test_fused_k_exceeds_hits(layout):
    """k larger than the number of matching docs pads with -1, like the
    oracle."""
    host = _host(docs=120, vocab=400, avg=8)
    ix = BUILDERS[layout](host)
    cap = max(host.max_posting_len, 1)
    # rare term: few hits, k much larger
    rare = int(np.argmin(np.where(host.df > 0, host.df, 10**9)))
    qh = np.zeros((1, 4), np.uint32)
    qh[0, 0] = host.term_hashes[rare]
    k = host.num_docs  # way past any df
    _assert_parity(ix, jnp.asarray(qh), k=k, cap=cap)
    fused = query.make_scorer(ix, k=k, cap=cap, engine="pallas")(
        jnp.asarray(qh))
    assert (np.asarray(fused.doc_ids)[0] == -1).sum() >= k - int(
        host.df[rare])


@pytest.mark.parametrize("layout", ["hor", "packed"])
def test_fused_rank_blend(layout):
    host = _host()
    ix = BUILDERS[layout](host)
    cap = max(host.max_posting_len, 1)
    qh = corpus.sample_query_terms(host.df, host.term_hashes, 4, 3,
                                   num_docs=host.num_docs, seed=8)
    oracle = query.make_scorer(ix, k=10, cap=cap, rank_blend=0.5)(
        jnp.asarray(qh))
    fused = query.make_scorer(ix, k=10, cap=cap, rank_blend=0.5,
                              engine="pallas")(jnp.asarray(qh))
    np.testing.assert_array_equal(np.asarray(fused.doc_ids),
                                  np.asarray(oracle.doc_ids))


@pytest.mark.parametrize("layout", ["hor", "packed"])
def test_fused_overflow_is_detected(layout):
    """An undersized routing budget is SURFACED (stats counter), not a
    silent posting drop."""
    host = _host()
    ix = BUILDERS[layout](host)
    cap = max(host.max_posting_len, 1)
    qh = corpus.sample_query_terms(host.df, host.term_hashes, 4, 4,
                                   num_docs=host.num_docs, seed=4)
    _, stats = query.make_scorer(ix, k=10, cap=cap, engine="pallas",
                                 max_pairs=2, return_stats=True)(
        jnp.asarray(qh))
    assert int(stats["pair_overflow"]) > 0


@pytest.mark.parametrize("layout", ["hor", "packed"])
def test_fused_default_budget_never_overflows(layout):
    """The build-time route_pairs_max budget is an exact upper bound at
    the default tile: overflow must be 0 without tuning."""
    host = _host()
    ix = BUILDERS[layout](host)
    cap = max(host.max_posting_len, 1)
    qh = corpus.sample_query_terms(host.df, host.term_hashes, 8, 4,
                                   num_docs=host.num_docs, seed=6)
    _, stats = query.make_scorer(ix, k=10, cap=cap, engine="pallas",
                                 return_stats=True)(jnp.asarray(qh))
    assert int(stats["pair_overflow"]) == 0


@pytest.mark.parametrize("layout", ["hor", "packed"])
@pytest.mark.parametrize("backend", ["pallas", "xla"])
def test_fused_mid_block_cap_matches_oracle(layout, backend):
    """A posting cap that cuts MID-BLOCK (not a multiple of the 128-lane
    block) must truncate exactly like the oracle's gather."""
    host = _host()
    ix = BUILDERS[layout](host)
    qh = corpus.sample_query_terms(host.df, host.term_hashes, 4, 3,
                                   num_docs=host.num_docs, seed=11)
    for cap in (130, 257, 100):
        _assert_parity(ix, jnp.asarray(qh), k=10, cap=cap, backend=backend)


@pytest.mark.parametrize("layout", ["hor", "packed"])
def test_fused_xla_backend_matches_oracle(layout):
    """The plain-HLO lowering of the fused engine (same block dedup,
    wide-row scatter) ranks identically too."""
    host = _host()
    ix = BUILDERS[layout](host)
    cap = max(host.max_posting_len, 1)
    qh = corpus.sample_query_terms(host.df, host.term_hashes, 8, 3,
                                   num_docs=host.num_docs, seed=9)
    _assert_parity(ix, jnp.asarray(qh), k=10, cap=cap, backend="xla")


def test_make_scorer_rejects_unknown_engine():
    host = _host(docs=60, vocab=80, avg=5)
    ix = layouts.build_blocked(host)
    with pytest.raises(ValueError):
        query.make_scorer(ix, k=5, cap=8, engine="cuda")


def test_make_scorer_rejects_unblocked_index_for_pallas():
    host = _host(docs=60, vocab=80, avg=5)
    with pytest.raises(TypeError, match="BlockedIndex or PackedCsrIndex"):
        query.make_scorer(layouts.build_csr(host), k=5, cap=8,
                          engine="pallas")
