"""Parity tests: fused batched decode-and-score engine vs the jnp oracle.

``make_scorer(engine="pallas")`` must return bit-identical top-k doc ids
to ``score_queries`` (the pure-jnp oracle) across the HOR and Packed
layouts — including deleted docs (norm == 0), absent terms, empty
queries, and k > hits.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import build, layouts, query
from repro.core.layouts import DocTable
from repro.text import corpus


def _host(seed=7, docs=600, vocab=500, avg=25):
    tc = corpus.generate(corpus.CorpusSpec(num_docs=docs, vocab=vocab,
                                           avg_distinct=avg, seed=seed))
    return build.bulk_build(tc)


def _absent_hash(host):
    """A nonzero u32 hash guaranteed not to be in the vocabulary."""
    taken = set(int(h) for h in host.term_hashes)
    h = 12345
    while h in taken or h == 0:
        h += 1
    return np.uint32(h)


BUILDERS = {"hor": layouts.build_blocked, "packed": layouts.build_packed_csr}


def _assert_parity(ix, qh, k, cap, **scorer_kw):
    oracle = query.make_scorer(ix, k=k, cap=cap)(qh)
    fused = query.make_scorer(ix, k=k, cap=cap, engine="pallas",
                              **scorer_kw)(qh)
    np.testing.assert_array_equal(np.asarray(fused.doc_ids),
                                  np.asarray(oracle.doc_ids))
    np.testing.assert_allclose(np.asarray(fused.scores),
                               np.asarray(oracle.scores),
                               rtol=1e-5, atol=1e-7)


@pytest.mark.parametrize("layout", ["hor", "packed"])
def test_fused_matches_oracle_batched(layout):
    host = _host()
    ix = BUILDERS[layout](host)
    cap = max(host.max_posting_len, 1)
    qh = corpus.sample_query_terms(host.df, host.term_hashes, 8, 4,
                                   num_docs=host.num_docs, seed=3)
    _assert_parity(ix, jnp.asarray(qh), k=10, cap=cap)


@pytest.mark.parametrize("layout", ["hor", "packed"])
@pytest.mark.slow
def test_fused_shared_terms_across_batch(layout):
    """Queries sharing terms exercise the cross-query pair dedup."""
    host = _host()
    ix = BUILDERS[layout](host)
    cap = max(host.max_posting_len, 1)
    q = corpus.sample_query_terms(host.df, host.term_hashes, 2, 4,
                                  num_docs=host.num_docs, seed=5)
    qh = np.stack([q[0], q[0], q[1], q[0]])       # heavy term sharing
    qh[1, 2:] = q[1][2:]                          # partial overlap too
    _assert_parity(ix, jnp.asarray(qh), k=10, cap=cap)


@pytest.mark.parametrize("layout", ["hor", "packed"])
@pytest.mark.slow
def test_fused_absent_and_empty_terms(layout):
    host = _host()
    ix = BUILDERS[layout](host)
    cap = max(host.max_posting_len, 1)
    q = corpus.sample_query_terms(host.df, host.term_hashes, 1, 4,
                                  num_docs=host.num_docs, seed=1)[0]
    absent = _absent_hash(host)
    qh = np.zeros((3, 4), np.uint32)
    qh[0] = q
    qh[0, 1] = absent                 # absent term mixed into a real query
    qh[1, 0] = absent                 # only-absent-term query
    # qh[2] stays all zeros           # fully empty query
    _assert_parity(ix, jnp.asarray(qh), k=5, cap=cap)
    fused = query.make_scorer(ix, k=5, cap=cap, engine="pallas")(
        jnp.asarray(qh))
    assert (np.asarray(fused.doc_ids)[1:] == -1).all()


@pytest.mark.parametrize("layout", ["hor", "packed"])
@pytest.mark.slow
def test_fused_deleted_docs(layout):
    """Docs with norm == 0 are deleted: never returned by either engine."""
    host = _host()
    ix = BUILDERS[layout](host)
    cap = max(host.max_posting_len, 1)
    norm = np.asarray(ix.docs.norm).copy()
    deleted = np.arange(0, host.num_docs, 3)
    norm[deleted] = 0.0
    ix = dataclasses.replace(
        ix, docs=DocTable(norm=jnp.asarray(norm), rank=ix.docs.rank))
    qh = corpus.sample_query_terms(host.df, host.term_hashes, 4, 3,
                                   num_docs=host.num_docs, seed=2)
    _assert_parity(ix, jnp.asarray(qh), k=10, cap=cap)
    fused = query.make_scorer(ix, k=10, cap=cap, engine="pallas")(
        jnp.asarray(qh))
    ids = np.asarray(fused.doc_ids)
    assert not np.isin(ids[ids >= 0], deleted).any()


@pytest.mark.parametrize("layout", ["hor", "packed"])
def test_fused_k_exceeds_hits(layout):
    """k larger than the number of matching docs pads with -1, like the
    oracle."""
    host = _host(docs=120, vocab=400, avg=8)
    ix = BUILDERS[layout](host)
    cap = max(host.max_posting_len, 1)
    # rare term: few hits, k much larger
    rare = int(np.argmin(np.where(host.df > 0, host.df, 10**9)))
    qh = np.zeros((1, 4), np.uint32)
    qh[0, 0] = host.term_hashes[rare]
    k = host.num_docs  # way past any df
    _assert_parity(ix, jnp.asarray(qh), k=k, cap=cap)
    fused = query.make_scorer(ix, k=k, cap=cap, engine="pallas")(
        jnp.asarray(qh))
    assert (np.asarray(fused.doc_ids)[0] == -1).sum() >= k - int(
        host.df[rare])


@pytest.mark.parametrize("layout", ["hor", "packed"])
@pytest.mark.slow
def test_fused_rank_blend(layout):
    host = _host()
    ix = BUILDERS[layout](host)
    cap = max(host.max_posting_len, 1)
    qh = corpus.sample_query_terms(host.df, host.term_hashes, 4, 3,
                                   num_docs=host.num_docs, seed=8)
    oracle = query.make_scorer(ix, k=10, cap=cap, rank_blend=0.5)(
        jnp.asarray(qh))
    fused = query.make_scorer(ix, k=10, cap=cap, rank_blend=0.5,
                              engine="pallas")(jnp.asarray(qh))
    np.testing.assert_array_equal(np.asarray(fused.doc_ids),
                                  np.asarray(oracle.doc_ids))


@pytest.mark.parametrize("layout", ["hor", "packed"])
def test_fused_overflow_is_detected(layout):
    """An undersized routing budget is SURFACED (stats counter), not a
    silent posting drop."""
    host = _host()
    ix = BUILDERS[layout](host)
    cap = max(host.max_posting_len, 1)
    qh = corpus.sample_query_terms(host.df, host.term_hashes, 4, 4,
                                   num_docs=host.num_docs, seed=4)
    _, stats = query.make_scorer(ix, k=10, cap=cap, engine="pallas",
                                 max_pairs=2, return_stats=True)(
        jnp.asarray(qh))
    assert int(stats["pair_overflow"]) > 0


@pytest.mark.parametrize("layout", ["hor", "packed"])
@pytest.mark.slow
def test_fused_default_budget_never_overflows(layout):
    """The build-time route_pairs_max budget is an exact upper bound at
    the default tile: overflow must be 0 without tuning."""
    host = _host()
    ix = BUILDERS[layout](host)
    cap = max(host.max_posting_len, 1)
    qh = corpus.sample_query_terms(host.df, host.term_hashes, 8, 4,
                                   num_docs=host.num_docs, seed=6)
    _, stats = query.make_scorer(ix, k=10, cap=cap, engine="pallas",
                                 return_stats=True)(jnp.asarray(qh))
    assert int(stats["pair_overflow"]) == 0


@pytest.mark.parametrize("layout", ["hor", "packed"])
@pytest.mark.parametrize("backend", ["pallas", "xla"])
@pytest.mark.slow
def test_fused_mid_block_cap_matches_oracle(layout, backend):
    """A posting cap that cuts MID-BLOCK (not a multiple of the 128-lane
    block) must truncate exactly like the oracle's gather."""
    host = _host()
    ix = BUILDERS[layout](host)
    qh = corpus.sample_query_terms(host.df, host.term_hashes, 4, 3,
                                   num_docs=host.num_docs, seed=11)
    for cap in (130, 257, 100):
        _assert_parity(ix, jnp.asarray(qh), k=10, cap=cap, backend=backend)


@pytest.mark.parametrize("layout", ["hor", "packed"])
@pytest.mark.slow
def test_fused_xla_backend_matches_oracle(layout):
    """The plain-HLO lowering of the fused engine (same block dedup,
    wide-row scatter) ranks identically too."""
    host = _host()
    ix = BUILDERS[layout](host)
    cap = max(host.max_posting_len, 1)
    qh = corpus.sample_query_terms(host.df, host.term_hashes, 8, 3,
                                   num_docs=host.num_docs, seed=9)
    _assert_parity(ix, jnp.asarray(qh), k=10, cap=cap, backend="xla")


@pytest.mark.parametrize("layout", ["hor", "packed"])
@pytest.mark.parametrize("backend", ["pallas", "xla"])
@pytest.mark.slow
def test_fused_duplicate_terms_match_oracle(layout, backend):
    """Regression: a term hash repeated across slots of one query must
    be scored ONCE by every engine (the gather used to double-count its
    tf·idf weight and inflate the query norm)."""
    host = _host()
    ix = BUILDERS[layout](host)
    cap = max(host.max_posting_len, 1)
    q = corpus.sample_query_terms(host.df, host.term_hashes, 2, 4,
                                  num_docs=host.num_docs, seed=13)
    qh = np.stack([q[0], q[0], q[1]])
    qh[0, 1] = qh[0, 0]               # duplicate inside one query
    qh[1, 3] = qh[1, 2]
    qh[2, 1:] = qh[2, 0]              # one term repeated in every slot
    _assert_parity(ix, jnp.asarray(qh), k=10, cap=cap, backend=backend)
    # duplicated slots change nothing vs the deduplicated query
    dedup = np.zeros_like(qh[2:3])
    dedup[0, 0] = qh[2, 0]
    a = query.make_scorer(ix, k=10, cap=cap, engine="pallas")(
        jnp.asarray(qh[2:3]))
    b = query.make_scorer(ix, k=10, cap=cap, engine="pallas")(
        jnp.asarray(dedup))
    np.testing.assert_array_equal(np.asarray(a.doc_ids),
                                  np.asarray(b.doc_ids))


def _tied_host(num_docs=1200):
    """Synthetic postings engineered for exact score TIES: term A covers
    every doc at tf=1, term B the upper half at tf=2; all norms equal.
    Querying A alone makes every doc's final score identical."""
    from repro.core.layouts import PostingsHost
    half = num_docs // 2
    term_hashes = np.array([111, 222], np.uint64).astype(np.uint32)
    doc_a = np.arange(num_docs, dtype=np.int32)
    doc_b = np.arange(half, num_docs, dtype=np.int32)
    return PostingsHost(
        term_hashes=term_hashes,
        df=np.array([num_docs, num_docs - half], np.int32),
        offsets=np.array([0, num_docs, num_docs + (num_docs - half)],
                         np.int64),
        doc_ids=np.concatenate([doc_a, doc_b]),
        tfs=np.concatenate([np.ones(num_docs, np.float32),
                            np.full(num_docs - half, 2.0, np.float32)]),
        num_docs=num_docs,
        norm=np.ones(num_docs, np.float32),
        rank=np.zeros(num_docs, np.float32))


@pytest.mark.parametrize("layout", ["hor", "packed"])
@pytest.mark.slow
def test_fused_tie_breaking_matches_oracle(layout):
    """Hundreds of exactly-tied docs spanning several 512-doc tiles: the
    per-tile candidate lists must merge with the oracle's lowest-doc-id
    tie order, bit-identically."""
    host = _tied_host()
    ix = BUILDERS[layout](host)
    cap = host.num_docs
    qh = np.zeros((2, 4), np.uint32)
    qh[0, 0] = 111                    # every doc tied
    qh[1, 0] = 111
    qh[1, 1] = 222                    # upper half breaks away, lower ties
    _assert_parity(ix, jnp.asarray(qh), k=25, cap=cap)
    fused = query.make_scorer(ix, k=25, cap=cap, engine="pallas")(
        jnp.asarray(qh))
    # all-tied query: ties resolve to the lowest doc ids, in order
    np.testing.assert_array_equal(np.asarray(fused.doc_ids)[0],
                                  np.arange(25))


@pytest.mark.parametrize("layout", ["hor", "packed"])
@pytest.mark.slow
def test_fused_deleted_docs_winning_tiles(layout):
    """Delete exactly the docs that WON the query (norm = 0): the
    tile-local top-k must skip them in-kernel, not return them and lose
    the real winners."""
    host = _host()
    ix = BUILDERS[layout](host)
    cap = max(host.max_posting_len, 1)
    qh = corpus.sample_query_terms(host.df, host.term_hashes, 2, 3,
                                   num_docs=host.num_docs, seed=21)
    winners = np.asarray(query.make_scorer(ix, k=10, cap=cap)(
        jnp.asarray(qh)).doc_ids)
    deleted = np.unique(winners[winners >= 0])
    norm = np.asarray(ix.docs.norm).copy()
    norm[deleted] = 0.0
    ix = dataclasses.replace(
        ix, docs=DocTable(norm=jnp.asarray(norm), rank=ix.docs.rank))
    _assert_parity(ix, jnp.asarray(qh), k=10, cap=cap)
    fused = query.make_scorer(ix, k=10, cap=cap, engine="pallas")(
        jnp.asarray(qh))
    ids = np.asarray(fused.doc_ids)
    assert not np.isin(ids[ids >= 0], deleted).any()
    assert (ids >= 0).any()           # the runners-up surface instead


@pytest.mark.parametrize("layout", ["hor", "packed"])
@pytest.mark.slow
def test_fused_all_tiles_empty_query(layout):
    """A query whose every tile is empty (no terms / absent terms) in a
    batch with real queries returns all -1 via the candidate path."""
    host = _host()
    ix = BUILDERS[layout](host)
    cap = max(host.max_posting_len, 1)
    q = corpus.sample_query_terms(host.df, host.term_hashes, 1, 4,
                                  num_docs=host.num_docs, seed=17)[0]
    qh = np.zeros((3, 4), np.uint32)
    qh[0] = q                         # real query keeps tiles visited
    qh[2, 0] = _absent_hash(host)     # absent-only query
    _assert_parity(ix, jnp.asarray(qh), k=7, cap=cap)
    fused = query.make_scorer(ix, k=7, cap=cap, engine="pallas")(
        jnp.asarray(qh))
    assert (np.asarray(fused.doc_ids)[1:] == -1).all()
    assert (np.asarray(fused.scores)[1:] == 0.0).all()


@pytest.mark.parametrize("layout", ["hor", "packed"])
@pytest.mark.slow
def test_fused_kernel_candidates_match_jnp_extraction(layout):
    """The in-kernel per-tile reduction must equal the pure-jnp
    ``extract_tile_candidates`` mirror applied to the SAME dense
    accumulator (identical pair order -> bit-identical scores)."""
    from repro.kernels import ops
    from repro.kernels.fused_decode_score import (
        TILE, default_k_tile, extract_tile_candidates)
    host = _host()
    ix = BUILDERS[layout](host)
    cap = max(host.max_posting_len, 1)
    k = 10
    qh = corpus.sample_query_terms(host.df, host.term_hashes, 4, 3,
                                   num_docs=host.num_docs, seed=19)
    present = jnp.asarray(qh) != 0
    tids = jnp.where(present, ix.lookup_terms(jnp.asarray(qh)), -1)
    idf_t = query.idf(ix.term_df(tids), host.num_docs)
    qnorm = jnp.sqrt(jnp.maximum(jnp.sum(idf_t * idf_t, axis=1), 1e-12))
    dense, _ = ops.fused_batched_scores(ix, tids, idf_t, cap)
    final = query.final_scores(dense, ix.docs.norm, ix.docs.rank, qnorm,
                               0.0)
    want_v, want_i = extract_tile_candidates(final, TILE,
                                             default_k_tile(k))
    got_v, got_i, _ = ops.fused_batched_topk(ix, tids, idf_t, cap, k)
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
    np.testing.assert_array_equal(np.asarray(got_v), np.asarray(want_v))


def test_merge_topk_candidates_pads_short_lists():
    """k beyond the candidate count pads with -inf / -1 instead of
    crashing (jax.lax.top_k requires k <= n)."""
    from repro.distributed.topk import merge_topk_candidates
    v = jnp.asarray([[3.0, 1.0], [2.0, -jnp.inf]])
    i = jnp.asarray([[30, 10], [20, -1]], dtype=jnp.int32)
    mv, mi = merge_topk_candidates(v, i, k=4)
    np.testing.assert_array_equal(np.asarray(mi),
                                  [[30, 10, -1, -1], [20, -1, -1, -1]])
    assert np.asarray(mv)[0, 2] == -np.inf


def test_make_scorer_rejects_unknown_engine():
    host = _host(docs=60, vocab=80, avg=5)
    ix = layouts.build_blocked(host)
    with pytest.raises(ValueError):
        query.make_scorer(ix, k=5, cap=8, engine="cuda")


def test_make_scorer_rejects_unblocked_index_for_pallas():
    host = _host(docs=60, vocab=80, avg=5)
    with pytest.raises(TypeError, match="BlockedIndex or PackedCsrIndex"):
        query.make_scorer(layouts.build_csr(host), k=5, cap=8,
                          engine="pallas")
