"""Paper Table 4 analytic size model (+ hypothesis properties)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip, don't break collection
from hypothesis import given, settings, strategies as st

from repro.core import size_model as sm


def test_paper_collection_ratio():
    """Paper Table 4/5 reproduction, claim by claim.

    * analytic Table-4 model (f=4, t=40): PR/ORIF ~ 6.5x — tuple-overhead
      elimination alone;
    * the paper's MEASURED 20x (Table 5) additionally includes PSQL
      TOAST/LZ compression of the packed point arrays (240.8M 16-byte
      points stored in 28,577 8KB pages = ~1 B/point).  Our beyond-paper
      PackedCsrIndex (delta+bitpack) is the explicit analogue: packed vs
      PR reaches the measured order of magnitude.
    """
    s = sm.PAPER_COLLECTION
    assert sm.pr_over_orif(s) > 5.0                    # analytic claim
    # absolute numbers in the right regime (PR ~10.7GB measured)
    assert 8e9 < sm.pr_bytes(s) < 14e9
    # compression-equivalent claim: packed layout vs PR > 10x
    ratio = sm.pr_bytes(s) / sm.packed_csr_layout_bytes(s)
    assert ratio > 10.0
    # PR per-tuple bytes match Table 5: 10.7GB / 240.8M tuples ~ 44 B
    measured_pr = 1_301_657 * 8192 / 240_806_511
    analytic_pr = sm.pr_bytes(s) / s.N_d
    assert abs(measured_pr - analytic_pr) / analytic_pr < 0.25


@settings(max_examples=200, deadline=None)
@given(d=st.integers(1, 10**7), w_avg=st.integers(1, 5000),
       vocab=st.integers(1, 10**6))
def test_orif_always_smaller(d, w_avg, vocab):
    """The paper's inequality: ORIF < PR  <=>  W < N_d (always true)."""
    n_d = d * w_avg
    w = min(vocab, n_d)   # every term appears at least once
    s = sm.CorpusStats(D=d, W=w, N_d=n_d, N=3 * n_d)
    assert sm.orif_bytes(s) <= sm.pr_bytes(s)
    assert sm.orif_bytes(s, positions=True) <= sm.pr_bytes(s, positions=True)


@settings(max_examples=100, deadline=None)
@given(d=st.integers(1, 10**6), w_avg=st.integers(1, 500))
def test_positions_monotone(d, w_avg):
    s = sm.CorpusStats(D=d, W=min(10**5, d * w_avg), N_d=d * w_avg,
                       N=3 * d * w_avg)
    assert sm.pr_bytes(s, positions=True) >= sm.pr_bytes(s)
    assert sm.orif_bytes(s, positions=True) >= sm.orif_bytes(s)


def test_layout_bytes_ordering():
    s = sm.PAPER_COLLECTION
    assert sm.packed_csr_layout_bytes(s) < sm.csr_layout_bytes(s) \
        < sm.coo_layout_bytes(s)
