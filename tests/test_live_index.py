"""Segmented live index: LSM ingest, tombstones, compaction, parity.

The central contract: at ANY point of an add/delete/compact schedule,
``SegmentedIndex.topk`` (fused pallas candidates engine, the default)
is bit-identical — ties included — to the jnp oracle over
``bulk_build`` of the equivalent live corpus.  Plus: delete semantics
end-to-end across every engine, multi-segment conjunctive stats
aggregation, the recompile-avoidance contract under churn, and the
posting-merge work advantage over the rebuild path.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import build, compaction, layouts, query
from repro.core import live_index as li
from repro.core.build import TokenizedCorpus
from repro.core.live_index import SegmentedIndex
from repro.text import corpus

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _slices(tc, bounds):
    return [TokenizedCorpus(tc.doc_term_ids[a:b], tc.doc_counts[a:b],
                            tc.term_hashes, b - a)
            for a, b in zip(bounds[:-1], bounds[1:])]


def _oracle_topk(si, qh, k):
    """jnp oracle over bulk_build of the equivalent live corpus, with
    its compact doc ids mapped back to the live index's global ids."""
    tc_live, live_ids = si.export_live_corpus()
    if tc_live.num_docs == 0:
        shape = (np.asarray(qh).shape[0], k)
        return np.full(shape, -1, np.int32), np.zeros(shape, np.float32)
    host = build.bulk_build(tc_live)
    ix = layouts.build_blocked(host)
    cap = max(host.max_posting_len, 1)
    r = query.make_scorer(ix, k=k, cap=cap)(jnp.asarray(qh))
    oid = np.asarray(r.doc_ids)
    mapped = np.where(oid >= 0, live_ids[np.maximum(oid, 0)], -1)
    return mapped.astype(np.int32), np.asarray(r.scores)


def _assert_live_parity(si, qh, k=10, **topk_kw):
    want_ids, want_scores = _oracle_topk(si, qh, k)
    got = si.topk(qh, k=k, **topk_kw)
    np.testing.assert_array_equal(np.asarray(got.doc_ids), want_ids)
    np.testing.assert_allclose(np.asarray(got.scores), want_scores,
                               rtol=1e-5, atol=1e-7)


def test_randomized_schedule_parity_every_step():
    """Randomized add/delete/compact schedule: fused multi-segment top-k
    equals the rebuild oracle at EVERY step (the acceptance criterion)."""
    rng = np.random.default_rng(0)
    tc = corpus.generate(corpus.CorpusSpec(num_docs=360, vocab=300,
                                           avg_distinct=18, seed=11))
    batches = _slices(tc, [0, 60, 110, 180, 240, 300, 360])
    si = SegmentedIndex(term_hashes=tc.term_hashes,
                        delta_doc_capacity=48,
                        delta_posting_capacity=2048,
                        policy=compaction.TieredPolicy(size_ratio=4.0,
                                                       min_run=3))
    qh = corpus.sample_query_terms(build.bulk_build(tc).df, tc.term_hashes,
                                   3, 3, num_docs=tc.num_docs, seed=5)
    for step, b in enumerate(batches):
        si.add_batch(b)
        if step >= 1:
            live = np.flatnonzero(si.live_mask())
            kill = rng.choice(live, size=min(7, len(live)), replace=False)
            si.delete(kill)
        if step == 3:
            si.compact(all_segments=True)
        _assert_live_parity(si, qh, k=10)
    assert si.stats.seals > 0 and si.stats.compactions > 0
    assert si.stats.deletes > 0


def test_engines_agree_and_make_scorer_dispatch():
    tc = corpus.generate(corpus.CorpusSpec(num_docs=200, vocab=250,
                                           avg_distinct=15, seed=3))
    si = SegmentedIndex(term_hashes=tc.term_hashes, delta_doc_capacity=48,
                        delta_posting_capacity=2048)
    si.add_batch(_slices(tc, [0, 200])[0])
    si.delete([5, 9])
    qh = corpus.sample_query_terms(np.asarray(si._df), si.term_hashes,
                                   3, 3, num_docs=si.live_doc_count,
                                   seed=2)
    want_ids, want_scores = _oracle_topk(si, qh, 10)
    for kw in (dict(engine="pallas", mode="candidates"),
               dict(engine="pallas", mode="dense"),
               dict(engine="jnp")):
        got = si.topk(qh, k=10, **kw)
        np.testing.assert_array_equal(np.asarray(got.doc_ids), want_ids)
        np.testing.assert_allclose(np.asarray(got.scores), want_scores,
                                   rtol=1e-5, atol=1e-7)
    # make_scorer dispatches a SegmentedIndex to the live path
    scorer = query.make_scorer(si, k=10, cap=None, engine="pallas")
    got = scorer(qh)
    np.testing.assert_array_equal(np.asarray(got.doc_ids), want_ids)
    with pytest.raises(ValueError):
        si.topk(qh, k=10, engine="cuda")


def _handmade_corpus(term_ids, counts, vocab=32):
    hashes = (np.arange(1, vocab + 1, dtype=np.uint32) * 2654435761
              ).astype(np.uint32)
    return TokenizedCorpus(
        doc_term_ids=[np.asarray(t, np.int64) for t in term_ids],
        doc_counts=[np.asarray(c, np.int64) for c in counts],
        term_hashes=hashes, num_docs=len(term_ids)), hashes


def test_delete_semantics_all_engines_and_readd():
    """Tombstoned docs never surface from any engine; a doc deleted and
    re-added with different content surfaces only as its new id with
    the new content."""
    tc1, hashes = _handmade_corpus(
        term_ids=[[0, 1], [0, 2], [1, 2], [0, 1, 2]],
        counts=[[3, 1], [2, 2], [1, 4], [1, 1, 1]])
    si = SegmentedIndex(term_hashes=hashes, delta_doc_capacity=4,
                        delta_posting_capacity=64,
                        policy=compaction.TieredPolicy(min_run=100))
    si.add_batch(tc1)            # fills delta exactly -> docs 0..3
    qh = np.zeros((1, 3), np.uint32)
    qh[0, 0] = hashes[0]
    top = si.topk(qh, k=4)
    winner = int(np.asarray(top.doc_ids)[0, 0])
    si.delete([winner])
    # re-add "the same document" with DIFFERENT content (term 3 only)
    tc2 = TokenizedCorpus(doc_term_ids=[np.asarray([3], np.int64)],
                          doc_counts=[np.asarray([5], np.int64)],
                          term_hashes=hashes, num_docs=1)
    si.add_batch(tc2)
    new_id = si.num_docs - 1
    for kw in (dict(engine="pallas", mode="candidates"),
               dict(engine="pallas", mode="dense"),
               dict(engine="jnp")):
        ids = np.asarray(si.topk(qh, k=4, **kw).doc_ids)
        assert winner not in ids[ids >= 0], kw
        _assert_live_parity(si, qh, k=4, **kw)
    # old content never matches; new content matches only the new id
    qh3 = np.zeros((1, 3), np.uint32)
    qh3[0, 0] = hashes[3]
    ids3 = np.asarray(si.topk(qh3, k=4).doc_ids)
    assert new_id in ids3[ids3 >= 0]
    assert winner not in ids3[ids3 >= 0]
    # the same holds after seal + compaction; the tombstoned doc's
    # postings are physically gone (store holds live postings only)
    si.seal()
    si.compact(all_segments=True)
    tc_live, _ = si.export_live_corpus()
    live_postings = int(sum(len(t) for t in tc_live.doc_term_ids))
    assert sum(si.segment_postings()) == live_postings
    assert si.delta_postings == 0
    ids = np.asarray(si.topk(qh, k=4).doc_ids)
    assert winner not in ids[ids >= 0]
    _assert_live_parity(si, qh, k=4)


def test_conjunctive_truncation_aggregates_across_segments():
    """A term whose posting list exceeds ``cap`` in an EARLY segment is
    counted even when the last segment scored has no truncation (the
    stats-plumbing fix)."""
    # segment 1: term 0 in 12 docs (> cap), term 1 in 6 (< cap);
    # segment 2: both terms in 2 docs
    tc1, hashes = _handmade_corpus(
        term_ids=[[0, 1]] * 6 + [[0]] * 6,
        counts=[[2, 1]] * 6 + [[2]] * 6)
    si = SegmentedIndex(term_hashes=hashes, delta_doc_capacity=16,
                        delta_posting_capacity=256,
                        policy=compaction.TieredPolicy(min_run=100))
    si.add_batch(tc1)
    si.seal()
    tc2 = TokenizedCorpus(
        doc_term_ids=[np.asarray([0, 1], np.int64)] * 2,
        doc_counts=[np.asarray([1, 1], np.int64)] * 2,
        term_hashes=hashes, num_docs=2)
    si.add_batch(tc2)
    si.seal()
    assert si.num_segments == 2
    qh = np.zeros(3, np.uint32)
    qh[0], qh[1] = hashes[0], hashes[1]
    # cap 8 < 12: only the FIRST segment truncates term 0
    _, stats = si.conjunctive(qh, k=5, cap=8)
    assert stats["truncated_terms"] == 1
    # cap above every local df: exact AND, no truncation, and results
    # match the single-index conjunctive over the rebuilt corpus
    r, stats = si.conjunctive(qh, k=5, cap=16)
    assert stats["truncated_terms"] == 0
    tc_live, live_ids = si.export_live_corpus()
    host = build.bulk_build(tc_live)
    ix = layouts.build_blocked(host)
    ref, ref_stats = query.conjunctive_filter(ix, jnp.asarray(qh), k=5,
                                              cap=16)
    rid = np.asarray(ref.doc_ids)
    mapped = np.where(rid >= 0, live_ids[np.maximum(rid, 0)], -1)
    np.testing.assert_array_equal(np.asarray(r.doc_ids), mapped)
    np.testing.assert_allclose(np.asarray(r.scores),
                               np.asarray(ref.scores), rtol=1e-5)
    assert int(ref_stats["truncated_terms"]) == 0


def test_churn_no_new_compilations_after_warmup():
    """The recompile-avoidance contract: after one warmup per size
    class, further seals, compactions (same classes), deletes, and
    queries add ZERO jit-cache entries."""
    tc = corpus.generate(corpus.CorpusSpec(num_docs=1600, vocab=500,
                                           avg_distinct=18, seed=4))
    B = 64
    si = SegmentedIndex(term_hashes=tc.term_hashes, delta_doc_capacity=B,
                        delta_posting_capacity=B * 40,
                        policy=compaction.TieredPolicy(size_ratio=4.0,
                                                       min_run=4))
    qh = corpus.sample_query_terms(
        build.bulk_build(_slices(tc, [0, 200])[0]).df, tc.term_hashes,
        4, 3, num_docs=200, seed=5)

    def one_round(a):
        si.add_batch(_slices(tc, [a, a + B])[0])
        si.topk(qh, k=10)
        si.topk(qh, k=10, engine="jnp")
        si.conjunctive(qh[0], k=10, cap=512)

    # warmup: several delta-class seals + one L1-class compaction + a
    # delete, with every engine queried
    step = 0
    for a in range(0, 6 * B, B):
        one_round(a)
        step = a + B
    si.delete([step - 1])
    si.topk(qh, k=10)
    assert si.stats.compactions >= 1
    snap = li.scorer_cache_sizes()

    # churn: six more seals, another same-class compaction, deletes,
    # queries — the jit caches must not grow
    for a in range(step, step + 6 * B, B):
        si.add_batch(_slices(tc, [a, a + B])[0])
        si.delete([a + 3])
        si.topk(qh, k=10)
        si.topk(qh, k=10, engine="jnp")
        si.conjunctive(qh[0], k=10, cap=512)
    assert si.stats.compactions >= 2
    assert li.scorer_cache_sizes() == snap


def test_ingest_merge_work_at_least_10x_below_rebuild():
    """Sustained ingest: posting-merge work per batch (postings touched
    by sort/merge) is >= 10x below the rebuild path's in steady state."""
    tc = corpus.generate(corpus.CorpusSpec(num_docs=3200, vocab=400,
                                           avg_distinct=16, seed=8))
    n_batches = 64
    bounds = np.linspace(0, tc.num_docs, n_batches + 1).astype(int)
    batches = _slices(tc, bounds)
    per = batches[0].num_docs
    si = SegmentedIndex(term_hashes=tc.term_hashes,
                        delta_doc_capacity=per,
                        delta_posting_capacity=per * 40,
                        policy=compaction.TieredPolicy(size_ratio=8.0,
                                                       min_run=8))
    rebuild_touched = 0
    total_postings = 0
    for b in batches:
        si.add_batch(b)
        total_postings += int(sum(len(x) for x in b.doc_term_ids))
        rebuild_touched += total_postings   # the rebuild re-sorts ALL
    live_per_batch = si.stats.postings_merged / n_batches
    steady = total_postings / max(live_per_batch, 1)
    cumulative = rebuild_touched / max(si.stats.postings_merged, 1)
    assert steady >= 10.0, (steady, si.stats)
    assert cumulative >= 5.0, (cumulative, si.stats)
    # each posting was appended exactly once
    assert si.stats.postings_appended == total_postings


def test_packed_seal_layout_parity():
    """seal(layout="packed"): delta+bit-packed sealed segments answer
    bit-identically to the oracle across a randomized add/delete/compact
    schedule, agree with the HOR seal of the same schedule, and mix into
    an HOR stack via the per-seal override."""
    rng = np.random.default_rng(1)
    tc = corpus.generate(corpus.CorpusSpec(num_docs=360, vocab=300,
                                           avg_distinct=18, seed=11))
    batches = _slices(tc, [0, 60, 110, 180, 240, 300, 360])
    kw = dict(delta_doc_capacity=48, delta_posting_capacity=2048,
              policy=compaction.TieredPolicy(size_ratio=4.0, min_run=3))
    si_p = SegmentedIndex(term_hashes=tc.term_hashes, seal_layout="packed",
                          **kw)
    si_h = SegmentedIndex(term_hashes=tc.term_hashes, **kw)
    qh = corpus.sample_query_terms(build.bulk_build(tc).df, tc.term_hashes,
                                   3, 3, num_docs=tc.num_docs, seed=5)
    for step, b in enumerate(batches):
        si_p.add_batch(b)
        si_h.add_batch(b)
        if step >= 1:
            live = np.flatnonzero(si_p.live_mask())
            kill = rng.choice(live, size=min(7, len(live)), replace=False)
            si_p.delete(kill)
            si_h.delete(kill)
        if step == 3:
            si_p.compact(all_segments=True)
            si_h.compact(all_segments=True)
        _assert_live_parity(si_p, qh, k=10)
        got_p = si_p.topk(qh, k=10)
        got_h = si_h.topk(qh, k=10)
        np.testing.assert_array_equal(np.asarray(got_p.doc_ids),
                                      np.asarray(got_h.doc_ids))
        np.testing.assert_allclose(np.asarray(got_p.scores),
                                   np.asarray(got_h.scores), rtol=1e-5)
    assert si_p.stats.seals > 0 and si_p.stats.compactions > 0
    from repro.core.layouts import PackedCsrIndex
    assert all(isinstance(s.index, PackedCsrIndex)
               for s in si_p.segments())
    # mixed stack: one packed seal inside an otherwise-HOR index
    assert si_h.delta_postings > 0     # schedule leaves a partial delta
    si_h.seal(layout="packed")
    layouts_seen = {type(s.index).__name__ for s in si_h.segments()}
    assert layouts_seen == {"BlockedIndex", "PackedCsrIndex"}
    _assert_live_parity(si_h, qh, k=10)
    # the jnp engine agrees over packed segments too
    _assert_live_parity(si_p, qh, k=10, engine="jnp")


def test_pick_compaction_policy():
    """Size-tiered trigger: merges the newest similar-sized run, leaves
    graduated runs alone until enough peers accumulate."""
    pick = compaction.pick_compaction
    assert pick([10, 10, 10, 10], 4.0, 4) == (0, 4)
    assert pick([100, 10, 10, 10, 10], 4.0, 4) == (1, 5)     # big stays
    assert pick([100, 10, 10, 10], 4.0, 4) is None           # run too short
    assert pick([40, 10, 10, 10, 10], 4.0, 4) == (1, 5)      # 40 !< 4*10
    assert pick([39, 12, 10, 11, 10], 4.0, 4) == (0, 5)      # within band
    assert pick([], 4.0, 4) is None
    assert pick([0, 0, 0, 0], 4.0, 4) == (0, 4)              # empties merge
    # min_run clamps to 2: a single-segment "merge" would never make
    # progress and would spin the compact-until-quiescent loop
    assert pick([5], 4.0, 1) is None
    assert pick([5, 5], 4.0, 1) == (0, 2)
    p = compaction.TieredPolicy(size_ratio=4.0, min_run=2)
    assert p.pick([8, 9]) == (0, 2)


def test_oversized_doc_direct_seal_and_empty_docs():
    """A doc larger than the delta's posting capacity seals directly as
    its own segment; zero-term docs stay live (norm 1e-12) either way."""
    vocab = 64
    hashes = (np.arange(1, vocab + 1, dtype=np.uint32) * 40503
              ).astype(np.uint32)
    big = np.arange(vocab, dtype=np.int64)
    tc = TokenizedCorpus(
        doc_term_ids=[np.asarray([0, 1], np.int64), big,
                      np.zeros(0, np.int64)],
        doc_counts=[np.asarray([1, 1], np.int64),
                    np.ones(vocab, np.int64), np.zeros(0, np.int64)],
        term_hashes=hashes, num_docs=3)
    si = SegmentedIndex(term_hashes=hashes, delta_doc_capacity=8,
                        delta_posting_capacity=16,
                        policy=compaction.TieredPolicy(min_run=100))
    si.add_batch(tc)
    assert si.num_docs == 3 and si.live_doc_count == 3
    assert si.num_segments >= 1      # the big doc forced a direct seal
    qh = np.zeros((1, 2), np.uint32)
    qh[0, 0] = hashes[5]             # only the big doc contains term 5
    ids = np.asarray(si.topk(qh, k=2).doc_ids)
    assert ids[0, 0] == 1
    _assert_live_parity(si, qh, k=2)


def test_to_host_roundtrip_matches_bulk():
    tc = corpus.generate(corpus.CorpusSpec(num_docs=150, vocab=200,
                                           avg_distinct=12, seed=6))
    si = SegmentedIndex(term_hashes=tc.term_hashes, delta_doc_capacity=64,
                        delta_posting_capacity=4096)
    si.add_batch(_slices(tc, [0, 150])[0])
    host = si.to_host()
    ref = build.bulk_build(tc)
    np.testing.assert_array_equal(host.df, ref.df)
    np.testing.assert_array_equal(host.doc_ids, ref.doc_ids)
    np.testing.assert_array_equal(host.offsets, ref.offsets)
    np.testing.assert_allclose(host.norm, ref.norm, rtol=1e-6)


def test_adaptive_budget_converges_to_zero_overflow():
    """ROADMAP follow-up: per-n_terms budgets derived from the overflow
    counter + a rolling sample — an overflowing workload converges to
    zero overflow warnings and stays there."""
    tc = corpus.generate(corpus.CorpusSpec(num_docs=400, vocab=400,
                                           avg_distinct=25, seed=2))
    host = build.bulk_build(tc)
    ix = layouts.build_blocked(host)
    cap = host.max_posting_len
    budget = query.AdaptiveRoutingBudget(initial=8)
    scorer = query.make_adaptive_scorer(ix, k=10, cap=cap, budget=budget)
    oracle = query.make_scorer(ix, k=10, cap=cap)
    stream = [corpus.sample_query_terms(host.df, host.term_hashes, 4, 4,
                                        num_docs=400, seed=s)
              for s in range(10)]
    overflows = []
    for qh in stream:
        _, stats = scorer(jnp.asarray(qh))
        overflows.append(int(stats["pair_overflow"]))
    assert overflows[0] > 0                       # deliberately undersized
    assert all(o == 0 for o in overflows[2:]), overflows
    # converged results match the default-budget oracle exactly
    r, _ = scorer(jnp.asarray(stream[-1]))
    ref = oracle(jnp.asarray(stream[-1]))
    np.testing.assert_array_equal(np.asarray(r.doc_ids),
                                  np.asarray(ref.doc_ids))
    # budgets stay quantized (bounded compile set)
    for v in budget._budgets.values():
        assert v & (v - 1) == 0


@pytest.mark.slow
def test_long_randomized_churn_sweep():
    """Long schedule: interleaved adds/deletes/compactions with parity,
    delete exclusion, and cache stability checked throughout."""
    rng = np.random.default_rng(42)
    tc = corpus.generate(corpus.CorpusSpec(num_docs=1200, vocab=400,
                                           avg_distinct=16, seed=21))
    bounds = np.linspace(0, 1200, 17).astype(int)
    batches = _slices(tc, bounds)
    si = SegmentedIndex(term_hashes=tc.term_hashes, delta_doc_capacity=40,
                        delta_posting_capacity=2048,
                        policy=compaction.TieredPolicy(size_ratio=4.0,
                                                       min_run=4))
    qh = corpus.sample_query_terms(build.bulk_build(tc).df,
                                   tc.term_hashes, 3, 3,
                                   num_docs=tc.num_docs, seed=9)
    deleted = set()
    snap = None
    for step, b in enumerate(batches):
        si.add_batch(b)
        live = np.flatnonzero(si.live_mask())
        kill = rng.choice(live, size=min(11, len(live)), replace=False)
        si.delete(kill)
        deleted.update(int(x) for x in kill)
        _assert_live_parity(si, qh, k=12)
        ids = np.asarray(si.topk(qh, k=12).doc_ids)
        assert not np.isin(ids[ids >= 0], list(deleted)).any()
        if step == 8:
            snap = li.scorer_cache_sizes()
    # a randomized tiered cascade may mint a handful of NEW size classes
    # late in the sweep (compile set is log-bounded, not frozen); the
    # strict zero-growth contract for WARM classes is pinned by
    # test_churn_no_new_compilations_after_warmup
    growth = (sum(li.scorer_cache_sizes().values()) -
              sum(snap.values()))
    assert 0 <= growth <= 4, (snap, li.scorer_cache_sizes())
    assert si.stats.compactions >= 2


DISTRIBUTED_SCRIPT = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.text import corpus
from repro.core import build, compaction
from repro.core.live_index import SegmentedIndex
from repro.distributed import retrieval

mesh = jax.make_mesh((4,), ("data",))
tc = corpus.generate(corpus.CorpusSpec(num_docs=500, vocab=400,
                                       avg_distinct=22, seed=9))
si = SegmentedIndex(term_hashes=tc.term_hashes, delta_doc_capacity=64,
                    delta_posting_capacity=4096,
                    policy=compaction.TieredPolicy(min_run=100))
for a in range(0, 500, 100):
    si.add_batch(build.TokenizedCorpus(tc.doc_term_ids[a:a+100],
                                       tc.doc_counts[a:a+100],
                                       tc.term_hashes, 100))
deleted = [7, 123, 456]
si.delete(deleted)
si.seal()
assert si.num_segments >= 4
stacks = retrieval.stack_segment_shards(si, 4)
scorer = retrieval.make_doc_sharded_segment_scorer(stacks, mesh, "data",
                                                   k=10)
qh = corpus.sample_query_terms(np.asarray(si._df), si.term_hashes, 3, 3,
                               num_docs=si.live_doc_count, seed=3)
for q in qh:
    vv, ids = scorer(jnp.asarray(q))
    ref = si.topk(q[None], k=10)
    # contiguous per-shard runs preserve ascending doc-id source order,
    # so the sharded merge reproduces the single-node ranking EXACTLY
    # (ties included), not just the same doc set
    np.testing.assert_array_equal(np.asarray(ids),
                                  np.asarray(ref.doc_ids)[0])
    np.testing.assert_allclose(np.asarray(vv),
                               np.asarray(ref.scores)[0], rtol=1e-5)
    assert not np.isin(np.asarray(ids), deleted).any()
print("LIVE_SHARDED_OK")

# sharding a PINNED VIEW: the stacks snapshot one epoch; later deletes
# on the live index do not leak into the sharded serving tier
view = si.view()
si.delete([11, 222])
stacks_v = retrieval.stack_segment_shards(view, 4)
scorer_v = retrieval.make_doc_sharded_segment_scorer(stacks_v, mesh,
                                                     "data", k=10)
for q in qh[:2]:
    vv, ids = scorer_v(jnp.asarray(q))
    ref = view.topk(q[None], k=10)
    np.testing.assert_array_equal(np.asarray(ids),
                                  np.asarray(ref.doc_ids)[0])
    np.testing.assert_allclose(np.asarray(vv),
                               np.asarray(ref.scores)[0], rtol=1e-5)
print("VIEW_SHARDED_OK")
"""


def test_doc_sharded_segment_stack_scorer():
    """Doc-sharded serving tier over per-shard segment stacks: agrees
    with the single-node live index, honours tombstones, in a 4-device
    subprocess (XLA_FLAGS must be set before jax initializes)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", DISTRIBUTED_SCRIPT],
                         env=env, capture_output=True, text=True,
                         timeout=500)
    assert "LIVE_SHARDED_OK" in out.stdout, out.stderr[-3000:]
    assert "VIEW_SHARDED_OK" in out.stdout, out.stderr[-3000:]
