"""Hypothesis property tests on system invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip, don't break collection
from hypothesis import given, settings, strategies as st

from repro.core import build, layouts, query
from repro.core.layouts import _pack_block_np
from repro.kernels import ref
from repro.text import corpus


@st.composite
def corpora(draw):
    docs = draw(st.integers(20, 120))
    vocab = draw(st.integers(20, 200))
    avg = draw(st.integers(3, 20))
    seed = draw(st.integers(0, 10_000))
    return corpus.CorpusSpec(num_docs=docs, vocab=vocab, avg_distinct=avg,
                             seed=seed)


@settings(max_examples=12, deadline=None)
@given(spec=corpora(), qseed=st.integers(0, 100))
def test_all_layouts_rank_identically(spec, qseed):
    """INVARIANT: the four representations + packed return the same
    ranked results for any corpus and any query (paper Table 3)."""
    host = build.bulk_build(corpus.generate(spec))
    if host.num_postings == 0:
        return
    qh = corpus.sample_query_terms(host.df, host.term_hashes, 1, 3,
                                   num_docs=host.num_docs, seed=qseed)[0]
    cap = max(host.max_posting_len, 1)
    results = {}
    for name, bld in [("pr", layouts.build_coo),
                      ("or", layouts.build_csr),
                      ("cor", layouts.build_compact_csr),
                      ("hor", lambda h: layouts.build_blocked(h, block=16)),
                      ("packed",
                       lambda h: layouts.build_packed_csr(h, block=16))]:
        r = query.score_query(bld(host), jnp.asarray(qh), k=5, cap=cap)
        results[name] = np.asarray(r.scores)
    for name, sc in results.items():
        np.testing.assert_allclose(sc, results["or"], rtol=3e-3, atol=1e-5,
                                   err_msg=name)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 2**20), min_size=1, max_size=64),
       st.integers(0, 2**16))
def test_pack_unpack_roundtrip(deltas, base):
    """bit-pack -> unpack is the identity for any delta list."""
    deltas = np.array(deltas, np.int64)
    deltas[0] = max(int(deltas[0]), 1)      # first delta >= 1 (doc > base)
    block = 64
    deltas = deltas[:block]
    width = max(1, int(deltas.max()).bit_length())
    padded = np.zeros(block, np.int64)
    padded[:len(deltas)] = deltas
    words = _pack_block_np(padded, width, block)
    docs = ref.ref_unpack_block(
        jnp.asarray(words), jnp.int32(width), jnp.int32(base - 1),
        jnp.int32(len(deltas)), block)
    expect = (base - 1) + np.cumsum(deltas)
    np.testing.assert_array_equal(np.asarray(docs)[:len(deltas)], expect)


@settings(max_examples=10, deadline=None)
@given(spec=corpora())
def test_incremental_build_invariant(spec):
    """Splitting the corpus at any point yields the identical index."""
    tc = corpus.generate(spec)
    full = build.bulk_build(tc)
    cut = max(1, spec.num_docs // 3)
    a = build.TokenizedCorpus(tc.doc_term_ids[:cut], tc.doc_counts[:cut],
                              tc.term_hashes, cut)
    b = build.TokenizedCorpus(tc.doc_term_ids[cut:], tc.doc_counts[cut:],
                              tc.term_hashes, tc.num_docs - cut)
    merged = build.add_documents(build.bulk_build(a), b)
    np.testing.assert_array_equal(merged.doc_ids, full.doc_ids)
    np.testing.assert_array_equal(merged.df, full.df)
    np.testing.assert_allclose(merged.norm, full.norm, rtol=1e-6)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 300), st.integers(1, 50))
def test_scores_bounded_by_cosine(d, seed):
    """Scores are cosine similarities -> bounded by ~1 + rank blend."""
    spec = corpus.CorpusSpec(num_docs=max(d, 20), vocab=60, avg_distinct=8,
                             seed=seed)
    host = build.bulk_build(corpus.generate(spec))
    ix = layouts.build_csr(host)
    qh = corpus.sample_query_terms(host.df, host.term_hashes, 1, 2,
                                   num_docs=host.num_docs, seed=seed)[0]
    r = query.score_query(ix, jnp.asarray(qh), k=5,
                          cap=max(host.max_posting_len, 1))
    sc = np.asarray(r.scores)
    assert (sc[np.isfinite(sc)] <= 1.0 + 1e-5).all()
