"""Hypothesis property tests on system invariants."""
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip, don't break collection
from hypothesis import given, settings, strategies as st

from repro.core import build, layouts, query
from repro.core.layouts import _pack_block_np
from repro.kernels import ref
from repro.text import corpus

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@st.composite
def corpora(draw):
    docs = draw(st.integers(20, 120))
    vocab = draw(st.integers(20, 200))
    avg = draw(st.integers(3, 20))
    seed = draw(st.integers(0, 10_000))
    return corpus.CorpusSpec(num_docs=docs, vocab=vocab, avg_distinct=avg,
                             seed=seed)


@settings(max_examples=12, deadline=None)
@given(spec=corpora(), qseed=st.integers(0, 100))
def test_all_layouts_rank_identically(spec, qseed):
    """INVARIANT: the four representations + packed return the same
    ranked results for any corpus and any query (paper Table 3)."""
    host = build.bulk_build(corpus.generate(spec))
    if host.num_postings == 0:
        return
    qh = corpus.sample_query_terms(host.df, host.term_hashes, 1, 3,
                                   num_docs=host.num_docs, seed=qseed)[0]
    cap = max(host.max_posting_len, 1)
    results = {}
    for name, bld in [("pr", layouts.build_coo),
                      ("or", layouts.build_csr),
                      ("cor", layouts.build_compact_csr),
                      ("hor", lambda h: layouts.build_blocked(h, block=16)),
                      ("packed",
                       lambda h: layouts.build_packed_csr(h, block=16))]:
        r = query.score_query(bld(host), jnp.asarray(qh), k=5, cap=cap)
        results[name] = np.asarray(r.scores)
    for name, sc in results.items():
        np.testing.assert_allclose(sc, results["or"], rtol=3e-3, atol=1e-5,
                                   err_msg=name)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 2**20), min_size=1, max_size=64),
       st.integers(0, 2**16))
def test_pack_unpack_roundtrip(deltas, base):
    """bit-pack -> unpack is the identity for any delta list."""
    deltas = np.array(deltas, np.int64)
    deltas[0] = max(int(deltas[0]), 1)      # first delta >= 1 (doc > base)
    block = 64
    deltas = deltas[:block]
    width = max(1, int(deltas.max()).bit_length())
    padded = np.zeros(block, np.int64)
    padded[:len(deltas)] = deltas
    words = _pack_block_np(padded, width, block)
    docs = ref.ref_unpack_block(
        jnp.asarray(words), jnp.int32(width), jnp.int32(base - 1),
        jnp.int32(len(deltas)), block)
    expect = (base - 1) + np.cumsum(deltas)
    np.testing.assert_array_equal(np.asarray(docs)[:len(deltas)], expect)


@settings(max_examples=10, deadline=None)
@given(spec=corpora())
def test_incremental_build_invariant(spec):
    """Splitting the corpus at any point yields the identical index."""
    tc = corpus.generate(spec)
    full = build.bulk_build(tc)
    cut = max(1, spec.num_docs // 3)
    a = build.TokenizedCorpus(tc.doc_term_ids[:cut], tc.doc_counts[:cut],
                              tc.term_hashes, cut)
    b = build.TokenizedCorpus(tc.doc_term_ids[cut:], tc.doc_counts[cut:],
                              tc.term_hashes, tc.num_docs - cut)
    merged = build.add_documents(build.bulk_build(a), b)
    np.testing.assert_array_equal(merged.doc_ids, full.doc_ids)
    np.testing.assert_array_equal(merged.df, full.df)
    np.testing.assert_allclose(merged.norm, full.norm, rtol=1e-6)


# ---------------------------------------------------------------------------
# layout-parity fuzz suite: random corpora + random add/delete/compact/
# seal schedules with per-seal random layout, asserting multi-segment
# top-k (ties included) is identical across {jnp oracle, single-host
# fused, doc-sharded segment stacks, term-sharded} x {hor, packed,
# mixed}.  slow-marked: the daily full suite runs it, the PR job keeps
# the fixed-schedule subprocess tests (test_distributed.py) instead.
# ---------------------------------------------------------------------------


@st.composite
def live_schedules(draw):
    docs = draw(st.integers(80, 200))
    spec = corpus.CorpusSpec(
        num_docs=docs, vocab=draw(st.integers(40, 150)),
        avg_distinct=draw(st.integers(4, 14)),
        seed=draw(st.integers(0, 10_000)))
    n_batches = draw(st.integers(2, 4))
    cuts = sorted(draw(st.lists(st.integers(1, docs - 1),
                                min_size=n_batches - 1,
                                max_size=n_batches - 1, unique=True)))
    bounds = [0] + cuts + [docs]
    # chooser-on schedules: a drawn LayoutCostModel threshold plus
    # layout=None seals route through the override ladder (policy rung);
    # policy_docs=0 means no policy, where None must fall through to the
    # historical default — both arms fuzz against the same oracle
    policy_docs = draw(st.sampled_from([0, 64, 256, 1024]))
    steps = []
    for _ in range(n_batches):
        steps.append({
            "layout": draw(st.sampled_from(["hor", "packed", "banded",
                                            None])),
            "delete": draw(st.integers(0, 5)),
            "compact": draw(st.booleans()),
        })
    return spec, bounds, steps, draw(st.integers(0, 1000)), policy_docs


def _run_schedule(spec, bounds, steps, seed, policy_docs=0):
    """Drive a SegmentedIndex through the drawn schedule; returns the
    index (delta sealed) and an rng for query sampling."""
    from repro.core import compaction, size_model
    from repro.core.build import TokenizedCorpus
    rng = np.random.default_rng(seed)
    tc = corpus.generate(spec)
    from repro.core.live_index import SegmentedIndex
    si = SegmentedIndex(term_hashes=tc.term_hashes,
                        delta_doc_capacity=48,
                        delta_posting_capacity=4096,
                        policy=compaction.TieredPolicy(size_ratio=4.0,
                                                       min_run=3),
                        layout_policy=(size_model.LayoutCostModel(
                            min_packed_docs=policy_docs)
                            if policy_docs else None))
    for (a, b), step in zip(zip(bounds[:-1], bounds[1:]), steps):
        si.add_batch(TokenizedCorpus(tc.doc_term_ids[a:b],
                                     tc.doc_counts[a:b],
                                     tc.term_hashes, b - a))
        si.seal(layout=step["layout"])
        if step["delete"]:
            live = np.flatnonzero(si.live_mask())
            kill = rng.choice(live, size=min(step["delete"], len(live)),
                              replace=False)
            si.delete(kill)
        if step["compact"]:
            si.compact()
    si.seal()                      # stragglers (post-delete reseals)
    return si, tc, rng


def _oracle_host(si):
    """bulk_build of the live corpus at the current epoch + the global
    ids of its (compact-renumbered) docs."""
    tc_live, live_ids = si.export_live_corpus()
    return build.bulk_build(tc_live), live_ids


@pytest.mark.slow
@settings(max_examples=8, deadline=None)
@given(sched=live_schedules())
def test_layout_parity_fuzz_single_host(sched):
    """Random schedules with per-seal random layout — including
    layout=None seals resolved by a drawn LayoutCostModel through the
    override ladder, and no-policy runs where None falls through to the
    default: the fused pallas engine (over the resulting
    hor/packed/mixed stack), the jnp oracle engine, the doc-sharded
    segment-stack scorer, and both term-sharded
    fused layouts all reproduce the bulk-build oracle's ranking —
    doc-partitioned paths bit-identically (ties included), term-sharded
    hor and packed bit-identical to EACH OTHER."""
    import jax
    from repro.distributed import retrieval
    si, tc, rng = _run_schedule(*sched)
    if (si.live_doc_count == 0 or si.num_segments == 0
            or int(np.asarray(si._df).sum()) == 0):
        return
    host, live_ids = _oracle_host(si)
    if host.num_postings == 0:
        return
    k = 10
    qh = corpus.sample_query_terms(np.asarray(si._df), si.term_hashes,
                                   3, 3, num_docs=si.live_doc_count,
                                   seed=int(rng.integers(1000)))
    # oracle over the live corpus, ids mapped back to global
    ref = query.make_scorer(layouts.build_blocked(host), k=k,
                            cap=max(host.max_posting_len, 1))(
        jnp.asarray(qh))
    oid = np.asarray(ref.doc_ids)
    want_ids = np.where(oid >= 0, live_ids[np.maximum(oid, 0)],
                        -1).astype(np.int32)
    want_scores = np.asarray(ref.scores)

    # single-host fused (pallas candidates) and jnp engines
    for engine in ("pallas", "jnp"):
        got = si.topk(qh, k=k, engine=engine)
        np.testing.assert_array_equal(np.asarray(got.doc_ids), want_ids)
        np.testing.assert_allclose(np.asarray(got.scores), want_scores,
                                   rtol=1e-5, atol=1e-7)

    # doc-sharded segment stack (mixed-layout groups) — bit-identical
    mesh = jax.make_mesh((1,), ("data",))
    stacks = retrieval.stack_segment_shards(si, 1)
    scorer = retrieval.make_doc_sharded_segment_scorer(stacks, mesh,
                                                       "data", k=k)
    for i, q in enumerate(qh):
        vv, ids = scorer(jnp.asarray(q))
        hit = np.isfinite(np.asarray(vv))
        np.testing.assert_array_equal(
            np.where(hit, np.asarray(ids), -1), want_ids[i])
        np.testing.assert_allclose(np.asarray(vv)[hit],
                                   want_scores[i][hit], rtol=1e-5,
                                   atol=1e-7)

    # term-sharded fused, both layouts, over the SAME live corpus:
    # hor == packed bitwise; both match the oracle's ranking
    tb = retrieval.build_term_sharded_blocked(host, 1)
    tp = retrieval.build_term_sharded_packed(host, 1)
    sh = retrieval.make_term_sharded_fused_scorer(tb, mesh, "data", k=k)
    sp = retrieval.make_term_sharded_fused_scorer(tp, mesh, "data", k=k)
    for i, q in enumerate(qh):
        hv, hi = sh(jnp.asarray(q))
        pv, pi = sp(jnp.asarray(q))
        np.testing.assert_array_equal(np.asarray(pv), np.asarray(hv))
        np.testing.assert_array_equal(np.asarray(pi), np.asarray(hi))
        hit = np.isfinite(np.asarray(pv))
        mapped = np.where((np.asarray(pi) >= 0) & hit,
                          live_ids[np.maximum(np.asarray(pi), 0)], -1)
        np.testing.assert_array_equal(mapped.astype(np.int32),
                                      want_ids[i])
        np.testing.assert_allclose(np.asarray(pv)[hit],
                                   want_scores[i][hit], rtol=1e-5,
                                   atol=1e-7)


SHARDED_FUZZ_SCRIPT = r"""
import numpy as np, jax, jax.numpy as jnp
from hypothesis import HealthCheck, given, settings, strategies as st
from repro.text import corpus
from repro.core import build, compaction, layouts, query
from repro.core.build import TokenizedCorpus
from repro.core.live_index import SegmentedIndex
from repro.distributed import retrieval

MESHES = {2: jax.sharding.Mesh(np.array(jax.devices()[:2]), ("data",)),
          4: jax.make_mesh((4,), ("data",))}


@settings(max_examples=6, deadline=None,
          suppress_health_check=list(HealthCheck))
@given(docs=st.integers(150, 300), vocab=st.integers(60, 200),
       avg=st.integers(5, 14), seed=st.integers(0, 5000),
       n_shards=st.sampled_from([2, 4]),
       layouts_seq=st.lists(st.sampled_from(["hor", "packed", "banded",
                                             None]),
                            min_size=4, max_size=4),
       policy_docs=st.sampled_from([0, 64, 256]),
       n_del=st.integers(0, 8))
def fuzz(docs, vocab, avg, seed, n_shards, layouts_seq, policy_docs,
         n_del):
    from repro.core import size_model
    mesh = MESHES[n_shards]
    rng = np.random.default_rng(seed)
    tc = corpus.generate(corpus.CorpusSpec(num_docs=docs, vocab=vocab,
                                           avg_distinct=avg, seed=seed))
    si = SegmentedIndex(term_hashes=tc.term_hashes,
                        delta_doc_capacity=128,
                        delta_posting_capacity=8192,
                        policy=compaction.TieredPolicy(min_run=100),
                        layout_policy=(size_model.LayoutCostModel(
                            min_packed_docs=policy_docs)
                            if policy_docs else None))
    step = docs // 4
    for i, a in enumerate(range(0, step * 4, step)):
        b = min(a + step, docs)
        si.add_batch(TokenizedCorpus(tc.doc_term_ids[a:b],
                                     tc.doc_counts[a:b],
                                     tc.term_hashes, b - a))
        si.seal(layout=layouts_seq[i])
    if n_del:
        live = np.flatnonzero(si.live_mask())
        si.delete(rng.choice(live, size=min(n_del, len(live)),
                             replace=False))
    si.seal()
    if (si.num_segments < n_shards or si.live_doc_count == 0
            or int(np.asarray(si._df).sum()) == 0):
        return
    k = 10
    qh = corpus.sample_query_terms(np.asarray(si._df), si.term_hashes,
                                   2, 3, num_docs=si.live_doc_count,
                                   seed=seed)

    # doc-sharded stacks (hor/packed/mixed): bit-identical to the
    # single-node live index (which is itself oracle-parity-tested)
    stacks = retrieval.stack_segment_shards(si, n_shards)
    scorer = retrieval.make_doc_sharded_segment_scorer(stacks, mesh,
                                                       "data", k=k)
    for q in qh:
        vv, ids = scorer(jnp.asarray(q))
        ref = si.topk(q[None], k=k)
        np.testing.assert_array_equal(np.asarray(ids),
                                      np.asarray(ref.doc_ids)[0])
        np.testing.assert_allclose(np.asarray(vv),
                                   np.asarray(ref.scores)[0],
                                   rtol=1e-5, atol=1e-7)

    # term-sharded over the live corpus: hor == packed BITWISE; both
    # match the oracle up to float-tie permutations (the [D] psum
    # regroups float adds across shards)
    tc_live, live_ids = si.export_live_corpus()
    host = build.bulk_build(tc_live)
    if host.num_postings == 0:
        return
    ref_sc = query.make_scorer(layouts.build_blocked(host), k=k,
                               cap=max(host.max_posting_len, 1))
    tb = retrieval.build_term_sharded_blocked(host, n_shards)
    tp = retrieval.build_term_sharded_packed(host, n_shards)
    sh = retrieval.make_term_sharded_fused_scorer(tb, mesh, "data", k=k)
    sp = retrieval.make_term_sharded_fused_scorer(tp, mesh, "data", k=k)
    for q in qh:
        hv, hi = sh(jnp.asarray(q))
        pv, pi = sp(jnp.asarray(q))
        np.testing.assert_array_equal(np.asarray(pv), np.asarray(hv))
        np.testing.assert_array_equal(np.asarray(pi), np.asarray(hi))
        ref = ref_sc(jnp.asarray(q[None]))
        rv = np.asarray(ref.scores)[0]
        rid = np.asarray(ref.doc_ids)[0]
        np.testing.assert_allclose(np.asarray(pv), rv, rtol=1e-5,
                                   atol=1e-7)
        # the [D] psum regroups float adds across shards, so near-ties
        # AT the k-th score may legally permute: every ref doc strictly
        # above the k-th score must still be present
        hit = rid >= 0
        if hit.any():
            kth = rv[hit][-1]
            strong = hit & (rv > kth + max(abs(kth) * 1e-5, 1e-7))
            got = set(np.asarray(pi).tolist())
            assert set(rid[strong].tolist()) <= got, (rid, pi)

    # bulk doc-sharded rebuild, both layouts, over the SAME live
    # corpus: packed must be BIT-identical to hor (same shard bounds,
    # same per-shard posting order, same candidate-merge tier)
    db = retrieval.build_doc_sharded_blocked(host, n_shards)
    dp = retrieval.build_doc_sharded_packed(host, n_shards)
    dh = retrieval.make_doc_sharded_fused_scorer(db, mesh, "data", k=k)
    dpk = retrieval.make_doc_sharded_fused_scorer(dp, mesh, "data", k=k)
    for q in qh:
        hv, hi = dh(jnp.asarray(q))
        pv, pi = dpk(jnp.asarray(q))
        np.testing.assert_array_equal(np.asarray(pv), np.asarray(hv))
        np.testing.assert_array_equal(np.asarray(pi), np.asarray(hi))


fuzz()
print("SHARDED_FUZZ_OK")
"""


@pytest.mark.slow
def test_layout_parity_fuzz_sharded():
    """The multi-device half of the fuzz suite (daily CI): random
    corpora and mixed-layout seal schedules (including
    chooser-resolved layout=None seals) across 2- and 4-shard meshes,
    doc-sharded stacks bit-identical to the live index, term-sharded
    hor/packed bit-identical to each other, and the bulk doc-sharded
    packed rebuild bit-identical to its hor twin (subprocess: XLA
    device count must be set before jax initializes)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", SHARDED_FUZZ_SCRIPT],
                         env=env, capture_output=True, text=True,
                         timeout=1200)
    assert "SHARDED_FUZZ_OK" in out.stdout, out.stderr[-4000:]


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 300), st.integers(1, 50))
def test_scores_bounded_by_cosine(d, seed):
    """Scores are cosine similarities -> bounded by ~1 + rank blend."""
    spec = corpus.CorpusSpec(num_docs=max(d, 20), vocab=60, avg_distinct=8,
                             seed=seed)
    host = build.bulk_build(corpus.generate(spec))
    ix = layouts.build_csr(host)
    qh = corpus.sample_query_terms(host.df, host.term_hashes, 1, 2,
                                   num_docs=host.num_docs, seed=seed)[0]
    r = query.score_query(ix, jnp.asarray(qh), k=5,
                          cap=max(host.max_posting_len, 1))
    sc = np.asarray(r.scores)
    assert (sc[np.isfinite(sc)] <= 1.0 + 1e-5).all()
