"""Observability tier: span tracing, the unified metrics registry, the
maintenance event log, and their serving/kernel integration.

The two load-bearing contracts:

  * stage spans share boundary timestamps, so a sampled response's
    top-level durations sum (exactly; asserted at 5%) to its measured
    e2e latency, and tracing NEVER changes engine output — traced and
    untraced servers answer bit-identically over a randomized churn
    schedule;
  * with tracing disabled (the default) no Span/Trace object is
    constructed anywhere on the serving path — asserted by making
    construction raise.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import build, compaction, layouts, query
from repro.core.live_index import SegmentedIndex
from repro.kernels import ops
from repro.obs import trace as obs_trace
from repro.obs.registry import (GLOBAL, EventLog, MetricsRegistry,
                                parse_prometheus, snapshot_from_json,
                                snapshot_to_json)
from repro.obs.trace import StageAggregator, Trace, Tracer
from repro.serve import QueryServer, ServerConfig
from repro.serve.cache import ResultCache
from repro.serve.metrics import LatencyWindow, ServerMetrics, percentiles
from repro.text import corpus


def _slice(tc, a, b):
    return dataclasses.replace(tc, doc_term_ids=tc.doc_term_ids[a:b],
                               doc_counts=tc.doc_counts[a:b],
                               num_docs=b - a)


# ---------------------------------------------------------------------------
# percentiles / LatencyWindow vs the numpy reference
# ---------------------------------------------------------------------------


def test_percentiles_match_numpy_reference():
    rng = np.random.default_rng(3)
    samples = rng.lognormal(4.0, 1.5, size=257)
    p = percentiles(samples, (50, 90, 99))
    for q in (50, 90, 99):
        assert p[f"p{q}"] == pytest.approx(
            float(np.percentile(samples, q)), rel=1e-12)


def test_percentiles_empty_and_single_sample():
    assert percentiles([]) == {"p50": 0.0, "p99": 0.0}
    p = percentiles([42.5])
    assert p["p50"] == 42.5 and p["p99"] == 42.5


def test_latency_window_edges():
    w = LatencyWindow()
    # empty window: zeros everywhere, qps 0 (not NaN/raise)
    s = w.summary()
    assert s == {"count": 0, "p50_us": 0.0, "p99_us": 0.0,
                 "mean_us": 0.0, "qps": 0.0}
    # single sample: percentiles collapse to it, qps still 0 (one
    # completion spans no interval)
    w.record(100.0)
    s = w.summary()
    assert s["count"] == 1 and s["p50_us"] == 100.0
    assert s["qps"] == 0.0
    # zero wall span with >= 2 completions must not divide by zero
    w.record(50.0)
    w._last = w._first
    assert w.qps() == 0.0
    w.reset()
    assert w.count == 0 and w.qps() == 0.0


# ---------------------------------------------------------------------------
# spans and the tracer
# ---------------------------------------------------------------------------


def test_span_shared_boundaries_sum_exactly():
    tr = Trace()
    a = tr.span("queue_wait", t0=1.0).end(2.5)
    b = tr.span("score", t0=a.t1).end(4.0)
    tr.span("segment", t0=3.0, parent="score").end(3.5)  # child: excluded
    tr.span("respond", t0=b.t1).end(5.0)
    d = tr.stage_durations()
    assert set(d) == {"queue_wait", "score", "respond"}
    assert sum(d.values()) == pytest.approx((5.0 - 1.0) * 1e6)
    assert tr.total_us() == pytest.approx(4e6)


def test_tracer_sampling_and_disabled():
    t = Tracer(sample_every=3)
    got = [t.sample() is not None for _ in range(9)]
    assert got == [False, False, True] * 3
    off = Tracer(sample_every=0)
    assert not off.enabled
    assert all(off.sample() is None for _ in range(5))


def test_stage_aggregator_feeds_registry_histograms():
    reg = MetricsRegistry()
    agg = StageAggregator(reg)
    tr = Trace()
    tr.span("score", t0=0.0).end(0.001)          # 1000us
    tr.span("respond", t0=0.001).end(0.0015)     # 500us
    agg.observe_trace(tr)
    agg.observe("score", 3000.0)
    s = agg.summary()
    assert s["score"]["count"] == 2
    assert s["score"]["sum"] == pytest.approx(4000.0)
    assert reg.get("serve_stage_score_us") is not None
    assert "type" not in s["score"]              # summary strips it
    agg.reset()
    assert agg.summary()["score"]["count"] == 0


# ---------------------------------------------------------------------------
# registry: instruments, export round-trips, failure modes
# ---------------------------------------------------------------------------


def _populated_registry() -> MetricsRegistry:
    reg = MetricsRegistry()
    reg.counter("serve_requests").inc(123)
    reg.gauge("delta_fill").set(0.62519731)
    reg.register_callback("index_epoch", lambda: 7)
    h = reg.histogram("serve_stage_score_us")
    for v in (101.5, 220.25, 3000.125, 47.0625):
        h.observe(v)
    return reg


def test_registry_snapshot_json_roundtrip():
    reg = _populated_registry()
    snap = reg.snapshot()
    assert snap["serve_requests"] == {"type": "counter", "value": 123}
    assert snap["index_epoch"] == {"type": "gauge", "value": 7.0}
    assert snap["serve_stage_score_us"]["count"] == 4
    restored = snapshot_from_json(snapshot_to_json(snap))
    assert restored == snap
    # and the JSON is plain-json safe (no numpy scalars leaked)
    json.dumps(snap)


def test_registry_prometheus_roundtrip():
    reg = _populated_registry()
    text = reg.to_prometheus()
    assert "# TYPE serve_requests counter" in text
    assert '{quantile="0.5"}' in text
    assert parse_prometheus(text) == reg.snapshot()


def test_registry_get_or_create_and_type_mismatch():
    reg = MetricsRegistry()
    c = reg.counter("x_total")
    assert reg.counter("x_total") is c
    with pytest.raises(TypeError):
        reg.gauge("x_total")
    reg.register_callback("live", lambda: 1.0)
    with pytest.raises(ValueError):
        reg.register_callback("live", lambda: 2.0)
    with pytest.raises(ValueError):
        reg.counter("bad name")
    with pytest.raises(ValueError):
        reg.counter("serve_requests").inc(-1)


def test_registry_reset_spares_callback_gauges():
    reg = _populated_registry()
    reg.reset()
    snap = reg.snapshot()
    assert snap["serve_requests"]["value"] == 0
    assert snap["serve_stage_score_us"]["count"] == 0
    assert snap["index_epoch"]["value"] == 7.0   # reads live state


# ---------------------------------------------------------------------------
# event log
# ---------------------------------------------------------------------------


def test_event_log_bounded_ring_and_counts():
    log = EventLog(capacity=4)
    for i in range(10):
        log.emit("seal", epoch=i)
    log.emit("compact", merged=3)
    assert len(log) == 4                 # ring evicted the oldest
    assert log.total == 11               # ...but the count survived
    assert log.counts() == {"seal": 10, "compact": 1}
    tail = log.tail(2)
    assert [e["kind"] for e in tail] == ["seal", "compact"]
    assert tail[-1]["seq"] == 11 and tail[-1]["merged"] == 3
    assert [e["epoch"] for e in log.tail(kind="seal")] == [7, 8, 9]


def test_segmented_index_emits_lifecycle_events():
    tc = corpus.generate(corpus.CorpusSpec(num_docs=600, vocab=200,
                                           avg_distinct=16, seed=6))
    si = SegmentedIndex(term_hashes=tc.term_hashes, delta_doc_capacity=128,
                        delta_posting_capacity=128 * 64,
                        policy=compaction.TieredPolicy(size_ratio=2.0,
                                                       min_run=2))
    for a in range(0, 600, 150):
        si.add_batch(_slice(tc, a, a + 150))
        si.seal()
    si.compact(all_segments=True)
    si.delete([1, 3])
    counts = si.events.counts()
    assert counts["ingest"] == 4 and counts["seal"] >= 4
    assert counts["compact"] >= 1 and counts["delete"] == 1
    seal = si.events.tail(kind="seal")[0]
    for field in ("epoch", "doc_base", "docs", "postings", "size_class",
                  "layout", "chooser_reason", "duration_us"):
        assert field in seal, field
    compact = si.events.tail(kind="compact")[-1]
    assert compact["postings_in"] >= compact["merged"] >= 2


# ---------------------------------------------------------------------------
# ServerMetrics: registry-backed counters, complete summary, deprecation
# ---------------------------------------------------------------------------


def test_server_metrics_registry_backed_and_summary_complete():
    cache = ResultCache(capacity=8)
    m = ServerMetrics(cache=cache)
    m.requests += 3
    m.batched_queries, m.padded_slots = 6, 2
    assert m.registry.counter("serve_requests").value == 3
    assert m.batch_fill() == pytest.approx(0.75)
    key = cache.make_key(np.asarray([1, 2], np.uint32), 10, 0)
    cache.put(key, np.asarray([5]), np.asarray([1.0]))
    cache.get(key)
    cache.get(cache.make_key(np.asarray([9, 9], np.uint32), 10, 0))
    s = m.summary()                      # no cache argument needed
    assert s["cache_hits"] == 1 and s["cache_misses"] == 1
    assert s["cache_hit_rate"] == pytest.approx(0.5)
    snap = m.snapshot()
    assert snap["cache_hits"]["value"] == 1.0
    assert snap["serve_requests"]["value"] == 3
    m.reset()
    assert m.requests == 0
    assert m.snapshot()["cache_hits"]["value"] == 1.0   # cache untouched


def test_server_metrics_summary_cache_arg_deprecated():
    cache = ResultCache(capacity=4)
    m = ServerMetrics(cache=cache)
    other = ResultCache(capacity=4)
    other.get(other.make_key(np.asarray([1], np.uint32), 10, 0))  # a miss
    with pytest.warns(DeprecationWarning):
        s = m.summary(other)
    # the parameter is inert: the attached cache is reported, the
    # passed one's counters never leak into the summary
    assert s["cache_hits"] == cache.hits
    assert s["cache_misses"] == cache.misses == 0

    # a metrics object with NO attached cache: the deprecated argument
    # still warns and still reports nothing (migration is attach_cache)
    bare = ServerMetrics()
    with pytest.warns(DeprecationWarning):
        s2 = bare.summary(other)
    assert "cache_hits" not in s2


# ---------------------------------------------------------------------------
# engine counters on the GLOBAL registry (jit-safe via debug.callback)
# ---------------------------------------------------------------------------


def test_overflow_counter_increments_on_engineered_corpus():
    """The PR-6 overflow corpus (2600 docs / 80 terms / seed 1) under
    the deliberately narrow pre-fix budget drops real pairs; the loud-
    overflow warning must now ALSO land in the global registry counter
    so capacity pressure is visible without scraping stderr."""
    from repro.kernels.fused_decode_score import build_batched_pairs

    tc = corpus.generate(corpus.CorpusSpec(num_docs=2600, vocab=80,
                                           avg_distinct=20, seed=1))
    host = build.bulk_build(tc)
    ix = layouts.build_blocked(host)
    cap = host.max_posting_len
    th = host.term_hashes
    qh = jnp.asarray(th[th != 0][None, :])
    t_ids = jnp.where(qh != 0, ix.lookup_terms(qh), -1)
    m = min(max(-(-cap // ix.block), 1), max(ix.max_blocks_per_term, 1))
    cb, cv, cq, cw, cc = ops.expand_block_candidates(
        ix.block_offsets, t_ids, jnp.ones_like(t_ids, jnp.float32), m,
        ix.block, cap)
    tf, tcn, n_tiles = ops.routing_spans(ix, 512)
    narrow = ops.round_up_pairs(ops.scaled_pairs_budget(ix, 512), 2)
    *_, ovf = build_batched_pairs(
        cb, cv, cq, cw.astype(jnp.float32), tf, tcn, n_tiles, 1, narrow,
        cand_cap=cc, pairs_per_step=2)
    assert int(ovf) > 0
    c = GLOBAL.counter("engine_pair_overflow")
    before = c.value
    ops.warn_on_overflow(ovf, "test_obs narrow budget")
    jax.effects_barrier()
    assert c.value == before + int(ovf)
    # zero overflow takes the silent branch: no increment
    ops.warn_on_overflow(jnp.zeros((), jnp.int32), "test_obs zero")
    jax.effects_barrier()
    assert c.value == before + int(ovf)


def test_overflow_counter_increments_under_jit():
    c = GLOBAL.counter("engine_pair_overflow")
    before = c.value

    @jax.jit
    def f(o):
        ops.warn_on_overflow(o, "test_obs jitted")
        return o + 1

    f(jnp.asarray(7, jnp.int32)).block_until_ready()
    jax.effects_barrier()
    assert c.value == before + 7


def test_truncated_terms_counter_via_conjunctive(small_host):
    ix = layouts.build_csr(small_host)
    df = np.asarray(small_host.df)
    # query the two most frequent terms with a cap below both dfs:
    # the gather truncates both posting lists
    busy = np.argsort(df)[-2:]
    cap = int(df[busy].min()) - 1
    assert cap >= 1
    qh = jnp.asarray(small_host.term_hashes[busy])
    c = GLOBAL.counter("engine_truncated_terms")
    before = c.value
    _, stats = query.conjunctive_filter(ix, qh, k=5, cap=cap)
    jax.effects_barrier()
    expect = int(stats["truncated_terms"])
    assert expect == 2
    assert c.value == before + expect
    # host-side ints route through the same counter without jax
    ops.record_truncated(3)
    assert c.value == before + expect + 3
    ops.record_truncated(0)
    assert c.value == before + expect + 3


# ---------------------------------------------------------------------------
# serving integration: disabled-tracing overhead, stage sums, parity
# ---------------------------------------------------------------------------


def _mini_corpus():
    return corpus.generate(corpus.CorpusSpec(num_docs=900, vocab=300,
                                             avg_distinct=16, seed=9))


def _make_server(tc, trace_sample):
    si = SegmentedIndex(term_hashes=tc.term_hashes, delta_doc_capacity=128,
                        delta_posting_capacity=128 * 64,
                        policy=compaction.TieredPolicy(size_ratio=4.0,
                                                       min_run=4))
    si.add_batch(_slice(tc, 0, 300))
    si.seal()
    cfg = ServerConfig(batch_size=4, n_terms_budget=8, k=10,
                       trace_sample=trace_sample)
    return si, QueryServer(si, cfg)


def _drive(si, server, tc, pool, *, seed=17, steps=8):
    """One randomized churn schedule: ingest/seal/compact interleaved
    with micro-batches.  Deterministic given ``seed``, so two
    identically-seeded servers see identical schedules."""
    rng = np.random.default_rng(seed)
    responses = []
    a = 300
    for step in range(steps):
        op = rng.integers(3)
        if op == 0 and a + 100 <= tc.num_docs:
            with server.index_lock:
                si.add_batch(_slice(tc, a, a + 100))
            a += 100
        elif op == 1:
            with server.index_lock:
                si.seal()
        elif op == 2:
            with server.index_lock:
                si.compact()
        tickets = [server.submit(pool[rng.integers(len(pool))])
                   for _ in range(4)]
        while server.pending:
            server.pump()
        responses += [t.result(timeout=120.0) for t in tickets]
    return responses


def test_disabled_tracing_constructs_no_span_objects(monkeypatch):
    """trace_sample=0 (the default) must never construct Span/Trace on
    the serving path — near-zero cost when off is the contract."""
    tc = _mini_corpus()
    si, server = _make_server(tc, trace_sample=0)
    server.warmup()

    def boom(self, *a, **k):
        raise AssertionError(f"{type(self).__name__} constructed with "
                             "tracing disabled")

    monkeypatch.setattr(obs_trace.Span, "__init__", boom)
    monkeypatch.setattr(obs_trace.Trace, "__init__", boom)
    pool = corpus.sample_query_terms(
        build.bulk_build(_slice(tc, 0, 300)).df, tc.term_hashes, 8, 3,
        num_docs=300, seed=2)
    responses = _drive(si, server, tc, pool, steps=4)
    assert len(responses) == 16
    assert all(r.trace is None for r in responses)
    assert server.stage_summary() == {}


def test_traced_stage_sums_and_bitwise_parity_under_churn():
    """The acceptance criterion: per-response stage durations sum to
    within 5% of the measured e2e latency (the shared-boundary
    construction makes it exact), and a traced server's outputs are
    BIT-identical to an untraced server's over the same randomized
    churn schedule — observability must never perturb results."""
    tc = _mini_corpus()
    pool = corpus.sample_query_terms(
        build.bulk_build(_slice(tc, 0, 300)).df, tc.term_hashes, 8, 3,
        num_docs=300, seed=2)
    si_t, srv_t = _make_server(tc, trace_sample=1)
    si_u, srv_u = _make_server(tc, trace_sample=0)
    srv_t.warmup()
    srv_u.warmup()
    traced = _drive(si_t, srv_t, tc, pool, seed=21)
    plain = _drive(si_u, srv_u, tc, pool, seed=21)

    assert len(traced) == len(plain)
    for rt, ru in zip(traced, plain):
        assert rt.trace is not None and ru.trace is None
        assert rt.epoch == ru.epoch
        np.testing.assert_array_equal(np.asarray(rt.doc_ids),
                                      np.asarray(ru.doc_ids))
        np.testing.assert_array_equal(
            np.asarray(rt.scores, np.float32).view(np.uint32),
            np.asarray(ru.scores, np.float32).view(np.uint32))
        stages = rt.trace.stage_durations()
        total = sum(stages.values())
        assert total == pytest.approx(rt.latency_us, rel=0.05)
        if rt.cached:
            assert set(stages) == {"queue_wait", "cache_hit"}
        else:
            assert set(stages) == {"queue_wait", "assemble", "score",
                                   "respond"}
            kids = {s.name for s in rt.trace.spans if s.parent == "score"}
            assert "segment" in kids and "merge" in kids
            seg = next(s for s in rt.trace.spans if s.name == "segment")
            for attr in ("size_class", "layout", "tile",
                         "candidate_bytes", "posting_bytes"):
                assert attr in seg.attrs, attr

    # uncached responses exist and their scoring really took the traced
    # path (epochs advanced under churn)
    assert any(not r.cached for r in traced)
    summary = srv_t.stage_summary()
    assert summary["e2e"]["count"] == len(traced)
    assert summary["score"]["p99"] > 0
    # the server-side snapshot merges per-server and GLOBAL engine
    # counters into one export (get-or-create so the assertion holds
    # even when this test runs before any engine counter fires)
    GLOBAL.counter("engine_pair_overflow")
    snap = srv_t.metrics_snapshot()
    assert "engine_pair_overflow" in snap
    assert snap["serve_requests"]["value"] == len(traced)
    assert "serve_stage_score_us" in snap
    json.dumps(snap)
    # maintenance events are queryable from the server
    assert any(e["kind"] == "seal" for e in srv_t.events())


# ---------------------------------------------------------------------------
# CI artifact gate: malformed registry sections fail loudly
# ---------------------------------------------------------------------------


def test_check_regression_rejects_malformed_registry():
    from benchmarks.check_regression import check_registry_section

    ok = {"registry": {"serve_requests": {"type": "counter", "value": 3},
                       "delta_fill": {"type": "gauge", "value": 0.5},
                       "serve_stage_score_us": {
                           "type": "histogram", "count": 2, "sum": 10.0,
                           "p50": 5.0, "p99": 9.0}},
          "stages": {"score": {"count": 2, "p50": 5.0, "p99": 9.0,
                               "sum": 10.0}}}
    assert check_registry_section(ok) == []
    assert check_registry_section({}) != []              # missing
    assert check_registry_section({"registry": {}}) != []  # empty
    bad_counter = json.loads(json.dumps(ok))
    bad_counter["registry"]["serve_requests"]["value"] = "3"
    assert any("counter" in p for p in check_registry_section(bad_counter))
    bad_hist = json.loads(json.dumps(ok))
    del bad_hist["registry"]["serve_stage_score_us"]["p99"]
    assert any("p99" in p for p in check_registry_section(bad_hist))
    bad_type = json.loads(json.dumps(ok))
    bad_type["registry"]["delta_fill"]["type"] = "dial"
    assert any("unknown" in p for p in check_registry_section(bad_type))
    no_stages = json.loads(json.dumps(ok))
    no_stages["stages"] = {}
    assert any("stages" in p for p in check_registry_section(no_stages))


def test_event_log_capacity_configurable_end_to_end():
    """EventLog capacity is caller-sized, not the hard-coded 256:
    ``resize`` rebounds the ring keeping the newest events (seq and
    per-kind counts survive), ``SegmentedIndex(event_capacity=)`` sizes
    the index's log at construction, and the serving tier plumbs it
    (``ServerConfig.event_capacity`` resizes the served index,
    ``MeshConfig`` inherits it for every replica)."""
    log = EventLog(capacity=4)
    for i in range(6):
        log.emit("seal", epoch=i)
    assert log.capacity == 4 and len(log) == 4
    log.resize(2)                         # shrink keeps the NEWEST
    assert log.capacity == 2
    assert [e["epoch"] for e in log.tail(10)] == [4, 5]
    assert log.total == 6 and log.counts() == {"seal": 6}
    log.resize(8)                         # grow keeps everything held
    log.emit("compact", merged=2)
    assert len(log) == 3
    with pytest.raises(ValueError):
        log.resize(0)

    tc = corpus.generate(corpus.CorpusSpec(num_docs=200, vocab=100,
                                           avg_distinct=10, seed=4))
    si = SegmentedIndex(term_hashes=tc.term_hashes,
                        delta_doc_capacity=200, event_capacity=7)
    assert si.events.capacity == 7

    from repro.serve import MeshConfig, MeshServer, QueryServer, ServerConfig
    si.add_batch(_slice(tc, 0, 200))
    si.seal()
    QueryServer(si, ServerConfig(backend="xla", event_capacity=9))
    assert si.events.capacity == 9
    QueryServer(si, ServerConfig(backend="xla"))     # None leaves it alone
    assert si.events.capacity == 9

    import jax
    ms = MeshServer(si, MeshConfig(batch_size=4, k=10, n_shards=1,
                                   n_replicas=2, auto_handoff=False,
                                   event_capacity=11),
                    mesh=jax.make_mesh((1,), ("shards",)))
    assert all(r.index.events.capacity == 11 for r in ms.replicas)
    ms.stop()
