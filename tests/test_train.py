"""Training substrate: optimizer, checkpoint/restart, retry, elastic."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import transformer as tfm
from repro.train import checkpoint as ckpt_lib
from repro.train import data as data_lib
from repro.train import elastic, loop as loop_lib, optimizer as opt_lib


def tiny_setup():
    cfg = tfm.TransformerConfig(name="t", n_layers=2, d_model=32,
                                n_heads=2, n_kv_heads=2, head_dim=16,
                                d_ff=64, vocab=128, chunk_q=8, loss_chunk=8)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    ocfg = opt_lib.AdamWConfig(lr=1e-2, warmup_steps=2, total_steps=40)
    step = jax.jit(opt_lib.make_train_step(
        lambda p, b: tfm.loss_fn(p, cfg, b), ocfg))
    mk = lambda s: jax.tree.map(                      # noqa: E731
        jnp.asarray, data_lib.lm_batch(0, s, 4, 16, 128))
    return params, opt_lib.init(params), step, mk


def test_loss_descends():
    params, state, step, mk = tiny_setup()
    first = last = None
    for i in range(12):
        params, state, m = step(params, state, mk(0))
        if first is None:
            first = float(m["loss"])
        last = float(m["loss"])
    assert last < first


def test_grad_clipping_reported():
    params, state, step, mk = tiny_setup()
    _, _, m = step(params, state, mk(0))
    assert float(m["grad_norm"]) > 0
    assert float(m["lr"]) > 0


def test_checkpoint_atomic_and_restartable():
    params, state, step, mk = tiny_setup()
    with tempfile.TemporaryDirectory() as d:
        cfg = loop_lib.LoopConfig(total_steps=10, ckpt_dir=d, ckpt_every=4,
                                  log_every=0)
        res = loop_lib.fit(step, params, state, mk, cfg)
        # steps list only contains COMMITTED checkpoints
        steps = ckpt_lib.list_steps(d)
        assert steps[-1] == 10
        # simulate a crash after step 8: drop the final checkpoint, then
        # restart — the loop resumes from 8 and REPLAYS steps 9-10 with
        # identical batches, landing on the identical loss.
        import shutil
        shutil.rmtree(ckpt_lib._step_dir(d, 10))
        res2 = loop_lib.fit(step, params, state, mk, cfg)
        np.testing.assert_allclose(float(res.metrics["loss"]),
                                   float(res2.metrics["loss"]), rtol=1e-6)
        # corrupt an in-progress write -> ignored
        os.makedirs(os.path.join(d, ".tmp_garbage"), exist_ok=True)
        ckpt_lib.save(d, 11, (res.params, res.opt_state))
        assert not os.path.exists(os.path.join(d, ".tmp_garbage"))


def test_restore_onto_mesh():
    """Elastic restore: leaves placed with current-mesh shardings."""
    params, state, *_ = tiny_setup()
    mesh = jax.make_mesh((1,), ("data",))
    with tempfile.TemporaryDirectory() as d:
        ckpt_lib.save(d, 1, params)
        restored, step = elastic.recover(
            d, params, mesh, lambda path, leaf: jax.sharding.PartitionSpec())
        assert step == 1
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_loop_retries_transient_failures():
    params, state, step, mk = tiny_setup()
    calls = {"n": 0}

    def flaky(p, s, b):
        calls["n"] += 1
        if calls["n"] == 2:
            raise RuntimeError("simulated preemption")
        return step(p, s, b)

    cfg = loop_lib.LoopConfig(total_steps=3, log_every=0, max_retries=2)
    res = loop_lib.fit(flaky, params, state, mk, cfg)
    assert res.retries == 1
    assert res.step == 3


def test_loop_raises_after_max_retries():
    params, state, step, mk = tiny_setup()

    def dead(p, s, b):
        raise RuntimeError("hard failure")

    cfg = loop_lib.LoopConfig(total_steps=1, log_every=0, max_retries=1)
    with pytest.raises(RuntimeError):
        loop_lib.fit(dead, params, state, mk, cfg)


def test_straggler_detection():
    params, state, step, mk = tiny_setup()
    import time

    def slow(p, s, b):
        time.sleep(0.05)
        return step(p, s, b)

    cfg = loop_lib.LoopConfig(total_steps=2, log_every=0,
                              step_deadline_s=0.01)
    res = loop_lib.fit(slow, params, state, mk, cfg)
    assert res.stragglers == 2


def test_neighbor_sampler_shapes_and_validity():
    g = data_lib.make_synthetic_graph(500, 4000, 8, 4, seed=0)
    sampler = data_lib.NeighborSampler(g, batch_nodes=8, fanout=(3, 2))
    b1 = sampler.sample(0)
    b2 = sampler.sample(0)
    np.testing.assert_array_equal(b1["src"], b2["src"])  # deterministic
    cap = 8 + 8 * 3 + 8 * 3 * 2
    assert b1["feats"].shape == (cap, 8)
    keep = b1["dst"] < cap
    assert (b1["src"][keep] < cap).all()
    assert b1["mask"].sum() <= 8


def test_prefetcher():
    seen = []
    pf = data_lib.Prefetcher(lambda s: {"step": s}, start_step=0, depth=2)
    it = iter(pf)
    for _ in range(3):
        seen.append(next(it)["step"])
    pf.close()
    assert seen == [0, 1, 2]
