import os
import sys

# src/ layout import without install
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def small_host():
    """A small synthetic corpus + canonical postings, shared per session."""
    from repro.core import build
    from repro.text import corpus
    tc = corpus.generate(corpus.CorpusSpec(num_docs=400, vocab=900,
                                           avg_distinct=30, seed=11))
    return build.bulk_build(tc)


@pytest.fixture(scope="session")
def query_hashes(small_host):
    from repro.text import corpus
    return corpus.sample_query_terms(small_host.df, small_host.term_hashes,
                                     6, 4, num_docs=small_host.num_docs,
                                     seed=3)
