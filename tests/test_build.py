"""Index construction: bulk vs incremental; direct index; expansion."""
import jax.numpy as jnp
import numpy as np

from repro.core import build, direct_index, layouts, query
from repro.text import corpus


def test_bulk_equals_incremental():
    """§3.6: building in two batches == building in one pass."""
    spec = corpus.CorpusSpec(num_docs=120, vocab=300, avg_distinct=20,
                             seed=5)
    tc = corpus.generate(spec)
    full = build.bulk_build(tc)

    half = 60
    tc1 = build.TokenizedCorpus(tc.doc_term_ids[:half], tc.doc_counts[:half],
                                tc.term_hashes, half)
    tc2 = build.TokenizedCorpus(tc.doc_term_ids[half:], tc.doc_counts[half:],
                                tc.term_hashes, tc.num_docs - half)
    part = build.bulk_build(tc1)
    merged = build.add_documents(part, tc2)
    assert merged.num_postings == full.num_postings
    np.testing.assert_array_equal(merged.df, full.df)
    np.testing.assert_array_equal(merged.doc_ids, full.doc_ids)
    np.testing.assert_allclose(merged.norm, full.norm, rtol=1e-6)


def test_corpus_stats(small_host):
    st = build.corpus_stats(small_host)
    assert st.D == small_host.num_docs
    assert st.W == small_host.num_terms
    assert st.N_d == small_host.num_postings
    assert st.N_d >= st.W     # the paper's key inequality premise


def test_direct_vs_scan_expansion(small_host, query_hashes):
    """§4.4: direct-index expansion == full-scan expansion (fast vs slow)."""
    ix = layouts.build_csr(small_host)
    cap = small_host.max_posting_len
    r = query.score_query(ix, jnp.asarray(query_hashes[0]), k=5, cap=cap)
    di = direct_index.build_direct(small_host)
    fast = direct_index.expand_query(di, r.doc_ids, small_host.num_terms,
                                     cap=di.max_doc_len)
    slow = direct_index.expand_query_scan(ix, r.doc_ids,
                                          small_host.num_terms)
    np.testing.assert_allclose(np.asarray(fast.weights),
                               np.asarray(slow.weights), rtol=1e-5)
    assert np.asarray(fast.term_ids).tolist() == \
        np.asarray(slow.term_ids).tolist()


def test_relevance_feedback(small_host, query_hashes):
    di = direct_index.build_direct(small_host)
    ix = layouts.build_csr(small_host)
    q = jnp.asarray(query_hashes[0])
    tids = ix.lookup_terms(q)
    r = query.score_query(ix, q, k=3, cap=small_host.max_posting_len)
    fb = direct_index.relevance_feedback(di, r.doc_ids, tids,
                                         small_host.num_terms,
                                         cap=di.max_doc_len)
    assert (np.asarray(fb.weights) >= 0).all()
    assert (np.asarray(fb.term_ids) >= -1).all()
