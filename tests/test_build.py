"""Index construction: bulk vs incremental; direct index; expansion."""
import jax.numpy as jnp
import numpy as np

from repro.core import build, direct_index, layouts, query
from repro.text import corpus


def test_bulk_equals_incremental():
    """§3.6: building in two batches == building in one pass."""
    spec = corpus.CorpusSpec(num_docs=120, vocab=300, avg_distinct=20,
                             seed=5)
    tc = corpus.generate(spec)
    full = build.bulk_build(tc)

    half = 60
    tc1 = build.TokenizedCorpus(tc.doc_term_ids[:half], tc.doc_counts[:half],
                                tc.term_hashes, half)
    tc2 = build.TokenizedCorpus(tc.doc_term_ids[half:], tc.doc_counts[half:],
                                tc.term_hashes, tc.num_docs - half)
    part = build.bulk_build(tc1)
    merged = build.add_documents(part, tc2)
    assert merged.num_postings == full.num_postings
    np.testing.assert_array_equal(merged.df, full.df)
    np.testing.assert_array_equal(merged.doc_ids, full.doc_ids)
    np.testing.assert_allclose(merged.norm, full.norm, rtol=1e-6)


def test_merge_vocab_vectorized():
    """The searchsorted remap matches the legacy dict-loop semantics:
    found hashes map to old ids, new hashes append in first-appearance
    order."""
    old = np.array([50, 10, 30], np.uint32)
    new = np.array([30, 7, 10, 99, 7], np.uint32)
    merged, remap = build.merge_vocab(old, new)
    # reference: the pre-vectorization dict loop
    hash_to_old = {int(h): i for i, h in enumerate(old)}
    ref_remap, extra = [], []
    for h in new:
        j = hash_to_old.get(int(h))
        if j is None:
            j = len(old) + len(extra)
            extra.append(h)
        ref_remap.append(j)
    np.testing.assert_array_equal(remap, ref_remap)
    np.testing.assert_array_equal(
        merged, np.concatenate([old, np.array(extra, np.uint32)]))
    # empty-old edge
    merged2, remap2 = build.merge_vocab(np.zeros(0, np.uint32), new)
    np.testing.assert_array_equal(merged2, new)
    np.testing.assert_array_equal(remap2, np.arange(len(new)))
    # all-found edge
    merged3, remap3 = build.merge_vocab(old, old[::-1].copy())
    np.testing.assert_array_equal(merged3, old)
    np.testing.assert_array_equal(remap3, [2, 1, 0])


def test_add_documents_with_new_terms_matches_legacy_merge():
    """The live-index compat wrapper reproduces the legacy one-shot
    merge exactly, including vocabulary growth (new hashes appended)."""
    tc1 = corpus.generate(corpus.CorpusSpec(num_docs=80, vocab=250,
                                            avg_distinct=15, seed=7))
    tc2 = corpus.generate(corpus.CorpusSpec(num_docs=50, vocab=290,
                                            avg_distinct=15, seed=8))
    host = build.bulk_build(tc1)
    got = build.add_documents(host, tc2)               # wrapper path
    ref = build._merge_documents(host, tc2, host.num_docs)  # legacy path
    np.testing.assert_array_equal(got.term_hashes, ref.term_hashes)
    assert got.num_terms > host.num_terms              # vocab grew
    np.testing.assert_array_equal(got.df, ref.df)
    np.testing.assert_array_equal(got.offsets, ref.offsets)
    np.testing.assert_array_equal(got.doc_ids, ref.doc_ids)
    np.testing.assert_allclose(got.tfs, ref.tfs)
    np.testing.assert_allclose(got.norm, ref.norm, rtol=1e-6)


def test_corpus_stats(small_host):
    st = build.corpus_stats(small_host)
    assert st.D == small_host.num_docs
    assert st.W == small_host.num_terms
    assert st.N_d == small_host.num_postings
    assert st.N_d >= st.W     # the paper's key inequality premise


def test_direct_vs_scan_expansion(small_host, query_hashes):
    """§4.4: direct-index expansion == full-scan expansion (fast vs slow)."""
    ix = layouts.build_csr(small_host)
    cap = small_host.max_posting_len
    r = query.score_query(ix, jnp.asarray(query_hashes[0]), k=5, cap=cap)
    di = direct_index.build_direct(small_host)
    fast = direct_index.expand_query(di, r.doc_ids, small_host.num_terms,
                                     cap=di.max_doc_len)
    slow = direct_index.expand_query_scan(ix, r.doc_ids,
                                          small_host.num_terms)
    np.testing.assert_allclose(np.asarray(fast.weights),
                               np.asarray(slow.weights), rtol=1e-5)
    assert np.asarray(fast.term_ids).tolist() == \
        np.asarray(slow.term_ids).tolist()


def test_relevance_feedback(small_host, query_hashes):
    di = direct_index.build_direct(small_host)
    ix = layouts.build_csr(small_host)
    q = jnp.asarray(query_hashes[0])
    tids = ix.lookup_terms(q)
    r = query.score_query(ix, q, k=3, cap=small_host.max_posting_len)
    fb = direct_index.relevance_feedback(di, r.doc_ids, tids,
                                         small_host.num_terms,
                                         cap=di.max_doc_len)
    assert (np.asarray(fb.weights) >= 0).all()
    assert (np.asarray(fb.term_ids) >= -1).all()
