"""Per-arch smoke tests: every assigned architecture, reduced config,
one real forward/train step on CPU, asserting shapes + finite outputs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import gnn as gnn_lib
from repro.models import recsys as rec_lib
from repro.models import transformer as tfm
from repro.train import data as data_lib
from repro.train import optimizer as opt_lib

LM_ARCHS = ["gemma3-4b", "minicpm3-4b", "qwen3-0.6b", "mixtral-8x7b",
            "mixtral-8x22b"]
REC_ARCHS = ["sasrec", "bert4rec", "dien", "xdeepfm"]


def _init_for(arch, cfg, key):
    if arch.kind == "lm":
        return tfm.init_params(key, cfg)
    if arch.kind == "gnn":
        return gnn_lib.init_params(key, cfg)
    return {"sasrec": rec_lib.init_sasrec, "bert4rec": rec_lib.init_bert4rec,
            "dien": rec_lib.init_dien,
            "xdeepfm": rec_lib.init_xdeepfm}[arch.arch_id](key, cfg)


def _batch_for(arch, cfg, shp, seed=0):
    if arch.kind == "lm":
        return data_lib.lm_batch(seed, 0, shp["batch"], shp["seq"],
                                 cfg.vocab)
    if arch.kind == "gnn":
        if shp.get("graph_level"):
            return data_lib.molecule_batch(seed, 0, shp["n_graphs"],
                                           shp["n_nodes"] // shp["n_graphs"],
                                           shp["n_edges"] // shp["n_graphs"],
                                           cfg.d_feat, cfg.n_classes)
        g = data_lib.make_synthetic_graph(shp["n_nodes"], shp["n_edges"],
                                          cfg.d_feat, cfg.n_classes, seed)
        return data_lib.fullgraph_batch(g, seed=seed)
    aid = arch.arch_id
    if aid == "sasrec":
        return data_lib.sasrec_batch(seed, 0, shp["batch"], cfg.seq_len,
                                     cfg.n_items, cfg.n_negatives)
    if aid == "bert4rec":
        return data_lib.bert4rec_batch(seed, 0, shp["batch"], cfg.seq_len,
                                       cfg.n_items, cfg.n_negatives)
    if aid == "dien":
        return data_lib.dien_batch(seed, 0, shp["batch"], cfg.seq_len,
                                   cfg.n_items)
    return data_lib.xdeepfm_batch(seed, 0, shp["batch"], cfg.n_fields,
                                  cfg.field_vocab, cfg.n_hot)


@pytest.mark.parametrize("arch_id", list(configs.ARCHS))
def test_train_step_smoke(arch_id):
    """One REAL train step (init'd params + AdamW) per arch."""
    arch = configs.get_arch(arch_id)
    shape_id = next(s for s, v in arch.smoke_shapes.items()
                    if v.get("step", "train") == "train"
                    or arch.kind == "gnn")
    shp = arch.smoke_shapes[shape_id]
    cfg = arch.make_config("smoke", shape_id)
    params = _init_for(arch, cfg, jax.random.PRNGKey(0))
    batch = jax.tree.map(jnp.asarray, _batch_for(arch, cfg, shp))

    if arch.kind == "lm":
        loss_fn = lambda p, b: tfm.loss_fn(p, cfg, b)       # noqa: E731
    elif arch.kind == "gnn":
        loss_fn = ((lambda p, b: gnn_lib.graph_loss(p, cfg, b))
                   if shp.get("graph_level")
                   else (lambda p, b: gnn_lib.node_loss(p, cfg, b)))
    else:
        lf = {"sasrec": rec_lib.sasrec_loss,
              "bert4rec": rec_lib.bert4rec_loss,
              "dien": rec_lib.dien_loss,
              "xdeepfm": rec_lib.xdeepfm_loss}[arch_id]
        loss_fn = lambda p, b: lf(p, cfg, b)                # noqa: E731

    step = jax.jit(opt_lib.make_train_step(
        loss_fn, opt_lib.AdamWConfig(lr=1e-3, warmup_steps=1,
                                     total_steps=10)))
    new_p, new_s, metrics = step(params, opt_lib.init(params), batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), (arch_id, loss)
    for leaf in jax.tree.leaves(new_p):
        assert np.isfinite(np.asarray(leaf)).all(), arch_id
    # params actually moved
    moved = any(not np.allclose(np.asarray(a), np.asarray(b))
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(new_p)))
    assert moved, arch_id


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_lm_serve_smoke(arch_id):
    """prefill + decode consistency for every LM arch (reduced config).

    MoE capacity is raised so it does not bind: capacity-based MoE is
    inherently batch-dependent (drop patterns differ between the 15- and
    16-token prefills), which is a property, not a bug — the equivalence
    being tested is the attention/cache path.
    """
    import dataclasses
    arch = configs.get_arch(arch_id)
    cfg = arch.make_config("smoke", "decode_32k")
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    params = tfm.init_params(jax.random.PRNGKey(1), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, cfg.vocab)
    pr_full = jax.jit(lambda p: tfm.prefill(p, cfg, toks))(params)
    pr_part = jax.jit(lambda p: tfm.prefill(p, cfg, toks[:, :15]))(params)
    cache = tfm.pad_cache(pr_part.cache, 16, cfg)
    logits, _, _ = jax.jit(
        lambda p, c: tfm.decode_step(p, cfg, c, toks[:, 15:16],
                                     pr_part.cache_len))(params, cache)
    a, b = np.asarray(logits), np.asarray(pr_full.logits)
    rel = np.abs(a - b).max() / (np.abs(b).max() + 1e-9)
    # MLA decode uses the absorbed form (different bf16 contraction order)
    tol = 2e-2 if cfg.attn == "mla" else 1e-3
    assert rel < tol, (arch_id, rel)
    assert np.isfinite(a).all()


@pytest.mark.parametrize("arch_id", REC_ARCHS)
def test_recsys_serve_and_retrieval_smoke(arch_id):
    arch = configs.get_arch(arch_id)
    for shape_id in ("serve_p99", "retrieval_cand"):
        cell = arch.cell(shape_id, scale="smoke")
        cfg = arch.make_config("smoke", shape_id)
        params = _init_for(arch, cfg, jax.random.PRNGKey(3))
        rng = np.random.default_rng(0)
        rest = []
        for a in cell.abstract_args[1:]:
            rest.append(jax.tree.map(
                lambda x: jnp.asarray(
                    rng.integers(0, 50, x.shape).astype(np.int32))
                if x.dtype == jnp.int32
                else jnp.asarray(rng.normal(size=x.shape).astype(np.float32)),
                a))
        out = jax.jit(cell.fn)(params, *rest)
        for leaf in jax.tree.leaves(out):
            arr = np.asarray(leaf)
            if arr.dtype.kind == "f":
                assert np.isfinite(arr).all(), (arch_id, shape_id)


def test_gemma3_local_global_pattern():
    cfg = configs.get_arch("gemma3-4b").make_config("full")
    pat = np.asarray(cfg.layer_is_global())
    assert pat.sum() == 34 // 6               # every 6th layer is global
    assert not pat[:5].any() and pat[5]       # 5 local then 1 global


def test_moe_capacity_drops_tokens():
    """Over-capacity tokens are dropped, not mis-routed."""
    cfg = tfm.MoeConfig(n_experts=2, top_k=1, capacity_factor=0.25,
                        groups=1)
    prm = {
        "router": jnp.asarray(np.eye(8, 2, dtype=np.float32) * 10),
        "w_gate": jnp.asarray(np.random.default_rng(0).normal(
            size=(2, 8, 16)).astype(np.float32)),
        "w_up": jnp.asarray(np.random.default_rng(1).normal(
            size=(2, 8, 16)).astype(np.float32)),
        "w_down": jnp.asarray(np.random.default_rng(2).normal(
            size=(2, 16, 8)).astype(np.float32)),
    }
    x = jnp.asarray(np.random.default_rng(3).normal(
        size=(16, 8)).astype(np.float32))
    out = tfm._moe_ffn(prm, x, cfg, jnp.float32)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    # capacity 0.25 * 16 / 2 = 2 slots/expert -> most tokens dropped (zero)
    zeros = (np.abs(np.asarray(out)).sum(-1) == 0).sum()
    assert zeros >= 8


def test_ring_cache_matches_full_cache():
    """SWA ring cache (window-sized) decodes identically to a full-length
    cache once the window wraps — the layout cut is semantics-free."""
    import dataclasses
    cfg_full = tfm.TransformerConfig(
        name="swa", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
        head_dim=16, d_ff=64, vocab=128, window=8, global_every=0,
        chunk_q=8, loss_chunk=8, ring_cache=False)
    cfg_ring = dataclasses.replace(cfg_full, ring_cache=True)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg_full)
    B, steps = 2, 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, steps), 0, 128)

    def run(cfg, slots):
        cache = tfm.init_cache(cfg, B, slots)
        cl = jnp.zeros((B,), jnp.int32)
        outs = []
        step = jax.jit(lambda c, t, l: tfm.decode_step(params, cfg, c, t, l))
        for i in range(steps):
            logits, cache, cl = step(cache, toks[:, i:i + 1], cl)
            outs.append(np.asarray(logits))
        return np.stack(outs)

    full = run(cfg_full, steps)
    ring = run(cfg_ring, steps)          # allocates only `window` slots
    assert tfm.cache_slots(cfg_ring, steps) == 8
    np.testing.assert_allclose(ring, full, rtol=2e-3, atol=2e-3)


def test_bucketed_retrieval_recall():
    """The sort-free bucketed top-k (used for sharded serving) must keep
    high recall vs exact top-k, and the iterative top-k must be EXACT."""
    from repro.models import recsys
    rng = np.random.default_rng(0)
    uv = jnp.asarray(rng.normal(size=(8, 16)).astype(np.float32))
    cand = jnp.asarray(rng.normal(size=(4096, 16)).astype(np.float32))
    k = 32
    exact_v, exact_i = jax.lax.top_k(uv @ cand.T, k)

    # iterative_topk is exact
    it_v, it_i = recsys.iterative_topk(jnp.asarray(uv @ cand.T), k)
    np.testing.assert_allclose(np.asarray(it_v), np.asarray(exact_v),
                               rtol=1e-6)

    # bucketed pipeline (chunked path): measure recall@k
    with jax.make_mesh((1,), ("data",)):
        bk_v, bk_i = recsys.retrieval_topk(uv, cand, k=k, chunk=512,
                                           batch_axes=("data",))
    recall = np.mean([
        len(set(np.asarray(bk_i[b]).tolist()) &
            set(np.asarray(exact_i[b]).tolist())) / k
        for b in range(8)])
    assert recall >= 0.85, recall
    # and every returned score must be a TRUE score of its returned id
    full = np.asarray(uv @ cand.T)
    for b in range(8):
        for v, i in zip(np.asarray(bk_v[b]), np.asarray(bk_i[b])):
            np.testing.assert_allclose(v, full[b, i], rtol=1e-5)
